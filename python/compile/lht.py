"""LHT — the tiny tensor interchange format between Python and Rust.

Layout (little-endian):
  magic  4 bytes  b"LHT1"
  dtype  u8       0 = f32, 1 = i32, 2 = u8
  ndim   u8
  dims   ndim x u32
  data   raw little-endian values, row-major

Writer here; reader/writer twin in ``rust/src/runtime/artifact.rs``.
"""

from __future__ import annotations

import struct
from pathlib import Path

import numpy as np

MAGIC = b"LHT1"
_DTYPES = {0: np.float32, 1: np.int32, 2: np.uint8}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1, np.dtype(np.uint8): 2}


def write(path: str | Path, arr: np.ndarray) -> None:
    arr = np.ascontiguousarray(arr)
    code = _CODES.get(arr.dtype)
    if code is None:
        raise TypeError(f"unsupported dtype {arr.dtype}")
    with open(path, "wb") as fh:
        fh.write(MAGIC)
        fh.write(struct.pack("<BB", code, arr.ndim))
        fh.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
        fh.write(arr.astype(arr.dtype.newbyteorder("<")).tobytes())


def read(path: str | Path) -> np.ndarray:
    with open(path, "rb") as fh:
        if fh.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        code, ndim = struct.unpack("<BB", fh.read(2))
        dims = struct.unpack(f"<{ndim}I", fh.read(4 * ndim))
        dtype = np.dtype(_DTYPES[code]).newbyteorder("<")
        data = np.frombuffer(fh.read(), dtype=dtype)
    return data.reshape(dims).astype(_DTYPES[code])

"""L2: the LogHD inference/training compute graphs, composed from L1 kernels.

Each public function here is a pure JAX function over concrete arrays; the
AOT driver (:mod:`compile.aot`) lowers the ``*_graph`` entries to HLO text
for the Rust runtime. Model tensors (encoder weights, bundles, profiles,
prototypes) are *graph inputs*, not baked constants — the Rust coordinator
owns them as data, which is what lets it inject bit-flip faults into the
stored model between evaluations exactly as the paper's protocol requires
(§IV-A) without recompiling.

Shapes (serving convention):
  x: (B, F)   queries                w: (F, D)  encoder projection
  b: (D,)     encoder phase          m: (n, D)  bundles (unit rows)
  p: (C, n)   activation profiles    h: (C, D)  prototypes (unit rows)
"""

from __future__ import annotations

import jax.numpy as jnp

from . import kernels


def encode_graph(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 mu: jnp.ndarray) -> jnp.ndarray:
    """Centered encoding phi(x) - mu: (B, F) -> (B, D).

    ``mu`` is the training-set mean encoding. Centering removes the large
    common (DC) component the cosine random-projection encoder introduces;
    without it bundle activations are dominated by shared energy and the
    activation space collapses (see DESIGN.md §Centering).
    """
    return kernels.encode(x, w, b) - mu.reshape(1, -1)


def loghd_activations(x, w, b, mu, m) -> jnp.ndarray:
    """Encode + cosine activations against the n bundles (Eq. 5): (B, n)."""
    return kernels.activations(encode_graph(x, w, b, mu), m)


def infer_loghd_graph(x, w, b, mu, m, p):
    """Full LogHD inference (Algorithm 1 step 6).

    Returns (dists, labels): (B, C) squared activation-space distances and
    (B,) argmin class ids (i32).
    """
    a = loghd_activations(x, w, b, mu, m)
    dists = kernels.decode_dists(a, p)
    return dists, jnp.argmin(dists, axis=1).astype(jnp.int32)


def infer_conventional_graph(x, w, b, mu, h):
    """Conventional HDC inference: cosine argmax over C prototypes.

    Also serves SparseHD: a dimension-masked prototype matrix (zeros on
    pruned coordinates, rows re-normalized over retained ones) changes only
    the weights, not the graph — the query norm is shared across classes so
    the argmax is unaffected by restricting it to retained dimensions.

    Returns (scores, labels): (B, C) cosine scores, (B,) argmax ids (i32).
    """
    scores = kernels.activations(encode_graph(x, w, b, mu), h)
    return scores, jnp.argmax(scores, axis=1).astype(jnp.int32)


def refine_step(m: jnp.ndarray, enc: jnp.ndarray, tau: jnp.ndarray, eta: float) -> jnp.ndarray:
    """One batched refinement step (Eq. 9) over a minibatch.

    m: (n, D) unit bundles; enc: (B, D) encoded batch; tau: (B, n) targets
    t(B_{y,j}) for each sample's class. Returns the re-normalized bundles.
    """
    a = kernels.activations(enc, m)  # (B, n)
    coef = (eta * (tau - a)).T  # (n, B)
    m2 = m + kernels.refine_delta(coef, enc)
    norms = jnp.sqrt(jnp.sum(m2 * m2, axis=1, keepdims=True))
    return m2 / jnp.maximum(norms, 1e-12)

"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written in
plain ``jax.numpy``. The pytest suite (``python/tests/``) sweeps shapes,
seeds, and dtypes with hypothesis and asserts ``allclose`` between each
kernel and its oracle — this is the L1 correctness signal for the whole
stack (the Rust runtime executes HLO lowered from graphs that call the
kernels, so kernel==ref implies the served numerics match the math in the
paper's Algorithm 1).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "encode_ref",
    "activation_ref",
    "cosine_scores_ref",
    "decode_ref",
    "refine_delta_ref",
]


def encode_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Random-projection cosine encoder phi(x) = cos(x @ W + b).

    x: (B, F) float32, w: (F, D) float32, b: (D,) or (1, D) float32.
    Returns (B, D) float32.
    """
    return jnp.cos(jnp.dot(x, w, preferred_element_type=jnp.float32) + b.reshape(1, -1))


def activation_ref(enc: jnp.ndarray, m: jnp.ndarray) -> jnp.ndarray:
    """Cosine activations A(x) (paper Eq. 5) against *pre-normalized* rows m.

    enc: (B, D) raw encodings; m: (n, D) with unit-L2 rows.
    Returns (B, n): <enc/|enc|, m_j>.
    """
    dots = jnp.dot(enc, m.T, preferred_element_type=jnp.float32)
    qn = jnp.sqrt(jnp.sum(enc * enc, axis=1, keepdims=True))
    return dots / jnp.maximum(qn, 1e-12)


def cosine_scores_ref(enc: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Conventional-HDC scores: cosine similarity to every class prototype.

    Identical math to activation_ref (prototypes pre-normalized); kept as a
    separate named oracle because L2 uses it on the (C, D) prototype matrix.
    """
    return activation_ref(enc, h)


def decode_ref(a: jnp.ndarray, p: jnp.ndarray) -> jnp.ndarray:
    """Squared-Euclidean profile decoding (paper Eq. 7).

    a: (B, n) activations, p: (C, n) class profiles.
    Returns (B, C) squared distances  ||A - P_c||^2.
    """
    an = jnp.sum(a * a, axis=1, keepdims=True)  # (B, 1)
    pn = jnp.sum(p * p, axis=1)  # (C,)
    cross = jnp.dot(a, p.T, preferred_element_type=jnp.float32)  # (B, C)
    return an - 2.0 * cross + pn.reshape(1, -1)


def refine_delta_ref(coef: jnp.ndarray, enc: jnp.ndarray) -> jnp.ndarray:
    """Batched perceptron-style bundle update (paper Eq. 9).

    coef: (n, B) = eta * (tau_j^(y_i) - A_j(x_i)); enc: (B, D).
    Returns (n, D): the additive bundle delta  coef @ enc.
    """
    return jnp.dot(coef, enc, preferred_element_type=jnp.float32)

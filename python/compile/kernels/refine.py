"""Pallas kernel: batched bundle refinement delta  Delta M = coef @ enc.

Paper Eq. 9 updates each bundle with a perceptron-style correction
  M_j += eta * (tau_j^(y) - A_j) * phi(x).
For a minibatch, the per-sample coefficients eta*(tau - A) form an (n, B)
matrix and the summed update over the batch is the rank-B product
coef @ enc — once again an MXU matmul. The kernel tiles D: each grid step
reads one (B, BLOCK_D) encoding tile and emits one (n, BLOCK_D) delta tile;
the small coef matrix stays VMEM-resident across all steps. L2 computes the
coefficients (via the activation kernel) and applies
M <- normalize(M + Delta M).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_block


def _refine_kernel(coef_ref, enc_ref, o_ref):
    # coef_ref: (n, B) — same block every step; enc_ref: (B, BLOCK_D).
    o_ref[...] = jnp.dot(coef_ref[...], enc_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_d",))
def refine_delta(coef: jnp.ndarray, enc: jnp.ndarray, *, block_d: int | None = None) -> jnp.ndarray:
    """Additive bundle delta for one minibatch.

    coef: (n, B) = eta * (tau - A)^T; enc: (B, D). Returns (n, D).
    """
    n, bsz = coef.shape
    bsz2, d = enc.shape
    assert bsz == bsz2, f"batch mismatch {bsz} vs {bsz2}"
    bd = block_d or pick_block(d)
    assert d % bd == 0
    return pl.pallas_call(
        _refine_kernel,
        grid=(d // bd,),
        in_specs=[
            pl.BlockSpec((n, bsz), lambda j: (0, 0)),
            pl.BlockSpec((bsz, bd), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((n, bd), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=INTERPRET,
    )(coef, enc)

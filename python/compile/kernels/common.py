"""Shared tiling helpers for the Pallas kernels.

All kernels tile the hypervector axis D into VMEM-sized blocks. On a real
TPU the block would be a multiple of the 128-lane register width and sized
so that every operand tile fits in the ~16 MB VMEM scratchpad (see
DESIGN.md §Hardware-Adaptation for the budget arithmetic). Under
``interpret=True`` (the only mode the CPU PJRT plugin can execute) tile
shape only affects structure, not speed, so we simply pick the largest
divisor of D below the target width to keep index maps exact (no masking).
"""

from __future__ import annotations

INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls; see DESIGN.md.

# Target tile width along D. 512 f32 lanes x (B=64 + F<=640 + n<=32) rows
# stays well under the 16 MB VMEM budget for every graph we lower.
TARGET_BLOCK_D = 512


def pick_block(d: int, target: int = TARGET_BLOCK_D) -> int:
    """Largest divisor of ``d`` that is <= ``target`` (>=1).

    Keeps the grid exact (d % block == 0) so BlockSpec index maps need no
    out-of-bounds masking in interpret mode.
    """
    if d <= target:
        return d
    for block in range(target, 0, -1):
        if d % block == 0:
            return block
    return 1

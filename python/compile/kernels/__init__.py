"""L1: Pallas kernels for LogHD's compute hot-spots.

Four kernels cover the paper's entire inference + refinement datapath:

- :mod:`encode`     — phi(x) = cos(xW + b), the (B,F)x(F,D) MXU matmul.
- :mod:`activation` — fused cosine activations A_j (Eq. 5) / HDC scores.
- :mod:`decode`     — nearest-profile squared distances (Eq. 7).
- :mod:`refine`     — batched perceptron bundle delta (Eq. 9).

All are lowered with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); see DESIGN.md §Hardware-Adaptation for the TPU tiling
rationale and :mod:`ref` for the pure-jnp oracles used by pytest.
"""

from .activation import activations
from .decode import decode_dists
from .encode import encode
from .refine import refine_delta

__all__ = ["encode", "activations", "decode_dists", "refine_delta"]

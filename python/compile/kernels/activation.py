"""Pallas kernel: fused cosine activations  A_j = <enc/|enc|, M_j>.

Computes the paper's Eq. 5 activation vector (and, with the class-prototype
matrix as ``m``, the conventional-HDC cosine score vector) in a single pass
over the encoded query: the D axis is tiled, and each grid step accumulates
both the per-bundle partial dot products AND the query's squared norm into
VMEM-resident accumulators (output blocks whose index map is constant along
the D grid axis). The division by the query norm happens once in the final
grid step — the query row never makes a second trip through HBM, which is
the fusion the paper's ASIC datapath gets from its dedicated
similarity units.

Bundle rows (M_j, or prototypes H_c) are expected to be pre-normalized, as
Algorithm 1 prescribes after construction and after every refinement step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_block


def _activation_kernel(q_ref, m_ref, dot_ref, qn_ref, *, steps: int):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        dot_ref[...] = jnp.zeros_like(dot_ref)
        qn_ref[...] = jnp.zeros_like(qn_ref)

    q = q_ref[...]  # (B, BLOCK_D)
    m = m_ref[...]  # (n, BLOCK_D)
    dot_ref[...] += jnp.dot(q, m.T, preferred_element_type=jnp.float32)
    qn_ref[...] += jnp.sum(q * q, axis=1, keepdims=True)

    @pl.when(j == steps - 1)
    def _finalize():
        dot_ref[...] = dot_ref[...] / jnp.maximum(jnp.sqrt(qn_ref[...]), 1e-12)


@functools.partial(jax.jit, static_argnames=("block_d",))
def activations(enc: jnp.ndarray, m: jnp.ndarray, *, block_d: int | None = None) -> jnp.ndarray:
    """Cosine activations against pre-normalized rows.

    enc: (B, D) raw encodings; m: (n, D) unit rows. Returns (B, n).
    """
    bsz, d = enc.shape
    n, d2 = m.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    bd = block_d or pick_block(d)
    assert d % bd == 0
    steps = d // bd
    kern = functools.partial(_activation_kernel, steps=steps)
    dots, _qn = pl.pallas_call(
        kern,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((bsz, bd), lambda j: (0, j)),
            pl.BlockSpec((n, bd), lambda j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bsz, n), lambda j: (0, 0)),
            pl.BlockSpec((bsz, 1), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, n), jnp.float32),
            jax.ShapeDtypeStruct((bsz, 1), jnp.float32),
        ],
        interpret=INTERPRET,
    )(enc, m)
    return dots

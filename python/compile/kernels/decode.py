"""Pallas kernel: profile decoding  ||A - P_c||^2 for every class c.

The paper's Eq. 7 nearest-profile decode, expanded into MXU-friendly form
  ||A||^2 - 2 A P^T + ||P_c||^2
so the (B, n) x (n, C) cross term runs as a matmul and the row norms fuse
into the same VMEM pass. The operands are tiny (n <= ~16, C <= a few
hundred) so a single grid step holds everything; the value of doing this in
a kernel is avoiding an extra HBM round-trip between the activation stage
and the decode stage when the full inference graph is lowered.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET


def _decode_kernel(a_ref, p_ref, o_ref):
    a = a_ref[...]  # (B, n)
    p = p_ref[...]  # (C, n)
    an = jnp.sum(a * a, axis=1, keepdims=True)  # (B, 1)
    pn = jnp.sum(p * p, axis=1)[None, :]  # (1, C)
    cross = jnp.dot(a, p.T, preferred_element_type=jnp.float32)  # (B, C)
    o_ref[...] = an - 2.0 * cross + pn


@jax.jit
def decode_dists(a: jnp.ndarray, p: jnp.ndarray) -> jnp.ndarray:
    """Squared Euclidean distances in activation space.

    a: (B, n) activations; p: (C, n) per-class profiles. Returns (B, C).
    """
    bsz, n = a.shape
    c, n2 = p.shape
    assert n == n2, f"profile width {n2} != activation width {n}"
    return pl.pallas_call(
        _decode_kernel,
        out_shape=jax.ShapeDtypeStruct((bsz, c), jnp.float32),
        interpret=INTERPRET,
    )(a, p)

"""Pallas kernel: random-projection cosine encoder  phi(x) = cos(xW + b).

This is the single most FLOP-heavy stage of the whole pipeline
(B x F x D MACs per batch; D = 10,000 in the paper's configuration), and is
the classic MXU shape: a (B, F) x (F, D) matmul. The kernel tiles the D
axis: each grid step holds the full (B, F) input tile, one (F, BLOCK_D)
weight tile and one (1, BLOCK_D) bias tile in VMEM, accumulates the matmul
in f32 on the MXU, applies the cosine nonlinearity in-register, and writes
the (B, BLOCK_D) output tile back to HBM exactly once — the schedule a CUDA
implementation would express with threadblocks is expressed here with the
grid + BlockSpec index maps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_block


def _encode_kernel(x_ref, w_ref, b_ref, o_ref):
    # x_ref: (B, F) — full input tile, identical for every grid step.
    # w_ref: (F, BLOCK_D), b_ref: (1, BLOCK_D), o_ref: (B, BLOCK_D).
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = jnp.cos(acc + b_ref[...])


@functools.partial(jax.jit, static_argnames=("block_d",))
def encode(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, *, block_d: int | None = None) -> jnp.ndarray:
    """phi(x) = cos(x @ W + b) via the tiled Pallas kernel.

    x: (B, F) f32; w: (F, D) f32; b: (D,) f32. Returns (B, D) f32.
    """
    bsz, f = x.shape
    f2, d = w.shape
    assert f == f2, f"feature mismatch {f} vs {f2}"
    assert b.shape == (d,), f"bias shape {b.shape} != ({d},)"
    bd = block_d or pick_block(d)
    assert d % bd == 0, f"block {bd} must divide D={d}"
    b2 = b.reshape(1, d)
    grid = (d // bd,)
    return pl.pallas_call(
        _encode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bsz, f), lambda j: (0, 0)),
            pl.BlockSpec((f, bd), lambda j: (0, j)),
            pl.BlockSpec((1, bd), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bsz, bd), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, d), jnp.float32),
        interpret=INTERPRET,
    )(x, w, b2)

"""AOT driver: train the serving models and emit the Rust-loadable artifacts.

Runs once under ``make artifacts`` (a no-op if artifacts are newer than the
Python sources). For every serving config this writes, under
``artifacts/<config>/``:

- ``manifest.json``      — entry points, tensor files, shapes, clean accs
- ``<entry>.hlo.txt``    — HLO *text* per inference graph (the interchange
  format: jax >= 0.5 emits protos with 64-bit instruction ids that
  xla_extension 0.5.1 rejects; the text parser reassigns ids — see
  /opt/xla-example/README.md)
- ``*.lht``              — model tensors + held-out test data + expected
  outputs of the first batch (Rust parity tests compare against these)

Python never runs again after this: the Rust binary is self-contained.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import data as dt
from . import lht
from . import model
from . import trainer


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    name: str
    dataset: str
    d: int
    k: int
    extra_bundles: int
    epochs: int
    batch: int = 64


# page_smoke is small/fast and drives the Rust integration tests;
# isolet_k2 is the paper's headline serving configuration (D=10k, k=2).
CONFIGS: dict[str, ServingConfig] = {
    c.name: c
    for c in [
        ServingConfig("page_smoke", "page", d=2000, k=2, extra_bundles=1, epochs=5),
        # n = ceil(log2 26) + 5 = 10 bundles: the paper's mid memory budget
        # (<= 0.4 of C*D) for ISOLET in Fig. 3.
        ServingConfig("isolet_k2", "isolet", d=10_000, k=2, extra_bundles=5, epochs=30),
    ]
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_entries(cfg: ServingConfig, f: int, c: int, n: int) -> dict[str, dict]:
    """Lower each serving graph at the config's fixed shapes."""
    b, d = cfg.batch, cfg.d
    entries = {}

    lowered = jax.jit(model.infer_loghd_graph).lower(
        _spec((b, f)), _spec((f, d)), _spec((d,)), _spec((d,)), _spec((n, d)),
        _spec((c, n)))
    entries["infer_loghd"] = {
        "hlo": to_hlo_text(lowered),
        "inputs": [["x", [b, f], "f32"], ["w", [f, d], "f32"], ["b", [d], "f32"],
                   ["mu", [d], "f32"], ["bundles", [n, d], "f32"],
                   ["profiles", [c, n], "f32"]],
        "outputs": [["dists", [b, c], "f32"], ["labels", [b], "i32"]],
    }

    lowered = jax.jit(model.infer_conventional_graph).lower(
        _spec((b, f)), _spec((f, d)), _spec((d,)), _spec((d,)), _spec((c, d)))
    entries["infer_conventional"] = {
        "hlo": to_hlo_text(lowered),
        "inputs": [["x", [b, f], "f32"], ["w", [f, d], "f32"], ["b", [d], "f32"],
                   ["mu", [d], "f32"], ["prototypes", [c, d], "f32"]],
        "outputs": [["scores", [b, c], "f32"], ["labels", [b], "i32"]],
    }

    lowered = jax.jit(model.encode_graph).lower(
        _spec((b, f)), _spec((f, d)), _spec((d,)), _spec((d,)))
    entries["encode"] = {
        "hlo": to_hlo_text(lowered),
        "inputs": [["x", [b, f], "f32"], ["w", [f, d], "f32"], ["b", [d], "f32"],
                   ["mu", [d], "f32"]],
        "outputs": [["enc", [b, d], "f32"]],
    }
    return entries


def build_config(cfg: ServingConfig, out_root: Path) -> dict:
    t0 = time.time()
    ds = dt.by_name(cfg.dataset)
    spec = ds.spec
    tc = trainer.TrainConfig(d=cfg.d, k=cfg.k, extra_bundles=cfg.extra_bundles,
                             epochs=cfg.epochs, batch=cfg.batch)
    print(f"[aot] {cfg.name}: training on {spec.name} "
          f"(F={spec.features} C={spec.classes} D={cfg.d} k={cfg.k})", flush=True)
    tm = trainer.train_all(ds.x_train, ds.y_train, ds.x_test, ds.y_test,
                           spec.classes, tc)
    print(f"[aot] {cfg.name}: clean acc conventional={tm.clean_acc_conventional:.4f} "
          f"loghd={tm.clean_acc_loghd:.4f} n={tm.n_bundles} "
          f"({time.time()-t0:.1f}s)", flush=True)

    out = out_root / cfg.name
    out.mkdir(parents=True, exist_ok=True)

    entries = lower_entries(cfg, spec.features, spec.classes, tm.n_bundles)
    manifest_entries = []
    for name, e in entries.items():
        (out / f"{name}.hlo.txt").write_text(e["hlo"])
        manifest_entries.append({
            "name": name, "hlo": f"{name}.hlo.txt",
            "inputs": e["inputs"], "outputs": e["outputs"],
        })

    tensors = {
        "w": tm.w, "b": tm.b, "mu": tm.mu, "prototypes": tm.prototypes,
        "bundles": tm.bundles, "profiles": tm.profiles,
        "codebook": tm.codebook.astype(np.int32),
        "x_test": ds.x_test, "y_test": ds.y_test.astype(np.int32),
    }
    for name, arr in tensors.items():
        lht.write(out / f"{name}.lht", arr)

    # Expected outputs for the first test batch: the Rust runtime parity
    # test executes the compiled HLO on the same inputs and compares.
    xb = ds.x_test[:cfg.batch]
    dists, labels = model.infer_loghd_graph(
        jnp.asarray(xb), tm.w, tm.b, tm.mu, tm.bundles, tm.profiles)
    lht.write(out / "expected_dists.lht", np.asarray(dists))
    lht.write(out / "expected_labels.lht", np.asarray(labels).astype(np.int32))
    scores, clabels = model.infer_conventional_graph(
        jnp.asarray(xb), tm.w, tm.b, tm.mu, tm.prototypes)
    lht.write(out / "expected_conv_scores.lht", np.asarray(scores))
    lht.write(out / "expected_conv_labels.lht", np.asarray(clabels).astype(np.int32))

    manifest = {
        "format": 1,
        "config": {
            "name": cfg.name, "dataset": spec.name, "D": cfg.d, "k": cfg.k,
            "n": tm.n_bundles, "C": spec.classes, "F": spec.features,
            "batch": cfg.batch, "extra_bundles": cfg.extra_bundles,
        },
        "clean_accuracy": {
            "conventional": tm.clean_acc_conventional,
            "loghd": tm.clean_acc_loghd,
        },
        "entries": manifest_entries,
        "tensors": {name: f"{name}.lht" for name in tensors},
        "expected": {
            "batch": cfg.batch,
            "dists": "expected_dists.lht", "labels": "expected_labels.lht",
            "conv_scores": "expected_conv_scores.lht",
            "conv_labels": "expected_conv_labels.lht",
        },
    }
    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"[aot] {cfg.name}: wrote {out} ({time.time()-t0:.1f}s total)", flush=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact root dir")
    ap.add_argument("--configs", default=",".join(CONFIGS),
                    help="comma-separated serving config names")
    args = ap.parse_args()
    out_root = Path(args.out)
    out_root.mkdir(parents=True, exist_ok=True)
    names = [n for n in args.configs.split(",") if n]
    index = {}
    for name in names:
        manifest = build_config(CONFIGS[name], out_root)
        index[name] = {"dir": name, "dataset": manifest["config"]["dataset"]}
    (out_root / "index.json").write_text(json.dumps(index, indent=1))
    print(f"[aot] done: {', '.join(names)}")


if __name__ == "__main__":
    main()

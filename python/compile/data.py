"""Synthetic dataset generators with the shapes of the paper's Table I.

No network access exists in this environment, so the four UCI datasets are
substituted by seeded, class-structured anisotropic Gaussian mixtures with
the *exact* feature/class/sample-count shapes of Table I (PAMAP2's 611k
train set is scaled to 24k, documented in DESIGN.md). Separation constants
are calibrated so a conventional D=10k HDC classifier lands in the
85–95% clean-accuracy band the HDC literature reports for these datasets —
LogHD's claims concern model geometry and fault response, which these
generators exercise on the identical code paths.

Class geometry is *hierarchical*, matching how HDC-friendly real datasets
behave: G group centers (distinct letters/activities), C class means
scattered tightly around them (confusable variants), anisotropic per-class
noise. This yields high within-class encoding similarity with a realistic
band of confusable pairs — the regime in which both conventional decoding
and LogHD's activation-profile decoding operate in the paper.

The generator is mirrored **sample-for-sample** in ``rust/src/data/synth.rs``
via the shared SplitMix64 stream (see :mod:`compile.prng`); draw order is
part of the format contract:

    group centers (G*F normals) -> class offsets (C*F normals) ->
    scales (C*F uniforms) -> train labels (round-robin, Fisher–Yates
    shuffle) -> train noise (n_train*F normals, row-major) ->
    test labels -> test noise.

Group assignment is deterministic: class c belongs to group c mod G.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .prng import SplitMix64

SCALE_LO = 0.6
SCALE_HI = 1.4


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """Shape + difficulty of one synthetic dataset (paper Table I row)."""

    name: str
    features: int
    classes: int
    n_train: int
    n_test: int
    groups: int  # G group centers; class c -> group c mod G
    sep_class: float  # class-offset std around its group center
    sigma: float  # within-class noise scale
    seed: int
    description: str = ""


# (sep_class, sigma) calibrated at D=2000 (conventional HDC / LogHD n=min+5
# clean accuracy; see EXPERIMENTS.md §Datasets):
# isolet 0.993/0.79, ucihar 0.969/0.81, pamap2 0.929/0.86, page 0.870/0.84.
SPECS: dict[str, DatasetSpec] = {
    s.name: s
    for s in [
        DatasetSpec("isolet", 617, 26, 6238, 1559, groups=9,
                    sep_class=0.14, sigma=0.65, seed=0x150_1E7,
                    description="Voice recognition (ISOLET-like)"),
        DatasetSpec("ucihar", 261, 12, 6213, 1554, groups=4,
                    sep_class=0.16, sigma=0.70, seed=0x0C1_4A8,
                    description="Mobile activity recognition (UCIHAR-like)"),
        DatasetSpec("pamap2", 75, 5, 24000, 4000, groups=2,
                    sep_class=0.26, sigma=0.90, seed=0x9A3_A92,
                    description="IMU activity recognition (PAMAP2-like, 611k train scaled to 24k)"),
        DatasetSpec("page", 10, 5, 4925, 548, groups=2,
                    sep_class=1.00, sigma=1.40, seed=0x9A6_E00,
                    description="Page layout blocks (PAGE-like)"),
    ]
}


@dataclasses.dataclass
class Dataset:
    spec: DatasetSpec
    x_train: np.ndarray  # (n_train, F) f32
    y_train: np.ndarray  # (n_train,) i32
    x_test: np.ndarray  # (n_test, F) f32
    y_test: np.ndarray  # (n_test,) i32


def _split(rng: SplitMix64, means: np.ndarray, scales: np.ndarray, n: int, c: int, f: int):
    y = np.array([i % c for i in range(n)], dtype=np.int32)
    rng.shuffle(y)
    z = rng.normal(n * f).reshape(n, f)
    x = means[y] + scales[y] * z
    return x.astype(np.float32), y


def generate(spec: DatasetSpec) -> Dataset:
    """Materialize a dataset; deterministic in ``spec.seed``."""
    rng = SplitMix64(spec.seed)
    c, f, g = spec.classes, spec.features, spec.groups
    centers = rng.normal(g * f).reshape(g, f)
    offsets = rng.normal(c * f).reshape(c, f)
    means = centers[np.arange(c) % g] + spec.sep_class * offsets
    scales = spec.sigma * (SCALE_LO + (SCALE_HI - SCALE_LO)
                           * rng.uniform(c * f).reshape(c, f))
    x_train, y_train = _split(rng, means, scales, spec.n_train, c, f)
    x_test, y_test = _split(rng, means, scales, spec.n_test, c, f)
    return Dataset(spec, x_train, y_train, x_test, y_test)


def by_name(name: str) -> Dataset:
    return generate(SPECS[name])

"""Capacity-aware k-ary codebook construction (paper §III-C, Eq. 2/3).

Greedy minimax-load selection: classes are assigned unique length-n k-ary
codes one at a time; each round picks the candidate code that minimizes the
worst-case updated per-bundle load  max_j (L_j + U(g(s_j))) + eps*xi,
where g(s) = s/(k-1) maps symbols to contribution strengths, U(w) = w^alpha
is the capacity surrogate, and xi ~ U[0,1) breaks ties / adds diversity.

Mirrored exactly (same SplitMix64 stream discipline — one xi per candidate
per round, candidates in lexicographic order) in
``rust/src/loghd/codebook.rs``; ``python/tests/test_codebook.py`` exports
vectors the Rust property tests compare against.
"""

from __future__ import annotations

import numpy as np

from .prng import SplitMix64

EPS_TIEBREAK = 1e-6
MAX_ENUM = 8192  # full enumeration bound on k**n
POOL_SIZE = 4096  # sampled candidate pool beyond it


def min_bundles(c: int, k: int) -> int:
    """Feasibility limit n >= ceil(log_k C)."""
    n = 1
    while k**n < c:
        n += 1
    return n


def g(s: np.ndarray, k: int) -> np.ndarray:
    """Symbol weight g(s) = s/(k-1)."""
    return s.astype(np.float64) / float(k - 1)


def capacity(w: np.ndarray, alpha: float) -> np.ndarray:
    """Capacity surrogate U(w) = w^alpha."""
    return np.power(w, alpha)


def _enumerate_codes(k: int, n: int) -> np.ndarray:
    """All k**n codes in lexicographic order, shape (k**n, n)."""
    idx = np.arange(k**n)
    cols = []
    for j in range(n - 1, -1, -1):
        cols.append((idx // (k**j)) % k)
    return np.stack(cols, axis=1).astype(np.int32)


def build_codebook(c: int, k: int, n: int, *, alpha: float = 1.0, seed: int = 0xC0DE) -> np.ndarray:
    """Greedy minimax-load codebook B in {0..k-1}^(C x n).

    Deterministic in ``seed``. Raises if k**n < C (infeasible).
    """
    if k**n < c:
        raise ValueError(f"k^n = {k}^{n} < C = {c}: infeasible codebook")
    rng = SplitMix64(seed)
    full = k**n <= MAX_ENUM
    if full:
        candidates = _enumerate_codes(k, n)
    else:
        # Sampled pool: POOL_SIZE codes, n symbols each, drawn as u64 % k in
        # row-major order (duplicates possible; uniqueness enforced below).
        raw = rng.u64(POOL_SIZE * n) % np.uint64(k)
        candidates = raw.reshape(POOL_SIZE, n).astype(np.int32)
    cand_cap = capacity(g(candidates, k), alpha)  # (Q, n)

    used = np.zeros(len(candidates), dtype=bool)
    loads = np.zeros(n, dtype=np.float64)
    rows = np.empty((c, n), dtype=np.int32)
    for i in range(c):
        xi = rng.uniform(len(candidates))
        worst = np.max(loads[None, :] + cand_cap, axis=1) + EPS_TIEBREAK * xi
        worst[used] = np.inf
        best = int(np.argmin(worst))
        rows[i] = candidates[best]
        loads += cand_cap[best]
        used[best] = True
        if not full:
            # kill duplicates of the chosen code in the sampled pool
            used |= np.all(candidates == candidates[best], axis=1)
    return rows


def bundle_loads(b: np.ndarray, k: int, alpha: float = 1.0) -> np.ndarray:
    """Per-bundle cumulative load L_j = sum_c U(g(B_{c,j}))."""
    return capacity(g(b, k), alpha).sum(axis=0)


def targets(b: np.ndarray, k: int) -> np.ndarray:
    """Refinement targets t(s) = 2 s/(k-1) - 1 (Eq. 8), shape (C, n)."""
    return (2.0 * b.astype(np.float64) / (k - 1) - 1.0).astype(np.float32)

"""Build-time training of the serving models (paper Algorithm 1), in JAX.

Runs once inside ``make artifacts``; never on the request path. Produces
the tensors the Rust coordinator serves: encoder (W, b), conventional
prototypes H, LogHD bundles M + profiles P + codebook B, and the SparseHD
dimension mask.

Faithfulness notes (also in DESIGN.md):
- Refinement (Eq. 9) is applied per *minibatch* rather than per sample —
  the summed rank-B update with small eta; standard and mirrored exactly by
  the Rust native trainer so the two worlds stay parity-testable.
- Activation profiles are recomputed after refinement so decoding matches
  the refined bundles (Algorithm 1 lists profiling before refinement; the
  refined bundles shift activations, so serving uses refreshed profiles).
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from . import codebook as cb
from . import kernels
from . import model
from .prng import SplitMix64


@dataclasses.dataclass
class TrainConfig:
    d: int = 10_000
    k: int = 2
    extra_bundles: int = 2  # epsilon redundancy (paper §III-G)
    alpha: float = 1.0  # capacity surrogate exponent
    eta: float = 3e-4  # refinement step size (paper §IV-A)
    epochs: int = 10  # refinement passes (paper uses 100; see DESIGN.md)
    conv_epochs: int = 3  # OnlineHD-style passes for the conventional baseline
    batch: int = 64
    encoder_seed: int = 0xE5C0DE
    codebook_seed: int = 0xC0DE
    shuffle_seed: int = 0x5EED


@dataclasses.dataclass
class TrainedModels:
    config: TrainConfig
    n_bundles: int
    w: np.ndarray  # (F, D)
    b: np.ndarray  # (D,)
    mu: np.ndarray  # (D,) training-set mean encoding (centering vector)
    prototypes: np.ndarray  # (C, D) unit rows
    bundles: np.ndarray  # (n, D) unit rows
    profiles: np.ndarray  # (C, n)
    codebook: np.ndarray  # (C, n) i32
    clean_acc_conventional: float = 0.0
    clean_acc_loghd: float = 0.0


def make_encoder(f: int, d: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """W ~ N(0, 1/sqrt(F))^(F x D) row-major, then b ~ U[0, 2pi)^D."""
    rng = SplitMix64(seed)
    w = (rng.normal(f * d).reshape(f, d) / np.sqrt(f)).astype(np.float32)
    b = (2.0 * np.pi * rng.uniform(d)).astype(np.float32)
    return w, b


def encode_all(x: np.ndarray, w: np.ndarray, b: np.ndarray, batch: int = 256) -> np.ndarray:
    """Encode a full dataset through the L1 kernel, batched."""
    out = np.empty((x.shape[0], w.shape[1]), dtype=np.float32)
    for lo in range(0, x.shape[0], batch):
        hi = min(lo + batch, x.shape[0])
        out[lo:hi] = np.asarray(kernels.encode(jnp.asarray(x[lo:hi]), w, b))
    return out


def _normalize_rows(m: np.ndarray) -> np.ndarray:
    return m / np.maximum(np.linalg.norm(m, axis=1, keepdims=True), 1e-12)


def train_prototypes(enc: np.ndarray, y: np.ndarray, c: int) -> np.ndarray:
    """Algorithm 1 step 1: superpose + L2-normalize per class."""
    h = np.zeros((c, enc.shape[1]), dtype=np.float64)
    np.add.at(h, y, enc.astype(np.float64))
    return _normalize_rows(h).astype(np.float32)


def refine_conventional(h: np.ndarray, enc: np.ndarray, y: np.ndarray,
                        epochs: int, eta: float, seed: int, batch: int = 64) -> np.ndarray:
    """OnlineHD-style perceptron passes for the conventional baseline.

    For each misclassified sample: H_y += eta*(1-s_y)*phi, H_yhat -=
    eta*(1-s_yhat)*phi, applied batched. Keeps the conventional baseline
    competitive so LogHD's compaction is measured against a strong model.
    """
    rng = SplitMix64(seed)
    h = h.astype(np.float64)
    # Unit-norm encodings so the update scale is comparable to the unit
    # prototype rows regardless of D (raw phi has norm ~sqrt(D/2)).
    encn = enc / np.maximum(np.linalg.norm(enc, axis=1, keepdims=True), 1e-12)
    idx = np.arange(len(y), dtype=np.int64)
    for _ in range(epochs):
        rng.shuffle(idx)
        for lo in range(0, len(idx), batch):
            sel = idx[lo:lo + batch]
            hn = _normalize_rows(h).astype(np.float32)
            scores = np.asarray(kernels.activations(jnp.asarray(enc[sel]), jnp.asarray(hn)))
            pred = scores.argmax(axis=1)
            wrong = pred != y[sel]
            if not wrong.any():
                continue
            for i in np.nonzero(wrong)[0]:
                yy, py = int(y[sel][i]), int(pred[i])
                e = encn[sel[i]]
                h[yy] += eta * (1.0 - scores[i, yy]) * e
                h[py] -= eta * (1.0 - scores[i, py]) * e
    return _normalize_rows(h).astype(np.float32)


def build_bundles(h: np.ndarray, book: np.ndarray, k: int) -> np.ndarray:
    """Algorithm 1 step 3 (Eq. 4): weighted superposition + normalize."""
    gmat = cb.g(book, k)  # (C, n)
    m = gmat.T @ h.astype(np.float64)  # (n, D)
    # An all-zero bundle (possible when a column of g is all zeros) stays
    # zero after normalization guard rather than dividing by ~0.
    return _normalize_rows(m).astype(np.float32)


def compute_profiles(enc: np.ndarray, y: np.ndarray, m: np.ndarray, c: int,
                     batch: int = 256) -> np.ndarray:
    """Algorithm 1 step 4 (Eq. 6): per-class mean activation vectors."""
    n = m.shape[0]
    acc = np.zeros((c, n), dtype=np.float64)
    cnt = np.zeros(c, dtype=np.int64)
    mj = jnp.asarray(m)
    for lo in range(0, enc.shape[0], batch):
        hi = min(lo + batch, enc.shape[0])
        a = np.asarray(kernels.activations(jnp.asarray(enc[lo:hi]), mj))
        np.add.at(acc, y[lo:hi], a.astype(np.float64))
        np.add.at(cnt, y[lo:hi], 1)
    return (acc / np.maximum(cnt, 1)[:, None]).astype(np.float32)


def refine_bundles(m: np.ndarray, enc: np.ndarray, y: np.ndarray, book: np.ndarray,
                   k: int, epochs: int, eta: float, seed: int, batch: int = 64) -> np.ndarray:
    """Algorithm 1 step 5 (Eq. 8/9), batched minibatch variant."""
    tgt = cb.targets(book, k)  # (C, n)
    rng = SplitMix64(seed)
    idx = np.arange(len(y), dtype=np.int64)
    mj = jnp.asarray(m)
    for _ in range(epochs):
        rng.shuffle(idx)
        for lo in range(0, len(idx), batch):
            sel = idx[lo:lo + batch]
            tau = jnp.asarray(tgt[y[sel]])  # (B, n)
            mj = model.refine_step(mj, jnp.asarray(enc[sel]), tau, eta)
    return np.asarray(mj)


def sparsehd_mask(h: np.ndarray, sparsity: float) -> np.ndarray:
    """SparseHD dimension-wise mask: keep the top (1-S)*D dimensions by
    cross-class discriminability (variance of the prototype matrix along
    each dimension). Returns a (D,) f32 0/1 mask."""
    d = h.shape[1]
    keep = max(1, int(round((1.0 - sparsity) * d)))
    saliency = h.astype(np.float64).var(axis=0)
    order = np.argsort(-saliency, kind="stable")
    mask = np.zeros(d, dtype=np.float32)
    mask[order[:keep]] = 1.0
    return mask


def accuracy_conventional(enc: np.ndarray, y: np.ndarray, h: np.ndarray, batch: int = 256) -> float:
    hits = 0
    hj = jnp.asarray(h)
    for lo in range(0, enc.shape[0], batch):
        hi = min(lo + batch, enc.shape[0])
        s = np.asarray(kernels.activations(jnp.asarray(enc[lo:hi]), hj))
        hits += int((s.argmax(axis=1) == y[lo:hi]).sum())
    return hits / len(y)


def accuracy_loghd(enc: np.ndarray, y: np.ndarray, m: np.ndarray, p: np.ndarray,
                   batch: int = 256) -> float:
    hits = 0
    mj, pj = jnp.asarray(m), jnp.asarray(p)
    for lo in range(0, enc.shape[0], batch):
        hi = min(lo + batch, enc.shape[0])
        a = kernels.activations(jnp.asarray(enc[lo:hi]), mj)
        d = np.asarray(kernels.decode_dists(a, pj))
        hits += int((d.argmin(axis=1) == y[lo:hi]).sum())
    return hits / len(y)


def train_all(x_train: np.ndarray, y_train: np.ndarray, x_test: np.ndarray,
              y_test: np.ndarray, c: int, cfg: TrainConfig) -> TrainedModels:
    """Full Algorithm 1 pipeline + conventional baseline, returning every
    tensor the serving artifacts need."""
    f = x_train.shape[1]
    w, b = make_encoder(f, cfg.d, cfg.encoder_seed)
    enc_train = encode_all(x_train, w, b)
    enc_test = encode_all(x_test, w, b)
    # Centering: remove the DC component of the cosine RP encoder (in f64,
    # mirrored by Rust); see DESIGN.md §Centering.
    mu = enc_train.astype(np.float64).mean(axis=0).astype(np.float32)
    enc_train = enc_train - mu
    enc_test = enc_test - mu

    h0 = train_prototypes(enc_train, y_train, c)
    h = refine_conventional(h0, enc_train, y_train, cfg.conv_epochs, 0.05,
                            cfg.shuffle_seed ^ 0xA5A5)

    n = cb.min_bundles(c, cfg.k) + cfg.extra_bundles
    book = cb.build_codebook(c, cfg.k, n, alpha=cfg.alpha, seed=cfg.codebook_seed)
    m = build_bundles(h, book, cfg.k)
    m = refine_bundles(m, enc_train, y_train, book, cfg.k, cfg.epochs, cfg.eta,
                       cfg.shuffle_seed)
    p = compute_profiles(enc_train, y_train, m, c)

    acc_conv = accuracy_conventional(enc_test, y_test, h)
    acc_log = accuracy_loghd(enc_test, y_test, m, p)
    return TrainedModels(cfg, n, w, b, mu, h, m, p, book, acc_conv, acc_log)

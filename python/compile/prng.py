"""SplitMix64-based deterministic PRNG, mirrored bit-for-bit in Rust.

The synthetic dataset generator must produce *identical* samples in the
Python (artifact build / JAX training) and Rust (figure harness, serving)
worlds so that parity tests compare like with like. Both sides therefore
implement the same primitive stream:

- SplitMix64 (Steele et al.) for raw u64s,
- uniform f64 in [0,1) as ``(z >> 11) * 2**-53``,
- standard normals via Box–Muller, each normal consuming exactly TWO
  uniforms (the sine twin is discarded to keep the stream position
  independent of call batching),
- Fisher–Yates shuffling with ``next_u64() % (i+1)`` indices.

The Rust twin lives in ``rust/src/util/rng.rs``; ``rust/tests/prng_parity``
checks the first values of every stream against vectors exported by
``python/tests/test_prng.py``.
"""

from __future__ import annotations

import numpy as np

_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_M1 = np.uint64(0xBF58476D1CE4E5B9)
_M2 = np.uint64(0x94D049BB133111EB)
_TWO53_INV = float(2.0**-53)


class SplitMix64:
    """Scalar-stateful, vectorized-output SplitMix64."""

    def __init__(self, seed: int):
        self._state = np.uint64(seed & 0xFFFFFFFFFFFFFFFF)

    def next_u64(self) -> int:
        """One u64 step (used by Fisher–Yates)."""
        return int(self.u64(1)[0])

    def u64(self, count: int) -> np.ndarray:
        """``count`` raw u64s as a vector, advancing the state by count."""
        base = np.uint64(self._state)
        idx = np.arange(1, count + 1, dtype=np.uint64)
        with np.errstate(over="ignore"):
            z = base + idx * _GAMMA
            self._state = np.uint64(base + np.uint64(count) * _GAMMA)
            z = (z ^ (z >> np.uint64(30))) * _M1
            z = (z ^ (z >> np.uint64(27))) * _M2
            z = z ^ (z >> np.uint64(31))
        return z

    def uniform(self, count: int) -> np.ndarray:
        """f64 uniforms in [0, 1)."""
        return (self.u64(count) >> np.uint64(11)).astype(np.float64) * _TWO53_INV

    def normal(self, count: int) -> np.ndarray:
        """Standard normals; consumes exactly 2*count uniforms (Box–Muller)."""
        u = self.uniform(2 * count)
        u1 = np.maximum(u[0::2], _TWO53_INV)  # avoid log(0)
        u2 = u[1::2]
        return np.sqrt(-2.0 * np.log(u1)) * np.cos(2.0 * np.pi * u2)

    def shuffle(self, arr: np.ndarray) -> None:
        """In-place Fisher–Yates, high-to-low, ``next_u64 % (i+1)`` indices."""
        n = len(arr)
        for i in range(n - 1, 0, -1):
            j = self.next_u64() % (i + 1)
            arr[i], arr[j] = arr[j], arr[i]

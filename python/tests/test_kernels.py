"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (including non-divisible D forcing single-block
and multi-block tilings) and seeds; allclose tolerances are tight because
both sides compute in f32 with f32 accumulation.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref
from compile.kernels.common import pick_block

TOL = dict(rtol=2e-5, atol=2e-5)


def _rng(seed):
    return np.random.default_rng(seed)


shapes = st.tuples(
    st.integers(1, 32),    # B
    st.integers(1, 40),    # F
    st.integers(1, 300),   # D
    st.integers(1, 9),     # n
    st.integers(2, 30),    # C
    st.integers(0, 2**31 - 1),
)


@settings(max_examples=30, deadline=None)
@given(shapes)
def test_encode_matches_ref(sh):
    b, f, d, _, _, seed = sh
    r = _rng(seed)
    x = r.normal(size=(b, f)).astype(np.float32)
    w = r.normal(size=(f, d)).astype(np.float32)
    bias = r.normal(size=(d,)).astype(np.float32)
    got = np.asarray(kernels.encode(x, w, bias))
    want = np.asarray(ref.encode_ref(x, w, bias))
    np.testing.assert_allclose(got, want, **TOL)


@settings(max_examples=30, deadline=None)
@given(shapes)
def test_activation_matches_ref(sh):
    b, _, d, n, _, seed = sh
    r = _rng(seed)
    enc = r.normal(size=(b, d)).astype(np.float32)
    m = r.normal(size=(n, d)).astype(np.float32)
    m /= np.maximum(np.linalg.norm(m, axis=1, keepdims=True), 1e-12)
    got = np.asarray(kernels.activations(enc, m))
    want = np.asarray(ref.activation_ref(enc, m))
    np.testing.assert_allclose(got, want, **TOL)
    assert np.abs(got).max() <= 1.0 + 1e-4  # cosine bound


@settings(max_examples=30, deadline=None)
@given(shapes)
def test_decode_matches_ref(sh):
    b, _, _, n, c, seed = sh
    r = _rng(seed)
    a = r.normal(size=(b, n)).astype(np.float32)
    p = r.normal(size=(c, n)).astype(np.float32)
    got = np.asarray(kernels.decode_dists(a, p))
    want = np.asarray(ref.decode_ref(a, p))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=30, deadline=None)
@given(shapes)
def test_refine_delta_matches_ref(sh):
    b, _, d, n, _, seed = sh
    r = _rng(seed)
    coef = r.normal(size=(n, b)).astype(np.float32)
    enc = r.normal(size=(b, d)).astype(np.float32)
    got = np.asarray(kernels.refine_delta(coef, enc))
    want = np.asarray(ref.refine_delta_ref(coef, enc))
    np.testing.assert_allclose(got, want, **TOL)


def test_multi_block_accumulation():
    """Force a >1 grid (block_d < D) and check accumulation across steps."""
    r = _rng(0)
    enc = r.normal(size=(4, 96)).astype(np.float32)
    m = r.normal(size=(3, 96)).astype(np.float32)
    m /= np.linalg.norm(m, axis=1, keepdims=True)
    got = np.asarray(kernels.activations(enc, m, block_d=16))
    want = np.asarray(ref.activation_ref(enc, m))
    np.testing.assert_allclose(got, want, **TOL)

    x = r.normal(size=(4, 7)).astype(np.float32)
    w = r.normal(size=(7, 96)).astype(np.float32)
    bias = r.normal(size=(96,)).astype(np.float32)
    got = np.asarray(kernels.encode(x, w, bias, block_d=24))
    want = np.asarray(ref.encode_ref(x, w, bias))
    np.testing.assert_allclose(got, want, **TOL)

    coef = r.normal(size=(3, 4)).astype(np.float32)
    got = np.asarray(kernels.refine_delta(coef, enc, block_d=32))
    want = np.asarray(ref.refine_delta_ref(coef, enc))
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("d,target", [(10_000, 512), (2000, 512), (617, 512), (128, 512), (1, 512)])
def test_pick_block_divides(d, target):
    b = pick_block(d, target)
    assert 1 <= b <= max(d, 1)
    assert d % b == 0
    assert b <= target or d <= target


def test_encode_values_bounded():
    """cos output must live in [-1, 1]."""
    r = _rng(3)
    x = (10 * r.normal(size=(8, 5))).astype(np.float32)
    w = r.normal(size=(5, 64)).astype(np.float32)
    bias = r.normal(size=(64,)).astype(np.float32)
    e = np.asarray(kernels.encode(x, w, bias))
    assert np.abs(e).max() <= 1.0 + 1e-6


def test_activation_zero_query_guarded():
    """A zero encoding must not produce NaNs (guarded norm)."""
    enc = np.zeros((2, 32), dtype=np.float32)
    m = np.eye(3, 32, dtype=np.float32)
    a = np.asarray(kernels.activations(enc, m))
    assert np.isfinite(a).all()

"""Trainer pipeline: Algorithm 1 end-to-end on a tiny dataset."""

import dataclasses

import numpy as np
import pytest

from compile import data as dt
from compile import trainer
from compile import codebook as cb


@pytest.fixture(scope="module")
def tiny():
    """PAGE-like data, small D: the full pipeline in seconds."""
    ds = dt.by_name("page")
    cfg = trainer.TrainConfig(d=512, k=2, extra_bundles=1, epochs=3,
                              conv_epochs=1)
    tm = trainer.train_all(ds.x_train[:1500], ds.y_train[:1500],
                           ds.x_test, ds.y_test, ds.spec.classes, cfg)
    return ds, tm


def test_shapes(tiny):
    ds, tm = tiny
    c, f, d = ds.spec.classes, ds.spec.features, tm.config.d
    n = tm.n_bundles
    assert n == cb.min_bundles(c, 2) + 1
    assert tm.w.shape == (f, d)
    assert tm.b.shape == (d,)
    assert tm.prototypes.shape == (c, d)
    assert tm.bundles.shape == (n, d)
    assert tm.profiles.shape == (c, n)
    assert tm.codebook.shape == (c, n)


def test_unit_rows(tiny):
    _, tm = tiny
    np.testing.assert_allclose(np.linalg.norm(tm.prototypes, axis=1), 1.0, atol=1e-4)
    np.testing.assert_allclose(np.linalg.norm(tm.bundles, axis=1), 1.0, atol=1e-4)


def test_accuracies_beat_chance_by_far(tiny):
    ds, tm = tiny
    chance = 1.0 / ds.spec.classes
    assert tm.clean_acc_conventional > 0.75 > 3 * chance
    assert tm.clean_acc_loghd > 0.70
    # LogHD trails conventional only modestly (paper: "competitive")
    assert tm.clean_acc_loghd > tm.clean_acc_conventional - 0.12


def test_profiles_within_cosine_bounds(tiny):
    _, tm = tiny
    assert np.abs(tm.profiles).max() <= 1.0 + 1e-5


def test_memory_reduction(tiny):
    """The headline claim: n*D + C*n floats vs C*D floats."""
    ds, tm = tiny
    c, d, n = ds.spec.classes, tm.config.d, tm.n_bundles
    loghd_floats = n * d + c * n
    conv_floats = c * d
    assert loghd_floats < conv_floats
    assert n <= np.ceil(np.log2(c)) + 1


def test_sparsehd_mask():
    r = np.random.default_rng(0)
    h = r.normal(size=(5, 100)).astype(np.float32)
    mask = trainer.sparsehd_mask(h, sparsity=0.7)
    assert mask.shape == (100,)
    assert mask.sum() == 30  # keeps (1-S)*D
    assert set(np.unique(mask)) <= {0.0, 1.0}
    # keeps the highest-variance dims
    sal = h.var(axis=0)
    kept = sal[mask == 1.0].min()
    dropped = sal[mask == 0.0].max()
    assert kept >= dropped - 1e-6


def test_encoder_deterministic():
    w1, b1 = trainer.make_encoder(7, 32, seed=5)
    w2, b2 = trainer.make_encoder(7, 32, seed=5)
    assert (w1 == w2).all() and (b1 == b2).all()
    assert (0 <= b1).all() and (b1 < 2 * np.pi).all()

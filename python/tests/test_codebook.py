"""Codebook construction invariants (paper §III-C)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import codebook as cb


def test_min_bundles():
    assert cb.min_bundles(26, 2) == 5   # paper: ceil(log2 26) = 5
    assert cb.min_bundles(26, 3) == 3   # paper: k=3, C=26 -> n=3
    assert cb.min_bundles(5, 2) == 3
    assert cb.min_bundles(2, 2) == 1
    assert cb.min_bundles(1, 2) == 1


def test_g_and_targets():
    b = np.array([[0, 1, 2]], dtype=np.int32)
    np.testing.assert_allclose(cb.g(b, 3), [[0.0, 0.5, 1.0]])
    np.testing.assert_allclose(cb.targets(b, 3), [[-1.0, 0.0, 1.0]])


def test_infeasible_raises():
    with pytest.raises(ValueError):
        cb.build_codebook(10, 2, 3)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 40), st.integers(2, 4), st.integers(0, 3),
       st.integers(0, 2**31 - 1))
def test_codebook_rows_unique_and_in_range(c, k, extra, seed):
    n = cb.min_bundles(c, k) + extra
    b = cb.build_codebook(c, k, n, seed=seed)
    assert b.shape == (c, n)
    assert b.min() >= 0 and b.max() < k
    assert len({tuple(row) for row in b}) == c  # uniqueness (paper req.)


def test_deterministic_in_seed():
    a = cb.build_codebook(26, 2, 5, seed=99)
    b = cb.build_codebook(26, 2, 5, seed=99)
    assert (a == b).all()
    c = cb.build_codebook(26, 2, 5, seed=100)
    assert not (a == c).all()


def test_greedy_beats_adversarial_load():
    """Minimax-load greedy must spread load more evenly than the
    lexicographic-prefix codebook (the pathological case Eq. 2 guards
    against: early lexicographic codes pile weight onto low positions)."""
    c, k, n = 20, 3, 5
    b_greedy = cb.build_codebook(c, k, n, seed=1)
    lex = cb._enumerate_codes(k, n)[:c]
    worst_greedy = cb.bundle_loads(b_greedy, k).max()
    worst_lex = cb.bundle_loads(lex, k).max()
    assert worst_greedy <= worst_lex + 1e-9


def test_sampled_pool_path():
    """k^n > MAX_ENUM exercises the sampled-candidate branch."""
    b = cb.build_codebook(50, 4, 8, seed=3)  # 4^8 = 65536 > 8192
    assert b.shape == (50, 8)
    assert len({tuple(row) for row in b}) == 50


def test_alpha_flattens_heavy_symbols():
    """Larger alpha penalizes heavy symbols harder: the max per-bundle
    *heavy-symbol count* should not grow when alpha increases."""
    c, k, n = 30, 3, 5
    b1 = cb.build_codebook(c, k, n, alpha=1.0, seed=7)
    b2 = cb.build_codebook(c, k, n, alpha=2.0, seed=7)
    heavy1 = (b1 == k - 1).sum(axis=0).max()
    heavy2 = (b2 == k - 1).sum(axis=0).max()
    assert heavy2 <= heavy1 + 1

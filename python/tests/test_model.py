"""L2 graph contracts: shapes, dtype, composition vs numpy references."""

import numpy as np
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def _setup(seed=0, b=8, f=11, d=128, n=4, c=6):
    r = np.random.default_rng(seed)
    x = r.normal(size=(b, f)).astype(np.float32)
    w = r.normal(size=(f, d)).astype(np.float32)
    bias = r.normal(size=(d,)).astype(np.float32)
    mu = r.normal(size=(d,)).astype(np.float32) * 0.1
    m = r.normal(size=(n, d)).astype(np.float32)
    m /= np.linalg.norm(m, axis=1, keepdims=True)
    p = r.normal(size=(c, n)).astype(np.float32)
    h = r.normal(size=(c, d)).astype(np.float32)
    h /= np.linalg.norm(h, axis=1, keepdims=True)
    return x, w, bias, mu, m, p, h


def test_infer_loghd_graph():
    x, w, bias, mu, m, p, _ = _setup()
    dists, labels = model.infer_loghd_graph(x, w, bias, mu, m, p)
    assert dists.shape == (8, 6) and labels.shape == (8,)
    assert labels.dtype == jnp.int32

    enc = ref.encode_ref(x, w, bias) - mu.reshape(1, -1)
    a = ref.activation_ref(enc, m)
    want = ref.decode_ref(a, p)
    np.testing.assert_allclose(np.asarray(dists), np.asarray(want), rtol=1e-4, atol=1e-4)
    assert (np.asarray(labels) == np.asarray(want).argmin(axis=1)).all()


def test_infer_conventional_graph():
    x, w, bias, mu, _, _, h = _setup()
    scores, labels = model.infer_conventional_graph(x, w, bias, mu, h)
    assert scores.shape == (8, 6) and labels.shape == (8,)

    enc = ref.encode_ref(x, w, bias) - mu.reshape(1, -1)
    want = ref.cosine_scores_ref(enc, h)
    np.testing.assert_allclose(np.asarray(scores), np.asarray(want), rtol=2e-5, atol=2e-5)
    assert (np.asarray(labels) == np.asarray(want).argmax(axis=1)).all()


def test_refine_step_moves_activation_toward_target():
    x, w, bias, _, m, _, _ = _setup()
    enc = jnp.asarray(np.asarray(ref.encode_ref(x, w, bias)))
    a0 = np.asarray(ref.activation_ref(np.asarray(enc), np.asarray(m)))
    tau = np.ones_like(a0, dtype=np.float32)  # push all activations up
    m1 = model.refine_step(jnp.asarray(m), enc, jnp.asarray(tau), eta=0.05)
    m1 = np.asarray(m1)
    np.testing.assert_allclose(np.linalg.norm(m1, axis=1), 1.0, atol=1e-5)
    a1 = np.asarray(ref.activation_ref(np.asarray(enc), m1))
    assert a1.mean() > a0.mean()  # moved toward +1 targets


def test_refine_step_zero_eta_is_identity_up_to_norm():
    x, w, bias, _, m, _, _ = _setup()
    enc = jnp.asarray(np.asarray(ref.encode_ref(x, w, bias)))
    tau = jnp.zeros((8, 4), dtype=jnp.float32)
    m1 = model.refine_step(jnp.asarray(m), enc, tau, eta=0.0)
    np.testing.assert_allclose(np.asarray(m1), m, rtol=1e-6, atol=1e-6)

"""AOT lowering + LHT format contracts (without full retraining)."""

import json
import numpy as np
import pytest

from compile import aot, lht, model


def test_lht_roundtrip(tmp_path):
    for arr in [
        np.arange(12, dtype=np.float32).reshape(3, 4),
        np.array([1, -2, 3], dtype=np.int32),
        np.arange(8, dtype=np.uint8).reshape(2, 2, 2),
    ]:
        p = tmp_path / "t.lht"
        lht.write(p, arr)
        back = lht.read(p)
        assert back.dtype == arr.dtype
        assert back.shape == arr.shape
        assert (back == arr).all()


def test_lht_rejects_bad_magic(tmp_path):
    p = tmp_path / "bad.lht"
    p.write_bytes(b"NOPE" + b"\x00" * 16)
    with pytest.raises(ValueError):
        lht.read(p)


def test_lht_rejects_unsupported_dtype(tmp_path):
    with pytest.raises(TypeError):
        lht.write(tmp_path / "x.lht", np.zeros(3, dtype=np.float64))


def test_lower_entries_produce_parseable_hlo():
    """Lower a miniature config and sanity-check the HLO text: must be real
    HLO (ENTRY + parameters matching the manifest arity)."""
    cfg = aot.ServingConfig("mini", "page", d=64, k=2, extra_bundles=0,
                            epochs=0, batch=4)
    entries = aot.lower_entries(cfg, f=10, c=5, n=3)
    assert set(entries) == {"infer_loghd", "infer_conventional", "encode"}
    for name, e in entries.items():
        hlo = e["hlo"]
        assert "ENTRY" in hlo and "HloModule" in hlo, name
        for pname, shape, dtype in e["inputs"]:
            assert isinstance(pname, str) and isinstance(shape, list)
        # entry arity matches the declared inputs:
        # entry_computation_layout={(t0, t1, ...)->...}
        sig = hlo.split("entry_computation_layout={(", 1)[1].split(")->", 1)[0]
        arity = sig.count("f32[") + sig.count("s32[")
        assert arity == len(e["inputs"]), name


def test_configs_table():
    assert "page_smoke" in aot.CONFIGS and "isolet_k2" in aot.CONFIGS
    iso = aot.CONFIGS["isolet_k2"]
    assert iso.d == 10_000 and iso.k == 2  # the paper's Table II config


def test_graph_outputs_match_manifest_decl():
    cfg = aot.ServingConfig("mini", "page", d=64, k=2, extra_bundles=0,
                            epochs=0, batch=4)
    entries = aot.lower_entries(cfg, f=10, c=5, n=3)
    r = np.random.default_rng(0)
    x = r.normal(size=(4, 10)).astype(np.float32)
    w = r.normal(size=(10, 64)).astype(np.float32)
    b = r.normal(size=(64,)).astype(np.float32)
    mu = r.normal(size=(64,)).astype(np.float32) * 0.1
    m = r.normal(size=(3, 64)).astype(np.float32)
    m /= np.linalg.norm(m, axis=1, keepdims=True)
    p = r.normal(size=(5, 3)).astype(np.float32)
    dists, labels = model.infer_loghd_graph(x, w, b, mu, m, p)
    decl = entries["infer_loghd"]["outputs"]
    assert list(dists.shape) == decl[0][1]
    assert list(labels.shape) == decl[1][1]

"""Synthetic dataset generator contract (Table I shapes + difficulty)."""

import numpy as np
import pytest

from compile import data as dt

TABLE_I = {  # name -> (features, classes, n_train, n_test)
    "isolet": (617, 26, 6238, 1559),
    "ucihar": (261, 12, 6213, 1554),
    "pamap2": (75, 5, 24000, 4000),  # 611k train scaled (DESIGN.md)
    "page": (10, 5, 4925, 548),
}


@pytest.mark.parametrize("name", list(TABLE_I))
def test_shapes_match_table1(name):
    f, c, ntr, nte = TABLE_I[name]
    spec = dt.SPECS[name]
    assert (spec.features, spec.classes, spec.n_train, spec.n_test) == (f, c, ntr, nte)


def test_page_generation_shapes_and_dtypes():
    ds = dt.by_name("page")
    assert ds.x_train.shape == (4925, 10) and ds.x_train.dtype == np.float32
    assert ds.y_train.shape == (4925,) and ds.y_train.dtype == np.int32
    assert ds.x_test.shape == (548, 10)
    assert ds.y_test.shape == (548,)


def test_deterministic():
    a = dt.by_name("page")
    b = dt.by_name("page")
    assert (a.x_train == b.x_train).all()
    assert (a.y_test == b.y_test).all()


def test_labels_balanced():
    ds = dt.by_name("page")
    counts = np.bincount(ds.y_train, minlength=5)
    assert counts.max() - counts.min() <= 1  # round-robin before shuffle


def test_classes_separable_but_not_trivial():
    """Nearest-class-mean accuracy on PAGE should sit in a realistic band:
    far above chance (structure exists) but below 100% (noise overlaps)."""
    ds = dt.by_name("page")
    c = ds.spec.classes
    means = np.stack([ds.x_train[ds.y_train == i].mean(axis=0) for i in range(c)])
    d2 = ((ds.x_test[:, None, :] - means[None]) ** 2).sum(axis=2)
    acc = (d2.argmin(axis=1) == ds.y_test).mean()
    assert 0.5 < acc < 0.999, acc


def test_train_test_disjoint_draws():
    """Test samples must not duplicate train samples (independent noise)."""
    ds = dt.by_name("page")
    assert not np.isin(ds.x_test[:, 0], ds.x_train[:, 0]).all()

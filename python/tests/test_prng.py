"""SplitMix64 stream contract — these exact vectors are also hardcoded in
``rust/src/util/rng.rs`` tests; together they pin the cross-language parity
of every downstream dataset/codebook/shuffle."""

import numpy as np
import pytest

from compile.prng import SplitMix64

# Canonical vectors (seed 42). Any change here breaks the Rust twin.
U64_SEED42 = [0xBDD732262FEB6E95, 0x28EFE333B266F103,
              0x47526757130F9F52, 0x581CE1FF0E4AE394]
UNIFORM_SEED42 = [0.74156488, 0.15991039, 0.27860113, 0.34419072]
NORMAL_SEED42 = [0.41471975, -0.89188621, 1.72959309, 0.54562044]
SHUFFLE10_SEED123 = [7, 3, 4, 9, 8, 2, 1, 0, 6, 5]


def test_u64_vectors():
    r = SplitMix64(42)
    assert [int(v) for v in r.u64(4)] == U64_SEED42


def test_uniform_vectors():
    r = SplitMix64(42)
    np.testing.assert_allclose(r.uniform(4), UNIFORM_SEED42, atol=1e-8)


def test_normal_vectors():
    r = SplitMix64(42)
    np.testing.assert_allclose(r.normal(4), NORMAL_SEED42, atol=1e-8)


def test_shuffle_vector():
    r = SplitMix64(123)
    a = np.arange(10)
    r.shuffle(a)
    assert list(a) == SHUFFLE10_SEED123


def test_stream_position_independent_of_batching():
    """u64(5) == u64(2) ++ u64(3): batching must not change the stream."""
    a = SplitMix64(7).u64(5)
    r = SplitMix64(7)
    b = np.concatenate([r.u64(2), r.u64(3)])
    assert (a == b).all()


def test_normal_consumes_two_uniforms_each():
    r1 = SplitMix64(9)
    r1.normal(3)
    r2 = SplitMix64(9)
    r2.uniform(6)
    assert int(r1.next_u64()) == int(r2.next_u64())


@pytest.mark.parametrize("seed", [0, 1, 42, 2**63])
def test_uniform_range(seed):
    u = SplitMix64(seed).uniform(10_000)
    assert (u >= 0).all() and (u < 1).all()


def test_normal_moments():
    z = SplitMix64(1234).normal(200_000)
    assert abs(z.mean()) < 0.01
    assert abs(z.std() - 1.0) < 0.01


def test_shuffle_is_permutation():
    r = SplitMix64(5)
    a = np.arange(1000)
    r.shuffle(a)
    assert sorted(a.tolist()) == list(range(1000))

//! Front-door event-loop bench: connection scale + saturation throughput.
//!
//! Two phases against one event-loop server (binary protocol, trivial
//! echo engine, so the wire + reactor path dominates):
//!
//! 1. **Concurrency hold** — open ≥10,000 simultaneous connections
//!    (both halves live in this process: 2 fds per connection) and keep
//!    them all open while a probe client measures round-trip latency
//!    through the crowd. Proves the reactor's per-connection cost is a
//!    buffer pair, not a thread.
//! 2. **Saturation** — a fixed pool of active connections runs windowed
//!    pipelining (closed loop, window W) until a request budget drains;
//!    reports aggregate throughput and client-measured p50/p99/p999.
//!
//! A counting global allocator reports allocator traffic over the
//! saturation phase as allocs/request and bytes/request. The counters
//! are process-wide — they include the load generator's own bookkeeping
//! (latency samples, thread spawns), so treat the numbers as an upper
//! bound on the serving path; the measured loop itself reads replies
//! without decoding them to keep the client's contribution near zero
//! (the strict zero-allocation claim lives in tests/alloc_regression.rs).
//!
//! Output: results/BENCH_frontdoor.json (EXPERIMENTS.md §Front door).
//! Environment knobs: LOGHD_FRONTDOOR_CONNS (default 10000),
//! LOGHD_FRONTDOOR_REQS (per active connection, default 1000).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use loghd::coordinator::frame;
use loghd::coordinator::{BatcherConfig, Engine, ModelRegistry, Server, ServerConfig};
use loghd::eval::metrics::percentile;
use loghd::tensor::Matrix;
use loghd::testkit::alloc_counter::CountingAlloc;
use loghd::util::json::{self, Value};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

const ACTIVE_CONNS: usize = 64;
const WINDOW: usize = 16;

struct Echo;
impl Engine for Echo {
    fn name(&self) -> String {
        "echo".into()
    }
    fn features(&self) -> usize {
        2
    }
    fn infer(&mut self, x: &Matrix) -> anyhow::Result<Vec<i32>> {
        Ok((0..x.rows()).map(|i| x.at(i, 0) as i32).collect())
    }
}

#[cfg(unix)]
mod rlimit {
    //! Raise RLIMIT_NOFILE so both halves of 10k loopback connections
    //! fit in one process. Raw FFI — this crate vendors all deps.
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }

    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }

    #[cfg(target_os = "linux")]
    const RLIMIT_NOFILE: i32 = 7;
    #[cfg(not(target_os = "linux"))]
    const RLIMIT_NOFILE: i32 = 8;

    /// Try to raise the fd soft limit to `want`; return the resulting
    /// soft limit.
    pub fn raise_nofile(want: u64) -> u64 {
        let mut lim = RLimit { cur: 0, max: 0 };
        if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
            return 1024;
        }
        if lim.cur < want {
            let new = RLimit { cur: want.min(lim.max), max: lim.max };
            unsafe { setrlimit(RLIMIT_NOFILE, &new) };
            if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
                return 1024;
            }
        }
        lim.cur
    }
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn read_reply(stream: &mut TcpStream, scratch: &mut Vec<u8>) -> Value {
    let mut hdr = [0u8; frame::HEADER_LEN];
    stream.read_exact(&mut hdr).expect("reply header");
    let len = u32::from_le_bytes(hdr[4..8].try_into().unwrap()) as usize;
    scratch.clear();
    scratch.extend_from_slice(&hdr);
    scratch.resize(frame::HEADER_LEN + len, 0);
    stream.read_exact(&mut scratch[frame::HEADER_LEN..]).expect("reply payload");
    match frame::try_extract(scratch, frame::DEFAULT_MAX_FRAME) {
        frame::Extract::Frame { header, payload } => {
            frame::decode_reply_to_json(&header, &scratch[payload]).expect("reply decode")
        }
        other => panic!("expected a reply frame, got {other:?}"),
    }
}

/// Read one reply frame into `scratch` without decoding it. The
/// saturation loop uses this so the allocs/request metric measures the
/// serving path, not a client-side JSON tree per reply.
fn read_reply_raw(stream: &mut TcpStream, scratch: &mut Vec<u8>) {
    let mut hdr = [0u8; frame::HEADER_LEN];
    stream.read_exact(&mut hdr).expect("reply header");
    assert_eq!(hdr[0], frame::MAGIC, "bad reply magic {:#04x}", hdr[0]);
    assert_eq!(hdr[2], frame::TYPE_REP_INFER, "unexpected reply type {:#04x}", hdr[2]);
    let len = u32::from_le_bytes(hdr[4..8].try_into().unwrap()) as usize;
    scratch.clear();
    scratch.resize(len, 0);
    stream.read_exact(scratch).expect("reply payload");
}

fn roundtrip(stream: &mut TcpStream, scratch: &mut Vec<u8>, features: &[f32]) -> Value {
    let mut req = Vec::new();
    frame::encode_infer_request(None, features, &mut req);
    stream.write_all(&req).expect("write request");
    read_reply(stream, scratch)
}

/// Closed-loop windowed pipelining on one connection; returns latency
/// samples in microseconds.
fn drive_conn(addr: std::net::SocketAddr, requests: usize) -> Vec<f64> {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_nodelay(true).expect("nodelay");
    let mut scratch = Vec::new();
    let mut frame_bytes = Vec::new();
    frame::encode_infer_request(None, &[1.0, 0.0], &mut frame_bytes);
    let mut latencies = Vec::with_capacity(requests);
    let mut sent_at = std::collections::VecDeque::with_capacity(WINDOW);
    let mut sent = 0usize;
    let mut received = 0usize;
    while received < requests {
        while sent < requests && sent - received < WINDOW {
            stream.write_all(&frame_bytes).expect("write");
            sent_at.push_back(Instant::now());
            sent += 1;
        }
        read_reply_raw(&mut stream, &mut scratch);
        let t0 = sent_at.pop_front().expect("reply without request");
        latencies.push(t0.elapsed().as_secs_f64() * 1e6);
        received += 1;
    }
    latencies
}

fn main() -> anyhow::Result<()> {
    let want_conns = env_usize("LOGHD_FRONTDOOR_CONNS", 10_000);
    let reqs_per_conn = env_usize("LOGHD_FRONTDOOR_REQS", 1_000);

    // Both connection halves live here: 2 fds each, plus server internals
    // (epoll, wakers, listener) and stdio headroom.
    let needed = (2 * want_conns + 512) as u64;
    #[cfg(unix)]
    let fd_limit = rlimit::raise_nofile(needed);
    #[cfg(not(unix))]
    let fd_limit = needed;
    let usable = ((fd_limit.saturating_sub(512)) / 2) as usize;
    let conns = want_conns.min(usable.max(64));
    if conns < want_conns {
        println!(
            "fd limit {fd_limit} clamps the hold phase to {conns} connections \
             (wanted {want_conns})"
        );
    }

    let registry = Arc::new(ModelRegistry::single(
        "echo",
        "demo",
        2,
        &BatcherConfig { max_batch: 64, max_delay: Duration::from_micros(200), max_pending: 8192 },
        vec![Box::new(|| Ok(Box::new(Echo) as Box<dyn Engine>))],
    ));
    let cfg = ServerConfig { reactors: 4, ..Default::default() };
    let mut server = Server::start_with("127.0.0.1:0", Arc::clone(&registry), cfg)?;
    let addr = server.addr;

    // --- Phase 1: hold `conns` open connections -------------------------
    println!("phase 1: opening {conns} connections…");
    let t0 = Instant::now();
    let mut held = Vec::with_capacity(conns);
    for i in 0..conns {
        match TcpStream::connect(addr) {
            Ok(s) => held.push(s),
            Err(e) => {
                println!("connect {i} failed ({e}); holding {} connections", held.len());
                break;
            }
        }
    }
    let accept_s = t0.elapsed().as_secs_f64();
    // Wait until the reactors have adopted every accepted socket.
    let deadline = Instant::now() + Duration::from_secs(30);
    while (server.stats().open as usize) < held.len() {
        assert!(Instant::now() < deadline, "reactors never adopted all connections");
        std::thread::sleep(Duration::from_millis(10));
    }
    let held_n = held.len();
    println!(
        "  {held_n} connections open in {accept_s:.2}s ({:.0} accepts/s)",
        held_n as f64 / accept_s
    );

    // Probe latency through the crowd: every held connection stays open
    // while one more client does serial round trips.
    let mut probe = TcpStream::connect(addr)?;
    probe.set_nodelay(true)?;
    let mut scratch = Vec::new();
    let mut probe_lat = Vec::with_capacity(200);
    for _ in 0..200 {
        let t = Instant::now();
        let r = roundtrip(&mut probe, &mut scratch, &[7.0, 0.0]);
        probe_lat.push(t.elapsed().as_secs_f64() * 1e6);
        assert_eq!(r.get("label").and_then(Value::as_f64), Some(7.0), "{r:?}");
    }
    probe_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let probe_p50 = percentile(&probe_lat, 0.50);
    let probe_p99 = percentile(&probe_lat, 0.99);
    println!("  probe through {held_n} idle conns: p50 {probe_p50:.0}µs p99 {probe_p99:.0}µs");
    assert_eq!(server.stats().open as usize, held_n + 1);
    drop(probe);
    drop(held);

    // --- Phase 2: saturation throughput ---------------------------------
    println!(
        "phase 2: {ACTIVE_CONNS} active connections x {reqs_per_conn} requests (window {WINDOW})…"
    );
    let t1 = Instant::now();
    let allocs_before = ALLOC.allocs();
    let alloc_bytes_before = ALLOC.bytes();
    let mut all_lat: Vec<f64> = Vec::with_capacity(ACTIVE_CONNS * reqs_per_conn);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..ACTIVE_CONNS)
            .map(|_| scope.spawn(move || drive_conn(addr, reqs_per_conn)))
            .collect();
        for h in handles {
            all_lat.extend(h.join().expect("load generator"));
        }
    });
    let elapsed = t1.elapsed().as_secs_f64();
    let allocs_delta = ALLOC.allocs() - allocs_before;
    let alloc_bytes_delta = ALLOC.bytes() - alloc_bytes_before;
    let total = ACTIVE_CONNS * reqs_per_conn;
    let rps = total as f64 / elapsed;
    let allocs_per_request = allocs_delta as f64 / total as f64;
    let alloc_bytes_per_request = alloc_bytes_delta as f64 / total as f64;
    all_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = percentile(&all_lat, 0.50);
    let p99 = percentile(&all_lat, 0.99);
    let p999 = percentile(&all_lat, 0.999);
    println!(
        "  {total} requests in {elapsed:.2}s: {rps:.0} req/s  p50 {p50:.0}µs  p99 {p99:.0}µs  p999 {p999:.0}µs"
    );
    println!(
        "  allocator (process-wide, incl. load generator): \
         {allocs_per_request:.2} allocs/req  {alloc_bytes_per_request:.0} bytes/req"
    );

    let tenant_stats = registry.stats(None).expect("tenant stats").1;
    println!(
        "  batching: fill {:.2} of max_batch, queue high-water {}",
        tenant_stats.batch_fill_ratio, tenant_stats.queue_depth_hwm
    );
    let wakeups = server.stats().wakeups;
    server.shutdown();

    std::fs::create_dir_all("results")?;
    let report = json::obj(vec![
        ("connections_target", json::num(want_conns as f64)),
        ("connections_held", json::num(held_n as f64)),
        ("fd_limit", json::num(fd_limit as f64)),
        ("accept_s", json::num(accept_s)),
        ("accepts_per_s", json::num(held_n as f64 / accept_s)),
        ("probe_p50_us", json::num(probe_p50)),
        ("probe_p99_us", json::num(probe_p99)),
        ("active_conns", json::num(ACTIVE_CONNS as f64)),
        ("window", json::num(WINDOW as f64)),
        ("requests", json::num(total as f64)),
        ("elapsed_s", json::num(elapsed)),
        ("throughput_rps", json::num(rps)),
        ("p50_us", json::num(p50)),
        ("p99_us", json::num(p99)),
        ("p999_us", json::num(p999)),
        ("reactor_wakeups", json::num(wakeups as f64)),
        ("allocs_per_request", json::num(allocs_per_request)),
        ("alloc_bytes_per_request", json::num(alloc_bytes_per_request)),
        ("batch_fill_ratio", json::num(tenant_stats.batch_fill_ratio)),
        ("queue_depth_hwm", json::num(tenant_stats.queue_depth_hwm as f64)),
    ]);
    std::fs::write("results/BENCH_frontdoor.json", json::to_string_pretty(&report) + "\n")?;
    println!("wrote results/BENCH_frontdoor.json");
    Ok(())
}

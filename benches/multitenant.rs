//! Multi-tenant serving bench: N named tenants at mixed precisions
//! (LogHD f32/int8/1-bit + the conventional baseline) behind one
//! [`ModelRegistry`], driven by concurrent per-tenant load generators at
//! replica counts 1 and 2 — the shard-dispatch scaling proof.
//!
//! Output: results/multitenant.csv plus machine-readable
//! results/BENCH_multitenant.json (per-tenant throughput + p50/p99 and
//! the replicas=2 speedup) so the trajectory is trackable across PRs
//! (EXPERIMENTS.md §Multi-tenant).

use std::sync::Arc;
use std::time::Instant;

use loghd::baselines::conventional::ConventionalModel;
use loghd::bench::CsvWriter;
use loghd::coordinator::{BatcherConfig, ModelRegistry, TenantSpec};
use loghd::data;
use loghd::loghd::model::{TrainOptions, TrainedStack};
use loghd::loghd::persist;
use loghd::quant::Precision;
use loghd::tensor::Matrix;
use loghd::util::json::{self, Value};

const REQUESTS_PER_TENANT: usize = 1000;
const D: usize = 2000;

/// Drive every tenant concurrently (open loop: enqueue the full backlog,
/// then await it) and report (elapsed seconds, per-tenant JSON rows).
fn run_mixed_load(
    specs: &[TenantSpec],
    replicas: usize,
    queries: &Matrix,
) -> anyhow::Result<(f64, Vec<Value>)> {
    let specs: Vec<TenantSpec> = specs
        .iter()
        .cloned()
        .map(|mut s| {
            s.replicas = replicas;
            s
        })
        .collect();
    let cfg = BatcherConfig {
        max_batch: 64,
        max_delay: std::time::Duration::from_millis(1),
        max_pending: 8192,
    };
    let registry = Arc::new(ModelRegistry::open(&specs, None, &cfg)?);
    // Warm-up: engine construction happens on the worker threads; one
    // blocking request per tenant keeps cold starts out of the timings.
    for s in &specs {
        registry.submit_blocking(Some(&s.name), queries.row(0).to_vec())?;
    }
    let t0 = Instant::now();
    let mut drain_s: Vec<(String, f64)> = Vec::new();
    std::thread::scope(|scope| {
        let handles: Vec<_> = specs
            .iter()
            .map(|s| {
                let reg = Arc::clone(&registry);
                scope.spawn(move || {
                    let tenant_t0 = Instant::now();
                    let coord = reg.coordinator(Some(&s.name)).expect("tenant");
                    let rxs: Vec<_> = (0..REQUESTS_PER_TENANT)
                        .map(|i| {
                            coord
                                .submit(queries.row(i % queries.rows()).to_vec())
                                .expect("submit")
                        })
                        .collect();
                    for rx in rxs {
                        let _ = rx.recv();
                    }
                    (s.name.clone(), tenant_t0.elapsed().as_secs_f64())
                })
            })
            .collect();
        for h in handles {
            drain_s.push(h.join().expect("generator thread"));
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    let mut rows = Vec::new();
    for info in registry.describe() {
        let tenant_elapsed = drain_s
            .iter()
            .find(|(n, _)| *n == info.name)
            .map(|(_, e)| *e)
            .unwrap_or(elapsed);
        let rps = REQUESTS_PER_TENANT as f64 / tenant_elapsed;
        println!(
            "  replicas={replicas} {:<10} {:<4} {rps:>9.0} req/s  p50 {:>7.0}µs  p99 {:>7.0}µs  mean_batch {:>5.1}",
            info.name,
            info.precision,
            info.stats.latency_p50_us,
            info.stats.latency_p99_us,
            info.stats.mean_batch_size
        );
        rows.push(json::obj(vec![
            ("model", json::s(info.name.clone())),
            ("kind", json::s(info.kind.clone())),
            ("precision", json::s(info.precision)),
            ("throughput_rps", json::num(rps)),
            ("drain_s", json::num(tenant_elapsed)),
            ("p50_us", json::num(info.stats.latency_p50_us)),
            ("p99_us", json::num(info.stats.latency_p99_us)),
            ("mean_batch", json::num(info.stats.mean_batch_size)),
            ("rejected", json::num(info.stats.rejected as f64)),
        ]));
    }
    Ok((elapsed, rows))
}

fn main() -> anyhow::Result<()> {
    let mut csv = CsvWriter::create(
        "results/multitenant.csv",
        "replicas,model,metric,value",
    )?;

    // One trained stack feeds four tenants: three LogHD precisions + the
    // conventional baseline, all under one registry (the paper's
    // many-models-per-budget pitch, exercised end-to-end).
    let ds = data::generate_scaled(data::spec("page").unwrap(), 1500, 256);
    let opts =
        TrainOptions { epochs: 3, conv_epochs: 1, extra_bundles: 4, ..Default::default() };
    let stack = TrainedStack::train(&ds.x_train, &ds.y_train, 5, D, 0xE5C0DE, &opts)?;
    let root = std::env::temp_dir().join("loghd_bench_multitenant");
    let _ = std::fs::remove_dir_all(&root);
    persist::save(&root.join("log"), &stack.encoder, &stack.loghd)?;
    persist::save_conventional(
        &root.join("conv"),
        &stack.encoder,
        &ConventionalModel::new(stack.prototypes.clone()),
    )?;
    let specs = vec![
        TenantSpec {
            name: "log_f32".into(),
            path: root.join("log"),
            precision: Precision::F32,
            replicas: 1,
            cascade: false,
        },
        TenantSpec {
            name: "log_b8".into(),
            path: root.join("log"),
            precision: Precision::B8,
            replicas: 1,
            cascade: false,
        },
        TenantSpec {
            name: "log_b1".into(),
            path: root.join("log"),
            precision: Precision::B1,
            replicas: 1,
            cascade: false,
        },
        TenantSpec {
            name: "conv_f32".into(),
            path: root.join("conv"),
            precision: Precision::F32,
            replicas: 1,
            cascade: false,
        },
    ];

    println!(
        "multi-tenant load: {} tenants x {REQUESTS_PER_TENANT} requests, D={D}",
        specs.len()
    );
    let mut runs = Vec::new();
    let mut elapsed_by_replicas = Vec::new();
    for replicas in [1usize, 2] {
        let (elapsed, rows) = run_mixed_load(&specs, replicas, &ds.x_test)?;
        let aggregate = (specs.len() * REQUESTS_PER_TENANT) as f64 / elapsed;
        println!(
            "  replicas={replicas}: {:.2}s total, aggregate {aggregate:.0} req/s",
            elapsed
        );
        for row in &rows {
            let model = row.get("model").and_then(Value::as_str).unwrap_or("?");
            for metric in ["throughput_rps", "p50_us", "p99_us"] {
                if let Some(v) = row.get(metric).and_then(Value::as_f64) {
                    csv.row(&[
                        replicas.to_string(),
                        model.to_string(),
                        metric.to_string(),
                        format!("{v:.1}"),
                    ])?;
                }
            }
        }
        runs.push(json::obj(vec![
            ("replicas", json::num(replicas as f64)),
            ("elapsed_s", json::num(elapsed)),
            ("aggregate_rps", json::num(aggregate)),
            ("tenants", json::arr(rows)),
        ]));
        elapsed_by_replicas.push(elapsed);
    }
    let speedup = elapsed_by_replicas[0] / elapsed_by_replicas[1];
    println!("replicas=2 speedup over replicas=1: {speedup:.2}x");

    let report = json::obj(vec![
        ("d", json::num(D as f64)),
        ("requests_per_tenant", json::num(REQUESTS_PER_TENANT as f64)),
        ("tenants", json::num(specs.len() as f64)),
        ("runs", json::arr(runs)),
        ("replicas2_speedup", json::num(speedup)),
    ]);
    std::fs::write("results/BENCH_multitenant.json", json::to_string_pretty(&report))?;
    println!("wrote results/BENCH_multitenant.json");
    let _ = std::fs::remove_dir_all(&root);
    Ok(())
}

//! Precision-cascade benchmarks: the calibrated b1 prefilter with
//! margin-gated escalation to exact decode, against the exact-only
//! engine it replaces (the acceptance shape: batch=64, D=2000, page).
//!
//! Three operating points bracket the cascade:
//!   threshold = 0        -> never escalates (the b1 ceiling),
//!   threshold = calibrated -> the `loghd calibrate` operating point,
//!   threshold = +inf     -> always escalates (gate overhead floor;
//!                           answers are bit-identical to exact).
//!
//! Output: results/cascade.csv plus machine-readable
//! results/BENCH_cascade.json (medians, the cascade's speedup over the
//! exact engine — acceptance wants >= 1.5x at the calibrated point —
//! plus the calibrated threshold, held-out agreement/escalation, and
//! allocator traffic through the steady-state `infer_into` path) so the
//! perf trajectory is trackable across PRs (EXPERIMENTS.md §Perf).

use std::sync::Arc;

use loghd::bench::{bench, CsvWriter};
use loghd::coordinator::{CascadeCounters, CascadeEngine, Engine, InferScratch, NativeEngine};
use loghd::data;
use loghd::loghd::cascade;
use loghd::loghd::model::{TrainOptions, TrainedStack};
use loghd::quant::Precision;
use loghd::testkit::alloc_counter::CountingAlloc;
use loghd::util::json;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

fn main() -> anyhow::Result<()> {
    let mut csv = CsvWriter::create("results/cascade.csv", "path,metric,value")?;

    let ds = data::generate_scaled(data::spec("page").unwrap(), 1500, 256);
    let opts = TrainOptions { epochs: 3, conv_epochs: 1, extra_bundles: 4, ..Default::default() };
    let stack = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 2000, 0xE5C0DE, &opts)?;
    let xb = ds.x_test.rows_slice(0, 64);

    // Fit the operating point exactly as `loghd calibrate` would, then
    // score it on traffic the fit never saw.
    let cal = cascade::calibrate(
        &stack.encoder,
        &stack.loghd,
        &ds.x_train,
        cascade::DEFAULT_TARGET,
        0xE5C0DE,
    )?;
    let (heldout_agreement, heldout_escalation) =
        cascade::evaluate(&stack.encoder, &stack.loghd, &ds.x_test, cal.threshold);
    println!(
        "calibrated threshold {:.6}: fit agreement {:.4} (CI [{:.4}, {:.4}]), held-out agreement {:.4}, escalation {:.3}",
        cal.threshold,
        cal.agreement,
        cal.agreement_ci.0,
        cal.agreement_ci.1,
        heldout_agreement,
        heldout_escalation
    );

    // --- Exact-only baseline: the engine the cascade competes with ---
    let mut exact = NativeEngine::with_precision(
        stack.encoder.clone(),
        stack.loghd.clone(),
        "page",
        Precision::F32,
    );
    let mut scratch = InferScratch::new();
    let _ = exact.infer_into(&xb, &mut scratch)?;
    let exact_stats = bench(5, 40, || {
        let _ = exact.infer_into(&xb, &mut scratch).unwrap();
    });
    println!("{}", exact_stats.format_line("exact f32 infer_into batch=64 D=2000"));
    csv.row(&[
        "exact_f32".into(),
        "batch64_median_s".into(),
        format!("{:.9}", exact_stats.median),
    ])?;

    // --- Cascade at the three operating points ---
    let mut calibrated_median = f64::NAN;
    let mut calibrated_allocs_per_batch = f64::NAN;
    let mut calibrated_escalation_benched = f64::NAN;
    let mut never_median = f64::NAN;
    let mut always_median = f64::NAN;
    for (tag, threshold) in [
        ("never_escalate", 0.0f32),
        ("calibrated", cal.threshold),
        ("always_escalate", f32::INFINITY),
    ] {
        let counters = Arc::new(CascadeCounters::new());
        let mut engine = CascadeEngine::with_precision(
            stack.encoder.clone(),
            stack.loghd.clone(),
            "page",
            Precision::F32,
            threshold,
            Arc::clone(&counters),
        );
        let mut scratch = InferScratch::new();
        // Settle scratch high-water marks so the allocator delta
        // measures the steady state, as in benches/serving.rs.
        let _ = engine.infer_into(&xb, &mut scratch)?;
        let a0 = ALLOC.allocs();
        const ALLOC_PROBE_ITERS: usize = 32;
        for _ in 0..ALLOC_PROBE_ITERS {
            let _ = engine.infer_into(&xb, &mut scratch).unwrap();
        }
        let allocs_per_batch = (ALLOC.allocs() - a0) as f64 / ALLOC_PROBE_ITERS as f64;
        let stats = bench(5, 40, || {
            let _ = engine.infer_into(&xb, &mut scratch).unwrap();
        });
        let (tier1, escalated, _) = counters.snapshot();
        let esc_rate = escalated as f64 / (tier1 + escalated).max(1) as f64;
        println!(
            "{}",
            stats.format_line(&format!("cascade {tag} (t={threshold:.4}) batch=64 D=2000"))
        );
        println!("  escalation on benched traffic: {esc_rate:.3}  allocs/batch: {allocs_per_batch:.1}");
        match tag {
            "calibrated" => {
                calibrated_median = stats.median;
                calibrated_allocs_per_batch = allocs_per_batch;
                calibrated_escalation_benched = esc_rate;
            }
            "never_escalate" => never_median = stats.median,
            _ => always_median = stats.median,
        }
        csv.row(&[
            format!("cascade_{tag}"),
            "batch64_median_s".into(),
            format!("{:.9}", stats.median),
        ])?;
    }

    let speedup = exact_stats.median / calibrated_median;
    println!(
        "cascade speedup over exact f32 at the calibrated point: {speedup:.2}x (target >= 1.5x); \
         b1 ceiling {:.2}x, always-escalate floor {:.2}x",
        exact_stats.median / never_median,
        exact_stats.median / always_median
    );

    let report = json::obj(vec![
        ("dispatch", json::s(loghd::tensor::simd::path_label())),
        ("batch", json::num(64.0)),
        ("d", json::num(2000.0)),
        ("calibrated_threshold", json::num(cal.threshold as f64)),
        ("calibration_agreement", json::num(cal.agreement)),
        ("heldout_agreement", json::num(heldout_agreement)),
        ("heldout_escalation_rate", json::num(heldout_escalation)),
        ("benched_escalation_rate", json::num(calibrated_escalation_benched)),
        ("exact_f32_median_s", json::num(exact_stats.median)),
        ("cascade_calibrated_median_s", json::num(calibrated_median)),
        ("cascade_never_escalate_median_s", json::num(never_median)),
        ("cascade_always_escalate_median_s", json::num(always_median)),
        ("cascade_speedup_vs_exact", json::num(speedup)),
        ("cascade_allocs_per_batch", json::num(calibrated_allocs_per_batch)),
    ]);
    std::fs::write("results/BENCH_cascade.json", json::to_string_pretty(&report))?;
    println!("wrote results/BENCH_cascade.json");
    Ok(())
}

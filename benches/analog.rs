//! Analog fault-surface campaign harness (EXPERIMENTS.md §Analog-resilience).
//!
//! Runs `eval::campaign::run_analog` — the equal-memory robustness grid
//! swept once per analog fault model (bit flips, conductance drift,
//! stuck-at cells, correlated line failures) — and writes
//! `results/BENCH_analog.json` plus a repo-root snapshot. Smoke profile
//! by default (CI-sized); `LOGHD_FULL=1` switches to the paper-scale
//! ISOLET grid.
//!
//! The artifact is deterministic outside its `meta` section for a fixed
//! profile, at any `LOGHD_THREADS` — same contract as the digital
//! robustness bench, pinned by `rust/tests/golden/analog_smoke.json`.

use loghd::eval::campaign::{self, AnalogConfig};

fn main() -> anyhow::Result<()> {
    let cfg = if std::env::var("LOGHD_FULL").as_deref() == Ok("1") {
        AnalogConfig::full()
    } else {
        AnalogConfig::smoke()
    };
    let res = campaign::run_analog(&cfg)?;
    print!("{}", res.summary());
    res.write_default_artifacts()?;
    println!("wrote results/BENCH_analog.json (+ repo-root snapshot)");
    Ok(())
}

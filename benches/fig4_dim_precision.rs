//! Regenerates paper Fig. 4: UCIHAR accuracy vs flip probability across
//! hypervector dimensionalities D and numeric precisions (1/2/4/8-bit) at
//! a matched memory budget.
//!
//! Output: results/fig4.csv + quick-look charts.

use loghd::bench::{ascii_chart, CsvWriter};
use loghd::eval::figures::{fig4, series_by, Row, Scope};

fn main() -> anyhow::Result<()> {
    let scope = Scope::from_env();
    eprintln!("[fig4] scope: base D={} (sweeps dims)", scope.d);
    let t0 = std::time::Instant::now();
    let rows = fig4(&scope)?;
    let mut csv = CsvWriter::create("results/fig4.csv", Row::csv_header())?;
    for r in &rows {
        csv.row(&r.csv())?;
    }
    let mut dims: Vec<usize> = rows.iter().map(|r| r.d).collect();
    dims.sort_unstable();
    dims.dedup();
    for d in dims {
        for bits in [1u32, 8] {
            let series = series_by(&rows, |r| {
                (r.d == d && r.bits == bits).then(|| (r.method.clone(), r.p))
            });
            if series.is_empty() {
                continue;
            }
            let xs: Vec<f64> = series[0].1.iter().map(|(x, _)| *x).collect();
            let lines: Vec<(String, Vec<f64>)> = series
                .into_iter()
                .map(|(k, pts)| (k, pts.into_iter().map(|(_, y)| y).collect()))
                .collect();
            println!(
                "{}",
                ascii_chart(&format!("Fig4 ucihar D={d} {bits}-bit (acc vs p)"), &xs, &lines)
            );
        }
    }
    eprintln!("[fig4] {} rows in {:?} -> results/fig4.csv", rows.len(), t0.elapsed());
    Ok(())
}

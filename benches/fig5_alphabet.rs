//! Regenerates paper Fig. 5: effect of alphabet size k — accuracy vs n/C
//! on PAGE and UCIHAR for k ∈ {2,3,4,8}, clean (p=0) and faulted (p=0.8).
//!
//! Output: results/fig5.csv + quick-look charts.

use loghd::bench::{ascii_chart, CsvWriter};
use loghd::eval::figures::{fig5, series_by, Row, Scope};

fn main() -> anyhow::Result<()> {
    let scope = Scope::from_env();
    let t0 = std::time::Instant::now();
    let rows = fig5(&scope, 8)?;
    let mut csv = CsvWriter::create("results/fig5.csv", Row::csv_header())?;
    for r in &rows {
        csv.row(&r.csv())?;
    }
    for dataset in ["page", "ucihar"] {
        for p in [0.0, 0.8] {
            let series = series_by(&rows, |r| {
                (r.dataset == dataset && (r.p - p).abs() < 1e-9)
                    .then(|| (r.method.clone(), r.budget))
            });
            if series.is_empty() {
                continue;
            }
            // union of x grids per k differs; chart each series on its own
            for (name, pts) in series {
                let xs: Vec<f64> = pts.iter().map(|(x, _)| *x).collect();
                let ys: Vec<f64> = pts.iter().map(|(_, y)| *y).collect();
                println!(
                    "{}",
                    ascii_chart(
                        &format!("Fig5 {dataset} p={p} {name} (acc vs n/C)"),
                        &xs,
                        &[(name.clone(), ys)]
                    )
                );
            }
        }
    }
    eprintln!("[fig5] {} rows in {:?} -> results/fig5.csv", rows.len(), t0.elapsed());
    Ok(())
}

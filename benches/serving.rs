//! Serving-path benchmarks: the per-precision model-inference kernels
//! (f32 vs int8 vs 1-bit packed), PJRT vs native engine throughput, and
//! the dynamic batcher's amortization sweep (batch size / max-delay
//! policy). Requires `make artifacts` for the PJRT half (skips gracefully
//! if the bundle is missing).
//!
//! Output: results/serving.csv plus machine-readable
//! results/BENCH_serving.json (per-precision median seconds + speedups
//! over f32, allocator traffic through the steady-state `infer_into`
//! path, and the batcher's fill ratio / queue high-water mark) so the
//! perf trajectory is trackable across PRs (EXPERIMENTS.md §Perf).
//!
//! The native-engine loop runs through [`Engine::infer_into`] — the
//! form the coordinator serves — with a reused [`InferScratch`], and a
//! counting global allocator reports allocs per batch over it. The
//! number includes the thread pool's per-call dispatch (row-parallel
//! encode hands closures to worker threads); the single-threaded
//! zero-allocation claim is asserted in tests/alloc_regression.rs.

use std::path::PathBuf;
use std::sync::Arc;

use loghd::bench::{bench, CsvWriter};
use loghd::coordinator::{BatcherConfig, Coordinator, Engine, InferScratch, NativeEngine};
use loghd::data;
use loghd::loghd::model::{TrainOptions, TrainedStack};
use loghd::loghd::qmodel::QuantizedLogHdModel;
use loghd::quant::Precision;
use loghd::runtime::PjrtRuntime;
use loghd::tensor::Matrix;
use loghd::testkit::alloc_counter::CountingAlloc;
use loghd::util::json;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

fn main() -> anyhow::Result<()> {
    let mut csv = CsvWriter::create("results/serving.csv", "path,metric,value")?;
    let bundle = PathBuf::from("artifacts/page_smoke");

    // --- Model-inference kernels per precision (the acceptance shape:
    // batch=64, D=2000, n=7 bundles) ---
    let ds = data::generate_scaled(data::spec("page").unwrap(), 1500, 256);
    let opts = TrainOptions { epochs: 3, conv_epochs: 1, extra_bundles: 4, ..Default::default() };
    let stack = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 2000, 0xE5C0DE, &opts)?;
    let xb = ds.x_test.rows_slice(0, 64);
    let enc = stack.encoder.encode(&xb);

    let f32_stats = bench(5, 40, || {
        let _ = stack.loghd.predict(&enc);
    });
    println!("{}", f32_stats.format_line("model f32 predict batch=64 D=2000"));

    let qm8 = QuantizedLogHdModel::from_model(&stack.loghd, Precision::B8);
    let int8_stats = bench(5, 40, || {
        let _ = qm8.predict(&enc);
    });
    println!("{}", int8_stats.format_line("model int8 packed predict batch=64 D=2000"));

    let qm1 = QuantizedLogHdModel::from_model(&stack.loghd, Precision::B1);
    let bit1_stats = bench(5, 40, || {
        let _ = qm1.predict(&enc);
    });
    println!("{}", bit1_stats.format_line("model 1-bit packed predict batch=64 D=2000"));

    let speedup_int8 = f32_stats.median / int8_stats.median;
    let speedup_bit1 = f32_stats.median / bit1_stats.median;
    println!(
        "speedup over f32: int8 {speedup_int8:.2}x (target >= 1.5x), 1-bit {speedup_bit1:.2}x (target >= 3x)"
    );
    for (path, stats) in
        [("model_f32", &f32_stats), ("model_int8", &int8_stats), ("model_bit1", &bit1_stats)]
    {
        csv.row(&[path.into(), "batch64_median_s".into(), format!("{:.9}", stats.median)])?;
    }

    // --- End-to-end native engines (encode + model), through the
    // steady-state `infer_into` serving form (reused scratch) ---
    let mut native_f32_into_median = f64::NAN;
    let mut native_f32_allocs_per_batch = f64::NAN;
    for precision in [Precision::F32, Precision::B8, Precision::B1] {
        let mut engine = NativeEngine::with_precision(
            stack.encoder.clone(),
            stack.loghd.clone(),
            "page",
            precision,
        );
        let mut scratch = InferScratch::new();
        // Settle every scratch buffer at its high-water mark first, so
        // the allocator delta measures the steady state.
        let _ = engine.infer_into(&xb, &mut scratch)?;
        let a0 = ALLOC.allocs();
        const ALLOC_PROBE_ITERS: usize = 32;
        for _ in 0..ALLOC_PROBE_ITERS {
            let _ = engine.infer_into(&xb, &mut scratch).unwrap();
        }
        let allocs_per_batch = (ALLOC.allocs() - a0) as f64 / ALLOC_PROBE_ITERS as f64;
        let stats = bench(3, 30, || {
            let _ = engine.infer_into(&xb, &mut scratch).unwrap();
        });
        let label = format!("native infer_into {} batch=64 D=2000", precision.label());
        println!("{}", stats.format_line(&label));
        println!("  allocs/batch (incl. thread-pool dispatch): {allocs_per_batch:.1}");
        if precision == Precision::F32 {
            native_f32_into_median = stats.median;
            native_f32_allocs_per_batch = allocs_per_batch;
        }
        csv.row(&[
            format!("native_{}", precision.label()),
            "batch64_median_s".into(),
            format!("{:.6}", stats.median),
        ])?;
    }

    // --- PJRT engine (needs artifacts) ---
    if bundle.join("manifest.json").exists() {
        let runtime = PjrtRuntime::load(&bundle)?;
        let m = &runtime.manifest;
        let mut xb = Matrix::zeros(m.batch, m.features);
        let x_test = m.tensor("x_test")?.to_matrix()?;
        for i in 0..m.batch {
            xb.row_mut(i).copy_from_slice(x_test.row(i % x_test.rows()));
        }
        let pjrt_stats = bench(3, 30, || {
            let _ = runtime.execute("infer_loghd", Some(&xb)).unwrap();
        });
        println!("{}", pjrt_stats.format_line("pjrt infer_loghd batch=64 (page_smoke)"));
        csv.row(&["pjrt".into(), "batch64_median_s".into(), format!("{:.6}", pjrt_stats.median)])?;
        println!("  pjrt per-query at batch64: {:.1}µs", pjrt_stats.median / 64.0 * 1e6);
    } else {
        eprintln!("[serving] artifacts/page_smoke missing -> PJRT half skipped (run `make artifacts`)");
    }

    // --- Batcher policy sweep (native engine, offered load) ---
    println!("\nbatcher policy sweep (native page model, 512 requests):");
    let mut sweep_fill_ratio = f64::NAN;
    let mut sweep_queue_hwm = f64::NAN;
    for (max_batch, delay_ms) in [(1usize, 0u64), (16, 1), (64, 2), (64, 8)] {
        let cfg = BatcherConfig {
            max_batch,
            max_delay: std::time::Duration::from_millis(delay_ms),
            max_pending: 4096,
        };
        let enc = stack.encoder.clone();
        let model = stack.loghd.clone();
        let coord = Arc::new(Coordinator::start(
            10,
            cfg,
            NativeEngine::factory(enc, model, "bench".into()),
        ));
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = (0..512)
            .map(|i| coord.submit(ds.x_test.row(i % ds.x_test.rows()).to_vec()).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let elapsed = t0.elapsed();
        let snap = coord.stats();
        println!(
            "  max_batch={max_batch:<3} delay={delay_ms}ms: {:>8.0} req/s  mean_batch={:<5.1} fill={:.2} queue_hwm={} p99={:.0}µs",
            512.0 / elapsed.as_secs_f64(),
            snap.mean_batch_size,
            snap.batch_fill_ratio,
            snap.queue_depth_hwm,
            snap.latency_p99_us
        );
        // The acceptance-shaped point (max_batch=64, 2ms) feeds the
        // snapshot-tracked report.
        if (max_batch, delay_ms) == (64, 2) {
            sweep_fill_ratio = snap.batch_fill_ratio;
            sweep_queue_hwm = snap.queue_depth_hwm as f64;
        }
        csv.row(&[
            format!("batcher_b{max_batch}_d{delay_ms}"),
            "req_per_s".into(),
            format!("{:.1}", 512.0 / elapsed.as_secs_f64()),
        ])?;
    }

    let report = json::obj(vec![
        ("dispatch", json::s(loghd::tensor::simd::path_label())),
        ("batch", json::num(64.0)),
        ("d", json::num(2000.0)),
        ("n_bundles", json::num(stack.loghd.n_bundles() as f64)),
        ("f32_median_s", json::num(f32_stats.median)),
        ("int8_median_s", json::num(int8_stats.median)),
        ("bit1_median_s", json::num(bit1_stats.median)),
        ("int8_speedup_vs_f32", json::num(speedup_int8)),
        ("bit1_speedup_vs_f32", json::num(speedup_bit1)),
        ("native_f32_infer_into_median_s", json::num(native_f32_into_median)),
        ("native_f32_allocs_per_batch", json::num(native_f32_allocs_per_batch)),
        ("batch_fill_ratio", json::num(sweep_fill_ratio)),
        ("queue_depth_hwm", json::num(sweep_queue_hwm)),
    ]);
    std::fs::write("results/BENCH_serving.json", json::to_string_pretty(&report))?;
    println!("wrote results/BENCH_serving.json");
    Ok(())
}

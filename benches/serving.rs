//! Serving-path benchmarks: PJRT vs native engine throughput, and the
//! dynamic batcher's amortization sweep (batch size / max-delay policy).
//! Requires `make artifacts` for the PJRT half (skips gracefully if the
//! bundle is missing).
//!
//! Output: results/serving.csv.

use std::path::PathBuf;
use std::sync::Arc;

use loghd::bench::{bench, CsvWriter};
use loghd::coordinator::{BatcherConfig, Coordinator, NativeEngine};
use loghd::data;
use loghd::loghd::model::{TrainOptions, TrainedStack};
use loghd::runtime::PjrtRuntime;
use loghd::tensor::Matrix;

fn main() -> anyhow::Result<()> {
    let mut csv = CsvWriter::create("results/serving.csv", "path,metric,value")?;
    let bundle = PathBuf::from("artifacts/page_smoke");

    // --- Native engine micro-bench (always available) ---
    let ds = data::generate_scaled(data::spec("page").unwrap(), 1500, 256);
    let opts = TrainOptions { epochs: 3, conv_epochs: 1, extra_bundles: 1, ..Default::default() };
    let stack = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 2000, 0xE5C0DE, &opts)?;
    let xb = ds.x_test.rows_slice(0, 64);
    let mut native = NativeEngine::new(stack.encoder.clone(), stack.loghd.clone(), "page");
    let native_stats = bench(3, 30, || {
        let _ = loghd::coordinator::Engine::infer(&mut native, &xb).unwrap();
    });
    println!("{}", native_stats.format_line("native infer batch=64 D=2000"));
    csv.row(&["native".into(), "batch64_median_s".into(), format!("{:.6}", native_stats.median)])?;

    // --- PJRT engine (needs artifacts) ---
    if bundle.join("manifest.json").exists() {
        let runtime = PjrtRuntime::load(&bundle)?;
        let m = &runtime.manifest;
        let mut xb = Matrix::zeros(m.batch, m.features);
        let x_test = m.tensor("x_test")?.to_matrix()?;
        for i in 0..m.batch {
            xb.row_mut(i).copy_from_slice(x_test.row(i % x_test.rows()));
        }
        let pjrt_stats = bench(3, 30, || {
            let _ = runtime.execute("infer_loghd", Some(&xb)).unwrap();
        });
        println!("{}", pjrt_stats.format_line("pjrt infer_loghd batch=64 (page_smoke)"));
        csv.row(&["pjrt".into(), "batch64_median_s".into(), format!("{:.6}", pjrt_stats.median)])?;

        let single = bench(3, 30, || {
            let _ = runtime.execute("infer_loghd", Some(&xb)).unwrap();
        });
        println!(
            "  pjrt per-query at batch64: {:.1}µs",
            single.median / 64.0 * 1e6
        );
    } else {
        eprintln!("[serving] artifacts/page_smoke missing -> PJRT half skipped (run `make artifacts`)");
    }

    // --- Batcher policy sweep (native engine, offered load) ---
    println!("\nbatcher policy sweep (native page model, 512 requests):");
    for (max_batch, delay_ms) in [(1usize, 0u64), (16, 1), (64, 2), (64, 8)] {
        let cfg = BatcherConfig {
            max_batch,
            max_delay: std::time::Duration::from_millis(delay_ms),
            max_pending: 4096,
        };
        let enc = stack.encoder.clone();
        let model = stack.loghd.clone();
        let coord = Arc::new(Coordinator::start(
            10,
            cfg,
            NativeEngine::factory(enc, model, "bench".into()),
        ));
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = (0..512)
            .map(|i| coord.submit(ds.x_test.row(i % ds.x_test.rows()).to_vec()).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let elapsed = t0.elapsed();
        let snap = coord.stats();
        println!(
            "  max_batch={max_batch:<3} delay={delay_ms}ms: {:>8.0} req/s  mean_batch={:<5.1} p99={:.0}µs",
            512.0 / elapsed.as_secs_f64(),
            snap.mean_batch_size,
            snap.latency_p99_us
        );
        csv.row(&[
            format!("batcher_b{max_batch}_d{delay_ms}"),
            "req_per_s".into(),
            format!("{:.1}", 512.0 / elapsed.as_secs_f64()),
        ])?;
    }
    Ok(())
}

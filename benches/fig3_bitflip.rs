//! Regenerates paper Fig. 3: test accuracy vs bit-flip probability p at
//! matched memory budgets across all four datasets, comparing SparseHD,
//! LogHD (k ∈ {2,3}) and the Hybrid.
//!
//! Output: results/fig3.csv + an ASCII quick-look per (dataset, budget).
//! CI scale by default; LOGHD_FULL=1 for the paper-scale grid.

use loghd::bench::{ascii_chart, CsvWriter};
use loghd::eval::figures::{fig3, series_by, Row, Scope};

fn main() -> anyhow::Result<()> {
    let scope = Scope::from_env();
    eprintln!("[fig3] scope: D={} ps={:?} seeds={:?}", scope.d, scope.ps, scope.seeds);
    let t0 = std::time::Instant::now();
    let rows = fig3(&scope, 8)?;
    let mut csv = CsvWriter::create("results/fig3.csv", Row::csv_header())?;
    for r in &rows {
        csv.row(&r.csv())?;
    }
    for dataset in ["isolet", "ucihar", "pamap2", "page"] {
        for budget in [0.2, 0.4, 0.6] {
            let series = series_by(&rows, |r| {
                (r.dataset == dataset && (r.budget - budget).abs() < 1e-9)
                    .then(|| (r.method.clone(), r.p))
            });
            if series.is_empty() {
                continue;
            }
            let xs: Vec<f64> = series[0].1.iter().map(|(x, _)| *x).collect();
            let lines: Vec<(String, Vec<f64>)> = series
                .into_iter()
                .map(|(k, pts)| (k, pts.into_iter().map(|(_, y)| y).collect()))
                .collect();
            println!(
                "{}",
                ascii_chart(
                    &format!("Fig3 {dataset} budget<={budget} (acc vs flip p)"),
                    &xs,
                    &lines
                )
            );
        }
    }
    eprintln!("[fig3] {} rows in {:?} -> results/fig3.csv", rows.len(), t0.elapsed());
    Ok(())
}

//! Regenerates paper Fig. 6: hybrid class- + feature-axis compression on
//! ISOLET — accuracy heatmaps over (number of bundles n) x (retained
//! fraction 1−S), per precision and flip probability.
//!
//! Output: results/fig6.csv + ASCII heatmaps.

use loghd::bench::CsvWriter;
use loghd::eval::figures::{fig6, Row, Scope};

fn main() -> anyhow::Result<()> {
    let scope = Scope::from_env();
    let t0 = std::time::Instant::now();
    let rows = fig6(&scope)?;
    let mut csv = CsvWriter::create("results/fig6.csv", Row::csv_header())?;
    for r in &rows {
        csv.row(&r.csv())?;
    }

    // ASCII heatmap per (bits, p): rows = n, cols = retained fraction.
    let mut bits_list: Vec<u32> = rows.iter().map(|r| r.bits).collect();
    bits_list.sort_unstable();
    bits_list.dedup();
    for &bits in &bits_list {
        for p in [0.0, 0.4] {
            let cells: Vec<&Row> = rows
                .iter()
                .filter(|r| r.bits == bits && (r.p - p).abs() < 1e-9)
                .collect();
            if cells.is_empty() {
                continue;
            }
            println!("## Fig6 isolet {bits}-bit p={p} (mean acc; rows=n, cols=retained)");
            let mut keys: Vec<String> = cells.iter().map(|r| r.method.clone()).collect();
            keys.sort();
            keys.dedup();
            let mut by_n: std::collections::BTreeMap<String, Vec<(f64, f64, usize)>> =
                Default::default();
            for r in &cells {
                let (npart, rpart) = r.method.split_once(',').unwrap();
                let rv: f64 = rpart.trim_start_matches("r=").parse().unwrap();
                by_n.entry(npart.to_string()).or_default().push((rv, r.accuracy, 1));
            }
            for (n, mut pts) in by_n {
                pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                // mean over seeds at the same retained value
                let mut merged: Vec<(f64, f64)> = Vec::new();
                for (rv, acc, _) in pts {
                    if let Some(last) = merged.last_mut() {
                        if (last.0 - rv).abs() < 1e-9 {
                            last.1 = (last.1 + acc) / 2.0;
                            continue;
                        }
                    }
                    merged.push((rv, acc));
                }
                let line: Vec<String> =
                    merged.iter().map(|(rv, a)| format!("{rv:.2}:{a:.3}")).collect();
                println!("  {n:<6} {}", line.join("  "));
            }
            println!();
        }
    }
    eprintln!("[fig6] {} rows in {:?} -> results/fig6.csv", rows.len(), t0.elapsed());
    Ok(())
}

//! Regenerates paper Table II: hardware efficiency ratios of the LogHD
//! ASIC against a SparseHD ASIC (matched memory), a Ryzen 9 9950X, and an
//! RTX 4090 — from measured op counts + the calibrated analytical model
//! (hwmodel) — plus a *measured* CPU data point on this machine (native
//! similarity-stage latency, conventional vs LogHD) to ground the
//! O(CD)→O(nD) compute claim in real wall-clock.
//!
//! Output: results/table2.csv.

use loghd::bench::{bench, CsvWriter};
use loghd::hd::similarity::activations;
use loghd::hwmodel;
use loghd::tensor::Matrix;
use loghd::util::rng::SplitMix64;

fn main() -> anyhow::Result<()> {
    let (f, d, c, n) = (617usize, 10_000usize, 26usize, 7usize);
    println!("Table II — LogHD (ASIC) vs baselines on ISOLET (C={c}, k=2, n={n}, D={d})");
    println!("{:<46} {:>10} {:>10} {:>12} {:>12}", "baseline/platform", "energy x", "speedup x", "paper E x", "paper S x");
    let paper = [(4.06, 2.19), (498.1, 62.6), (24.3, 6.58)];
    let rows = hwmodel::table2(f, d, c, n);
    let mut csv = CsvWriter::create("results/table2.csv", "baseline,platform,energy_ratio,speedup,paper_energy,paper_speedup")?;
    for ((name, e, s), (pe, ps)) in rows.iter().zip(paper) {
        println!("{name:<46} {e:>10.2} {s:>10.2} {pe:>12.2} {ps:>12.2}");
        let (base, plat) = name.split_once(" / ").unwrap_or((name.as_str(), ""));
        csv.row(&[base.into(), plat.into(), format!("{e:.3}"), format!("{s:.3}"),
                  format!("{pe}"), format!("{ps}")])?;
    }

    // Measured CPU point: similarity stage (class memory) wall-clock,
    // conventional (C x D) vs LogHD (n x D + C x n decode) on this host.
    let mut rng = SplitMix64::new(7);
    let batch = 64;
    let queries = Matrix::from_vec(batch, d, rng.normals_f32(batch * d));
    let protos = Matrix::from_vec(c, d, rng.normals_f32(c * d));
    let bundles = Matrix::from_vec(n, d, rng.normals_f32(n * d));
    let profiles = Matrix::from_vec(c, n, rng.normals_f32(c * n));

    let conv = bench(3, 20, || {
        let _ = activations(&queries, &protos);
    });
    let log = bench(3, 20, || {
        let a = activations(&queries, &bundles);
        // profile decode
        let mut best = vec![0usize; batch];
        for i in 0..batch {
            let mut bd = f32::INFINITY;
            for cc in 0..c {
                let dist = loghd::tensor::sqdist(a.row(i), profiles.row(cc));
                if dist < bd {
                    bd = dist;
                    best[i] = cc;
                }
            }
        }
        std::hint::black_box(best);
    });
    let measured_speedup = conv.median / log.median;
    println!();
    println!("measured on this host (native similarity stage, batch {batch}):");
    println!("  conventional C*D: {}", conv.format_line("conv"));
    println!("  loghd n*D + C*n : {}", log.format_line("loghd"));
    println!(
        "  measured class-memory speedup {:.2}x (op-count prediction {:.2}x)",
        measured_speedup,
        (c * d) as f64 / ((n * d) + 2 * c * n) as f64
    );
    csv.row(&["measured-host".into(), "this CPU".into(), "".into(),
              format!("{measured_speedup:.3}"), "".into(), format!("{:.3}", c as f64 / n as f64)])?;
    Ok(())
}

//! Equal-memory robustness campaign harness (EXPERIMENTS.md §Robustness).
//!
//! Runs `eval::campaign` — solve equal-memory cells at one stored-size
//! budget, Monte-Carlo bit-flip campaigns over them, resilience metrics
//! with bootstrap CIs — and writes `results/BENCH_robustness.json` plus
//! a repo-root snapshot. Smoke profile by default (CI-sized);
//! `LOGHD_FULL=1` switches to the paper-scale ISOLET grid.
//!
//! The artifact is deterministic outside its `meta` section for a fixed
//! profile, at any `LOGHD_THREADS` — which is what lets CI and the
//! golden conformance suite compare it at all.

use loghd::eval::campaign::{self, CampaignConfig};

fn main() -> anyhow::Result<()> {
    let cfg = if std::env::var("LOGHD_FULL").as_deref() == Ok("1") {
        CampaignConfig::full()
    } else {
        CampaignConfig::smoke()
    };
    let res = campaign::run(&cfg)?;
    print!("{}", res.summary());
    res.write_default_artifacts()?;
    println!("wrote results/BENCH_robustness.json (+ repo-root snapshot)");
    Ok(())
}

//! Encoder hot-path benchmark: the fused SIMD panel-GEMM + polynomial-cos
//! encode vs the scalar reference, plus the encode-vs-decode cost
//! breakdown per serving precision (EXPERIMENTS.md §Perf).
//!
//! The kernel comparison is **single-core by construction** (both sides
//! loop `encode_row` on the calling thread), so the reported speedup is
//! the SIMD win, not a thread-count artifact. The end-to-end section uses
//! the normal (pooled) engine path.
//!
//! Output: results/encode.csv, results/BENCH_encode.json, and a repo-root
//! BENCH_encode.json snapshot so the perf trajectory is reviewable in the
//! tree (refresh it from CI's artifact or a local run).

use loghd::bench::{bench, CsvWriter};
use loghd::coordinator::{Engine, NativeEngine};
use loghd::data;
use loghd::encoder::Encoder;
use loghd::loghd::model::{TrainOptions, TrainedStack};
use loghd::loghd::qmodel::QuantizedLogHdModel;
use loghd::quant::Precision;
use loghd::tensor::{simd, Matrix};
use loghd::util::json;
use loghd::util::rng::SplitMix64;

fn main() -> anyhow::Result<()> {
    let mut csv = CsvWriter::create("results/encode.csv", "path,metric,value")?;
    let dispatch = simd::path_label();
    println!("dispatch path: {dispatch}");

    // --- Single-core fused-encode kernel vs scalar reference ---
    // Serving-adjacent shape: batch=64 queries, F=64 features, D=2048.
    let (bsz, f, d) = (64usize, 64usize, 2048usize);
    let enc = Encoder::new(f, d, 0xE5C0DE);
    let wpack = enc.wpack();
    let mut rng = SplitMix64::new(42);
    let x = Matrix::from_vec(bsz, f, rng.normals_f32(bsz * f));
    let mut out = Matrix::zeros(bsz, d);

    let scalar_stats = bench(3, 40, || {
        for i in 0..bsz {
            simd::scalar::encode_row(x.row(i), wpack, &enc.b, &enc.mu, out.row_mut(i));
        }
    });
    println!("{}", scalar_stats.format_line("encode scalar 1-core batch=64 F=64 D=2048"));

    let fused_stats = bench(3, 40, || {
        for i in 0..bsz {
            simd::encode_row(x.row(i), wpack, &enc.b, &enc.mu, out.row_mut(i));
        }
    });
    let fused_label = format!("encode {dispatch} 1-core batch=64 F=64 D=2048");
    println!("{}", fused_stats.format_line(&fused_label));

    let speedup = scalar_stats.median / fused_stats.median;
    let melems = (bsz * d) as f64 / fused_stats.median / 1e6;
    println!(
        "encode speedup vs scalar: {speedup:.2}x ({melems:.1} Melem/s fused; target >= 3x on AVX2)"
    );
    for (path, stats) in [("encode_scalar", &scalar_stats), ("encode_simd", &fused_stats)] {
        csv.row(&[path.into(), "batch64_median_s".into(), format!("{:.9}", stats.median)])?;
    }

    // --- Encode-vs-decode breakdown on the serving shape (page model,
    // D=2000, n=7 bundles) ---
    let ds = data::generate_scaled(data::spec("page").unwrap(), 1500, 256);
    let opts = TrainOptions { epochs: 3, conv_epochs: 1, extra_bundles: 4, ..Default::default() };
    let stack = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 2000, 0xE5C0DE, &opts)?;
    let xb = ds.x_test.rows_slice(0, 64);
    let encoded = stack.encoder.encode(&xb);

    let encode_stats = bench(3, 30, || {
        let _ = stack.encoder.encode(&xb);
    });
    println!("{}", encode_stats.format_line("stage encode batch=64 D=2000"));

    let dec_f32 = bench(3, 30, || {
        let _ = stack.loghd.predict(&encoded);
    });
    let qm8 = QuantizedLogHdModel::from_model(&stack.loghd, Precision::B8);
    let dec_b8 = bench(3, 30, || {
        let _ = qm8.predict(&encoded);
    });
    let qm1 = QuantizedLogHdModel::from_model(&stack.loghd, Precision::B1);
    let dec_b1 = bench(3, 30, || {
        let _ = qm1.predict(&encoded);
    });
    println!("{}", dec_f32.format_line("stage decode f32 batch=64"));
    println!("{}", dec_b8.format_line("stage decode b8 batch=64"));
    println!("{}", dec_b1.format_line("stage decode b1 batch=64"));
    for (path, stats) in [
        ("stage_encode", encode_stats),
        ("stage_decode_f32", dec_f32),
        ("stage_decode_b8", dec_b8),
        ("stage_decode_b1", dec_b1),
    ] {
        csv.row(&[path.into(), "batch64_median_s".into(), format!("{:.9}", stats.median)])?;
    }

    // --- End-to-end engine latency per precision ---
    let mut e2e = Vec::new();
    for precision in [Precision::F32, Precision::B8, Precision::B1] {
        let mut engine = NativeEngine::with_precision(
            stack.encoder.clone(),
            stack.loghd.clone(),
            "page",
            precision,
        );
        let stats = bench(3, 30, || {
            let _ = engine.infer(&xb).unwrap();
        });
        println!("{}", stats.format_line(&format!("e2e native {} batch=64", precision.label())));
        csv.row(&[
            format!("e2e_{}", precision.label()),
            "batch64_median_s".into(),
            format!("{:.9}", stats.median),
        ])?;
        e2e.push((precision.label(), json::num(stats.median)));
    }

    let report = json::obj(vec![
        ("dispatch", json::s(dispatch)),
        ("threads", json::num(loghd::util::threadpool::available_threads() as f64)),
        ("kernel_batch", json::num(bsz as f64)),
        ("kernel_features", json::num(f as f64)),
        ("kernel_d", json::num(d as f64)),
        ("scalar_encode_median_s", json::num(scalar_stats.median)),
        ("simd_encode_median_s", json::num(fused_stats.median)),
        ("encode_speedup_vs_scalar", json::num(speedup)),
        (
            "stages_batch64_d2000_s",
            json::obj(vec![
                ("encode", json::num(encode_stats.median)),
                ("decode_f32", json::num(dec_f32.median)),
                ("decode_b8", json::num(dec_b8.median)),
                ("decode_b1", json::num(dec_b1.median)),
            ]),
        ),
        ("e2e_batch64_median_s", json::obj(e2e)),
    ]);
    let text = json::to_string_pretty(&report);
    std::fs::write("results/BENCH_encode.json", &text)?;
    std::fs::write("BENCH_encode.json", &text)?;
    println!("wrote results/BENCH_encode.json (+ repo-root snapshot)");
    Ok(())
}

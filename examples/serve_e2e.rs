//! END-TO-END serving driver — proves all three layers compose.
//!
//! Loads the Python-AOT artifact bundle (L2 graphs calling L1 Pallas
//! kernels, lowered to HLO text by `make artifacts`), compiles it on the
//! PJRT CPU client, spins up the L3 coordinator (dynamic batcher + worker
//! + TCP server), drives the bundle's real held-out test set through it as
//! batched requests, and reports accuracy + latency/throughput. Also
//! cross-checks the native-Rust engine on the same tensors (parity).
//!
//!   make artifacts && cargo run --release --example serve_e2e
//!   (defaults to artifacts/page_smoke; pass a bundle dir to override)

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;

use loghd::coordinator::{BatcherConfig, Coordinator, ModelRegistry, PjrtEngine, Server};
use loghd::eval::accuracy;
use loghd::loghd::persist;
use loghd::runtime::artifact::Manifest;

fn main() -> anyhow::Result<()> {
    let bundle = PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts/page_smoke".into()),
    );
    if !bundle.join("manifest.json").exists() {
        anyhow::bail!("bundle {} missing — run `make artifacts` first", bundle.display());
    }
    let manifest = Manifest::load(&bundle)?;
    println!(
        "bundle {}: dataset={} D={} k={} n={} batch={} (trained clean acc: conv {:.3} / loghd {:.3})",
        manifest.name, manifest.dataset, manifest.d, manifest.k, manifest.n,
        manifest.batch, manifest.clean_acc_conventional, manifest.clean_acc_loghd
    );

    // L3 coordinator over the PJRT engine (L1+L2 compiled HLO).
    let cfg = BatcherConfig {
        max_batch: manifest.batch,
        max_delay: std::time::Duration::from_millis(4),
        max_pending: 4096,
    };
    let coord = Arc::new(Coordinator::start(
        manifest.features,
        cfg,
        PjrtEngine::factory(bundle.clone(), "infer_loghd".into()),
    ));
    let registry =
        Arc::new(ModelRegistry::single_with(&manifest.name, "aot-bundle", Arc::clone(&coord)));
    let mut server = Server::start("127.0.0.1:0", Arc::clone(&registry))?;
    println!("coordinator + TCP server up on {}", server.addr);

    // Drive the bundle's real held-out test set through the coordinator.
    let (x_test, y_test) = persist::load_test_data(&bundle)?;
    let n_queries = x_test.rows();
    // warm-up: engine construction (PJRT compile) happens on the worker
    // thread; one blocking request keeps the cold start out of the stats.
    coord.submit_blocking(x_test.row(0).to_vec()).expect("warmup");
    println!("serving {n_queries} batched requests (the full held-out test set)...");
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..n_queries)
        .map(|i| coord.submit(x_test.row(i).to_vec()).expect("submit"))
        .collect();
    let mut preds = Vec::with_capacity(n_queries);
    for rx in rxs {
        preds.push(rx.recv()?.label);
    }
    let elapsed = t0.elapsed();
    let served_acc = accuracy(&preds, &y_test);

    // A few requests over the real TCP wire, too.
    let mut stream = TcpStream::connect(server.addr)?;
    let feat_json: Vec<String> = x_test.row(0).iter().map(|v| format!("{v}")).collect();
    writeln!(stream, "{{\"features\": [{}]}}", feat_json.join(","))?;
    writeln!(stream, "{{\"cmd\": \"stats\"}}")?;
    stream.shutdown(std::net::Shutdown::Write)?;
    let tcp_lines: Vec<String> = BufReader::new(stream).lines().collect::<Result<_, _>>()?;

    // Native-engine parity on the same tensors (Python-trained bundle).
    let (encoder, model) = persist::load_from_aot_bundle(&bundle)?;
    let native_preds = model.predict(&encoder.encode(&x_test));
    let agree = preds.iter().zip(&native_preds).filter(|(a, b)| a == b).count();

    let snap = coord.stats();
    println!();
    println!("=== END-TO-END REPORT ({}) ===", manifest.name);
    println!("served accuracy      : {served_acc:.4} (expected ~{:.4})", manifest.clean_acc_loghd);
    println!("throughput           : {:.0} req/s ({n_queries} requests in {elapsed:.2?})",
        n_queries as f64 / elapsed.as_secs_f64());
    println!("latency              : p50 {:.0}µs  p95 {:.0}µs  p99 {:.0}µs  mean {:.0}µs",
        snap.latency_p50_us, snap.latency_p95_us, snap.latency_p99_us, snap.latency_mean_us);
    println!("batching             : {} batches, mean size {:.1}", snap.batches, snap.mean_batch_size);
    println!("XLA vs native parity : {agree}/{n_queries} labels agree ({:.2}%)",
        100.0 * agree as f64 / n_queries as f64);
    println!("TCP round-trip       : {}", tcp_lines.first().map(String::as_str).unwrap_or("-"));

    server.shutdown();
    anyhow::ensure!(served_acc > manifest.clean_acc_loghd - 0.02, "served accuracy regressed");
    anyhow::ensure!(agree as f64 >= 0.99 * n_queries as f64, "XLA/native parity broke");
    println!("OK");
    Ok(())
}

//! Quickstart: train a LogHD classifier on the PAGE-like dataset, compare
//! it against the conventional O(C·D) model, and show the memory math.
//!
//!   cargo run --release --example quickstart

use loghd::baselines::ConventionalModel;
use loghd::data;
use loghd::eval::accuracy;
use loghd::loghd::model::{TrainOptions, TrainedStack};

fn main() -> anyhow::Result<()> {
    let spec = data::spec("page").unwrap();
    let ds = data::generate(spec);
    println!(
        "dataset: {} — {} features, {} classes, {} train / {} test",
        spec.name, spec.features, spec.classes, spec.n_train, spec.n_test
    );

    let d = 2000;
    let opts = TrainOptions { extra_bundles: 1, epochs: 10, ..Default::default() };
    println!("training at D={d} (k={}, epsilon={} extra bundles)...", opts.k, opts.extra_bundles);
    let stack = TrainedStack::train(&ds.x_train, &ds.y_train, spec.classes, d, 0xE5C0DE, &opts)?;

    let enc_test = stack.encoder.encode(&ds.x_test);
    let conv = ConventionalModel::new(stack.prototypes.clone());
    let conv_acc = accuracy(&conv.predict(&enc_test), &ds.y_test);
    let log_acc = accuracy(&stack.loghd.predict(&enc_test), &ds.y_test);

    println!();
    println!("conventional HDC : acc {:.4}, {} stored floats (C*D)", conv_acc, conv.memory_floats());
    println!(
        "LogHD (n={})     : acc {:.4}, {} stored floats (n*D + C*n) = {:.1}% of conventional",
        stack.loghd.n_bundles(),
        log_acc,
        stack.loghd.memory_floats(),
        100.0 * stack.loghd.budget_fraction()
    );
    println!(
        "class-axis compression: {} prototypes -> {} bundles ({}x fewer stored vectors)",
        spec.classes,
        stack.loghd.n_bundles(),
        spec.classes as f64 / stack.loghd.n_bundles() as f64
    );
    Ok(())
}

//! Hybrid tuning (a slice of Fig. 6): sweep sparsity at fixed bundle
//! counts on ISOLET and watch the U-shaped response + the memory knob.
//!
//!   cargo run --release --example hybrid_tuning

use loghd::data;
use loghd::eval::sweep::{Method, Workbench};
use loghd::loghd::codebook::min_bundles;
use loghd::loghd::model::TrainOptions;
use loghd::quant::Precision;

fn main() -> anyhow::Result<()> {
    let spec = data::spec("isolet").unwrap();
    let ds = data::generate_scaled(spec, 3000, 800);
    let opts = TrainOptions { epochs: 5, conv_epochs: 2, ..Default::default() };
    let mut wb = Workbench::new(&ds, 2000, 0xE5C0DE, opts);
    let c = wb.classes;

    let retained = [1.0, 0.85, 0.7, 0.55, 0.4, 0.25, 0.1];
    println!("isolet D=2000, 8-bit. cells = clean acc | acc at p=0.4   (budget = n*(1-S)/C)");
    print!("{:<8}", "n \\ 1-S");
    for r in &retained {
        print!(" {r:>13.2}");
    }
    println!();
    for extra in [0usize, 2, 5] {
        let n = min_bundles(c, 2) + extra;
        print!("{n:<8}");
        for &r in &retained {
            let method = if r >= 1.0 {
                Method::LogHd { k: 2, n }
            } else {
                Method::Hybrid { k: 2, n, sparsity: 1.0 - r }
            };
            let clean = wb.evaluate(method, Precision::B8, 0.0, 1)?;
            let faulted = wb.evaluate(method, Precision::B8, 0.4, 1)?;
            print!("  {clean:.3}|{faulted:.3}");
        }
        println!();
    }
    println!("\nreading: across a row, moderate pruning can help clean accuracy (U-shape),");
    println!("but fault tolerance (right of '|') decays as retained dimensionality shrinks —");
    println!("the paper's §IV-D conclusion: the hybrid is a tunable middle ground whose");
    println!("robustness ceiling is bounded by the dimensionality reduction it imposes.");
    Ok(())
}

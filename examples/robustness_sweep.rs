//! Robustness mini-study (a one-dataset slice of Fig. 3): accuracy vs
//! bit-flip probability at a matched memory budget, SparseHD vs LogHD vs
//! Hybrid, plus the paper's headline statistic — how much higher a fault
//! rate each method sustains at a target accuracy.
//!
//!   cargo run --release --example robustness_sweep [dataset] [budget]

use loghd::data;
use loghd::eval::figures::methods_at_budget;
use loghd::eval::sweep::Workbench;
use loghd::eval::{mean_std, sustained_until};
use loghd::loghd::model::TrainOptions;
use loghd::quant::Precision;

fn main() -> anyhow::Result<()> {
    let dataset = std::env::args().nth(1).unwrap_or_else(|| "ucihar".into());
    let budget: f64 = std::env::args().nth(2).map(|s| s.parse().unwrap()).unwrap_or(0.4);
    let spec = data::spec(&dataset).expect("unknown dataset");
    let ds = data::generate_scaled(spec, spec.n_train.min(3000), spec.n_test.min(800));
    let opts = TrainOptions { epochs: 5, conv_epochs: 2, ..Default::default() };
    let mut wb = Workbench::new(&ds, 2000, 0xE5C0DE, opts);
    println!(
        "{dataset} at budget <= {budget} of C*D (D=2000, 8-bit stored model), clean conventional = {:.4}",
        wb.conventional_clean()
    );

    let ps = [0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8];
    let seeds = [1u64, 2, 3];
    let floor_frac = 0.95; // "target accuracy" = 95% of clean accuracy
    let mut sustained: Vec<(String, f64)> = Vec::new();
    for method in methods_at_budget(wb.classes, budget) {
        let mut curve = Vec::new();
        print!("{:<24}", method.label());
        for &p in &ps {
            let accs: Vec<f64> = seeds
                .iter()
                .map(|&s| wb.evaluate(method, Precision::B8, p, s).unwrap())
                .collect();
            let (mean, _std) = mean_std(&accs);
            curve.push(mean);
            print!(" {mean:.3}");
        }
        println!();
        let floor = curve[0] * floor_frac;
        let p_max = sustained_until(&ps, &curve, floor);
        sustained.push((method.label(), p_max));
    }
    println!("\nsustained flip rate at 95%-of-clean accuracy:");
    let sparse_p = sustained
        .iter()
        .find(|(name, _)| name.starts_with("sparsehd"))
        .map(|(_, p)| *p)
        .unwrap_or(0.0);
    for (name, p) in &sustained {
        let rel = if sparse_p > 0.0 { format!(" ({:.1}x SparseHD)", p / sparse_p) } else { String::new() };
        println!("  {name:<24} p <= {p:.3}{rel}");
    }
    println!("\npaper claim: LogHD sustains target accuracy at ~2.5-3.0x higher flip rates than feature-axis compression");
    Ok(())
}

#!/usr/bin/env python3
"""Compare a fresh bench report against its committed snapshot.

Usage:
    bench_delta.py FRESH.json SNAPSHOT.json METRIC:DIRECTION [...]
                   [--max-regress 0.15] [--require]

Each METRIC:DIRECTION names a numeric field in both JSON documents and
which way is better: ``lower`` (latencies, allocs) or ``higher``
(throughput). Dotted paths descend into nested objects, so
``e2e_batch64_median_s.f32:lower`` gates a field inside
BENCH_encode.json's per-precision block. A metric regressing by more than
``--max-regress`` (relative, default 15%) fails the run with exit 1.

Snapshots are blessed by copying a CI artifact over the repo-root file;
until then they hold ``null`` placeholders (see BENCH_encode.json for
the convention) and every comparison is reported as an explicit
``SKIPPED (unblessed)`` line, so wiring the gate into CI is safe before
the first real numbers land. A metric is also skipped when either side
is missing, non-numeric, or the snapshot value is zero (no relative
delta exists). Pass ``--require`` once a snapshot has been blessed:
skips then fail the run with exit 1, so a silently-renamed or dropped
metric can never turn the gate into a no-op.

Stdlib only — CI runners and the authoring container both lack
third-party Python packages.
"""

from __future__ import annotations

import argparse
import json
import numbers
import sys


def load(path: str) -> dict:
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except OSError as e:
        sys.exit(f"bench_delta: cannot read {path}: {e}")
    except json.JSONDecodeError as e:
        sys.exit(f"bench_delta: {path} is not valid JSON: {e}")
    if not isinstance(doc, dict):
        sys.exit(f"bench_delta: {path} must hold a JSON object")
    return doc


def numeric(doc: dict, key: str):
    """Resolve ``key`` in ``doc``; dotted paths descend into nested objects."""
    v = doc
    for part in key.split("."):
        if not isinstance(v, dict):
            return None
        v = v.get(part)
    if isinstance(v, numbers.Real) and not isinstance(v, bool):
        return float(v)
    return None


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", help="freshly produced bench report")
    ap.add_argument("snapshot", help="committed snapshot to compare against")
    ap.add_argument(
        "metrics",
        nargs="+",
        metavar="METRIC:DIRECTION",
        help="field (dotted path for nested) and its better direction (lower|higher)",
    )
    ap.add_argument(
        "--max-regress",
        type=float,
        default=0.15,
        help="relative regression that fails the gate (default 0.15)",
    )
    ap.add_argument(
        "--require",
        action="store_true",
        help="fail (exit 1) when any metric is skipped — for gates whose "
        "snapshot has been blessed and must stay comparable",
    )
    args = ap.parse_args()

    fresh = load(args.fresh)
    snap = load(args.snapshot)

    failures = []
    skipped = []
    for spec in args.metrics:
        name, sep, direction = spec.partition(":")
        if not sep or direction not in ("lower", "higher"):
            sys.exit(f"bench_delta: bad metric spec '{spec}' (want NAME:lower|higher)")
        f = numeric(fresh, name)
        s = numeric(snap, name)
        if f is None or s is None:
            print(f"  SKIPPED (unblessed) {name}: fresh={f}, snapshot={s}")
            skipped.append(name)
            continue
        if s == 0.0:
            print(f"  SKIPPED (zero snapshot) {name}: no relative delta exists")
            skipped.append(name)
            continue
        # Positive regression = got worse in the metric's bad direction.
        regress = (f - s) / s if direction == "lower" else (s - f) / s
        verdict = "FAIL" if regress > args.max_regress else "ok"
        print(
            f"  {verdict:<5} {name}: snapshot {s:.6g} -> fresh {f:.6g} "
            f"({regress:+.1%} vs {args.max_regress:.0%} budget, {direction} is better)"
        )
        if regress > args.max_regress:
            failures.append(name)

    if failures:
        print(f"bench_delta: {len(failures)} metric(s) regressed: {', '.join(failures)}")
        return 1
    if args.require and skipped:
        print(
            f"bench_delta: --require set but {len(skipped)} metric(s) "
            f"skipped: {', '.join(skipped)}"
        )
        return 1
    if skipped:
        print(f"bench_delta: within budget ({len(skipped)} metric(s) skipped)")
    else:
        print("bench_delta: within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())

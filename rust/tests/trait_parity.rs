//! Trait-object parity: the unified model core's dispatched
//! `predict` / `apply_flips` pipeline must be **bit-identical** to the
//! pre-refactor direct call sequences, for every migrated family, at
//! every precision the campaign grids use, clean and corrupted.
//!
//! The dense-width references below are verbatim transplants of the
//! per-method match arms `eval::sweep::Workbench::evaluate_cell` carried
//! before the trait migration (built on the retained scalar helpers
//! `corrupt` / `corrupt_masked` / `corrupt_profiles`). The packed-width
//! references re-specify the pre-refactor stream **from first
//! principles** — one `value_flip_mask` per stored part, plane sizes
//! computed from the model shape (n·D bundles, n columns of C, the
//! n-vector mean), in that fixed order — rather than calling the shared
//! driver, so a regression in the driver's stream discipline (plane
//! reorder, batched draws) fails here instead of passing tautologically.
//! Each cell draws its fault stream from `cell_stream`, exactly as
//! campaigns do — so equality here means campaign artifacts are
//! unchanged by the refactor, byte for byte.

use loghd::baselines::{ConventionalModel, DecoHdModel, HybridModel, SparseHdModel};
use loghd::eval::metrics::accuracy;
use loghd::eval::sweep::{
    cell_stream, corrupt, corrupt_masked, corrupt_profiles, gather_cols, Method, Workbench,
};
use loghd::faults::value_flip_mask;
use loghd::loghd::model::{LogHdModel, TrainOptions};
use loghd::loghd::qmodel::QuantizedLogHdModel;
use loghd::model::HdClassifier;
use loghd::quant::Precision;
use loghd::testkit;
use loghd::util::rng::SplitMix64;

fn bench(d: usize) -> Workbench {
    let ds = testkit::mini("page").unwrap();
    let opts = TrainOptions { epochs: 3, conv_epochs: 1, ..Default::default() };
    Workbench::new(&ds, d, 0xE5C0DE, opts)
}

/// The family models the reference path corrupts, built once with the
/// same deterministic constructions the Workbench caches use.
struct RefModels {
    loghd: LogHdModel,
    hybrid: HybridModel,
    sparse: SparseHdModel,
}

impl RefModels {
    fn build(wb: &mut Workbench, k: u32, n: usize, sparsity: f64) -> Self {
        let loghd = wb.loghd(k, n).unwrap().clone();
        let hybrid =
            HybridModel::from_loghd(&loghd, &wb.enc_train, &wb.y_train, sparsity).unwrap();
        let sparse = SparseHdModel::from_prototypes(&wb.prototypes, sparsity);
        Self { loghd, hybrid, sparse }
    }
}

/// The pre-refactor per-part fault stream for a packed LogHD-shaped
/// model, drawn from first principles: one `value_flip_mask` for the
/// (n·d)-value bundle plane, one per (C)-value profile column, one for
/// the n-value profile mean — applied in that order, then a view
/// refresh. This is the stream `QuantizedLogHdModel::inject_value_faults`
/// consumed before the trait migration; spelling it out here (instead of
/// calling the shared driver) keeps the packed parity legs
/// non-tautological.
fn packed_reference_flips(
    qm: &mut QuantizedLogHdModel,
    n: usize,
    c: usize,
    d: usize,
    flip_p: f64,
    rng: &mut SplitMix64,
) {
    let bits = qm.precision.bits();
    let plane_values: Vec<usize> =
        std::iter::once(n * d).chain(std::iter::repeat(c).take(n)).chain([n]).collect();
    for (i, values) in plane_values.into_iter().enumerate() {
        let mask = value_flip_mask(values, bits, flip_p, rng);
        qm.apply_flips(i, &mask);
    }
    qm.refresh();
}

/// The pre-refactor direct evaluation of one (method, precision, p)
/// cell: per-family corruption + per-family scoring, consuming `rng`
/// exactly as the old `evaluate_cell` match did.
fn reference_cell(
    wb: &Workbench,
    models: &RefModels,
    method: Method,
    precision: Precision,
    flip_p: f64,
    rng: &mut SplitMix64,
) -> Vec<i32> {
    match method {
        Method::Conventional => {
            let h = corrupt(&wb.prototypes, precision, flip_p, rng);
            ConventionalModel::new(h).predict(&wb.enc_test)
        }
        Method::SparseHd { .. } => {
            let model = &models.sparse;
            let h = corrupt_masked(&model.prototypes, &model.mask, precision, flip_p, rng);
            ConventionalModel::new(h).predict(&wb.enc_test)
        }
        Method::LogHd { .. } => {
            let model = &models.loghd;
            match precision {
                Precision::B1 | Precision::B8 => {
                    let mut qm = QuantizedLogHdModel::from_model(model, precision);
                    let (n, c, d) = (model.n_bundles(), model.classes, model.d);
                    packed_reference_flips(&mut qm, n, c, d, flip_p, rng);
                    qm.predict(&wb.enc_test)
                }
                _ => {
                    let corrupted = LogHdModel {
                        classes: model.classes,
                        d: model.d,
                        book: model.book.clone(),
                        bundles: corrupt(&model.bundles, precision, flip_p, rng),
                        profiles: corrupt_profiles(&model.profiles, precision, flip_p, rng),
                    };
                    corrupted.predict(&wb.enc_test)
                }
            }
        }
        Method::Hybrid { .. } => {
            let hybrid = &models.hybrid;
            match precision {
                Precision::B1 | Precision::B8 => {
                    let kept: Vec<usize> = hybrid
                        .mask
                        .iter()
                        .enumerate()
                        .filter(|(_, keep)| **keep)
                        .map(|(i, _)| i)
                        .collect();
                    let inner = LogHdModel {
                        classes: hybrid.inner.classes,
                        d: kept.len(),
                        book: hybrid.inner.book.clone(),
                        bundles: gather_cols(&hybrid.inner.bundles, &kept),
                        profiles: hybrid.inner.profiles.clone(),
                    };
                    let mut qm = QuantizedLogHdModel::from_model(&inner, precision);
                    qm.set_activation_gain((kept.len() as f32 / wb.d as f32).sqrt());
                    let (n, c, d) = (inner.n_bundles(), inner.classes, inner.d);
                    packed_reference_flips(&mut qm, n, c, d, flip_p, rng);
                    qm.predict(&gather_cols(&wb.enc_test, &kept))
                }
                _ => {
                    let corrupted = LogHdModel {
                        classes: hybrid.inner.classes,
                        d: hybrid.inner.d,
                        book: hybrid.inner.book.clone(),
                        bundles: corrupt_masked(
                            &hybrid.inner.bundles,
                            &hybrid.mask,
                            precision,
                            flip_p,
                            rng,
                        ),
                        profiles: corrupt_profiles(
                            &hybrid.inner.profiles,
                            precision,
                            flip_p,
                            rng,
                        ),
                    };
                    corrupted.predict(&wb.enc_test)
                }
            }
        }
        Method::DecoHd { .. } => unreachable!("no pre-refactor reference for DecoHD"),
    }
}

/// Trait-dispatched predictions for the same cell on the same stream.
fn trait_cell(
    wb: &Workbench,
    method: Method,
    precision: Precision,
    flip_p: f64,
    rng: &mut SplitMix64,
) -> Vec<i32> {
    let mut inst = wb.instance(method, precision).unwrap();
    loghd::model::inject_value_faults(inst.as_mut(), flip_p, rng);
    inst.predict(&wb.enc_test)
}

#[test]
fn all_five_families_dispatch_bit_identically() {
    let mut wb = bench(192);
    let (k, n, sparsity) = (2u32, 4usize, 0.5f64);
    let methods = [
        Method::Conventional,
        Method::SparseHd { sparsity },
        Method::LogHd { k, n },
        Method::Hybrid { k, n, sparsity },
    ];
    for method in methods {
        wb.warm(method).unwrap();
    }
    let models = RefModels::build(&mut wb, k, n, sparsity);
    for method in methods {
        for precision in [Precision::F32, Precision::B8, Precision::B1] {
            for (p, trial) in [(0.0, 0u64), (0.25, 1), (0.6, 2)] {
                let mut r1 = cell_stream(7, &method, precision, p, trial);
                let want = reference_cell(&wb, &models, method, precision, p, &mut r1);
                let mut r2 = cell_stream(7, &method, precision, p, trial);
                let got = trait_cell(&wb, method, precision, p, &mut r2);
                assert_eq!(
                    got,
                    want,
                    "{} @{} p={p} trial={trial}: trait dispatch diverged from direct calls",
                    method.label(),
                    precision.label()
                );
                // and the streams must end at the same position
                assert_eq!(
                    r1.next_u64(),
                    r2.next_u64(),
                    "{} @{} p={p}: stream positions diverged",
                    method.label(),
                    precision.label()
                );
            }
        }
    }
}

#[test]
fn dense_quant_widths_also_match() {
    // B2/B4 have no packed kernel; they take the quantize-flip-dequantize
    // path in both worlds.
    let mut wb = bench(128);
    let (k, n, sparsity) = (2u32, 4usize, 0.5f64);
    let method = Method::LogHd { k, n };
    wb.warm(method).unwrap();
    let models = RefModels::build(&mut wb, k, n, sparsity);
    for precision in [Precision::B2, Precision::B4] {
        for p in [0.0, 0.4] {
            let mut r1 = cell_stream(3, &method, precision, p, 0);
            let want = reference_cell(&wb, &models, method, precision, p, &mut r1);
            let mut r2 = cell_stream(3, &method, precision, p, 0);
            let got = trait_cell(&wb, method, precision, p, &mut r2);
            assert_eq!(got, want, "{precision:?} p={p}");
        }
    }
}

#[test]
fn evaluate_cell_accuracy_equals_trait_pipeline() {
    // Workbench::evaluate_cell is the trait pipeline; pin the composed
    // accuracy too so any future wrapper drift is caught at the API the
    // campaign engine actually calls.
    let mut wb = bench(128);
    let method = Method::SparseHd { sparsity: 0.4 };
    wb.warm(method).unwrap();
    let mut r1 = cell_stream(11, &method, Precision::B8, 0.3, 0);
    let via_wb = wb.evaluate_cell(method, Precision::B8, 0.3, &mut r1).unwrap();
    let mut r2 = cell_stream(11, &method, Precision::B8, 0.3, 0);
    let pred = trait_cell(&wb, method, Precision::B8, 0.3, &mut r2);
    assert_eq!(via_wb, accuracy(&pred, &wb.y_test));
}

#[test]
fn stored_bits_parity_between_solver_and_instances() {
    // The campaign solver's closed-form accounting must equal the
    // trait-reported fault-surface size for every family x precision —
    // including the DecoHD newcomer.
    let mut wb = bench(192);
    let methods = [
        Method::Conventional,
        Method::SparseHd { sparsity: 0.5 },
        Method::LogHd { k: 2, n: 4 },
        Method::Hybrid { k: 2, n: 4, sparsity: 0.5 },
        Method::DecoHd { rank: 3 },
    ];
    for method in methods {
        wb.warm(method).unwrap();
        for precision in [Precision::F32, Precision::B8, Precision::B1] {
            let inst = wb.instance(method, precision).unwrap();
            assert_eq!(
                inst.stored_bits(),
                loghd::eval::stored_bits(&method, precision, wb.classes, wb.d),
                "{} @{}",
                method.label(),
                precision.label()
            );
            assert_eq!(inst.classes(), wb.classes);
            assert_eq!(inst.d(), wb.d);
        }
    }
}

#[test]
fn decohd_trait_cell_is_well_behaved() {
    // No pre-refactor reference exists for DecoHD (it was born on the
    // trait), so pin its contract directly: p=0 is the clean model,
    // the surface is exactly its two declared planes, and heavy
    // corruption does not help.
    let mut wb = bench(192);
    let method = Method::DecoHd { rank: 3 };
    wb.warm(method).unwrap();
    let deco = DecoHdModel::from_prototypes(&wb.prototypes, 3).unwrap();
    for precision in [Precision::F32, Precision::B8, Precision::B1] {
        let mut rng = cell_stream(5, &method, precision, 0.0, 0);
        let clean = trait_cell(&wb, method, precision, 0.0, &mut rng);
        if precision == Precision::F32 {
            assert_eq!(clean, deco.predict(&wb.enc_test), "clean f32 must be the model itself");
        }
        let surface = wb.instance(method, precision).unwrap().fault_surface();
        assert_eq!(surface.planes.len(), 2);
        assert_eq!(surface.planes[0].label, "basis");
        assert_eq!(surface.planes[1].label, "coeffs");
        let mut rng = cell_stream(5, &method, precision, 0.7, 1);
        let wrecked = trait_cell(&wb, method, precision, 0.7, &mut rng);
        let (ca, wa) = (accuracy(&clean, &wb.y_test), accuracy(&wrecked, &wb.y_test));
        assert!(wa <= ca + 0.05, "{precision:?}: flips helped? {wa} vs {ca}");
    }
}

//! End-to-end suite for streaming continual learning (the PR-9
//! acceptance path):
//!
//! - the smoke drift campaign runs frozen-vs-online through the real
//!   registry, drops zero inferences across its 18 live publishes,
//!   matches the committed structural golden
//!   (`rust/tests/golden/drift_smoke.json`, re-bless with
//!   `LOGHD_BLESS=1`), and shows the online tenant sustaining accuracy
//!   where the frozen tenant degrades;
//! - feedback and inference run *concurrently* through the TCP front
//!   door across several live publishes — every inference answers,
//!   trainer generations are monotone, and the same verb works on the
//!   binary framing;
//! - reservoir sampling and the drift stream are deterministic in
//!   their seeds (property-style, several seeds);
//! - the drift artifact is bit-identical across `LOGHD_THREADS`
//!   settings (pinned by running the actual binary twice).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;

use loghd::coordinator::{frame, BatcherConfig, EngineFactory, ModelRegistry, NativeEngine, Server};
use loghd::data;
use loghd::eval::drift::{self, DriftConfig};
use loghd::loghd::model::{TrainOptions, TrainedStack};
use loghd::loghd::online::{OnlineConfig, OnlineTrainer, Reservoir};
use loghd::testkit::golden::{self, GoldenOptions};
use loghd::util::json::{self, Value};
use loghd::util::rng::SplitMix64;

// ---------------------------------------------------------------------------
// Drift campaign: golden + zero-drop + the continual-learning payoff
// ---------------------------------------------------------------------------

#[test]
fn drift_smoke_campaign_matches_golden_and_online_sustains() {
    let res = drift::run(&DriftConfig::smoke()).expect("smoke drift campaign");
    let v = res.to_json();

    // --- schema sanity ---
    assert_eq!(v.get("schema").unwrap().as_str(), Some("loghd-drift/v1"));
    let curve = v.get("curve").unwrap().as_array().unwrap();
    assert_eq!(curve.len(), 8, "one report per stream window");
    for w in curve {
        for key in ["frozen_acc", "online_acc"] {
            let a = w.get(key).unwrap().as_f64().unwrap();
            assert!((0.0..=1.0).contains(&a), "{key} {a} out of range");
        }
    }

    // --- zero-drop accounting across every live publish ---
    assert_eq!(res.dropped, 0, "inferences dropped during live publishes");
    assert_eq!(res.feedback_rejected, 0);
    assert_eq!(res.publishes, 18, "cadence of 64 over 1200 accepted samples");
    assert!(res.publishes >= 2, "campaign must cross at least two publish cycles");
    assert_eq!(res.final_classes, 6, "mid-stream class addition cost one codeword");

    // --- the committed golden pins the structural core ---
    golden::check_file("rust/tests/golden/drift_smoke.json", &v, &GoldenOptions::exact())
        .unwrap();

    // --- the continual-learning payoff: the frozen tenant degrades
    // under rotation + covariate shift + the unseen class, the online
    // tenant tracks the stream ---
    let first_frozen = res.windows[0].frozen_acc;
    assert!(
        res.frozen_last2 < first_frozen - 0.05,
        "frozen tenant should degrade under drift: {:.4} -> {:.4}",
        first_frozen,
        res.frozen_last2
    );
    assert!(
        res.online_last2 > res.frozen_last2 + 0.02,
        "online tenant must sustain accuracy where frozen degrades \
         (online {:.4} vs frozen {:.4})",
        res.online_last2,
        res.frozen_last2
    );
}

// ---------------------------------------------------------------------------
// Concurrent feedback + inference through the TCP front door
// ---------------------------------------------------------------------------

fn infer_line(features: &[f32]) -> Vec<u8> {
    let feats: Vec<Value> = features.iter().map(|f| json::num(*f as f64)).collect();
    let mut bytes = json::to_string(&json::obj(vec![("features", json::arr(feats))])).into_bytes();
    bytes.push(b'\n');
    bytes
}

fn feedback_doc(features: &[f32], label: i32) -> Value {
    let feats: Vec<Value> = features.iter().map(|f| json::num(*f as f64)).collect();
    json::obj(vec![
        ("cmd", json::s("feedback")),
        ("features", json::arr(feats)),
        ("label", json::num(label as f64)),
    ])
}

fn read_json_reply(reader: &mut BufReader<TcpStream>) -> Value {
    let mut line = String::new();
    let n = reader.read_line(&mut line).unwrap();
    assert!(n > 0, "server closed before replying");
    json::parse(line.trim()).unwrap_or_else(|e| panic!("bad reply '{line}': {e}"))
}

fn read_binary_reply(stream: &mut TcpStream) -> Value {
    let mut hdr = [0u8; frame::HEADER_LEN];
    stream.read_exact(&mut hdr).unwrap();
    let len = u32::from_le_bytes(hdr[4..8].try_into().unwrap()) as usize;
    let mut whole = hdr.to_vec();
    whole.resize(frame::HEADER_LEN + len, 0);
    stream.read_exact(&mut whole[frame::HEADER_LEN..]).unwrap();
    match frame::try_extract(&whole, frame::DEFAULT_MAX_FRAME) {
        frame::Extract::Frame { header, payload } => {
            frame::decode_reply_to_json(&header, &whole[payload]).unwrap()
        }
        other => panic!("expected a reply frame, got {other:?}"),
    }
}

#[test]
fn concurrent_feedback_and_inference_survive_live_publishes() {
    let ds = data::generate_scaled(data::spec("page").unwrap(), 300, 60);
    let opts = TrainOptions { epochs: 1, conv_epochs: 0, extra_bundles: 1, ..Default::default() };
    let st = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 128, 1, &opts).unwrap();
    let factories: Vec<EngineFactory> = (0..2)
        .map(|_| NativeEngine::factory(st.encoder.clone(), st.loghd.clone(), "page".into()))
        .collect();
    let registry = Arc::new(ModelRegistry::single(
        "page",
        "loghd",
        10,
        &BatcherConfig::default(),
        factories,
    ));
    let cfg = OnlineConfig { publish_every: 25, min_samples: 20, ..Default::default() };
    registry
        .attach_trainer(None, OnlineTrainer::new(st.encoder.clone(), st.loghd.clone(), cfg))
        .unwrap();
    let mut server = Server::start("127.0.0.1:0", Arc::clone(&registry)).unwrap();
    let addr = server.addr;

    // Two inference clients hammer the tenant for the whole feedback
    // stream; every reply must be a label, never an error.
    let stop = Arc::new(AtomicBool::new(false));
    let rows: Arc<Vec<Vec<f32>>> =
        Arc::new((0..ds.x_test.rows()).map(|i| ds.x_test.row(i).to_vec()).collect());
    let mut clients = Vec::new();
    for c in 0..2usize {
        let stop = Arc::clone(&stop);
        let rows = Arc::clone(&rows);
        clients.push(thread::spawn(move || -> (u64, u64) {
            let mut stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let (mut ok, mut bad) = (0u64, 0u64);
            let mut i = c;
            while !stop.load(Ordering::Relaxed) {
                stream.write_all(&infer_line(&rows[i % rows.len()])).unwrap();
                let reply = read_json_reply(&mut reader);
                match reply.get("label").and_then(Value::as_f64) {
                    Some(l) if (0.0..5.0).contains(&l) => ok += 1,
                    _ => bad += 1,
                }
                i += 1;
            }
            (ok, bad)
        }));
    }

    // 150 labeled samples at a cadence of 25: six live publishes while
    // the inference clients run.
    let fb = TcpStream::connect(addr).unwrap();
    let mut fb_writer = fb.try_clone().unwrap();
    let mut fb_reader = BufReader::new(fb);
    let (mut publishes, mut last_gen) = (0u64, 0u64);
    for i in 0..150usize {
        let row = ds.x_train.row(i % ds.x_train.rows());
        let doc = feedback_doc(row, ds.y_train[i % ds.y_train.len()]);
        let mut line = json::to_string(&doc).into_bytes();
        line.push(b'\n');
        fb_writer.write_all(&line).unwrap();
        let reply = read_json_reply(&mut fb_reader);
        assert!(reply.get("error").is_none(), "feedback {i} failed: {}", json::to_string(&reply));
        let generation = reply.get("generation").unwrap().as_f64().unwrap() as u64;
        assert!(generation >= last_gen, "trainer generation went backwards at sample {i}");
        last_gen = generation;
        if reply.get("published").and_then(Value::as_bool) == Some(true) {
            publishes += 1;
        }
    }
    assert!(publishes >= 2, "need >= 2 live publishes under load, got {publishes}");
    assert_eq!(last_gen, publishes, "every publish bumps the generation exactly once");

    // The same verb works on the binary framing (admin JSON-over-frames).
    let mut bin = TcpStream::connect(addr).unwrap();
    let mut out = Vec::new();
    frame::encode_admin_request(&feedback_doc(ds.x_train.row(0), ds.y_train[0]), &mut out);
    bin.write_all(&out).unwrap();
    let reply = read_binary_reply(&mut bin);
    assert!(reply.get("error").is_none(), "{}", json::to_string(&reply));
    assert_eq!(reply.get("ingested").unwrap().as_f64(), Some(151.0));

    // Wire-visible trainer counters on the stats verb.
    fb_writer.write_all(b"{\"cmd\":\"stats\"}\n").unwrap();
    let stats = read_json_reply(&mut fb_reader);
    assert_eq!(stats.get("trainer_ingested").unwrap().as_f64(), Some(151.0));
    assert_eq!(stats.get("trainer_generation").unwrap().as_f64(), Some(publishes as f64));

    stop.store(true, Ordering::Relaxed);
    let mut total_ok = 0u64;
    for client in clients {
        let (ok, bad) = client.join().unwrap();
        assert_eq!(bad, 0, "inferences errored/dropped during live publishes");
        total_ok += ok;
    }
    assert!(total_ok > 0, "inference clients never got a reply in");
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Determinism properties: reservoir + drift stream
// ---------------------------------------------------------------------------

#[test]
fn reservoir_sampling_is_deterministic_in_its_seed() {
    for seed in [1u64, 7, 42, 0xDEAD_BEEF] {
        let mut a = Reservoir::new(32, seed);
        let mut b = Reservoir::new(32, seed);
        let mut data_rng = SplitMix64::new(seed ^ 0x5151);
        for i in 0..500 {
            let row: Vec<f32> = (0..4).map(|_| data_rng.normal() as f32).collect();
            let label = (i % 5) as i32;
            a.push(row.clone(), label);
            b.push(row, label);
        }
        assert_eq!(a.labels(), b.labels(), "seed {seed}: retained sets diverged");
        assert_eq!(a.len(), 32);
        assert_eq!(a.seen(), 500);
        assert_eq!(
            a.to_matrix(4).data(),
            b.to_matrix(4).data(),
            "seed {seed}: retained rows diverged"
        );
    }
    // ... and different seeds retain different subsets of a long stream.
    let mut a = Reservoir::new(16, 1);
    let mut b = Reservoir::new(16, 2);
    for i in 0..2000 {
        a.push(vec![i as f32], 0);
        b.push(vec![i as f32], 0);
    }
    assert_ne!(a.to_matrix(1).data(), b.to_matrix(1).data());
}

#[test]
fn drift_stream_windows_are_deterministic_across_instances() {
    for seed_tweak in [0u64, 3, 11] {
        let mut base = *data::spec("page").unwrap();
        base.seed ^= seed_tweak;
        let spec = data::DriftSpec {
            base,
            windows: 5,
            samples_per_window: 40,
            rotate_frac: 0.3,
            shift_scale: 0.4,
            add_class_at: Some(2),
        };
        let s1 = data::DriftStream::new(spec);
        let s2 = data::DriftStream::new(spec);
        for w in [4, 0, 2] {
            // out-of-order access on purpose
            let a = s1.window(w);
            let b = s2.window(w);
            assert_eq!(a.x.data(), b.x.data(), "tweak {seed_tweak} window {w}");
            assert_eq!(a.y, b.y);
            assert_eq!(a.classes, b.classes);
        }
    }
}

// ---------------------------------------------------------------------------
// Thread-count invariance of the artifact (real binary, twice)
// ---------------------------------------------------------------------------

/// `LOGHD_THREADS=1` and `=4` must produce byte-identical drift
/// artifacts (outside `meta`, which records the thread count). A
/// reduced stream keeps the doubled binary run CI-sized; the golden
/// above pins the full smoke profile once.
#[test]
fn drift_artifact_is_thread_count_invariant() {
    let bin = env!("CARGO_BIN_EXE_loghd");
    let dir = std::env::temp_dir().join("loghd_drift_threads");
    let _ = std::fs::create_dir_all(&dir);

    let mut docs = Vec::new();
    for threads in ["1", "4"] {
        let out = dir.join(format!("drift_t{threads}.json"));
        let status = std::process::Command::new(bin)
            .args([
                "drift",
                "--profile",
                "smoke",
                "--windows",
                "5",
                "--samples_per_window",
                "64",
                "--publish_every",
                "32",
                "--out",
            ])
            .arg(&out)
            .env("LOGHD_THREADS", threads)
            .current_dir(&dir)
            .status()
            .expect("spawn loghd drift");
        assert!(status.success(), "loghd drift failed at LOGHD_THREADS={threads}");
        let text = std::fs::read_to_string(&out).unwrap();
        docs.push(golden::without_keys(json::parse(&text).unwrap(), &["meta"]));
    }
    assert_eq!(
        json::to_string(&docs[0]),
        json::to_string(&docs[1]),
        "drift artifact depends on LOGHD_THREADS"
    );
    let _ = std::fs::remove_dir_all(dir);
}

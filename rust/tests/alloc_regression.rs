//! Allocation-regression gate for the steady-state serving path.
//!
//! Installs a counting global allocator, drives a live TCP server
//! through warmup round-trips until every pool and scratch buffer has
//! settled at its high-water mark, then asserts the allocator sees
//! **zero** calls across a measured window of binary-protocol requests
//! (on Linux, where the epoll reactor runs; the portable `poll(2)`
//! fallback rebuilds its fd set per wakeup and gets a small bound
//! instead). The JSON-lines protocol is held to a small documented
//! per-request constant — its request parse builds a `Value` tree and
//! its reply goes through `json::to_string`.
//!
//! The client half of each round-trip is itself allocation-free: the
//! request bytes are pre-encoded once and replies are read with
//! `read_exact` into stack buffers, so a nonzero delta can only come
//! from the serving path under test.
//!
//! `LOGHD_THREADS=1` is set before anything else so `parallel_rows`
//! runs inline (the thread-pool path hands closures to worker threads,
//! which allocates); the engine under test never encodes, but the guard
//! keeps the test honest if the fixture grows.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use loghd::coordinator::{
    frame, BatcherConfig, Engine, InferScratch, ModelRegistry, Server, ServerConfig,
};
use loghd::tensor::Matrix;
use loghd::testkit::alloc_counter::CountingAlloc;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

/// Round-trips to settle pools/rings/scratch at their high-water marks.
const WARMUP: usize = 64;
/// Measured round-trips per (protocol, reactor-count) configuration.
const MEASURE: usize = 256;
/// Documented JSON-lines ceiling: allocator calls per request admitted
/// on the measured window (request `Value` tree + feature collect +
/// reply document + `json::to_string`).
const JSON_ALLOCS_PER_REQ: u64 = 64;

/// Engine that echoes each row's first feature as its label, with a
/// zero-allocation `infer_into` (labels land in the reused scratch).
struct Echo;

impl Engine for Echo {
    fn name(&self) -> String {
        "echo".into()
    }
    fn features(&self) -> usize {
        2
    }
    fn infer(&mut self, x: &Matrix) -> anyhow::Result<Vec<i32>> {
        Ok((0..x.rows()).map(|i| x.at(i, 0) as i32).collect())
    }
    fn infer_into<'s>(&mut self, x: &Matrix, s: &'s mut InferScratch) -> anyhow::Result<&'s [i32]> {
        s.labels.clear();
        s.labels.extend((0..x.rows()).map(|i| x.at(i, 0) as i32));
        Ok(&s.labels)
    }
}

fn echo_registry() -> Arc<ModelRegistry> {
    // A short fill window keeps single-client round-trips fast without
    // touching the allocation profile (the wait is a condvar timeout).
    let cfg = BatcherConfig {
        max_batch: 8,
        max_delay: Duration::from_micros(200),
        max_pending: 64,
    };
    Arc::new(ModelRegistry::single(
        "echo",
        "demo",
        2,
        &cfg,
        vec![Box::new(|| Ok(Box::new(Echo) as Box<dyn Engine>))],
    ))
}

/// One binary round-trip: write the pre-encoded request, `read_exact`
/// the 8-byte header and the fixed-size reply payload into stack
/// buffers, and check the label. No heap traffic on success.
fn roundtrip_binary(stream: &mut TcpStream, req: &[u8]) {
    stream.write_all(req).unwrap();
    let mut hdr = [0u8; frame::HEADER_LEN];
    stream.read_exact(&mut hdr).unwrap();
    assert_eq!(hdr[0], frame::MAGIC);
    assert_eq!(hdr[2], frame::TYPE_REP_INFER);
    let len = u32::from_le_bytes([hdr[4], hdr[5], hdr[6], hdr[7]]) as usize;
    // Reply payload: [u64 id][i32 label][f64 latency][u8 len]["echo"].
    let mut payload = [0u8; 64];
    assert!(len <= payload.len(), "unexpected reply payload of {len} bytes");
    stream.read_exact(&mut payload[..len]).unwrap();
    let label = i32::from_le_bytes([payload[8], payload[9], payload[10], payload[11]]);
    assert_eq!(label, 7);
}

/// One JSON-lines round-trip: write the pre-encoded line, read into a
/// stack buffer until the newline, substring-check the label (parsing
/// the reply would allocate and pollute the JSON budget).
fn roundtrip_json(stream: &mut TcpStream, req: &[u8]) {
    stream.write_all(req).unwrap();
    let mut buf = [0u8; 256];
    let mut pos = 0;
    while !buf[..pos].contains(&b'\n') {
        assert!(pos < buf.len(), "reply line exceeds {} bytes", buf.len());
        let n = stream.read(&mut buf[pos..]).unwrap();
        assert!(n > 0, "server closed mid-reply");
        pos += n;
    }
    let needle = b"\"label\": 7";
    assert!(
        buf[..pos].windows(needle.len()).any(|w| w == needle),
        "unexpected reply: {}",
        String::from_utf8_lossy(&buf[..pos])
    );
}

fn measure(reactors: usize, req: &[u8], roundtrip: fn(&mut TcpStream, &[u8])) -> u64 {
    let mut server = Server::start_with(
        "127.0.0.1:0",
        echo_registry(),
        ServerConfig { reactors, ..Default::default() },
    )
    .unwrap();
    let mut stream = TcpStream::connect(server.addr).unwrap();
    stream.set_nodelay(true).unwrap();
    for _ in 0..WARMUP {
        roundtrip(&mut stream, req);
    }
    let before = ALLOC.allocs();
    for _ in 0..MEASURE {
        roundtrip(&mut stream, req);
    }
    let delta = ALLOC.allocs() - before;
    drop(stream);
    server.shutdown();
    delta
}

/// The tentpole's acceptance gate, both protocols over 1 and 4
/// reactors. One `#[test]` so configurations run sequentially — the
/// counters are process-wide and concurrent servers would cross-talk.
#[test]
fn steady_state_requests_do_not_allocate() {
    // Must precede any loghd call: the thread-count choice is latched in
    // a OnceLock the first time the pool is consulted.
    std::env::set_var("LOGHD_THREADS", "1");

    let mut bin_req = Vec::new();
    frame::encode_infer_request(None, &[7.0, 0.0], &mut bin_req);
    let json_req = b"{\"features\": [7, 0]}\n".to_vec();

    for reactors in [1usize, 4] {
        let delta = measure(reactors, &bin_req, roundtrip_binary);
        // The epoll reactor's steady state is allocation-free; the
        // portable poll(2) fallback pays a per-wakeup fd-set rebuild.
        if cfg!(target_os = "linux") {
            assert_eq!(
                delta, 0,
                "binary path allocated {delta} times over {MEASURE} requests \
                 ({reactors} reactors); the steady state must be allocation-free"
            );
        } else {
            assert!(
                delta <= 8 * MEASURE as u64,
                "binary path allocated {delta} times over {MEASURE} requests \
                 ({reactors} reactors)"
            );
        }

        let delta = measure(reactors, &json_req, roundtrip_json);
        assert!(
            delta <= JSON_ALLOCS_PER_REQ * MEASURE as u64,
            "json path allocated {delta} times over {MEASURE} requests \
             ({reactors} reactors); budget is {JSON_ALLOCS_PER_REQ}/request"
        );
    }
}

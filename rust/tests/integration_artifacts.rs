//! Integration over the Python-AOT artifacts: PJRT load/compile/execute,
//! numerical parity against the JAX-recorded expected outputs, native-
//! vs-XLA engine parity, and end-to-end coordinator serving.
//!
//! These tests need `make artifacts` (the page_smoke bundle). They are
//! skipped — loudly — when the bundle is absent so `cargo test` still
//! passes on a fresh checkout.

use std::path::PathBuf;
use std::sync::Arc;

use loghd::coordinator::{BatcherConfig, Coordinator, PjrtEngine};
use loghd::eval::accuracy;
use loghd::loghd::persist;
use loghd::runtime::artifact::read_lht;
use loghd::runtime::PjrtRuntime;
use loghd::tensor::Matrix;

fn bundle() -> Option<PathBuf> {
    // tests run from the workspace root
    let dir = PathBuf::from("artifacts/page_smoke");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/page_smoke missing (run `make artifacts`)");
        None
    }
}

fn first_batch(runtime: &PjrtRuntime) -> Matrix {
    let m = &runtime.manifest;
    let x_test = m.tensor("x_test").unwrap().to_matrix().unwrap();
    x_test.rows_slice(0, m.batch)
}

#[test]
fn pjrt_matches_jax_expected_outputs() {
    let Some(dir) = bundle() else { return };
    let runtime = PjrtRuntime::load(&dir).unwrap();
    let xb = first_batch(&runtime);
    let out = runtime.execute("infer_loghd", Some(&xb)).unwrap();

    let expected_dists = read_lht(&dir.join("expected_dists.lht")).unwrap();
    let expected_labels = read_lht(&dir.join("expected_labels.lht")).unwrap();
    let (_, _, dists) = out.f32_named("dists").unwrap();
    let (_, _, labels) = out.i32_named("labels").unwrap();

    let want = expected_dists.as_f32().unwrap();
    assert_eq!(dists.len(), want.len());
    for (a, b) in dists.iter().zip(want) {
        assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
    }
    assert_eq!(labels, expected_labels.as_i32().unwrap());
}

#[test]
fn pjrt_conventional_entry_matches() {
    let Some(dir) = bundle() else { return };
    let runtime = PjrtRuntime::load(&dir).unwrap();
    let xb = first_batch(&runtime);
    let out = runtime.execute("infer_conventional", Some(&xb)).unwrap();
    let expected = read_lht(&dir.join("expected_conv_labels.lht")).unwrap();
    let (_, _, labels) = out.i32_named("labels").unwrap();
    assert_eq!(labels, expected.as_i32().unwrap());
}

#[test]
fn native_engine_parity_with_xla_path() {
    let Some(dir) = bundle() else { return };
    let runtime = PjrtRuntime::load(&dir).unwrap();
    let (encoder, model) = persist::load_from_aot_bundle(&dir).unwrap();
    let (x_test, y_test) = persist::load_test_data(&dir).unwrap();

    let xla_labels = runtime.infer_labels("infer_loghd", &x_test).unwrap();
    let native_labels = model.predict(&encoder.encode(&x_test));
    let agree = xla_labels.iter().zip(&native_labels).filter(|(a, b)| a == b).count();
    assert!(
        agree as f64 >= 0.99 * x_test.rows() as f64,
        "only {agree}/{} labels agree between XLA and native",
        x_test.rows()
    );

    // and both hit the manifest's recorded clean accuracy
    let acc = accuracy(&xla_labels, &y_test);
    assert!(
        (acc - runtime.manifest.clean_acc_loghd).abs() < 0.02,
        "served acc {acc} vs manifest {}",
        runtime.manifest.clean_acc_loghd
    );
}

#[test]
fn full_test_set_accuracy_through_runtime() {
    let Some(dir) = bundle() else { return };
    let runtime = PjrtRuntime::load(&dir).unwrap();
    let (x_test, y_test) = persist::load_test_data(&dir).unwrap();
    let labels = runtime.infer_labels("infer_loghd", &x_test).unwrap();
    assert_eq!(labels.len(), y_test.len()); // padding trimmed correctly
    let acc = accuracy(&labels, &y_test);
    assert!(acc > 0.6, "artifact accuracy {acc}");
}

#[test]
fn coordinator_serves_pjrt_engine_end_to_end() {
    let Some(dir) = bundle() else { return };
    let manifest = loghd::runtime::artifact::Manifest::load(&dir).unwrap();
    let (x_test, y_test) = persist::load_test_data(&dir).unwrap();
    let coord = Arc::new(Coordinator::start(
        manifest.features,
        BatcherConfig {
            max_batch: manifest.batch,
            max_delay: std::time::Duration::from_millis(5),
            max_pending: 4096,
        },
        PjrtEngine::factory(dir.clone(), "infer_loghd".into()),
    ));
    let n = 200.min(x_test.rows());
    let rxs: Vec<_> = (0..n).map(|i| coord.submit(x_test.row(i).to_vec()).unwrap()).collect();
    let preds: Vec<i32> = rxs.into_iter().map(|rx| rx.recv().unwrap().label).collect();
    let acc = accuracy(&preds, &y_test[..n]);
    assert!(acc > 0.6, "served accuracy {acc}");
    let snap = coord.stats();
    assert_eq!(snap.responses, n as u64);
    assert!(snap.mean_batch_size > 1.0, "batching never amortized: {}", snap.mean_batch_size);
}

#[test]
fn fault_injection_on_served_model_degrades_accuracy() {
    // The serving-side fault story: flip bits in the runtime's stored
    // bundle tensor and watch served accuracy drop — no recompilation.
    let Some(dir) = bundle() else { return };
    let mut runtime = PjrtRuntime::load(&dir).unwrap();
    let (x_test, y_test) = persist::load_test_data(&dir).unwrap();
    let clean = accuracy(&runtime.infer_labels("infer_loghd", &x_test).unwrap(), &y_test);

    let mut rng = loghd::util::rng::SplitMix64::new(13);
    let bundles = runtime.tensor("bundles").unwrap().clone();
    let corrupted = loghd::eval::corrupt(&bundles, loghd::quant::Precision::B8, 0.7, &mut rng);
    runtime.set_tensor("bundles", corrupted).unwrap();
    let faulted = accuracy(&runtime.infer_labels("infer_loghd", &x_test).unwrap(), &y_test);
    assert!(faulted < clean, "p=0.7 flips should hurt: {faulted} vs {clean}");
}

//! End-to-end acceptance for the precision-cascade serving tier.
//!
//! Two claims are pinned here:
//!
//! 1. **Held-out fidelity** — a threshold calibrated at the default
//!    target (99.5% agreement with the exact path) keeps that agreement
//!    on traffic it never saw, both at the margin level
//!    (`cascade::evaluate`) and at the served-engine level
//!    (`CascadeEngine` labels vs `NativeEngine` f32 labels).
//!
//! 2. **Fault containment** — corrupting the packed b1 prefilter raises
//!    the escalation rate (damaged rows lose their margins and fall
//!    through to the exact tier) but does not push cascade-vs-exact
//!    disagreement past the calibrated bound: the gate is what makes
//!    the cascade *robust*, not just fast. A deterministic subset
//!    property anchors both severities: the cascade's disagreeing rows
//!    are always a subset of the raw b1 twin's disagreeing rows,
//!    because every escalated row is answered by the exact path.

use std::sync::Arc;

use loghd::coordinator::{CascadeCounters, CascadeEngine, Engine, NativeEngine};
use loghd::data;
use loghd::loghd::cascade;
use loghd::loghd::model::{TrainOptions, TrainedStack};
use loghd::loghd::QuantizedLogHdModel;
use loghd::quant::Precision;
use loghd::util::rng::SplitMix64;

const CLASSES: usize = 5;
const D: usize = 2048;

fn stack() -> (data::Dataset, TrainedStack) {
    let ds = data::generate_scaled(data::spec("page").unwrap(), 1500, 600);
    let opts =
        TrainOptions { epochs: 3, conv_epochs: 1, extra_bundles: 4, ..Default::default() };
    let st = TrainedStack::train(&ds.x_train, &ds.y_train, CLASSES, D, 0xE5C0DE, &opts).unwrap();
    (ds, st)
}

fn agreement(a: &[i32], b: &[i32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).filter(|(x, y)| x == y).count() as f64 / a.len() as f64
}

#[test]
fn calibrated_cascade_meets_the_heldout_fidelity_target() {
    let (ds, st) = stack();
    let cal =
        cascade::calibrate(&st.encoder, &st.loghd, &ds.x_train, cascade::DEFAULT_TARGET, 11)
            .unwrap();
    assert!(cal.agreement >= cascade::DEFAULT_TARGET);
    assert!(cal.agreement_ci.0 <= cal.agreement);

    // Margin-level held-out fidelity at the fitted operating point.
    let (holdout_agreement, holdout_escalation) =
        cascade::evaluate(&st.encoder, &st.loghd, &ds.x_test, cal.threshold);
    assert!(
        holdout_agreement >= cascade::DEFAULT_TARGET,
        "held-out agreement {holdout_agreement} below the calibrated target"
    );
    assert!(
        holdout_escalation < 1.0,
        "a useful operating point must answer some traffic from tier 1"
    );

    // Engine-level: the labels the served cascade emits agree with the
    // exact engine on >= 99.5% of held-out rows.
    let mut exact =
        NativeEngine::with_precision(st.encoder.clone(), st.loghd.clone(), "it", Precision::F32);
    let counters = Arc::new(CascadeCounters::new());
    let mut casc = CascadeEngine::with_precision(
        st.encoder.clone(),
        st.loghd.clone(),
        "it",
        Precision::F32,
        cal.threshold,
        Arc::clone(&counters),
    );
    let exact_labels = exact.infer(&ds.x_test).unwrap();
    let casc_labels = casc.infer(&ds.x_test).unwrap();
    let engine_agreement = agreement(&casc_labels, &exact_labels);
    assert!(
        engine_agreement >= cascade::DEFAULT_TARGET,
        "served cascade agreement {engine_agreement} below the calibrated target"
    );
    let (tier1, escalated, agreed) = counters.snapshot();
    assert_eq!(tier1 + escalated, ds.x_test.rows() as u64);
    assert!(agreed <= escalated);
}

#[test]
fn b1_faults_raise_escalation_without_breaking_the_calibrated_bound() {
    let (ds, st) = stack();
    let cal =
        cascade::calibrate(&st.encoder, &st.loghd, &ds.x_train, cascade::DEFAULT_TARGET, 13)
            .unwrap();
    let mut exact = NativeEngine::with_precision(
        st.encoder.clone(),
        st.loghd.clone(),
        "exact-ref",
        Precision::F32,
    );
    let exact_labels = exact.infer(&ds.x_test).unwrap();

    // Clean baseline at the calibrated operating point.
    let clean_counters = Arc::new(CascadeCounters::new());
    let mut clean = CascadeEngine::with_precision(
        st.encoder.clone(),
        st.loghd.clone(),
        "clean",
        Precision::F32,
        cal.threshold,
        Arc::clone(&clean_counters),
    );
    let clean_labels = clean.infer(&ds.x_test).unwrap();
    let clean_agreement = agreement(&clean_labels, &exact_labels);
    let (_, clean_escalated, _) = clean_counters.snapshot();

    // Campaign over two fault severities on the b1 prefilter's stored
    // planes: light (the containment claim) and heavy (the escalation
    // claim). The exact tier is never corrupted — the cascade's promise
    // is that the *gate* keeps prefilter damage out of the answers.
    let run_faulted = |p: f64, seed: u64| {
        let mut twin = QuantizedLogHdModel::from_model(&st.loghd, Precision::B1);
        let mut rng = SplitMix64::new(seed);
        let flips = twin.inject_value_faults(p, &mut rng);
        assert!(flips > 0, "fault campaign at p={p} must flip something");
        let enc = st.encoder.encode(&ds.x_test);
        let raw_b1_labels = twin.predict(&enc);
        let counters = Arc::new(CascadeCounters::new());
        let mut engine = CascadeEngine::from_parts(
            st.encoder.clone(),
            twin,
            st.loghd.clone(),
            "faulted",
            Precision::F32,
            cal.threshold,
            Arc::clone(&counters),
        );
        let labels = engine.infer(&ds.x_test).unwrap();
        let (_, escalated, _) = counters.snapshot();
        (labels, raw_b1_labels, escalated)
    };

    // Light corruption: the answered traffic stays within the calibrated
    // bound's reach — corruption may cost at most one more "bound" of
    // disagreement on top of the clean operating point.
    let (light_labels, light_raw, _) = run_faulted(0.002, 0xFA17);
    let light_agreement = agreement(&light_labels, &exact_labels);
    let bound = 1.0 - cascade::DEFAULT_TARGET;
    assert!(
        1.0 - light_agreement <= (1.0 - clean_agreement) + bound,
        "light b1 faults pushed disagreement to {} (clean {}, bound {bound})",
        1.0 - light_agreement,
        1.0 - clean_agreement
    );
    // Deterministic subset property: every cascade miss is a tier-1 row
    // the raw (faulted) b1 twin also missed — escalated rows are exact.
    for ((c, r), e) in light_labels.iter().zip(&light_raw).zip(&exact_labels) {
        if c != e {
            assert_eq!(c, r, "a cascade miss must come from the b1 tier");
        }
    }
    assert!(agreement(&light_labels, &exact_labels) >= agreement(&light_raw, &exact_labels));

    // Heavy corruption: margins collapse, so the gate routes strictly
    // more traffic to the exact tier than the clean cascade did — the
    // escalation rate is the fault detector.
    let (heavy_labels, heavy_raw, heavy_escalated) = run_faulted(0.05, 0xFA18);
    assert!(
        heavy_escalated > clean_escalated,
        "heavy b1 faults must raise escalation ({heavy_escalated} <= {clean_escalated})"
    );
    assert!(agreement(&heavy_labels, &exact_labels) >= agreement(&heavy_raw, &exact_labels));
}

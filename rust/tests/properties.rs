//! Property tests via a mini seeded-case harness (proptest is not
//! vendored offline). Each property runs many randomized cases from a
//! deterministic SplitMix64 stream; failures print the case seed so they
//! reproduce exactly.

use loghd::hd::similarity::activations;
use loghd::loghd::codebook;
use loghd::loghd::model::LogHdModel;
use loghd::loghd::qmodel::QuantizedLogHdModel;
use loghd::quant::{self, Precision};
use loghd::tensor::{self, simd, Matrix};
use loghd::util::json;
use loghd::util::rng::SplitMix64;

/// The widths the SIMD agreement properties sweep: word/lane boundaries
/// (63/64/65), sub-vector sizes, and a long row; each also checked on an
/// offset sub-slice so unaligned tails are exercised.
const SIMD_WIDTHS: [usize; 6] = [1, 63, 64, 65, 200, 1000];

/// Run `cases` seeded property checks.
fn forall(name: &str, cases: usize, mut prop: impl FnMut(&mut SplitMix64)) {
    for case in 0..cases {
        let seed = 0xBEEF_0000 + case as u64;
        let mut rng = SplitMix64::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            panic!("property '{name}' failed at case seed {seed:#x}: {e:?}");
        }
    }
}

#[test]
fn prop_simd_f32_kernels_match_scalar_reference() {
    // The dispatched f32 kernels must stay within FMA/lane-reassociation
    // distance (1e-5 relative) of the scalar reference, across widths
    // and unaligned tails, whatever path `simd::path()` picked. Under
    // `LOGHD_FORCE_SCALAR=1` (the CI scalar leg) this degenerates to
    // exact self-agreement — both dispatch modes run the same pins.
    forall("simd-f32", 20, |rng| {
        for width in SIMD_WIDTHS {
            for off in [0usize, 1] {
                let n = width + off;
                let a = rng.normals_f32(n);
                let rows: Vec<Vec<f32>> = (0..4).map(|_| rng.normals_f32(n)).collect();
                let (a, r0, r1) = (&a[off..], &rows[0][off..], &rows[1][off..]);
                let (r2, r3) = (&rows[2][off..], &rows[3][off..]);
                let close = |g: f32, w: f32| (g - w).abs() <= 1e-5 * (1.0 + w.abs());

                let want = simd::scalar::dot(a, r0);
                assert!(close(simd::dot(a, r0), want), "dot w={width} off={off}");

                let got4 = simd::dot4(a, r0, r1, r2, r3);
                let want4 = simd::scalar::dot4(a, r0, r1, r2, r3);
                for (g, w) in got4.iter().zip(want4) {
                    assert!(close(*g, w), "dot4 w={width} off={off}");
                }

                assert_eq!(
                    simd::max_abs(a),
                    simd::scalar::max_abs(a),
                    "max_abs w={width} off={off}"
                );

                let alpha = rng.normal() as f32;
                let mut y_got = rows[0][off..].to_vec();
                let mut y_want = y_got.clone();
                simd::axpy(alpha, a, &mut y_got);
                simd::scalar::axpy(alpha, a, &mut y_want);
                for (g, w) in y_got.iter().zip(&y_want) {
                    assert!(close(*g, *w), "axpy w={width} off={off}");
                }
            }
        }
    });
}

#[test]
fn prop_simd_int_kernels_bit_exact_vs_scalar() {
    // Integer kernels have no reassociation slack: XNOR/popcount, the
    // i16/i32 dots, and the int8 quantize map must match the scalar
    // reference exactly (the quantize pin is what keeps the B8 query
    // side bit-identical to the stored-tensor quantizer policy).
    forall("simd-int", 20, |rng| {
        for width in SIMD_WIDTHS {
            for off in [0usize, 1] {
                let n = width + off;
                // int8-valued i16 rows, including the +128 fault code
                let gen_row = |rng: &mut SplitMix64| -> Vec<i16> {
                    (0..n).map(|_| (rng.below(256) as i64 - 127) as i16).collect()
                };
                let a = gen_row(rng);
                let rows: Vec<Vec<i16>> = (0..4).map(|_| gen_row(rng)).collect();
                let (av, r0, r1) = (&a[off..], &rows[0][off..], &rows[1][off..]);
                let (r2, r3) = (&rows[2][off..], &rows[3][off..]);
                assert_eq!(simd::dot_i16(av, r0), simd::scalar::dot_i16(av, r0), "w={width}");
                assert_eq!(
                    simd::dot_i16_4(av, r0, r1, r2, r3),
                    simd::scalar::dot_i16_4(av, r0, r1, r2, r3),
                    "dot_i16_4 w={width} off={off}"
                );

                let wa: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
                let wb: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
                assert_eq!(
                    simd::hamming(&wa[off..], &wb[off..]),
                    simd::scalar::hamming(&wa[off..], &wb[off..]),
                    "hamming w={width} off={off}"
                );

                let src = rng.normals_f32(n);
                let scale = (simd::max_abs(&src) / 127.0).max(1e-12);
                let mut got = vec![0i16; n - off];
                let mut want = got.clone();
                simd::quantize_i16(&src[off..], scale, &mut got);
                simd::scalar::quantize_i16(&src[off..], scale, &mut want);
                assert_eq!(got, want, "quantize_i16 w={width} off={off}");
            }
        }
    });
}

#[test]
fn quantize_rounding_edges_match_f32_round() {
    // Deterministic adversarial inputs for the int8 map: exact halfway
    // ties (must round away from zero, like `f32::round`) and the
    // double-rounding trap 0.5 − 2⁻²⁵ (where a trunc(x + 0.5) trick
    // rounds up to 1 but `round` gives 0).
    let trap = 0.5f32 - f32::EPSILON / 4.0;
    let src = [trap, -trap, 0.5, -0.5, 1.5, 2.5, -1.5, -2.5, 63.5, -63.5, 126.5, -126.5];
    let want: [i16; 12] = [0, 0, 1, -1, 2, 3, -2, -3, 64, -64, 127, -127];
    let mut got = [0i16; 12];
    simd::quantize_i16(&src, 1.0, &mut got);
    assert_eq!(got, want, "dispatched path");
    let mut got_scalar = [0i16; 12];
    simd::scalar::quantize_i16(&src, 1.0, &mut got_scalar);
    assert_eq!(got_scalar, want, "scalar reference");
}

#[test]
fn prop_poly_cos_within_1e6_of_libm() {
    // The SIMD encoder epilogue's cosine: ≤ 1e-6 absolute from libm over
    // the documented |x| ≤ 8192 domain, including quadrant boundaries.
    forall("poly-cos", 10, |rng| {
        for _ in 0..5_000 {
            let x = ((rng.uniform() - 0.5) * 2.0 * 8192.0) as f32;
            let want = (x as f64).cos() as f32;
            assert!((simd::cos_poly(x) - want).abs() <= 1e-6, "x={x}");
        }
        // near multiples of π/4 (reduction/select boundaries)
        for k in -64i64..=64 {
            for eps in [-1e-4f64, -1e-6, 0.0, 1e-6, 1e-4] {
                let x = (k as f64 * std::f64::consts::FRAC_PI_4 + eps) as f32;
                let want = (x as f64).cos() as f32;
                assert!((simd::cos_poly(x) - want).abs() <= 1e-6, "k={k} eps={eps}");
            }
        }
        // beyond the reduction domain the scalar twin is exactly libm
        for x in [1.0e8f32, -1.0e8, 9000.0, f32::INFINITY] {
            assert_eq!(simd::cos_poly(x).to_bits(), x.cos().to_bits(), "x={x}");
        }
        assert!(simd::cos_poly(f32::NAN).is_nan());
    });
}

#[test]
fn prop_vector_cos_epilogue_within_1e6_of_libm() {
    // Pin the *vector* cosine (cos_ps / cos_q) that the SIMD encoder
    // epilogue actually runs, not just the scalar `cos_poly` twin: with
    // F = 1, x = [1.0], bias = mu = 0, the panel GEMM is the exact
    // product 1.0 · w_j, so encode_row's output is the dispatched
    // cosine of w_j alone — comparable to libm at the full 1e-6 bound.
    forall("vector-cos", 10, |rng| {
        let d = 64 + rng.below(200) as usize;
        let mut angles: Vec<f32> =
            (0..d).map(|_| ((rng.uniform() - 0.5) * 2.0 * 8192.0) as f32).collect();
        // sprinkle quadrant boundaries into the batch
        for (slot, k) in (0..d).step_by(7).zip(-32i64..) {
            angles[slot] = (k as f64 * std::f64::consts::FRAC_PI_4) as f32;
        }
        // and out-of-domain magnitudes: the tile must fall back to libm
        // (bounded output) instead of running the polynomial there
        angles[3] = 1.0e8;
        angles[11] = -2.5e7;
        let w = Matrix::from_vec(1, d, angles.clone());
        let packed = simd::PackedPanels::pack_columns(&w);
        let zeros = vec![0.0f32; d];
        let mut out = vec![0.0f32; d];
        simd::encode_row(&[1.0], &packed, &zeros, &zeros, &mut out);
        for (j, angle) in angles.iter().enumerate() {
            let want = (*angle as f64).cos() as f32;
            assert!((out[j] - want).abs() <= 1e-6, "j={j} angle={angle}");
        }
    });
}

#[test]
fn prop_fused_encode_matches_two_pass_reference() {
    // The fused panel-GEMM + cos encoder vs the explicit matmul-then-
    // libm-cos reference: ≤ 1e-5 relative on the angle plus the 1e-6
    // poly budget, across panel-boundary widths and batch shapes.
    forall("fused-encode", 12, |rng| {
        let f = 1 + rng.below(24) as usize;
        let d = 1 + rng.below(300) as usize;
        let b = 1 + rng.below(5) as usize;
        let mut enc = loghd::encoder::Encoder::new(f, d, rng.next_u64());
        enc.set_mu(rng.normals_f32(d));
        let x = Matrix::from_vec(b, f, rng.normals_f32(b * f));
        let out = enc.encode(&x);
        for i in 0..b {
            for j in 0..d {
                let mut acc = 0.0f32;
                for k in 0..f {
                    acc += x.at(i, k) * enc.w().at(k, j);
                }
                let angle = acc + enc.b[j];
                let want = angle.cos() - enc.mu[j];
                let tol = 2e-6 + 1e-5 * (1.0 + angle.abs());
                assert!(
                    (out.at(i, j) - want).abs() <= tol,
                    "f={f} d={d} ({i},{j}): {} vs {want}",
                    out.at(i, j)
                );
            }
        }
    });
}

#[test]
fn prop_codebook_unique_feasible_balanced() {
    forall("codebook", 40, |rng| {
        let c = 2 + (rng.below(40) as usize);
        let k = 2 + (rng.below(3) as u32);
        let n = codebook::min_bundles(c, k) + rng.below(3) as usize;
        let cb = codebook::build(c, k, n, 1.0, rng.next_u64()).unwrap();
        // unique rows
        let mut rows = cb.rows.clone();
        rows.sort();
        rows.dedup();
        assert_eq!(rows.len(), c);
        // greedy bound: worst load <= total/n + max single contribution
        let loads = cb.bundle_loads(1.0);
        let total: f64 = loads.iter().sum();
        let worst = loads.iter().cloned().fold(0.0, f64::max);
        assert!(worst <= total / n as f64 + 1.0 + 1e-9, "worst {worst}, total {total}, n {n}");
    });
}

#[test]
fn prop_quant_roundtrip_bounded() {
    forall("quant", 40, |rng| {
        let rows = 1 + rng.below(6) as usize;
        let cols = 1 + rng.below(200) as usize;
        let m = Matrix::from_vec(rows, cols, rng.normals_f32(rows * cols));
        for p in Precision::ALL_QUANT {
            let q = quant::quantize(&m, p);
            let back = quant::dequantize(&q);
            if p == Precision::B1 {
                // sign preserved for nonzero values
                for (a, b) in m.data().iter().zip(back.data()) {
                    if a.abs() > 1e-6 {
                        assert_eq!(a.signum(), b.signum());
                    }
                }
            } else {
                let step = q.scale;
                for (a, b) in m.data().iter().zip(back.data()) {
                    assert!((a - b).abs() <= 0.5 * step + 1e-6);
                }
            }
        }
    });
}

#[test]
fn prop_packed_set_get_identity() {
    forall("packed", 60, |rng| {
        let bits = 1 + rng.below(16) as u32;
        let count = 1 + rng.below(300) as usize;
        let mask = (1u64 << bits) - 1;
        let mut p = quant::PackedTensor::new(bits, count);
        let values: Vec<u64> = (0..count).map(|_| rng.next_u64() & mask).collect();
        for (i, v) in values.iter().enumerate() {
            p.set(i, *v);
        }
        for (i, v) in values.iter().enumerate() {
            assert_eq!(p.get(i), *v, "bits={bits} idx={i}");
        }
    });
}

#[test]
fn prop_json_roundtrip() {
    fn random_value(rng: &mut SplitMix64, depth: usize) -> json::Value {
        match if depth >= 3 { rng.below(4) } else { rng.below(6) } {
            0 => json::Value::Null,
            1 => json::Value::Bool(rng.below(2) == 1),
            2 => json::Value::Number((rng.normal() * 100.0 * 8.0).round() / 8.0),
            3 => json::Value::String(format!("s{}-\"quoted\" \n tab\t", rng.below(1000))),
            4 => json::Value::Array(
                (0..rng.below(4)).map(|_| random_value(rng, depth + 1)).collect(),
            ),
            _ => json::Value::Object(
                (0..rng.below(4))
                    .map(|i| (format!("key{i}"), random_value(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    forall("json", 60, |rng| {
        let v = random_value(rng, 0);
        let text = json::to_string(&v);
        let back = json::parse(&text).unwrap();
        assert_eq!(v, back, "compact roundtrip: {text}");
        let pretty = json::to_string_pretty(&v);
        assert_eq!(v, json::parse(&pretty).unwrap(), "pretty roundtrip");
    });
}

#[test]
fn prop_similarity_bounds_and_scale_invariance() {
    forall("similarity", 40, |rng| {
        let b = 1 + rng.below(8) as usize;
        let d = 2 + rng.below(128) as usize;
        let n = 1 + rng.below(6) as usize;
        let enc = Matrix::from_vec(b, d, rng.normals_f32(b * d));
        let mut m = Matrix::from_vec(n, d, rng.normals_f32(n * d));
        tensor::normalize_rows(&mut m);
        let a = loghd::hd::similarity::activations(&enc, &m);
        assert!(a.data().iter().all(|v| v.abs() <= 1.0 + 1e-4));
        // scaling the query must not change cosine activations
        let mut enc2 = enc.clone();
        for v in enc2.data_mut() {
            *v *= 3.5;
        }
        let a2 = loghd::hd::similarity::activations(&enc2, &m);
        for (x, y) in a.data().iter().zip(a2.data()) {
            assert!((x - y).abs() < 1e-4);
        }
    });
}

#[test]
fn prop_flip_rate_concentrates() {
    forall("fliprate", 15, |rng| {
        let p = 0.02 + 0.6 * rng.uniform();
        let total = 50_000;
        let flips =
            loghd::faults::flip_positions(total, p, rng).len() as f64 / total as f64;
        let sigma = (p * (1.0 - p) / total as f64).sqrt();
        assert!((flips - p).abs() < 8.0 * sigma + 1e-3, "p={p} rate={flips}");
    });
}

#[test]
fn prop_profile_decode_permutation_invariance() {
    // Permuting class order of profiles permutes predictions consistently.
    forall("decode-perm", 20, |rng| {
        let b = 1 + rng.below(6) as usize;
        let d = 16 + rng.below(64) as usize;
        let n = 2 + rng.below(4) as usize;
        let c = 3 + rng.below(5) as usize;
        let enc = Matrix::from_vec(b, d, rng.normals_f32(b * d));
        let mut bundles = Matrix::from_vec(n, d, rng.normals_f32(n * d));
        tensor::normalize_rows(&mut bundles);
        let profiles = Matrix::from_vec(c, n, rng.normals_f32(c * n));
        let book = codebook::build(c, 2, codebook::min_bundles(c, 2).max(n), 1.0, 7).unwrap();
        let model = loghd::loghd::model::LogHdModel {
            classes: c,
            d,
            book: book.clone(),
            bundles: bundles.clone(),
            profiles: profiles.clone(),
        };
        let preds = model.predict(&enc);
        // rotate classes by 1
        let mut rotated = Matrix::zeros(c, n);
        for i in 0..c {
            rotated.row_mut((i + 1) % c).copy_from_slice(profiles.row(i));
        }
        let model2 = loghd::loghd::model::LogHdModel {
            classes: c,
            d,
            book,
            bundles,
            profiles: rotated,
        };
        let preds2 = model2.predict(&enc);
        for (a, b2) in preds.iter().zip(&preds2) {
            assert_eq!((*a + 1) % c as i32, *b2);
        }
    });
}

/// Random LogHD model with unit-norm bundles and bounded profiles (the
/// shapes the packed kernels serve).
fn random_model(rng: &mut SplitMix64, c: usize, d: usize, n: usize) -> LogHdModel {
    let mut bundles = Matrix::from_vec(n, d, rng.normals_f32(n * d));
    tensor::normalize_rows(&mut bundles);
    let profiles = Matrix::from_vec(
        c,
        n,
        rng.normals_f32(c * n).into_iter().map(|v| 0.3 * v).collect(),
    );
    let book = codebook::build(c, 2, codebook::min_bundles(c, 2).max(n), 1.0, rng.next_u64())
        .unwrap();
    LogHdModel { classes: c, d, book, bundles, profiles }
}

#[test]
fn prop_b1_xnor_activations_match_sign_dequant_argmax() {
    // The XNOR/popcount path and the f32 path over sign-dequantized
    // operands see the same ±1 geometry, so per-query activation argmax
    // must agree exactly whenever the packed maximum is unique (ties are
    // integer-exact in the packed domain but summation-order-dependent in
    // f32, so tied rows are checked for tied-ness instead).
    forall("b1-xnor-argmax", 30, |rng| {
        let b = 1 + rng.below(6) as usize;
        let d = 32 + rng.below(480) as usize;
        let n = 2 + rng.below(5) as usize;
        let c = 3 + rng.below(4) as usize;
        let model = random_model(rng, c, d, n);
        let enc = Matrix::from_vec(b, d, rng.normals_f32(b * d));
        let qm = QuantizedLogHdModel::from_model(&model, Precision::B1);
        let got = qm.activations(&enc);
        let enc_signs = quant::quantize_roundtrip(&enc, Precision::B1);
        let bundles_signs = quant::dequantize(&qm.bundles);
        let want = activations(&enc_signs, &bundles_signs);
        // one packed activation step = 2·calibration/D
        let step = std::f32::consts::FRAC_PI_2 / d as f32 * 2.0;
        for i in 0..b {
            let row = got.row(i);
            let best = tensor::argmax(row);
            let second = row
                .iter()
                .enumerate()
                .filter(|(j, _)| *j != best)
                .map(|(_, v)| *v)
                .fold(f32::NEG_INFINITY, f32::max);
            let wrow = want.row(i);
            if row[best] - second > 0.5 * step {
                assert_eq!(
                    best,
                    tensor::argmax(wrow),
                    "row {i}: packed argmax {best} vs f32 {}",
                    tensor::argmax(wrow)
                );
            } else {
                // packed tie: the f32 winner must be one of the tied ints
                let diff = (wrow[tensor::argmax(wrow)] - wrow[best]).abs();
                assert!(diff < 1e-3, "row {i}: tie mishandled (diff {diff})");
            }
        }
    });
}

#[test]
fn prop_b8_packed_activations_within_quant_tolerance() {
    // The i32/int8 kernel must reproduce the f32 activations of the
    // quantized operands (same levels, exact integer accumulation).
    forall("b8-activations", 30, |rng| {
        let b = 1 + rng.below(6) as usize;
        let d = 16 + rng.below(300) as usize;
        let n = 2 + rng.below(5) as usize;
        let c = 3 + rng.below(4) as usize;
        let model = random_model(rng, c, d, n);
        let enc = Matrix::from_vec(b, d, rng.normals_f32(b * d));
        let qm = QuantizedLogHdModel::from_model(&model, Precision::B8);
        let got = qm.activations(&enc);
        let enc_q = quant::quantize_roundtrip(&enc, Precision::B8);
        let bundles_q = quant::dequantize(&qm.bundles);
        let want = activations(&enc_q, &bundles_q);
        for (g, w) in got.data().iter().zip(want.data()) {
            assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "{g} vs {w}");
        }
        // and both stay within quantization distance of the f32 model
        let full = activations(&enc, &model.bundles);
        for (g, w) in got.data().iter().zip(full.data()) {
            assert!((g - w).abs() < 0.05, "int8 drifted from f32: {g} vs {w}");
        }
    });
}

#[test]
fn prop_fused_decode_matches_naive_sqdist() {
    // decode_dists' |A|² − 2AᵀP + |P|² fusion vs the scalar loop,
    // including the clamp-to-zero of tiny negative expansion residues.
    forall("fused-decode", 30, |rng| {
        let b = 1 + rng.below(8) as usize;
        let d = 16 + rng.below(128) as usize;
        let n = 2 + rng.below(6) as usize;
        let c = 3 + rng.below(6) as usize;
        let model = random_model(rng, c, d, n);
        let enc = Matrix::from_vec(b, d, rng.normals_f32(b * d));
        let dists = model.decode_dists(&enc);
        let a = activations(&enc, &model.bundles);
        for i in 0..b {
            for cls in 0..c {
                let naive = tensor::sqdist(a.row(i), model.profiles.row(cls));
                assert!(
                    (dists.at(i, cls) - naive).abs() < 1e-4 * (1.0 + naive),
                    "({i},{cls}): fused {} vs naive {naive}",
                    dists.at(i, cls)
                );
                assert!(dists.at(i, cls) >= 0.0, "negative distance at ({i},{cls})");
            }
        }
        // degenerate case: a profile equal to a query's activation row
        // must clamp to exactly zero, never a negative residue
        let mut profiles = model.profiles.clone();
        profiles.row_mut(0).copy_from_slice(a.row(0));
        let model2 = LogHdModel { profiles, ..model };
        let d2 = model2.decode_dists(&enc);
        assert!(d2.at(0, 0) >= 0.0);
        assert!(d2.at(0, 0) < 1e-5, "self-distance {}", d2.at(0, 0));
    });
}

#[test]
fn prop_packed_fault_injection_stays_in_domain() {
    // flip → infer must stay packed: predictions remain valid labels and
    // p = 0 is the identity, for both packed widths.
    forall("packed-faults", 12, |rng| {
        let b = 2 + rng.below(4) as usize;
        let d = 64 + rng.below(192) as usize;
        let n = 3 + rng.below(3) as usize;
        let c = 3 + rng.below(4) as usize;
        let model = random_model(rng, c, d, n);
        let enc = Matrix::from_vec(b, d, rng.normals_f32(b * d));
        for precision in [Precision::B1, Precision::B8] {
            let mut qm = QuantizedLogHdModel::from_model(&model, precision);
            let clean = qm.predict(&enc);
            assert!(clean.iter().all(|l| (0..c as i32).contains(l)));
            assert_eq!(qm.inject_value_faults(0.0, rng), 0);
            assert_eq!(qm.predict(&enc), clean, "{precision:?}: p=0 changed output");
            qm.inject_value_faults(0.7, rng);
            let faulted = qm.predict(&enc);
            assert!(faulted.iter().all(|l| (0..c as i32).contains(l)), "{precision:?}");
        }
    });
}

#[test]
fn prop_flip_positions_binomial_edges_and_determinism() {
    // The i.i.d. per-bit sampler behind every fault model: counts must
    // concentrate at p·total (binomial 6σ), positions must be strictly
    // increasing (hence duplicate-free) and in range, p = 0 / p = 1 are
    // exact, and the same seed replays the same mask.
    use loghd::faults;
    forall("flip-positions", 10, |rng| {
        let total = 10_000 + rng.below(40_000) as usize;
        let p = rng.uniform();
        let seed = rng.next_u64();
        let pos = faults::flip_positions(total, p, &mut SplitMix64::new(seed));
        for w in pos.windows(2) {
            assert!(w[0] < w[1], "positions not strictly increasing");
        }
        if let Some(&last) = pos.last() {
            assert!(last < total);
        }
        let sigma = (p * (1.0 - p) * total as f64).sqrt();
        assert!(
            (pos.len() as f64 - p * total as f64).abs() <= 6.0 * sigma + 1.0,
            "p={p}: {} flips of {total}, off by more than 6 sigma",
            pos.len()
        );
        assert_eq!(pos, faults::flip_positions(total, p, &mut SplitMix64::new(seed)));
        assert!(faults::flip_positions(total, 0.0, rng).is_empty());
        assert_eq!(
            faults::flip_positions(total, 1.0, rng),
            (0..total).collect::<Vec<_>>()
        );
    });
}

#[test]
fn prop_flip_packed_count_concentrates_and_replays() {
    use loghd::faults;
    use loghd::quant::PackedTensor;
    forall("flip-packed", 8, |rng| {
        let bits = 1 + rng.below(8) as u32;
        let count = 4_000 + rng.below(4_000) as usize;
        let p = 0.05 + 0.5 * rng.uniform();
        let seed = rng.next_u64();
        let mut t = PackedTensor::new(bits, count);
        let flips = faults::flip_packed(&mut t, p, &mut SplitMix64::new(seed));
        let total_bits = t.total_bits() as f64;
        let sigma = (p * (1.0 - p) * total_bits).sqrt();
        assert!(
            (flips as f64 - p * total_bits).abs() <= 6.0 * sigma + 1.0,
            "bits={bits}: {flips} flips of {total_bits}"
        );
        // from all-zero words, unique positions mean flips == set bits
        let ones: u32 = t.words().iter().map(|w| w.count_ones()).sum();
        assert_eq!(ones as usize, flips);
        // same seed -> bit-identical corrupted words
        let mut t2 = PackedTensor::new(bits, count);
        faults::flip_packed(&mut t2, p, &mut SplitMix64::new(seed));
        assert_eq!(t, t2);
    });
}

#[test]
fn prop_seeded_flip_mask_packed_and_dense_twins_agree() {
    // Differential fault test: inject the same seeded per-value flip
    // mask into a packed model, then score (a) the packed kernels on the
    // corrupted words and (b) the f32 pipeline on the dequantized twin
    // of those same words. Predictions must agree wherever the dense
    // decision is not a near-tie (packed integer math and f32 math may
    // legitimately split exact ties).
    forall("flip-differential", 8, |rng| {
        let b = 4 + rng.below(4) as usize;
        let d = 64 + rng.below(192) as usize;
        let n = 3 + rng.below(3) as usize;
        let c = 3 + rng.below(4) as usize;
        let model = random_model(rng, c, d, n);
        let enc = Matrix::from_vec(b, d, rng.normals_f32(b * d));
        for precision in [Precision::B8, Precision::B1] {
            let mut qm = QuantizedLogHdModel::from_model(&model, precision);
            let seed = rng.next_u64();
            let p = 0.05 + 0.4 * rng.uniform();
            qm.inject_value_faults(p, &mut SplitMix64::new(seed));
            let packed_pred = qm.predict(&enc);

            // dense twin of the corrupted stored state, scored in f32
            let (bundles_deq, profiles_deq) = qm.dequantized_state();
            let enc_q = quant::quantize_roundtrip(&enc, precision);
            let mut a = activations(&enc_q, &bundles_deq);
            if precision == Precision::B1 {
                // packed 1-bit activations are arcsine-calibrated to
                // cosine scale ((π/2)·s); the dense cosine against the
                // ±scale twin rows is scale·sqrt(d)·s — align them.
                let calib =
                    std::f32::consts::FRAC_PI_2 / (qm.bundles.scale * (d as f32).sqrt());
                for v in a.data_mut() {
                    *v *= calib;
                }
            }
            let dists = tensor::pairwise_sqdists(&a, &profiles_deq);
            for (i, &packed_label) in packed_pred.iter().enumerate() {
                let row = dists.row(i);
                let dense_label = tensor::argmin(row) as i32;
                if dense_label != packed_label {
                    // only near-ties may split between the two datapaths
                    let gap = (row[packed_label as usize] - row[dense_label as usize]).abs();
                    assert!(
                        gap < 5e-2 * (1.0 + row[dense_label as usize]),
                        "{precision:?} row {i}: packed {packed_label} vs dense {dense_label}, \
                         dist gap {gap}"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_analog_drift_field_concentrates_and_applies_at_plane_scale() {
    // The sampled drift field is standard normal (mean within 6σ/√n,
    // second moment within 6σ of 1), and application shifts every f32
    // value by exactly sigma·A·z_i at the plane amplitude A.
    use loghd::faults::{self, FaultModel, PlaneFault};
    forall("analog-drift", 10, |rng| {
        let rows = 20 + rng.below(30) as usize;
        let cols = 100 + rng.below(200) as usize;
        let sigma = 0.1 + 1.5 * rng.uniform();
        let fault = faults::sample_plane_fault(
            &FaultModel::GaussianDrift { sigma },
            rows,
            cols,
            32,
            rng,
        );
        let PlaneFault::Drift { sigma: s32, z } = &fault else { panic!("wrong variant") };
        assert_eq!(z.len(), rows * cols);
        assert!((f64::from(*s32) - sigma).abs() < 1e-6);
        let n = (rows * cols) as f64;
        let mean = z.iter().map(|v| f64::from(*v)).sum::<f64>() / n;
        let m2 = z.iter().map(|v| f64::from(*v).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() <= 6.0 / n.sqrt(), "mean {mean}");
        assert!((m2 - 1.0).abs() <= 6.0 * (2.0 / n).sqrt(), "second moment {m2}");

        let amp = (0.5 + 3.0 * rng.uniform()) as f32;
        let mut data = vec![amp; rows * cols];
        faults::apply_analog_f32(&mut data, cols, &fault);
        for (v, zi) in data.iter().zip(z) {
            assert_eq!(*v, amp + s32 * amp * zi);
        }
    });
}

#[test]
fn prop_analog_stuckat_fraction_polarity_and_rails() {
    // Victim count concentrates at frac·values (binomial 6σ), victims
    // are strictly increasing, polarity semantics hold (Low/High pin
    // one rail, Mixed flips a fair coin), and application pins exactly
    // the victims to ±A leaving every other cell untouched.
    use loghd::faults::{self, FaultModel, PlaneFault, StuckPolarity};
    forall("analog-stuckat", 8, |rng| {
        let rows = 40 + rng.below(40) as usize;
        let cols = 100 + rng.below(100) as usize;
        let total = rows * cols;
        let frac = 0.05 + 0.6 * rng.uniform();
        for polarity in [StuckPolarity::Low, StuckPolarity::High, StuckPolarity::Mixed] {
            let fault = faults::sample_plane_fault(
                &FaultModel::StuckAt { frac, polarity },
                rows,
                cols,
                32,
                rng,
            );
            let PlaneFault::Stuck(cells) = &fault else { panic!("wrong variant") };
            let sigma = (frac * (1.0 - frac) * total as f64).sqrt();
            assert!(
                (cells.len() as f64 - frac * total as f64).abs() <= 6.0 * sigma + 1.0,
                "frac={frac}: {} victims of {total}",
                cells.len()
            );
            for w in cells.windows(2) {
                assert!(w[0].0 < w[1].0, "victims not strictly increasing");
            }
            match polarity {
                StuckPolarity::Low => assert!(cells.iter().all(|&(_, high)| !high)),
                StuckPolarity::High => assert!(cells.iter().all(|&(_, high)| high)),
                StuckPolarity::Mixed => {
                    let highs = cells.iter().filter(|&&(_, high)| high).count() as f64;
                    let m = cells.len() as f64;
                    assert!(
                        (highs - 0.5 * m).abs() <= 6.0 * (0.25 * m).sqrt() + 1.0,
                        "coin bias: {highs} highs of {m}"
                    );
                }
            }

            let mut data: Vec<f32> =
                (0..total).map(|i| 0.25 + (i % 7) as f32 * 0.05).collect();
            let amp = faults::plane_amplitude(&data);
            let before = data.clone();
            faults::apply_analog_f32(&mut data, cols, &fault);
            let mut vi = 0;
            for (i, (b, a)) in before.iter().zip(&data).enumerate() {
                if vi < cells.len() && cells[vi].0 == i {
                    assert_eq!(*a, if cells[vi].1 { amp } else { -amp }, "victim {i}");
                    vi += 1;
                } else {
                    assert_eq!(a, b, "untouched cell {i} changed");
                }
            }
        }
    });
}

#[test]
fn prop_analog_line_spans_cover_and_stay_sorted() {
    // Failed rows are strictly increasing unions of span-extended
    // starts, clamped to the plane; coverage tracks the stationary
    // 1 − (1 − rate)^span within a (loose) 6σ band; rate = 1 fails
    // every row.
    use loghd::faults::{self, FaultModel, PlaneFault};
    forall("analog-lines", 10, |rng| {
        let rows = 500 + rng.below(1500) as usize;
        let cols = 4 + rng.below(16) as usize;
        let span = 1 + rng.below(4) as usize;
        let rate = 0.02 + 0.3 * rng.uniform();
        let fault = faults::sample_plane_fault(
            &FaultModel::LineFailure { rate, span },
            rows,
            cols,
            32,
            rng,
        );
        let PlaneFault::Lines(failed) = &fault else { panic!("wrong variant") };
        for w in failed.windows(2) {
            assert!(w[0] < w[1], "failed rows not strictly increasing");
        }
        if let Some(&last) = failed.last() {
            assert!(last < rows);
        }
        assert_eq!(fault.touched(cols), failed.len() * cols);
        let cov = 1.0 - (1.0 - rate).powi(span as i32);
        let got = failed.len() as f64 / rows as f64;
        let sigma = (rate * (1.0 - rate) / rows as f64).sqrt() * span as f64;
        assert!(
            (got - cov).abs() <= 6.0 * sigma + span as f64 / rows as f64,
            "span={span} rate={rate}: coverage {got} vs {cov}"
        );

        let all = faults::sample_plane_fault(
            &FaultModel::LineFailure { rate: 1.0, span },
            50,
            cols,
            32,
            rng,
        );
        let PlaneFault::Lines(f2) = &all else { panic!("wrong variant") };
        assert_eq!(f2, &(0..50).collect::<Vec<_>>());
    });
}

#[test]
fn prop_analog_zero_severity_is_a_no_op_with_zero_draws() {
    // Severity 0 must sample an empty fault AND consume no rng draws
    // under every model — the invariant that keeps the severity-0 grid
    // column bit-identical across fault models in the campaign.
    use loghd::faults::{self, FaultModel, StuckPolarity};
    forall("analog-zero", 20, |rng| {
        let rows = 1 + rng.below(40) as usize;
        let cols = 1 + rng.below(60) as usize;
        let span = 1 + rng.below(4) as usize;
        let models = [
            FaultModel::BitFlip { p: 0.0 },
            FaultModel::GaussianDrift { sigma: 0.0 },
            FaultModel::StuckAt { frac: 0.0, polarity: StuckPolarity::Mixed },
            FaultModel::LineFailure { rate: 0.0, span },
        ];
        for m in &models {
            let mut probe = rng.clone();
            let fault = faults::sample_plane_fault(m, rows, cols, 32, rng);
            assert!(fault.is_empty(), "{m:?}");
            assert_eq!(fault.touched(cols), 0, "{m:?}");
            assert_eq!(rng.next_u64(), probe.next_u64(), "{m:?} consumed draws");
        }
    });
}

#[test]
fn prop_analog_sampling_replays_per_seed() {
    // Same seed, same geometry -> bit-identical fault realization, for
    // every model family (the determinism the campaign's per-cell
    // streams rely on).
    use loghd::faults::{self, FaultModel, StuckPolarity};
    forall("analog-replay", 10, |rng| {
        let rows = 10 + rng.below(50) as usize;
        let cols = 10 + rng.below(50) as usize;
        let models = [
            FaultModel::BitFlip { p: 0.3 },
            FaultModel::GaussianDrift { sigma: 0.7 },
            FaultModel::StuckAt { frac: 0.2, polarity: StuckPolarity::Mixed },
            FaultModel::LineFailure { rate: 0.1, span: 3 },
        ];
        for m in &models {
            let seed = rng.next_u64();
            let a = faults::sample_plane_fault(m, rows, cols, 8, &mut SplitMix64::new(seed));
            let b = faults::sample_plane_fault(m, rows, cols, 8, &mut SplitMix64::new(seed));
            assert_eq!(a, b, "{m:?}");
        }
    });
}

#[test]
fn prop_dataset_generator_statistics() {
    // per-class sample means approach the class means as samples grow
    forall("datagen", 4, |rng| {
        let mut spec = *loghd::data::spec("page").unwrap();
        spec.seed = rng.next_u64();
        spec.n_train = 2500;
        spec.n_test = 10;
        let ds = loghd::data::generate(&spec);
        // class means should differ pairwise (groups + offsets)
        let c = spec.classes;
        let f = spec.features;
        let mut means = vec![vec![0.0f64; f]; c];
        let mut counts = vec![0usize; c];
        for i in 0..ds.x_train.rows() {
            let cls = ds.y_train[i] as usize;
            counts[cls] += 1;
            for (m, v) in means[cls].iter_mut().zip(ds.x_train.row(i)) {
                *m += *v as f64;
            }
        }
        for (m, cnt) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= *cnt as f64;
            }
        }
        for a in 0..c {
            for b in (a + 1)..c {
                let dist: f64 = means[a]
                    .iter()
                    .zip(&means[b])
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum();
                assert!(dist > 0.05, "classes {a},{b} indistinct (d={dist})");
            }
        }
    });
}

//! Property tests via a mini seeded-case harness (proptest is not
//! vendored offline). Each property runs many randomized cases from a
//! deterministic SplitMix64 stream; failures print the case seed so they
//! reproduce exactly.

use loghd::loghd::codebook;
use loghd::quant::{self, Precision};
use loghd::tensor::{self, Matrix};
use loghd::util::json;
use loghd::util::rng::SplitMix64;

/// Run `cases` seeded property checks.
fn forall(name: &str, cases: usize, mut prop: impl FnMut(&mut SplitMix64)) {
    for case in 0..cases {
        let seed = 0xBEEF_0000 + case as u64;
        let mut rng = SplitMix64::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            panic!("property '{name}' failed at case seed {seed:#x}: {e:?}");
        }
    }
}

#[test]
fn prop_codebook_unique_feasible_balanced() {
    forall("codebook", 40, |rng| {
        let c = 2 + (rng.below(40) as usize);
        let k = 2 + (rng.below(3) as u32);
        let n = codebook::min_bundles(c, k) + rng.below(3) as usize;
        let cb = codebook::build(c, k, n, 1.0, rng.next_u64()).unwrap();
        // unique rows
        let mut rows = cb.rows.clone();
        rows.sort();
        rows.dedup();
        assert_eq!(rows.len(), c);
        // greedy bound: worst load <= total/n + max single contribution
        let loads = cb.bundle_loads(1.0);
        let total: f64 = loads.iter().sum();
        let worst = loads.iter().cloned().fold(0.0, f64::max);
        assert!(worst <= total / n as f64 + 1.0 + 1e-9, "worst {worst}, total {total}, n {n}");
    });
}

#[test]
fn prop_quant_roundtrip_bounded() {
    forall("quant", 40, |rng| {
        let rows = 1 + rng.below(6) as usize;
        let cols = 1 + rng.below(200) as usize;
        let m = Matrix::from_vec(rows, cols, rng.normals_f32(rows * cols));
        for p in Precision::ALL_QUANT {
            let q = quant::quantize(&m, p);
            let back = quant::dequantize(&q);
            if p == Precision::B1 {
                // sign preserved for nonzero values
                for (a, b) in m.data().iter().zip(back.data()) {
                    if a.abs() > 1e-6 {
                        assert_eq!(a.signum(), b.signum());
                    }
                }
            } else {
                let step = q.scale;
                for (a, b) in m.data().iter().zip(back.data()) {
                    assert!((a - b).abs() <= 0.5 * step + 1e-6);
                }
            }
        }
    });
}

#[test]
fn prop_packed_set_get_identity() {
    forall("packed", 60, |rng| {
        let bits = 1 + rng.below(16) as u32;
        let count = 1 + rng.below(300) as usize;
        let mask = (1u64 << bits) - 1;
        let mut p = quant::PackedTensor::new(bits, count);
        let values: Vec<u64> = (0..count).map(|_| rng.next_u64() & mask).collect();
        for (i, v) in values.iter().enumerate() {
            p.set(i, *v);
        }
        for (i, v) in values.iter().enumerate() {
            assert_eq!(p.get(i), *v, "bits={bits} idx={i}");
        }
    });
}

#[test]
fn prop_json_roundtrip() {
    fn random_value(rng: &mut SplitMix64, depth: usize) -> json::Value {
        match if depth >= 3 { rng.below(4) } else { rng.below(6) } {
            0 => json::Value::Null,
            1 => json::Value::Bool(rng.below(2) == 1),
            2 => json::Value::Number((rng.normal() * 100.0 * 8.0).round() / 8.0),
            3 => json::Value::String(format!("s{}-\"quoted\" \n tab\t", rng.below(1000))),
            4 => json::Value::Array(
                (0..rng.below(4)).map(|_| random_value(rng, depth + 1)).collect(),
            ),
            _ => json::Value::Object(
                (0..rng.below(4))
                    .map(|i| (format!("key{i}"), random_value(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    forall("json", 60, |rng| {
        let v = random_value(rng, 0);
        let text = json::to_string(&v);
        let back = json::parse(&text).unwrap();
        assert_eq!(v, back, "compact roundtrip: {text}");
        let pretty = json::to_string_pretty(&v);
        assert_eq!(v, json::parse(&pretty).unwrap(), "pretty roundtrip");
    });
}

#[test]
fn prop_similarity_bounds_and_scale_invariance() {
    forall("similarity", 40, |rng| {
        let b = 1 + rng.below(8) as usize;
        let d = 2 + rng.below(128) as usize;
        let n = 1 + rng.below(6) as usize;
        let enc = Matrix::from_vec(b, d, rng.normals_f32(b * d));
        let mut m = Matrix::from_vec(n, d, rng.normals_f32(n * d));
        tensor::normalize_rows(&mut m);
        let a = loghd::hd::similarity::activations(&enc, &m);
        assert!(a.data().iter().all(|v| v.abs() <= 1.0 + 1e-4));
        // scaling the query must not change cosine activations
        let mut enc2 = enc.clone();
        for v in enc2.data_mut() {
            *v *= 3.5;
        }
        let a2 = loghd::hd::similarity::activations(&enc2, &m);
        for (x, y) in a.data().iter().zip(a2.data()) {
            assert!((x - y).abs() < 1e-4);
        }
    });
}

#[test]
fn prop_flip_rate_concentrates() {
    forall("fliprate", 15, |rng| {
        let p = 0.02 + 0.6 * rng.uniform();
        let total = 50_000;
        let flips =
            loghd::faults::flip_positions(total, p, rng).len() as f64 / total as f64;
        let sigma = (p * (1.0 - p) / total as f64).sqrt();
        assert!((flips - p).abs() < 8.0 * sigma + 1e-3, "p={p} rate={flips}");
    });
}

#[test]
fn prop_profile_decode_permutation_invariance() {
    // Permuting class order of profiles permutes predictions consistently.
    forall("decode-perm", 20, |rng| {
        let b = 1 + rng.below(6) as usize;
        let d = 16 + rng.below(64) as usize;
        let n = 2 + rng.below(4) as usize;
        let c = 3 + rng.below(5) as usize;
        let enc = Matrix::from_vec(b, d, rng.normals_f32(b * d));
        let mut bundles = Matrix::from_vec(n, d, rng.normals_f32(n * d));
        tensor::normalize_rows(&mut bundles);
        let profiles = Matrix::from_vec(c, n, rng.normals_f32(c * n));
        let book = codebook::build(c, 2, codebook::min_bundles(c, 2).max(n), 1.0, 7).unwrap();
        let model = loghd::loghd::model::LogHdModel {
            classes: c,
            d,
            book: book.clone(),
            bundles: bundles.clone(),
            profiles: profiles.clone(),
        };
        let preds = model.predict(&enc);
        // rotate classes by 1
        let mut rotated = Matrix::zeros(c, n);
        for i in 0..c {
            rotated.row_mut((i + 1) % c).copy_from_slice(profiles.row(i));
        }
        let model2 = loghd::loghd::model::LogHdModel {
            classes: c,
            d,
            book,
            bundles,
            profiles: rotated,
        };
        let preds2 = model2.predict(&enc);
        for (a, b2) in preds.iter().zip(&preds2) {
            assert_eq!((*a + 1) % c as i32, *b2);
        }
    });
}

#[test]
fn prop_dataset_generator_statistics() {
    // per-class sample means approach the class means as samples grow
    forall("datagen", 4, |rng| {
        let mut spec = *loghd::data::spec("page").unwrap();
        spec.seed = rng.next_u64();
        spec.n_train = 2500;
        spec.n_test = 10;
        let ds = loghd::data::generate(&spec);
        // class means should differ pairwise (groups + offsets)
        let c = spec.classes;
        let f = spec.features;
        let mut means = vec![vec![0.0f64; f]; c];
        let mut counts = vec![0usize; c];
        for i in 0..ds.x_train.rows() {
            let cls = ds.y_train[i] as usize;
            counts[cls] += 1;
            for (m, v) in means[cls].iter_mut().zip(ds.x_train.row(i)) {
                *m += *v as f64;
            }
        }
        for (m, cnt) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= *cnt as f64;
            }
        }
        for a in 0..c {
            for b in (a + 1)..c {
                let dist: f64 = means[a]
                    .iter()
                    .zip(&means[b])
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum();
                assert!(dist > 0.05, "classes {a},{b} indistinct (d={dist})");
            }
        }
    });
}

//! Property tests via a mini seeded-case harness (proptest is not
//! vendored offline). Each property runs many randomized cases from a
//! deterministic SplitMix64 stream; failures print the case seed so they
//! reproduce exactly.

use loghd::hd::similarity::activations;
use loghd::loghd::codebook;
use loghd::loghd::model::LogHdModel;
use loghd::loghd::qmodel::QuantizedLogHdModel;
use loghd::quant::{self, Precision};
use loghd::tensor::{self, Matrix};
use loghd::util::json;
use loghd::util::rng::SplitMix64;

/// Run `cases` seeded property checks.
fn forall(name: &str, cases: usize, mut prop: impl FnMut(&mut SplitMix64)) {
    for case in 0..cases {
        let seed = 0xBEEF_0000 + case as u64;
        let mut rng = SplitMix64::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            panic!("property '{name}' failed at case seed {seed:#x}: {e:?}");
        }
    }
}

#[test]
fn prop_codebook_unique_feasible_balanced() {
    forall("codebook", 40, |rng| {
        let c = 2 + (rng.below(40) as usize);
        let k = 2 + (rng.below(3) as u32);
        let n = codebook::min_bundles(c, k) + rng.below(3) as usize;
        let cb = codebook::build(c, k, n, 1.0, rng.next_u64()).unwrap();
        // unique rows
        let mut rows = cb.rows.clone();
        rows.sort();
        rows.dedup();
        assert_eq!(rows.len(), c);
        // greedy bound: worst load <= total/n + max single contribution
        let loads = cb.bundle_loads(1.0);
        let total: f64 = loads.iter().sum();
        let worst = loads.iter().cloned().fold(0.0, f64::max);
        assert!(worst <= total / n as f64 + 1.0 + 1e-9, "worst {worst}, total {total}, n {n}");
    });
}

#[test]
fn prop_quant_roundtrip_bounded() {
    forall("quant", 40, |rng| {
        let rows = 1 + rng.below(6) as usize;
        let cols = 1 + rng.below(200) as usize;
        let m = Matrix::from_vec(rows, cols, rng.normals_f32(rows * cols));
        for p in Precision::ALL_QUANT {
            let q = quant::quantize(&m, p);
            let back = quant::dequantize(&q);
            if p == Precision::B1 {
                // sign preserved for nonzero values
                for (a, b) in m.data().iter().zip(back.data()) {
                    if a.abs() > 1e-6 {
                        assert_eq!(a.signum(), b.signum());
                    }
                }
            } else {
                let step = q.scale;
                for (a, b) in m.data().iter().zip(back.data()) {
                    assert!((a - b).abs() <= 0.5 * step + 1e-6);
                }
            }
        }
    });
}

#[test]
fn prop_packed_set_get_identity() {
    forall("packed", 60, |rng| {
        let bits = 1 + rng.below(16) as u32;
        let count = 1 + rng.below(300) as usize;
        let mask = (1u64 << bits) - 1;
        let mut p = quant::PackedTensor::new(bits, count);
        let values: Vec<u64> = (0..count).map(|_| rng.next_u64() & mask).collect();
        for (i, v) in values.iter().enumerate() {
            p.set(i, *v);
        }
        for (i, v) in values.iter().enumerate() {
            assert_eq!(p.get(i), *v, "bits={bits} idx={i}");
        }
    });
}

#[test]
fn prop_json_roundtrip() {
    fn random_value(rng: &mut SplitMix64, depth: usize) -> json::Value {
        match if depth >= 3 { rng.below(4) } else { rng.below(6) } {
            0 => json::Value::Null,
            1 => json::Value::Bool(rng.below(2) == 1),
            2 => json::Value::Number((rng.normal() * 100.0 * 8.0).round() / 8.0),
            3 => json::Value::String(format!("s{}-\"quoted\" \n tab\t", rng.below(1000))),
            4 => json::Value::Array(
                (0..rng.below(4)).map(|_| random_value(rng, depth + 1)).collect(),
            ),
            _ => json::Value::Object(
                (0..rng.below(4))
                    .map(|i| (format!("key{i}"), random_value(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    forall("json", 60, |rng| {
        let v = random_value(rng, 0);
        let text = json::to_string(&v);
        let back = json::parse(&text).unwrap();
        assert_eq!(v, back, "compact roundtrip: {text}");
        let pretty = json::to_string_pretty(&v);
        assert_eq!(v, json::parse(&pretty).unwrap(), "pretty roundtrip");
    });
}

#[test]
fn prop_similarity_bounds_and_scale_invariance() {
    forall("similarity", 40, |rng| {
        let b = 1 + rng.below(8) as usize;
        let d = 2 + rng.below(128) as usize;
        let n = 1 + rng.below(6) as usize;
        let enc = Matrix::from_vec(b, d, rng.normals_f32(b * d));
        let mut m = Matrix::from_vec(n, d, rng.normals_f32(n * d));
        tensor::normalize_rows(&mut m);
        let a = loghd::hd::similarity::activations(&enc, &m);
        assert!(a.data().iter().all(|v| v.abs() <= 1.0 + 1e-4));
        // scaling the query must not change cosine activations
        let mut enc2 = enc.clone();
        for v in enc2.data_mut() {
            *v *= 3.5;
        }
        let a2 = loghd::hd::similarity::activations(&enc2, &m);
        for (x, y) in a.data().iter().zip(a2.data()) {
            assert!((x - y).abs() < 1e-4);
        }
    });
}

#[test]
fn prop_flip_rate_concentrates() {
    forall("fliprate", 15, |rng| {
        let p = 0.02 + 0.6 * rng.uniform();
        let total = 50_000;
        let flips =
            loghd::faults::flip_positions(total, p, rng).len() as f64 / total as f64;
        let sigma = (p * (1.0 - p) / total as f64).sqrt();
        assert!((flips - p).abs() < 8.0 * sigma + 1e-3, "p={p} rate={flips}");
    });
}

#[test]
fn prop_profile_decode_permutation_invariance() {
    // Permuting class order of profiles permutes predictions consistently.
    forall("decode-perm", 20, |rng| {
        let b = 1 + rng.below(6) as usize;
        let d = 16 + rng.below(64) as usize;
        let n = 2 + rng.below(4) as usize;
        let c = 3 + rng.below(5) as usize;
        let enc = Matrix::from_vec(b, d, rng.normals_f32(b * d));
        let mut bundles = Matrix::from_vec(n, d, rng.normals_f32(n * d));
        tensor::normalize_rows(&mut bundles);
        let profiles = Matrix::from_vec(c, n, rng.normals_f32(c * n));
        let book = codebook::build(c, 2, codebook::min_bundles(c, 2).max(n), 1.0, 7).unwrap();
        let model = loghd::loghd::model::LogHdModel {
            classes: c,
            d,
            book: book.clone(),
            bundles: bundles.clone(),
            profiles: profiles.clone(),
        };
        let preds = model.predict(&enc);
        // rotate classes by 1
        let mut rotated = Matrix::zeros(c, n);
        for i in 0..c {
            rotated.row_mut((i + 1) % c).copy_from_slice(profiles.row(i));
        }
        let model2 = loghd::loghd::model::LogHdModel {
            classes: c,
            d,
            book,
            bundles,
            profiles: rotated,
        };
        let preds2 = model2.predict(&enc);
        for (a, b2) in preds.iter().zip(&preds2) {
            assert_eq!((*a + 1) % c as i32, *b2);
        }
    });
}

/// Random LogHD model with unit-norm bundles and bounded profiles (the
/// shapes the packed kernels serve).
fn random_model(rng: &mut SplitMix64, c: usize, d: usize, n: usize) -> LogHdModel {
    let mut bundles = Matrix::from_vec(n, d, rng.normals_f32(n * d));
    tensor::normalize_rows(&mut bundles);
    let profiles = Matrix::from_vec(
        c,
        n,
        rng.normals_f32(c * n).into_iter().map(|v| 0.3 * v).collect(),
    );
    let book = codebook::build(c, 2, codebook::min_bundles(c, 2).max(n), 1.0, rng.next_u64())
        .unwrap();
    LogHdModel { classes: c, d, book, bundles, profiles }
}

#[test]
fn prop_b1_xnor_activations_match_sign_dequant_argmax() {
    // The XNOR/popcount path and the f32 path over sign-dequantized
    // operands see the same ±1 geometry, so per-query activation argmax
    // must agree exactly whenever the packed maximum is unique (ties are
    // integer-exact in the packed domain but summation-order-dependent in
    // f32, so tied rows are checked for tied-ness instead).
    forall("b1-xnor-argmax", 30, |rng| {
        let b = 1 + rng.below(6) as usize;
        let d = 32 + rng.below(480) as usize;
        let n = 2 + rng.below(5) as usize;
        let c = 3 + rng.below(4) as usize;
        let model = random_model(rng, c, d, n);
        let enc = Matrix::from_vec(b, d, rng.normals_f32(b * d));
        let qm = QuantizedLogHdModel::from_model(&model, Precision::B1);
        let got = qm.activations(&enc);
        let enc_signs = quant::quantize_roundtrip(&enc, Precision::B1);
        let bundles_signs = quant::dequantize(&qm.bundles);
        let want = activations(&enc_signs, &bundles_signs);
        // one packed activation step = 2·calibration/D
        let step = std::f32::consts::FRAC_PI_2 / d as f32 * 2.0;
        for i in 0..b {
            let row = got.row(i);
            let best = tensor::argmax(row);
            let second = row
                .iter()
                .enumerate()
                .filter(|(j, _)| *j != best)
                .map(|(_, v)| *v)
                .fold(f32::NEG_INFINITY, f32::max);
            let wrow = want.row(i);
            if row[best] - second > 0.5 * step {
                assert_eq!(
                    best,
                    tensor::argmax(wrow),
                    "row {i}: packed argmax {best} vs f32 {}",
                    tensor::argmax(wrow)
                );
            } else {
                // packed tie: the f32 winner must be one of the tied ints
                let diff = (wrow[tensor::argmax(wrow)] - wrow[best]).abs();
                assert!(diff < 1e-3, "row {i}: tie mishandled (diff {diff})");
            }
        }
    });
}

#[test]
fn prop_b8_packed_activations_within_quant_tolerance() {
    // The i32/int8 kernel must reproduce the f32 activations of the
    // quantized operands (same levels, exact integer accumulation).
    forall("b8-activations", 30, |rng| {
        let b = 1 + rng.below(6) as usize;
        let d = 16 + rng.below(300) as usize;
        let n = 2 + rng.below(5) as usize;
        let c = 3 + rng.below(4) as usize;
        let model = random_model(rng, c, d, n);
        let enc = Matrix::from_vec(b, d, rng.normals_f32(b * d));
        let qm = QuantizedLogHdModel::from_model(&model, Precision::B8);
        let got = qm.activations(&enc);
        let enc_q = quant::quantize_roundtrip(&enc, Precision::B8);
        let bundles_q = quant::dequantize(&qm.bundles);
        let want = activations(&enc_q, &bundles_q);
        for (g, w) in got.data().iter().zip(want.data()) {
            assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "{g} vs {w}");
        }
        // and both stay within quantization distance of the f32 model
        let full = activations(&enc, &model.bundles);
        for (g, w) in got.data().iter().zip(full.data()) {
            assert!((g - w).abs() < 0.05, "int8 drifted from f32: {g} vs {w}");
        }
    });
}

#[test]
fn prop_fused_decode_matches_naive_sqdist() {
    // decode_dists' |A|² − 2AᵀP + |P|² fusion vs the scalar loop,
    // including the clamp-to-zero of tiny negative expansion residues.
    forall("fused-decode", 30, |rng| {
        let b = 1 + rng.below(8) as usize;
        let d = 16 + rng.below(128) as usize;
        let n = 2 + rng.below(6) as usize;
        let c = 3 + rng.below(6) as usize;
        let model = random_model(rng, c, d, n);
        let enc = Matrix::from_vec(b, d, rng.normals_f32(b * d));
        let dists = model.decode_dists(&enc);
        let a = activations(&enc, &model.bundles);
        for i in 0..b {
            for cls in 0..c {
                let naive = tensor::sqdist(a.row(i), model.profiles.row(cls));
                assert!(
                    (dists.at(i, cls) - naive).abs() < 1e-4 * (1.0 + naive),
                    "({i},{cls}): fused {} vs naive {naive}",
                    dists.at(i, cls)
                );
                assert!(dists.at(i, cls) >= 0.0, "negative distance at ({i},{cls})");
            }
        }
        // degenerate case: a profile equal to a query's activation row
        // must clamp to exactly zero, never a negative residue
        let mut profiles = model.profiles.clone();
        profiles.row_mut(0).copy_from_slice(a.row(0));
        let model2 = LogHdModel { profiles, ..model };
        let d2 = model2.decode_dists(&enc);
        assert!(d2.at(0, 0) >= 0.0);
        assert!(d2.at(0, 0) < 1e-5, "self-distance {}", d2.at(0, 0));
    });
}

#[test]
fn prop_packed_fault_injection_stays_in_domain() {
    // flip → infer must stay packed: predictions remain valid labels and
    // p = 0 is the identity, for both packed widths.
    forall("packed-faults", 12, |rng| {
        let b = 2 + rng.below(4) as usize;
        let d = 64 + rng.below(192) as usize;
        let n = 3 + rng.below(3) as usize;
        let c = 3 + rng.below(4) as usize;
        let model = random_model(rng, c, d, n);
        let enc = Matrix::from_vec(b, d, rng.normals_f32(b * d));
        for precision in [Precision::B1, Precision::B8] {
            let mut qm = QuantizedLogHdModel::from_model(&model, precision);
            let clean = qm.predict(&enc);
            assert!(clean.iter().all(|l| (0..c as i32).contains(l)));
            assert_eq!(qm.inject_value_faults(0.0, rng), 0);
            assert_eq!(qm.predict(&enc), clean, "{precision:?}: p=0 changed output");
            qm.inject_value_faults(0.7, rng);
            let faulted = qm.predict(&enc);
            assert!(faulted.iter().all(|l| (0..c as i32).contains(l)), "{precision:?}");
        }
    });
}

#[test]
fn prop_dataset_generator_statistics() {
    // per-class sample means approach the class means as samples grow
    forall("datagen", 4, |rng| {
        let mut spec = *loghd::data::spec("page").unwrap();
        spec.seed = rng.next_u64();
        spec.n_train = 2500;
        spec.n_test = 10;
        let ds = loghd::data::generate(&spec);
        // class means should differ pairwise (groups + offsets)
        let c = spec.classes;
        let f = spec.features;
        let mut means = vec![vec![0.0f64; f]; c];
        let mut counts = vec![0usize; c];
        for i in 0..ds.x_train.rows() {
            let cls = ds.y_train[i] as usize;
            counts[cls] += 1;
            for (m, v) in means[cls].iter_mut().zip(ds.x_train.row(i)) {
                *m += *v as f64;
            }
        }
        for (m, cnt) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= *cnt as f64;
            }
        }
        for a in 0..c {
            for b in (a + 1)..c {
                let dist: f64 = means[a]
                    .iter()
                    .zip(&means[b])
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum();
                assert!(dist > 0.05, "classes {a},{b} indistinct (d={dist})");
            }
        }
    });
}

//! End-to-end conformance suite for the equal-memory robustness
//! campaign (`loghd robustness`):
//!
//! - the smoke campaign runs and emits a schema-valid
//!   `loghd-robustness/v1` document,
//! - its solver table + schema match the committed golden artifact
//!   (`rust/tests/golden/robustness_smoke.json`, re-bless with
//!   `LOGHD_BLESS=1`),
//! - the paper's headline statistic reproduces on the miniature
//!   workload: the class-axis vs feature-axis resilience ratio is
//!   finite and >= 1,
//! - the artifact is bit-identical across `LOGHD_THREADS` settings
//!   (pinned by running the actual binary twice),
//! - the analog campaign (`--fault-model`) sweeps all four fault
//!   models, matches its own golden
//!   (`rust/tests/golden/analog_smoke.json`), and its bit-flip leg
//!   reproduces the digital artifact *exactly* — the analog layer adds
//!   zero draws to the digital stream.

use loghd::eval::campaign::{self, AnalogConfig, CampaignConfig};
use loghd::testkit::golden::{self, GoldenOptions};
use loghd::util::json::{self, Value};

fn smoke_result() -> (campaign::CampaignResult, Value) {
    let res = campaign::run(&CampaignConfig::smoke()).expect("smoke campaign");
    let v = res.to_json();
    (res, v)
}

#[test]
fn smoke_campaign_schema_golden_and_resilience_ratio() {
    let (res, v) = smoke_result();

    // --- schema sanity ---
    assert_eq!(v.get("schema").unwrap().as_str(), Some("loghd-robustness/v1"));
    let ps = v.get("ps").unwrap().as_array().unwrap();
    let cells = v.get("cells").unwrap().as_array().unwrap();
    assert_eq!(cells.len(), 6, "smoke grid must solve exactly 6 equal-memory cells");
    for cell in cells {
        let label = cell.get("label").unwrap().as_str().unwrap();
        let mean = cell.get("acc_mean").unwrap().as_array().unwrap();
        let std = cell.get("acc_std").unwrap().as_array().unwrap();
        assert_eq!(mean.len(), ps.len(), "{label}: curve length");
        assert_eq!(std.len(), ps.len(), "{label}: std length");
        for a in mean {
            let a = a.as_f64().unwrap();
            assert!((0.0..=1.0).contains(&a), "{label}: accuracy {a} out of range");
        }
        let r = cell.get("resilience").unwrap().as_f64().unwrap();
        assert!(r.is_finite() && r >= 0.0, "{label}: resilience {r}");
        let ci = cell.get("resilience_ci95").unwrap().as_array().unwrap();
        assert!(ci[0].as_f64().unwrap() <= ci[1].as_f64().unwrap() + 1e-12, "{label}: ci order");
        // every cell honors the memory budget within tolerance
        let dev = cell.get("budget_dev").unwrap().as_f64().unwrap();
        assert!(dev.abs() <= 0.05, "{label}: budget deviation {dev}");
    }

    // --- the committed golden pins schema + the solver table exactly ---
    golden::check_file(
        "rust/tests/golden/robustness_smoke.json",
        &v,
        &GoldenOptions::exact(),
    )
    .unwrap();

    // --- the headline claim on the miniature workload ---
    let ratio = res.resilience_ratio.expect("feature-axis side must reach the target clean");
    assert!(ratio.is_finite(), "resilience ratio must be finite");
    assert!(
        ratio >= 1.0,
        "LogHD-vs-feature-axis resilience ratio {ratio:.3} < 1 (class-axis best {:?}, \
         feature-axis best {:?})",
        res.class_axis_best,
        res.feature_axis_best
    );
    // and both sides actually sustain the target somewhere on the grid
    assert!(res.feature_axis_best.1 > 0.0);
    assert!(res.class_axis_best.1 > 0.0);
}

#[test]
fn analog_smoke_campaign_matches_golden_and_digital_bitflip() {
    let res = campaign::run_analog(&AnalogConfig::smoke()).expect("analog smoke campaign");
    let v = res.to_json();

    // --- schema sanity: four models, six solved cells each ---
    assert_eq!(v.get("schema").unwrap().as_str(), Some("loghd-analog/v1"));
    let models = v.get("models").unwrap().as_array().unwrap();
    assert_eq!(models.len(), 4, "smoke analog campaign must sweep all four fault models");
    for m in models {
        let label = m.get("fault_model").unwrap().as_str().unwrap();
        let cells = m.get_path(&["campaign", "cells"]).unwrap().as_array().unwrap();
        assert_eq!(cells.len(), 6, "{label}: per-model solver table");
        assert!(m.get_path(&["technology", "name"]).unwrap().as_str().is_some(), "{label}");
    }

    // --- the committed golden pins schema, severity normalization,
    // technology annotations, and the per-model solver tables ---
    golden::check_file(
        "rust/tests/golden/analog_smoke.json",
        &v,
        &GoldenOptions::exact(),
    )
    .unwrap();

    // --- differential: the bit-flip leg IS the digital campaign
    // (stream salt 0, severity = flip rate, one draw per plane) ---
    let (_, digital) = smoke_result();
    assert_eq!(
        json::to_string(&golden::without_keys(res.runs[0].campaign.to_json(), &["meta"])),
        json::to_string(&golden::without_keys(digital, &["meta"])),
        "analog bitflip leg diverged from the digital campaign"
    );
    // ... so it must also pass the committed *digital* golden
    // unchanged (skipped when blessing: the digital suite owns that
    // file's re-bless).
    if !golden::blessing() {
        golden::check_file(
            "rust/tests/golden/robustness_smoke.json",
            &res.runs[0].campaign.to_json(),
            &GoldenOptions::exact(),
        )
        .unwrap();
    }

    // every model resolves a resilience ratio on the smoke workload
    for leg in &res.runs {
        let ratio = leg.campaign.resilience_ratio;
        assert!(
            ratio.is_some_and(f64::is_finite),
            "{}: resilience ratio {ratio:?}",
            leg.kind.label()
        );
    }
}

/// `LOGHD_THREADS=1` and `=4` must produce byte-identical artifacts
/// (outside `meta`, which records the thread count). The worker-pool
/// size is latched per process, so this drives the real binary twice.
#[test]
fn campaign_artifact_is_thread_count_invariant() {
    let bin = env!("CARGO_BIN_EXE_loghd");
    let dir = std::env::temp_dir().join("loghd_robustness_threads");
    let _ = std::fs::create_dir_all(&dir);

    let mut docs = Vec::new();
    for threads in ["1", "4"] {
        let out = dir.join(format!("campaign_t{threads}.json"));
        let status = std::process::Command::new(bin)
            .args(["robustness", "--profile", "smoke", "--out"])
            .arg(&out)
            .env("LOGHD_THREADS", threads)
            .current_dir(&dir)
            .status()
            .expect("spawn loghd robustness");
        assert!(status.success(), "loghd robustness failed at LOGHD_THREADS={threads}");
        let text = std::fs::read_to_string(&out).unwrap();
        docs.push(golden::without_keys(json::parse(&text).unwrap(), &["meta"]));
    }
    assert_eq!(
        json::to_string(&docs[0]),
        json::to_string(&docs[1]),
        "campaign output depends on LOGHD_THREADS"
    );
    let _ = std::fs::remove_dir_all(dir);
}

/// Same contract for the analog campaign: every fault model's
/// Monte-Carlo must be bit-identical at any `LOGHD_THREADS` (one trial
/// keeps the doubled binary run CI-sized).
#[test]
fn analog_artifact_is_thread_count_invariant() {
    let bin = env!("CARGO_BIN_EXE_loghd");
    let dir = std::env::temp_dir().join("loghd_analog_threads");
    let _ = std::fs::create_dir_all(&dir);

    let mut docs = Vec::new();
    for threads in ["1", "4"] {
        let out = dir.join(format!("analog_t{threads}.json"));
        let status = std::process::Command::new(bin)
            .args([
                "robustness",
                "--profile",
                "smoke",
                "--trials",
                "1",
                "--fault-model",
                "all",
                "--out",
            ])
            .arg(&out)
            .env("LOGHD_THREADS", threads)
            .current_dir(&dir)
            .status()
            .expect("spawn loghd robustness --fault-model all");
        assert!(status.success(), "analog robustness failed at LOGHD_THREADS={threads}");
        let text = std::fs::read_to_string(&out).unwrap();
        docs.push(golden::without_keys(json::parse(&text).unwrap(), &["meta"]));
    }
    assert_eq!(
        json::to_string(&docs[0]),
        json::to_string(&docs[1]),
        "analog campaign output depends on LOGHD_THREADS"
    );
    let _ = std::fs::remove_dir_all(dir);
}

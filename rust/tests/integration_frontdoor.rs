//! Front-door protocol conformance + torture suite (the PR-7
//! acceptance path).
//!
//! - **Conformance differential**: every abstract protocol case from
//!   `docs/PROTOCOL.md` (inference, routing, admin verbs, every coded
//!   error) runs over BOTH wire protocols — JSON-lines and binary
//!   frames — against fresh servers, and the decoded replies must be
//!   semantically identical. The JSON transcript is pinned by a golden
//!   (`rust/tests/golden/frontdoor_conformance.json`, re-bless with
//!   `LOGHD_BLESS=1`).
//! - **Torture**: byte-at-a-time delivery, splits at every byte
//!   boundary (driving the [`Conn`] state machine directly, so every
//!   cut is deterministic), seed-deterministic random chunking,
//!   pipelined many-requests-per-read with serial admin semantics,
//!   oversized / truncated / overlong inputs rejected with coded errors
//!   while the connection survives, and a slow reader exercising
//!   write-side backpressure.
//! - **Event-loop regressions**: an idle server takes zero poller
//!   wakeups (no busy-wait accept loop), and shutdown drains admitted
//!   in-flight requests before the last thread joins (no detached
//!   per-client threads).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use loghd::coordinator::conn::{self, Conn, SubmitReq};
use loghd::coordinator::frame;
use loghd::coordinator::{
    BatcherConfig, Engine, EngineFactory, ModelRegistry, Server, ServerConfig,
};
use loghd::testkit::golden::{self, GoldenOptions};
use loghd::tensor::Matrix;
use loghd::util::json::{self, Value};
use loghd::util::rng::SplitMix64;

/// Label = first feature.
struct Echo;
impl Engine for Echo {
    fn name(&self) -> String {
        "echo".into()
    }
    fn features(&self) -> usize {
        2
    }
    fn infer(&mut self, x: &Matrix) -> anyhow::Result<Vec<i32>> {
        Ok((0..x.rows()).map(|i| x.at(i, 0) as i32).collect())
    }
}

/// Label = 2 × first feature (so routing mistakes are visible).
struct Double;
impl Engine for Double {
    fn name(&self) -> String {
        "double".into()
    }
    fn features(&self) -> usize {
        2
    }
    fn infer(&mut self, x: &Matrix) -> anyhow::Result<Vec<i32>> {
        Ok((0..x.rows()).map(|i| 2 * x.at(i, 0) as i32).collect())
    }
}

fn echo_factory() -> EngineFactory {
    Box::new(|| Ok(Box::new(Echo) as Box<dyn Engine>))
}

fn double_factory() -> EngineFactory {
    Box::new(|| Ok(Box::new(Double) as Box<dyn Engine>))
}

fn two_tenants() -> ModelRegistry {
    ModelRegistry::with_tenants(
        vec![
            ("echo", "demo", 2, vec![echo_factory()]),
            ("double", "demo", 2, vec![double_factory()]),
        ],
        "echo",
        &BatcherConfig::default(),
    )
}

fn echo_only() -> Arc<ModelRegistry> {
    Arc::new(ModelRegistry::single(
        "echo",
        "demo",
        2,
        &BatcherConfig::default(),
        vec![echo_factory()],
    ))
}

// ---------------------------------------------------------------------------
// Protocol-agnostic case encoding + reply decoding
// ---------------------------------------------------------------------------

/// One abstract protocol case, encodable on both wire protocols.
enum Case {
    Infer { model: Option<&'static str>, features: Vec<f32> },
    Admin(Value),
}

fn admin(fields: Vec<(&str, Value)>) -> Case {
    Case::Admin(json::obj(fields))
}

/// The full conformance script: routing, every admin verb, every
/// recoverable error code — mirrored from `docs/PROTOCOL.md`.
fn conformance_cases() -> Vec<Case> {
    vec![
        Case::Infer { model: None, features: vec![7.0, 0.0] },
        Case::Infer { model: Some("double"), features: vec![3.0, 0.0] },
        Case::Infer { model: None, features: vec![9.0, 9.0] },
        Case::Infer { model: None, features: vec![1.0] }, // bad_width
        Case::Infer { model: Some("ghost"), features: vec![1.0, 2.0] }, // unknown_model
        admin(vec![("cmd", json::s("stats"))]),
        admin(vec![("cmd", json::s("stats")), ("model", json::s("double"))]),
        admin(vec![("cmd", json::s("models"))]),
        admin(vec![("cmd", json::s("frobnicate"))]), // bad_request
        admin(vec![("cmd", json::s("reload")), ("bits", json::num(-1.0))]), // bad_request
    ]
}

fn case_json_line(case: &Case) -> Vec<u8> {
    let text = match case {
        Case::Infer { model, features } => {
            let mut fields = Vec::new();
            if let Some(m) = model {
                fields.push(("model", json::s(*m)));
            }
            let feats: Vec<Value> = features.iter().map(|f| json::num(*f as f64)).collect();
            fields.push(("features", json::arr(feats)));
            json::to_string(&json::obj(fields))
        }
        Case::Admin(doc) => json::to_string(doc),
    };
    let mut bytes = text.into_bytes();
    bytes.push(b'\n');
    bytes
}

fn case_binary_frame(case: &Case) -> Vec<u8> {
    let mut out = Vec::new();
    match case {
        Case::Infer { model, features } => frame::encode_infer_request(*model, features, &mut out),
        Case::Admin(doc) => frame::encode_admin_request(doc, &mut out),
    }
    out
}

fn read_json_reply(reader: &mut BufReader<TcpStream>) -> Value {
    let mut line = String::new();
    let n = reader.read_line(&mut line).unwrap();
    assert!(n > 0, "server closed before replying");
    json::parse(line.trim()).unwrap_or_else(|e| panic!("bad reply '{line}': {e}"))
}

fn read_binary_reply(stream: &mut TcpStream) -> Value {
    let mut hdr = [0u8; frame::HEADER_LEN];
    stream.read_exact(&mut hdr).unwrap();
    let len = u32::from_le_bytes(hdr[4..8].try_into().unwrap()) as usize;
    let mut whole = hdr.to_vec();
    whole.resize(frame::HEADER_LEN + len, 0);
    stream.read_exact(&mut whole[frame::HEADER_LEN..]).unwrap();
    match frame::try_extract(&whole, frame::DEFAULT_MAX_FRAME) {
        frame::Extract::Frame { header, payload } => {
            frame::decode_reply_to_json(&header, &whole[payload]).unwrap()
        }
        other => panic!("expected a reply frame, got {other:?}"),
    }
}

/// Timing-dependent reply fields, removed before any comparison.
const VOLATILE: &[&str] = &["latency_us", "latency_p50_us", "latency_p99_us", "throughput_rps"];

fn normalize(v: Value) -> Value {
    match v {
        Value::Object(fields) => Value::Object(
            fields
                .into_iter()
                .filter(|(k, _)| !VOLATILE.contains(&k.as_str()))
                .map(|(k, v)| (k, normalize(v)))
                .collect(),
        ),
        Value::Array(items) => Value::Array(items.into_iter().map(normalize).collect()),
        other => other,
    }
}

// ---------------------------------------------------------------------------
// Conformance differential + golden transcript
// ---------------------------------------------------------------------------

#[test]
fn json_and_binary_protocols_are_semantically_identical() {
    let run = |binary: bool| -> Vec<Value> {
        let registry = Arc::new(two_tenants());
        let mut server = Server::start("127.0.0.1:0", registry).unwrap();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let replies: Vec<Value> = conformance_cases()
            .iter()
            .map(|case| {
                if binary {
                    stream.write_all(&case_binary_frame(case)).unwrap();
                    normalize(read_binary_reply(&mut stream))
                } else {
                    stream.write_all(&case_json_line(case)).unwrap();
                    normalize(read_json_reply(&mut reader))
                }
            })
            .collect();
        server.shutdown();
        replies
    };
    let json_replies = run(false);
    let binary_replies = run(true);
    assert_eq!(json_replies.len(), binary_replies.len());
    for (i, (j, b)) in json_replies.iter().zip(&binary_replies).enumerate() {
        assert_eq!(j, b, "case {i} diverged between protocols");
    }
    let transcript = json::obj(vec![("replies", json::arr(json_replies))]);
    golden::check_file(
        "rust/tests/golden/frontdoor_conformance.json",
        &transcript,
        &GoldenOptions::exact(),
    )
    .unwrap();
}

// ---------------------------------------------------------------------------
// Split torture (Conn-level: deterministic cut placement)
// ---------------------------------------------------------------------------

/// Resolve parsed submissions serially (blocking), exactly like the
/// portable fallback server does.
fn resolve(conn: &mut Conn, registry: &ModelRegistry, submits: Vec<SubmitReq>) {
    for s in submits {
        let bytes = match registry.submit_blocking(s.model.as_deref(), s.features) {
            Ok((name, resp)) => conn::encode_infer_reply_bytes(conn.protocol(), &name, &resp),
            Err(e) => conn::encode_error_bytes(conn.protocol(), &e.to_string(), e.code()),
        };
        conn.complete(registry, s.seq, bytes);
    }
}

fn decode_binary_stream(mut bytes: &[u8]) -> Vec<Value> {
    let mut out = Vec::new();
    while !bytes.is_empty() {
        match frame::try_extract(bytes, frame::DEFAULT_MAX_FRAME) {
            frame::Extract::Frame { header, payload } => {
                out.push(frame::decode_reply_to_json(&header, &bytes[payload]).unwrap());
                bytes = &bytes[frame::HEADER_LEN + header.payload_len..];
            }
            other => panic!("expected a reply frame, got {other:?}"),
        }
    }
    out
}

fn decode_json_stream(bytes: &[u8]) -> Vec<Value> {
    String::from_utf8(bytes.to_vec())
        .unwrap()
        .lines()
        .map(|l| json::parse(l).unwrap())
        .collect()
}

/// Feed `script` to a fresh Conn in chunks ending at `cuts` (ascending,
/// last == script.len()) and return the normalized decoded transcript.
fn run_chunked(script: &[u8], cuts: &[usize], binary: bool) -> Vec<Value> {
    let registry = two_tenants();
    let mut conn = Conn::new(frame::DEFAULT_MAX_FRAME);
    let mut wire = Vec::new();
    let mut pos = 0;
    for &cut in cuts {
        conn.ingest(&script[pos..cut]);
        pos = cut;
        let mut submits = Vec::new();
        conn.process(&registry, usize::MAX, &mut submits);
        resolve(&mut conn, &registry, submits);
        let n = conn.writable().len();
        wire.extend_from_slice(conn.writable());
        conn.advance_write(n);
    }
    assert_eq!(pos, script.len());
    let docs = if binary { decode_binary_stream(&wire) } else { decode_json_stream(&wire) };
    docs.into_iter().map(normalize).collect()
}

fn torture_binary_script() -> Vec<u8> {
    let mut s = Vec::new();
    frame::encode_infer_request(None, &[5.0, 0.0], &mut s);
    frame::encode_infer_request(None, &[1.0], &mut s); // bad_width
    frame::encode_admin_request(&json::obj(vec![("cmd", json::s("stats"))]), &mut s);
    frame::encode_infer_request(Some("double"), &[4.0, 0.0], &mut s);
    s
}

const TORTURE_JSON_SCRIPT: &[u8] = b"{\"features\": [5, 0]}\nnot json\n{\"cmd\": \"stats\"}\n{\"model\": \"double\", \"features\": [4, 0]}\n";

#[test]
fn splits_at_every_byte_boundary_yield_identical_transcripts() {
    let bin = torture_binary_script();
    for (script, binary) in [(bin.as_slice(), true), (TORTURE_JSON_SCRIPT, false)] {
        let reference = run_chunked(script, &[script.len()], binary);
        assert_eq!(reference.len(), 4);
        let proto = if binary { "binary" } else { "json" };
        for cut in 1..script.len() {
            let got = run_chunked(script, &[cut, script.len()], binary);
            assert_eq!(got, reference, "{proto} split at byte {cut}");
        }
    }
}

#[test]
fn random_chunking_is_seed_deterministic() {
    let bin = torture_binary_script();
    let mut rng = SplitMix64::new(0xF00D);
    for (script, binary) in [(bin.as_slice(), true), (TORTURE_JSON_SCRIPT, false)] {
        let reference = run_chunked(script, &[script.len()], binary);
        for round in 0..20 {
            let mut cuts: Vec<usize> = (0..(1 + rng.below(5)))
                .map(|_| 1 + rng.below(script.len() as u64 - 1) as usize)
                .collect();
            cuts.push(script.len());
            cuts.sort_unstable();
            cuts.dedup();
            let got = run_chunked(script, &cuts, binary);
            assert_eq!(got, reference, "round {round} cuts {cuts:?}");
        }
    }
}

// ---------------------------------------------------------------------------
// Socket-level torture
// ---------------------------------------------------------------------------

#[test]
fn byte_at_a_time_delivery_over_tcp_both_protocols() {
    for binary in [false, true] {
        let registry = Arc::new(two_tenants());
        let mut server = Server::start("127.0.0.1:0", registry).unwrap();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        stream.set_nodelay(true).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        // Trailing stats keeps the transcript deterministic: it cannot
        // execute until every earlier reply has been written.
        let cases = vec![
            Case::Infer { model: None, features: vec![5.0, 0.0] },
            Case::Infer { model: None, features: vec![1.0] },
            Case::Infer { model: Some("double"), features: vec![4.0, 0.0] },
            admin(vec![("cmd", json::s("stats"))]),
        ];
        let script: Vec<u8> = cases
            .iter()
            .flat_map(|c| if binary { case_binary_frame(c) } else { case_json_line(c) })
            .collect();
        for b in &script {
            stream.write_all(std::slice::from_ref(b)).unwrap();
        }
        let reply = |stream: &mut TcpStream, reader: &mut BufReader<TcpStream>| {
            if binary {
                read_binary_reply(stream)
            } else {
                read_json_reply(reader)
            }
        };
        let r = reply(&mut stream, &mut reader);
        assert_eq!(r.get("label").and_then(Value::as_f64), Some(5.0), "{r:?}");
        let r = reply(&mut stream, &mut reader);
        assert_eq!(r.get("code").and_then(Value::as_str), Some("bad_width"), "{r:?}");
        let r = reply(&mut stream, &mut reader);
        assert_eq!(r.get("label").and_then(Value::as_f64), Some(8.0), "{r:?}");
        let r = reply(&mut stream, &mut reader);
        assert_eq!(r.get("responses").and_then(Value::as_f64), Some(1.0), "{r:?}");
        server.shutdown();
    }
}

#[test]
fn pipelined_binary_requests_reply_in_order_with_serial_admin() {
    let registry = echo_only();
    let mut server = Server::start("127.0.0.1:0", registry).unwrap();
    let mut stream = TcpStream::connect(server.addr).unwrap();
    let n = 32;
    let mut script = Vec::new();
    for i in 0..n {
        frame::encode_infer_request(None, &[i as f32, 0.0], &mut script);
    }
    frame::encode_admin_request(&json::obj(vec![("cmd", json::s("stats"))]), &mut script);
    stream.write_all(&script).unwrap();
    for i in 0..n {
        let r = read_binary_reply(&mut stream);
        assert_eq!(r.get("label").and_then(Value::as_f64), Some(i as f64), "{r:?}");
        assert_eq!(r.get("id").and_then(Value::as_f64), Some(i as f64), "{r:?}");
    }
    // The pipelined stats observes every preceding inference (serial
    // semantics preserved under batching and out-of-order completion).
    let s = read_binary_reply(&mut stream);
    assert_eq!(s.get("responses").and_then(Value::as_f64), Some(n as f64), "{s:?}");
    assert_eq!(s.get("requests").and_then(Value::as_f64), Some(n as f64), "{s:?}");
    server.shutdown();
}

#[test]
fn oversized_frame_gets_coded_error_and_connection_survives() {
    let registry = echo_only();
    let cfg = ServerConfig { max_frame: 256, ..Default::default() };
    let mut server = Server::start_with("127.0.0.1:0", registry, cfg).unwrap();
    let mut stream = TcpStream::connect(server.addr).unwrap();
    let mut script = vec![frame::MAGIC, frame::VERSION, frame::TYPE_REQ_INFER, 0];
    script.extend_from_slice(&(1000u32).to_le_bytes());
    script.extend_from_slice(&[0u8; 1000]); // streamed, discarded
    frame::encode_infer_request(None, &[6.0, 0.0], &mut script);
    stream.write_all(&script).unwrap();
    let e = read_binary_reply(&mut stream);
    assert_eq!(e.get("code").and_then(Value::as_str), Some("bad_request"), "{e:?}");
    assert!(
        e.get("error").and_then(Value::as_str).unwrap().contains("exceeds"),
        "{e:?}"
    );
    let ok = read_binary_reply(&mut stream);
    assert_eq!(ok.get("label").and_then(Value::as_f64), Some(6.0), "{ok:?}");
    server.shutdown();
}

#[test]
fn truncated_frame_at_eof_closes_cleanly_without_reply() {
    let registry = echo_only();
    let mut server = Server::start("127.0.0.1:0", registry).unwrap();
    let mut stream = TcpStream::connect(server.addr).unwrap();
    let mut script = vec![frame::MAGIC, frame::VERSION, frame::TYPE_REQ_INFER, 0];
    script.extend_from_slice(&(64u32).to_le_bytes());
    script.extend_from_slice(&[0u8; 10]); // 54 bytes short
    stream.write_all(&script).unwrap();
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    let mut rest = Vec::new();
    stream.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "partial frame must be dropped, got {} bytes", rest.len());
    server.shutdown();
}

#[test]
fn bad_magic_mid_stream_replies_then_disconnects() {
    let registry = echo_only();
    let mut server = Server::start("127.0.0.1:0", registry).unwrap();
    let mut stream = TcpStream::connect(server.addr).unwrap();
    let mut script = Vec::new();
    frame::encode_infer_request(None, &[2.0, 0.0], &mut script);
    script.extend_from_slice(b"garbage after a valid frame");
    stream.write_all(&script).unwrap();
    let ok = read_binary_reply(&mut stream);
    assert_eq!(ok.get("label").and_then(Value::as_f64), Some(2.0), "{ok:?}");
    let e = read_binary_reply(&mut stream);
    assert_eq!(e.get("code").and_then(Value::as_str), Some("bad_request"), "{e:?}");
    assert!(e.get("error").and_then(Value::as_str).unwrap().contains("magic"), "{e:?}");
    // Desynchronized stream: the server closes after the error reply.
    let mut rest = Vec::new();
    stream.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty());
    server.shutdown();
}

#[test]
fn overlong_json_line_is_rejected_and_skipped() {
    let registry = echo_only();
    let cfg = ServerConfig { max_frame: 64, ..Default::default() };
    let mut server = Server::start_with("127.0.0.1:0", registry, cfg).unwrap();
    let mut stream = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    // 200 junk bytes, no newline: over the 64-byte line limit. The pause
    // lets the server observe the overlong prefix before the newline.
    stream.write_all(&[b'x'; 200]).unwrap();
    std::thread::sleep(Duration::from_millis(100));
    stream.write_all(b"\n{\"features\": [3, 0]}\n").unwrap();
    let e = read_json_reply(&mut reader);
    assert_eq!(e.get("code").and_then(Value::as_str), Some("bad_request"), "{e:?}");
    let ok = read_json_reply(&mut reader);
    assert_eq!(ok.get("label").and_then(Value::as_f64), Some(3.0), "{ok:?}");
    server.shutdown();
}

#[test]
fn slow_reader_hits_write_backpressure_and_loses_nothing() {
    let registry = echo_only();
    let cfg = ServerConfig { write_hwm: 1024, ..Default::default() };
    let mut server = Server::start_with("127.0.0.1:0", registry, cfg).unwrap();
    let mut stream = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    // ~300 pipelined `models` commands produce far more reply bytes than
    // the 1 KiB high-water mark; the client reads nothing until every
    // request is written, forcing the server to pause reads mid-stream.
    let n = 300;
    let mut script = Vec::new();
    for _ in 0..n {
        script.extend_from_slice(b"{\"cmd\": \"models\"}\n");
    }
    stream.write_all(&script).unwrap();
    for i in 0..n {
        let r = read_json_reply(&mut reader);
        assert_eq!(r.get("default").and_then(Value::as_str), Some("echo"), "reply {i}: {r:?}");
    }
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Event-loop regressions
// ---------------------------------------------------------------------------

#[cfg(unix)]
#[test]
fn idle_event_loop_takes_no_wakeups() {
    let registry = echo_only();
    let mut server = Server::start("127.0.0.1:0", registry).unwrap();
    // No clients: the reactors must be parked in poll, not spinning.
    std::thread::sleep(Duration::from_millis(200));
    assert_eq!(server.stats().wakeups, 0, "idle server must not wake");
    // Activity wakes it...
    let mut stream = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    stream.write_all(b"{\"cmd\": \"stats\"}\n").unwrap();
    let _ = read_json_reply(&mut reader);
    assert!(server.stats().wakeups > 0);
    drop(reader);
    drop(stream);
    // ...and once the connection is gone it parks again.
    std::thread::sleep(Duration::from_millis(200));
    let settled = server.stats().wakeups;
    std::thread::sleep(Duration::from_millis(300));
    assert_eq!(server.stats().wakeups, settled, "post-activity idle must not wake");
    server.shutdown();
}

/// Engine that blocks inside `infer` until released — lets the test
/// hold a request in flight across a shutdown.
struct Gate {
    release: Arc<(Mutex<bool>, Condvar)>,
}
impl Engine for Gate {
    fn name(&self) -> String {
        "gate".into()
    }
    fn features(&self) -> usize {
        2
    }
    fn infer(&mut self, x: &Matrix) -> anyhow::Result<Vec<i32>> {
        let (lock, cvar) = &*self.release;
        let mut released = lock.lock().unwrap();
        while !*released {
            released = cvar.wait(released).unwrap();
        }
        Ok(vec![42; x.rows()])
    }
}

#[test]
fn shutdown_drains_admitted_requests_before_joining() {
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let g2 = Arc::clone(&gate);
    let registry = Arc::new(ModelRegistry::single(
        "gate",
        "demo",
        2,
        &BatcherConfig::default(),
        vec![Box::new(move || Ok(Box::new(Gate { release: g2 }) as Box<dyn Engine>))],
    ));
    let server = Server::start("127.0.0.1:0", Arc::clone(&registry)).unwrap();
    let addr = server.addr;
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    stream.write_all(b"{\"features\": [1, 2]}\n").unwrap();
    // Wait until the request is admitted into the batcher.
    let deadline = Instant::now() + Duration::from_secs(5);
    while registry.stats(None).unwrap().1.requests < 1 {
        assert!(Instant::now() < deadline, "request never admitted");
        std::thread::sleep(Duration::from_millis(5));
    }
    // Shut down WHILE the request is still blocked inside the engine:
    // the drain must wait for it rather than abandon the connection.
    let shut = std::thread::spawn(move || {
        let mut server = server;
        server.shutdown();
        server
    });
    std::thread::sleep(Duration::from_millis(50));
    {
        let (lock, cvar) = &*gate;
        *lock.lock().unwrap() = true;
        cvar.notify_all();
    }
    let v = read_json_reply(&mut reader);
    assert_eq!(v.get("label").and_then(Value::as_f64), Some(42.0), "{v:?}");
    let server = shut.join().unwrap();
    assert_eq!(server.stats().open, 0, "shutdown left connections open");
    // The drained connection is closed...
    let mut line = String::new();
    assert_eq!(reader.read_line(&mut line).unwrap(), 0);
    // ...and the listener is gone: no thread is left accepting.
    assert!(TcpStream::connect(addr).is_err(), "listener still accepting after shutdown");
}

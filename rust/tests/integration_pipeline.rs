//! Native end-to-end integration: train → evaluate → fault-inject, and
//! check the paper's qualitative claims hold at small scale.

use loghd::data;
use loghd::eval::figures::methods_at_budget;
use loghd::eval::sweep::{Method, Workbench};
use loghd::eval::sustained_until;
use loghd::loghd::model::TrainOptions;
use loghd::quant::Precision;

fn bench(name: &str, d: usize) -> Workbench {
    let spec = data::spec(name).unwrap();
    let ds = data::generate_scaled(spec, spec.n_train.min(2000), spec.n_test.min(600));
    let opts = TrainOptions { epochs: 4, conv_epochs: 2, ..Default::default() };
    Workbench::new(&ds, d, 0xE5C0DE, opts)
}

#[test]
fn clean_accuracy_floors_per_dataset() {
    // (dataset, conventional floor, loghd floor at n=min+2)
    for (name, conv_floor, log_floor) in
        [("page", 0.75, 0.70), ("ucihar", 0.85, 0.62), ("pamap2", 0.80, 0.70)]
    {
        let mut wb = bench(name, 1000);
        let conv = wb.evaluate(Method::Conventional, Precision::F32, 0.0, 1).unwrap();
        assert!(conv > conv_floor, "{name}: conventional {conv} <= {conv_floor}");
        let n = loghd::loghd::codebook::min_bundles(wb.classes, 2) + 2;
        let log = wb.evaluate(Method::LogHd { k: 2, n }, Precision::F32, 0.0, 1).unwrap();
        assert!(log > log_floor, "{name}: loghd {log} <= {log_floor}");
    }
}

#[test]
fn bundle_memory_robust_to_stored_state_upsets() {
    // The paper's §II-C mechanism claim at CI scale: because LogHD keeps
    // full dimensionality D, corruption of the *hypervector memory* (the
    // bundles) is averaged away by concentration of measure — accuracy
    // under heavy bundle upsets stays close to clean.
    let mut wb = bench("ucihar", 2000);
    let n = 6;
    let model = wb.loghd(2, n).unwrap().clone();
    let clean = {
        let pred = model.predict(&wb.enc_test);
        loghd::eval::accuracy(&pred, &wb.y_test)
    };
    let mut rng = loghd::util::rng::SplitMix64::new(11);
    let bundles =
        loghd::eval::corrupt(&model.bundles, Precision::B8, 0.4, &mut rng);
    let corrupted = loghd::loghd::model::LogHdModel { bundles, ..model };
    let faulted = {
        let pred = corrupted.predict(&wb.enc_test);
        loghd::eval::accuracy(&pred, &wb.y_test)
    };
    assert!(
        faulted > 0.70 * clean,
        "bundle memory should degrade gracefully: {faulted} vs clean {clean}"
    );
}

#[test]
fn full_protocol_degrades_monotonically_and_gracefully() {
    // Full protocol (bundles + profiles upset) at CI scale: degradation is
    // monotone in p and never collapses to chance at moderate p. The
    // LogHD-vs-SparseHD *crossover* is a D=10k-scale effect (run the fig3
    // bench with LOGHD_FULL=1); EXPERIMENTS.md §Fig3 records both scales.
    let mut wb = bench("ucihar", 2000);
    let n = 6;
    let ps = [0.0, 0.3, 0.6];
    let curve: Vec<f64> = ps
        .iter()
        .map(|&p| {
            let a1 = wb.evaluate(Method::LogHd { k: 2, n }, Precision::B8, p, 1).unwrap();
            let a2 = wb.evaluate(Method::LogHd { k: 2, n }, Precision::B8, p, 2).unwrap();
            (a1 + a2) / 2.0
        })
        .collect();
    assert!(curve[0] > curve[2] - 0.02, "no degradation signal: {curve:?}");
    let chance = 1.0 / wb.classes as f64;
    assert!(curve[1] > 2.0 * chance, "collapsed to chance at p=0.3: {curve:?}");
    // sustained_until sanity on the measured curve
    let floor = curve[0] * 0.5;
    let sustained = sustained_until(&ps, &curve, floor);
    assert!(sustained >= 0.0 && sustained <= 0.6);
}

#[test]
fn sparsehd_robustness_shrinks_with_effective_dimensionality() {
    // Fig. 1(a)/Fig. 4 mechanism: more aggressive feature-axis pruning
    // (smaller effective D) means steeper fault degradation for SparseHD.
    let mut wb = bench("ucihar", 2000);
    let p = 0.5;
    let mild = {
        let a1 = wb.evaluate(Method::SparseHd { sparsity: 0.2 }, Precision::B8, p, 1).unwrap();
        let a2 = wb.evaluate(Method::SparseHd { sparsity: 0.2 }, Precision::B8, p, 2).unwrap();
        (a1 + a2) / 2.0
    };
    let aggressive = {
        let a1 = wb.evaluate(Method::SparseHd { sparsity: 0.9 }, Precision::B8, p, 1).unwrap();
        let a2 = wb.evaluate(Method::SparseHd { sparsity: 0.9 }, Precision::B8, p, 2).unwrap();
        (a1 + a2) / 2.0
    };
    assert!(
        mild > aggressive + 0.02,
        "keeping more dimensions should be more robust: S=0.2 -> {mild}, S=0.9 -> {aggressive}"
    );
}

#[test]
fn budget_accounting_matches_method_construction() {
    let wb = bench("page", 512);
    for budget in [0.4, 0.6, 0.8] {
        for m in methods_at_budget(wb.classes, budget) {
            match m {
                Method::SparseHd { sparsity } => {
                    assert!((1.0 - sparsity) <= budget + 1e-9)
                }
                Method::LogHd { n, .. } => {
                    assert!(n as f64 / wb.classes as f64 <= budget + 1e-9)
                }
                Method::Hybrid { n, sparsity, .. } => {
                    let frac = n as f64 * (1.0 - sparsity) / wb.classes as f64;
                    assert!(frac <= budget + 0.05, "hybrid over budget: {frac} vs {budget}");
                }
                Method::Conventional => {}
                Method::DecoHd { rank } => {
                    assert!(rank as f64 / wb.classes as f64 <= budget + 1e-9)
                }
            }
        }
    }
}

#[test]
fn quantization_degrades_gracefully() {
    let mut wb = bench("page", 1000);
    let n = loghd::loghd::codebook::min_bundles(wb.classes, 2) + 1;
    let f32acc = wb.evaluate(Method::LogHd { k: 2, n }, Precision::F32, 0.0, 1).unwrap();
    let q8 = wb.evaluate(Method::LogHd { k: 2, n }, Precision::B8, 0.0, 1).unwrap();
    let q1 = wb.evaluate(Method::LogHd { k: 2, n }, Precision::B1, 0.0, 1).unwrap();
    assert!((f32acc - q8).abs() < 0.06, "8-bit far from f32: {f32acc} vs {q8}");
    assert!(q1 > 0.3, "1-bit collapsed: {q1}");
}

#[test]
fn alphabet_k3_feasible_with_fewer_bundles() {
    // paper: k=3, C=26 -> n=3 bundles (8.7x fewer stored prototypes)
    assert_eq!(loghd::loghd::codebook::min_bundles(26, 3), 3);
    let mut wb = bench("page", 1000);
    let n3 = loghd::loghd::codebook::min_bundles(wb.classes, 3); // C=5 -> 2
    let acc = wb.evaluate(Method::LogHd { k: 3, n: n3 + 1 }, Precision::F32, 0.0, 1).unwrap();
    assert!(acc > 0.5, "k=3 loghd collapsed: {acc}");
}

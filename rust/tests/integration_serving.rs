//! Multi-tenant serving integration (the PR-2 acceptance path):
//!
//! - a registry hosting ≥2 named models at different precisions AND
//!   feature widths behind one TCP endpoint,
//! - routing by the request's `"model"` field (default tenant when
//!   omitted),
//! - a mid-stream hot reload that drops no request,
//! - per-model stats snapshots that diverge under skewed load,
//! - and the error-path contract: malformed JSON, wrong feature width,
//!   unknown model, and queue-full backpressure each produce a structured
//!   `{"error", "code"}` reply without killing the connection.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use loghd::coordinator::{BatcherConfig, Engine, ModelRegistry, Server, TenantSpec};
use loghd::data;
use loghd::loghd::model::{TrainOptions, TrainedStack};
use loghd::loghd::persist;
use loghd::quant::Precision;
use loghd::tensor::Matrix;
use loghd::util::json::{self, Value};

fn train_and_save(dataset: &str, d: usize, seed: u64, dir: &Path) {
    let spec = data::spec(dataset).unwrap();
    let ds = data::generate_scaled(spec, 400, 50);
    let opts =
        TrainOptions { epochs: 2, conv_epochs: 0, extra_bundles: 1, ..Default::default() };
    let st =
        TrainedStack::train(&ds.x_train, &ds.y_train, spec.classes, d, seed, &opts).unwrap();
    persist::save(dir, &st.encoder, &st.loghd).unwrap();
}

/// One JSON-lines client connection.
struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Self { writer: stream, reader }
    }

    fn roundtrip(&mut self, line: &str) -> Value {
        writeln!(self.writer, "{line}").unwrap();
        let mut buf = String::new();
        self.reader.read_line(&mut buf).unwrap();
        json::parse(buf.trim()).unwrap_or_else(|e| panic!("bad reply '{buf}': {e}"))
    }
}

fn features_json(width: usize) -> String {
    format!("{{\"features\": [{}]}}", vec!["0.5"; width].join(", "))
}

#[test]
fn multi_tenant_routing_hot_reload_and_stats_divergence() {
    let root = std::env::temp_dir().join("loghd_it_serving");
    let _ = std::fs::remove_dir_all(&root);
    let page_dir = root.join("page");
    let pamap_dir = root.join("pamap");
    train_and_save("page", 128, 1, &page_dir); // F=10
    train_and_save("pamap2", 128, 2, &pamap_dir); // F=75
    let specs = vec![
        TenantSpec {
            name: "page".into(),
            path: page_dir.clone(),
            precision: Precision::F32,
            replicas: 2,
            cascade: false,
        },
        TenantSpec {
            name: "pamap".into(),
            path: pamap_dir.clone(),
            precision: Precision::B1,
            replicas: 1,
            cascade: false,
        },
    ];
    let registry = Arc::new(
        ModelRegistry::open(&specs, Some("page"), &BatcherConfig::default()).unwrap(),
    );
    let mut server = Server::start("127.0.0.1:0", Arc::clone(&registry)).unwrap();
    let mut client = Client::connect(server.addr);

    // The models verb sees both tenants at their precisions.
    let models = client.roundtrip(r#"{"cmd": "models"}"#);
    assert_eq!(models.get("default").and_then(Value::as_str), Some("page"));
    let list = models.get("models").and_then(Value::as_array).unwrap();
    assert_eq!(list.len(), 2);
    let pamap = list
        .iter()
        .find(|m| m.get("model").and_then(Value::as_str) == Some("pamap"))
        .unwrap();
    assert_eq!(pamap.get("precision").and_then(Value::as_str), Some("b1"));
    let page = list
        .iter()
        .find(|m| m.get("model").and_then(Value::as_str) == Some("page"))
        .unwrap();
    assert_eq!(page.get("replicas").and_then(Value::as_f64), Some(2.0));

    // Routing: no "model" field -> default tenant; explicit field routes.
    let r = client.roundtrip(&features_json(10));
    assert_eq!(r.get("model").and_then(Value::as_str), Some("page"), "{r:?}");
    assert!(r.get("label").and_then(Value::as_f64).is_some());
    let r = client.roundtrip(&format!(
        "{{\"model\": \"pamap\", \"features\": [{}]}}",
        vec!["0.5"; 75].join(", ")
    ));
    assert_eq!(r.get("model").and_then(Value::as_str), Some("pamap"));

    // Skewed load makes the per-model snapshots diverge.
    for _ in 0..8 {
        let r = client.roundtrip(&features_json(10));
        assert!(r.get("error").is_none(), "{r:?}");
    }
    let s_page = client.roundtrip(r#"{"cmd": "stats", "model": "page"}"#);
    let s_pamap = client.roundtrip(r#"{"cmd": "stats", "model": "pamap"}"#);
    let responses =
        |v: &Value| v.get("responses").and_then(Value::as_f64).unwrap() as u64;
    assert!(responses(&s_page) >= 9);
    assert_eq!(responses(&s_pamap), 1);
    assert_ne!(responses(&s_page), responses(&s_pamap));

    // Hot reload mid-stream: a background client keeps the default tenant
    // under load while the artifact is retrained on disk and swapped to
    // int8 — every request must be answered.
    let streamer = {
        let reg = Arc::clone(&registry);
        std::thread::spawn(move || {
            let mut ok = 0;
            for _ in 0..200 {
                if reg.submit_blocking(Some("page"), vec![0.5; 10]).is_ok() {
                    ok += 1;
                }
            }
            ok
        })
    };
    train_and_save("page", 128, 7, &page_dir); // retrain in place (same width)
    let r = client.roundtrip(r#"{"cmd": "reload", "model": "page", "bits": 8}"#);
    assert_eq!(r.get("reloaded").and_then(Value::as_str), Some("page"), "{r:?}");
    assert_eq!(r.get("precision").and_then(Value::as_str), Some("b8"));
    assert_eq!(streamer.join().unwrap(), 200, "requests dropped across hot swap");
    // Both replicas adopt the swap once they pass through the batch loop.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let s = client.roundtrip(r#"{"cmd": "stats", "model": "page"}"#);
        if s.get("reloads").and_then(Value::as_f64).unwrap_or(0.0) >= 2.0 {
            break;
        }
        assert!(Instant::now() < deadline, "replicas never adopted the reload: {s:?}");
        std::thread::sleep(Duration::from_millis(10));
    }
    // Serving continues on the swapped engine.
    let r = client.roundtrip(&features_json(10));
    assert!(r.get("label").and_then(Value::as_f64).is_some(), "{r:?}");

    // A reload that would change the admitted feature width is refused
    // with a structured error (and the tenant keeps serving).
    let r = client.roundtrip(&format!(
        "{{\"cmd\": \"reload\", \"model\": \"page\", \"path\": \"{}\"}}",
        pamap_dir.display()
    ));
    assert_eq!(r.get("code").and_then(Value::as_str), Some("reload_failed"), "{r:?}");
    let r = client.roundtrip(&features_json(10));
    assert!(r.get("error").is_none(), "{r:?}");

    server.shutdown();
    let _ = std::fs::remove_dir_all(&root);
}

/// Trivial engine for the backpressure test (no model load needed).
struct Echo;

impl Engine for Echo {
    fn name(&self) -> String {
        "echo".into()
    }
    fn features(&self) -> usize {
        2
    }
    fn infer(&mut self, x: &Matrix) -> anyhow::Result<Vec<i32>> {
        Ok((0..x.rows()).map(|i| x.at(i, 0) as i32).collect())
    }
}

#[test]
fn queue_full_backpressure_is_a_structured_reply() {
    // Tiny queue + long fill window: concurrent clients overflow
    // max_pending while the worker is still waiting to fill its batch.
    let cfg = BatcherConfig {
        max_batch: 64,
        max_delay: Duration::from_millis(400),
        max_pending: 2,
    };
    let registry = Arc::new(ModelRegistry::single(
        "echo",
        "demo",
        2,
        &cfg,
        vec![Box::new(|| Ok(Box::new(Echo) as Box<dyn Engine>))],
    ));
    let mut server = Server::start("127.0.0.1:0", Arc::clone(&registry)).unwrap();
    let handles: Vec<_> = (0..10)
        .map(|_| {
            let addr = server.addr;
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                let v = c.roundtrip(r#"{"features": [1, 0]}"#);
                let rejected = v.get("error").is_some();
                if rejected {
                    assert_eq!(
                        v.get("code").and_then(Value::as_str),
                        Some("backpressure"),
                        "{v:?}"
                    );
                }
                // The connection survives the rejection: a follow-up
                // command on the same socket still gets an answer.
                let s = c.roundtrip(r#"{"cmd": "stats"}"#);
                assert!(s.get("requests").is_some(), "{s:?}");
                rejected
            })
        })
        .collect();
    let results: Vec<bool> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let rejections = results.iter().filter(|r| **r).count();
    assert!(rejections >= 1, "expected at least one backpressure rejection");
    assert!(rejections < results.len(), "some requests must be admitted");
    server.shutdown();
}

//! Bit-packed tensor storage: `bits`-wide little-endian fields packed into
//! u64 words. This is the "stored model state" that the fault injector
//! flips bits in — flipping a packed bit corrupts exactly one value's
//! field, including its sign/magnitude structure, as on real hardware.

/// Packed fixed-width integer array.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedTensor {
    bits: u32,
    count: usize,
    words: Vec<u64>,
}

impl PackedTensor {
    pub fn new(bits: u32, count: usize) -> Self {
        assert!(bits >= 1 && bits <= 32, "field width {bits} unsupported");
        let total_bits = bits as usize * count;
        Self { bits, count, words: vec![0; total_bits.div_ceil(64)] }
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    pub fn count(&self) -> usize {
        self.count
    }

    /// Total payload bits (the fault-injection surface).
    pub fn total_bits(&self) -> usize {
        self.bits as usize * self.count
    }

    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Get field `i` (little-endian bit order within the stream).
    #[inline]
    pub fn get(&self, i: usize) -> u64 {
        debug_assert!(i < self.count);
        let bits = self.bits as usize;
        let start = i * bits;
        let word = start / 64;
        let off = start % 64;
        let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
        if off + bits <= 64 {
            (self.words[word] >> off) & mask
        } else {
            let lo = self.words[word] >> off;
            let hi = self.words[word + 1] << (64 - off);
            (lo | hi) & mask
        }
    }

    /// Set field `i`.
    #[inline]
    pub fn set(&mut self, i: usize, value: u64) {
        debug_assert!(i < self.count);
        let bits = self.bits as usize;
        let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
        let value = value & mask;
        let start = i * bits;
        let word = start / 64;
        let off = start % 64;
        if off + bits <= 64 {
            self.words[word] = (self.words[word] & !(mask << off)) | (value << off);
        } else {
            let lo_bits = 64 - off;
            self.words[word] =
                (self.words[word] & !(mask << off)) | ((value << off) & u64::MAX);
            let hi_mask = mask >> lo_bits;
            self.words[word + 1] =
                (self.words[word + 1] & !hi_mask) | (value >> lo_bits);
        }
    }

    /// Flip payload bit `bit_index` (0..total_bits).
    #[inline]
    pub fn flip_bit(&mut self, bit_index: usize) {
        debug_assert!(bit_index < self.total_bits());
        self.words[bit_index / 64] ^= 1u64 << (bit_index % 64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn set_get_roundtrip_all_widths() {
        let mut rng = SplitMix64::new(2);
        for bits in [1u32, 2, 3, 4, 7, 8, 13, 16, 31, 32] {
            let count = 100;
            let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
            let values: Vec<u64> = (0..count).map(|_| rng.next_u64() & mask).collect();
            let mut p = PackedTensor::new(bits, count);
            for (i, v) in values.iter().enumerate() {
                p.set(i, *v);
            }
            for (i, v) in values.iter().enumerate() {
                assert_eq!(p.get(i), *v, "bits={bits} i={i}");
            }
        }
    }

    #[test]
    fn overwrite_does_not_leak_into_neighbors() {
        let mut p = PackedTensor::new(3, 10);
        for i in 0..10 {
            p.set(i, 0b101);
        }
        p.set(4, 0b010);
        for i in 0..10 {
            assert_eq!(p.get(i), if i == 4 { 0b010 } else { 0b101 });
        }
    }

    #[test]
    fn flip_bit_changes_exactly_one_field() {
        let mut p = PackedTensor::new(4, 8);
        for i in 0..8 {
            p.set(i, 0b1010);
        }
        p.flip_bit(4 * 3 + 1); // field 3, bit 1
        for i in 0..8 {
            assert_eq!(p.get(i), if i == 3 { 0b1000 } else { 0b1010 });
        }
        p.flip_bit(4 * 3 + 1); // flip back
        assert_eq!(p.get(3), 0b1010);
    }

    #[test]
    fn total_bits_accounting() {
        let p = PackedTensor::new(5, 13);
        assert_eq!(p.total_bits(), 65);
        assert_eq!(p.words().len(), 2);
    }
}

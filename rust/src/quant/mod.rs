//! Post-training quantization (QuantHD-style, paper §IV-A).
//!
//! Training runs in f32; for each target precision (1, 2, 4, 8 bits) the
//! stored model tensors are quantized symmetrically per-tensor and packed
//! into bit-plane words ([`packed::PackedTensor`]). Bit flips are injected
//! into the *packed representation* — exactly the stored-state fault model
//! of the paper. At 1 and 8 bits inference runs directly in the packed
//! domain (`loghd::qmodel` over the [`to_bit_matrix`](Quantized::to_bit_matrix)
//! / [`to_i16_matrix`](Quantized::to_i16_matrix) kernel views); the other
//! widths dequantize on the fly as before.
//!
//! # Example
//!
//! Symmetric per-tensor quantization bounds the round-trip error by the
//! step size:
//!
//! ```
//! use loghd::quant::{self, Precision};
//! use loghd::tensor::Matrix;
//!
//! let m = Matrix::from_vec(1, 4, vec![-1.0, -0.25, 0.25, 1.0]);
//! let q = quant::quantize(&m, Precision::B8);
//! let back = quant::dequantize(&q);
//! for (a, b) in m.data().iter().zip(back.data()) {
//!     assert!((a - b).abs() <= q.scale);
//! }
//! ```

pub mod packed;

pub use packed::PackedTensor;

use crate::tensor::{BitMatrix, I16Matrix, Matrix};

/// Quantization precision in bits (1, 2, 4, or 8). `F32` is the
/// unquantized control.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    B1,
    B2,
    B4,
    B8,
    F32,
}

impl Precision {
    pub fn bits(self) -> u32 {
        match self {
            Precision::B1 => 1,
            Precision::B2 => 2,
            Precision::B4 => 4,
            Precision::B8 => 8,
            Precision::F32 => 32,
        }
    }

    pub fn from_bits(bits: u32) -> Option<Self> {
        Some(match bits {
            1 => Precision::B1,
            2 => Precision::B2,
            4 => Precision::B4,
            8 => Precision::B8,
            32 => Precision::F32,
            _ => return None,
        })
    }

    pub const ALL_QUANT: [Precision; 4] =
        [Precision::B1, Precision::B2, Precision::B4, Precision::B8];
}

impl Precision {
    /// Short lowercase tag for logs / CSV / JSON keys.
    pub fn label(self) -> &'static str {
        match self {
            Precision::B1 => "b1",
            Precision::B2 => "b2",
            Precision::B4 => "b4",
            Precision::B8 => "b8",
            Precision::F32 => "f32",
        }
    }
}

/// Symmetric uniform quantizer state for one tensor.
#[derive(Debug, Clone)]
pub struct Quantized {
    pub precision: Precision,
    pub rows: usize,
    pub cols: usize,
    pub scale: f32,
    pub packed: PackedTensor,
}

impl Quantized {
    /// Lift 1-bit packed storage into the row-aligned [`BitMatrix`]
    /// kernel layout (a bit copy, not a dequantization — the packed
    /// stream stays the canonical stored state / fault surface).
    pub fn to_bit_matrix(&self) -> BitMatrix {
        assert_eq!(self.precision, Precision::B1, "to_bit_matrix needs 1-bit storage");
        let cols = self.cols;
        BitMatrix::from_fn(self.rows, cols, |r, c| self.packed.get(r * cols + c) == 1)
    }

    /// Lift 8-bit offset-binary packed storage into the [`I16Matrix`]
    /// kernel container. The all-ones fault code decodes to +128, which
    /// is why the container is i16 (it must not saturate).
    pub fn to_i16_matrix(&self) -> I16Matrix {
        assert_eq!(self.precision, Precision::B8, "to_i16_matrix needs 8-bit storage");
        let qmax = 127i64;
        let count = self.rows * self.cols;
        let data = (0..count).map(|i| (self.packed.get(i) as i64 - qmax) as i16).collect();
        I16Matrix::new(self.rows, self.cols, self.scale, data)
    }
}

/// Quantize a matrix. 1-bit is the sign representation at the tensor's
/// mean magnitude; >=2 bits are symmetric mid-rise integer levels in
/// [-(2^(b-1)-1), +(2^(b-1)-1)] at scale max|x|/(2^(b-1)-1).
pub fn quantize(m: &Matrix, precision: Precision) -> Quantized {
    let bits = precision.bits();
    assert!(bits < 32, "use the raw matrix for f32");
    let data = m.data();
    if bits == 1 {
        let mean_abs =
            (data.iter().map(|v| v.abs() as f64).sum::<f64>() / data.len().max(1) as f64) as f32;
        let mut packed = PackedTensor::new(1, data.len());
        for (i, v) in data.iter().enumerate() {
            packed.set(i, u64::from(*v >= 0.0));
        }
        return Quantized {
            precision,
            rows: m.rows(),
            cols: m.cols(),
            scale: mean_abs.max(1e-12),
            packed,
        };
    }
    let qmax = (1i64 << (bits - 1)) - 1; // e.g. 127 for 8-bit
    let max_abs = data.iter().fold(0.0f32, |a, v| a.max(v.abs()));
    let scale = (max_abs / qmax as f32).max(1e-12);
    let mut packed = PackedTensor::new(bits, data.len());
    for (i, v) in data.iter().enumerate() {
        let q = (v / scale).round().clamp(-(qmax as f32), qmax as f32) as i64;
        // offset-binary storage: [0, 2^bits - 2]; the all-ones code is
        // reachable only through bit flips and decodes to qmax+1 (a fault).
        packed.set(i, (q + qmax) as u64);
    }
    Quantized { precision, rows: m.rows(), cols: m.cols(), scale, packed }
}

/// Dequantize back to a dense matrix (after optional fault injection).
pub fn dequantize(q: &Quantized) -> Matrix {
    let bits = q.precision.bits();
    let count = q.rows * q.cols;
    let mut out = Vec::with_capacity(count);
    if bits == 1 {
        for i in 0..count {
            out.push(if q.packed.get(i) == 1 { q.scale } else { -q.scale });
        }
    } else {
        let qmax = (1i64 << (bits - 1)) - 1;
        for i in 0..count {
            let raw = q.packed.get(i) as i64 - qmax;
            out.push(raw as f32 * q.scale);
        }
    }
    Matrix::from_vec(q.rows, q.cols, out)
}

/// Round-trip helper: quantize to `precision` then back (f32 passes
/// through untouched). This is the "post-training quantization then
/// evaluate" protocol of §IV-A.
pub fn quantize_roundtrip(m: &Matrix, precision: Precision) -> Matrix {
    match precision {
        Precision::F32 => m.clone(),
        p => dequantize(&quantize(m, p)),
    }
}

/// Apply a sampled analog plane fault to packed storage through its
/// conductance-level mapping (`cols` is the plane's row width):
///
/// - drift moves each stored *level* by `round(sigma · qmax · z)`
///   (for 1-bit sign storage the sign flips when `±1 + sigma·z`
///   crosses zero), clamped to the level rails,
/// - stuck-at pins a cell to a rail: low = minimum code (level −qmax /
///   sign 0), high = maximum valid code (level +qmax / sign 1),
/// - line failures read whole rows at the low rail.
///
/// Digital flips route through [`crate::faults::apply_value_mask`], so
/// the packed digital path is unchanged. The all-ones fault code stays
/// reachable only through bit flips: analog perturbations land on
/// valid levels by construction.
pub fn apply_analog_packed(t: &mut PackedTensor, cols: usize, fault: &crate::faults::PlaneFault) {
    use crate::faults::PlaneFault;
    let bits = t.bits();
    if bits == 1 {
        match fault {
            PlaneFault::Flips(mask) => crate::faults::apply_value_mask(t, mask),
            PlaneFault::Drift { sigma, z } => {
                if z.is_empty() {
                    return;
                }
                assert_eq!(z.len(), t.count(), "drift field does not match plane size");
                for (i, zi) in z.iter().enumerate() {
                    let sign = if t.get(i) == 1 { 1.0f32 } else { -1.0 };
                    t.set(i, u64::from(sign + sigma * zi >= 0.0));
                }
            }
            PlaneFault::Stuck(cells) => {
                for &(v, high) in cells {
                    t.set(v, u64::from(high));
                }
            }
            PlaneFault::Lines(rows) => {
                for &r in rows {
                    for v in r * cols..(r + 1) * cols {
                        t.set(v, 0);
                    }
                }
            }
        }
        return;
    }
    let qmax = (1i64 << (bits - 1)) - 1;
    match fault {
        PlaneFault::Flips(mask) => crate::faults::apply_value_mask(t, mask),
        PlaneFault::Drift { sigma, z } => {
            if z.is_empty() {
                return;
            }
            assert_eq!(z.len(), t.count(), "drift field does not match plane size");
            for (i, zi) in z.iter().enumerate() {
                let level = t.get(i) as i64 - qmax;
                let step = (sigma * qmax as f32 * zi).round() as i64;
                let drifted = (level + step).clamp(-qmax, qmax);
                t.set(i, (drifted + qmax) as u64);
            }
        }
        PlaneFault::Stuck(cells) => {
            for &(v, high) in cells {
                t.set(v, if high { (2 * qmax) as u64 } else { 0 });
            }
        }
        PlaneFault::Lines(rows) => {
            for &r in rows {
                for v in r * cols..(r + 1) * cols {
                    t.set(v, 0);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        let mut rng = SplitMix64::new(3);
        let m = Matrix::from_vec(4, 32, rng.normals_f32(128));
        for p in [Precision::B2, Precision::B4, Precision::B8] {
            let q = quantize(&m, p);
            let back = dequantize(&q);
            let step = q.scale;
            for (a, b) in m.data().iter().zip(back.data()) {
                assert!(
                    (a - b).abs() <= 0.5 * step + 1e-6,
                    "{p:?}: |{a} - {b}| > step/2 = {}",
                    0.5 * step
                );
            }
        }
    }

    #[test]
    fn one_bit_is_sign() {
        let m = Matrix::from_vec(1, 4, vec![0.5, -0.25, 1.0, -2.0]);
        let q = quantize(&m, Precision::B1);
        let back = dequantize(&q);
        for (orig, b) in m.data().iter().zip(back.data()) {
            assert_eq!(orig.signum(), b.signum());
            assert!((b.abs() - q.scale).abs() < 1e-6);
        }
    }

    #[test]
    fn f32_passthrough() {
        let m = Matrix::from_vec(2, 2, vec![1.0, -2.0, 3.0, -4.0]);
        assert_eq!(quantize_roundtrip(&m, Precision::F32).data(), m.data());
    }

    #[test]
    fn higher_precision_lower_error() {
        let mut rng = SplitMix64::new(7);
        let m = Matrix::from_vec(8, 64, rng.normals_f32(512));
        let mut last = f64::INFINITY;
        for p in [Precision::B2, Precision::B4, Precision::B8] {
            let back = quantize_roundtrip(&m, p);
            let err: f64 = m
                .data()
                .iter()
                .zip(back.data())
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum();
            assert!(err < last, "{p:?} err {err} not < {last}");
            last = err;
        }
    }

    #[test]
    fn bit_matrix_view_matches_signs() {
        let mut rng = SplitMix64::new(13);
        let m = Matrix::from_vec(3, 70, rng.normals_f32(210));
        let q = quantize(&m, Precision::B1);
        let bits = q.to_bit_matrix();
        for r in 0..3 {
            for c in 0..70 {
                assert_eq!(bits.get(r, c), m.at(r, c) >= 0.0, "({r},{c})");
            }
        }
    }

    #[test]
    fn i16_view_matches_dequantized_levels() {
        let mut rng = SplitMix64::new(17);
        let m = Matrix::from_vec(2, 40, rng.normals_f32(80));
        let q = quantize(&m, Precision::B8);
        let view = q.to_i16_matrix();
        let back = dequantize(&q);
        for r in 0..2 {
            for c in 0..40 {
                let want = back.at(r, c);
                let got = view.row(r)[c] as f32 * view.scale;
                assert!((got - want).abs() < 1e-6, "({r},{c}): {got} vs {want}");
            }
        }
    }

    #[test]
    fn i16_query_quantizer_pins_stored_b8_policy() {
        // The serving hot path quantizes queries via I16Matrix::quantize;
        // stored tensors go through quantize(.., B8). The two implement
        // one level policy (scale = max|x|/127, round, clamp) and must
        // stay bit-identical, or the int8 engine drifts from its stored
        // operands.
        let mut rng = SplitMix64::new(23);
        let m = Matrix::from_vec(3, 77, rng.normals_f32(231));
        assert_eq!(I16Matrix::quantize(&m), quantize(&m, Precision::B8).to_i16_matrix());
    }

    #[test]
    fn i16_view_carries_fault_code_without_saturating() {
        let m = Matrix::from_vec(1, 2, vec![1.0, -1.0]);
        let mut q = quantize(&m, Precision::B8);
        // Force value 0 to the all-ones code (only reachable via flips).
        q.packed.set(0, 0xFF);
        assert_eq!(q.to_i16_matrix().row(0)[0], 128);
    }

    #[test]
    fn precision_bits_table() {
        assert_eq!(Precision::B1.bits(), 1);
        assert_eq!(Precision::B8.bits(), 8);
        assert_eq!(Precision::from_bits(4), Some(Precision::B4));
        assert_eq!(Precision::from_bits(3), None);
    }

    #[test]
    fn analog_stuck_pins_packed_levels_to_the_rails() {
        use crate::faults::PlaneFault;
        let m = Matrix::from_vec(1, 4, vec![0.5, -0.25, 1.0, -2.0]);
        let mut q8 = quantize(&m, Precision::B8);
        apply_analog_packed(&mut q8.packed, 4, &PlaneFault::Stuck(vec![(0, true), (2, false)]));
        assert_eq!(q8.packed.get(0), 254, "high rail is the max valid code, not the fault code");
        assert_eq!(q8.packed.get(2), 0, "low rail is code 0");
        let back = dequantize(&q8);
        assert!((back.at(0, 0) - 127.0 * q8.scale).abs() < 1e-6);
        assert!((back.at(0, 2) + 127.0 * q8.scale).abs() < 1e-6);

        let mut q1 = quantize(&m, Precision::B1);
        apply_analog_packed(&mut q1.packed, 4, &PlaneFault::Stuck(vec![(1, true), (2, false)]));
        assert_eq!(q1.packed.get(1), 1);
        assert_eq!(q1.packed.get(2), 0);
    }

    #[test]
    fn analog_drift_moves_levels_and_clamps_at_the_rails() {
        use crate::faults::PlaneFault;
        let m = Matrix::from_vec(1, 3, vec![1.0, 0.0, -1.0]);
        let mut q = quantize(&m, Precision::B8);
        let codes: Vec<u64> = (0..3).map(|i| q.packed.get(i)).collect();
        // +1 full-scale z on every cell: level += 127, clamped at +127.
        let fault = PlaneFault::Drift { sigma: 1.0, z: vec![1.0, 1.0, 1.0] };
        apply_analog_packed(&mut q.packed, 3, &fault);
        assert_eq!(q.packed.get(0), 254, "already at +qmax, clamped");
        assert_eq!(q.packed.get(1), codes[1] + 127);
        assert_eq!(q.packed.get(2), 127, "-qmax drifts up to level 0");
        // 1-bit: a strong opposing drift flips the sign, a weak one can't.
        let mut q1 = quantize(&m, Precision::B1);
        let strong = PlaneFault::Drift { sigma: 2.0, z: vec![-1.0, 0.0, 1.0] };
        apply_analog_packed(&mut q1.packed, 3, &strong);
        assert_eq!(q1.packed.get(0), 0, "sign flipped by -2 full-scale drift");
        assert_eq!(q1.packed.get(2), 1, "sign flipped by +2 full-scale drift");
        let mut q1b = quantize(&m, Precision::B1);
        let weak = PlaneFault::Drift { sigma: 0.5, z: vec![-1.0, 0.0, 1.0] };
        apply_analog_packed(&mut q1b.packed, 3, &weak);
        assert_eq!(q1b.packed.get(0), 1, "weak drift cannot cross zero");
    }

    #[test]
    fn analog_lines_read_whole_rows_at_the_low_rail() {
        use crate::faults::PlaneFault;
        let mut rng = SplitMix64::new(29);
        let m = Matrix::from_vec(4, 8, rng.normals_f32(32));
        let mut q = quantize(&m, Precision::B4);
        apply_analog_packed(&mut q.packed, 8, &PlaneFault::Lines(vec![1, 3]));
        for c in 0..8 {
            assert_eq!(q.packed.get(8 + c), 0, "row 1 col {c}");
            assert_eq!(q.packed.get(24 + c), 0, "row 3 col {c}");
        }
        // untouched rows keep their codes
        let back = dequantize(&q);
        for c in 0..8 {
            assert!((back.at(1, c) + 7.0 * q.scale).abs() < 1e-6);
        }
    }
}

//! Command-line interface (hand-rolled parser — clap is not vendored).
//!
//! ```text
//! loghd info                              # datasets + artifact bundles
//! loghd train  --dataset page --d 2000 --out models/page [--k 2 ...]
//!              [--baseline_out models/page_conv] [--decohd_out models/page_deco [--rank 3]]
//! loghd eval   --model models/page [--p 0.2 --bits 8]   # any registered artifact kind
//! loghd inspect <dir>                     # ModelCard + zoo kind + trait stored_bits
//! loghd calibrate --model models/page [--target 0.995]  # fit the cascade threshold
//! loghd serve  --model page=models/page:8,conv=models/page_conv
//!              [--replicas 2 --default page --addr 127.0.0.1:7878] [--cascade true]
//!              | --artifacts artifacts/page_smoke [--entry infer_loghd]
//! loghd robustness [--profile smoke|full] [--decohd true] [--out path.json]
//!                  [--fault-model bitflip,drift,stuckat,line|all [--span 2]]
//! loghd drift  [--profile smoke|full] [--out path.json]   # frozen-vs-online stream
//! loghd table2 [--n 7]                    # hardware-efficiency ratios
//! ```

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::baselines::decohd::{self, DecoHdModel};
use crate::config::RunConfig;
use crate::coordinator::{
    BatcherConfig, EngineFactory, ModelRegistry, PjrtEngine, Server, TenantSpec,
};
use crate::data;
use crate::eval::{accuracy, Workbench};
use crate::eval::sweep::Method;
use crate::faults::FaultModelKind;
use crate::hwmodel;
use crate::loghd::model::TrainedStack;
use crate::loghd::persist;
use crate::model::{self, zoo, HdClassifier};
use crate::quant::Precision;
use crate::runtime::artifact::ModelCard;

/// Parsed command line: subcommand + `--key value` flags + positionals.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub flags: HashMap<String, String>,
    /// Bare (non-flag) arguments, in order. Only `inspect` accepts one;
    /// [`run`] rejects strays for every other command.
    pub positional: Vec<String>,
}

/// Parse argv-style input (exposed for tests).
pub fn parse_args<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
    let mut it = argv.into_iter();
    let command = it.next().unwrap_or_default();
    let mut flags = HashMap::new();
    let mut positional = Vec::new();
    let mut pending: Option<String> = None;
    for tok in it {
        if let Some(key) = pending.take() {
            flags.insert(key, tok);
        } else if let Some(stripped) = tok.strip_prefix("--") {
            if let Some((k, v)) = stripped.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else {
                pending = Some(stripped.to_string());
            }
        } else {
            positional.push(tok);
        }
    }
    if let Some(key) = pending {
        flags.insert(key, "true".to_string()); // boolean flag
    }
    Ok(Args { command, flags, positional })
}

fn flag<'a>(args: &'a Args, key: &str) -> Option<&'a str> {
    args.flags.get(key).map(String::as_str)
}

/// Binary entrypoint.
pub fn main_entry() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Dispatch. Separated from `main_entry` for testing.
pub fn run(argv: Vec<String>) -> Result<()> {
    let args = parse_args(argv)?;
    if args.command != "inspect" {
        if let Some(stray) = args.positional.first() {
            bail!("unexpected positional argument '{stray}'");
        }
    }
    match args.command.as_str() {
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        "info" => cmd_info(),
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "inspect" => cmd_inspect(&args),
        "calibrate" => cmd_calibrate(&args),
        "serve" => cmd_serve(&args),
        "robustness" => cmd_robustness(&args),
        "drift" => cmd_drift(&args),
        "table2" => cmd_table2(&args),
        other => bail!("unknown command '{other}' (try 'loghd help')"),
    }
}

const HELP: &str = "\
loghd — LogHD: class-axis compression of HDC classifiers (paper reproduction)

USAGE:
  loghd info
  loghd train  --dataset <name> --d <dim> --out <dir> [--k K --extra_bundles E --epochs T]
               [--baseline_out <dir>]   # also save the conventional O(C*D) baseline
               [--decohd_out <dir> [--rank r]]   # also save a DecoHD decomposition
  loghd eval   --model <dir> [--p <flip prob>] [--bits 1|2|4|8|32] [--seed S]
  loghd inspect <dir>                    # or: loghd inspect --model <dir>
  loghd calibrate --model <dir> [--dataset <name>] [--target 0.995] [--seed S]
               [--out <path.json>]      # fit + persist the cascade threshold
  loghd serve  (--model <name=dir[:bits],...> | --artifacts <bundle dir> [--entry infer_loghd])
               [--replicas R] [--default <name>] [--bits 1|2|4|8|32]
               [--cascade true]        # b1 prefilter + margin-gated escalation
               [--addr 127.0.0.1:7878] [--max_batch 64] [--max_delay_ms 2]
               [--reactors 2]          # event-loop reactor threads (unix)
  loghd robustness [--profile smoke|full] [--dataset <name>] [--d <dim>]
               [--budget <frac of C*D*32>] [--target <frac of clean acc>]
               [--trials T] [--seed S] [--decohd true] [--out <path.json>]
               [--fault-model bitflip,drift,stuckat,line|all]
               [--span <rows>] [--drift_sigma_max <sigma>]
  loghd drift  [--profile smoke|full] [--dataset <name>] [--d <dim>]
               [--windows W] [--samples_per_window N] [--rotate_frac R]
               [--shift_scale S] [--add_class_at W|none] [--replicas R]
               [--publish_every N] [--seed S] [--out <path.json>]
  loghd table2 [--n <bundles>]

eval loads ANY registered artifact kind (loghd, conventional, decohd,
aot bundle), snapshots it at --bits, injects stored-state bit flips
through the shared fault-surface driver, and reports test accuracy.

inspect prints an artifact's ModelCard, its model-zoo registration, the
trait-reported stored_bits per serving precision, and the enumeration
of stored bit-planes the fault injector targets — each with its
(rows x cols x bits) geometry and value domain, cross-checked against
the trait-reported total.

calibrate fits the precision cascade's operating threshold offline: it
decodes a calibration set through both the packed b1 twin and the exact
f32 path, picks the smallest normalized-margin threshold whose b1/exact
agreement meets --target (with a bootstrap confidence interval whose
lower bound must also clear it), reports held-out agreement, and
persists the threshold into the artifact's model.json — which is what
`serve --cascade` admission requires.

serve hosts every named model behind one TCP endpoint speaking both
JSON-lines and length-prefixed binary frames (sniffed per connection by
the first byte; see docs/PROTOCOL.md): requests route by their \"model\"
field (default: the --default tenant), {\"cmd\":\"models\"} lists tenants,
{\"cmd\":\"reload\"} hot-swaps one tenant's artifact without dropping
in-flight requests. --cascade true serves every --model tenant through
the precision cascade (each artifact must carry a calibrated threshold
— run `loghd calibrate` first — and the tenant's bits become the exact
tier, so b1 tenants are refused); per-tenant stats grow cascade_*
tier/escalation fields. On unix the front door is --reactors nonblocking
epoll/poll event-loop threads; connections cost buffers, not threads.

robustness solves equal-memory (method, precision, n/sparsity) cells at
one stored-size budget, runs Monte-Carlo bit-flip campaigns over them,
and reports accuracy-vs-flip-rate curves plus the class-axis vs
feature-axis resilience ratio (the paper's headline claim). --decohd
true appends DecoHD cells to the solved grid. Output is bit-identical
for any LOGHD_THREADS; default --out is results/BENCH_robustness.json
plus a repo-root snapshot.

drift replays a non-stationary stream (rotating class means, covariate
shift, a mid-stream class addition) against two tenants of one serving
registry — a frozen one and one learning online through the feedback
verb with live hot-publishes — and records accuracy-over-time for
both, the publish history, and the zero-drop counters. Output is
bit-identical for any LOGHD_THREADS outside meta; default --out is
results/BENCH_drift.json plus a repo-root snapshot.

--fault-model switches the campaign onto the analog fault surface: the
same solved grid is swept under each listed model (digital bitflip,
Gaussian conductance drift, stuck-at cells, correlated line failures)
on a shared normalized severity grid, each annotated with its memory
technology and modeled energy; default --out becomes
results/BENCH_analog.json (+ repo-root snapshot).
";

fn cmd_info() -> Result<()> {
    println!("datasets (synthetic, Table I shapes):");
    for s in data::SPECS {
        println!(
            "  {:<8} F={:<4} C={:<3} train={:<6} test={:<6} {}",
            s.name, s.features, s.classes, s.n_train, s.n_test, s.description
        );
    }
    let root = PathBuf::from("artifacts");
    if root.join("index.json").exists() {
        println!("artifact bundles under {}:", root.display());
        for entry in std::fs::read_dir(&root)? {
            let dir = entry?.path();
            if dir.join("manifest.json").exists() {
                let m = crate::runtime::artifact::Manifest::load(&dir)?;
                println!(
                    "  {:<12} dataset={} D={} k={} n={} batch={} acc(conv/loghd)={:.3}/{:.3}",
                    m.name,
                    m.dataset,
                    m.d,
                    m.k,
                    m.n,
                    m.batch,
                    m.clean_acc_conventional,
                    m.clean_acc_loghd
                );
            }
        }
    } else {
        println!("no artifacts/ found — run `make artifacts`");
    }
    Ok(())
}

fn config_from(args: &Args) -> Result<RunConfig> {
    let mut cfg = match flag(args, "config") {
        Some(path) => RunConfig::from_file(&PathBuf::from(path))?,
        None => RunConfig::default(),
    };
    cfg.apply_overrides(&args.flags)?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let out = PathBuf::from(flag(args, "out").context("--out <dir> required")?);
    let spec = data::spec(&cfg.dataset).with_context(|| format!("unknown dataset {}", cfg.dataset))?;
    crate::log_info!("training on {} at D={} (k={}, +{} bundles, {} epochs)",
        cfg.dataset, cfg.d, cfg.train.k, cfg.train.extra_bundles, cfg.train.epochs);
    let ds = data::generate(spec);
    let stack = TrainedStack::train(&ds.x_train, &ds.y_train, spec.classes, cfg.d,
        cfg.encoder_seed, &cfg.train)?;
    let enc_test = stack.encoder.encode(&ds.x_test);
    let acc = accuracy(&stack.loghd.predict(&enc_test), &ds.y_test);
    persist::save(&out, &stack.encoder, &stack.loghd)?;
    if let Some(bdir) = flag(args, "baseline_out") {
        let conv =
            crate::baselines::conventional::ConventionalModel::new(stack.prototypes.clone());
        persist::save_conventional(&PathBuf::from(bdir), &stack.encoder, &conv)?;
        println!("saved conventional baseline ({} floats) to {bdir}", conv.memory_floats());
    }
    if let Some(ddir) = flag(args, "decohd_out") {
        let rank = match flag(args, "rank") {
            Some(r) => r.parse().context("--rank")?,
            None => decohd::default_rank(spec.classes),
        };
        let deco = DecoHdModel::from_prototypes(&stack.prototypes, rank)?;
        persist::save_decohd(&PathBuf::from(ddir), &stack.encoder, &deco)?;
        println!(
            "saved decohd(r={rank}) baseline ({} floats, {:.3} of C*D) to {ddir}",
            deco.memory_floats(),
            deco.budget_fraction()
        );
    }
    println!(
        "trained loghd(k={}, n={}) on {}: clean acc {:.4}, budget {:.3} of C*D, saved to {}",
        stack.loghd.book.k,
        stack.loghd.n_bundles(),
        cfg.dataset,
        acc,
        stack.loghd.budget_fraction(),
        out.display()
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model_dir = PathBuf::from(flag(args, "model").context("--model <dir> required")?);
    let loaded = persist::load_any(&model_dir)?;
    let p: f64 = flag(args, "p").unwrap_or("0").parse().context("--p must be a number")?;
    let bits: u32 = flag(args, "bits").unwrap_or("32").parse().context("--bits")?;
    let seed: u64 = flag(args, "seed").unwrap_or("1").parse().context("--seed")?;
    let precision = Precision::from_bits(bits).context("--bits must be 1|2|4|8|32")?;

    // dataset inferred from feature width
    let spec = data::SPECS
        .iter()
        .find(|s| s.features == loaded.features())
        .context("no dataset matches model feature width")?;
    let ds = data::generate(spec);
    let enc_test = loaded.encoder().encode(&ds.x_test);

    // The trait pipeline, uniform across every registered kind:
    // snapshot the model at `precision`, flip bits across its whole
    // stored fault surface, score the corrupted planes.
    let mut inst = loaded.instance(precision);
    let mut rng = crate::util::rng::SplitMix64::new(seed ^ 0xFA17);
    let flips = model::inject_value_faults(inst.as_mut(), p, &mut rng);
    let acc = accuracy(&inst.predict(&enc_test), &ds.y_test);
    println!(
        "dataset={} kind={} D={} stored={} bits total, bits={} p={:.2} flips={} -> accuracy {:.4}",
        spec.name,
        loaded.kind(),
        inst.d(),
        inst.stored_bits(),
        bits,
        p,
        flips,
        acc
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let dir = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| flag(args, "model"))
        .context("usage: loghd inspect <artifact dir>")?;
    if args.positional.len() > 1 {
        bail!("inspect takes one artifact dir, got {:?}", args.positional);
    }
    let dir = PathBuf::from(dir);
    let card = ModelCard::load(&dir)?;
    let spec = zoo::lookup(&card.kind).with_context(|| {
        format!("kind '{}' is not in the model zoo (registered: {})", card.kind, zoo::kinds())
    })?;
    println!("artifact   {}", dir.display());
    println!("kind       {} — {}", spec.kind, spec.description);
    println!("family     {}", spec.family);
    println!("classes    {}", card.classes);
    println!("d          {}", card.d);
    println!("features   {}", card.features);

    let loaded = spec.load(&dir)?;
    let conv_bits = (card.classes * card.d * 32) as f64;
    println!("stored size by serving precision (trait-reported, = fault surface):");
    for precision in [Precision::F32, Precision::B8, Precision::B1] {
        let inst = loaded.instance(precision);
        let bits = inst.stored_bits();
        println!(
            "  {:<4} {:>12} bits  ({:>5.1}% of the f32 conventional C*D footprint)",
            precision.label(),
            bits,
            100.0 * bits as f64 / conv_bits
        );
    }
    let inst = loaded.instance(Precision::F32);
    let surface = inst.fault_surface();
    println!("fault surface ({} planes at f32):", surface.planes.len());
    let mut total = 0usize;
    for plane in &surface.planes {
        total += plane.total_bits();
        println!(
            "  {:<16} {:>6} rows x {:<6} cols x {:>2} bits [{:<6}] = {:>12} bits",
            plane.label,
            plane.rows,
            plane.cols,
            plane.bits,
            plane.domain(),
            plane.total_bits()
        );
    }
    // The enumerated geometry must account for every stored bit the
    // trait reports — anything else means injector/model drift.
    let stored = inst.stored_bits();
    if total != stored {
        bail!("plane geometry totals {total} bits but the trait reports {stored}");
    }
    println!("  {:<16} plane total {total} bits == trait stored_bits", "(check)");
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let model_dir = PathBuf::from(flag(args, "model").context("--model <dir> required")?);
    let target: f64 = flag(args, "target")
        .map(str::parse)
        .transpose()
        .context("--target")?
        .unwrap_or(crate::loghd::cascade::DEFAULT_TARGET);
    let seed: u64 = flag(args, "seed").unwrap_or("1").parse().context("--seed")?;
    let loaded = persist::load_any(&model_dir)?;
    let (encoder, model) = match loaded {
        persist::LoadedModel::LogHd(e, m) => (e, m),
        other => bail!(
            "calibrate needs a loghd artifact (the cascade's b1 twin), got kind '{}'",
            other.kind()
        ),
    };
    // Dataset inferred from feature width, exactly like `eval`.
    let spec = match flag(args, "dataset") {
        Some(name) => data::spec(name).with_context(|| format!("unknown dataset {name}"))?,
        None => data::SPECS
            .iter()
            .find(|s| s.features == encoder.features())
            .context("no dataset matches model feature width; pass --dataset")?,
    };
    let ds = data::generate(spec);
    let cal = crate::loghd::cascade::calibrate(&encoder, &model, &ds.x_train, target, seed)?;
    let (holdout_agreement, holdout_escalation) =
        crate::loghd::cascade::evaluate(&encoder, &model, &ds.x_test, cal.threshold);
    crate::loghd::cascade::write_threshold(&model_dir, &cal)?;
    println!(
        "calibrated cascade on {} ({} rows): threshold {:.6e} at target {:.4}",
        spec.name, cal.rows, cal.threshold, cal.target
    );
    println!(
        "  fit:      agreement {:.4} (bootstrap CI [{:.4}, {:.4}]), escalation {:.4}",
        cal.agreement, cal.agreement_ci.0, cal.agreement_ci.1, cal.escalation_rate
    );
    println!(
        "  held-out: agreement {:.4}, escalation {:.4} ({} rows)",
        holdout_agreement,
        holdout_escalation,
        ds.x_test.rows()
    );
    println!("wrote cascade_threshold into {}", model_dir.join("model.json").display());
    if let Some(path) = flag(args, "out") {
        use crate::util::json;
        write_json_to(
            path,
            &json::obj(vec![
                ("dataset", json::s(spec.name)),
                ("threshold", json::num(cal.threshold as f64)),
                ("target", json::num(cal.target)),
                ("fit_agreement", json::num(cal.agreement)),
                ("fit_agreement_ci_lower", json::num(cal.agreement_ci.0)),
                ("fit_agreement_ci_upper", json::num(cal.agreement_ci.1)),
                ("fit_escalation_rate", json::num(cal.escalation_rate)),
                ("fit_rows", json::num(cal.rows as f64)),
                ("holdout_agreement", json::num(holdout_agreement)),
                ("holdout_escalation_rate", json::num(holdout_escalation)),
            ]),
        )?;
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = flag(args, "addr").unwrap_or("127.0.0.1:7878").to_string();
    let max_batch: usize = flag(args, "max_batch").unwrap_or("64").parse()?;
    let max_delay_ms: u64 = flag(args, "max_delay_ms").unwrap_or("2").parse()?;
    let replicas: usize =
        flag(args, "replicas").unwrap_or("1").parse().context("--replicas")?;
    let replicas = replicas.max(1);
    let reactors: usize = flag(args, "reactors").unwrap_or("2").parse().context("--reactors")?;
    let server_cfg =
        crate::coordinator::ServerConfig { reactors: reactors.max(1), ..Default::default() };
    let cfg = BatcherConfig {
        max_batch,
        max_delay: std::time::Duration::from_millis(max_delay_ms),
        ..Default::default()
    };

    let registry = if let Some(bundle) = flag(args, "artifacts") {
        let dir = PathBuf::from(bundle);
        let manifest = crate::runtime::artifact::Manifest::load(&dir)?;
        let entry = flag(args, "entry").unwrap_or("infer_loghd").to_string();
        let factories: Vec<EngineFactory> = (0..replicas)
            .map(|_| PjrtEngine::factory(dir.clone(), entry.clone()))
            .collect();
        ModelRegistry::single(&manifest.name, "aot-bundle", manifest.features, &cfg, factories)
    } else if let Some(spec_str) = flag(args, "model") {
        let default_bits: u32 = flag(args, "bits").unwrap_or("32").parse().context("--bits")?;
        let cascade: bool = flag(args, "cascade")
            .map(str::parse)
            .transpose()
            .context("--cascade must be true|false")?
            .unwrap_or(false);
        let specs = spec_str
            .split(',')
            .map(|frag| {
                TenantSpec::parse(frag.trim(), default_bits, replicas).map(|mut s| {
                    s.cascade = cascade;
                    s
                })
            })
            .collect::<Result<Vec<_>>>()?;
        ModelRegistry::open(&specs, flag(args, "default"), &cfg)?
    } else {
        bail!("serve needs --artifacts <bundle> or --model <name=dir[:bits],...>");
    };

    let registry = Arc::new(registry);
    let mut server = Server::start_with(&addr, Arc::clone(&registry), server_cfg)?;
    println!("serving on {} — tenants:", server.addr);
    for info in registry.describe() {
        println!(
            "  {:<16} kind={:<12} precision={:<4} replicas={} features={}{}{}",
            info.name,
            info.kind,
            info.precision,
            info.replicas,
            info.features,
            if info.cascade.is_some() { "  cascade=b1-prefilter" } else { "" },
            if info.is_default { "  (default)" } else { "" }
        );
    }
    // Block forever (Ctrl-C kills the process; graceful path is tested via
    // the library API).
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
        let _ = &mut server;
    }
}

fn cmd_robustness(args: &Args) -> Result<()> {
    let profile = flag(args, "profile").unwrap_or("smoke");
    let mut cfg = crate::eval::CampaignConfig::by_name(profile)
        .with_context(|| format!("unknown profile '{profile}' (smoke|full)"))?;
    if let Some(ds) = flag(args, "dataset") {
        cfg.dataset = ds.to_string();
    }
    if let Some(d) = flag(args, "d") {
        cfg.d = d.parse().context("--d")?;
    }
    if let Some(b) = flag(args, "budget") {
        cfg.budget_frac_f32 = b.parse().context("--budget")?;
    }
    if let Some(t) = flag(args, "target") {
        cfg.target_frac = t.parse().context("--target")?;
    }
    if let Some(t) = flag(args, "trials") {
        cfg.trials = t.parse().context("--trials")?;
    }
    if let Some(s) = flag(args, "seed") {
        cfg.seed = s.parse().context("--seed")?;
    }
    if let Some(v) = flag(args, "decohd") {
        cfg.decohd = v.parse().context("--decohd must be true|false")?;
    }

    // --fault-model routes the same solved grid through the analog
    // campaign (digital bitflip is the zero-salt member, so passing
    // `--fault-model bitflip` reproduces the digital artifact exactly).
    if let Some(list) = flag(args, "fault-model").or_else(|| flag(args, "fault_model")) {
        let kinds: Vec<FaultModelKind> = if list.trim().eq_ignore_ascii_case("all") {
            FaultModelKind::ALL.to_vec()
        } else {
            list.split(',')
                .map(|tok| {
                    FaultModelKind::parse(tok).with_context(|| {
                        format!(
                            "unknown fault model '{}' (bitflip|drift|stuckat|line|all)",
                            tok.trim()
                        )
                    })
                })
                .collect::<Result<Vec<_>>>()?
        };
        let mut acfg = crate::eval::AnalogConfig::smoke();
        acfg.base = cfg;
        acfg.kinds = kinds;
        if let Some(s) = flag(args, "span") {
            acfg.span = s.parse().context("--span")?;
        }
        if let Some(s) = flag(args, "drift_sigma_max") {
            acfg.drift_sigma_max = s.parse().context("--drift_sigma_max")?;
        }
        let res = crate::eval::campaign::run_analog(&acfg)?;
        print!("{}", res.summary());
        match flag(args, "out") {
            Some(path) => write_json_to(path, &res.to_json())?,
            None => {
                res.write_default_artifacts()?;
                println!("wrote results/BENCH_analog.json (+ repo-root snapshot)");
            }
        }
        return Ok(());
    }

    let res = crate::eval::campaign::run(&cfg)?;
    print!("{}", res.summary());
    match flag(args, "out") {
        Some(path) => write_json_to(path, &res.to_json())?,
        None => {
            res.write_default_artifacts()?;
            println!("wrote results/BENCH_robustness.json (+ repo-root snapshot)");
        }
    }
    Ok(())
}

fn cmd_drift(args: &Args) -> Result<()> {
    let profile = flag(args, "profile").unwrap_or("smoke");
    let mut cfg = crate::eval::DriftConfig::by_name(profile)
        .with_context(|| format!("unknown profile '{profile}' (smoke|full)"))?;
    if let Some(ds) = flag(args, "dataset") {
        cfg.dataset = ds.to_string();
    }
    if let Some(d) = flag(args, "d") {
        cfg.d = d.parse().context("--d")?;
    }
    if let Some(w) = flag(args, "windows") {
        cfg.windows = w.parse().context("--windows")?;
    }
    if let Some(n) = flag(args, "samples_per_window") {
        cfg.samples_per_window = n.parse().context("--samples_per_window")?;
    }
    if let Some(r) = flag(args, "rotate_frac") {
        cfg.rotate_frac = r.parse().context("--rotate_frac")?;
    }
    if let Some(s) = flag(args, "shift_scale") {
        cfg.shift_scale = s.parse().context("--shift_scale")?;
    }
    if let Some(a) = flag(args, "add_class_at") {
        cfg.add_class_at = if a.eq_ignore_ascii_case("none") {
            None
        } else {
            Some(a.parse().context("--add_class_at must be a window index or 'none'")?)
        };
    }
    if let Some(r) = flag(args, "replicas") {
        cfg.replicas = r.parse().context("--replicas")?;
    }
    if let Some(p) = flag(args, "publish_every") {
        cfg.publish_every = p.parse().context("--publish_every")?;
    }
    if let Some(s) = flag(args, "seed") {
        cfg.seed = s.parse().context("--seed")?;
    }
    let res = crate::eval::drift::run(&cfg)?;
    print!("{}", res.summary());
    match flag(args, "out") {
        Some(path) => write_json_to(path, &res.to_json())?,
        None => {
            res.write_default_artifacts()?;
            println!("wrote results/BENCH_drift.json (+ repo-root snapshot)");
        }
    }
    Ok(())
}

fn write_json_to(path: &str, v: &crate::util::json::Value) -> Result<()> {
    let path = PathBuf::from(path);
    if let Some(parent) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(&path, crate::util::json::to_string_pretty(v))?;
    println!("wrote {}", path.display());
    Ok(())
}

fn cmd_table2(args: &Args) -> Result<()> {
    let n: usize = flag(args, "n").unwrap_or("7").parse()?;
    println!("Table II — hardware efficiency ratios (LogHD ASIC / baseline), ISOLET C=26 k=2 n={n}");
    println!("{:<44} {:>12} {:>12}", "baseline / platform", "energy x", "speedup x");
    for (name, e, s) in hwmodel::table2(617, 10_000, 26, n) {
        println!("{name:<44} {e:>12.2} {s:>12.2}");
    }
    println!("paper reports: 4.06/2.19 (SparseHD ASIC), 498.1/62.6 (CPU), 24.3/6.58 (GPU)");
    Ok(())
}

/// Quick robustness probe used by tests: evaluate a method grid cell on a
/// small workbench (kept here so the binary exposes the full pipeline).
pub fn quick_cell(dataset: &str, d: usize, method: Method, bits: u32, p: f64) -> Result<f64> {
    let spec = data::spec(dataset).context("dataset")?;
    let ds = data::generate_scaled(spec, 600.min(spec.n_train), 200.min(spec.n_test));
    let opts = crate::loghd::model::TrainOptions {
        epochs: 3,
        conv_epochs: 1,
        ..Default::default()
    };
    let mut wb = Workbench::new(&ds, d, 0xE5C0DE, opts);
    wb.evaluate(method, Precision::from_bits(bits).context("bits")?, p, 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flags() {
        let a = parse_args(vec!["train".into(), "--dataset".into(), "page".into(),
            "--d=512".into(), "--native".into()]).unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.flags["dataset"], "page");
        assert_eq!(a.flags["d"], "512");
        assert_eq!(a.flags["native"], "true");
        assert!(a.positional.is_empty());
    }

    #[test]
    fn parses_positional_for_inspect() {
        let a = parse_args(vec!["inspect".into(), "models/page".into()]).unwrap();
        assert_eq!(a.command, "inspect");
        assert_eq!(a.positional, vec!["models/page".to_string()]);
    }

    #[test]
    fn rejects_positional_outside_inspect() {
        let err = run(vec!["eval".into(), "stray".into()]).unwrap_err();
        assert!(err.to_string().contains("positional"), "{err}");
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(vec!["frobnicate".into()]).is_err());
    }

    #[test]
    fn robustness_rejects_unknown_profile() {
        let err =
            run(vec!["robustness".into(), "--profile".into(), "warp".into()]).unwrap_err();
        assert!(err.to_string().contains("unknown profile"), "{err}");
    }

    #[test]
    fn drift_rejects_unknown_profile_and_bad_flags() {
        let err = run(vec!["drift".into(), "--profile".into(), "warp".into()]).unwrap_err();
        assert!(err.to_string().contains("unknown profile"), "{err}");
        let err = run(vec!["drift".into(), "--add_class_at".into(), "soon".into()]).unwrap_err();
        assert!(err.to_string().contains("add_class_at"), "{err}");
        // Override validation catches an uncrossable publish cadence.
        let err =
            run(vec!["drift".into(), "--publish_every".into(), "100000".into()]).unwrap_err();
        assert!(err.to_string().contains("publish cadences"), "{err}");
    }

    #[test]
    fn robustness_rejects_unknown_fault_model() {
        let err = run(vec![
            "robustness".into(),
            "--fault-model".into(),
            "cosmic".into(),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("unknown fault model"), "{err}");
    }

    #[test]
    fn help_and_info_run() {
        run(vec![]).unwrap();
        run(vec!["info".into()]).unwrap();
        run(vec!["table2".into()]).unwrap();
    }

    #[test]
    fn train_eval_inspect_roundtrip_via_cli() {
        let dir = std::env::temp_dir().join("loghd_cli_train");
        let bdir = std::env::temp_dir().join("loghd_cli_train_conv");
        let ddir = std::env::temp_dir().join("loghd_cli_train_deco");
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&bdir);
        let _ = std::fs::remove_dir_all(&ddir);
        run(vec![
            "train".into(),
            "--dataset".into(), "page".into(),
            "--d".into(), "256".into(),
            "--epochs".into(), "1".into(),
            "--conv_epochs".into(), "0".into(),
            "--out".into(), dir.to_str().unwrap().into(),
            "--baseline_out".into(), bdir.to_str().unwrap().into(),
            "--decohd_out".into(), ddir.to_str().unwrap().into(),
        ])
        .unwrap();
        run(vec![
            "eval".into(),
            "--model".into(), dir.to_str().unwrap().into(),
            "--bits".into(), "8".into(),
            "--p".into(), "0.1".into(),
        ])
        .unwrap();
        // eval works for every registered kind through the trait layer
        run(vec!["eval".into(), "--model".into(), ddir.to_str().unwrap().into()]).unwrap();
        // calibrate fits + persists the cascade threshold into the card...
        assert!(ModelCard::load(&dir).unwrap().cascade_threshold.is_none());
        run(vec![
            "calibrate".into(),
            "--model".into(), dir.to_str().unwrap().into(),
            "--target".into(), "0.9".into(),
            "--seed".into(), "2".into(),
        ])
        .unwrap();
        assert!(ModelCard::load(&dir).unwrap().cascade_threshold.is_some());
        // ...and refuses artifact kinds with no b1 twin to prefilter with.
        let err = run(vec![
            "calibrate".into(),
            "--model".into(), bdir.to_str().unwrap().into(),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("loghd artifact"), "{err}");
        // inspect resolves each artifact through the zoo registry
        for d in [&dir, &bdir, &ddir] {
            run(vec!["inspect".into(), d.to_str().unwrap().into()]).unwrap();
        }
        assert!(run(vec!["inspect".into()]).is_err(), "inspect needs a dir");
        // all three artifact kinds landed on disk with registry-loadable manifests
        assert_eq!(persist::load_any(&dir).unwrap().kind(), "loghd");
        assert_eq!(persist::load_any(&bdir).unwrap().kind(), "conventional");
        assert_eq!(persist::load_any(&ddir).unwrap().kind(), "decohd");
        let _ = std::fs::remove_dir_all(dir);
        let _ = std::fs::remove_dir_all(bdir);
        let _ = std::fs::remove_dir_all(ddir);
    }
}

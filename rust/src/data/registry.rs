//! Dataset registry — the Rust twin of `python/compile/data.py::SPECS`.
//!
//! Shapes follow the paper's Table I exactly (PAMAP2 train scaled
//! 611k→24k; see DESIGN.md). Difficulty constants were calibrated so
//! conventional HDC / LogHD clean accuracies land in the bands the HDC
//! literature reports for these datasets (see EXPERIMENTS.md §Datasets).

/// Shape + difficulty of one synthetic dataset.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub features: usize,
    pub classes: usize,
    pub n_train: usize,
    pub n_test: usize,
    pub groups: usize,
    pub sep_class: f64,
    pub sigma: f64,
    pub seed: u64,
    pub description: &'static str,
}

/// All Table I datasets. Constants MUST match the Python twin.
pub const SPECS: &[DatasetSpec] = &[
    DatasetSpec {
        name: "isolet",
        features: 617,
        classes: 26,
        n_train: 6238,
        n_test: 1559,
        groups: 9,
        sep_class: 0.14,
        sigma: 0.65,
        seed: 0x150_1E7,
        description: "Voice recognition (ISOLET-like)",
    },
    DatasetSpec {
        name: "ucihar",
        features: 261,
        classes: 12,
        n_train: 6213,
        n_test: 1554,
        groups: 4,
        sep_class: 0.16,
        sigma: 0.70,
        seed: 0x0C1_4A8,
        description: "Mobile activity recognition (UCIHAR-like)",
    },
    DatasetSpec {
        name: "pamap2",
        features: 75,
        classes: 5,
        n_train: 24000,
        n_test: 4000,
        groups: 2,
        sep_class: 0.26,
        sigma: 0.90,
        seed: 0x9A3_A92,
        description: "IMU activity recognition (PAMAP2-like, 611k train scaled to 24k)",
    },
    DatasetSpec {
        name: "page",
        features: 10,
        classes: 5,
        n_train: 4925,
        n_test: 548,
        groups: 2,
        sep_class: 1.00,
        sigma: 1.40,
        seed: 0x9A6_E00,
        description: "Page layout blocks (PAGE-like)",
    },
];

/// Look a spec up by name.
pub fn spec(name: &str) -> Option<&'static DatasetSpec> {
    SPECS.iter().find(|s| s.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_shapes() {
        let iso = spec("isolet").unwrap();
        assert_eq!((iso.features, iso.classes, iso.n_train, iso.n_test), (617, 26, 6238, 1559));
        let uci = spec("ucihar").unwrap();
        assert_eq!((uci.features, uci.classes), (261, 12));
        let pam = spec("pamap2").unwrap();
        assert_eq!((pam.features, pam.classes), (75, 5));
        let page = spec("page").unwrap();
        assert_eq!((page.features, page.classes, page.n_train, page.n_test), (10, 5, 4925, 548));
    }

    #[test]
    fn unknown_dataset() {
        assert!(spec("nope").is_none());
    }
}

//! CSV dataset loader.
//!
//! The synthetic generators are the default in this offline environment,
//! but a downstream user with the real UCI files can drop them in as CSV
//! (one row per sample, features then an integer label in the last
//! column) and run every harness unchanged.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Matrix;

/// Parsed CSV dataset: features (N×F) + labels (N).
#[derive(Debug, Clone)]
pub struct CsvData {
    pub x: Matrix,
    pub y: Vec<i32>,
    pub classes: usize,
}

/// Load `path`. `has_header` skips the first line.
pub fn load(path: &Path, has_header: bool) -> Result<CsvData> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse(&text, has_header)
}

/// Parse CSV text (exposed for tests).
pub fn parse(text: &str, has_header: bool) -> Result<CsvData> {
    let mut rows: Vec<Vec<f32>> = Vec::new();
    let mut labels: Vec<i32> = Vec::new();
    let mut width: Option<usize> = None;
    for (lineno, line) in text.lines().enumerate() {
        if lineno == 0 && has_header {
            continue;
        }
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        if fields.len() < 2 {
            bail!("line {}: need at least one feature and a label", lineno + 1);
        }
        let f = fields.len() - 1;
        match width {
            None => width = Some(f),
            Some(wid) if wid != f => {
                bail!("line {}: {} features, expected {}", lineno + 1, f, wid)
            }
            _ => {}
        }
        let mut row = Vec::with_capacity(f);
        for v in &fields[..f] {
            row.push(
                v.parse::<f32>()
                    .with_context(|| format!("line {}: bad feature '{v}'", lineno + 1))?,
            );
        }
        let label: i32 = fields[f]
            .parse::<f32>()
            .with_context(|| format!("line {}: bad label '{}'", lineno + 1, fields[f]))?
            as i32;
        if label < 0 {
            bail!("line {}: negative label {label}", lineno + 1);
        }
        rows.push(row);
        labels.push(label);
    }
    if rows.is_empty() {
        bail!("no data rows");
    }
    let classes = labels.iter().map(|y| *y as usize + 1).max().unwrap_or(0);
    Ok(CsvData { x: Matrix::from_rows(&rows), y: labels, classes })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic() {
        let d = parse("1.0,2.0,0\n3.0,4.0,1\n", false).unwrap();
        assert_eq!(d.x.rows(), 2);
        assert_eq!(d.x.cols(), 2);
        assert_eq!(d.y, vec![0, 1]);
        assert_eq!(d.classes, 2);
    }

    #[test]
    fn skips_header_and_blank_lines() {
        let d = parse("f1,f2,label\n1,2,0\n\n3,4,2\n", true).unwrap();
        assert_eq!(d.x.rows(), 2);
        assert_eq!(d.classes, 3);
    }

    #[test]
    fn rejects_ragged() {
        assert!(parse("1,2,0\n1,0\n", false).is_err());
    }

    #[test]
    fn rejects_bad_values() {
        assert!(parse("a,2,0\n", false).is_err());
        assert!(parse("1,2,-1\n", false).is_err());
        assert!(parse("", false).is_err());
    }
}

//! Datasets: Table I synthetic generators (Python-parity), the spec
//! registry, and a CSV loader for real data drop-ins.

pub mod csv;
pub mod registry;
pub mod synth;

pub use registry::{spec, DatasetSpec, SPECS};
pub use synth::{
    by_name, generate, generate_scaled, Dataset, DriftSpec, DriftStream, DriftWindow,
};

//! Synthetic dataset generator — the sample-for-sample twin of
//! `python/compile/data.py::generate` (see that module for the rationale
//! and the draw-order contract; both sides consume the same SplitMix64
//! stream so the materialized datasets are identical up to f32 rounding).

use super::registry::DatasetSpec;
use crate::tensor::Matrix;
use crate::util::rng::SplitMix64;

pub const SCALE_LO: f64 = 0.6;
pub const SCALE_HI: f64 = 1.4;

/// A materialized dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub spec: DatasetSpec,
    pub x_train: Matrix,
    pub y_train: Vec<i32>,
    pub x_test: Matrix,
    pub y_test: Vec<i32>,
}

fn split(
    rng: &mut SplitMix64,
    means: &Matrix,
    scales: &Matrix,
    n: usize,
    c: usize,
    f: usize,
) -> (Matrix, Vec<i32>) {
    let mut y: Vec<i32> = (0..n).map(|i| (i % c) as i32).collect();
    rng.shuffle(&mut y);
    let mut x = Matrix::zeros(n, f);
    for i in 0..n {
        let cls = y[i] as usize;
        let mrow = means.row(cls);
        let srow = scales.row(cls);
        let row = x.row_mut(i);
        for j in 0..f {
            let z = rng.normal();
            row[j] = (mrow[j] as f64 + srow[j] as f64 * z) as f32;
        }
    }
    (x, y)
}

/// Materialize a dataset; deterministic in `spec.seed`.
pub fn generate(spec: &DatasetSpec) -> Dataset {
    let mut rng = SplitMix64::new(spec.seed);
    let (c, f, g) = (spec.classes, spec.features, spec.groups);

    let mut centers = Matrix::zeros(g, f);
    for v in centers.data_mut() {
        *v = rng.normal() as f32;
    }
    // Python computes means in f64 then casts samples; mirror that by
    // keeping means in f64 precision paths below (values are small; the
    // f32 roundtrip here matches numpy's float32 output cast).
    let mut offsets = Matrix::zeros(c, f);
    for v in offsets.data_mut() {
        *v = rng.normal() as f32;
    }
    let mut means = Matrix::zeros(c, f);
    for cls in 0..c {
        let ctr = centers.row(cls % g).to_vec();
        let off = offsets.row(cls);
        let row = means.row_mut(cls);
        for j in 0..f {
            row[j] = (ctr[j] as f64 + spec.sep_class * off[j] as f64) as f32;
        }
    }
    let mut scales = Matrix::zeros(c, f);
    for v in scales.data_mut() {
        *v = (spec.sigma * (SCALE_LO + (SCALE_HI - SCALE_LO) * rng.uniform())) as f32;
    }

    let (x_train, y_train) = split(&mut rng, &means, &scales, spec.n_train, c, f);
    let (x_test, y_test) = split(&mut rng, &means, &scales, spec.n_test, c, f);
    Dataset { spec: *spec, x_train, y_train, x_test, y_test }
}

/// Generate by registry name.
pub fn by_name(name: &str) -> Option<Dataset> {
    super::registry::spec(name).map(generate)
}

/// A scaled-down variant for tests/benches: same geometry (same means,
/// scales — i.e. same leading PRNG draws), fewer samples.
pub fn generate_scaled(spec: &DatasetSpec, n_train: usize, n_test: usize) -> Dataset {
    let mut s = *spec;
    s.n_train = n_train;
    s.n_test = n_test;
    generate(&s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry;

    #[test]
    fn page_shapes_and_balance() {
        let ds = by_name("page").unwrap();
        assert_eq!(ds.x_train.rows(), 4925);
        assert_eq!(ds.x_train.cols(), 10);
        assert_eq!(ds.x_test.rows(), 548);
        let mut counts = [0usize; 5];
        for y in &ds.y_train {
            counts[*y as usize] += 1;
        }
        let max = counts.iter().max().unwrap();
        let min = counts.iter().min().unwrap();
        assert!(max - min <= 1, "{counts:?}");
    }

    #[test]
    fn deterministic() {
        let a = by_name("page").unwrap();
        let b = by_name("page").unwrap();
        assert_eq!(a.x_train.data(), b.x_train.data());
        assert_eq!(a.y_test, b.y_test);
    }

    #[test]
    fn labels_in_range() {
        let ds = generate_scaled(registry::spec("ucihar").unwrap(), 120, 40);
        assert!(ds.y_train.iter().all(|y| (0..12).contains(y)));
        assert!(ds.y_test.iter().all(|y| (0..12).contains(y)));
    }

    #[test]
    fn classes_have_distinct_means() {
        let ds = generate_scaled(registry::spec("page").unwrap(), 1000, 10);
        let c = ds.spec.classes;
        let f = ds.spec.features;
        let mut means = Matrix::zeros(c, f);
        let mut counts = vec![0f32; c];
        for i in 0..ds.x_train.rows() {
            let cls = ds.y_train[i] as usize;
            counts[cls] += 1.0;
            for (a, v) in means.row_mut(cls).iter_mut().zip(ds.x_train.row(i)) {
                *a += v;
            }
        }
        for cls in 0..c {
            for v in means.row_mut(cls) {
                *v /= counts[cls];
            }
        }
        for a in 0..c {
            for b in (a + 1)..c {
                let d = crate::tensor::sqdist(means.row(a), means.row(b));
                assert!(d > 0.1, "classes {a},{b} too close: {d}");
            }
        }
    }
}

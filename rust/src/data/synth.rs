//! Synthetic dataset generator — the sample-for-sample twin of
//! `python/compile/data.py::generate` (see that module for the rationale
//! and the draw-order contract; both sides consume the same SplitMix64
//! stream so the materialized datasets are identical up to f32 rounding).

use super::registry::DatasetSpec;
use crate::tensor::Matrix;
use crate::util::rng::SplitMix64;

pub const SCALE_LO: f64 = 0.6;
pub const SCALE_HI: f64 = 1.4;

/// A materialized dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub spec: DatasetSpec,
    pub x_train: Matrix,
    pub y_train: Vec<i32>,
    pub x_test: Matrix,
    pub y_test: Vec<i32>,
}

fn split(
    rng: &mut SplitMix64,
    means: &Matrix,
    scales: &Matrix,
    n: usize,
    c: usize,
    f: usize,
) -> (Matrix, Vec<i32>) {
    let mut y: Vec<i32> = (0..n).map(|i| (i % c) as i32).collect();
    rng.shuffle(&mut y);
    let mut x = Matrix::zeros(n, f);
    for i in 0..n {
        let cls = y[i] as usize;
        let mrow = means.row(cls);
        let srow = scales.row(cls);
        let row = x.row_mut(i);
        for j in 0..f {
            let z = rng.normal();
            row[j] = (mrow[j] as f64 + srow[j] as f64 * z) as f32;
        }
    }
    (x, y)
}

/// Materialize a dataset; deterministic in `spec.seed`.
pub fn generate(spec: &DatasetSpec) -> Dataset {
    let mut rng = SplitMix64::new(spec.seed);
    let (c, f, g) = (spec.classes, spec.features, spec.groups);

    let mut centers = Matrix::zeros(g, f);
    for v in centers.data_mut() {
        *v = rng.normal() as f32;
    }
    // Python computes means in f64 then casts samples; mirror that by
    // keeping means in f64 precision paths below (values are small; the
    // f32 roundtrip here matches numpy's float32 output cast).
    let mut offsets = Matrix::zeros(c, f);
    for v in offsets.data_mut() {
        *v = rng.normal() as f32;
    }
    let mut means = Matrix::zeros(c, f);
    for cls in 0..c {
        let ctr = centers.row(cls % g).to_vec();
        let off = offsets.row(cls);
        let row = means.row_mut(cls);
        for j in 0..f {
            row[j] = (ctr[j] as f64 + spec.sep_class * off[j] as f64) as f32;
        }
    }
    let mut scales = Matrix::zeros(c, f);
    for v in scales.data_mut() {
        *v = (spec.sigma * (SCALE_LO + (SCALE_HI - SCALE_LO) * rng.uniform())) as f32;
    }

    let (x_train, y_train) = split(&mut rng, &means, &scales, spec.n_train, c, f);
    let (x_test, y_test) = split(&mut rng, &means, &scales, spec.n_test, c, f);
    Dataset { spec: *spec, x_train, y_train, x_test, y_test }
}

/// Generate by registry name.
pub fn by_name(name: &str) -> Option<Dataset> {
    super::registry::spec(name).map(generate)
}

/// A scaled-down variant for tests/benches: same geometry (same means,
/// scales — i.e. same leading PRNG draws), fewer samples.
pub fn generate_scaled(spec: &DatasetSpec, n_train: usize, n_test: usize) -> Dataset {
    let mut s = *spec;
    s.n_train = n_train;
    s.n_test = n_test;
    generate(&s)
}

/// Controlled non-stationarity layered on the [`generate`] geometry —
/// the workload behind `loghd drift` (continual-learning campaigns).
///
/// The stream is a sequence of fixed-size windows over three drift
/// mechanisms, each individually tunable:
///
/// - **rotating class means**: every class mean interpolates from the
///   stationary [`generate`]-style geometry toward an independently
///   drawn target set (the class structure genuinely rearranges —
///   targets use permuted group centers, not a shared translation);
/// - **covariate shift**: a fixed random direction is added to *every*
///   sample, growing linearly to `shift_scale` by the last window;
/// - **class addition**: from window `add_class_at` on, one extra
///   class (label = `base.classes`) joins the label rotation.
///
/// Windows are deterministic in `(base.seed, window index)` alone:
/// materializing window 5 never requires (and is never perturbed by)
/// materializing windows 0–4.
#[derive(Debug, Clone, Copy)]
pub struct DriftSpec {
    pub base: DatasetSpec,
    pub windows: usize,
    pub samples_per_window: usize,
    /// Per-window interpolation rate toward the target means; the
    /// rotation progress at window `w` is `min(1, rotate_frac · w)`.
    pub rotate_frac: f64,
    /// Covariate-shift magnitude reached at the final window.
    pub shift_scale: f64,
    /// Window index from which the extra class emits samples.
    pub add_class_at: Option<usize>,
}

/// One materialized stream window.
#[derive(Debug, Clone)]
pub struct DriftWindow {
    pub index: usize,
    pub x: Matrix,
    pub y: Vec<i32>,
    /// Classes live in THIS window (`base.classes`, +1 once the extra
    /// class has joined).
    pub classes: usize,
    /// Rotation progress in [0, 1] applied to the class means.
    pub progress: f64,
}

/// Frozen drift geometry: start/target means, per-class scales, and
/// the covariate-shift direction, all drawn once from `base.seed`.
#[derive(Debug, Clone)]
pub struct DriftStream {
    spec: DriftSpec,
    means0: Matrix,
    means1: Matrix,
    scales: Matrix,
    shift_dir: Vec<f32>,
    window_seed: u64,
}

impl DriftStream {
    pub fn new(spec: DriftSpec) -> Self {
        assert!(spec.windows >= 2, "a drift stream needs at least 2 windows");
        assert!(spec.samples_per_window > 0, "windows must be non-empty");
        let (c, f, g) = (spec.base.classes, spec.base.features, spec.base.groups);
        // One extra row everywhere: the geometry always carries the
        // future class so enabling `add_class_at` never re-draws the
        // base classes.
        let total = c + 1;
        let mut rng = SplitMix64::new(spec.base.seed ^ 0xD21F_75EA);

        let mut centers = Matrix::zeros(g, f);
        for v in centers.data_mut() {
            *v = rng.normal() as f32;
        }
        let draw_means = |rng: &mut SplitMix64, rotate: usize| {
            let mut offsets = Matrix::zeros(total, f);
            for v in offsets.data_mut() {
                *v = rng.normal() as f32;
            }
            let mut means = Matrix::zeros(total, f);
            for cls in 0..total {
                let ctr = centers.row((cls + rotate) % g).to_vec();
                let off = offsets.row(cls);
                let row = means.row_mut(cls);
                for j in 0..f {
                    row[j] = (ctr[j] as f64 + spec.base.sep_class * off[j] as f64) as f32;
                }
            }
            means
        };
        let means0 = draw_means(&mut rng, 0);
        // The target set hangs off *rotated* group centers, so full
        // progress is a genuine rearrangement of the class layout.
        let means1 = draw_means(&mut rng, 1);
        let mut scales = Matrix::zeros(total, f);
        for v in scales.data_mut() {
            *v = (spec.base.sigma * (SCALE_LO + (SCALE_HI - SCALE_LO) * rng.uniform())) as f32;
        }
        let shift_dir: Vec<f32> = (0..f).map(|_| rng.normal() as f32).collect();
        let window_seed = rng.next_u64();
        Self { spec, means0, means1, scales, shift_dir, window_seed }
    }

    pub fn spec(&self) -> &DriftSpec {
        &self.spec
    }

    /// Classes live in window `w`.
    pub fn classes_at(&self, w: usize) -> usize {
        let c = self.spec.base.classes;
        match self.spec.add_class_at {
            Some(at) if w >= at => c + 1,
            _ => c,
        }
    }

    /// Materialize window `w` — deterministic in `(base.seed, w)`.
    pub fn window(&self, w: usize) -> DriftWindow {
        assert!(w < self.spec.windows, "window {w} out of range 0..{}", self.spec.windows);
        let f = self.spec.base.features;
        let classes = self.classes_at(w);
        let progress = (self.spec.rotate_frac * w as f64).min(1.0);
        let mut means = Matrix::zeros(classes, f);
        for cls in 0..classes {
            let a = self.means0.row(cls);
            let b = self.means1.row(cls);
            let row = means.row_mut(cls);
            for j in 0..f {
                row[j] = ((1.0 - progress) * a[j] as f64 + progress * b[j] as f64) as f32;
            }
        }
        let mut rng = SplitMix64::new(self.window_seed).fork(w as u64 + 1);
        let (mut x, y) =
            split(&mut rng, &means, &self.scales, self.spec.samples_per_window, classes, f);
        // Covariate shift: one global direction, ramped over the stream.
        let ramp = self.spec.shift_scale * w as f64 / (self.spec.windows - 1) as f64;
        if ramp != 0.0 {
            for i in 0..x.rows() {
                let row = x.row_mut(i);
                for j in 0..f {
                    row[j] = (row[j] as f64 + ramp * self.shift_dir[j] as f64) as f32;
                }
            }
        }
        DriftWindow { index: w, x, y, classes, progress }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry;

    #[test]
    fn page_shapes_and_balance() {
        let ds = by_name("page").unwrap();
        assert_eq!(ds.x_train.rows(), 4925);
        assert_eq!(ds.x_train.cols(), 10);
        assert_eq!(ds.x_test.rows(), 548);
        let mut counts = [0usize; 5];
        for y in &ds.y_train {
            counts[*y as usize] += 1;
        }
        let max = counts.iter().max().unwrap();
        let min = counts.iter().min().unwrap();
        assert!(max - min <= 1, "{counts:?}");
    }

    #[test]
    fn deterministic() {
        let a = by_name("page").unwrap();
        let b = by_name("page").unwrap();
        assert_eq!(a.x_train.data(), b.x_train.data());
        assert_eq!(a.y_test, b.y_test);
    }

    #[test]
    fn labels_in_range() {
        let ds = generate_scaled(registry::spec("ucihar").unwrap(), 120, 40);
        assert!(ds.y_train.iter().all(|y| (0..12).contains(y)));
        assert!(ds.y_test.iter().all(|y| (0..12).contains(y)));
    }

    fn drift_spec(rotate: f64, shift: f64, add_at: Option<usize>) -> DriftSpec {
        DriftSpec {
            base: *registry::spec("page").unwrap(),
            windows: 6,
            samples_per_window: 120,
            rotate_frac: rotate,
            shift_scale: shift,
            add_class_at: add_at,
        }
    }

    fn class_mean(w: &DriftWindow, cls: i32) -> Vec<f64> {
        let f = w.x.cols();
        let mut acc = vec![0f64; f];
        let mut n = 0f64;
        for i in 0..w.x.rows() {
            if w.y[i] == cls {
                n += 1.0;
                for (a, v) in acc.iter_mut().zip(w.x.row(i)) {
                    *a += *v as f64;
                }
            }
        }
        acc.iter().map(|a| a / n.max(1.0)).collect()
    }

    #[test]
    fn drift_windows_are_deterministic_and_order_free() {
        let s1 = DriftStream::new(drift_spec(0.3, 0.5, Some(3)));
        let s2 = DriftStream::new(drift_spec(0.3, 0.5, Some(3)));
        // Same window from two streams, and out-of-order access on one
        // stream, all agree bit-for-bit.
        let a = s1.window(4);
        let _ = s1.window(0);
        let b = s1.window(4);
        let c = s2.window(4);
        assert_eq!(a.x.data(), b.x.data());
        assert_eq!(a.x.data(), c.x.data());
        assert_eq!(a.y, c.y);
        // ... and distinct windows differ.
        assert_ne!(s1.window(1).x.data(), s1.window(2).x.data());
    }

    #[test]
    fn drift_adds_exactly_one_class_at_the_configured_window() {
        let s = DriftStream::new(drift_spec(0.2, 0.0, Some(3)));
        for w in 0..6 {
            let win = s.window(w);
            let expect = if w >= 3 { 6 } else { 5 };
            assert_eq!(win.classes, expect, "window {w}");
            assert_eq!(s.classes_at(w), expect);
            assert!(win.y.iter().all(|y| (0..expect as i32).contains(y)), "window {w}");
            if w >= 3 {
                assert!(win.y.contains(&5), "new class must actually emit samples");
            }
        }
        // No add_class_at: the class count never moves.
        let frozen = DriftStream::new(drift_spec(0.2, 0.0, None));
        assert_eq!(frozen.window(5).classes, 5);
    }

    #[test]
    fn rotation_moves_class_means_and_zero_drift_is_stationary() {
        let s = DriftStream::new(drift_spec(0.5, 0.0, None));
        let first = class_mean(&s.window(0), 0);
        let last = class_mean(&s.window(5), 0);
        let moved: f64 =
            first.iter().zip(&last).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        assert!(moved > 0.5, "class-0 mean only moved {moved}");
        // rotate_frac = 0 and shift = 0: every window shares the class
        // geometry (empirical means stay close across the stream).
        let flat = DriftStream::new(drift_spec(0.0, 0.0, None));
        let a = class_mean(&flat.window(0), 0);
        let b = class_mean(&flat.window(5), 0);
        let still: f64 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt();
        assert!(still < moved / 2.0, "stationary stream moved {still} vs drifted {moved}");
    }

    #[test]
    fn covariate_shift_translates_every_class_the_same_way() {
        let spec = drift_spec(0.0, 2.0, None);
        let shifted = DriftStream::new(spec);
        let deltas: Vec<Vec<f64>> = (0..2)
            .map(|cls| {
                let a = class_mean(&shifted.window(0), cls);
                let b = class_mean(&shifted.window(5), cls);
                a.iter().zip(&b).map(|(x, y)| y - x).collect()
            })
            .collect();
        let norm: f64 = deltas[0].iter().map(|d| d * d).sum::<f64>().sqrt();
        assert!(norm > 0.5, "shift barely moved the data: {norm}");
        // Both classes translate along (approximately) the same vector.
        let dot: f64 = deltas[0].iter().zip(&deltas[1]).map(|(a, b)| a * b).sum();
        let n1: f64 = deltas[1].iter().map(|d| d * d).sum::<f64>().sqrt();
        assert!(dot / (norm * n1) > 0.8, "classes shifted in different directions");
    }

    #[test]
    fn classes_have_distinct_means() {
        let ds = generate_scaled(registry::spec("page").unwrap(), 1000, 10);
        let c = ds.spec.classes;
        let f = ds.spec.features;
        let mut means = Matrix::zeros(c, f);
        let mut counts = vec![0f32; c];
        for i in 0..ds.x_train.rows() {
            let cls = ds.y_train[i] as usize;
            counts[cls] += 1.0;
            for (a, v) in means.row_mut(cls).iter_mut().zip(ds.x_train.row(i)) {
                *a += v;
            }
        }
        for cls in 0..c {
            for v in means.row_mut(cls) {
                *v /= counts[cls];
            }
        }
        for a in 0..c {
            for b in (a + 1)..c {
                let d = crate::tensor::sqdist(means.row(a), means.row(b));
                assert!(d > 0.1, "classes {a},{b} too close: {d}");
            }
        }
    }
}

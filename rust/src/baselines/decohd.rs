//! DecoHD-style decomposed classification (Yun et al., 2025) — the
//! class-axis baseline that proves the model-core abstraction.
//!
//! DecoHD's idea, transplanted to this stack's post-training setting:
//! instead of storing one prototype per class (O(C·D)) or LogHD's
//! codebook bundles, store a small shared **basis** of r hypervectors
//! (r ≤ C, typically r ≈ ⌈log₂ C⌉) plus per-class **coefficients** over
//! that basis — the class weights are *decomposed* through a shared
//! dictionary, O(r·D + C·r), the same asymptotic shape as LogHD with a
//! learned rather than coded mixing matrix.
//!
//! Construction is deterministic truncated PCA of the prototype matrix
//! through its C×C Gram matrix (cyclic Jacobi eigendecomposition — C is
//! tiny, so this costs microseconds and needs no LAPACK): the top-r
//! eigenvectors give an orthonormal basis of the best rank-r subspace
//! (Eckart–Young), and row-normalized coefficients make the
//! reconstructed class vectors unit — so clean scores are exactly the
//! cosine scores of the conventional baseline against its rank-r
//! projection.
//!
//! The family registers once in [`crate::model::zoo`] and is thereby
//! servable (`loghd serve`), persistable (kind `native-decohd`),
//! inspectable (`loghd inspect`), and evaluable in equal-memory fault
//! campaigns (`Method::DecoHd`, `loghd robustness --decohd true`) —
//! with no per-subsystem wiring. Fault surface: the basis plane and the
//! coefficient plane (see `model::instances::decohd`).

use anyhow::{bail, Result};

use crate::hd::similarity::activations;
use crate::loghd::codebook::min_bundles;
use crate::tensor::{self, Matrix};

/// A DecoHD model: shared basis + per-class mixing coefficients.
#[derive(Debug, Clone)]
pub struct DecoHdModel {
    /// (r, D) orthonormal basis rows spanning the prototype subspace.
    pub basis: Matrix,
    /// (C, r) per-class coefficients, unit rows (so reconstructed class
    /// vectors are unit and scores are cosine-scaled).
    pub coeffs: Matrix,
}

impl DecoHdModel {
    /// Decompose trained (unit-row) prototypes at `rank` basis vectors.
    pub fn from_prototypes(h: &Matrix, rank: usize) -> Result<Self> {
        let classes = h.rows();
        if classes == 0 || h.cols() == 0 {
            bail!("cannot decompose an empty prototype matrix");
        }
        if rank == 0 || rank > classes {
            bail!("decohd rank must be in 1..=C (= {classes}), got {rank}");
        }
        // Gram matrix G = H·Hᵀ (C×C): eigenvectors of G are the left
        // singular vectors of H, so U_rᵀ·H spans the best rank-r
        // subspace of the class vectors.
        let gram = tensor::matmul_nt(h, h);
        let (eigvals, eigvecs) = jacobi_eigh(&gram);
        let mut order: Vec<usize> = (0..classes).collect();
        order.sort_by(|&a, &b| eigvals[b].total_cmp(&eigvals[a]));

        let d = h.cols();
        let mut basis = Matrix::zeros(rank, d);
        for (i, &ei) in order.iter().take(rank).enumerate() {
            let row = basis.row_mut(i);
            for c in 0..classes {
                let u = eigvecs[c * classes + ei] as f32;
                if u != 0.0 {
                    tensor::axpy(u, h.row(c), row);
                }
            }
        }
        tensor::normalize_rows(&mut basis);
        let mut coeffs = tensor::matmul_nt(h, &basis);
        tensor::normalize_rows(&mut coeffs);
        Ok(Self { basis, coeffs })
    }

    pub fn classes(&self) -> usize {
        self.coeffs.rows()
    }

    pub fn d(&self) -> usize {
        self.basis.cols()
    }

    /// Basis size r.
    pub fn rank(&self) -> usize {
        self.basis.rows()
    }

    /// Per-class decision scores (B, C): cosine activations against the
    /// basis, mixed through the coefficients — equal to cosine scores
    /// against the (unit) rank-r reconstructed class vectors.
    pub fn scores(&self, enc: &Matrix) -> Matrix {
        tensor::matmul_nt(&activations(enc, &self.basis), &self.coeffs)
    }

    /// Argmax labels.
    pub fn predict(&self, enc: &Matrix) -> Vec<i32> {
        let s = self.scores(enc);
        (0..s.rows()).map(|i| tensor::argmax(s.row(i)) as i32).collect()
    }

    /// Stored values: r·D basis + C·r coefficients — one term of the
    /// shared accounting the campaign solver uses.
    pub fn memory_floats(&self) -> usize {
        self.rank() * self.d() + self.classes() * self.rank()
    }

    /// Fraction of the conventional C·D footprint.
    pub fn budget_fraction(&self) -> f64 {
        self.memory_floats() as f64 / (self.classes() * self.d()) as f64
    }
}

/// The default rank for C classes: ⌈log₂ C⌉ clamped to [1, C] — the
/// same bundle-count scale LogHD's codebook needs, so the two class-axis
/// families land in comparable memory regimes out of the box.
pub fn default_rank(classes: usize) -> usize {
    min_bundles(classes, 2).clamp(1, classes.max(1))
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix given as a
/// row-major (n, n) [`Matrix`]. Returns `(eigenvalues, eigenvectors)`
/// with eigenvectors stored column-major-by-index in a flat row-major
/// n×n array: `eigvecs[i * n + j]` is component i of eigenvector j.
/// Deterministic (fixed sweep order, no randomness); n is the class
/// count here, so cost is negligible.
fn jacobi_eigh(m: &Matrix) -> (Vec<f64>, Vec<f64>) {
    let n = m.rows();
    assert_eq!(n, m.cols(), "jacobi_eigh needs a square matrix");
    let mut a: Vec<f64> = m.data().iter().map(|v| *v as f64).collect();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let scale: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-300);
    for _sweep in 0..64 {
        let off: f64 = (0..n)
            .flat_map(|p| ((p + 1)..n).map(move |q| (p, q)))
            .map(|(p, q)| a[p * n + q] * a[p * n + q])
            .sum();
        if off.sqrt() <= 1e-14 * scale {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[p * n + q];
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let app = a[p * n + p];
                let aqq = a[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                for i in 0..n {
                    let aip = a[i * n + p];
                    let aiq = a[i * n + q];
                    a[i * n + p] = c * aip - s * aiq;
                    a[i * n + q] = s * aip + c * aiq;
                }
                for j in 0..n {
                    let apj = a[p * n + j];
                    let aqj = a[q * n + j];
                    a[p * n + j] = c * apj - s * aqj;
                    a[q * n + j] = s * apj + c * aqj;
                }
                for i in 0..n {
                    let vip = v[i * n + p];
                    let viq = v[i * n + q];
                    v[i * n + p] = c * vip - s * viq;
                    v[i * n + q] = s * vip + c * viq;
                }
            }
        }
    }
    let eigvals: Vec<f64> = (0..n).map(|i| a[i * n + i]).collect();
    (eigvals, v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    fn unit_prototypes(c: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = SplitMix64::new(seed);
        let mut h = Matrix::from_vec(c, d, rng.normals_f32(c * d));
        tensor::normalize_rows(&mut h);
        h
    }

    #[test]
    fn jacobi_recovers_a_known_spectrum() {
        // diag(3, 1) rotated by 45°: eigenvalues {3, 1}.
        let r = std::f32::consts::FRAC_1_SQRT_2;
        let q = Matrix::from_vec(2, 2, vec![r, -r, r, r]);
        let lam = Matrix::from_vec(2, 2, vec![3.0, 0.0, 0.0, 1.0]);
        let m = tensor::matmul_nt(&tensor::matmul(&q, &lam), &q);
        let (mut vals, _) = jacobi_eigh(&m);
        vals.sort_by(|a, b| b.total_cmp(a));
        assert!((vals[0] - 3.0).abs() < 1e-5, "{vals:?}");
        assert!((vals[1] - 1.0).abs() < 1e-5, "{vals:?}");
    }

    #[test]
    fn basis_rows_are_orthonormal() {
        let h = unit_prototypes(6, 128, 1);
        let m = DecoHdModel::from_prototypes(&h, 3).unwrap();
        let g = tensor::matmul_nt(&m.basis, &m.basis);
        for i in 0..3 {
            for j in 0..3 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((g.at(i, j) - want).abs() < 1e-4, "G[{i}][{j}] = {}", g.at(i, j));
            }
        }
    }

    #[test]
    fn full_rank_matches_conventional_scores() {
        // At r = C the decomposition is exact: scores equal the cosine
        // activations of the original unit prototypes.
        let h = unit_prototypes(5, 96, 2);
        let m = DecoHdModel::from_prototypes(&h, 5).unwrap();
        let mut rng = SplitMix64::new(7);
        let enc = Matrix::from_vec(8, 96, rng.normals_f32(8 * 96));
        let got = m.scores(&enc);
        let want = activations(&enc, &h);
        for (a, b) in got.data().iter().zip(want.data()) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn truncation_compresses_and_still_classifies() {
        let ds = crate::data::generate_scaled(crate::data::spec("page").unwrap(), 500, 150);
        let opts = crate::loghd::model::TrainOptions {
            epochs: 0,
            conv_epochs: 1,
            ..Default::default()
        };
        let stack = crate::loghd::model::TrainedStack::train(
            &ds.x_train,
            &ds.y_train,
            5,
            256,
            0xE5C0DE,
            &opts,
        )
        .unwrap();
        let enc_test = stack.encoder.encode(&ds.x_test);
        let conv_acc = {
            let pred =
                crate::baselines::ConventionalModel::new(stack.prototypes.clone()).predict(&enc_test);
            crate::eval::accuracy(&pred, &ds.y_test)
        };
        let m = DecoHdModel::from_prototypes(&stack.prototypes, 3).unwrap();
        let acc = crate::eval::accuracy(&m.predict(&enc_test), &ds.y_test);
        assert!(m.memory_floats() < 5 * 256, "no compression: {}", m.memory_floats());
        assert!((m.budget_fraction() - (3.0 * (256.0 + 5.0)) / (5.0 * 256.0)).abs() < 1e-12);
        assert!(acc > conv_acc - 0.15, "rank-3 decohd collapsed: {acc} vs conv {conv_acc}");
    }

    #[test]
    fn rank_validation_and_default() {
        let h = unit_prototypes(5, 32, 3);
        assert!(DecoHdModel::from_prototypes(&h, 0).is_err());
        assert!(DecoHdModel::from_prototypes(&h, 6).is_err());
        assert_eq!(default_rank(5), 3); // ceil(log2 5)
        assert_eq!(default_rank(2), 1);
        assert_eq!(default_rank(26), 5);
        assert_eq!(default_rank(1), 1);
    }
}

//! Hybrid class- + feature-axis compression (paper §IV-D, Fig. 6):
//! LogHD bundles sparsified with a SparseHD-style dimension mask.
//!
//! The mask is derived from the *bundle* matrix (the stored state), the
//! masked bundles are re-normalized, and the activation profiles are
//! recomputed on the training set so decoding matches the masked
//! geometry. Memory: n·(1−S)·D + C·n, i.e. budget ≈ n(1−S)/C.

use anyhow::Result;

use crate::baselines::sparsehd::build_mask;
use crate::loghd::model::LogHdModel;
use crate::loghd::profiles::compute_profiles;
use crate::tensor::{self, Matrix};

/// Hybrid model: a LogHD model whose bundles carry a dimension mask.
#[derive(Debug, Clone)]
pub struct HybridModel {
    pub inner: LogHdModel,
    pub mask: Vec<bool>,
    pub sparsity: f64,
}

impl HybridModel {
    /// Sparsify a trained LogHD model at sparsity S, refreshing profiles
    /// on the (encoded, centered) training set.
    pub fn from_loghd(
        loghd: &LogHdModel,
        enc_train: &Matrix,
        y_train: &[i32],
        sparsity: f64,
    ) -> Result<Self> {
        let mask = build_mask(&loghd.bundles, sparsity);
        let mut bundles = loghd.bundles.clone();
        for r in 0..bundles.rows() {
            for (v, keep) in bundles.row_mut(r).iter_mut().zip(&mask) {
                if !keep {
                    *v = 0.0;
                }
            }
        }
        tensor::normalize_rows(&mut bundles);
        let profiles = compute_profiles(enc_train, y_train, &bundles, loghd.classes);
        let inner = LogHdModel {
            classes: loghd.classes,
            d: loghd.d,
            book: loghd.book.clone(),
            bundles,
            profiles,
        };
        Ok(Self { inner, mask, sparsity })
    }

    pub fn predict(&self, enc: &Matrix) -> Vec<i32> {
        self.inner.predict(enc)
    }

    pub fn retained(&self) -> usize {
        self.mask.iter().filter(|m| **m).count()
    }

    /// Stored values: n bundles over the retained coordinates plus the
    /// profiles in their deviations+mean stored form — the same
    /// [`crate::model::loghd_stored_values`] rule the equal-memory
    /// campaign solver budgets with.
    pub fn memory_floats(&self) -> usize {
        crate::model::loghd_stored_values(
            self.inner.n_bundles(),
            self.retained(),
            self.inner.classes,
        )
    }

    /// Fraction of the conventional C*D footprint.
    pub fn budget_fraction(&self) -> f64 {
        self.memory_floats() as f64 / (self.inner.classes * self.inner.d) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::loghd::model::{TrainOptions, TrainedStack};

    fn stack() -> (data::Dataset, TrainedStack) {
        let ds = data::generate_scaled(data::spec("page").unwrap(), 600, 200);
        let opts = TrainOptions { epochs: 3, conv_epochs: 1, extra_bundles: 1, ..Default::default() };
        let st = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 256, 0xE5C0DE, &opts).unwrap();
        (ds, st)
    }

    #[test]
    fn hybrid_reduces_memory_below_loghd() {
        let (ds, st) = stack();
        let mut enc = st.encoder.encode(&ds.x_train);
        let _ = &mut enc;
        let hybrid = HybridModel::from_loghd(&st.loghd, &enc, &ds.y_train, 0.5).unwrap();
        assert!(hybrid.memory_floats() < st.loghd.memory_floats());
        assert!(hybrid.budget_fraction() < st.loghd.budget_fraction());
    }

    #[test]
    fn moderate_sparsity_keeps_accuracy_reasonable() {
        let (ds, st) = stack();
        let enc_train = st.encoder.encode(&ds.x_train);
        let enc_test = st.encoder.encode(&ds.x_test);
        let base_preds = st.loghd.predict(&enc_test);
        let base_acc = base_preds.iter().zip(&ds.y_test).filter(|(p, y)| p == y).count() as f64
            / ds.y_test.len() as f64;
        let hybrid = HybridModel::from_loghd(&st.loghd, &enc_train, &ds.y_train, 0.3).unwrap();
        let preds = hybrid.predict(&enc_test);
        let acc = preds.iter().zip(&ds.y_test).filter(|(p, y)| p == y).count() as f64
            / ds.y_test.len() as f64;
        assert!(acc > base_acc - 0.15, "hybrid acc {acc} vs base {base_acc}");
    }

    #[test]
    fn masked_bundles_are_zero_on_pruned_dims() {
        let (ds, st) = stack();
        let enc_train = st.encoder.encode(&ds.x_train);
        let hybrid = HybridModel::from_loghd(&st.loghd, &enc_train, &ds.y_train, 0.7).unwrap();
        for r in 0..hybrid.inner.bundles.rows() {
            for (v, keep) in hybrid.inner.bundles.row(r).iter().zip(&hybrid.mask) {
                if !keep {
                    assert_eq!(*v, 0.0);
                }
            }
        }
    }
}

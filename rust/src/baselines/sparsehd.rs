//! SparseHD (Imani et al., FCCM'19) — the feature-axis baseline.
//!
//! Dimension-wise sparsification: rank hypervector dimensions by
//! cross-class discriminability (variance of the prototype matrix along
//! each dimension), keep the top (1−S)·D, zero the rest, and re-normalize
//! prototype rows over the retained coordinates. Memory is (1−S)·C·D
//! values (plus an index bitmap the paper, like us, excludes from the
//! budget accounting).

use crate::hd::similarity::activations;
use crate::tensor::{self, Matrix};

/// SparseHD model: masked prototypes + the retained-dimension mask.
#[derive(Debug, Clone)]
pub struct SparseHdModel {
    pub prototypes: Matrix, // (C, D), zeros on pruned dims, unit rows
    pub mask: Vec<bool>,    // true = retained
    pub sparsity: f64,      // S: fraction pruned
}

/// Saliency: variance of prototype values along each dimension (f64).
pub fn dimension_saliency(h: &Matrix) -> Vec<f64> {
    let (c, d) = (h.rows(), h.cols());
    let mut mean = vec![0.0f64; d];
    for r in 0..c {
        for (m, v) in mean.iter_mut().zip(h.row(r)) {
            *m += *v as f64;
        }
    }
    for m in mean.iter_mut() {
        *m /= c as f64;
    }
    let mut var = vec![0.0f64; d];
    for r in 0..c {
        for ((vv, v), m) in var.iter_mut().zip(h.row(r)).zip(&mean) {
            let dlt = *v as f64 - *m;
            *vv += dlt * dlt;
        }
    }
    for vv in var.iter_mut() {
        *vv /= c as f64;
    }
    var
}

/// Retained dimensions at sparsity S — the one rounding rule shared by
/// [`build_mask`] and the equal-memory budget accounting
/// (`eval::campaign::stored_bits`); if they ever diverged, "equal
/// memory" cells would stop being equal memory.
pub fn retained_dims(d: usize, sparsity: f64) -> usize {
    ((1.0 - sparsity) * d as f64).round().max(1.0) as usize
}

/// Build the retained-dimension mask for sparsity S (stable top-k).
pub fn build_mask(h: &Matrix, sparsity: f64) -> Vec<bool> {
    assert!((0.0..1.0).contains(&sparsity), "sparsity {sparsity} out of [0,1)");
    let d = h.cols();
    let keep = retained_dims(d, sparsity);
    let sal = dimension_saliency(h);
    let mut order: Vec<usize> = (0..d).collect();
    // stable sort descending by saliency (ties keep original order,
    // matching numpy's stable argsort in the Python twin)
    order.sort_by(|&a, &b| sal[b].partial_cmp(&sal[a]).unwrap_or(std::cmp::Ordering::Equal));
    let mut mask = vec![false; d];
    for &i in order.iter().take(keep) {
        mask[i] = true;
    }
    mask
}

impl SparseHdModel {
    /// Sparsify trained prototypes at sparsity S.
    pub fn from_prototypes(h: &Matrix, sparsity: f64) -> Self {
        let mask = build_mask(h, sparsity);
        let mut pruned = h.clone();
        for r in 0..pruned.rows() {
            for (v, keep) in pruned.row_mut(r).iter_mut().zip(&mask) {
                if !keep {
                    *v = 0.0;
                }
            }
        }
        tensor::normalize_rows(&mut pruned);
        Self { prototypes: pruned, mask, sparsity }
    }

    pub fn classes(&self) -> usize {
        self.prototypes.rows()
    }

    /// Retained dimensions (1−S)·D.
    pub fn retained(&self) -> usize {
        self.mask.iter().filter(|m| **m).count()
    }

    /// Cosine scores. The query is used in full: pruned model coordinates
    /// are zero so they contribute nothing, and the shared query norm does
    /// not move the argmax (see L2 docstring).
    pub fn scores(&self, enc: &Matrix) -> Matrix {
        activations(enc, &self.prototypes)
    }

    pub fn predict(&self, enc: &Matrix) -> Vec<i32> {
        let s = self.scores(enc);
        (0..s.rows()).map(|i| tensor::argmax(s.row(i)) as i32).collect()
    }

    /// Stored values: retained * C (the paper's budget accounting).
    pub fn memory_floats(&self) -> usize {
        self.retained() * self.classes()
    }

    /// Budget fraction of the conventional C*D footprint = 1 - S.
    pub fn budget_fraction(&self) -> f64 {
        self.retained() as f64 / self.mask.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn mask_keeps_highest_variance_dims() {
        // dim1 varies across classes, dim0/2 constant
        let h = Matrix::from_vec(3, 3, vec![0.5, 1.0, 0.1, 0.5, -1.0, 0.1, 0.5, 0.0, 0.1]);
        let mask = build_mask(&h, 0.66);
        assert_eq!(mask, vec![false, true, false]);
    }

    #[test]
    fn retained_count_matches_sparsity() {
        let mut rng = SplitMix64::new(1);
        let h = Matrix::from_vec(5, 100, rng.normals_f32(500));
        for s in [0.0, 0.3, 0.7, 0.9] {
            let m = SparseHdModel::from_prototypes(&h, s);
            assert_eq!(m.retained(), ((1.0 - s) * 100.0).round() as usize);
            assert!((m.budget_fraction() - (1.0 - s)).abs() < 0.011);
        }
    }

    #[test]
    fn pruned_rows_are_unit_over_retained() {
        let mut rng = SplitMix64::new(2);
        let h = Matrix::from_vec(4, 64, rng.normals_f32(256));
        let m = SparseHdModel::from_prototypes(&h, 0.5);
        for r in 0..4 {
            assert!((tensor::norm(m.prototypes.row(r)) - 1.0).abs() < 1e-5);
            // zeros exactly on pruned dims
            for (v, keep) in m.prototypes.row(r).iter().zip(&m.mask) {
                if !keep {
                    assert_eq!(*v, 0.0);
                }
            }
        }
    }

    #[test]
    fn zero_sparsity_equals_conventional() {
        let mut rng = SplitMix64::new(3);
        let mut h = Matrix::from_vec(3, 32, rng.normals_f32(96));
        tensor::normalize_rows(&mut h);
        let m = SparseHdModel::from_prototypes(&h, 0.0);
        let q = Matrix::from_vec(2, 32, rng.normals_f32(64));
        let a = m.scores(&q);
        let b = activations(&q, &h);
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() < 1e-5);
        }
    }
}

//! Baselines the paper evaluates against, plus the hybrid composition:
//! conventional HDC (O(CD)), SparseHD (feature axis), and
//! LogHD+SparseHD (hybrid, §IV-D).

pub mod conventional;
pub mod hybrid;
pub mod sparsehd;

pub use conventional::ConventionalModel;
pub use hybrid::HybridModel;
pub use sparsehd::SparseHdModel;

//! Baselines the paper evaluates against, plus the hybrid composition:
//! conventional HDC (O(CD)), SparseHD (feature axis), LogHD+SparseHD
//! (hybrid, §IV-D), and the DecoHD-style decomposed class-weight
//! classifier (class axis, follow-up work).

pub mod conventional;
pub mod decohd;
pub mod hybrid;
pub mod sparsehd;

pub use conventional::ConventionalModel;
pub use decohd::DecoHdModel;
pub use hybrid::HybridModel;
pub use sparsehd::SparseHdModel;

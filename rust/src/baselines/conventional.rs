//! Conventional HDC classifier (paper §III-A): one prototype per class,
//! cosine argmax. The O(C·D) baseline every compression method is
//! measured against.

use crate::hd::similarity::{activations, activations_with};
use crate::tensor::{self, Matrix, NtPrepared};

/// Conventional model: (C, D) unit-row prototype matrix.
#[derive(Debug, Clone)]
pub struct ConventionalModel {
    pub prototypes: Matrix,
}

impl ConventionalModel {
    pub fn new(prototypes: Matrix) -> Self {
        Self { prototypes }
    }

    pub fn classes(&self) -> usize {
        self.prototypes.rows()
    }

    pub fn d(&self) -> usize {
        self.prototypes.cols()
    }

    /// Cosine scores (B, C).
    pub fn scores(&self, enc: &Matrix) -> Matrix {
        activations(enc, &self.prototypes)
    }

    /// Argmax labels.
    pub fn predict(&self, enc: &Matrix) -> Vec<i32> {
        let s = self.scores(enc);
        (0..s.rows()).map(|i| tensor::argmax(s.row(i)) as i32).collect()
    }

    /// The prepared GEMM form of the prototype matrix for serving
    /// (build once next to the model; C typically sits in the mid-width
    /// regime, so this hoists the per-batch transposed copy).
    pub fn prepare(&self) -> NtPrepared {
        NtPrepared::for_operand(&self.prototypes)
    }

    /// [`Self::predict`] over the prepared operand from
    /// [`Self::prepare`] — identical math, per-batch prep hoisted.
    pub fn predict_prepared(&self, enc: &Matrix, prep: &NtPrepared) -> Vec<i32> {
        let s = activations_with(enc, &self.prototypes, prep);
        (0..s.rows()).map(|i| tensor::argmax(s.row(i)) as i32).collect()
    }

    /// [`Self::predict_prepared`] writing the score matrix and labels
    /// into caller-owned scratch — the zero-allocation serving form.
    pub fn predict_prepared_into(
        &self,
        enc: &Matrix,
        prep: &NtPrepared,
        scores: &mut Matrix,
        labels: &mut Vec<i32>,
    ) {
        crate::hd::similarity::activations_with_into(enc, &self.prototypes, prep, scores);
        labels.clear();
        labels.extend((0..scores.rows()).map(|i| tensor::argmax(scores.row(i)) as i32));
    }

    /// Stored floats: C*D.
    pub fn memory_floats(&self) -> usize {
        self.classes() * self.d()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predicts_nearest_prototype() {
        let h = Matrix::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let model = ConventionalModel::new(h);
        let q = Matrix::from_vec(2, 2, vec![0.9, 0.1, -0.2, 2.0]);
        assert_eq!(model.predict(&q), vec![0, 1]);
        assert_eq!(model.memory_floats(), 4);
    }
}

//! Dynamic batcher + request lifecycle.
//!
//! Policy (vLLM-router-like, scaled to this problem): a bounded pending
//! queue (backpressure: `submit` rejects when full); each worker replica
//! drains up to `max_batch` requests, waiting at most `max_delay` past the
//! oldest request's arrival to fill the batch — the knob that trades p99
//! latency against PJRT dispatch amortization (the batcher bench sweeps it).
//!
//! A [`Coordinator`] may run **several worker replicas** over the same
//! queue ([`Coordinator::start_pool`]): each replica owns its own engine
//! instance and pulls the next ready batch (shard) in arrival order, so
//! dispatch is round-robin across idle replicas and degrades to
//! least-loaded under skew. [`Coordinator::reload`] hot-swaps every
//! replica's engine between batches without dropping queued or in-flight
//! requests (generation-counted factory handoff).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::tensor::Matrix;

use super::conn::Protocol;
use super::stats::{StatsCollector, StatsSnapshot};
use super::worker::EngineFactory;
use super::InferScratch;

/// Batching configuration.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_delay: Duration,
    pub max_pending: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 64, max_delay: Duration::from_millis(2), max_pending: 1024 }
    }
}

/// An inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub features: Vec<f32>,
}

/// The coordinator's answer.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub label: i32,
    /// End-to-end latency (enqueue -> response send).
    pub latency: Duration,
}

/// Why a submit was refused (or an admitted request went unanswered).
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    QueueFull(usize),
    ShutDown,
    BadWidth { got: usize, want: usize },
    /// The batch this request landed in failed inference; the engine is
    /// still serving and a retry may land in a healthy batch.
    EngineFailure,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull(pending) => {
                write!(f, "queue full ({pending} pending): backpressure")
            }
            SubmitError::ShutDown => write!(f, "coordinator is shut down"),
            SubmitError::BadWidth { got, want } => {
                write!(f, "feature width {got} != expected {want}")
            }
            SubmitError::EngineFailure => {
                write!(f, "inference failed for this request's batch")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// Why a hot reload was refused.
#[derive(Debug, PartialEq, Eq)]
pub enum ReloadError {
    ShutDown,
    WrongReplicaCount { got: usize, want: usize },
}

impl std::fmt::Display for ReloadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReloadError::ShutDown => write!(f, "coordinator is shut down"),
            ReloadError::WrongReplicaCount { got, want } => {
                write!(f, "reload needs one engine factory per replica ({got} != {want})")
            }
        }
    }
}

impl std::error::Error for ReloadError {}

/// Non-blocking completion hook for [`Coordinator::submit_with`]: invoked
/// exactly once, from a worker thread, with the response or the reason
/// the admitted request went unanswered. Used where per-request boxing is
/// acceptable (the blocking API wraps its channel in one); the
/// steady-state front door uses [`CompletionSink`] instead, which carries
/// no per-request allocation.
pub type ResponseCallback = Box<dyn FnOnce(Result<Response, SubmitError>) + Send + 'static>;

/// The request-invariant completion channel of the zero-allocation
/// serving path: ONE sink (an `Arc`, cloned refcount-only per request)
/// receives every outcome, with the per-request identity riding in the
/// [`Ticket`]. The feature vector is handed back so the front end can
/// recycle it into its pool.
pub trait CompletionSink: Send + Sync {
    /// Called exactly once per submitted ticket — with the response, or
    /// with the admission/engine/shutdown error.
    fn complete(&self, ticket: Ticket, outcome: Result<Response, SubmitError>, features: Vec<f32>);
}

/// Per-request routing state threaded through [`Coordinator::submit_sink`]
/// and handed back via [`CompletionSink::complete`]: the connection token
/// and reply sequence (front-end bookkeeping, opaque to the batcher), the
/// wire protocol, the resolved tenant name (an `Arc<str>` set by the
/// registry — no per-request string copy), and a recycled buffer the sink
/// encodes the reply into.
#[derive(Debug)]
pub struct Ticket {
    /// Front-end connection identity (opaque to the batcher).
    pub token: u64,
    /// Connection-local reply slot.
    pub seq: u64,
    /// Wire protocol the reply must be encoded for.
    pub protocol: Protocol,
    /// Resolved tenant name (set by `ModelRegistry::submit_ticket`).
    pub name: Arc<str>,
    /// Reply encode buffer, recycled through the front end's pool.
    pub buf: Vec<u8>,
}

/// How a job's answer travels back to its submitter.
enum Completion {
    Callback(ResponseCallback),
    Sink { sink: Arc<dyn CompletionSink>, ticket: Ticket },
}

impl Completion {
    /// Deliver the outcome, handing the feature vector back to sinks for
    /// recycling (callbacks drop it — their callers never pool).
    fn deliver(self, outcome: Result<Response, SubmitError>, features: Vec<f32>) {
        match self {
            Completion::Callback(cb) => cb(outcome),
            Completion::Sink { sink, ticket } => sink.complete(ticket, outcome, features),
        }
    }
}

struct Job {
    request: Request,
    enqueued: Instant,
    completion: Completion,
}

/// Per-replica reusable batch state, owned by the worker loop: the
/// assembled feature matrix, the engine's [`InferScratch`], the job list
/// the queue drains into, and the staging area for deliveries made after
/// the stats lock drops. Every buffer settles at the batch high-water
/// mark — at steady state a shard is served with zero allocations.
struct BatchScratch {
    x: Matrix,
    infer: InferScratch,
    jobs: Vec<Job>,
    done: Vec<(Completion, Response, Vec<f32>)>,
}

impl BatchScratch {
    fn new() -> Self {
        Self {
            x: Matrix::zeros(0, 0),
            infer: InferScratch::new(),
            jobs: Vec::new(),
            done: Vec::new(),
        }
    }
}

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    not_empty: Condvar,
    cfg: BatcherConfig,
    features: usize,
    shutdown: AtomicBool,
    next_id: AtomicU64,
    stats: Mutex<StatsCollector>,
    /// Bumped once per [`Coordinator::reload`]. Each replica has its own
    /// slot in `pending_engines`; a reload overwrites every slot
    /// (latest-wins), so a replica that missed an intermediate reload
    /// adopts only the newest engine and can never strand on a stale one.
    reload_gen: AtomicU64,
    pending_engines: Vec<Mutex<Option<EngineFactory>>>,
    /// Serializes [`Coordinator::reload`] callers so two concurrent
    /// reloads cannot interleave their per-replica slot writes and leave
    /// the pool serving a mix of generations.
    reload_lock: Mutex<()>,
    /// Workers still alive; the last one to die on a construction failure
    /// shuts the pool down so callers see `ShutDown` instead of hanging.
    live_workers: AtomicUsize,
}

/// The running coordinator: router + batcher + a pool of engine worker
/// threads (one engine instance per replica).
pub struct Coordinator {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Start a single-replica coordinator. The engine is constructed ON
    /// the worker thread from `factory` (PJRT handles are not Sync/Send).
    pub fn start(features: usize, cfg: BatcherConfig, factory: EngineFactory) -> Self {
        Self::start_pool(features, cfg, vec![factory])
    }

    /// Start a sharded pool: one worker thread (and one engine instance)
    /// per factory, all draining the shared batcher queue.
    pub fn start_pool(features: usize, cfg: BatcherConfig, factories: Vec<EngineFactory>) -> Self {
        assert!(!factories.is_empty(), "coordinator needs at least one replica");
        let max_batch = cfg.max_batch;
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            not_empty: Condvar::new(),
            cfg,
            features,
            shutdown: AtomicBool::new(false),
            next_id: AtomicU64::new(0),
            stats: Mutex::new(StatsCollector {
                started: Some(Instant::now()),
                max_batch,
                ..Default::default()
            }),
            reload_gen: AtomicU64::new(0),
            pending_engines: (0..factories.len()).map(|_| Mutex::new(None)).collect(),
            reload_lock: Mutex::new(()),
            live_workers: AtomicUsize::new(factories.len()),
        });
        let workers = factories
            .into_iter()
            .enumerate()
            .map(|(replica, factory)| {
                let w = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("loghd-worker-{replica}"))
                    .spawn(move || worker_loop(w, replica, factory))
                    .expect("spawning worker")
            })
            .collect();
        Self { shared, workers }
    }

    /// Number of worker replicas the pool was started with.
    pub fn replicas(&self) -> usize {
        self.workers.len()
    }

    /// Replicas whose engine constructed successfully. Lower than
    /// [`replicas`](Self::replicas) when a replica died at startup — the
    /// pool degrades instead of poisoning, and this is how operators see
    /// the lost capacity (surfaced by the `models` admin verb).
    pub fn live_replicas(&self) -> usize {
        self.shared.live_workers.load(Ordering::Acquire)
    }

    /// Feature width this coordinator admits.
    pub fn features(&self) -> usize {
        self.shared.features
    }

    /// Hot-swap every replica's engine: drop one replacement factory into
    /// each replica's slot (overwriting any not-yet-adopted one —
    /// latest-wins) and bump the reload generation. Workers adopt the new
    /// engine between batches, so queued and in-flight requests are
    /// served without drops (the current batch finishes on the old
    /// engine). A factory that fails to construct leaves that replica on
    /// its previous engine. The new engines must accept the same feature
    /// width — the queue may still hold requests admitted against it.
    pub fn reload(&self, factories: Vec<EngineFactory>) -> Result<(), ReloadError> {
        if self.shared.shutdown.load(Ordering::Acquire) {
            return Err(ReloadError::ShutDown);
        }
        if factories.len() != self.workers.len() {
            return Err(ReloadError::WrongReplicaCount {
                got: factories.len(),
                want: self.workers.len(),
            });
        }
        let _serialize = self.shared.reload_lock.lock().unwrap();
        for (slot, factory) in self.shared.pending_engines.iter().zip(factories) {
            *slot.lock().unwrap() = Some(factory);
        }
        self.shared.reload_gen.fetch_add(1, Ordering::Release);
        // Bridge the generation bump and the wakeup with the queue mutex:
        // an idle worker checks reload_gen under this lock and then waits
        // untimed, so notifying without synchronizing on the lock could
        // land between its check and its wait() and be lost.
        drop(self.shared.queue.lock().unwrap());
        self.shared.not_empty.notify_all();
        Ok(())
    }

    /// Admission control + enqueue shared by every submit flavor. On
    /// refusal the completion and features are handed back so the caller
    /// decides how to deliver the error (and can recycle the vector).
    fn enqueue(
        &self,
        features: Vec<f32>,
        completion: Completion,
    ) -> Result<(), (SubmitError, Completion, Vec<f32>)> {
        if self.shared.shutdown.load(Ordering::Acquire) {
            return Err((SubmitError::ShutDown, completion, features));
        }
        if features.len() != self.shared.features {
            let err = SubmitError::BadWidth { got: features.len(), want: self.shared.features };
            return Err((err, completion, features));
        }
        {
            let mut q = self.shared.queue.lock().unwrap();
            // Re-check under the lock: a dying pool fails the queue while
            // holding it, so this load is ordered against that drain and a
            // request can never be enqueued after it (it would hang).
            if self.shared.shutdown.load(Ordering::Acquire) {
                return Err((SubmitError::ShutDown, completion, features));
            }
            if q.len() >= self.shared.cfg.max_pending {
                self.shared.stats.lock().unwrap().rejected += 1;
                return Err((SubmitError::QueueFull(q.len()), completion, features));
            }
            let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
            q.push_back(Job {
                request: Request { id, features },
                enqueued: Instant::now(),
                completion,
            });
            let depth = q.len() as u64;
            let mut stats = self.shared.stats.lock().unwrap();
            stats.requests += 1;
            stats.queue_depth_hwm = stats.queue_depth_hwm.max(depth);
        }
        self.shared.not_empty.notify_one();
        Ok(())
    }

    /// Enqueue a request; returns the receiver for its response. Sugar
    /// over the callback machinery: on failure the sender drops unsent,
    /// which is the blocking protocol's failure signal (recv fails; the
    /// caller disambiguates via the shutdown flag).
    pub fn submit(&self, features: Vec<f32>) -> Result<mpsc::Receiver<Response>, SubmitError> {
        let (tx, rx) = mpsc::channel();
        let cb: ResponseCallback = Box::new(move |result| {
            if let Ok(resp) = result {
                let _ = tx.send(resp);
            }
        });
        match self.enqueue(features, Completion::Callback(cb)) {
            Ok(()) => Ok(rx),
            Err((err, _completion, _features)) => Err(err),
        }
    }

    /// Enqueue a request with a completion callback instead of a channel.
    /// The callback fires exactly once — with the response, or with the
    /// admission/engine/shutdown error — always from a worker thread
    /// except for synchronous admission refusals, which invoke it inline.
    pub fn submit_with(&self, features: Vec<f32>, cb: ResponseCallback) {
        if let Err((err, completion, features)) = self.enqueue(features, Completion::Callback(cb)) {
            completion.deliver(Err(err), features);
        }
    }

    /// Enqueue a request on the zero-allocation path: ONE shared sink
    /// (refcount-clone per request, no boxing) receives the outcome with
    /// `ticket` identifying the request. Every outcome — including
    /// synchronous admission refusals — is delivered through the sink, so
    /// the ticket's buffers always come back for recycling.
    pub fn submit_sink(&self, features: Vec<f32>, sink: &Arc<dyn CompletionSink>, ticket: Ticket) {
        let completion = Completion::Sink { sink: Arc::clone(sink), ticket };
        if let Err((err, completion, features)) = self.enqueue(features, completion) {
            completion.deliver(Err(err), features);
        }
    }

    /// Submit and wait for the answer. A dropped response channel means
    /// either the pool shut down or this request's batch failed
    /// inference — disambiguated via the shutdown flag so transient
    /// engine errors do not masquerade as a dead coordinator.
    pub fn submit_blocking(&self, features: Vec<f32>) -> Result<Response, SubmitError> {
        let rx = self.submit(features)?;
        rx.recv().map_err(|_| {
            if self.shared.shutdown.load(Ordering::Acquire) {
                SubmitError::ShutDown
            } else {
                SubmitError::EngineFailure
            }
        })
    }

    pub fn stats(&self) -> StatsSnapshot {
        self.shared.stats.lock().unwrap().snapshot()
    }

    /// Graceful shutdown: drain the queue, stop every worker.
    pub fn shutdown(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        // Bridge the flag store and the wakeup with the queue mutex: an
        // idle worker checks the flag under this lock and then waits
        // untimed, so a notify that isn't ordered by the lock could fire
        // between its check and its wait() — the worker would sleep
        // forever (post-shutdown enqueues are refused and never notify)
        // and join() below would deadlock.
        drop(self.shared.queue.lock().unwrap());
        self.shared.not_empty.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(shared: Arc<Shared>, replica: usize, factory: EngineFactory) {
    let mut engine = match factory() {
        Ok(e) => e,
        Err(err) => {
            crate::log_error!("worker {replica} engine construction failed: {err:#}");
            // Degrade, don't poison: surviving replicas keep serving. Only
            // when the LAST worker dies does the pool shut down — and the
            // queue is cleared so already-enqueued senders drop and
            // blocked callers observe the failure instead of hanging.
            if shared.live_workers.fetch_sub(1, Ordering::AcqRel) == 1 {
                shared.shutdown.store(true, Ordering::Release);
                let orphans: Vec<Job> = shared.queue.lock().unwrap().drain(..).collect();
                shared.not_empty.notify_all();
                for job in orphans {
                    let Job { request, completion, .. } = job;
                    completion.deliver(Err(SubmitError::ShutDown), request.features);
                }
            }
            return;
        }
    };
    crate::log_info!(
        "worker {replica} up: engine={} features={}",
        engine.name(),
        shared.features
    );
    // Engine generation this replica has adopted. Each reload overwrites
    // this replica's slot and bumps the generation; adopting jumps
    // straight to the latest generation (intermediate reloads collapse).
    let mut seen_gen = 0u64;
    let mut scratch = BatchScratch::new();
    loop {
        // Adopt a pending engine swap before pulling the next shard.
        let current_gen = shared.reload_gen.load(Ordering::Acquire);
        if current_gen != seen_gen {
            seen_gen = current_gen;
            let pending = shared.pending_engines[replica].lock().unwrap().take();
            if let Some(build) = pending {
                match build() {
                    Ok(e) => {
                        engine = e;
                        shared.stats.lock().unwrap().reloads += 1;
                        crate::log_info!(
                            "worker {replica} hot-swapped engine -> {}",
                            engine.name()
                        );
                    }
                    Err(err) => {
                        crate::log_error!(
                            "worker {replica} reload failed (keeping {}): {err:#}",
                            engine.name()
                        );
                    }
                }
            }
        }
        if !collect_batch(&shared, seen_gen, &mut scratch.jobs) {
            break;
        }
        if scratch.jobs.is_empty() {
            continue;
        }
        let n = scratch.jobs.len();
        // Assemble in place: resize never shrinks capacity, and every
        // admitted row is width-checked, so each row is fully overwritten
        // — no zero-fill, no fresh matrix.
        scratch.x.resize(n, shared.features);
        for (i, job) in scratch.jobs.iter().enumerate() {
            scratch.x.row_mut(i).copy_from_slice(&job.request.features);
        }
        let labels = match engine.infer_into(&scratch.x, &mut scratch.infer) {
            Ok(l) => l,
            Err(err) => {
                crate::log_error!("inference failed for batch of {n}: {err:#}");
                shared.stats.lock().unwrap().failures += n as u64;
                for job in scratch.jobs.drain(..) {
                    let Job { request, completion, .. } = job;
                    completion.deliver(Err(SubmitError::EngineFailure), request.features);
                }
                continue;
            }
        };
        let now = Instant::now();
        {
            // One stats-lock acquisition for the whole shard.
            let mut stats = shared.stats.lock().unwrap();
            stats.batches += 1;
            stats.batched_items += n as u64;
            for (job, &label) in scratch.jobs.drain(..).zip(labels) {
                let latency = now.duration_since(job.enqueued);
                stats.latency.record(latency);
                stats.responses += 1;
                let Job { request, completion, .. } = job;
                scratch.done.push((
                    completion,
                    Response { id: request.id, label, latency },
                    request.features,
                ));
            }
        }
        // Deliver outside the stats lock: sink/callback completions do
        // real work (encode a reply, wake a reactor).
        for (completion, resp, features) in scratch.done.drain(..) {
            completion.deliver(Ok(resp), features);
        }
    }
    crate::log_info!("worker {replica} drained; shutting down");
}

/// Wait for work, then apply the max-batch/max-delay policy, draining the
/// shard into `out` (the caller's reused buffer — must be empty).
/// Returns false when shut down AND the queue is empty (drain semantics);
/// returns true with `out` empty when a reload generation newer than
/// `seen_gen` arrives, so the caller can adopt the new engine promptly
/// even while idle.
///
/// The idle wait is an *untimed* condvar wait: every producer of work
/// notifies (`enqueue` → `notify_one`, `reload`/`shutdown` →
/// `notify_all`), so there is no poll interval and no wakeup-latency
/// floor. Invariant: every producer makes its state change visible
/// under the queue mutex (enqueue pushes under it; flag/generation
/// writers lock-and-release it after the store) *before* notifying —
/// otherwise the notify can land between this loop's checks and its
/// `wait()` and be lost forever. The fill window waits precisely until `oldest + max_delay` —
/// `max_delay` is honored as configured, not rounded up to a tick.
fn collect_batch(shared: &Shared, seen_gen: u64, out: &mut Vec<Job>) -> bool {
    debug_assert!(out.is_empty());
    let cfg = &shared.cfg;
    let mut q = shared.queue.lock().unwrap();
    loop {
        if !q.is_empty() {
            break;
        }
        if shared.shutdown.load(Ordering::Acquire) {
            return false;
        }
        if shared.reload_gen.load(Ordering::Acquire) != seen_gen {
            return true;
        }
        q = shared.not_empty.wait(q).unwrap();
    }
    let oldest = q.front().unwrap().enqueued;
    // Fill window: wait for more work until max_delay past the oldest.
    // A reload generation newer than `seen_gen` breaks the window — the
    // partial batch ships immediately so the worker adopts the new
    // engine after this shard instead of absorbing the reload's
    // notify_all into `wait_timeout` and sitting out the rest of
    // `max_delay` on the stale engine.
    while q.len() < cfg.max_batch && !shared.shutdown.load(Ordering::Acquire) {
        if shared.reload_gen.load(Ordering::Acquire) != seen_gen {
            break;
        }
        let age = oldest.elapsed();
        if age >= cfg.max_delay {
            break;
        }
        let (guard, _) = shared
            .not_empty
            .wait_timeout(q, cfg.max_delay - age)
            .unwrap();
        q = guard;
    }
    let take = q.len().min(cfg.max_batch);
    for _ in 0..take {
        out.push(q.pop_front().unwrap());
    }
    drop(q);
    // One stats-lock acquisition for the whole shard's queue waits.
    let mut stats = shared.stats.lock().unwrap();
    for job in out.iter() {
        stats.queue_wait.record(job.enqueued.elapsed());
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Engine;
    use anyhow::Result as AResult;

    /// Engine that labels each row by rounding its first feature.
    struct RoundFirst {
        batch_sizes: Arc<Mutex<Vec<usize>>>,
    }

    impl Engine for RoundFirst {
        fn name(&self) -> String {
            "round-first".into()
        }
        fn features(&self) -> usize {
            3
        }
        fn infer(&mut self, x: &Matrix) -> AResult<Vec<i32>> {
            self.batch_sizes.lock().unwrap().push(x.rows());
            Ok((0..x.rows()).map(|i| x.at(i, 0).round() as i32).collect())
        }
    }

    fn start(sizes: Arc<Mutex<Vec<usize>>>, cfg: BatcherConfig) -> Coordinator {
        Coordinator::start(
            3,
            cfg,
            Box::new(move || Ok(Box::new(RoundFirst { batch_sizes: sizes }))),
        )
    }

    #[test]
    fn responses_match_requests() {
        let sizes = Arc::new(Mutex::new(Vec::new()));
        let coord = start(sizes, BatcherConfig::default());
        let mut rxs = Vec::new();
        for i in 0..20 {
            rxs.push((i, coord.submit(vec![i as f32, 0.0, 0.0]).unwrap()));
        }
        for (i, rx) in rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.label, i);
        }
        let snap = coord.stats();
        assert_eq!(snap.responses, 20);
        assert_eq!(snap.requests, 20);
    }

    #[test]
    fn rejects_bad_width() {
        let sizes = Arc::new(Mutex::new(Vec::new()));
        let coord = start(sizes, BatcherConfig::default());
        assert_eq!(
            coord.submit(vec![1.0]).unwrap_err(),
            SubmitError::BadWidth { got: 1, want: 3 }
        );
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let sizes = Arc::new(Mutex::new(Vec::new()));
        // tiny queue + long delay so jobs pile up
        let cfg = BatcherConfig {
            max_batch: 64,
            max_delay: Duration::from_millis(200),
            max_pending: 4,
        };
        let coord = start(sizes, cfg);
        let mut ok = 0;
        let mut full = 0;
        let mut rxs = Vec::new();
        for _ in 0..64 {
            match coord.submit(vec![1.0, 0.0, 0.0]) {
                Ok(rx) => {
                    ok += 1;
                    rxs.push(rx);
                }
                Err(SubmitError::QueueFull(_)) => full += 1,
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(full > 0, "expected backpressure ({ok} accepted)");
        for rx in rxs {
            let _ = rx.recv();
        }
    }

    #[test]
    fn batches_amortize_under_load() {
        let sizes = Arc::new(Mutex::new(Vec::new()));
        let cfg = BatcherConfig {
            max_batch: 16,
            max_delay: Duration::from_millis(30),
            max_pending: 1024,
        };
        let coord = start(Arc::clone(&sizes), cfg);
        let rxs: Vec<_> =
            (0..48).map(|_| coord.submit(vec![0.0, 0.0, 0.0]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let sizes = sizes.lock().unwrap();
        assert!(
            sizes.iter().any(|s| *s > 1),
            "expected at least one multi-request batch, got {sizes:?}"
        );
        assert!(sizes.iter().all(|s| *s <= 16));
    }

    /// Engine that answers every request with a fixed tag.
    struct Tagged(i32);

    impl Engine for Tagged {
        fn name(&self) -> String {
            format!("tagged-{}", self.0)
        }
        fn features(&self) -> usize {
            1
        }
        fn infer(&mut self, x: &Matrix) -> AResult<Vec<i32>> {
            Ok(vec![self.0; x.rows()])
        }
    }

    fn tagged_factory(tag: i32) -> EngineFactory {
        Box::new(move || Ok(Box::new(Tagged(tag)) as Box<dyn Engine>))
    }

    #[test]
    fn pool_replicas_share_the_queue() {
        let coord = Coordinator::start_pool(
            1,
            BatcherConfig { max_batch: 4, ..Default::default() },
            vec![tagged_factory(7), tagged_factory(7)],
        );
        assert_eq!(coord.replicas(), 2);
        assert_eq!(coord.features(), 1);
        let rxs: Vec<_> = (0..64).map(|_| coord.submit(vec![0.0]).unwrap()).collect();
        for rx in rxs {
            assert_eq!(rx.recv().unwrap().label, 7);
        }
        assert_eq!(coord.stats().responses, 64);
    }

    #[test]
    fn reload_hot_swaps_without_dropping() {
        let coord = Coordinator::start_pool(
            1,
            BatcherConfig::default(),
            vec![tagged_factory(1), tagged_factory(1)],
        );
        assert_eq!(
            coord.reload(vec![tagged_factory(9)]).unwrap_err(),
            ReloadError::WrongReplicaCount { got: 1, want: 2 }
        );
        let rxs: Vec<_> = (0..16).map(|_| coord.submit(vec![0.0]).unwrap()).collect();
        coord.reload(vec![tagged_factory(2), tagged_factory(2)]).unwrap();
        // Every pre-reload request is answered (by either generation).
        for rx in rxs {
            let label = rx.recv().unwrap().label;
            assert!(label == 1 || label == 2, "unexpected label {label}");
        }
        // The new engine takes over for later requests.
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let resp = coord.submit_blocking(vec![0.0]).unwrap();
            if resp.label == 2 {
                break;
            }
            assert!(Instant::now() < deadline, "engine never swapped");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(coord.stats().reloads >= 1);
    }

    /// Regression: a reload landing while a worker sits in the fill
    /// window must break the window (ship the partial batch) instead of
    /// being absorbed by `wait_timeout` — pre-fix, the in-flight request
    /// below waited out the full 2s `max_delay` and engine adoption was
    /// delayed behind it.
    #[test]
    fn reload_breaks_the_fill_window() {
        let coord = Coordinator::start_pool(
            1,
            BatcherConfig {
                max_batch: 64,
                max_delay: Duration::from_secs(2),
                max_pending: 16,
            },
            vec![tagged_factory(1)],
        );
        let t0 = Instant::now();
        let rx = coord.submit(vec![0.0]).unwrap();
        // Let the worker enter the fill window, then reload mid-fill.
        std::thread::sleep(Duration::from_millis(100));
        coord.reload(vec![tagged_factory(2)]).unwrap();
        let resp = rx.recv().unwrap();
        assert!(resp.label == 1 || resp.label == 2);
        let elapsed = t0.elapsed();
        assert!(
            elapsed < Duration::from_secs(1),
            "reload did not break the fill window: first response took {elapsed:?} \
             (max_delay is 2s)"
        );
        // And the new engine is adopted right after the partial batch.
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            if coord.submit_blocking(vec![0.0]).unwrap().label == 2 {
                break;
            }
            assert!(Instant::now() < deadline, "engine never swapped after mid-fill reload");
        }
    }

    #[test]
    fn rapid_reloads_collapse_to_latest() {
        let coord = Coordinator::start_pool(
            1,
            BatcherConfig::default(),
            vec![tagged_factory(1), tagged_factory(1)],
        );
        coord.submit_blocking(vec![0.0]).unwrap();
        coord.reload(vec![tagged_factory(2), tagged_factory(2)]).unwrap();
        coord.reload(vec![tagged_factory(3), tagged_factory(3)]).unwrap();
        // Every replica must converge on the LATEST generation — a
        // replica that missed the intermediate reload must still land on
        // 3, never strand on 1 or 2.
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut consecutive = 0;
        while consecutive < 12 {
            let label = coord.submit_blocking(vec![0.0]).unwrap().label;
            assert!((1..=3).contains(&label), "unexpected label {label}");
            consecutive = if label == 3 { consecutive + 1 } else { 0 };
            assert!(Instant::now() < deadline, "replicas never converged on latest engine");
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    #[test]
    fn pool_survives_one_replica_construction_failure() {
        let coord = Coordinator::start_pool(
            1,
            BatcherConfig::default(),
            vec![Box::new(|| anyhow::bail!("boom")), tagged_factory(5)],
        );
        for _ in 0..8 {
            assert_eq!(coord.submit_blocking(vec![0.0]).unwrap().label, 5);
        }
        // The lost capacity is observable.
        assert_eq!(coord.replicas(), 2);
        assert_eq!(coord.live_replicas(), 1);
    }

    #[test]
    fn pool_shuts_down_when_every_replica_fails() {
        let coord = Coordinator::start_pool(
            1,
            BatcherConfig::default(),
            vec![Box::new(|| anyhow::bail!("a")), Box::new(|| anyhow::bail!("b"))],
        );
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            match coord.submit_blocking(vec![0.0]) {
                Err(SubmitError::ShutDown) => break,
                Ok(_) | Err(SubmitError::EngineFailure) => {}
                Err(e) => panic!("unexpected {e}"),
            }
            assert!(Instant::now() < deadline, "pool never reported shutdown");
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn submit_with_delivers_responses_and_admission_errors() {
        let sizes = Arc::new(Mutex::new(Vec::new()));
        let coord = start(sizes, BatcherConfig::default());
        let (tx, rx) = mpsc::channel();
        for i in 0..8 {
            let tx = tx.clone();
            coord.submit_with(
                vec![i as f32, 0.0, 0.0],
                Box::new(move |res| tx.send((i, res)).unwrap()),
            );
        }
        for _ in 0..8 {
            let (i, res) = rx.recv().unwrap();
            assert_eq!(res.unwrap().label, i);
        }
        // Admission refusal invokes the callback synchronously with Err.
        let (tx2, rx2) = mpsc::channel();
        coord.submit_with(vec![1.0], Box::new(move |res| tx2.send(res).unwrap()));
        assert_eq!(rx2.recv().unwrap().unwrap_err(), SubmitError::BadWidth { got: 1, want: 3 });
    }

    #[test]
    fn submit_sink_delivers_outcomes_and_returns_features() {
        struct TestSink {
            tx: Mutex<mpsc::Sender<(Ticket, Result<Response, SubmitError>, Vec<f32>)>>,
        }
        impl CompletionSink for TestSink {
            fn complete(
                &self,
                ticket: Ticket,
                outcome: Result<Response, SubmitError>,
                features: Vec<f32>,
            ) {
                let _ = self.tx.lock().unwrap().send((ticket, outcome, features));
            }
        }
        let sizes = Arc::new(Mutex::new(Vec::new()));
        let coord = start(sizes, BatcherConfig::default());
        let (tx, rx) = mpsc::channel();
        let sink: Arc<dyn CompletionSink> = Arc::new(TestSink { tx: Mutex::new(tx) });
        let ticket = |seq: u64| Ticket {
            token: 3,
            seq,
            protocol: Protocol::Binary,
            name: Arc::from("t"),
            buf: Vec::new(),
        };
        coord.submit_sink(vec![4.0, 0.0, 0.0], &sink, ticket(0));
        let (t, outcome, feats) = rx.recv().unwrap();
        assert_eq!((t.token, t.seq), (3, 0));
        assert_eq!(outcome.unwrap().label, 4);
        assert_eq!(feats, vec![4.0, 0.0, 0.0]);
        // Admission refusals arrive through the sink too, features intact
        // (the front end recycles them into its pool).
        coord.submit_sink(vec![1.0], &sink, ticket(1));
        let (t, outcome, feats) = rx.recv().unwrap();
        assert_eq!(t.seq, 1);
        assert_eq!(outcome.unwrap_err(), SubmitError::BadWidth { got: 1, want: 3 });
        assert_eq!(feats, vec![1.0]);
    }

    #[test]
    fn submit_with_reports_engine_failure_and_shutdown() {
        struct AlwaysFails;
        impl Engine for AlwaysFails {
            fn name(&self) -> String {
                "always-fails".into()
            }
            fn features(&self) -> usize {
                1
            }
            fn infer(&mut self, _x: &Matrix) -> AResult<Vec<i32>> {
                anyhow::bail!("broken")
            }
        }
        let mut coord =
            Coordinator::start(1, BatcherConfig::default(), Box::new(|| Ok(Box::new(AlwaysFails))));
        let (tx, rx) = mpsc::channel();
        coord.submit_with(vec![0.0], Box::new(move |res| tx.send(res).unwrap()));
        assert_eq!(rx.recv().unwrap().unwrap_err(), SubmitError::EngineFailure);
        coord.shutdown();
        let (tx, rx) = mpsc::channel();
        coord.submit_with(vec![0.0], Box::new(move |res| tx.send(res).unwrap()));
        assert_eq!(rx.recv().unwrap().unwrap_err(), SubmitError::ShutDown);
    }

    #[test]
    fn shutdown_drains_pending() {
        let sizes = Arc::new(Mutex::new(Vec::new()));
        let mut coord = start(sizes, BatcherConfig::default());
        let rxs: Vec<_> =
            (0..8).map(|i| coord.submit(vec![i as f32, 0.0, 0.0]).unwrap()).collect();
        coord.shutdown();
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().label, i as i32);
        }
        assert_eq!(coord.submit(vec![0.0; 3]).unwrap_err(), SubmitError::ShutDown);
    }
}

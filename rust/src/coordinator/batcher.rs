//! Dynamic batcher + request lifecycle.
//!
//! Policy (vLLM-router-like, scaled to this problem): a bounded pending
//! queue (backpressure: `submit` rejects when full); the worker drains up
//! to `max_batch` requests, waiting at most `max_delay` past the oldest
//! request's arrival to fill the batch — the knob that trades p99 latency
//! against PJRT dispatch amortization (the batcher bench sweeps it).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::tensor::Matrix;

use super::stats::{StatsCollector, StatsSnapshot};
use super::worker::EngineFactory;

/// Batching configuration.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_delay: Duration,
    pub max_pending: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 64, max_delay: Duration::from_millis(2), max_pending: 1024 }
    }
}

/// An inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub features: Vec<f32>,
}

/// The coordinator's answer.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub label: i32,
    /// End-to-end latency (enqueue -> response send).
    pub latency: Duration,
}

/// Why a submit was refused.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    QueueFull(usize),
    ShutDown,
    BadWidth { got: usize, want: usize },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull(pending) => {
                write!(f, "queue full ({pending} pending): backpressure")
            }
            SubmitError::ShutDown => write!(f, "coordinator is shut down"),
            SubmitError::BadWidth { got, want } => {
                write!(f, "feature width {got} != expected {want}")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

struct Job {
    request: Request,
    enqueued: Instant,
    tx: mpsc::Sender<Response>,
}

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    not_empty: Condvar,
    cfg: BatcherConfig,
    features: usize,
    shutdown: AtomicBool,
    next_id: AtomicU64,
    stats: Mutex<StatsCollector>,
}

/// The running coordinator: router + batcher + one engine worker thread.
pub struct Coordinator {
    shared: Arc<Shared>,
    worker: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Start the coordinator. The engine is constructed ON the worker
    /// thread from `factory` (PJRT handles are not Sync/Send).
    pub fn start(features: usize, cfg: BatcherConfig, factory: EngineFactory) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            not_empty: Condvar::new(),
            cfg,
            features,
            shutdown: AtomicBool::new(false),
            next_id: AtomicU64::new(0),
            stats: Mutex::new(StatsCollector {
                started: Some(Instant::now()),
                ..Default::default()
            }),
        });
        let w = Arc::clone(&shared);
        let worker = std::thread::Builder::new()
            .name("loghd-worker".into())
            .spawn(move || worker_loop(w, factory))
            .expect("spawning worker");
        Self { shared, worker: Some(worker) }
    }

    /// Enqueue a request; returns the receiver for its response.
    pub fn submit(&self, features: Vec<f32>) -> Result<mpsc::Receiver<Response>, SubmitError> {
        if self.shared.shutdown.load(Ordering::Acquire) {
            return Err(SubmitError::ShutDown);
        }
        if features.len() != self.shared.features {
            return Err(SubmitError::BadWidth {
                got: features.len(),
                want: self.shared.features,
            });
        }
        let (tx, rx) = mpsc::channel();
        {
            let mut q = self.shared.queue.lock().unwrap();
            if q.len() >= self.shared.cfg.max_pending {
                self.shared.stats.lock().unwrap().rejected += 1;
                return Err(SubmitError::QueueFull(q.len()));
            }
            let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
            q.push_back(Job { request: Request { id, features }, enqueued: Instant::now(), tx });
            self.shared.stats.lock().unwrap().requests += 1;
        }
        self.shared.not_empty.notify_one();
        Ok(rx)
    }

    /// Submit and wait for the answer.
    pub fn submit_blocking(&self, features: Vec<f32>) -> Result<Response, SubmitError> {
        let rx = self.submit(features)?;
        rx.recv().map_err(|_| SubmitError::ShutDown)
    }

    pub fn stats(&self) -> StatsSnapshot {
        self.shared.stats.lock().unwrap().snapshot()
    }

    /// Graceful shutdown: drain the queue, stop the worker.
    pub fn shutdown(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.not_empty.notify_all();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(shared: Arc<Shared>, factory: EngineFactory) {
    let mut engine = match factory() {
        Ok(e) => e,
        Err(err) => {
            crate::log_error!("engine construction failed: {err:#}");
            // Drain everything with a poison response path: drop senders.
            shared.shutdown.store(true, Ordering::Release);
            return;
        }
    };
    crate::log_info!("worker up: engine={} features={}", engine.name(), shared.features);
    loop {
        let batch = collect_batch(&shared);
        let Some(jobs) = batch else { break };
        if jobs.is_empty() {
            continue;
        }
        let mut x = Matrix::zeros(jobs.len(), shared.features);
        for (i, job) in jobs.iter().enumerate() {
            x.row_mut(i).copy_from_slice(&job.request.features);
        }
        let labels = match engine.infer(&x) {
            Ok(l) => l,
            Err(err) => {
                crate::log_error!("inference failed for batch of {}: {err:#}", jobs.len());
                continue; // senders drop -> callers see disconnect
            }
        };
        let now = Instant::now();
        let mut stats = shared.stats.lock().unwrap();
        stats.batches += 1;
        stats.batched_items += jobs.len() as u64;
        for (job, label) in jobs.into_iter().zip(labels) {
            let latency = now.duration_since(job.enqueued);
            stats.latency.record(latency);
            stats.responses += 1;
            let _ = job.tx.send(Response { id: job.request.id, label, latency });
        }
    }
    crate::log_info!("worker drained; shutting down");
}

/// Wait for work, then apply the max-batch/max-delay policy.
/// Returns None when shut down AND the queue is empty (drain semantics).
fn collect_batch(shared: &Shared) -> Option<Vec<Job>> {
    let cfg = &shared.cfg;
    let mut q = shared.queue.lock().unwrap();
    loop {
        if !q.is_empty() {
            break;
        }
        if shared.shutdown.load(Ordering::Acquire) {
            return None;
        }
        let (guard, _) =
            shared.not_empty.wait_timeout(q, Duration::from_millis(50)).unwrap();
        q = guard;
    }
    let oldest = q.front().unwrap().enqueued;
    // Fill window: wait for more work until max_delay past the oldest.
    while q.len() < cfg.max_batch && !shared.shutdown.load(Ordering::Acquire) {
        let age = oldest.elapsed();
        if age >= cfg.max_delay {
            break;
        }
        let (guard, _) = shared
            .not_empty
            .wait_timeout(q, cfg.max_delay - age)
            .unwrap();
        q = guard;
    }
    let take = q.len().min(cfg.max_batch);
    let mut jobs = Vec::with_capacity(take);
    for _ in 0..take {
        let job = q.pop_front().unwrap();
        shared
            .stats
            .lock()
            .unwrap()
            .queue_wait
            .record(job.enqueued.elapsed());
        jobs.push(job);
    }
    Some(jobs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Engine;
    use anyhow::Result as AResult;

    /// Engine that labels each row by rounding its first feature.
    struct RoundFirst {
        batch_sizes: Arc<Mutex<Vec<usize>>>,
    }

    impl Engine for RoundFirst {
        fn name(&self) -> String {
            "round-first".into()
        }
        fn features(&self) -> usize {
            3
        }
        fn infer(&mut self, x: &Matrix) -> AResult<Vec<i32>> {
            self.batch_sizes.lock().unwrap().push(x.rows());
            Ok((0..x.rows()).map(|i| x.at(i, 0).round() as i32).collect())
        }
    }

    fn start(sizes: Arc<Mutex<Vec<usize>>>, cfg: BatcherConfig) -> Coordinator {
        Coordinator::start(
            3,
            cfg,
            Box::new(move || Ok(Box::new(RoundFirst { batch_sizes: sizes }))),
        )
    }

    #[test]
    fn responses_match_requests() {
        let sizes = Arc::new(Mutex::new(Vec::new()));
        let coord = start(sizes, BatcherConfig::default());
        let mut rxs = Vec::new();
        for i in 0..20 {
            rxs.push((i, coord.submit(vec![i as f32, 0.0, 0.0]).unwrap()));
        }
        for (i, rx) in rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.label, i);
        }
        let snap = coord.stats();
        assert_eq!(snap.responses, 20);
        assert_eq!(snap.requests, 20);
    }

    #[test]
    fn rejects_bad_width() {
        let sizes = Arc::new(Mutex::new(Vec::new()));
        let coord = start(sizes, BatcherConfig::default());
        assert_eq!(
            coord.submit(vec![1.0]).unwrap_err(),
            SubmitError::BadWidth { got: 1, want: 3 }
        );
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let sizes = Arc::new(Mutex::new(Vec::new()));
        // tiny queue + long delay so jobs pile up
        let cfg = BatcherConfig {
            max_batch: 64,
            max_delay: Duration::from_millis(200),
            max_pending: 4,
        };
        let coord = start(sizes, cfg);
        let mut ok = 0;
        let mut full = 0;
        let mut rxs = Vec::new();
        for _ in 0..64 {
            match coord.submit(vec![1.0, 0.0, 0.0]) {
                Ok(rx) => {
                    ok += 1;
                    rxs.push(rx);
                }
                Err(SubmitError::QueueFull(_)) => full += 1,
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(full > 0, "expected backpressure ({ok} accepted)");
        for rx in rxs {
            let _ = rx.recv();
        }
    }

    #[test]
    fn batches_amortize_under_load() {
        let sizes = Arc::new(Mutex::new(Vec::new()));
        let cfg = BatcherConfig {
            max_batch: 16,
            max_delay: Duration::from_millis(30),
            max_pending: 1024,
        };
        let coord = start(Arc::clone(&sizes), cfg);
        let rxs: Vec<_> =
            (0..48).map(|_| coord.submit(vec![0.0, 0.0, 0.0]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let sizes = sizes.lock().unwrap();
        assert!(
            sizes.iter().any(|s| *s > 1),
            "expected at least one multi-request batch, got {sizes:?}"
        );
        assert!(sizes.iter().all(|s| *s <= 16));
    }

    #[test]
    fn shutdown_drains_pending() {
        let sizes = Arc::new(Mutex::new(Vec::new()));
        let mut coord = start(sizes, BatcherConfig::default());
        let rxs: Vec<_> =
            (0..8).map(|i| coord.submit(vec![i as f32, 0.0, 0.0]).unwrap()).collect();
        coord.shutdown();
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().label, i as i32);
        }
        assert_eq!(coord.submit(vec![0.0; 3]).unwrap_err(), SubmitError::ShutDown);
    }
}

//! Inference engines the coordinator can run.

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::encoder::Encoder;
use crate::loghd::model::LogHdModel;
use crate::runtime::PjrtRuntime;
use crate::tensor::Matrix;

use super::Engine;

/// Engines are built on the worker thread (PJRT handles are not Send):
/// the coordinator takes a factory, not an engine.
pub type EngineFactory = Box<dyn FnOnce() -> Result<Box<dyn Engine>> + Send>;

/// The AOT path: a compiled HLO entry served via PJRT.
pub struct PjrtEngine {
    runtime: PjrtRuntime,
    entry: String,
}

impl PjrtEngine {
    /// Load an artifact bundle and serve `entry` (e.g. "infer_loghd").
    pub fn load(dir: &PathBuf, entry: &str) -> Result<Self> {
        let runtime = PjrtRuntime::load(dir)?;
        runtime
            .manifest
            .entry(entry)
            .with_context(|| format!("bundle has no entry '{entry}'"))?;
        Ok(Self { runtime, entry: entry.to_string() })
    }

    /// Factory for [`super::Coordinator::start`].
    pub fn factory(dir: PathBuf, entry: String) -> EngineFactory {
        Box::new(move || Ok(Box::new(PjrtEngine::load(&dir, &entry)?) as Box<dyn Engine>))
    }

    pub fn runtime(&self) -> &PjrtRuntime {
        &self.runtime
    }

    pub fn runtime_mut(&mut self) -> &mut PjrtRuntime {
        &mut self.runtime
    }
}

impl Engine for PjrtEngine {
    fn name(&self) -> String {
        format!("pjrt:{}:{}", self.runtime.manifest.name, self.entry)
    }

    fn features(&self) -> usize {
        self.runtime.manifest.features
    }

    fn infer(&mut self, x: &Matrix) -> Result<Vec<i32>> {
        self.runtime.infer_labels(&self.entry, x)
    }
}

/// The native path: encoder + LogHD decode in pure Rust.
pub struct NativeEngine {
    pub encoder: Encoder,
    pub model: LogHdModel,
    label: String,
}

impl NativeEngine {
    pub fn new(encoder: Encoder, model: LogHdModel, label: impl Into<String>) -> Self {
        Self { encoder, model, label: label.into() }
    }

    pub fn factory(encoder: Encoder, model: LogHdModel, label: String) -> EngineFactory {
        Box::new(move || Ok(Box::new(NativeEngine::new(encoder, model, label)) as Box<dyn Engine>))
    }
}

impl Engine for NativeEngine {
    fn name(&self) -> String {
        format!("native:{}", self.label)
    }

    fn features(&self) -> usize {
        self.encoder.features()
    }

    fn infer(&mut self, x: &Matrix) -> Result<Vec<i32>> {
        let enc = self.encoder.encode(x);
        Ok(self.model.predict(&enc))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::loghd::model::{TrainOptions, TrainedStack};

    #[test]
    fn native_engine_serves() {
        let ds = data::generate_scaled(data::spec("page").unwrap(), 400, 50);
        let opts = TrainOptions { epochs: 2, conv_epochs: 0, extra_bundles: 1, ..Default::default() };
        let st = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 128, 1, &opts).unwrap();
        let mut engine = NativeEngine::new(st.encoder, st.loghd, "page");
        assert_eq!(engine.features(), 10);
        let labels = engine.infer(&ds.x_test.rows_slice(0, 10)).unwrap();
        assert_eq!(labels.len(), 10);
        assert!(labels.iter().all(|l| (0..5).contains(l)));
        assert!(engine.name().starts_with("native:"));
    }
}

//! Inference engines the coordinator can run.
//!
//! Each worker replica in a [`super::Coordinator`] pool owns one engine
//! instance built from an [`EngineFactory`]; the pool pulls ready batches
//! (shards) off the shared queue in arrival order — round-robin across
//! idle replicas, least-loaded under skew.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::baselines::conventional::ConventionalModel;
use crate::encoder::Encoder;
use crate::loghd::model::LogHdModel;
use crate::loghd::qmodel::QuantizedLogHdModel;
use crate::quant::{self, Precision};
use crate::runtime::PjrtRuntime;
use crate::tensor::Matrix;

use super::Engine;

/// Engines are built on the worker thread (PJRT handles are not Send):
/// the coordinator takes a factory, not an engine.
pub type EngineFactory = Box<dyn FnOnce() -> Result<Box<dyn Engine>> + Send>;

/// The AOT path: a compiled HLO entry served via PJRT.
pub struct PjrtEngine {
    runtime: PjrtRuntime,
    entry: String,
}

impl PjrtEngine {
    /// Load an artifact bundle and serve `entry` (e.g. "infer_loghd").
    pub fn load(dir: &Path, entry: &str) -> Result<Self> {
        let runtime = PjrtRuntime::load(dir)?;
        runtime
            .manifest
            .entry(entry)
            .with_context(|| format!("bundle has no entry '{entry}'"))?;
        Ok(Self { runtime, entry: entry.to_string() })
    }

    /// Factory for [`super::Coordinator::start`].
    pub fn factory(dir: PathBuf, entry: String) -> EngineFactory {
        Box::new(move || Ok(Box::new(PjrtEngine::load(&dir, &entry)?) as Box<dyn Engine>))
    }

    pub fn runtime(&self) -> &PjrtRuntime {
        &self.runtime
    }

    pub fn runtime_mut(&mut self) -> &mut PjrtRuntime {
        &mut self.runtime
    }
}

impl Engine for PjrtEngine {
    fn name(&self) -> String {
        format!("pjrt:{}:{}", self.runtime.manifest.name, self.entry)
    }

    fn features(&self) -> usize {
        self.runtime.manifest.features
    }

    fn infer(&mut self, x: &Matrix) -> Result<Vec<i32>> {
        self.runtime.infer_labels(&self.entry, x)
    }
}

/// The native path: encoder + LogHD decode in pure Rust, at a selectable
/// serving precision.
///
/// - `F32` (default): the dense model as trained.
/// - `B1` / `B8`: the bit-packed twin (`loghd::qmodel`) — XNOR/popcount
///   resp. int8/i32 kernels over the packed stored state.
/// - `B2` / `B4`: post-training-quantized weights served through the f32
///   kernels (no packed kernel exists at those widths).
pub struct NativeEngine {
    pub encoder: Encoder,
    pub precision: Precision,
    state: ModelState,
    label: String,
}

/// What the engine actually holds: the dense f32 tensors are dropped at
/// the packed precisions — keeping both would make the memory-reduction
/// mode cost *more* memory per worker than plain f32.
enum ModelState {
    Dense(LogHdModel),
    Packed(QuantizedLogHdModel),
}

impl NativeEngine {
    /// F32 engine (the historical constructor).
    pub fn new(encoder: Encoder, model: LogHdModel, label: impl Into<String>) -> Self {
        Self::with_precision(encoder, model, label, Precision::F32)
    }

    /// Engine serving at an explicit precision (see type docs).
    pub fn with_precision(
        encoder: Encoder,
        model: LogHdModel,
        label: impl Into<String>,
        precision: Precision,
    ) -> Self {
        let state = match precision {
            Precision::F32 => ModelState::Dense(model),
            Precision::B1 | Precision::B8 => {
                ModelState::Packed(QuantizedLogHdModel::from_model(&model, precision))
            }
            Precision::B2 | Precision::B4 => {
                let bundles = quant::quantize_roundtrip(&model.bundles, precision);
                let profiles = quant::quantize_roundtrip(&model.profiles, precision);
                ModelState::Dense(LogHdModel { bundles, profiles, ..model })
            }
        };
        Self { encoder, precision, state, label: label.into() }
    }

    /// The dense model, when this precision serves one (F32/B2/B4).
    pub fn model(&self) -> Option<&LogHdModel> {
        match &self.state {
            ModelState::Dense(m) => Some(m),
            ModelState::Packed(_) => None,
        }
    }

    /// The packed twin, when this precision serves one (B1/B8).
    pub fn quantized_model(&self) -> Option<&QuantizedLogHdModel> {
        match &self.state {
            ModelState::Dense(_) => None,
            ModelState::Packed(q) => Some(q),
        }
    }

    pub fn factory(encoder: Encoder, model: LogHdModel, label: String) -> EngineFactory {
        Self::factory_with_precision(encoder, model, label, Precision::F32)
    }

    /// Factory for [`super::Coordinator::start`] at an explicit precision.
    pub fn factory_with_precision(
        encoder: Encoder,
        model: LogHdModel,
        label: String,
        precision: Precision,
    ) -> EngineFactory {
        Box::new(move || {
            Ok(Box::new(NativeEngine::with_precision(encoder, model, label, precision))
                as Box<dyn Engine>)
        })
    }
}

impl Engine for NativeEngine {
    fn name(&self) -> String {
        format!("native:{}:{}", self.label, self.precision.label())
    }

    fn features(&self) -> usize {
        self.encoder.features()
    }

    fn infer(&mut self, x: &Matrix) -> Result<Vec<i32>> {
        let enc = self.encoder.encode(x);
        Ok(match &self.state {
            ModelState::Dense(model) => model.predict(&enc),
            ModelState::Packed(qm) => qm.predict(&enc),
        })
    }
}

/// The conventional-HDC baseline served natively: encoder + one-prototype-
/// per-class cosine argmax. Sub-f32 precisions are post-training-quantized
/// round-trips of the prototype matrix served through the f32 kernels
/// (there is no packed conventional kernel — the O(C·D) baseline exists
/// for tenant-mix comparisons, not throughput records).
pub struct ConventionalEngine {
    pub encoder: Encoder,
    pub precision: Precision,
    model: ConventionalModel,
    label: String,
}

impl ConventionalEngine {
    pub fn new(
        encoder: Encoder,
        model: ConventionalModel,
        label: impl Into<String>,
        precision: Precision,
    ) -> Self {
        let model = match precision {
            Precision::F32 => model,
            _ => ConventionalModel::new(quant::quantize_roundtrip(&model.prototypes, precision)),
        };
        Self { encoder, precision, model, label: label.into() }
    }

    /// Factory for [`super::Coordinator::start`] / `start_pool`.
    pub fn factory(
        encoder: Encoder,
        model: ConventionalModel,
        label: String,
        precision: Precision,
    ) -> EngineFactory {
        Box::new(move || {
            Ok(Box::new(ConventionalEngine::new(encoder, model, label, precision))
                as Box<dyn Engine>)
        })
    }
}

impl Engine for ConventionalEngine {
    fn name(&self) -> String {
        format!("conv:{}:{}", self.label, self.precision.label())
    }

    fn features(&self) -> usize {
        self.encoder.features()
    }

    fn infer(&mut self, x: &Matrix) -> Result<Vec<i32>> {
        let enc = self.encoder.encode(x);
        Ok(self.model.predict(&enc))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::loghd::model::{TrainOptions, TrainedStack};

    #[test]
    fn native_engine_serves() {
        let ds = data::generate_scaled(data::spec("page").unwrap(), 400, 50);
        let opts = TrainOptions { epochs: 2, conv_epochs: 0, extra_bundles: 1, ..Default::default() };
        let st = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 128, 1, &opts).unwrap();
        let mut engine = NativeEngine::new(st.encoder, st.loghd, "page");
        assert_eq!(engine.features(), 10);
        let labels = engine.infer(&ds.x_test.rows_slice(0, 10)).unwrap();
        assert_eq!(labels.len(), 10);
        assert!(labels.iter().all(|l| (0..5).contains(l)));
        assert!(engine.name().starts_with("native:"));
        assert!(engine.name().ends_with(":f32"));
    }

    #[test]
    fn native_engine_serves_every_precision() {
        let ds = data::generate_scaled(data::spec("page").unwrap(), 400, 50);
        let opts = TrainOptions { epochs: 2, conv_epochs: 0, extra_bundles: 1, ..Default::default() };
        let st = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 256, 1, &opts).unwrap();
        for precision in [
            Precision::F32,
            Precision::B8,
            Precision::B4,
            Precision::B2,
            Precision::B1,
        ] {
            let mut engine = NativeEngine::with_precision(
                st.encoder.clone(),
                st.loghd.clone(),
                "page",
                precision,
            );
            let labels = engine.infer(&ds.x_test.rows_slice(0, 16)).unwrap();
            assert_eq!(labels.len(), 16, "{precision:?}");
            assert!(labels.iter().all(|l| (0..5).contains(l)), "{precision:?}");
            assert!(engine.name().ends_with(precision.label()), "{precision:?}");
            // packed precisions must not keep the dense tensors alive
            let packed = matches!(precision, Precision::B1 | Precision::B8);
            assert_eq!(engine.model().is_none(), packed, "{precision:?}");
            assert_eq!(engine.quantized_model().is_some(), packed, "{precision:?}");
        }
    }

    #[test]
    fn conventional_engine_serves() {
        let ds = data::generate_scaled(data::spec("page").unwrap(), 400, 50);
        let opts = TrainOptions { epochs: 1, conv_epochs: 1, ..Default::default() };
        let st = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 128, 1, &opts).unwrap();
        let conv = ConventionalModel::new(st.prototypes.clone());
        for precision in [Precision::F32, Precision::B8] {
            let mut engine =
                ConventionalEngine::new(st.encoder.clone(), conv.clone(), "page", precision);
            assert_eq!(engine.features(), 10);
            let labels = engine.infer(&ds.x_test.rows_slice(0, 10)).unwrap();
            assert_eq!(labels.len(), 10);
            assert!(labels.iter().all(|l| (0..5).contains(l)));
            assert!(engine.name().starts_with("conv:"), "{}", engine.name());
        }
    }
}

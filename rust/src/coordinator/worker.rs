//! Inference engines the coordinator can run.
//!
//! Each worker replica in a [`super::Coordinator`] pool owns one engine
//! instance built from an [`EngineFactory`]; the pool pulls ready batches
//! (shards) off the shared queue in arrival order — round-robin across
//! idle replicas, least-loaded under skew.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::baselines::conventional::ConventionalModel;
use crate::encoder::Encoder;
use crate::loghd::model::{DecodePrep, LogHdModel};
use crate::loghd::qmodel::{QuantizedLogHdModel, QueryScratch};
use crate::model::HdClassifier;
use crate::quant::{self, Precision};
use crate::runtime::PjrtRuntime;
use crate::tensor::{Matrix, NtPrepared};

use super::{Engine, InferScratch};

/// Engines are built on the worker thread (PJRT handles are not Send):
/// the coordinator takes a factory, not an engine.
pub type EngineFactory = Box<dyn FnOnce() -> Result<Box<dyn Engine>> + Send>;

/// The AOT path: a compiled HLO entry served via PJRT.
pub struct PjrtEngine {
    runtime: PjrtRuntime,
    entry: String,
}

impl PjrtEngine {
    /// Load an artifact bundle and serve `entry` (e.g. "infer_loghd").
    pub fn load(dir: &Path, entry: &str) -> Result<Self> {
        let runtime = PjrtRuntime::load(dir)?;
        runtime
            .manifest
            .entry(entry)
            .with_context(|| format!("bundle has no entry '{entry}'"))?;
        Ok(Self { runtime, entry: entry.to_string() })
    }

    /// Factory for [`super::Coordinator::start`].
    pub fn factory(dir: PathBuf, entry: String) -> EngineFactory {
        Box::new(move || Ok(Box::new(PjrtEngine::load(&dir, &entry)?) as Box<dyn Engine>))
    }

    pub fn runtime(&self) -> &PjrtRuntime {
        &self.runtime
    }

    pub fn runtime_mut(&mut self) -> &mut PjrtRuntime {
        &mut self.runtime
    }
}

impl Engine for PjrtEngine {
    fn name(&self) -> String {
        format!("pjrt:{}:{}", self.runtime.manifest.name, self.entry)
    }

    fn features(&self) -> usize {
        self.runtime.manifest.features
    }

    fn infer(&mut self, x: &Matrix) -> Result<Vec<i32>> {
        self.runtime.infer_labels(&self.entry, x)
    }

    fn infer_into<'s>(&mut self, x: &Matrix, scratch: &'s mut InferScratch) -> Result<&'s [i32]> {
        // PJRT allocates device buffers at the FFI boundary regardless;
        // the labels vec is the only host-side piece worth reusing.
        scratch.labels = self.runtime.infer_labels(&self.entry, x)?;
        Ok(&scratch.labels)
    }
}

/// The native path: encoder + LogHD decode in pure Rust, at a selectable
/// serving precision.
///
/// - `F32` (default): the dense model as trained.
/// - `B1` / `B8`: the bit-packed twin (`loghd::qmodel`) — XNOR/popcount
///   resp. int8/i32 kernels over the packed stored state.
/// - `B2` / `B4`: post-training-quantized weights served through the f32
///   kernels (no packed kernel exists at those widths).
pub struct NativeEngine {
    pub encoder: Encoder,
    pub precision: Precision,
    state: ModelState,
    label: String,
}

/// What the engine actually holds: the dense f32 tensors are dropped at
/// the packed precisions — keeping both would make the memory-reduction
/// mode cost *more* memory per worker than plain f32. Both variants
/// carry per-replica serving state the model structs themselves don't:
/// prepared GEMM operand forms (built once at engine construction) and,
/// for the packed path, the reusable query-quantization scratch.
enum ModelState {
    Dense(DenseDecode),
    Packed { model: QuantizedLogHdModel, scratch: QueryScratch },
}

/// A dense LogHD model plus its request-invariant decode state
/// ([`DecodePrep`]: prepared GEMM operand forms + `|P|²`), built once at
/// engine construction. The decode pipeline itself stays on the model
/// type (`LogHdModel::predict_prepared`) so serving cannot drift from
/// the reference `predict`.
struct DenseDecode {
    model: LogHdModel,
    prep: DecodePrep,
}

impl DenseDecode {
    fn new(model: LogHdModel) -> Self {
        let prep = DecodePrep::new(&model);
        Self { model, prep }
    }

    fn predict(&self, enc: &Matrix) -> Vec<i32> {
        self.model.predict_prepared(enc, &self.prep)
    }
}

impl NativeEngine {
    /// F32 engine (the historical constructor).
    pub fn new(encoder: Encoder, model: LogHdModel, label: impl Into<String>) -> Self {
        Self::with_precision(encoder, model, label, Precision::F32)
    }

    /// Engine serving at an explicit precision (see type docs).
    pub fn with_precision(
        encoder: Encoder,
        model: LogHdModel,
        label: impl Into<String>,
        precision: Precision,
    ) -> Self {
        let state = match precision {
            Precision::F32 => ModelState::Dense(DenseDecode::new(model)),
            Precision::B1 | Precision::B8 => ModelState::Packed {
                model: QuantizedLogHdModel::from_model(&model, precision),
                scratch: QueryScratch::new(),
            },
            Precision::B2 | Precision::B4 => {
                let bundles = quant::quantize_roundtrip(&model.bundles, precision);
                let profiles = quant::quantize_roundtrip(&model.profiles, precision);
                ModelState::Dense(DenseDecode::new(LogHdModel { bundles, profiles, ..model }))
            }
        };
        Self { encoder, precision, state, label: label.into() }
    }

    /// The dense model, when this precision serves one (F32/B2/B4).
    pub fn model(&self) -> Option<&LogHdModel> {
        match &self.state {
            ModelState::Dense(d) => Some(&d.model),
            ModelState::Packed { .. } => None,
        }
    }

    /// The packed twin, when this precision serves one (B1/B8).
    pub fn quantized_model(&self) -> Option<&QuantizedLogHdModel> {
        match &self.state {
            ModelState::Dense(_) => None,
            ModelState::Packed { model, .. } => Some(model),
        }
    }

    pub fn factory(encoder: Encoder, model: LogHdModel, label: String) -> EngineFactory {
        Self::factory_with_precision(encoder, model, label, Precision::F32)
    }

    /// Factory for [`super::Coordinator::start`] at an explicit precision.
    pub fn factory_with_precision(
        encoder: Encoder,
        model: LogHdModel,
        label: String,
        precision: Precision,
    ) -> EngineFactory {
        Box::new(move || {
            Ok(Box::new(NativeEngine::with_precision(encoder, model, label, precision))
                as Box<dyn Engine>)
        })
    }
}

impl Engine for NativeEngine {
    fn name(&self) -> String {
        format!("native:{}:{}", self.label, self.precision.label())
    }

    fn features(&self) -> usize {
        self.encoder.features()
    }

    fn infer(&mut self, x: &Matrix) -> Result<Vec<i32>> {
        let enc = self.encoder.encode(x);
        Ok(match &mut self.state {
            ModelState::Dense(dense) => dense.predict(&enc),
            ModelState::Packed { model, scratch } => model.predict_scratch(&enc, scratch),
        })
    }

    fn infer_into<'s>(&mut self, x: &Matrix, s: &'s mut InferScratch) -> Result<&'s [i32]> {
        self.encoder.encode_into(x, &mut s.enc);
        match &mut self.state {
            ModelState::Dense(dense) => dense.model.predict_prepared_into(
                &s.enc,
                &dense.prep,
                &mut s.acts,
                &mut s.dists,
                &mut s.asq,
                &mut s.labels,
            ),
            ModelState::Packed { model, scratch } => model.predict_into(
                &s.enc,
                scratch,
                &mut s.acts,
                &mut s.dists,
                &mut s.asq,
                &mut s.labels,
            ),
        }
        Ok(&s.labels)
    }
}

/// The conventional-HDC baseline served natively: encoder + one-prototype-
/// per-class cosine argmax. Sub-f32 precisions are post-training-quantized
/// round-trips of the prototype matrix served through the f32 kernels
/// (there is no packed conventional kernel — the O(C·D) baseline exists
/// for tenant-mix comparisons, not throughput records).
pub struct ConventionalEngine {
    pub encoder: Encoder,
    pub precision: Precision,
    model: ConventionalModel,
    /// Prepared GEMM form of the (C, D) prototype matrix — C sits
    /// squarely in the mid-width regime for most datasets, so this is
    /// the transposed copy that used to be rebuilt every batch.
    prototypes_prep: NtPrepared,
    label: String,
}

impl ConventionalEngine {
    pub fn new(
        encoder: Encoder,
        model: ConventionalModel,
        label: impl Into<String>,
        precision: Precision,
    ) -> Self {
        let model = match precision {
            Precision::F32 => model,
            _ => ConventionalModel::new(quant::quantize_roundtrip(&model.prototypes, precision)),
        };
        let prototypes_prep = model.prepare();
        Self { encoder, precision, model, prototypes_prep, label: label.into() }
    }

    /// Factory for [`super::Coordinator::start`] / `start_pool`.
    pub fn factory(
        encoder: Encoder,
        model: ConventionalModel,
        label: String,
        precision: Precision,
    ) -> EngineFactory {
        Box::new(move || {
            Ok(Box::new(ConventionalEngine::new(encoder, model, label, precision))
                as Box<dyn Engine>)
        })
    }
}

impl Engine for ConventionalEngine {
    fn name(&self) -> String {
        format!("conv:{}:{}", self.label, self.precision.label())
    }

    fn features(&self) -> usize {
        self.encoder.features()
    }

    fn infer(&mut self, x: &Matrix) -> Result<Vec<i32>> {
        let enc = self.encoder.encode(x);
        Ok(self.model.predict_prepared(&enc, &self.prototypes_prep))
    }

    fn infer_into<'s>(&mut self, x: &Matrix, s: &'s mut InferScratch) -> Result<&'s [i32]> {
        self.encoder.encode_into(x, &mut s.enc);
        self.model.predict_prepared_into(&s.enc, &self.prototypes_prep, &mut s.acts, &mut s.labels);
        Ok(&s.labels)
    }
}

/// Shared cascade telemetry, aggregated across all replicas of a tenant
/// (each replica's engine holds a clone of the same `Arc`). Relaxed
/// ordering everywhere: these are monotone counters read by the stats
/// path, not synchronization.
#[derive(Debug, Default)]
pub struct CascadeCounters {
    /// Rows answered by the b1 tier (margin cleared the threshold).
    pub tier1: AtomicU64,
    /// Rows escalated to the exact tier.
    pub escalated: AtomicU64,
    /// Escalated rows whose tentative b1 label matched the exact label —
    /// observed b1/exact agreement on exactly the traffic the cascade
    /// was *least* confident about (tier-1 rows are answered by b1 and
    /// covered by the offline calibration bound instead).
    pub agreed: AtomicU64,
}

impl CascadeCounters {
    pub fn new() -> Self {
        Self::default()
    }

    /// One consistent-enough read of (tier1, escalated, agreed).
    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.tier1.load(Ordering::Relaxed),
            self.escalated.load(Ordering::Relaxed),
            self.agreed.load(Ordering::Relaxed),
        )
    }
}

/// The adaptive precision cascade: a packed b1 prefilter in front of an
/// exact decode tier.
///
/// Every batch is encoded once, then decoded by the b1 XNOR/popcount
/// twin in one fused pass. Rows whose normalized decode margin
/// (runner-up minus best squared distance, per-model normalized — see
/// `QuantizedLogHdModel::margin_scale`) is `>= threshold` are answered
/// from the b1 tier immediately; the ambiguous remainder is gathered
/// into a compacted sub-batch (row copies out of the already-encoded
/// batch — no re-encode) and decoded by the exact tier (dense f32/b2/b4
/// or packed b8, mirroring [`NativeEngine`]'s state split). Exact labels
/// are scattered back over the tentative b1 labels.
///
/// Degenerate thresholds pin the semantics: `0.0` never escalates
/// (margins are non-negative, so every row clears the gate) and
/// `f32::INFINITY` always escalates — making the cascade bit-identical
/// to the exact engine (the engine tests assert both ends). Operating
/// thresholds come from the offline calibrator
/// (`loghd::cascade::calibrate`, persisted in the artifact's
/// `ModelCard` and enforced at registry admission).
///
/// `infer_into` allocates nothing at steady state: every intermediate —
/// including the escalation gather — lives in [`InferScratch`]'s
/// cascade fields and the engine-owned query scratches.
pub struct CascadeEngine {
    pub encoder: Encoder,
    /// Exact-tier precision (the cascade's own prefilter is always b1).
    pub exact_precision: Precision,
    b1: QuantizedLogHdModel,
    b1_scratch: QueryScratch,
    exact: ModelState,
    threshold: f32,
    label: String,
    counters: Arc<CascadeCounters>,
}

impl CascadeEngine {
    /// Build the cascade from a trained dense model: quantize the b1
    /// prefilter twin and materialize the exact tier at
    /// `exact_precision` (any width except b1 — a b1 exact tier would
    /// make escalation a no-op).
    pub fn with_precision(
        encoder: Encoder,
        model: LogHdModel,
        label: impl Into<String>,
        exact_precision: Precision,
        threshold: f32,
        counters: Arc<CascadeCounters>,
    ) -> Self {
        assert!(
            exact_precision != Precision::B1,
            "cascade exact tier must be wider than the b1 prefilter"
        );
        assert!(threshold >= 0.0, "cascade threshold must be non-negative");
        let b1 = QuantizedLogHdModel::from_model(&model, Precision::B1);
        Self::from_parts(encoder, b1, model, label, exact_precision, threshold, counters)
    }

    /// Assemble the cascade from an explicit b1 prefilter (tests inject
    /// faults into the packed twin before serving it) plus the dense
    /// model the exact tier is derived from.
    pub fn from_parts(
        encoder: Encoder,
        b1: QuantizedLogHdModel,
        model: LogHdModel,
        label: impl Into<String>,
        exact_precision: Precision,
        threshold: f32,
        counters: Arc<CascadeCounters>,
    ) -> Self {
        assert_eq!(b1.precision, Precision::B1, "prefilter must be the b1 twin");
        let exact = match exact_precision {
            Precision::B1 => unreachable!("checked by constructors"),
            Precision::F32 => ModelState::Dense(DenseDecode::new(model)),
            Precision::B8 => ModelState::Packed {
                model: QuantizedLogHdModel::from_model(&model, Precision::B8),
                scratch: QueryScratch::new(),
            },
            p @ (Precision::B2 | Precision::B4) => {
                let bundles = quant::quantize_roundtrip(&model.bundles, p);
                let profiles = quant::quantize_roundtrip(&model.profiles, p);
                ModelState::Dense(DenseDecode::new(LogHdModel { bundles, profiles, ..model }))
            }
        };
        Self {
            encoder,
            exact_precision,
            b1,
            b1_scratch: QueryScratch::new(),
            exact,
            threshold,
            label: label.into(),
            counters,
        }
    }

    /// Factory for [`super::Coordinator::start`] / `start_pool`. Every
    /// replica built from factories sharing one `counters` Arc reports
    /// into the same per-tenant cascade telemetry.
    pub fn factory_with_precision(
        encoder: Encoder,
        model: LogHdModel,
        label: String,
        exact_precision: Precision,
        threshold: f32,
        counters: Arc<CascadeCounters>,
    ) -> EngineFactory {
        Box::new(move || {
            Ok(Box::new(CascadeEngine::with_precision(
                encoder,
                model,
                label,
                exact_precision,
                threshold,
                counters,
            )) as Box<dyn Engine>)
        })
    }

    /// The calibrated operating threshold this engine gates on.
    pub fn threshold(&self) -> f32 {
        self.threshold
    }

    /// The shared telemetry this engine reports into.
    pub fn counters(&self) -> &Arc<CascadeCounters> {
        &self.counters
    }
}

impl Engine for CascadeEngine {
    fn name(&self) -> String {
        format!("cascade:{}:b1->{}", self.label, self.exact_precision.label())
    }

    fn features(&self) -> usize {
        self.encoder.features()
    }

    fn infer(&mut self, x: &Matrix) -> Result<Vec<i32>> {
        let mut scratch = InferScratch::new();
        self.infer_into(x, &mut scratch)?;
        Ok(std::mem::take(&mut scratch.labels))
    }

    fn infer_into<'s>(&mut self, x: &Matrix, s: &'s mut InferScratch) -> Result<&'s [i32]> {
        self.encoder.encode_into(x, &mut s.enc);
        // Tier 1: fused b1 decode + margins over the whole batch.
        self.b1.predict_margins_into(
            &s.enc,
            &mut self.b1_scratch,
            &mut s.acts,
            &mut s.dists,
            &mut s.asq,
            &mut s.labels,
            &mut s.margins,
        );
        // Partition: a row escalates when its margin fails the gate
        // (margins are non-negative, so threshold 0 keeps every row in
        // tier 1 and +inf escalates everything with a runner-up).
        s.esc_rows.clear();
        for (i, &m) in s.margins.iter().enumerate() {
            if m < self.threshold {
                s.esc_rows.push(i as u32);
            }
        }
        let esc = s.esc_rows.len();
        if esc > 0 {
            // Gather the escalated rows (already encoded) into the
            // compacted sub-batch. `Matrix::resize` reuses its backing
            // allocation, and every exposed row is fully overwritten.
            s.esc_enc.resize(esc, s.enc.cols());
            for (k, &i) in s.esc_rows.iter().enumerate() {
                s.esc_enc.row_mut(k).copy_from_slice(s.enc.row(i as usize));
            }
            match &mut self.exact {
                ModelState::Dense(dense) => dense.model.predict_prepared_into(
                    &s.esc_enc,
                    &dense.prep,
                    &mut s.esc_acts,
                    &mut s.esc_dists,
                    &mut s.esc_asq,
                    &mut s.esc_labels,
                ),
                ModelState::Packed { model, scratch } => model.predict_into(
                    &s.esc_enc,
                    scratch,
                    &mut s.esc_acts,
                    &mut s.esc_dists,
                    &mut s.esc_asq,
                    &mut s.esc_labels,
                ),
            }
            // Scatter exact labels back, counting b1/exact agreement on
            // the escalated traffic as we go.
            let mut agreed = 0u64;
            for (k, &i) in s.esc_rows.iter().enumerate() {
                let exact = s.esc_labels[k];
                if exact == s.labels[i as usize] {
                    agreed += 1;
                }
                s.labels[i as usize] = exact;
            }
            self.counters.escalated.fetch_add(esc as u64, Ordering::Relaxed);
            self.counters.agreed.fetch_add(agreed, Ordering::Relaxed);
        }
        self.counters.tier1.fetch_add((x.rows() - esc) as u64, Ordering::Relaxed);
        Ok(&s.labels)
    }
}

/// The generic model-zoo engine: encoder + any [`HdClassifier`]
/// instance (see `model::instances`). Families without a specialized
/// serving engine (currently DecoHD) serve through this — the trait's
/// `predict` is the same code path the fault sweeps evaluate, so a
/// family registered in `model::zoo` is servable with zero extra
/// wiring. LogHD keeps [`NativeEngine`] (prepared GEMM operands, query
/// scratch) and the conventional baseline keeps [`ConventionalEngine`];
/// both predate this engine and stay for their hot-path state.
pub struct ZooEngine {
    pub encoder: Encoder,
    pub precision: Precision,
    model: Box<dyn HdClassifier>,
    label: String,
}

impl ZooEngine {
    pub fn new(
        encoder: Encoder,
        model: Box<dyn HdClassifier>,
        label: impl Into<String>,
        precision: Precision,
    ) -> Self {
        Self { encoder, precision, model, label: label.into() }
    }

    /// The instance being served (inspection / tests).
    pub fn model(&self) -> &dyn HdClassifier {
        self.model.as_ref()
    }
}

impl Engine for ZooEngine {
    fn name(&self) -> String {
        format!("{}:{}:{}", self.model.kind(), self.label, self.precision.label())
    }

    fn features(&self) -> usize {
        self.encoder.features()
    }

    fn infer(&mut self, x: &Matrix) -> Result<Vec<i32>> {
        let enc = self.encoder.encode(x);
        Ok(self.model.predict(&enc))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::loghd::model::{TrainOptions, TrainedStack};

    #[test]
    fn native_engine_serves() {
        let ds = data::generate_scaled(data::spec("page").unwrap(), 400, 50);
        let opts = TrainOptions { epochs: 2, conv_epochs: 0, extra_bundles: 1, ..Default::default() };
        let st = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 128, 1, &opts).unwrap();
        let mut engine = NativeEngine::new(st.encoder, st.loghd, "page");
        assert_eq!(engine.features(), 10);
        let labels = engine.infer(&ds.x_test.rows_slice(0, 10)).unwrap();
        assert_eq!(labels.len(), 10);
        assert!(labels.iter().all(|l| (0..5).contains(l)));
        assert!(engine.name().starts_with("native:"));
        assert!(engine.name().ends_with(":f32"));
    }

    #[test]
    fn native_engine_serves_every_precision() {
        let ds = data::generate_scaled(data::spec("page").unwrap(), 400, 50);
        let opts = TrainOptions { epochs: 2, conv_epochs: 0, extra_bundles: 1, ..Default::default() };
        let st = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 256, 1, &opts).unwrap();
        for precision in [
            Precision::F32,
            Precision::B8,
            Precision::B4,
            Precision::B2,
            Precision::B1,
        ] {
            let mut engine = NativeEngine::with_precision(
                st.encoder.clone(),
                st.loghd.clone(),
                "page",
                precision,
            );
            let labels = engine.infer(&ds.x_test.rows_slice(0, 16)).unwrap();
            assert_eq!(labels.len(), 16, "{precision:?}");
            assert!(labels.iter().all(|l| (0..5).contains(l)), "{precision:?}");
            assert!(engine.name().ends_with(precision.label()), "{precision:?}");
            // packed precisions must not keep the dense tensors alive
            let packed = matches!(precision, Precision::B1 | Precision::B8);
            assert_eq!(engine.model().is_none(), packed, "{precision:?}");
            assert_eq!(engine.quantized_model().is_some(), packed, "{precision:?}");
        }
    }

    #[test]
    fn engines_match_plain_model_predictions() {
        // The prepared-operand serving paths (hoisted transposes, query
        // scratch) must be prediction-identical to the model structs'
        // own predict methods.
        let ds = data::generate_scaled(data::spec("page").unwrap(), 400, 50);
        let opts =
            TrainOptions { epochs: 2, conv_epochs: 1, extra_bundles: 1, ..Default::default() };
        let st = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 512, 9, &opts).unwrap();
        let xb = ds.x_test.rows_slice(0, 24);
        let enc = st.encoder.encode(&xb);
        for precision in [Precision::F32, Precision::B8, Precision::B1] {
            let mut engine = NativeEngine::with_precision(
                st.encoder.clone(),
                st.loghd.clone(),
                "page",
                precision,
            );
            let want = match precision {
                Precision::F32 => st.loghd.predict(&enc),
                p => QuantizedLogHdModel::from_model(&st.loghd, p).predict(&enc),
            };
            assert_eq!(engine.infer(&xb).unwrap(), want, "{precision:?}");
        }
        let conv = ConventionalModel::new(st.prototypes.clone());
        let mut engine =
            ConventionalEngine::new(st.encoder.clone(), conv.clone(), "page", Precision::F32);
        assert_eq!(engine.infer(&xb).unwrap(), conv.predict(&enc));
    }

    #[test]
    fn infer_into_matches_infer_for_every_engine() {
        // The scratch-reusing serving form must be bit-identical to the
        // allocating `infer` for every engine kind — ONE InferScratch is
        // deliberately shared across engines, precisions, and batch
        // sizes (grow, shrink, regrow) to prove stale scratch contents
        // never leak into a prediction.
        let ds = data::generate_scaled(data::spec("page").unwrap(), 400, 50);
        let opts =
            TrainOptions { epochs: 2, conv_epochs: 1, extra_bundles: 1, ..Default::default() };
        let st = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 512, 9, &opts).unwrap();
        let mut scratch = InferScratch::new();
        let batches = [
            ds.x_test.rows_slice(0, 24),
            ds.x_test.rows_slice(24, 31),
            ds.x_test.rows_slice(0, 24),
        ];
        for precision in [
            Precision::F32,
            Precision::B8,
            Precision::B4,
            Precision::B2,
            Precision::B1,
        ] {
            let mut engine = NativeEngine::with_precision(
                st.encoder.clone(),
                st.loghd.clone(),
                "page",
                precision,
            );
            for xb in &batches {
                let want = engine.infer(xb).unwrap();
                let got = engine.infer_into(xb, &mut scratch).unwrap();
                assert_eq!(got, want.as_slice(), "native {precision:?}");
            }
        }
        let conv = ConventionalModel::new(st.prototypes.clone());
        let mut engine = ConventionalEngine::new(st.encoder.clone(), conv, "page", Precision::F32);
        for xb in &batches {
            let want = engine.infer(xb).unwrap();
            assert_eq!(engine.infer_into(xb, &mut scratch).unwrap(), want.as_slice(), "conv");
        }
        let deco = crate::baselines::DecoHdModel::from_prototypes(&st.prototypes, 3).unwrap();
        let mut engine = ZooEngine::new(
            st.encoder.clone(),
            crate::model::instances::decohd(&deco, Precision::F32),
            "page",
            Precision::F32,
        );
        for xb in &batches {
            // ZooEngine has no override: this pins the trait default.
            let want = engine.infer(xb).unwrap();
            assert_eq!(engine.infer_into(xb, &mut scratch).unwrap(), want.as_slice(), "zoo");
        }
        // Cascade at both degenerate thresholds, still on the SAME
        // shared scratch (the escalation buffers must tolerate reuse
        // alongside every other engine kind).
        for (threshold, exact) in
            [(0.0f32, Precision::F32), (f32::INFINITY, Precision::F32), (f32::INFINITY, Precision::B8)]
        {
            let mut engine = CascadeEngine::with_precision(
                st.encoder.clone(),
                st.loghd.clone(),
                "page",
                exact,
                threshold,
                Arc::new(CascadeCounters::new()),
            );
            for xb in &batches {
                let want = engine.infer(xb).unwrap();
                let got = engine.infer_into(xb, &mut scratch).unwrap();
                assert_eq!(got, want.as_slice(), "cascade t={threshold} exact={exact:?}");
            }
        }
    }

    #[test]
    fn cascade_degenerate_thresholds_pin_both_tiers() {
        let ds = data::generate_scaled(data::spec("page").unwrap(), 400, 50);
        let opts =
            TrainOptions { epochs: 2, conv_epochs: 1, extra_bundles: 1, ..Default::default() };
        let st = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 512, 9, &opts).unwrap();
        let xb = ds.x_test.rows_slice(0, 32);
        let mut scratch = InferScratch::new();

        // Threshold 0: never escalate — output is exactly the b1 twin's.
        let counters = Arc::new(CascadeCounters::new());
        let mut never = CascadeEngine::with_precision(
            st.encoder.clone(),
            st.loghd.clone(),
            "page",
            Precision::F32,
            0.0,
            counters.clone(),
        );
        let got = never.infer_into(&xb, &mut scratch).unwrap().to_vec();
        let mut b1 = NativeEngine::with_precision(
            st.encoder.clone(),
            st.loghd.clone(),
            "page",
            Precision::B1,
        );
        assert_eq!(got, b1.infer(&xb).unwrap(), "threshold 0 must be the pure b1 path");
        assert_eq!(counters.snapshot(), (32, 0, 0), "threshold 0 escalated rows");

        // Threshold +inf: always escalate — bit-identical to the exact
        // engine at each exact-tier precision.
        for exact in [Precision::F32, Precision::B8, Precision::B4, Precision::B2] {
            let counters = Arc::new(CascadeCounters::new());
            let mut always = CascadeEngine::with_precision(
                st.encoder.clone(),
                st.loghd.clone(),
                "page",
                exact,
                f32::INFINITY,
                counters.clone(),
            );
            let got = always.infer_into(&xb, &mut scratch).unwrap().to_vec();
            let mut exact_engine = NativeEngine::with_precision(
                st.encoder.clone(),
                st.loghd.clone(),
                "page",
                exact,
            );
            assert_eq!(
                got,
                exact_engine.infer(&xb).unwrap(),
                "threshold inf must be bit-identical to the exact {exact:?} engine"
            );
            let (tier1, escalated, _) = counters.snapshot();
            assert_eq!((tier1, escalated), (0, 32), "{exact:?}: rows not all escalated");
        }
        assert!(never.name().starts_with("cascade:page:b1->"), "{}", never.name());
    }

    #[test]
    fn zoo_engine_serves_decohd_at_every_precision() {
        let ds = data::generate_scaled(data::spec("page").unwrap(), 400, 50);
        let opts = TrainOptions { epochs: 1, conv_epochs: 1, ..Default::default() };
        let st = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 128, 1, &opts).unwrap();
        let deco =
            crate::baselines::DecoHdModel::from_prototypes(&st.prototypes, 3).unwrap();
        for precision in [Precision::F32, Precision::B8, Precision::B1] {
            let mut engine = ZooEngine::new(
                st.encoder.clone(),
                crate::model::instances::decohd(&deco, precision),
                "page",
                precision,
            );
            assert_eq!(engine.features(), 10);
            let labels = engine.infer(&ds.x_test.rows_slice(0, 12)).unwrap();
            assert_eq!(labels.len(), 12, "{precision:?}");
            assert!(labels.iter().all(|l| (0..5).contains(l)), "{precision:?}");
            assert!(engine.name().starts_with("decohd:page:"), "{}", engine.name());
            assert_eq!(engine.model().kind(), "decohd");
        }
        // f32 serving must equal the model's own predict
        let mut engine = ZooEngine::new(
            st.encoder.clone(),
            crate::model::instances::decohd(&deco, Precision::F32),
            "page",
            Precision::F32,
        );
        let xb = ds.x_test.rows_slice(0, 20);
        assert_eq!(engine.infer(&xb).unwrap(), deco.predict(&st.encoder.encode(&xb)));
    }

    #[test]
    fn conventional_engine_serves() {
        let ds = data::generate_scaled(data::spec("page").unwrap(), 400, 50);
        let opts = TrainOptions { epochs: 1, conv_epochs: 1, ..Default::default() };
        let st = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 128, 1, &opts).unwrap();
        let conv = ConventionalModel::new(st.prototypes.clone());
        for precision in [Precision::F32, Precision::B8] {
            let mut engine =
                ConventionalEngine::new(st.encoder.clone(), conv.clone(), "page", precision);
            assert_eq!(engine.features(), 10);
            let labels = engine.infer(&ds.x_test.rows_slice(0, 10)).unwrap();
            assert_eq!(labels.len(), 10);
            assert!(labels.iter().all(|l| (0..5).contains(l)));
            assert!(engine.name().starts_with("conv:"), "{}", engine.name());
        }
    }
}

//! Multi-tenant model registry: named models → sharded coordinator pools.
//!
//! The paper's pitch is that class-axis reduction makes a classifier
//! O(D·log_k C) instead of O(C·D) — small enough to pack *many* models
//! into one serving budget. This module is that packing layer: a
//! [`ModelRegistry`] hosts several named tenants, each a
//! [`Coordinator`] pool of worker replicas at its own precision
//! (f32 / int8 / 1-bit, LogHD or the conventional baseline), routes
//! requests by tenant name with per-tenant backpressure, and hot-swaps a
//! tenant's artifact in place without dropping in-flight requests.
//!
//! The TCP front-end ([`super::Server`]) speaks to this registry; see
//! `docs/PROTOCOL.md` for the wire protocol (the `"model"` routing field
//! and the `models` / `reload` admin verbs map 1:1 onto this API).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::loghd::online::{FeedbackError, OnlineTrainer, TrainerStats};
use crate::model::zoo;
use crate::quant::Precision;
use crate::runtime::artifact::ModelCard;

use super::batcher::{
    BatcherConfig, CompletionSink, Coordinator, Response, ResponseCallback, SubmitError, Ticket,
};
use super::stats::StatsSnapshot;
use super::worker::{CascadeCounters, CascadeEngine, EngineFactory, NativeEngine};

/// How one tenant is provisioned: artifact path, serving precision, and
/// replica count.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub name: String,
    pub path: PathBuf,
    pub precision: Precision,
    pub replicas: usize,
    /// Serve through the precision cascade: b1 prefilter + margin-gated
    /// escalation to the exact tier at `precision`. Admission requires a
    /// calibrated `cascade_threshold` in the artifact's model card (run
    /// `loghd calibrate`) and an exact tier wider than b1.
    pub cascade: bool,
}

impl TenantSpec {
    /// Parse one `name=path[:bits]` CLI fragment (`loghd serve --model`).
    /// A bare `path` names the tenant after the directory basename
    /// (computed *after* any `:bits` suffix is stripped); a missing
    /// `:bits` suffix falls back to `default_bits`. The suffix is only
    /// treated as bits when it is a *valid* precision (1|2|4|8|32), so a
    /// directory like `/data/nightly:2024` parses as a plain path; the
    /// residual ambiguity is a directory literally ending in one of the
    /// five valid suffixes — rename it or symlink around it.
    pub fn parse(fragment: &str, default_bits: u32, replicas: usize) -> Result<Self> {
        let (explicit_name, rest) = match fragment.split_once('=') {
            Some((n, r)) => (Some(n.to_string()), r),
            None => (None, fragment),
        };
        let parsed = rest.rsplit_once(':').and_then(|(p, suffix)| {
            let b = suffix.parse::<u32>().ok()?;
            Precision::from_bits(b).map(|precision| (p.to_string(), precision))
        });
        let (path, precision) = match parsed {
            Some(pair) => pair,
            None => {
                let precision = Precision::from_bits(default_bits)
                    .with_context(|| format!("--bits must be 1|2|4|8|32, got {default_bits}"))?;
                (rest.to_string(), precision)
            }
        };
        let name = explicit_name.unwrap_or_else(|| {
            Path::new(&path)
                .file_name()
                .and_then(|s| s.to_str())
                .unwrap_or("default")
                .to_string()
        });
        if name.is_empty() || path.is_empty() {
            bail!("bad model spec '{fragment}' (want name=path[:bits])");
        }
        Ok(Self { name, path: PathBuf::from(path), precision, replicas, cascade: false })
    }
}

/// Why the registry refused a request (maps to the wire protocol's
/// `{"error", "code"}` replies — see [`RouteError::code`]).
#[derive(Debug)]
pub enum RouteError {
    UnknownModel(String),
    Submit { model: String, err: SubmitError },
    Reload { model: String, message: String },
    /// The `feedback` verb hit a tenant with no attached trainer.
    NoTrainer(String),
    /// The tenant's trainer rejected a feedback sample.
    Feedback { model: String, err: FeedbackError },
}

impl RouteError {
    /// Stable machine-readable code for the wire protocol.
    pub fn code(&self) -> &'static str {
        match self {
            RouteError::UnknownModel(_) => "unknown_model",
            RouteError::Submit { err: SubmitError::QueueFull(_), .. } => "backpressure",
            RouteError::Submit { err: SubmitError::BadWidth { .. }, .. } => "bad_width",
            RouteError::Submit { err: SubmitError::ShutDown, .. } => "shutdown",
            RouteError::Submit { err: SubmitError::EngineFailure, .. } => "engine_error",
            RouteError::Reload { .. } => "reload_failed",
            RouteError::NoTrainer(_) => "no_trainer",
            RouteError::Feedback { err: FeedbackError::BadLabel { .. }, .. } => "bad_label",
            RouteError::Feedback { err: FeedbackError::BadWidth { .. }, .. } => "bad_width",
        }
    }
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::UnknownModel(m) => write!(f, "unknown model '{m}'"),
            RouteError::Submit { model, err } => write!(f, "model '{model}': {err}"),
            RouteError::Reload { model, message } => {
                write!(f, "reload of '{model}' failed: {message}")
            }
            RouteError::NoTrainer(m) => {
                write!(f, "model '{m}' has no online trainer attached")
            }
            RouteError::Feedback { model, err } => write!(f, "model '{model}': {err}"),
        }
    }
}

impl std::error::Error for RouteError {}

/// Point-in-time description of one tenant (the `models` admin verb).
#[derive(Debug, Clone)]
pub struct TenantInfo {
    pub name: String,
    pub kind: String,
    pub path: Option<PathBuf>,
    pub precision: &'static str,
    pub replicas: usize,
    /// Replicas actually serving; < `replicas` when one died at startup.
    pub live_replicas: usize,
    pub features: usize,
    pub is_default: bool,
    pub stats: StatsSnapshot,
    /// Online-trainer counters, for tenants with a trainer attached.
    pub trainer: Option<TrainerStats>,
    /// Cascade operating point + tier counters, for `--cascade` tenants.
    pub cascade: Option<CascadeSnapshot>,
}

/// Point-in-time cascade telemetry for one tenant (the `stats` /
/// `models` verbs): the calibrated operating threshold plus the shared
/// [`CascadeCounters`] every replica in the pool reports into.
#[derive(Debug, Clone, Copy)]
pub struct CascadeSnapshot {
    /// Normalized-margin threshold the pool is currently gating on.
    pub threshold: f32,
    /// Rows answered by the b1 tier since startup.
    pub tier1: u64,
    /// Rows escalated to the exact tier since startup.
    pub escalated: u64,
    /// Escalated rows whose tentative b1 label matched the exact label.
    pub agreed: u64,
}

/// Live cascade state for one tenant. The counters Arc is created once
/// at `open` and survives hot reloads and online publishes, so the
/// tier-1/escalation counters stay monotone across generations; only
/// the threshold is refreshed from the incoming artifact's model card.
struct CascadeState {
    threshold: f32,
    counters: Arc<CascadeCounters>,
}

impl CascadeState {
    fn snapshot(&self) -> CascadeSnapshot {
        let (tier1, escalated, agreed) = self.counters.snapshot();
        CascadeSnapshot { threshold: self.threshold, tier1, escalated, agreed }
    }
}

/// Mutable tenant metadata, swapped under lock on hot reload.
struct TenantMeta {
    kind: String,
    path: Option<PathBuf>,
    precision: Precision,
}

struct Tenant {
    coordinator: Arc<Coordinator>,
    meta: Mutex<TenantMeta>,
    /// The tenant's name as a shared `Arc<str>` so the ticket path can
    /// stamp replies with the model name without a per-request `String`.
    name: Arc<str>,
    /// Streaming trainer, when the tenant learns online (`feedback`
    /// verb). The mutex serializes ingest/refit/publish; inference
    /// never takes it.
    trainer: Mutex<Option<OnlineTrainer>>,
    /// Cascade operating point + shared counters, for `--cascade`
    /// tenants; `None` tenants serve their precision directly.
    cascade: Mutex<Option<CascadeState>>,
}

impl Tenant {
    fn new(coordinator: Arc<Coordinator>, meta: TenantMeta, name: &str) -> Self {
        Self {
            coordinator,
            meta: Mutex::new(meta),
            name: Arc::from(name),
            trainer: Mutex::new(None),
            cascade: Mutex::new(None),
        }
    }
}

/// What the `feedback` verb acknowledges: the trainer's state right
/// after this sample was absorbed (and after the publish, if this
/// sample's cadence tick triggered one).
#[derive(Debug, Clone, Copy)]
pub struct FeedbackAck {
    pub ingested: u64,
    pub buffered: usize,
    pub generation: u64,
    pub classes: usize,
    /// Whether THIS call refit + hot-swapped the serving engines.
    pub published: bool,
}

/// A fixed set of named tenants, each served by its own sharded
/// [`Coordinator`] pool. The tenant set is decided at startup; *what*
/// each tenant serves can be hot-swapped via [`ModelRegistry::reload`].
pub struct ModelRegistry {
    tenants: HashMap<String, Tenant>,
    default: String,
}

impl ModelRegistry {
    /// Load every spec'd artifact and start its pool. `default` names the
    /// tenant that serves requests without a `"model"` field (falls back
    /// to the first spec).
    pub fn open(specs: &[TenantSpec], default: Option<&str>, cfg: &BatcherConfig) -> Result<Self> {
        if specs.is_empty() {
            bail!("registry needs at least one model spec");
        }
        let mut tenants = HashMap::new();
        for spec in specs {
            if tenants.contains_key(&spec.name) {
                bail!("duplicate tenant name '{}'", spec.name);
            }
            let replicas = spec.replicas.max(1);
            let cascade = if spec.cascade {
                let threshold = cascade_admission(&spec.path, spec.precision, &spec.name)?;
                Some(CascadeState { threshold, counters: Arc::new(CascadeCounters::new()) })
            } else {
                None
            };
            let (kind, features, factories) = match &cascade {
                Some(cs) => zoo::cascade_engine_factories(
                    &spec.path,
                    spec.precision,
                    replicas,
                    &spec.name,
                    cs.threshold,
                    Arc::clone(&cs.counters),
                )?,
                None => build_factories(&spec.path, spec.precision, replicas, &spec.name)?,
            };
            crate::log_info!(
                "tenant '{}': kind={kind} path={} precision={} replicas={replicas} cascade={}",
                spec.name,
                spec.path.display(),
                spec.precision.label(),
                spec.cascade
            );
            let coordinator = Arc::new(Coordinator::start_pool(features, cfg.clone(), factories));
            let tenant = Tenant::new(
                coordinator,
                TenantMeta {
                    kind,
                    path: Some(spec.path.clone()),
                    precision: spec.precision,
                },
                &spec.name,
            );
            *tenant.cascade.lock().unwrap() = cascade;
            tenants.insert(spec.name.clone(), tenant);
        }
        let default = match default {
            Some(d) => {
                if !tenants.contains_key(d) {
                    bail!("default model '{d}' is not among the configured tenants");
                }
                d.to_string()
            }
            None => specs[0].name.clone(),
        };
        Ok(Self { tenants, default })
    }

    /// Single-tenant registry over pre-built engine factories (the PJRT
    /// serve path and tests use this — no artifact directory involved).
    pub fn single(
        name: &str,
        kind: &str,
        features: usize,
        cfg: &BatcherConfig,
        factories: Vec<EngineFactory>,
    ) -> Self {
        let coordinator = Arc::new(Coordinator::start_pool(features, cfg.clone(), factories));
        Self::single_with(name, kind, coordinator)
    }

    /// Multi-tenant registry over pre-built engine factories — the
    /// conformance tests and benches use this to host several fully
    /// deterministic synthetic tenants with no artifacts on disk. Each
    /// tuple is `(name, kind, features, per-replica factories)`.
    pub fn with_tenants(
        tenants: Vec<(&str, &str, usize, Vec<EngineFactory>)>,
        default: &str,
        cfg: &BatcherConfig,
    ) -> Self {
        assert!(!tenants.is_empty(), "registry needs at least one tenant");
        let mut map = HashMap::new();
        for (name, kind, features, factories) in tenants {
            let coordinator = Arc::new(Coordinator::start_pool(features, cfg.clone(), factories));
            let prev = map.insert(
                name.to_string(),
                Tenant::new(
                    coordinator,
                    TenantMeta { kind: kind.to_string(), path: None, precision: Precision::F32 },
                    name,
                ),
            );
            assert!(prev.is_none(), "duplicate tenant name '{name}'");
        }
        assert!(map.contains_key(default), "default tenant '{default}' is not configured");
        Self { tenants: map, default: default.to_string() }
    }

    /// Wrap an already-running coordinator as the sole tenant.
    pub fn single_with(name: &str, kind: &str, coordinator: Arc<Coordinator>) -> Self {
        let mut tenants = HashMap::new();
        tenants.insert(
            name.to_string(),
            Tenant::new(
                coordinator,
                TenantMeta { kind: kind.to_string(), path: None, precision: Precision::F32 },
                name,
            ),
        );
        Self { tenants, default: name.to_string() }
    }

    fn tenant(&self, model: Option<&str>) -> Result<(&str, &Tenant), RouteError> {
        let name = model.unwrap_or(&self.default);
        match self.tenants.get_key_value(name) {
            Some((k, t)) => Ok((k.as_str(), t)),
            None => Err(RouteError::UnknownModel(name.to_string())),
        }
    }

    /// The tenant serving requests that carry no `"model"` field.
    pub fn default_model(&self) -> &str {
        &self.default
    }

    /// Tenant names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.tenants.keys().cloned().collect();
        names.sort();
        names
    }

    /// Route a request to `model` (or the default tenant) and wait for
    /// the answer. Admission control is per tenant: a full queue on one
    /// tenant rejects with backpressure without affecting the others.
    pub fn submit_blocking(
        &self,
        model: Option<&str>,
        features: Vec<f32>,
    ) -> Result<(String, Response), RouteError> {
        let (name, tenant) = self.tenant(model)?;
        let resp = tenant
            .coordinator
            .submit_blocking(features)
            .map_err(|err| RouteError::Submit { model: name.to_string(), err })?;
        Ok((name.to_string(), resp))
    }

    /// Route a request without blocking: resolve the tenant, then hand
    /// the callback to its batcher. The only synchronous error is
    /// `UnknownModel` (routing happens here); every later outcome —
    /// admission refusal, engine failure, shutdown, or the response —
    /// arrives through the callback as a [`SubmitError`], which the
    /// caller wraps back into [`RouteError::Submit`] with the returned
    /// tenant name to keep wire error strings identical to the blocking
    /// path. Reactor threads use this so they never park on a channel.
    pub fn submit_with(
        &self,
        model: Option<&str>,
        features: Vec<f32>,
        cb: ResponseCallback,
    ) -> Result<String, RouteError> {
        let (name, tenant) = self.tenant(model)?;
        tenant.coordinator.submit_with(features, cb);
        Ok(name.to_string())
    }

    /// The zero-allocation routing form: resolve the tenant, stamp the
    /// ticket's `name` with the tenant's shared `Arc<str>` (no `String`
    /// per request), and enqueue through the shared [`CompletionSink`].
    /// On an unknown model the ticket and features come straight back so
    /// the caller can answer inline and recycle both.
    #[allow(clippy::result_large_err)]
    pub fn submit_ticket(
        &self,
        model: Option<&str>,
        features: Vec<f32>,
        sink: &Arc<dyn CompletionSink>,
        mut ticket: Ticket,
    ) -> Result<(), (RouteError, Ticket, Vec<f32>)> {
        match self.tenant(model) {
            Ok((_, tenant)) => {
                ticket.name = Arc::clone(&tenant.name);
                tenant.coordinator.submit_sink(features, sink, ticket);
                Ok(())
            }
            Err(e) => Err((e, ticket, features)),
        }
    }

    /// Per-tenant stats snapshot.
    pub fn stats(&self, model: Option<&str>) -> Result<(String, StatsSnapshot), RouteError> {
        let (name, tenant) = self.tenant(model)?;
        Ok((name.to_string(), tenant.coordinator.stats()))
    }

    /// The coordinator behind a tenant (benches drive it directly).
    pub fn coordinator(&self, model: Option<&str>) -> Result<Arc<Coordinator>, RouteError> {
        let (_, tenant) = self.tenant(model)?;
        Ok(Arc::clone(&tenant.coordinator))
    }

    /// Describe every tenant (sorted by name).
    pub fn describe(&self) -> Vec<TenantInfo> {
        let mut out: Vec<TenantInfo> =
            self.tenants.iter().map(|(name, t)| self.info(name, t)).collect();
        out.sort_by(|a, b| a.name.cmp(&b.name));
        out
    }

    fn info(&self, name: &str, t: &Tenant) -> TenantInfo {
        let meta = t.meta.lock().unwrap();
        TenantInfo {
            name: name.to_string(),
            kind: meta.kind.clone(),
            path: meta.path.clone(),
            precision: meta.precision.label(),
            replicas: t.coordinator.replicas(),
            live_replicas: t.coordinator.live_replicas(),
            features: t.coordinator.features(),
            is_default: name == self.default,
            stats: t.coordinator.stats(),
            trainer: t.trainer.lock().unwrap().as_ref().map(|tr| tr.stats()),
            cascade: t.cascade.lock().unwrap().as_ref().map(CascadeState::snapshot),
        }
    }

    /// Hot-swap one tenant's artifact without dropping in-flight
    /// requests. `path` defaults to the tenant's current artifact path
    /// (re-read from disk — the retrain-in-place flow); `bits` defaults
    /// to its current precision. The replacement must admit the same
    /// feature width, because queued requests were validated against it.
    pub fn reload(
        &self,
        model: Option<&str>,
        path: Option<&Path>,
        bits: Option<u32>,
    ) -> Result<TenantInfo, RouteError> {
        let (name, tenant) = self.tenant(model)?;
        let fail =
            |message: String| RouteError::Reload { model: name.to_string(), message };
        let (path, precision) = {
            let meta = tenant.meta.lock().unwrap();
            let path = match path {
                Some(p) => p.to_path_buf(),
                None => meta.path.clone().ok_or_else(|| {
                    fail("tenant has no artifact path; pass \"path\"".to_string())
                })?,
            };
            let precision = match bits {
                Some(b) => Precision::from_bits(b)
                    .ok_or_else(|| fail(format!("bits must be 1|2|4|8|32, got {b}")))?,
                None => meta.precision,
            };
            (path, precision)
        };
        // Cheap admission check before touching tensors.
        let card = ModelCard::load(&path).map_err(|e| fail(format!("{e:#}")))?;
        let want = tenant.coordinator.features();
        if card.features != want {
            return Err(fail(format!(
                "artifact feature width {} != serving width {want}",
                card.features
            )));
        }
        let replicas = tenant.coordinator.replicas();
        // Cascade tenants stay cascade tenants across reloads: the
        // incoming artifact must itself be calibrated (its threshold
        // replaces the old one), and the counters Arc carries over so
        // the tier telemetry stays monotone across generations.
        let cascade = tenant
            .cascade
            .lock()
            .unwrap()
            .as_ref()
            .map(|cs| Arc::clone(&cs.counters));
        let (kind, features, factories, new_threshold) = match &cascade {
            Some(counters) => {
                let threshold = cascade_admission(&path, precision, name)
                    .map_err(|e| fail(format!("{e:#}")))?;
                let (kind, features, factories) = zoo::cascade_engine_factories(
                    &path,
                    precision,
                    replicas,
                    name,
                    threshold,
                    Arc::clone(counters),
                )
                .map_err(|e| fail(format!("{e:#}")))?;
                (kind, features, factories, Some(threshold))
            }
            None => {
                let (kind, features, factories) =
                    build_factories(&path, precision, replicas, name)
                        .map_err(|e| fail(format!("{e:#}")))?;
                (kind, features, factories, None)
            }
        };
        if features != want {
            return Err(fail(format!("artifact feature width {features} != serving width {want}")));
        }
        {
            // The meta lock is held ACROSS the coordinator reload so two
            // racing registry reloads of one tenant serialize as a unit:
            // the meta always describes the engines the pool last adopted.
            let mut meta = tenant.meta.lock().unwrap();
            tenant.coordinator.reload(factories).map_err(|e| fail(e.to_string()))?;
            meta.kind = kind;
            meta.path = Some(path);
            meta.precision = precision;
            if let Some(threshold) = new_threshold {
                if let Some(cs) = tenant.cascade.lock().unwrap().as_mut() {
                    cs.threshold = threshold;
                }
            }
        }
        crate::log_info!("tenant '{name}' reloaded ({} replicas notified)", replicas);
        Ok(self.info(name, tenant))
    }

    /// Attach (or replace) a tenant's streaming trainer, enabling the
    /// `feedback` verb for it. The trainer's encoder must admit the
    /// tenant's serving feature width — queued requests were validated
    /// against it, and a published engine must keep accepting them.
    pub fn attach_trainer(
        &self,
        model: Option<&str>,
        trainer: OnlineTrainer,
    ) -> Result<(), RouteError> {
        let (name, tenant) = self.tenant(model)?;
        let want = tenant.coordinator.features();
        let got = trainer.encoder().features();
        if got != want {
            return Err(RouteError::Feedback {
                model: name.to_string(),
                err: FeedbackError::BadWidth { got, want },
            });
        }
        *tenant.trainer.lock().unwrap() = Some(trainer);
        Ok(())
    }

    /// Ingest one labeled feedback sample into a tenant's trainer and,
    /// when the cadence fires, refit + publish the refreshed model
    /// through the coordinator's generation handoff (in-flight and
    /// queued inferences all complete — same zero-drop guarantee as
    /// [`Self::reload`]). Runs synchronously on the caller's thread;
    /// the publish cost is bounded by the reservoir size.
    pub fn feedback(
        &self,
        model: Option<&str>,
        features: &[f32],
        label: i32,
    ) -> Result<(String, FeedbackAck), RouteError> {
        let (name, tenant) = self.tenant(model)?;
        let mut guard = tenant.trainer.lock().unwrap();
        let trainer = guard.as_mut().ok_or_else(|| RouteError::NoTrainer(name.to_string()))?;
        trainer
            .ingest(features, label)
            .map_err(|err| RouteError::Feedback { model: name.to_string(), err })?;
        let mut published = false;
        if trainer.publish_due() {
            trainer.refit();
            let (encoder, model_snap) = trainer.snapshot();
            let precision = tenant.meta.lock().unwrap().precision;
            let replicas = tenant.coordinator.replicas();
            // Cascade tenants publish cascade engines: the operating
            // threshold carries over from the last calibration (the
            // margin normalization is per-model, so the gate stays
            // meaningful across refits; the live `agreed` counter tracks
            // the realized b1/exact agreement until the next
            // `loghd calibrate` + reload tightens it again).
            let cascade = tenant
                .cascade
                .lock()
                .unwrap()
                .as_ref()
                .map(|cs| (cs.threshold, Arc::clone(&cs.counters)));
            let factories: Vec<EngineFactory> = (0..replicas)
                .map(|_| match &cascade {
                    Some((threshold, counters)) => CascadeEngine::factory_with_precision(
                        encoder.clone(),
                        model_snap.clone(),
                        name.to_string(),
                        precision,
                        *threshold,
                        Arc::clone(counters),
                    ),
                    None => NativeEngine::factory_with_precision(
                        encoder.clone(),
                        model_snap.clone(),
                        name.to_string(),
                        precision,
                    ),
                })
                .collect();
            tenant
                .coordinator
                .reload(factories)
                .map_err(|e| RouteError::Reload { model: name.to_string(), message: e.to_string() })?;
            trainer.mark_published();
            published = true;
            crate::log_info!(
                "tenant '{name}' published online generation {} ({} classes)",
                trainer.generation(),
                trainer.classes()
            );
        }
        let s = trainer.stats();
        Ok((
            name.to_string(),
            FeedbackAck {
                ingested: s.ingested,
                buffered: s.buffered,
                generation: s.generation,
                classes: s.classes,
                published,
            },
        ))
    }

    /// Trainer counters for the `stats` verb; `None` for tenants that
    /// serve frozen (no trainer attached).
    pub fn trainer_stats(&self, model: Option<&str>) -> Result<Option<TrainerStats>, RouteError> {
        let (_, tenant) = self.tenant(model)?;
        Ok(tenant.trainer.lock().unwrap().as_ref().map(|t| t.stats()))
    }

    /// Cascade operating point + tier counters for the `stats` verb;
    /// `None` for tenants that serve their precision directly.
    pub fn cascade_stats(
        &self,
        model: Option<&str>,
    ) -> Result<Option<CascadeSnapshot>, RouteError> {
        let (_, tenant) = self.tenant(model)?;
        Ok(tenant.cascade.lock().unwrap().as_ref().map(CascadeState::snapshot))
    }
}

/// Admission gate for `--cascade` tenants, applied at [`ModelRegistry::open`]
/// and again on every [`ModelRegistry::reload`]: the artifact must carry a
/// calibrated `cascade_threshold` in its model card, and the exact tier
/// must be wider than the b1 prefilter (a b1 exact tier would make
/// escalation a no-op).
fn cascade_admission(path: &Path, precision: Precision, name: &str) -> Result<f32> {
    if precision == Precision::B1 {
        bail!(
            "tenant '{name}': --cascade needs an exact tier wider than the b1 \
             prefilter; serve it at bits 2|4|8|32"
        );
    }
    let card = ModelCard::load(path)
        .with_context(|| format!("tenant '{name}': cascade admission"))?;
    match card.cascade_threshold {
        Some(t) if t.is_finite() && t >= 0.0 => Ok(t as f32),
        Some(t) => bail!(
            "tenant '{name}': artifact {} carries an invalid cascade_threshold {t}",
            path.display()
        ),
        None => bail!(
            "tenant '{name}': artifact {} has no calibrated cascade threshold; \
             run `loghd calibrate --model {}` first",
            path.display(),
            path.display()
        ),
    }
}

/// Load an artifact and build one engine factory per replica — a thin
/// alias for [`zoo::engine_factories`], the single engine-dispatch
/// point of the model zoo. Any family registered there (including the
/// DecoHD baseline) is servable here with no registry changes.
fn build_factories(
    path: &Path,
    precision: Precision,
    replicas: usize,
    label: &str,
) -> Result<(String, usize, Vec<EngineFactory>)> {
    zoo::engine_factories(path, precision, replicas, label)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::conventional::ConventionalModel;
    use crate::coordinator::Engine;
    use crate::data;
    use crate::loghd::model::{TrainOptions, TrainedStack};
    use crate::tensor::Matrix;

    #[test]
    fn tenant_spec_parse_forms() {
        let s = TenantSpec::parse("page=models/page:8", 32, 2).unwrap();
        assert_eq!(s.name, "page");
        assert_eq!(s.path, PathBuf::from("models/page"));
        assert_eq!(s.precision, Precision::B8);
        assert_eq!(s.replicas, 2);
        let s = TenantSpec::parse("page=models/page", 32, 1).unwrap();
        assert_eq!(s.precision, Precision::F32);
        let s = TenantSpec::parse("models/page", 1, 1).unwrap();
        assert_eq!(s.name, "page");
        assert_eq!(s.precision, Precision::B1);
        // bare path WITH bits: the name comes from the stripped path
        let s = TenantSpec::parse("models/page:8", 32, 1).unwrap();
        assert_eq!(s.name, "page");
        assert_eq!(s.path, PathBuf::from("models/page"));
        assert_eq!(s.precision, Precision::B8);
        // a ':<n>' suffix that is NOT a valid precision is part of the
        // path, so directories containing colons stay servable
        let s = TenantSpec::parse("snap=/data/nightly:2024", 32, 1).unwrap();
        assert_eq!(s.path, PathBuf::from("/data/nightly:2024"));
        assert_eq!(s.precision, Precision::F32);
        assert!(TenantSpec::parse("=x", 32, 1).is_err());
        assert!(TenantSpec::parse("page=models/page", 7, 1).is_err(), "bad default bits");
    }

    struct Echo;

    impl Engine for Echo {
        fn name(&self) -> String {
            "echo".into()
        }
        fn features(&self) -> usize {
            2
        }
        fn infer(&mut self, x: &Matrix) -> anyhow::Result<Vec<i32>> {
            Ok((0..x.rows()).map(|i| x.at(i, 0) as i32).collect())
        }
    }

    #[test]
    fn single_registry_routes_and_maps_error_codes() {
        let registry = ModelRegistry::single(
            "echo",
            "demo",
            2,
            &BatcherConfig::default(),
            vec![Box::new(|| Ok(Box::new(Echo) as Box<dyn Engine>))],
        );
        assert_eq!(registry.default_model(), "echo");
        assert_eq!(registry.names(), vec!["echo".to_string()]);
        let (model, resp) = registry.submit_blocking(None, vec![5.0, 0.0]).unwrap();
        assert_eq!((model.as_str(), resp.label), ("echo", 5));
        let err = registry.submit_blocking(Some("nope"), vec![1.0, 0.0]).unwrap_err();
        assert_eq!(err.code(), "unknown_model");
        let err = registry.submit_blocking(Some("echo"), vec![1.0]).unwrap_err();
        assert_eq!(err.code(), "bad_width");
        let err = registry.reload(Some("echo"), None, None).unwrap_err();
        assert_eq!(err.code(), "reload_failed");
        let infos = registry.describe();
        assert_eq!(infos.len(), 1);
        assert!(infos[0].is_default);
        assert_eq!(infos[0].stats.responses, 1);
    }

    #[test]
    fn with_tenants_routes_callbacks_by_name() {
        let registry = ModelRegistry::with_tenants(
            vec![
                ("echo", "demo", 2, vec![Box::new(|| Ok(Box::new(Echo) as Box<dyn Engine>))]),
                ("echo2", "demo", 2, vec![Box::new(|| Ok(Box::new(Echo) as Box<dyn Engine>))]),
            ],
            "echo",
            &BatcherConfig::default(),
        );
        assert_eq!(registry.default_model(), "echo");
        assert_eq!(registry.names(), vec!["echo".to_string(), "echo2".to_string()]);
        let (tx, rx) = std::sync::mpsc::channel();
        let name = registry
            .submit_with(Some("echo2"), vec![7.0, 0.0], Box::new(move |r| tx.send(r).unwrap()))
            .unwrap();
        assert_eq!(name, "echo2");
        assert_eq!(rx.recv().unwrap().unwrap().label, 7);
        // Routing failures are synchronous; admission failures arrive
        // through the callback with the same code mapping as blocking.
        let err = registry
            .submit_with(Some("nope"), vec![0.0, 0.0], Box::new(|_| {}))
            .unwrap_err();
        assert_eq!(err.code(), "unknown_model");
        let (tx, rx) = std::sync::mpsc::channel();
        let name = registry
            .submit_with(None, vec![1.0], Box::new(move |r| tx.send(r).unwrap()))
            .unwrap();
        let err = RouteError::Submit { model: name, err: rx.recv().unwrap().unwrap_err() };
        assert_eq!(err.code(), "bad_width");
        assert_eq!(err.to_string(), "model 'echo': feature width 1 != expected 2");
    }

    #[test]
    fn feedback_routes_ingests_and_publishes() {
        let ds = data::generate_scaled(data::spec("page").unwrap(), 400, 50);
        let opts =
            TrainOptions { epochs: 1, conv_epochs: 0, extra_bundles: 1, ..Default::default() };
        let st = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 128, 1, &opts).unwrap();
        let factory =
            NativeEngine::factory(st.encoder.clone(), st.loghd.clone(), "page".into());
        let registry =
            ModelRegistry::single("page", "loghd", 10, &BatcherConfig::default(), vec![factory]);
        // No trainer attached: the verb refuses with its own code.
        let err = registry.feedback(None, ds.x_train.row(0), 0).unwrap_err();
        assert_eq!(err.code(), "no_trainer");
        assert!(registry.trainer_stats(None).unwrap().is_none());
        // A width-mismatched trainer is refused at attach time.
        let narrow = OnlineTrainer::new(
            crate::encoder::Encoder::new(3, 64, 1),
            st.loghd.clone(),
            crate::loghd::online::OnlineConfig::default(),
        );
        assert_eq!(registry.attach_trainer(None, narrow).unwrap_err().code(), "bad_width");
        let cfg = crate::loghd::online::OnlineConfig {
            publish_every: 8,
            min_samples: 8,
            ..Default::default()
        };
        let trainer = OnlineTrainer::new(st.encoder.clone(), st.loghd.clone(), cfg);
        registry.attach_trainer(None, trainer).unwrap();
        // Coded rejections, counted but not fatal.
        assert_eq!(registry.feedback(None, &[0.0; 3], 0).unwrap_err().code(), "bad_width");
        assert_eq!(registry.feedback(None, ds.x_train.row(0), -2).unwrap_err().code(), "bad_label");
        let mut published = 0;
        for i in 0..16 {
            let (m, ack) = registry.feedback(None, ds.x_train.row(i), ds.y_train[i]).unwrap();
            assert_eq!(m, "page");
            assert_eq!(ack.ingested, i as u64 + 1);
            if ack.published {
                published += 1;
                assert_eq!(ack.generation, published as u64);
            }
        }
        assert_eq!(published, 2, "publish cadence is every 8 accepted ingests");
        let s = registry.trainer_stats(None).unwrap().unwrap();
        assert_eq!((s.ingested, s.rejected, s.generation), (16, 2, 2));
        // Serving still answers after two live publishes.
        let (_, resp) = registry.submit_blocking(None, ds.x_test.row(0).to_vec()).unwrap();
        assert!((0..5).contains(&resp.label));
        let err = registry.feedback(Some("nope"), ds.x_train.row(0), 0).unwrap_err();
        assert_eq!(err.code(), "unknown_model");
    }

    #[test]
    fn open_serves_mixed_tenants_and_hot_swaps() {
        let root = std::env::temp_dir().join("loghd_registry_test");
        let _ = std::fs::remove_dir_all(&root);
        let ds = data::generate_scaled(data::spec("page").unwrap(), 300, 40);
        let opts =
            TrainOptions { epochs: 1, conv_epochs: 1, extra_bundles: 1, ..Default::default() };
        let st = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 128, 1, &opts).unwrap();
        crate::loghd::persist::save(&root.join("log"), &st.encoder, &st.loghd).unwrap();
        crate::loghd::persist::save_conventional(
            &root.join("conv"),
            &st.encoder,
            &ConventionalModel::new(st.prototypes.clone()),
        )
        .unwrap();
        let deco =
            crate::baselines::DecoHdModel::from_prototypes(&st.prototypes, 3).unwrap();
        crate::loghd::persist::save_decohd(&root.join("deco"), &st.encoder, &deco).unwrap();
        let specs = vec![
            TenantSpec {
                name: "log".into(),
                path: root.join("log"),
                precision: Precision::B1,
                replicas: 2,
                cascade: false,
            },
            TenantSpec {
                name: "conv".into(),
                path: root.join("conv"),
                precision: Precision::F32,
                replicas: 1,
                cascade: false,
            },
            TenantSpec {
                name: "deco".into(),
                path: root.join("deco"),
                precision: Precision::B8,
                replicas: 1,
                cascade: false,
            },
        ];
        let registry =
            ModelRegistry::open(&specs, Some("log"), &BatcherConfig::default()).unwrap();
        for i in 0..6 {
            let (m, resp) = registry.submit_blocking(None, ds.x_test.row(i).to_vec()).unwrap();
            assert_eq!(m, "log");
            assert!((0..5).contains(&resp.label));
        }
        let (m, resp) =
            registry.submit_blocking(Some("conv"), ds.x_test.row(0).to_vec()).unwrap();
        assert_eq!(m, "conv");
        assert!((0..5).contains(&resp.label));
        // The zoo-registered DecoHD tenant serves through the same wire
        // path as the hand-wired engines.
        let (m, resp) =
            registry.submit_blocking(Some("deco"), ds.x_test.row(0).to_vec()).unwrap();
        assert_eq!(m, "deco");
        assert!((0..5).contains(&resp.label));
        let infos = registry.describe();
        assert_eq!(infos.len(), 3);
        let log = infos.iter().find(|i| i.name == "log").unwrap();
        assert_eq!((log.kind.as_str(), log.precision, log.replicas), ("loghd", "b1", 2));
        let deco_info = infos.iter().find(|i| i.name == "deco").unwrap();
        assert_eq!((deco_info.kind.as_str(), deco_info.precision), ("decohd", "b8"));
        // Hot-swap the loghd tenant to int8; old and new widths match.
        let info = registry.reload(Some("log"), None, Some(8)).unwrap();
        assert_eq!(info.precision, "b8");
        let (_, resp) =
            registry.submit_blocking(Some("log"), ds.x_test.row(0).to_vec()).unwrap();
        assert!((0..5).contains(&resp.label));
        // Unknown tenant and bad default are rejected.
        assert!(registry.reload(Some("nope"), None, None).is_err());
        assert!(ModelRegistry::open(&specs, Some("nope"), &BatcherConfig::default()).is_err());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn cascade_tenants_gate_admission_and_report_tier_stats() {
        let root = std::env::temp_dir().join("loghd_registry_cascade_test");
        let _ = std::fs::remove_dir_all(&root);
        let ds = data::generate_scaled(data::spec("page").unwrap(), 400, 60);
        let opts =
            TrainOptions { epochs: 2, conv_epochs: 1, extra_bundles: 2, ..Default::default() };
        let st = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 256, 1, &opts).unwrap();
        crate::loghd::persist::save(&root.join("log"), &st.encoder, &st.loghd).unwrap();
        let mut spec = TenantSpec {
            name: "log".into(),
            path: root.join("log"),
            precision: Precision::B8,
            replicas: 1,
            cascade: true,
        };
        let cfg = BatcherConfig::default();
        // An uncalibrated artifact is refused, and the error names the fix.
        let err = ModelRegistry::open(std::slice::from_ref(&spec), None, &cfg).unwrap_err();
        assert!(err.to_string().contains("loghd calibrate"), "{err:#}");
        // Calibrate + persist the threshold; admission then passes...
        let cal =
            crate::loghd::cascade::calibrate(&st.encoder, &st.loghd, &ds.x_train, 0.99, 7)
                .unwrap();
        crate::loghd::cascade::write_threshold(&root.join("log"), &cal).unwrap();
        // ...except at a b1 exact tier, which would make escalation a no-op.
        spec.precision = Precision::B1;
        let err = ModelRegistry::open(std::slice::from_ref(&spec), None, &cfg).unwrap_err();
        assert!(err.to_string().contains("wider than the b1"), "{err:#}");
        spec.precision = Precision::B8;
        let registry = ModelRegistry::open(std::slice::from_ref(&spec), None, &cfg).unwrap();
        for i in 0..8 {
            let (_, resp) = registry.submit_blocking(None, ds.x_test.row(i).to_vec()).unwrap();
            assert!((0..5).contains(&resp.label));
        }
        let snap = registry.cascade_stats(None).unwrap().unwrap();
        assert_eq!(snap.threshold, cal.threshold);
        assert_eq!(snap.tier1 + snap.escalated, 8, "every row lands in exactly one tier");
        assert!(snap.agreed <= snap.escalated);
        let info = &registry.describe()[0];
        assert!(info.cascade.is_some(), "describe() carries the cascade snapshot");
        // Hot reload keeps the cascade: the threshold is re-admitted from
        // the (still calibrated) card and the counters carry over.
        let info = registry.reload(None, None, Some(32)).unwrap();
        assert_eq!(info.precision, "f32");
        assert_eq!(info.cascade.unwrap().threshold, cal.threshold);
        let snap = registry.cascade_stats(None).unwrap().unwrap();
        assert_eq!(snap.tier1 + snap.escalated, 8, "tier counters survive reload");
        // The conventional family has no b1 twin to cascade from: even a
        // card with a threshold is refused at factory construction.
        crate::loghd::persist::save_conventional(
            &root.join("conv"),
            &st.encoder,
            &ConventionalModel::new(st.prototypes.clone()),
        )
        .unwrap();
        crate::loghd::cascade::write_threshold(&root.join("conv"), &cal).unwrap();
        let conv = TenantSpec {
            name: "conv".into(),
            path: root.join("conv"),
            precision: Precision::F32,
            replicas: 1,
            cascade: true,
        };
        let err = ModelRegistry::open(&[conv], None, &cfg).unwrap_err();
        assert!(err.to_string().contains("loghd family"), "{err:#}");
        // Plain tenants keep reporting no cascade stats at all.
        let plain = TenantSpec { cascade: false, ..spec };
        let registry = ModelRegistry::open(&[plain], None, &cfg).unwrap();
        assert!(registry.cascade_stats(None).unwrap().is_none());
        assert!(registry.describe()[0].cascade.is_none());
        let _ = std::fs::remove_dir_all(&root);
    }
}

//! Readiness-driven event-loop front door (epoll, with a portable
//! `poll(2)` fallback).
//!
//! A small fixed pool of reactor threads multiplexes every connection:
//! reactor 0 owns the listener (accepted sockets are handed out
//! round-robin), and each reactor runs a level-triggered readiness loop
//! over its connections' [`super::conn::Conn`] state machines. Design
//! points the tests pin:
//!
//! - **No busy-wait.** The loop blocks with an infinite timeout; an
//!   idle server takes zero wakeups (`ServerStats::wakeups` is the
//!   proof). Cross-thread work (accepted sockets, batcher completions)
//!   arrives through a per-reactor waker.
//! - **Non-blocking inference.** Requests are routed with
//!   [`ModelRegistry::submit_ticket`] through one shared per-reactor
//!   [`CompletionSink`]: the worker thread encodes the reply into the
//!   ticket's pooled buffer, mails the ticket (plus the request's
//!   feature vector, for recycling) back to the owning reactor's
//!   completion queue, and wakes it. Reactor threads never park on a
//!   channel, and the steady state allocates nothing per request.
//! - **Write-interest-driven backpressure.** A connection whose write
//!   buffer passes the high-water mark stops being read (and parsed)
//!   until the peer drains it; `EPOLLOUT` interest exists only while
//!   reply bytes are queued.
//! - **Graceful drain.** Shutdown closes the listener, stops reading,
//!   then keeps the loop alive until every admitted request has been
//!   answered and flushed (bounded by `drain_deadline`) — connections
//!   are never abandoned mid-reply, and every reactor thread is joined.
//!
//! The poller is raw `epoll(7)` on Linux and `poll(2)` elsewhere on
//! unix — hand-rolled FFI against the libc the process already links,
//! because this crate vendors every dependency. The waker is a
//! loopback socket pair built from `std` only.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use super::batcher::{CompletionSink, Response, SubmitError, Ticket};
use super::conn::{self, Conn, SubmitReq};
use super::registry::{ModelRegistry, RouteError};
use super::server::{ServerConfig, ServerStats};

/// A completed reply travelling back to a reactor: the ticket (now
/// carrying the encoded reply bytes in its pooled buffer) plus the
/// request's feature vector, returned for recycling.
type CompletionMsg = (Ticket, Vec<f32>);

const TOKEN_WAKER: u64 = 0;
const TOKEN_LISTENER: u64 = 1;
const TOKEN_BASE: u64 = 2;

/// One readiness event, normalized across the epoll and poll backends.
struct Event {
    token: u64,
    readable: bool,
    writable: bool,
}

#[cfg(target_os = "linux")]
mod sys {
    //! Raw `epoll(7)` bindings — no libc crate, just the symbols the
    //! process already links.
    use super::Event;
    use std::io;
    use std::os::fd::{AsRawFd, FromRawFd, OwnedFd, RawFd};

    // On x86 the kernel ABI packs epoll_event to 12 bytes.
    #[repr(C)]
    #[cfg_attr(any(target_arch = "x86", target_arch = "x86_64"), repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
    }

    const EPOLL_CLOEXEC: i32 = 0x80000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;

    const MAX_EVENTS: usize = 256;

    pub struct Poller {
        ep: OwnedFd,
    }

    impl Poller {
        pub fn new() -> io::Result<Self> {
            let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if fd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Self { ep: unsafe { OwnedFd::from_raw_fd(fd) } })
        }

        fn ctl(&self, op: i32, fd: RawFd, token: u64, read: bool, write: bool) -> io::Result<()> {
            let mut ev = EpollEvent {
                events: if read { EPOLLIN } else { 0 } | if write { EPOLLOUT } else { 0 },
                data: token,
            };
            let rc = unsafe { epoll_ctl(self.ep.as_raw_fd(), op, fd, &mut ev) };
            if rc < 0 {
                Err(io::Error::last_os_error())
            } else {
                Ok(())
            }
        }

        pub fn register(
            &mut self,
            fd: RawFd,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, read, write)
        }

        pub fn reregister(
            &mut self,
            fd: RawFd,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, read, write)
        }

        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, false, false)
        }

        /// Block until readiness (timeout in ms; -1 = forever). A signal
        /// interruption reports as an empty event set.
        pub fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
            out.clear();
            let mut buf = [EpollEvent { events: 0, data: 0 }; MAX_EVENTS];
            let n = unsafe {
                epoll_wait(self.ep.as_raw_fd(), buf.as_mut_ptr(), MAX_EVENTS as i32, timeout_ms)
            };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(err);
            }
            for ev in buf.iter().take(n as usize) {
                let bits = ev.events;
                out.push(Event {
                    token: ev.data,
                    readable: bits & (EPOLLIN | EPOLLERR | EPOLLHUP) != 0,
                    writable: bits & (EPOLLOUT | EPOLLERR | EPOLLHUP) != 0,
                });
            }
            Ok(())
        }
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
mod sys {
    //! Portable `poll(2)` fallback for the non-Linux unixes. The fd set
    //! is rebuilt per wait — fine at this backend's scale, and it keeps
    //! the registration model identical to the epoll arm.
    use super::Event;
    use std::collections::HashMap;
    use std::io;
    use std::os::fd::RawFd;

    #[repr(C)]
    struct PollFd {
        fd: i32,
        events: i16,
        revents: i16,
    }

    extern "C" {
        // nfds_t is `unsigned int` on the BSDs and macOS this arm serves.
        fn poll(fds: *mut PollFd, nfds: u32, timeout: i32) -> i32;
    }

    const POLLIN: i16 = 0x001;
    const POLLOUT: i16 = 0x004;
    const POLLERR: i16 = 0x008;
    const POLLHUP: i16 = 0x010;
    const POLLNVAL: i16 = 0x020;

    pub struct Poller {
        regs: HashMap<RawFd, (u64, bool, bool)>,
    }

    impl Poller {
        pub fn new() -> io::Result<Self> {
            Ok(Self { regs: HashMap::new() })
        }

        pub fn register(
            &mut self,
            fd: RawFd,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            self.regs.insert(fd, (token, read, write));
            Ok(())
        }

        pub fn reregister(
            &mut self,
            fd: RawFd,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            self.register(fd, token, read, write)
        }

        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            self.regs.remove(&fd);
            Ok(())
        }

        pub fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
            out.clear();
            let mut fds: Vec<PollFd> = Vec::with_capacity(self.regs.len());
            let mut tokens: Vec<u64> = Vec::with_capacity(self.regs.len());
            for (&fd, &(token, read, write)) in &self.regs {
                let events = if read { POLLIN } else { 0 } | if write { POLLOUT } else { 0 };
                fds.push(PollFd { fd, events, revents: 0 });
                tokens.push(token);
            }
            let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as u32, timeout_ms) };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(err);
            }
            for (pfd, &token) in fds.iter().zip(&tokens) {
                let bits = pfd.revents;
                if bits == 0 {
                    continue;
                }
                out.push(Event {
                    token,
                    readable: bits & (POLLIN | POLLERR | POLLHUP | POLLNVAL) != 0,
                    writable: bits & (POLLOUT | POLLERR | POLLHUP | POLLNVAL) != 0,
                });
            }
            Ok(())
        }
    }
}

use sys::Poller;

/// Cross-thread mailbox for one reactor: sockets to adopt, completed
/// replies to deliver, and the waker that breaks its poll sleep.
struct Handle {
    incoming: Mutex<Vec<TcpStream>>,
    completions: Mutex<Vec<CompletionMsg>>,
    /// Write end of the reactor's loopback waker pair.
    wake: TcpStream,
}

impl Handle {
    fn wake(&self) {
        // One byte is a level trigger, not a count: a short or failed
        // write (WouldBlock = a wake byte is already pending) is fine.
        #[allow(clippy::unused_io_amount)]
        let _ = (&self.wake).write(&[1u8]);
    }
}

struct Shared {
    stop: AtomicBool,
    wakeups: AtomicU64,
    accepted: AtomicU64,
    open: AtomicU64,
    handles: Vec<Handle>,
}

/// The reactor-side completion sink, shared by every request a reactor
/// dispatches. The worker thread encodes the reply into the ticket's
/// pooled buffer (off the reactor), then mails the ticket and the
/// request's feature vector back for recycling and wakes the reactor.
struct ReactorSink {
    shared: Arc<Shared>,
    idx: usize,
}

impl CompletionSink for ReactorSink {
    fn complete(
        &self,
        mut ticket: Ticket,
        outcome: Result<Response, SubmitError>,
        features: Vec<f32>,
    ) {
        match outcome {
            Ok(resp) => conn::encode_infer_reply_into(
                ticket.protocol,
                &ticket.name,
                &resp,
                &mut ticket.buf,
            ),
            Err(err) => {
                let e = RouteError::Submit { model: ticket.name.to_string(), err };
                conn::encode_error_into(ticket.protocol, &e.to_string(), e.code(), &mut ticket.buf);
            }
        }
        let handle = &self.shared.handles[self.idx];
        handle.completions.lock().unwrap().push((ticket, features));
        handle.wake();
    }
}

/// The running event-loop server (behind the [`super::Server`] facade).
pub struct EventLoop {
    pub addr: SocketAddr,
    shared: Arc<Shared>,
    threads: Vec<JoinHandle<()>>,
}

impl EventLoop {
    pub fn start(addr: &str, registry: Arc<ModelRegistry>, cfg: ServerConfig) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let reactors = cfg.reactors.max(1);
        let mut handles = Vec::with_capacity(reactors);
        let mut waker_rxs = Vec::with_capacity(reactors);
        for _ in 0..reactors {
            let (tx, rx) = waker_pair().context("creating reactor waker")?;
            handles.push(Handle {
                incoming: Mutex::new(Vec::new()),
                completions: Mutex::new(Vec::new()),
                wake: tx,
            });
            waker_rxs.push(rx);
        }
        let shared = Arc::new(Shared {
            stop: AtomicBool::new(false),
            wakeups: AtomicU64::new(0),
            accepted: AtomicU64::new(0),
            open: AtomicU64::new(0),
            handles,
        });
        let mut threads = Vec::with_capacity(reactors);
        let mut listener = Some(listener);
        for idx in 0..reactors {
            let sink: Arc<dyn CompletionSink> =
                Arc::new(ReactorSink { shared: Arc::clone(&shared), idx });
            let mut reactor = Reactor {
                idx,
                reactors,
                cfg: cfg.clone(),
                registry: Arc::clone(&registry),
                shared: Arc::clone(&shared),
                poller: Poller::new().context("creating poller")?,
                waker_rx: waker_rxs.remove(0),
                listener: if idx == 0 { listener.take() } else { None },
                conns: HashMap::new(),
                next_token: TOKEN_BASE,
                rr: 0,
                stop_reading: false,
                sink,
                empty_name: Arc::from(""),
                submit_scratch: Vec::new(),
                completion_scratch: Vec::new(),
                incoming_scratch: Vec::new(),
            };
            reactor
                .poller
                .register(reactor.waker_rx.as_raw_fd(), TOKEN_WAKER, true, false)
                .context("registering waker")?;
            if let Some(l) = &reactor.listener {
                reactor
                    .poller
                    .register(l.as_raw_fd(), TOKEN_LISTENER, true, false)
                    .context("registering listener")?;
            }
            threads.push(
                std::thread::Builder::new()
                    .name(format!("loghd-reactor-{idx}"))
                    .spawn(move || reactor.run())
                    .context("spawning reactor")?,
            );
        }
        Ok(Self { addr: local, shared, threads })
    }

    pub fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::Release);
        for h in &self.shared.handles {
            h.wake();
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }

    pub fn stats(&self) -> ServerStats {
        ServerStats {
            wakeups: self.shared.wakeups.load(Ordering::Relaxed),
            accepted: self.shared.accepted.load(Ordering::Relaxed),
            open: self.shared.open.load(Ordering::Relaxed),
        }
    }
}

impl Drop for EventLoop {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// A loopback socket pair standing in for `pipe(2)` — pure std, no
/// per-OS flag constants. Returns (write end, read end), both
/// non-blocking.
fn waker_pair() -> io::Result<(TcpStream, TcpStream)> {
    let l = TcpListener::bind(("127.0.0.1", 0))?;
    let tx = TcpStream::connect(l.local_addr()?)?;
    // Guard against an unrelated connection racing onto the ephemeral
    // port: accept until we see our own peer address.
    let want = tx.local_addr()?;
    let rx = loop {
        let (s, peer) = l.accept()?;
        if peer == want {
            break s;
        }
    };
    tx.set_nonblocking(true)?;
    tx.set_nodelay(true)?;
    rx.set_nonblocking(true)?;
    Ok((tx, rx))
}

struct ConnEntry {
    stream: TcpStream,
    conn: Conn,
    /// Currently registered (read, write) interest.
    interest: (bool, bool),
}

struct Reactor {
    idx: usize,
    reactors: usize,
    cfg: ServerConfig,
    registry: Arc<ModelRegistry>,
    shared: Arc<Shared>,
    poller: Poller,
    waker_rx: TcpStream,
    listener: Option<TcpListener>,
    conns: HashMap<u64, ConnEntry>,
    next_token: u64,
    /// Round-robin cursor for handing accepted sockets to reactors.
    rr: usize,
    /// Set during drain: no new bytes are read or parsed.
    stop_reading: bool,
    /// The one [`CompletionSink`] every request this reactor dispatches
    /// completes through (no per-request callback box).
    sink: Arc<dyn CompletionSink>,
    /// Placeholder ticket name until the registry stamps the tenant's
    /// shared `Arc<str>` at routing time.
    empty_name: Arc<str>,
    /// Reused across readiness events so parsing allocates nothing in
    /// the steady state.
    submit_scratch: Vec<SubmitReq>,
    /// Swapped against the completion mailbox each drain, so the
    /// mailbox itself also settles at its high-water capacity.
    completion_scratch: Vec<CompletionMsg>,
    incoming_scratch: Vec<TcpStream>,
}

impl Reactor {
    fn run(&mut self) {
        let mut events: Vec<Event> = Vec::new();
        let mut drain_deadline: Option<Instant> = None;
        loop {
            let timeout_ms = if drain_deadline.is_some() { 20 } else { -1 };
            if let Err(e) = self.poller.wait(&mut events, timeout_ms) {
                crate::log_error!("reactor {}: poll failed: {e}", self.idx);
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            self.shared.wakeups.fetch_add(1, Ordering::Relaxed);
            let batch = std::mem::take(&mut events);
            for ev in &batch {
                match ev.token {
                    TOKEN_WAKER => self.drain_waker(),
                    TOKEN_LISTENER => self.accept_ready(),
                    token => {
                        if ev.readable {
                            self.handle_readable(token);
                        }
                        if ev.writable {
                            self.service(token);
                        }
                    }
                }
            }
            events = batch;
            self.drain_queues();
            if drain_deadline.is_none() && self.shared.stop.load(Ordering::Acquire) {
                drain_deadline = Some(Instant::now() + self.cfg.drain_deadline);
                self.begin_drain();
            }
            if let Some(deadline) = drain_deadline {
                self.reap_quiesced();
                if self.conns.is_empty() || Instant::now() >= deadline {
                    break;
                }
            }
        }
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for t in tokens {
            self.close(t);
        }
    }

    fn drain_waker(&mut self) {
        let mut buf = [0u8; 256];
        loop {
            match self.waker_rx.read(&mut buf) {
                Ok(0) => break,
                Ok(_) => continue,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    /// Adopt cross-thread work: completed replies, then handed-off
    /// sockets. Both mailboxes are *swapped* against reactor-owned
    /// scratch vectors, so neither side reallocates once warmed up.
    fn drain_queues(&mut self) {
        let mut completions = std::mem::take(&mut self.completion_scratch);
        std::mem::swap(
            &mut *self.shared.handles[self.idx].completions.lock().unwrap(),
            &mut completions,
        );
        for (ticket, features) in completions.drain(..) {
            if let Some(entry) = self.conns.get_mut(&ticket.token) {
                entry.conn.recycle_feat(features);
                let token = ticket.token;
                entry.conn.complete(&self.registry, ticket.seq, ticket.buf);
                self.service(token);
            }
        }
        self.completion_scratch = completions;
        let mut incoming = std::mem::take(&mut self.incoming_scratch);
        std::mem::swap(
            &mut *self.shared.handles[self.idx].incoming.lock().unwrap(),
            &mut incoming,
        );
        for stream in incoming.drain(..) {
            self.adopt(stream);
        }
        self.incoming_scratch = incoming;
    }

    fn accept_ready(&mut self) {
        loop {
            let Some(listener) = &self.listener else { return };
            match listener.accept() {
                Ok((stream, _)) => {
                    self.shared.accepted.fetch_add(1, Ordering::Relaxed);
                    self.shared.open.fetch_add(1, Ordering::Relaxed);
                    let target = self.rr;
                    self.rr = (self.rr + 1) % self.reactors;
                    if target == self.idx {
                        self.adopt(stream);
                    } else {
                        self.shared.handles[target].incoming.lock().unwrap().push(stream);
                        self.shared.handles[target].wake();
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    crate::log_error!("accept failed: {e}");
                    break;
                }
            }
        }
    }

    fn adopt(&mut self, stream: TcpStream) {
        if stream.set_nonblocking(true).is_err() {
            self.shared.open.fetch_sub(1, Ordering::Relaxed);
            return;
        }
        let _ = stream.set_nodelay(true);
        let token = self.next_token;
        self.next_token += 1;
        let read = !self.stop_reading;
        if self.poller.register(stream.as_raw_fd(), token, read, false).is_err() {
            self.shared.open.fetch_sub(1, Ordering::Relaxed);
            return;
        }
        self.conns.insert(
            token,
            ConnEntry { stream, conn: Conn::new(self.cfg.max_frame), interest: (read, false) },
        );
    }

    fn close(&mut self, token: u64) {
        if let Some(entry) = self.conns.remove(&token) {
            let _ = self.poller.deregister(entry.stream.as_raw_fd());
            self.shared.open.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Read everything available (until WouldBlock, EOF, or write
    /// backpressure), parsing as we go, then dispatch and flush.
    fn handle_readable(&mut self, token: u64) {
        let mut submits = std::mem::take(&mut self.submit_scratch);
        let mut dead = false;
        {
            let Some(entry) = self.conns.get_mut(&token) else {
                self.submit_scratch = submits;
                return;
            };
            if !self.stop_reading && !entry.conn.at_eof() && !entry.conn.is_closing() {
                let mut chunk = [0u8; 16 * 1024];
                loop {
                    if entry.conn.wbuf_len() >= self.cfg.write_hwm {
                        break;
                    }
                    match entry.stream.read(&mut chunk) {
                        Ok(0) => {
                            entry.conn.on_eof(&self.registry, &mut submits);
                            break;
                        }
                        Ok(n) => {
                            entry.conn.ingest(&chunk[..n]);
                            entry.conn.process(&self.registry, self.cfg.write_hwm, &mut submits);
                            if entry.conn.is_closing() {
                                break;
                            }
                        }
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                        Err(_) => {
                            dead = true;
                            break;
                        }
                    }
                }
            }
        }
        if dead {
            self.submit_scratch = submits;
            self.close(token);
            return;
        }
        self.dispatch(token, &mut submits);
        self.submit_scratch = submits;
        self.service(token);
    }

    /// Route parsed inference requests through the registry's ticket
    /// path: each request carries a pooled reply buffer out and back,
    /// the shared [`ReactorSink`] encodes the reply OFF the reactor
    /// thread, and the only synchronous failure is an unknown tenant
    /// (answered inline, vectors recycled). Drains `submits`.
    fn dispatch(&mut self, token: u64, submits: &mut Vec<SubmitReq>) {
        for s in submits.drain(..) {
            let Some(entry) = self.conns.get_mut(&token) else { return };
            let ticket = Ticket {
                token,
                seq: s.seq,
                protocol: entry.conn.protocol(),
                name: Arc::clone(&self.empty_name),
                buf: entry.conn.take_buf(),
            };
            if let Err((e, mut ticket, features)) =
                self.registry.submit_ticket(s.model.as_deref(), s.features, &self.sink, ticket)
            {
                if let Some(entry) = self.conns.get_mut(&token) {
                    entry.conn.recycle_feat(features);
                    conn::encode_error_into(
                        ticket.protocol,
                        &e.to_string(),
                        e.code(),
                        &mut ticket.buf,
                    );
                    entry.conn.complete(&self.registry, ticket.seq, ticket.buf);
                }
            }
        }
    }

    /// Flush queued reply bytes; when backpressure clears, resume
    /// parsing buffered input; close the connection once it is done;
    /// finally reconcile poller interest with the new state.
    fn service(&mut self, token: u64) {
        loop {
            let mut dead = false;
            let mut progressed = false;
            let mut submits = std::mem::take(&mut self.submit_scratch);
            {
                let Some(entry) = self.conns.get_mut(&token) else {
                    self.submit_scratch = submits;
                    return;
                };
                while entry.conn.wants_write() {
                    match entry.stream.write(entry.conn.writable()) {
                        Ok(0) => {
                            dead = true;
                            break;
                        }
                        Ok(n) => {
                            entry.conn.advance_write(n);
                            progressed = true;
                        }
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                        Err(_) => {
                            dead = true;
                            break;
                        }
                    }
                }
                if !dead
                    && !self.stop_reading
                    && entry.conn.has_input()
                    && entry.conn.wbuf_len() < self.cfg.write_hwm
                    && entry.conn.process(&self.registry, self.cfg.write_hwm, &mut submits)
                {
                    progressed = true;
                }
            }
            if dead {
                self.submit_scratch = submits;
                self.close(token);
                return;
            }
            self.dispatch(token, &mut submits);
            self.submit_scratch = submits;
            match self.conns.get(&token) {
                Some(entry) if entry.conn.done() => {
                    self.close(token);
                    return;
                }
                Some(_) => {}
                None => return,
            }
            if !progressed {
                break;
            }
        }
        self.update_interest(token);
    }

    fn update_interest(&mut self, token: u64) {
        let Some(entry) = self.conns.get_mut(&token) else { return };
        let read = !self.stop_reading
            && !entry.conn.at_eof()
            && !entry.conn.is_closing()
            && entry.conn.wbuf_len() < self.cfg.write_hwm;
        let write = entry.conn.wants_write();
        if entry.interest != (read, write) {
            let _ = self.poller.reregister(entry.stream.as_raw_fd(), token, read, write);
            entry.interest = (read, write);
        }
    }

    /// Enter drain: close the listener, stop reading everywhere, and
    /// let the loop run until every owed reply has flushed.
    fn begin_drain(&mut self) {
        if let Some(listener) = self.listener.take() {
            let _ = self.poller.deregister(listener.as_raw_fd());
        }
        self.stop_reading = true;
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for t in tokens {
            self.update_interest(t);
        }
    }

    fn reap_quiesced(&mut self) {
        let tokens: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, e)| e.conn.quiesced())
            .map(|(t, _)| *t)
            .collect();
        for t in tokens {
            self.close(t);
        }
    }
}

//! Per-connection protocol state machine, shared by every front end.
//!
//! A [`Conn`] owns one connection's read/write buffers and speaks BOTH
//! wire protocols: JSON-lines (`docs/PROTOCOL.md`) and binary frames
//! ([`super::frame`]), selected by the first byte of the stream (the
//! sniffing rule: [`frame::MAGIC`] ⇒ binary, anything else ⇒
//! JSON-lines). It is deliberately I/O-free — callers feed bytes in
//! with [`Conn::ingest`], pull parsed inference submissions out of
//! [`Conn::process`], and drain reply bytes from [`Conn::writable`] —
//! so the epoll reactor ([`super::eventloop`]), the portable threaded
//! fallback, and the torture tests all drive the exact same logic.
//!
//! Reply ordering: every request is assigned a connection-local
//! sequence number in arrival order, and replies are written strictly
//! in that order (a ring-shaped reorder buffer holds replies that
//! complete early). Admin verbs are *deferred* until every earlier
//! reply has been written, which preserves the old thread-per-connection
//! server's serial semantics: a pipelined `stats` request observes the
//! effects of every inference request that preceded it on the wire.
//!
//! Error-survival model (the torture suite pins all three):
//! - a malformed payload inside a complete frame (or a bad JSON line)
//!   ⇒ coded error reply, connection survives — length/newline
//!   delimiting means the stream never desynchronizes;
//! - an oversized declared length ⇒ coded error reply, then the payload
//!   is discarded as it streams in, and the connection survives;
//! - a bad magic byte at a binary frame boundary ⇒ the stream is
//!   desynchronized: one final error reply, then close.

use std::collections::VecDeque;

use crate::util::json::{self, Value};

use super::batcher::Response;
use super::frame;
use super::registry::{CascadeSnapshot, ModelRegistry, TenantInfo};
use super::stats::StatsSnapshot;

/// Wire-level error: (human message, stable machine code).
pub type WireError = (String, &'static str);

/// Which protocol a connection speaks (decided by its first byte).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Protocol {
    /// No bytes seen yet.
    Unknown,
    /// One JSON document per `\n`-terminated line.
    JsonLines,
    /// Length-prefixed binary frames ([`super::frame`]).
    Binary,
}

/// An inference request parsed off the wire, awaiting dispatch to the
/// registry. The caller routes it (blocking or via callback) and hands
/// the encoded reply back through [`Conn::complete`] with the same
/// `seq`.
#[derive(Debug)]
pub struct SubmitReq {
    /// Connection-local reply slot (arrival order).
    pub seq: u64,
    /// Tenant to route to (`None` ⇒ the registry default).
    pub model: Option<String>,
    pub features: Vec<f32>,
}

/// A reply slot waiting its turn in the write order.
enum Pending {
    /// Encoded reply bytes, ready to write.
    Bytes(Vec<u8>),
    /// A deferred admin document, executed against the registry only
    /// when every earlier reply has been written (serial semantics).
    Admin(Value),
}

/// Max recycled vectors held per pool (per connection).
const POOL_SLOTS: usize = 64;
/// Max capacity (in bytes) a vector may retain to be pooled — oversized
/// one-off buffers are returned to the allocator instead of pinned.
const POOL_BYTES: usize = 64 * 1024;

/// One connection's buffers, protocol state, and reply reordering.
pub struct Conn {
    protocol: Protocol,
    max_frame: usize,
    rbuf: Vec<u8>,
    rpos: usize,
    wbuf: Vec<u8>,
    wpos: usize,
    /// Next sequence number to assign to an arriving request.
    next_seq: u64,
    /// Next sequence number whose reply goes on the wire.
    next_write: u64,
    /// Reply reorder ring: slot `i` holds the reply for sequence
    /// `next_write + i` once it completes (`None` = still owed). A ring
    /// instead of a map so the steady state allocates nothing — slots
    /// settle at the pipelining high-water mark and are reused.
    ready: VecDeque<Option<Pending>>,
    in_flight: usize,
    /// Remaining payload bytes of an oversized binary frame to discard.
    skip: usize,
    /// Discarding an over-long JSON line until its newline.
    json_skip: bool,
    closing: bool,
    eof: bool,
    /// Recycled feature vectors for parsed inference requests (filled by
    /// the front end as completions hand vectors back).
    feat_pool: Vec<Vec<f32>>,
    /// Recycled reply-encode buffers (dispatch takes one per request;
    /// [`Conn::drain_ready`] returns each after its bytes are copied to
    /// the write buffer).
    buf_pool: Vec<Vec<u8>>,
}

impl Conn {
    pub fn new(max_frame: usize) -> Self {
        Self {
            protocol: Protocol::Unknown,
            max_frame,
            rbuf: Vec::new(),
            rpos: 0,
            wbuf: Vec::new(),
            wpos: 0,
            next_seq: 0,
            next_write: 0,
            ready: VecDeque::new(),
            in_flight: 0,
            skip: 0,
            json_skip: false,
            closing: false,
            eof: false,
            feat_pool: Vec::new(),
            buf_pool: Vec::new(),
        }
    }

    /// A cleared feature vector from the pool (or a fresh one).
    pub fn take_feat(&mut self) -> Vec<f32> {
        self.feat_pool.pop().unwrap_or_default()
    }

    /// Return a spent feature vector to the pool.
    pub fn recycle_feat(&mut self, mut v: Vec<f32>) {
        if self.feat_pool.len() < POOL_SLOTS && v.capacity() * 4 <= POOL_BYTES {
            v.clear();
            self.feat_pool.push(v);
        }
    }

    /// A cleared reply-encode buffer from the pool (or a fresh one).
    pub fn take_buf(&mut self) -> Vec<u8> {
        self.buf_pool.pop().unwrap_or_default()
    }

    /// Return a spent reply buffer to the pool.
    pub fn recycle_buf(&mut self, mut v: Vec<u8>) {
        if self.buf_pool.len() < POOL_SLOTS && v.capacity() <= POOL_BYTES {
            v.clear();
            self.buf_pool.push(v);
        }
    }

    pub fn protocol(&self) -> Protocol {
        self.protocol
    }

    /// Inference requests dispatched but not yet completed.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// A fatal protocol error was hit: stop reading, close after the
    /// final error reply flushes.
    pub fn is_closing(&self) -> bool {
        self.closing
    }

    pub fn at_eof(&self) -> bool {
        self.eof
    }

    /// Unprocessed input is buffered (resume [`Conn::process`] once
    /// write backpressure clears).
    pub fn has_input(&self) -> bool {
        self.rpos < self.rbuf.len()
    }

    /// Connection is finished: the peer half-closed (or a fatal error
    /// was hit), every admitted request was answered, and every reply
    /// byte was handed to the socket.
    pub fn done(&self) -> bool {
        (self.eof || self.closing) && self.quiesced()
    }

    /// No replies owed: nothing in flight, nothing buffered to write.
    pub fn quiesced(&self) -> bool {
        self.in_flight == 0 && self.ready.is_empty() && !self.wants_write()
    }

    pub fn wants_write(&self) -> bool {
        self.wpos < self.wbuf.len()
    }

    /// Bytes queued for the socket (the write-backpressure gauge).
    pub fn wbuf_len(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    pub fn writable(&self) -> &[u8] {
        &self.wbuf[self.wpos..]
    }

    pub fn advance_write(&mut self, n: usize) {
        self.wpos += n;
        debug_assert!(self.wpos <= self.wbuf.len());
        if self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        } else if self.wpos > 64 * 1024 {
            self.wbuf.drain(..self.wpos);
            self.wpos = 0;
        }
    }

    /// Append raw bytes read off the socket.
    pub fn ingest(&mut self, data: &[u8]) {
        self.rbuf.extend_from_slice(data);
    }

    /// Parse as many complete requests as the buffer holds, stopping
    /// early if queued reply bytes reach `write_budget` (backpressure:
    /// a slow reader must not buffer unbounded replies). Admin requests
    /// and protocol errors are resolved internally (into the reply
    /// order); inference requests are pushed to `out` for the caller to
    /// route. Returns true if any input was consumed.
    pub fn process(
        &mut self,
        registry: &ModelRegistry,
        write_budget: usize,
        out: &mut Vec<SubmitReq>,
    ) -> bool {
        let mut progressed = false;
        loop {
            if self.closing || self.wbuf_len() >= write_budget {
                break;
            }
            if self.protocol == Protocol::Unknown {
                match self.rbuf.get(self.rpos) {
                    None => break,
                    Some(&b) if b == frame::MAGIC => self.protocol = Protocol::Binary,
                    Some(_) => self.protocol = Protocol::JsonLines,
                }
            }
            let stepped = match self.protocol {
                Protocol::JsonLines => self.step_json(registry, out),
                Protocol::Binary => self.step_binary(registry, out),
                Protocol::Unknown => unreachable!("protocol sniffed above"),
            };
            if !stepped {
                break;
            }
            progressed = true;
        }
        self.compact_rbuf();
        progressed
    }

    /// The peer half-closed its write side. A trailing JSON line with
    /// no newline terminator is still processed (matching
    /// `BufRead::lines`, which the old server was built on); a partial
    /// binary frame is dropped.
    pub fn on_eof(&mut self, registry: &ModelRegistry, out: &mut Vec<SubmitReq>) {
        self.eof = true;
        if self.protocol == Protocol::JsonLines
            && !self.json_skip
            && !self.closing
            && self.rpos < self.rbuf.len()
        {
            let line = String::from_utf8_lossy(&self.rbuf[self.rpos..]).into_owned();
            self.rpos = self.rbuf.len();
            self.handle_json_line(registry, &line, out);
        }
        self.compact_rbuf();
    }

    /// Deliver the encoded reply for an inference request previously
    /// returned by [`Conn::process`]. Replies may arrive in any order;
    /// they are written in sequence order.
    pub fn complete(&mut self, registry: &ModelRegistry, seq: u64, bytes: Vec<u8>) {
        debug_assert!(self.in_flight > 0, "complete() without a dispatched request");
        self.in_flight = self.in_flight.saturating_sub(1);
        self.park(seq, Pending::Bytes(bytes));
        self.drain_ready(registry);
    }

    fn alloc_seq(&mut self) -> u64 {
        let s = self.next_seq;
        self.next_seq += 1;
        s
    }

    /// Place a reply into its ring slot (`seq - next_write`), growing
    /// the ring to cover it. Slots only grow to the pipelining
    /// high-water mark, then are reused.
    fn park(&mut self, seq: u64, pending: Pending) {
        // Checked: a duplicate/late completion for an already-written seq
        // must not wrap to a huge index and abort in resize_with.
        let Some(offset) = seq.checked_sub(self.next_write) else {
            debug_assert!(false, "seq {seq} already written");
            return;
        };
        let idx = offset as usize;
        if self.ready.len() <= idx {
            self.ready.resize_with(idx + 1, || None);
        }
        self.ready[idx] = Some(pending);
    }

    fn insert(&mut self, registry: &ModelRegistry, seq: u64, pending: Pending) {
        self.park(seq, pending);
        self.drain_ready(registry);
    }

    /// Move every in-order ready reply into the write buffer, executing
    /// deferred admin documents as their turn comes (so an admin verb
    /// observes the effects of every request that preceded it).
    fn drain_ready(&mut self, registry: &ModelRegistry) {
        while matches!(self.ready.front(), Some(Some(_))) {
            let pending = self.ready.pop_front().flatten().expect("front checked Some");
            self.next_write += 1;
            match pending {
                Pending::Bytes(b) => {
                    self.wbuf.extend_from_slice(&b);
                    self.recycle_buf(b);
                }
                Pending::Admin(doc) => {
                    let bytes = match admin_reply(&doc, registry) {
                        Ok(v) => encode_admin_reply_bytes(self.protocol, &v),
                        Err((msg, code)) => encode_error_bytes(self.protocol, &msg, code),
                    };
                    self.wbuf.extend_from_slice(&bytes);
                }
            }
        }
    }

    fn compact_rbuf(&mut self) {
        if self.rpos == self.rbuf.len() {
            self.rbuf.clear();
            self.rpos = 0;
        } else if self.rpos > 16 * 1024 {
            self.rbuf.drain(..self.rpos);
            self.rpos = 0;
        }
    }

    /// One JSON-lines step: consume a skip region or one line.
    fn step_json(&mut self, registry: &ModelRegistry, out: &mut Vec<SubmitReq>) -> bool {
        let avail = &self.rbuf[self.rpos..];
        let newline = avail.iter().position(|&b| b == b'\n');
        if self.json_skip {
            return match newline {
                Some(i) => {
                    self.rpos += i + 1;
                    self.json_skip = false;
                    true
                }
                None => {
                    self.rpos = self.rbuf.len();
                    false
                }
            };
        }
        match newline {
            None => {
                if avail.len() > self.max_frame {
                    let seq = self.alloc_seq();
                    let msg = format!("line exceeds the {} byte limit", self.max_frame);
                    let bytes = encode_error_bytes(self.protocol, &msg, "bad_request");
                    self.insert(registry, seq, Pending::Bytes(bytes));
                    self.json_skip = true;
                    self.rpos = self.rbuf.len();
                    true
                } else {
                    false
                }
            }
            Some(i) => {
                let mut end = self.rpos + i;
                if end > self.rpos && self.rbuf[end - 1] == b'\r' {
                    end -= 1;
                }
                let line = String::from_utf8_lossy(&self.rbuf[self.rpos..end]).into_owned();
                self.rpos += i + 1;
                self.handle_json_line(registry, &line, out);
                true
            }
        }
    }

    fn handle_json_line(
        &mut self,
        registry: &ModelRegistry,
        line: &str,
        out: &mut Vec<SubmitReq>,
    ) {
        if line.trim().is_empty() {
            return;
        }
        let seq = self.alloc_seq();
        match parse_json_request(line) {
            Err((msg, code)) => {
                let bytes = encode_error_bytes(self.protocol, &msg, code);
                self.insert(registry, seq, Pending::Bytes(bytes));
            }
            Ok(Parsed::Admin(doc)) => self.insert(registry, seq, Pending::Admin(doc)),
            Ok(Parsed::Infer { model, features }) => {
                self.in_flight += 1;
                out.push(SubmitReq { seq, model, features });
            }
        }
    }

    /// One binary step: consume a skip region or one frame.
    fn step_binary(&mut self, registry: &ModelRegistry, out: &mut Vec<SubmitReq>) -> bool {
        if self.skip > 0 {
            let avail = self.rbuf.len() - self.rpos;
            let take = avail.min(self.skip);
            self.rpos += take;
            self.skip -= take;
            return self.skip == 0 && self.rpos < self.rbuf.len();
        }
        match frame::try_extract(&self.rbuf[self.rpos..], self.max_frame) {
            frame::Extract::NeedMore => false,
            frame::Extract::BadMagic(b) => {
                let seq = self.alloc_seq();
                let msg = format!("bad frame magic {b:#04x}: stream desynchronized");
                let bytes = encode_error_bytes(self.protocol, &msg, "bad_request");
                self.insert(registry, seq, Pending::Bytes(bytes));
                self.closing = true;
                self.rpos = self.rbuf.len();
                false
            }
            frame::Extract::Oversized { declared, .. } => {
                let seq = self.alloc_seq();
                let msg = format!(
                    "frame payload of {declared} bytes exceeds the {} byte cap",
                    self.max_frame
                );
                let bytes = encode_error_bytes(self.protocol, &msg, "bad_request");
                self.insert(registry, seq, Pending::Bytes(bytes));
                self.rpos += frame::HEADER_LEN;
                self.skip = declared;
                true
            }
            frame::Extract::Frame { header, payload } => {
                let lo = self.rpos + payload.start;
                let hi = self.rpos + payload.end;
                self.rpos += frame::HEADER_LEN + header.payload_len;
                let seq = self.alloc_seq();
                if header.version == frame::VERSION
                    && header.reserved == 0
                    && header.frame_type == frame::TYPE_REQ_INFER
                {
                    // Hot path: decode the f32 payload straight out of
                    // the read buffer into a pooled feature vector — no
                    // intermediate Vec, no per-request allocation for
                    // default-tenant requests.
                    let mut features = self.take_feat();
                    match frame::decode_infer_into(&self.rbuf[lo..hi], &mut features) {
                        Err((msg, code)) => {
                            self.recycle_feat(features);
                            let bytes = encode_error_bytes(self.protocol, &msg, code);
                            self.insert(registry, seq, Pending::Bytes(bytes));
                        }
                        Ok(model_range) => {
                            let model = if model_range.is_empty() {
                                None
                            } else {
                                let m = &self.rbuf[lo + model_range.start..lo + model_range.end];
                                // decode_infer_into validated the bytes.
                                Some(std::str::from_utf8(m).expect("validated utf-8").to_string())
                            };
                            self.in_flight += 1;
                            out.push(SubmitReq { seq, model, features });
                        }
                    }
                } else {
                    // Admin frames and header-level violations go through
                    // the reference decoder (identical error vocabulary).
                    match frame::decode_request(&header, &self.rbuf[lo..hi]) {
                        Err((msg, code)) => {
                            let bytes = encode_error_bytes(self.protocol, &msg, code);
                            self.insert(registry, seq, Pending::Bytes(bytes));
                        }
                        Ok(frame::BinaryRequest::Admin(doc)) => {
                            self.insert(registry, seq, Pending::Admin(doc))
                        }
                        Ok(frame::BinaryRequest::Infer { model, features }) => {
                            self.in_flight += 1;
                            out.push(SubmitReq { seq, model, features });
                        }
                    }
                }
                true
            }
        }
    }
}

/// A parsed JSON-lines request.
enum Parsed {
    Infer { model: Option<String>, features: Vec<f32> },
    Admin(Value),
}

/// A field that must be a string when present — a non-string value is a
/// protocol error, never silently treated as absent (a numeric "model"
/// must not route to the default tenant).
fn optional_str<'a>(v: &'a Value, key: &str) -> Result<Option<&'a str>, WireError> {
    match v.get(key) {
        None => Ok(None),
        Some(Value::String(s)) => Ok(Some(s.as_str())),
        Some(_) => Err((format!("'{key}' must be a string"), "bad_request")),
    }
}

fn parse_json_request(line: &str) -> Result<Parsed, WireError> {
    let v = json::parse(line).map_err(|e| (format!("bad json: {e}"), "bad_request"))?;
    let model = optional_str(&v, "model")?.map(str::to_string);
    match optional_str(&v, "cmd")? {
        Some(_) => Ok(Parsed::Admin(v)),
        None => {
            let feats = v
                .get("features")
                .and_then(Value::as_array)
                .ok_or_else(|| ("missing 'features' array".to_string(), "bad_request"))?;
            let features: Vec<f32> = feats
                .iter()
                .map(|f| {
                    f.as_f64()
                        .map(|x| x as f32)
                        .ok_or_else(|| ("non-numeric feature".to_string(), "bad_request"))
                })
                .collect::<Result<_, _>>()?;
            Ok(Parsed::Infer { model, features })
        }
    }
}

fn stats_fields(s: &StatsSnapshot) -> Vec<(&'static str, Value)> {
    vec![
        ("requests", json::num(s.requests as f64)),
        ("responses", json::num(s.responses as f64)),
        ("rejected", json::num(s.rejected as f64)),
        ("failures", json::num(s.failures as f64)),
        ("reloads", json::num(s.reloads as f64)),
        ("mean_batch", json::num(s.mean_batch_size)),
        ("latency_p50_us", json::num(s.latency_p50_us)),
        ("latency_p99_us", json::num(s.latency_p99_us)),
        ("throughput_rps", json::num(s.throughput_rps)),
    ]
}

/// Extra `stats`/`models` fields for tenants with an online trainer.
/// Conditional on attachment so frozen tenants keep the exact 9-field
/// stats surface the conformance goldens pin.
fn trainer_fields(t: &crate::loghd::online::TrainerStats) -> Vec<(&'static str, Value)> {
    vec![
        ("trainer_ingested", json::num(t.ingested as f64)),
        ("trainer_rejected", json::num(t.rejected as f64)),
        ("trainer_buffered", json::num(t.buffered as f64)),
        ("trainer_generation", json::num(t.generation as f64)),
        ("trainer_classes", json::num(t.classes as f64)),
    ]
}

/// Extra `stats`/`models` fields for `--cascade` tenants. Conditional on
/// the cascade being configured so plain tenants keep the exact 9-field
/// stats surface the conformance goldens pin. Rates are derived here so
/// both protocols report identical documents.
fn cascade_fields(c: &CascadeSnapshot) -> Vec<(&'static str, Value)> {
    let total = (c.tier1 + c.escalated) as f64;
    let rate = |n: u64| if total > 0.0 { n as f64 / total } else { 0.0 };
    vec![
        ("cascade_threshold", json::num(c.threshold as f64)),
        ("cascade_tier1", json::num(c.tier1 as f64)),
        ("cascade_escalated", json::num(c.escalated as f64)),
        ("cascade_agreed", json::num(c.agreed as f64)),
        ("cascade_tier1_rate", json::num(rate(c.tier1))),
        ("cascade_escalation_rate", json::num(rate(c.escalated))),
    ]
}

fn tenant_json(info: &TenantInfo) -> Value {
    let mut fields = vec![
        ("model", json::s(info.name.clone())),
        ("kind", json::s(info.kind.clone())),
        ("precision", json::s(info.precision)),
        ("replicas", json::num(info.replicas as f64)),
        ("live_replicas", json::num(info.live_replicas as f64)),
        ("features", json::num(info.features as f64)),
        ("default", Value::Bool(info.is_default)),
    ];
    if let Some(path) = &info.path {
        fields.push(("path", json::s(path.display().to_string())));
    }
    fields.extend(stats_fields(&info.stats));
    if let Some(t) = &info.trainer {
        fields.extend(trainer_fields(t));
    }
    if let Some(c) = &info.cascade {
        fields.extend(cascade_fields(c));
    }
    json::obj(fields)
}

/// Execute one admin document (`stats` / `models` / `reload`) against
/// the registry and build the reply document. Shared verbatim by both
/// protocols — the conformance suite's equivalence claim rests on this
/// being the single implementation.
pub fn admin_reply(doc: &Value, registry: &ModelRegistry) -> Result<Value, WireError> {
    let model = optional_str(doc, "model")?;
    match optional_str(doc, "cmd")? {
        Some("stats") => {
            let (name, s) = registry.stats(model).map_err(|e| (e.to_string(), e.code()))?;
            let mut fields = vec![("model", json::s(name))];
            fields.extend(stats_fields(&s));
            if let Ok(Some(t)) = registry.trainer_stats(model) {
                fields.extend(trainer_fields(&t));
            }
            if let Ok(Some(c)) = registry.cascade_stats(model) {
                fields.extend(cascade_fields(&c));
            }
            Ok(json::obj(fields))
        }
        Some("models") => {
            let models: Vec<Value> = registry.describe().iter().map(tenant_json).collect();
            Ok(json::obj(vec![
                ("default", json::s(registry.default_model())),
                ("models", json::arr(models)),
            ]))
        }
        Some("reload") => {
            let path = optional_str(doc, "path")?.map(std::path::Path::new);
            let bits = match doc.get("bits") {
                None => None,
                Some(b) => match b.as_f64() {
                    Some(x) if x.fract() == 0.0 && x >= 0.0 => Some(x as u32),
                    _ => {
                        return Err((
                            "'bits' must be a non-negative integer".into(),
                            "bad_request",
                        ))
                    }
                },
            };
            let info =
                registry.reload(model, path, bits).map_err(|e| (e.to_string(), e.code()))?;
            Ok(json::obj(vec![
                ("reloaded", json::s(info.name)),
                ("kind", json::s(info.kind)),
                ("precision", json::s(info.precision)),
                ("replicas", json::num(info.replicas as f64)),
            ]))
        }
        Some("feedback") => {
            let feats = doc
                .get("features")
                .and_then(Value::as_array)
                .ok_or_else(|| ("missing 'features' array".to_string(), "bad_request"))?;
            let features: Vec<f32> = feats
                .iter()
                .map(|f| {
                    f.as_f64()
                        .map(|x| x as f32)
                        .ok_or_else(|| ("non-numeric feature".to_string(), "bad_request"))
                })
                .collect::<Result<_, _>>()?;
            // Integer-strict like `bits`: a fractional or non-numeric
            // label is a protocol error; a well-formed but out-of-range
            // one is the trainer's call (coded `bad_label`).
            let label = match doc.get("label").and_then(Value::as_f64) {
                Some(x)
                    if x.fract() == 0.0
                        && (i32::MIN as f64..=i32::MAX as f64).contains(&x) =>
                {
                    x as i32
                }
                _ => return Err(("'label' must be an integer".into(), "bad_request")),
            };
            let (name, ack) = registry
                .feedback(model, &features, label)
                .map_err(|e| (e.to_string(), e.code()))?;
            Ok(json::obj(vec![
                ("model", json::s(name)),
                ("ingested", json::num(ack.ingested as f64)),
                ("buffered", json::num(ack.buffered as f64)),
                ("generation", json::num(ack.generation as f64)),
                ("classes", json::num(ack.classes as f64)),
                ("published", Value::Bool(ack.published)),
            ]))
        }
        Some(other) => Err((format!("unknown cmd '{other}'"), "bad_request")),
        None => Err(("admin document missing 'cmd'".into(), "bad_request")),
    }
}

/// The JSON-lines inference reply document (field order is part of the
/// protocol's observable surface and pinned by the golden transcript).
pub fn infer_reply_json(model: &str, resp: &Response) -> Value {
    json::obj(vec![
        ("id", json::num(resp.id as f64)),
        ("model", json::s(model)),
        ("label", json::num(resp.label as f64)),
        ("latency_us", json::num(resp.latency.as_secs_f64() * 1e6)),
    ])
}

/// Encode an inference reply for `protocol`, appending to `out` — the
/// pooled-buffer form used by the reactor's completion sink. The binary
/// arm is allocation-free once `out` has capacity; the JSON arm pays
/// the documented small per-reply constant (`json::to_string` builds an
/// intermediate `String`).
pub fn encode_infer_reply_into(
    protocol: Protocol,
    model: &str,
    resp: &Response,
    out: &mut Vec<u8>,
) {
    match protocol {
        Protocol::JsonLines | Protocol::Unknown => {
            let s = json::to_string(&infer_reply_json(model, resp));
            out.extend_from_slice(s.as_bytes());
            out.push(b'\n');
        }
        Protocol::Binary => frame::encode_infer_reply(
            resp.id,
            resp.label,
            resp.latency.as_secs_f64() * 1e6,
            model,
            out,
        ),
    }
}

/// Encode an inference reply for `protocol`.
pub fn encode_infer_reply_bytes(protocol: Protocol, model: &str, resp: &Response) -> Vec<u8> {
    let mut out = Vec::new();
    encode_infer_reply_into(protocol, model, resp, &mut out);
    out
}

/// Encode an admin reply document for `protocol`.
pub fn encode_admin_reply_bytes(protocol: Protocol, doc: &Value) -> Vec<u8> {
    let text = json::to_string(doc);
    match protocol {
        Protocol::JsonLines | Protocol::Unknown => {
            let mut s = text;
            s.push('\n');
            s.into_bytes()
        }
        Protocol::Binary => {
            let mut out = Vec::new();
            frame::encode_admin_reply(&text, &mut out);
            out
        }
    }
}

/// Encode a coded error reply for `protocol`, appending to `out`.
pub fn encode_error_into(protocol: Protocol, msg: &str, code: &str, out: &mut Vec<u8>) {
    match protocol {
        Protocol::JsonLines | Protocol::Unknown => {
            let s = json::to_string(&json::obj(vec![
                ("error", json::s(msg)),
                ("code", json::s(code)),
            ]));
            out.extend_from_slice(s.as_bytes());
            out.push(b'\n');
        }
        Protocol::Binary => frame::encode_error_reply(msg, code, out),
    }
}

/// Encode a coded error reply for `protocol`.
pub fn encode_error_bytes(protocol: Protocol, msg: &str, code: &str) -> Vec<u8> {
    let mut out = Vec::new();
    encode_error_into(protocol, msg, code, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::Engine;
    use crate::tensor::Matrix;
    use std::time::Duration;

    struct Echo;
    impl Engine for Echo {
        fn name(&self) -> String {
            "echo".into()
        }
        fn features(&self) -> usize {
            2
        }
        fn infer(&mut self, x: &Matrix) -> anyhow::Result<Vec<i32>> {
            Ok((0..x.rows()).map(|i| x.at(i, 0) as i32).collect())
        }
    }

    fn echo_registry() -> ModelRegistry {
        ModelRegistry::single(
            "echo",
            "demo",
            2,
            &BatcherConfig::default(),
            vec![Box::new(|| Ok(Box::new(Echo) as Box<dyn Engine>))],
        )
    }

    fn resp(id: u64, label: i32) -> Response {
        Response { id, label, latency: Duration::from_micros(10) }
    }

    #[test]
    fn replies_are_written_in_request_order() {
        let registry = echo_registry();
        let mut conn = Conn::new(frame::DEFAULT_MAX_FRAME);
        let mut out = Vec::new();
        conn.ingest(b"{\"features\": [1, 0]}\n{\"features\": [2, 0]}\n");
        assert!(conn.process(&registry, usize::MAX, &mut out));
        assert_eq!(conn.protocol(), Protocol::JsonLines);
        assert_eq!(out.len(), 2);
        assert_eq!(conn.in_flight(), 2);
        // Complete the SECOND request first: nothing may be written yet.
        let b1 = encode_infer_reply_bytes(conn.protocol(), "echo", &resp(1, 2));
        conn.complete(&registry, out[1].seq, b1);
        assert!(!conn.wants_write());
        let b0 = encode_infer_reply_bytes(conn.protocol(), "echo", &resp(0, 1));
        conn.complete(&registry, out[0].seq, b0);
        let text = String::from_utf8(conn.writable().to_vec()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("\"id\": 0"), "{}", lines[0]);
        assert!(lines[1].contains("\"id\": 1"), "{}", lines[1]);
        assert_eq!(conn.in_flight(), 0);
    }

    #[test]
    fn pipelined_admin_waits_for_earlier_inference() {
        let registry = echo_registry();
        let mut conn = Conn::new(frame::DEFAULT_MAX_FRAME);
        let mut out = Vec::new();
        conn.ingest(b"{\"features\": [3, 0]}\n{\"cmd\": \"stats\"}\n");
        conn.process(&registry, usize::MAX, &mut out);
        assert_eq!(out.len(), 1);
        // The stats document must not execute yet — the inference reply
        // (and its `responses` increment) comes first.
        assert!(!conn.wants_write());
        let (_, r) = registry.submit_blocking(None, vec![3.0, 0.0]).unwrap();
        let bytes = encode_infer_reply_bytes(conn.protocol(), "echo", &r);
        conn.complete(&registry, out[0].seq, bytes);
        let text = String::from_utf8(conn.writable().to_vec()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let stats = json::parse(lines[1]).unwrap();
        assert_eq!(stats.get("responses").and_then(Value::as_f64), Some(1.0));
    }

    #[test]
    fn binary_oversized_frame_is_survivable() {
        let registry = echo_registry();
        let mut conn = Conn::new(64);
        let mut out = Vec::new();
        // An oversized header, its (streamed, discarded) payload, then a
        // good frame — the connection must answer both.
        let mut buf = Vec::new();
        buf.push(frame::MAGIC);
        buf.push(frame::VERSION);
        buf.push(frame::TYPE_REQ_INFER);
        buf.push(0);
        buf.extend_from_slice(&(100u32).to_le_bytes());
        buf.extend_from_slice(&[0xAA; 100]);
        frame::encode_infer_request(None, &[4.0, 0.0], &mut buf);
        conn.ingest(&buf);
        conn.process(&registry, usize::MAX, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].features, vec![4.0, 0.0]);
        assert!(!conn.is_closing());
        // The error reply for the oversized frame is already queued.
        let w = conn.writable().to_vec();
        let (h, p) = match frame::try_extract(&w, frame::DEFAULT_MAX_FRAME) {
            frame::Extract::Frame { header, payload } => (header, w[payload].to_vec()),
            other => panic!("{other:?}"),
        };
        let doc = frame::decode_reply_to_json(&h, &p).unwrap();
        assert_eq!(doc.get("code").and_then(Value::as_str), Some("bad_request"));
    }

    #[test]
    fn bad_magic_mid_stream_closes_after_error() {
        let registry = echo_registry();
        let mut conn = Conn::new(frame::DEFAULT_MAX_FRAME);
        let mut out = Vec::new();
        let mut buf = Vec::new();
        frame::encode_infer_request(None, &[1.0, 0.0], &mut buf);
        buf.extend_from_slice(b"garbage");
        conn.ingest(&buf);
        conn.process(&registry, usize::MAX, &mut out);
        assert_eq!(out.len(), 1);
        assert!(conn.is_closing());
        assert!(!conn.done(), "must still flush the in-flight reply + error");
        let bytes = encode_infer_reply_bytes(conn.protocol(), "echo", &resp(0, 1));
        conn.complete(&registry, out[0].seq, bytes);
        let n = conn.writable().len();
        conn.advance_write(n);
        assert!(conn.done());
    }

    #[test]
    fn feedback_verb_ingests_and_reports_trainer_stats() {
        let registry = echo_registry();
        let mut conn = Conn::new(frame::DEFAULT_MAX_FRAME);
        let mut out = Vec::new();
        // Without a trainer: coded refusal, and the stats reply keeps the
        // bare 9-field surface (no trainer_* fields).
        conn.ingest(b"{\"cmd\": \"feedback\", \"features\": [1, 0], \"label\": 0}\n");
        conn.ingest(b"{\"cmd\": \"stats\"}\n");
        conn.process(&registry, usize::MAX, &mut out);
        assert!(out.is_empty(), "feedback is an admin verb, not an inference");
        let text = String::from_utf8(conn.writable().to_vec()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        let err = json::parse(lines[0]).unwrap();
        assert_eq!(err.get("code").and_then(Value::as_str), Some("no_trainer"));
        let stats = json::parse(lines[1]).unwrap();
        assert!(stats.get("trainer_ingested").is_none());
        assert!(stats.get("cascade_threshold").is_none(), "bare tenants keep the 9-field surface");
        let n = conn.writable().len();
        conn.advance_write(n);

        // Attach a (hand-built, width-2) trainer: acks flow, malformed
        // documents stay bad_request, out-of-range labels are bad_label,
        // and stats grows the trainer_* fields.
        let encoder = crate::encoder::Encoder::new(2, 16, 1);
        let book = crate::loghd::codebook::build(3, 2, 2, 1.0, 1).unwrap();
        let mut bundles =
            Matrix::from_vec(2, 16, crate::util::rng::SplitMix64::new(2).normals_f32(32));
        crate::tensor::normalize_rows(&mut bundles);
        let model = crate::loghd::LogHdModel {
            classes: 3,
            d: 16,
            book,
            bundles,
            profiles: Matrix::zeros(3, 2),
        };
        let trainer = crate::loghd::OnlineTrainer::new(
            encoder,
            model,
            crate::loghd::OnlineConfig { publish_every: 1000, ..Default::default() },
        );
        registry.attach_trainer(None, trainer).unwrap();
        conn.ingest(b"{\"cmd\": \"feedback\", \"features\": [0.5, 1.5], \"label\": 1}\n");
        conn.ingest(b"{\"cmd\": \"feedback\", \"features\": [0.5, 1.5], \"label\": 1.5}\n");
        conn.ingest(b"{\"cmd\": \"feedback\", \"features\": [0.5, 1.5], \"label\": 9}\n");
        conn.ingest(b"{\"cmd\": \"feedback\", \"features\": [0.5, \"x\"], \"label\": 1}\n");
        conn.ingest(b"{\"cmd\": \"feedback\", \"features\": [0.5, 1.5]}\n");
        conn.ingest(b"{\"cmd\": \"stats\"}\n");
        conn.process(&registry, usize::MAX, &mut out);
        let text = String::from_utf8(conn.writable().to_vec()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        let ack = json::parse(lines[0]).unwrap();
        assert_eq!(ack.get("model").and_then(Value::as_str), Some("echo"));
        assert_eq!(ack.get("ingested").and_then(Value::as_f64), Some(1.0));
        assert_eq!(ack.get("buffered").and_then(Value::as_f64), Some(1.0));
        assert_eq!(ack.get("classes").and_then(Value::as_f64), Some(3.0));
        assert!(matches!(ack.get("published"), Some(Value::Bool(false))));
        let code = |l: &str| json::parse(l).unwrap().get("code").and_then(Value::as_str).map(str::to_string);
        assert_eq!(code(lines[1]).as_deref(), Some("bad_request"), "fractional label");
        assert_eq!(code(lines[2]).as_deref(), Some("bad_label"), "label gap");
        assert_eq!(code(lines[3]).as_deref(), Some("bad_request"), "non-numeric feature");
        assert_eq!(code(lines[4]).as_deref(), Some("bad_request"), "missing label");
        let stats = json::parse(lines[5]).unwrap();
        assert_eq!(stats.get("trainer_ingested").and_then(Value::as_f64), Some(1.0));
        assert_eq!(stats.get("trainer_rejected").and_then(Value::as_f64), Some(1.0));
        assert_eq!(stats.get("trainer_generation").and_then(Value::as_f64), Some(0.0));
    }

    #[test]
    fn stats_surface_grows_cascade_fields_only_for_cascade_tenants() {
        // Pin the exact extra field set `--cascade` tenants expose on the
        // `stats` and `models` verbs; plain tenants keep the golden
        // 9-field surface (asserted next to the trainer fields above).
        let root = std::env::temp_dir().join("loghd_conn_cascade_stats");
        let _ = std::fs::remove_dir_all(&root);
        let ds = crate::data::generate_scaled(crate::data::spec("page").unwrap(), 200, 30);
        let opts = crate::loghd::TrainOptions {
            epochs: 1,
            conv_epochs: 0,
            extra_bundles: 1,
            ..Default::default()
        };
        let st =
            crate::loghd::TrainedStack::train(&ds.x_train, &ds.y_train, 5, 128, 1, &opts).unwrap();
        crate::loghd::persist::save(&root.join("m"), &st.encoder, &st.loghd).unwrap();
        let cal =
            crate::loghd::cascade::calibrate(&st.encoder, &st.loghd, &ds.x_train, 0.9, 3).unwrap();
        crate::loghd::cascade::write_threshold(&root.join("m"), &cal).unwrap();
        let spec = crate::coordinator::TenantSpec {
            name: "m".into(),
            path: root.join("m"),
            precision: crate::quant::Precision::F32,
            replicas: 1,
            cascade: true,
        };
        let registry = ModelRegistry::open(&[spec], None, &BatcherConfig::default()).unwrap();
        for i in 0..4 {
            registry.submit_blocking(None, ds.x_test.row(i).to_vec()).unwrap();
        }
        let mut conn = Conn::new(frame::DEFAULT_MAX_FRAME);
        let mut out = Vec::new();
        conn.ingest(b"{\"cmd\": \"stats\"}\n{\"cmd\": \"models\"}\n");
        conn.process(&registry, usize::MAX, &mut out);
        let text = String::from_utf8(conn.writable().to_vec()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        let stats = json::parse(lines[0]).unwrap();
        for key in [
            "cascade_threshold",
            "cascade_tier1",
            "cascade_escalated",
            "cascade_agreed",
            "cascade_tier1_rate",
            "cascade_escalation_rate",
        ] {
            assert!(stats.get(key).is_some(), "stats reply missing {key}");
        }
        assert_eq!(
            stats.get("cascade_threshold").and_then(Value::as_f64),
            Some(cal.threshold as f64)
        );
        let tier1 = stats.get("cascade_tier1").and_then(Value::as_f64).unwrap();
        let esc = stats.get("cascade_escalated").and_then(Value::as_f64).unwrap();
        assert_eq!(tier1 + esc, 4.0, "every routed row lands in exactly one tier");
        let rate = stats.get("cascade_escalation_rate").and_then(Value::as_f64).unwrap();
        assert!((rate - esc / 4.0).abs() < 1e-12);
        let models = json::parse(lines[1]).unwrap();
        let arr = models.get("models").and_then(Value::as_array).unwrap();
        assert!(arr[0].get("cascade_tier1").is_some(), "models verb carries the same fields");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn write_budget_pauses_parsing() {
        let registry = echo_registry();
        let mut conn = Conn::new(frame::DEFAULT_MAX_FRAME);
        let mut out = Vec::new();
        // Two bad lines: each produces an immediate error reply. With a
        // tiny write budget only the first is parsed.
        conn.ingest(b"x\ny\n");
        conn.process(&registry, 8, &mut out);
        assert!(conn.has_input());
        let one = conn.wbuf_len();
        assert!(one > 8);
        // Draining the write buffer resumes parsing.
        let n = conn.writable().len();
        conn.advance_write(n);
        conn.process(&registry, 8, &mut out);
        assert!(!conn.has_input());
        assert!(conn.wbuf_len() > 0);
    }
}

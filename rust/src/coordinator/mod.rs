//! The serving coordinator — L3's system contribution.
//!
//! Shape: request router → dynamic batcher (max-batch / max-delay, bounded
//! queue with backpressure) → a worker thread that owns the inference
//! engine (PJRT executables are not `Sync`; the engine is *constructed on*
//! the worker thread from a `Send` factory) → per-request response
//! channels → metrics.
//!
//! Two engines implement [`Engine`]:
//! - [`worker::PjrtEngine`] — the AOT path: compiled HLO via the PJRT C
//!   API (Python never runs here).
//! - [`worker::NativeEngine`] — the pure-Rust path used by the figure
//!   harnesses and as a serving fallback; also the parity reference.

pub mod batcher;
pub mod server;
pub mod stats;
pub mod worker;

pub use batcher::{BatcherConfig, Coordinator, Request, Response, SubmitError};
pub use server::Server;
pub use stats::StatsSnapshot;
pub use worker::{EngineFactory, NativeEngine, PjrtEngine};

use anyhow::Result;

use crate::tensor::Matrix;

/// An inference engine: a batch of feature rows in, one label per row out.
pub trait Engine {
    /// Human-readable engine id (for metrics / logs).
    fn name(&self) -> String;
    /// Feature width expected in requests.
    fn features(&self) -> usize;
    /// Classify a batch.
    fn infer(&mut self, x: &Matrix) -> Result<Vec<i32>>;
}

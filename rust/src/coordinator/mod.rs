//! The serving coordinator — L3's system contribution.
//!
//! Shape: TCP front-end ([`Server`]) → multi-tenant [`ModelRegistry`]
//! (named models, per-tenant admission control, hot reload) → per-tenant
//! [`Coordinator`]: a dynamic batcher (max-batch / max-delay, bounded
//! queue with backpressure) feeding a pool of worker replicas. Each
//! replica owns its engine instance (PJRT executables are not `Sync`; the
//! engine is *constructed on* the worker thread from a `Send` factory)
//! and pulls ready batches off the shared queue — round-robin across idle
//! replicas, least-loaded under skew. Answers travel back through a
//! per-request completion: a boxed callback (blocking/legacy paths) or a
//! shared [`CompletionSink`] carrying a [`Ticket`] (the zero-allocation
//! front-door path); [`stats`] aggregates per-tenant metrics.
//!
//! The front door itself is layered: [`eventloop`] (unix) runs a small
//! fixed pool of epoll/poll reactor threads; [`conn`] is the
//! protocol-agnostic per-connection state machine (sniffing, framing,
//! reply ordering, backpressure accounting) shared by the reactor, the
//! portable blocking fallback, and the torture tests; [`frame`] is the
//! pure length-prefixed binary codec. JSON-lines and binary clients get
//! semantically identical replies — `docs/PROTOCOL.md` specifies both.
//!
//! Five engines implement [`Engine`]:
//! - [`worker::PjrtEngine`] — the AOT path: compiled HLO via the PJRT C
//!   API (Python never runs here).
//! - [`worker::NativeEngine`] — the pure-Rust LogHD path used by the
//!   figure harnesses and as a serving fallback; also the parity
//!   reference. Serves f32, int8, and 1-bit packed precisions.
//! - [`worker::CascadeEngine`] — the adaptive precision cascade: every
//!   batch runs the packed b1 twin first, rows whose normalized decode
//!   margin clears a calibrated threshold are answered immediately, and
//!   only the ambiguous remainder is gathered into a compacted
//!   sub-batch for exact decode (see `docs/ARCHITECTURE.md` §Hot path).
//! - [`worker::ConventionalEngine`] — the O(C·D) baseline, for tenant
//!   mixes that compare LogHD against it under one memory budget.
//! - [`worker::ZooEngine`] — the generic trait-backed engine: any
//!   [`crate::model::HdClassifier`] instance from the model zoo
//!   (currently the DecoHD baseline) serves through it with no
//!   per-family wiring; engine dispatch lives in `model::zoo`.
//!
//! # Example
//!
//! Any [`Engine`] can be served; a registry routes by model name and
//! answers on per-request channels:
//!
//! ```
//! use loghd::coordinator::{BatcherConfig, Engine, ModelRegistry};
//! use loghd::tensor::Matrix;
//!
//! struct Echo;
//! impl Engine for Echo {
//!     fn name(&self) -> String {
//!         "echo".into()
//!     }
//!     fn features(&self) -> usize {
//!         2
//!     }
//!     fn infer(&mut self, x: &Matrix) -> anyhow::Result<Vec<i32>> {
//!         Ok((0..x.rows()).map(|i| x.at(i, 0) as i32).collect())
//!     }
//! }
//!
//! let registry = ModelRegistry::single(
//!     "echo",
//!     "demo",
//!     2,
//!     &BatcherConfig::default(),
//!     vec![Box::new(|| Ok(Box::new(Echo) as Box<dyn Engine>))],
//! );
//! let (model, resp) = registry.submit_blocking(None, vec![7.0, 0.0]).unwrap();
//! assert_eq!((model.as_str(), resp.label), ("echo", 7));
//! ```

pub mod batcher;
pub mod conn;
#[cfg(unix)]
pub mod eventloop;
pub mod frame;
pub mod registry;
pub mod server;
pub mod stats;
pub mod worker;

pub use batcher::{
    BatcherConfig, CompletionSink, Coordinator, ReloadError, Request, Response, ResponseCallback,
    SubmitError, Ticket,
};
pub use registry::{CascadeSnapshot, ModelRegistry, RouteError, TenantInfo, TenantSpec};
pub use server::{Server, ServerConfig, ServerStats};
pub use stats::StatsSnapshot;
pub use worker::{
    CascadeCounters, CascadeEngine, ConventionalEngine, EngineFactory, NativeEngine, PjrtEngine,
    ZooEngine,
};

use anyhow::Result;

use crate::tensor::Matrix;

/// An inference engine: a batch of feature rows in, one label per row out.
pub trait Engine {
    /// Human-readable engine id (for metrics / logs).
    fn name(&self) -> String;
    /// Feature width expected in requests.
    fn features(&self) -> usize;
    /// Classify a batch.
    fn infer(&mut self, x: &Matrix) -> Result<Vec<i32>>;
    /// [`Self::infer`] through caller-owned scratch — the steady-state
    /// serving form. Engines with native `_into` pipelines override this
    /// to reuse every intermediate across batches; the default delegates
    /// to [`Self::infer`] (correct for any engine, but allocating). The
    /// returned slice borrows `scratch.labels` and is bit-identical to
    /// what `infer` returns — parity is pinned per engine in
    /// `worker::tests`.
    fn infer_into<'s>(&mut self, x: &Matrix, scratch: &'s mut InferScratch) -> Result<&'s [i32]> {
        scratch.labels = self.infer(x)?;
        Ok(&scratch.labels)
    }
}

/// Reusable inference buffers owned by a worker replica and threaded
/// through [`Engine::infer_into`]: the encoded batch, the activation and
/// distance matrices, the per-query squared-norm terms, and the output
/// labels. Buffers grow to the batch high-water mark and then stop
/// allocating; engines use whichever fields their pipeline needs.
#[derive(Debug, Default)]
pub struct InferScratch {
    /// Output labels — what [`Engine::infer_into`] returns a borrow of.
    pub labels: Vec<i32>,
    /// Encoded batch (B, D).
    pub enc: Matrix,
    /// Bundle activations (B, n) / conventional scores (B, C).
    pub acts: Matrix,
    /// Activation-space squared distances (B, C).
    pub dists: Matrix,
    /// Per-query `|A|²` terms of the fused squared-distance decode.
    pub asq: Vec<f32>,
    /// Per-row normalized decode margins (cascade tier-1 output).
    pub margins: Vec<f32>,
    /// Original batch indices of the rows the cascade escalates.
    pub esc_rows: Vec<u32>,
    /// Compacted escalated sub-batch, gathered from `enc` (no re-encode).
    pub esc_enc: Matrix,
    /// Exact-tier activations over the escalated sub-batch.
    pub esc_acts: Matrix,
    /// Exact-tier squared distances over the escalated sub-batch.
    pub esc_dists: Matrix,
    /// Exact-tier `|A|²` terms over the escalated sub-batch.
    pub esc_asq: Vec<f32>,
    /// Exact-tier labels over the escalated sub-batch, scattered back
    /// into `labels` by row index.
    pub esc_labels: Vec<i32>,
}

impl InferScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

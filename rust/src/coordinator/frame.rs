//! Binary frame codec for the front door.
//!
//! The wire layout is specified in `docs/PROTOCOL.md` §Binary framing —
//! that file is the source of truth for client authors; this module is
//! the reference implementation, pure (no I/O) so the torture suite can
//! drive it byte by byte. A connection speaks binary frames when its
//! FIRST byte is [`MAGIC`]; anything else selects JSON-lines (see
//! [`super::server`]). Both protocols carry the same request/reply/admin
//! semantics and the same error codes.
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//! offset size  field
//! 0      1     magic 0xB7
//! 1      1     version (currently 0x01)
//! 2      1     frame type (see the TYPE_* constants)
//! 3      1     reserved, must be 0
//! 4      4     payload length N (u32; bounded by the server's max_frame)
//! 8      N     payload
//! ```
//!
//! Because every frame is length-delimited, a malformed *payload* never
//! desynchronizes the stream: the frame is consumed, a coded error reply
//! is sent, and the connection survives. An oversized declared length is
//! also survivable (the server discards the payload as it streams in).
//! Only a bad magic byte at a frame boundary is unrecoverable — the
//! stream has desynchronized and the connection is closed after a final
//! error frame.

use crate::util::json::{self, Value};

/// First byte of every binary frame (and the protocol-sniffing byte:
/// a connection whose first byte is not `MAGIC` speaks JSON-lines).
/// Deliberately outside ASCII and invalid as UTF-8 lead byte, so no JSON
/// document can start with it.
pub const MAGIC: u8 = 0xB7;
/// Current protocol version.
pub const VERSION: u8 = 0x01;
/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 8;
/// Default cap on declared payload length (16 MiB).
pub const DEFAULT_MAX_FRAME: usize = 16 * 1024 * 1024;

/// Inference request: `[u8 model_len][model utf8][u32 count][count × f32]`.
pub const TYPE_REQ_INFER: u8 = 0x01;
/// Inference reply: `[u64 id][i32 label][f64 latency_us][u8 model_len][model utf8]`.
pub const TYPE_REP_INFER: u8 = 0x02;
/// Admin request: a UTF-8 JSON document with a `"cmd"` field — exactly
/// the JSON-lines admin request body.
pub const TYPE_REQ_ADMIN: u8 = 0x03;
/// Admin reply: the same UTF-8 JSON document the JSON-lines protocol
/// would send for this request.
pub const TYPE_REP_ADMIN: u8 = 0x04;
/// Error reply: `[u8 code_len][code utf8][message utf8 …]`.
pub const TYPE_REP_ERROR: u8 = 0x05;

/// A parsed frame header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Header {
    pub version: u8,
    pub frame_type: u8,
    pub reserved: u8,
    pub payload_len: usize,
}

/// Outcome of [`try_extract`] on a (possibly incomplete) byte buffer.
#[derive(Debug, PartialEq, Eq)]
pub enum Extract {
    /// Not enough bytes for a header + declared payload yet.
    NeedMore,
    /// One complete frame: header plus the payload's byte range within
    /// the input buffer. Consume `HEADER_LEN + payload_len` bytes.
    Frame { header: Header, payload: std::ops::Range<usize> },
    /// The header declares a payload larger than `max_frame`. The caller
    /// should reply with a coded error, consume the header, and discard
    /// the next `declared` payload bytes as they arrive — the connection
    /// survives.
    Oversized { header: Header, declared: usize },
    /// The byte at a frame boundary is not [`MAGIC`]: the stream is
    /// desynchronized and the connection cannot be saved.
    BadMagic(u8),
}

/// Try to extract one frame from the front of `buf`. Header-level
/// problems other than bad magic (unknown version/type, nonzero
/// reserved byte) are NOT rejected here — the frame boundary is still
/// trustworthy, so they surface as per-frame coded errors from
/// [`decode_request`].
pub fn try_extract(buf: &[u8], max_frame: usize) -> Extract {
    if buf.is_empty() {
        return Extract::NeedMore;
    }
    if buf[0] != MAGIC {
        return Extract::BadMagic(buf[0]);
    }
    if buf.len() < HEADER_LEN {
        return Extract::NeedMore;
    }
    let header = Header {
        version: buf[1],
        frame_type: buf[2],
        reserved: buf[3],
        payload_len: u32::from_le_bytes([buf[4], buf[5], buf[6], buf[7]]) as usize,
    };
    if header.payload_len > max_frame {
        return Extract::Oversized { header, declared: header.payload_len };
    }
    if buf.len() < HEADER_LEN + header.payload_len {
        return Extract::NeedMore;
    }
    Extract::Frame { header, payload: HEADER_LEN..HEADER_LEN + header.payload_len }
}

/// A decoded binary request (the codec's half of the shared
/// [`super::server`] request model).
#[derive(Debug, PartialEq)]
pub enum BinaryRequest {
    /// `model` is `None` for the default tenant (model_len 0).
    Infer { model: Option<String>, features: Vec<f32> },
    /// The admin JSON document, parsed.
    Admin(Value),
}

/// Wire-level error: (human message, stable machine code). Matches the
/// JSON-lines error vocabulary — see docs/PROTOCOL.md §Errors.
pub type FrameError = (String, &'static str);

fn bad(msg: impl Into<String>) -> FrameError {
    (msg.into(), "bad_request")
}

/// Decode a complete frame's request payload. Every failure here is a
/// survivable per-frame error: the frame boundary was sound, so the
/// caller replies with the coded error and keeps the connection.
pub fn decode_request(header: &Header, payload: &[u8]) -> Result<BinaryRequest, FrameError> {
    if header.version != VERSION {
        return Err(bad(format!(
            "unsupported frame version {} (expected {VERSION})",
            header.version
        )));
    }
    if header.reserved != 0 {
        return Err(bad(format!("reserved header byte must be 0, got {}", header.reserved)));
    }
    match header.frame_type {
        TYPE_REQ_INFER => decode_infer(payload),
        TYPE_REQ_ADMIN => {
            let text = std::str::from_utf8(payload)
                .map_err(|_| bad("admin frame payload is not valid utf-8"))?;
            let doc = json::parse(text).map_err(|e| bad(format!("bad json: {e}")))?;
            if doc.get("cmd").is_none() {
                return Err(bad("admin frame missing 'cmd' (inference uses frame type 0x01)"));
            }
            Ok(BinaryRequest::Admin(doc))
        }
        TYPE_REP_INFER | TYPE_REP_ADMIN | TYPE_REP_ERROR => Err(bad(format!(
            "frame type {:#04x} is a reply type, not a request",
            header.frame_type
        ))),
        other => Err(bad(format!("unknown frame type {other:#04x}"))),
    }
}

/// The truncated-payload torture target: every length field is checked
/// against the actual payload extent before any slice is taken.
fn decode_infer(payload: &[u8]) -> Result<BinaryRequest, FrameError> {
    let mut features = Vec::new();
    let model_range = decode_infer_into(payload, &mut features)?;
    let model = if model_range.is_empty() {
        None
    } else {
        // decode_infer_into already validated the bytes as UTF-8.
        Some(std::str::from_utf8(&payload[model_range]).expect("validated utf-8").to_string())
    };
    Ok(BinaryRequest::Infer { model, features })
}

/// [`decode_infer`] through a caller-owned feature vector — the
/// zero-allocation serving form. Decoded f32s land in `features`
/// (cleared first); the model name is returned as its validated UTF-8
/// byte range *within `payload`* (empty ⇒ the default tenant) so the
/// caller can borrow it without a `String`. Validation is identical to
/// [`decode_request`]'s infer arm — the torture suite covers it via the
/// delegating path.
pub fn decode_infer_into(
    payload: &[u8],
    features: &mut Vec<f32>,
) -> Result<std::ops::Range<usize>, FrameError> {
    features.clear();
    let Some((&model_len, rest)) = payload.split_first() else {
        return Err(bad("truncated inference frame: missing model length"));
    };
    let model_len = model_len as usize;
    if rest.len() < model_len {
        return Err(bad(format!(
            "truncated inference frame: model length {model_len} overruns payload"
        )));
    }
    let (model_bytes, rest) = rest.split_at(model_len);
    if std::str::from_utf8(model_bytes).is_err() {
        return Err(bad("model name is not valid utf-8"));
    }
    if rest.len() < 4 {
        return Err(bad("truncated inference frame: missing feature count"));
    }
    let (count_bytes, feat_bytes) = rest.split_at(4);
    let count = u32::from_le_bytes(count_bytes.try_into().unwrap()) as usize;
    if feat_bytes.len() != count * 4 {
        return Err(bad(format!(
            "inference frame declares {count} features but carries {} payload bytes",
            feat_bytes.len()
        )));
    }
    features.extend(
        feat_bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())),
    );
    Ok(1..1 + model_len)
}

fn push_header(out: &mut Vec<u8>, frame_type: u8, payload_len: usize) {
    out.push(MAGIC);
    out.push(VERSION);
    out.push(frame_type);
    out.push(0);
    out.extend_from_slice(&(payload_len as u32).to_le_bytes());
}

/// Encode an inference request frame.
pub fn encode_infer_request(model: Option<&str>, features: &[f32], out: &mut Vec<u8>) {
    let model = model.unwrap_or("");
    assert!(model.len() <= u8::MAX as usize, "model name longer than 255 bytes");
    let payload_len = 1 + model.len() + 4 + features.len() * 4;
    push_header(out, TYPE_REQ_INFER, payload_len);
    out.push(model.len() as u8);
    out.extend_from_slice(model.as_bytes());
    out.extend_from_slice(&(features.len() as u32).to_le_bytes());
    for f in features {
        out.extend_from_slice(&f.to_le_bytes());
    }
}

/// Encode an admin request frame carrying `doc` (must have a `"cmd"`).
pub fn encode_admin_request(doc: &Value, out: &mut Vec<u8>) {
    let text = json::to_string(doc);
    push_header(out, TYPE_REQ_ADMIN, text.len());
    out.extend_from_slice(text.as_bytes());
}

/// Encode an inference reply frame.
pub fn encode_infer_reply(id: u64, label: i32, latency_us: f64, model: &str, out: &mut Vec<u8>) {
    assert!(model.len() <= u8::MAX as usize, "model name longer than 255 bytes");
    let payload_len = 8 + 4 + 8 + 1 + model.len();
    push_header(out, TYPE_REP_INFER, payload_len);
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&label.to_le_bytes());
    out.extend_from_slice(&latency_us.to_le_bytes());
    out.push(model.len() as u8);
    out.extend_from_slice(model.as_bytes());
}

/// Encode an admin reply frame carrying a serialized JSON document.
pub fn encode_admin_reply(json_text: &str, out: &mut Vec<u8>) {
    push_header(out, TYPE_REP_ADMIN, json_text.len());
    out.extend_from_slice(json_text.as_bytes());
}

/// Encode an error reply frame.
pub fn encode_error_reply(message: &str, code: &str, out: &mut Vec<u8>) {
    assert!(code.len() <= u8::MAX as usize, "error code longer than 255 bytes");
    push_header(out, TYPE_REP_ERROR, 1 + code.len() + message.len());
    out.push(code.len() as u8);
    out.extend_from_slice(code.as_bytes());
    out.extend_from_slice(message.as_bytes());
}

/// Decode a *reply* frame into the JSON document the JSON-lines protocol
/// would have sent for the same request — the client-side half used by
/// the conformance differential suite and the load-generator bench.
pub fn decode_reply_to_json(header: &Header, payload: &[u8]) -> Result<Value, FrameError> {
    if header.version != VERSION {
        return Err(bad(format!("unsupported frame version {}", header.version)));
    }
    match header.frame_type {
        TYPE_REP_INFER => {
            if payload.len() < 8 + 4 + 8 + 1 {
                return Err(bad("truncated inference reply"));
            }
            let id = u64::from_le_bytes(payload[0..8].try_into().unwrap());
            let label = i32::from_le_bytes(payload[8..12].try_into().unwrap());
            let latency_us = f64::from_le_bytes(payload[12..20].try_into().unwrap());
            let model_len = payload[20] as usize;
            if payload.len() != 21 + model_len {
                return Err(bad("inference reply model length overruns payload"));
            }
            let model = std::str::from_utf8(&payload[21..])
                .map_err(|_| bad("inference reply model is not valid utf-8"))?;
            Ok(json::obj(vec![
                ("id", json::num(id as f64)),
                ("model", json::s(model)),
                ("label", json::num(label as f64)),
                ("latency_us", json::num(latency_us)),
            ]))
        }
        TYPE_REP_ADMIN => {
            let text = std::str::from_utf8(payload)
                .map_err(|_| bad("admin reply is not valid utf-8"))?;
            json::parse(text).map_err(|e| bad(format!("bad json in admin reply: {e}")))
        }
        TYPE_REP_ERROR => {
            let Some((&code_len, rest)) = payload.split_first() else {
                return Err(bad("truncated error reply"));
            };
            let code_len = code_len as usize;
            if rest.len() < code_len {
                return Err(bad("error reply code length overruns payload"));
            }
            let code = std::str::from_utf8(&rest[..code_len])
                .map_err(|_| bad("error code is not valid utf-8"))?;
            let message = std::str::from_utf8(&rest[code_len..])
                .map_err(|_| bad("error message is not valid utf-8"))?;
            Ok(json::obj(vec![("error", json::s(message)), ("code", json::s(code))]))
        }
        other => Err(bad(format!("frame type {other:#04x} is not a reply"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn extract_one(buf: &[u8]) -> (Header, Vec<u8>) {
        match try_extract(buf, DEFAULT_MAX_FRAME) {
            Extract::Frame { header, payload } => (header, buf[payload].to_vec()),
            other => panic!("expected a frame, got {other:?}"),
        }
    }

    #[test]
    fn infer_request_round_trip() {
        let mut buf = Vec::new();
        encode_infer_request(Some("page"), &[1.5, -2.0, 0.0], &mut buf);
        let (header, payload) = extract_one(&buf);
        assert_eq!(header.version, VERSION);
        assert_eq!(header.frame_type, TYPE_REQ_INFER);
        assert_eq!(buf.len(), HEADER_LEN + header.payload_len);
        let req = decode_request(&header, &payload).unwrap();
        assert_eq!(
            req,
            BinaryRequest::Infer {
                model: Some("page".into()),
                features: vec![1.5, -2.0, 0.0]
            }
        );
    }

    #[test]
    fn default_tenant_is_model_len_zero() {
        let mut buf = Vec::new();
        encode_infer_request(None, &[0.25], &mut buf);
        let (header, payload) = extract_one(&buf);
        let req = decode_request(&header, &payload).unwrap();
        assert_eq!(req, BinaryRequest::Infer { model: None, features: vec![0.25] });
    }

    #[test]
    fn admin_round_trip_requires_cmd() {
        let mut buf = Vec::new();
        encode_admin_request(&json::obj(vec![("cmd", json::s("stats"))]), &mut buf);
        let (header, payload) = extract_one(&buf);
        match decode_request(&header, &payload).unwrap() {
            BinaryRequest::Admin(doc) => {
                assert_eq!(doc.get("cmd").and_then(Value::as_str), Some("stats"))
            }
            other => panic!("{other:?}"),
        }
        // a JSON payload without "cmd" is a coded error, not an inference
        let header = Header {
            version: VERSION,
            frame_type: TYPE_REQ_ADMIN,
            reserved: 0,
            payload_len: 2,
        };
        let err = decode_request(&header, b"{}").unwrap_err();
        assert_eq!(err.1, "bad_request");
        assert!(err.0.contains("missing 'cmd'"), "{}", err.0);
    }

    #[test]
    fn incremental_extraction_needs_every_byte() {
        let mut buf = Vec::new();
        encode_infer_request(Some("m"), &[1.0, 2.0], &mut buf);
        for cut in 0..buf.len() {
            assert_eq!(
                try_extract(&buf[..cut], DEFAULT_MAX_FRAME),
                Extract::NeedMore,
                "cut at {cut}"
            );
        }
        assert!(matches!(
            try_extract(&buf, DEFAULT_MAX_FRAME),
            Extract::Frame { .. }
        ));
    }

    #[test]
    fn bad_magic_is_detected_immediately() {
        assert_eq!(try_extract(b"{\"a\": 1}", DEFAULT_MAX_FRAME), Extract::BadMagic(b'{'));
        assert_eq!(try_extract(&[0x00], DEFAULT_MAX_FRAME), Extract::BadMagic(0x00));
        assert_eq!(try_extract(&[], DEFAULT_MAX_FRAME), Extract::NeedMore);
    }

    #[test]
    fn oversized_length_reports_before_payload_arrives() {
        let mut buf = Vec::new();
        push_header(&mut buf, TYPE_REQ_INFER, 1 << 30);
        match try_extract(&buf, DEFAULT_MAX_FRAME) {
            Extract::Oversized { declared, .. } => assert_eq!(declared, 1 << 30),
            other => panic!("{other:?}"),
        }
        // exactly at the cap is allowed (NeedMore until the payload lands)
        let mut buf = Vec::new();
        push_header(&mut buf, TYPE_REQ_INFER, 64);
        assert_eq!(try_extract(&buf, 64), Extract::NeedMore);
        match try_extract(&buf, 63) {
            Extract::Oversized { declared, .. } => assert_eq!(declared, 64),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn truncated_payload_structures_are_coded_errors() {
        // feature count larger than the carried bytes
        let mut payload = vec![0u8]; // model_len 0
        payload.extend_from_slice(&10u32.to_le_bytes());
        payload.extend_from_slice(&1.0f32.to_le_bytes()); // only one float
        let header = Header {
            version: VERSION,
            frame_type: TYPE_REQ_INFER,
            reserved: 0,
            payload_len: payload.len(),
        };
        let err = decode_request(&header, &payload).unwrap_err();
        assert_eq!(err.1, "bad_request");
        assert!(err.0.contains("declares 10 features"), "{}", err.0);

        // model_len overruns the payload
        let header2 = Header { payload_len: 3, ..header };
        let err = decode_request(&header2, &[200, b'a', b'b']).unwrap_err();
        assert!(err.0.contains("model length 200 overruns"), "{}", err.0);

        // empty payload
        let header3 = Header { payload_len: 0, ..header };
        let err = decode_request(&header3, &[]).unwrap_err();
        assert!(err.0.contains("missing model length"), "{}", err.0);

        // missing feature count
        let header4 = Header { payload_len: 2, ..header };
        let err = decode_request(&header4, &[1, b'x']).unwrap_err();
        assert!(err.0.contains("missing feature count"), "{}", err.0);
    }

    #[test]
    fn header_violations_are_per_frame_errors() {
        let header = Header {
            version: 9,
            frame_type: TYPE_REQ_INFER,
            reserved: 0,
            payload_len: 0,
        };
        assert!(decode_request(&header, &[]).unwrap_err().0.contains("version 9"));
        let header = Header { version: VERSION, frame_type: 0x7F, reserved: 0, payload_len: 0 };
        assert!(decode_request(&header, &[]).unwrap_err().0.contains("unknown frame type"));
        let header =
            Header { version: VERSION, frame_type: TYPE_REQ_INFER, reserved: 3, payload_len: 0 };
        assert!(decode_request(&header, &[]).unwrap_err().0.contains("reserved"));
        let header =
            Header { version: VERSION, frame_type: TYPE_REP_INFER, reserved: 0, payload_len: 0 };
        assert!(decode_request(&header, &[]).unwrap_err().0.contains("reply type"));
    }

    #[test]
    fn reply_frames_decode_to_the_json_lines_documents() {
        let mut buf = Vec::new();
        encode_infer_reply(41, 3, 812.5, "page", &mut buf);
        let (header, payload) = extract_one(&buf);
        let doc = decode_reply_to_json(&header, &payload).unwrap();
        assert_eq!(doc.get("id").and_then(Value::as_f64), Some(41.0));
        assert_eq!(doc.get("model").and_then(Value::as_str), Some("page"));
        assert_eq!(doc.get("label").and_then(Value::as_f64), Some(3.0));
        assert_eq!(doc.get("latency_us").and_then(Value::as_f64), Some(812.5));

        let mut buf = Vec::new();
        encode_error_reply("unknown model 'x'", "unknown_model", &mut buf);
        let (header, payload) = extract_one(&buf);
        let doc = decode_reply_to_json(&header, &payload).unwrap();
        assert_eq!(doc.get("code").and_then(Value::as_str), Some("unknown_model"));
        assert_eq!(doc.get("error").and_then(Value::as_str), Some("unknown model 'x'"));

        let mut buf = Vec::new();
        encode_admin_reply(r#"{"model": "page", "requests": 4}"#, &mut buf);
        let (header, payload) = extract_one(&buf);
        let doc = decode_reply_to_json(&header, &payload).unwrap();
        assert_eq!(doc.get("requests").and_then(Value::as_f64), Some(4.0));
    }

    #[test]
    fn pipelined_frames_extract_in_order() {
        let mut buf = Vec::new();
        encode_infer_request(Some("a"), &[1.0], &mut buf);
        encode_infer_request(Some("b"), &[2.0], &mut buf);
        encode_admin_request(&json::obj(vec![("cmd", json::s("models"))]), &mut buf);
        let mut off = 0;
        let mut models = Vec::new();
        while off < buf.len() {
            match try_extract(&buf[off..], DEFAULT_MAX_FRAME) {
                Extract::Frame { header, payload } => {
                    let payload = &buf[off..][payload];
                    match decode_request(&header, payload).unwrap() {
                        BinaryRequest::Infer { model, .. } => {
                            models.push(model.unwrap_or_default())
                        }
                        BinaryRequest::Admin(_) => models.push("<admin>".into()),
                    }
                    off += HEADER_LEN + header.payload_len;
                }
                other => panic!("{other:?}"),
            }
        }
        assert_eq!(models, ["a", "b", "<admin>"]);
    }
}

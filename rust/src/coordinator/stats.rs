//! Serving metrics: counters + a log-bucketed latency histogram with
//! approximate quantiles (no external deps; bounded memory).

use std::time::Duration;

/// Log-bucketed histogram over [1µs, ~17min), 5% bucket growth.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum_us: f64,
    max_us: f64,
}

const BASE_US: f64 = 1.0;
const GROWTH: f64 = 1.05;
const NBUCKETS: usize = 420; // 1µs * 1.05^420 ≈ 8e8 µs

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self { buckets: vec![0; NBUCKETS], count: 0, sum_us: 0.0, max_us: 0.0 }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_secs_f64() * 1e6;
        let idx = if us <= BASE_US {
            0
        } else {
            ((us / BASE_US).ln() / GROWTH.ln()).floor() as usize
        }
        .min(NBUCKETS - 1);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us / self.count as f64
        }
    }

    /// Approximate quantile (upper edge of the containing bucket).
    pub fn quantile_us(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target.max(1) {
                return BASE_US * GROWTH.powi(i as i32 + 1);
            }
        }
        self.max_us
    }
}

/// Collected over the coordinator's lifetime.
#[derive(Debug, Clone, Default)]
pub struct StatsCollector {
    pub latency: LatencyHistogram,
    pub queue_wait: LatencyHistogram,
    pub requests: u64,
    pub responses: u64,
    pub rejected: u64,
    /// Admitted requests whose batch failed inference (answered with an
    /// engine-failure error, not a label) — without this, a tenant
    /// failing every batch would only show up as requests leaking past
    /// responses+rejected.
    pub failures: u64,
    pub batches: u64,
    pub batched_items: u64,
    /// Successful per-replica engine hot-swaps (a pool-wide reload of R
    /// replicas increments this R times as each worker adopts it).
    pub reloads: u64,
    /// Deepest the pending queue has ever been (recorded at admission,
    /// under the queue lock) — the high-water mark that tells an
    /// operator how close the tenant came to backpressure.
    pub queue_depth_hwm: u64,
    /// The tenant's configured batch ceiling, recorded at pool start so
    /// the snapshot can report fill ratio without reaching into config.
    pub max_batch: usize,
    pub started: Option<std::time::Instant>,
}

/// Point-in-time snapshot for reporting.
#[derive(Debug, Clone)]
pub struct StatsSnapshot {
    pub requests: u64,
    pub responses: u64,
    pub rejected: u64,
    pub failures: u64,
    pub batches: u64,
    pub reloads: u64,
    pub mean_batch_size: f64,
    /// `mean_batch_size / max_batch` — how full the configured batch
    /// window runs (0.0 when no batch ceiling was recorded).
    pub batch_fill_ratio: f64,
    pub queue_depth_hwm: u64,
    pub latency_p50_us: f64,
    pub latency_p95_us: f64,
    pub latency_p99_us: f64,
    pub latency_mean_us: f64,
    pub queue_p99_us: f64,
    pub throughput_rps: f64,
}

impl StatsCollector {
    pub fn snapshot(&self) -> StatsSnapshot {
        let elapsed = self.started.map_or(0.0, |t| t.elapsed().as_secs_f64());
        let mean_batch_size = if self.batches == 0 {
            0.0
        } else {
            self.batched_items as f64 / self.batches as f64
        };
        StatsSnapshot {
            requests: self.requests,
            responses: self.responses,
            rejected: self.rejected,
            failures: self.failures,
            batches: self.batches,
            reloads: self.reloads,
            mean_batch_size,
            batch_fill_ratio: if self.max_batch == 0 {
                0.0
            } else {
                mean_batch_size / self.max_batch as f64
            },
            queue_depth_hwm: self.queue_depth_hwm,
            latency_p50_us: self.latency.quantile_us(0.50),
            latency_p95_us: self.latency.quantile_us(0.95),
            latency_p99_us: self.latency.quantile_us(0.99),
            latency_mean_us: self.latency.mean_us(),
            queue_p99_us: self.queue_wait.quantile_us(0.99),
            throughput_rps: if elapsed > 0.0 { self.responses as f64 / elapsed } else { 0.0 },
        }
    }
}

impl StatsSnapshot {
    pub fn format_report(&self) -> String {
        format!(
            "requests={} responses={} rejected={} batches={} mean_batch={:.1} \
             fill={:.2} queue_hwm={}\n\
             latency: mean {:.1}µs p50 {:.1}µs p95 {:.1}µs p99 {:.1}µs | queue p99 {:.1}µs\n\
             throughput: {:.1} req/s",
            self.requests,
            self.responses,
            self.rejected,
            self.batches,
            self.mean_batch_size,
            self.batch_fill_ratio,
            self.queue_depth_hwm,
            self.latency_mean_us,
            self.latency_p50_us,
            self.latency_p95_us,
            self.latency_p99_us,
            self.queue_p99_us,
            self.throughput_rps
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = LatencyHistogram::default();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_us(0.5);
        let p95 = h.quantile_us(0.95);
        let p99 = h.quantile_us(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        // ~5% bucket resolution
        assert!((p50 - 500.0).abs() / 500.0 < 0.15, "p50 {p50}");
        assert!((p99 - 990.0).abs() / 990.0 < 0.15, "p99 {p99}");
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.quantile_us(0.5), 0.0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn snapshot_math() {
        let mut s = StatsCollector::default();
        s.batches = 4;
        s.batched_items = 10;
        s.max_batch = 5;
        s.queue_depth_hwm = 7;
        let snap = s.snapshot();
        assert!((snap.mean_batch_size - 2.5).abs() < 1e-12);
        assert!((snap.batch_fill_ratio - 0.5).abs() < 1e-12);
        assert_eq!(snap.queue_depth_hwm, 7);
        assert!(snap.format_report().contains("mean_batch=2.5"));
        assert!(snap.format_report().contains("queue_hwm=7"));
    }

    #[test]
    fn fill_ratio_without_recorded_ceiling_is_zero() {
        let snap = StatsCollector::default().snapshot();
        assert_eq!(snap.batch_fill_ratio, 0.0);
        assert_eq!(snap.queue_depth_hwm, 0);
    }
}

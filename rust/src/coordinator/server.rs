//! TCP JSON-lines front-end over the coordinator.
//!
//! Wire protocol (one JSON document per line):
//!   -> {"features": [f, f, ...]}
//!   <- {"id": N, "label": L, "latency_us": T}
//!   <- {"error": "..."}            (bad request / backpressure)
//! A line `{"cmd": "stats"}` returns the metrics snapshot. Connections are
//! handled on per-client threads; the coordinator itself serializes work
//! through the dynamic batcher.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::util::json::{self, Value};

use super::batcher::Coordinator;

/// A running TCP server.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve `coordinator`.
    pub fn start(addr: &str, coordinator: Arc<Coordinator>) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept_thread = std::thread::Builder::new()
            .name("loghd-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Acquire) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let coord = Arc::clone(&coordinator);
                            std::thread::spawn(move || {
                                let _ = handle_client(stream, coord);
                            });
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(10));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        crate::log_info!("serving on {local}");
        Ok(Self { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn error_line(msg: &str) -> String {
    json::to_string(&json::obj(vec![("error", json::s(msg))]))
}

fn handle_client(stream: TcpStream, coord: Arc<Coordinator>) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match handle_line(&line, &coord) {
            Ok(v) => v,
            Err(msg) => error_line(&msg),
        };
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
    }
    crate::log_debug!("client {peer:?} disconnected");
    Ok(())
}

fn handle_line(line: &str, coord: &Coordinator) -> Result<String, String> {
    let v = json::parse(line).map_err(|e| format!("bad json: {e}"))?;
    if v.get("cmd").and_then(Value::as_str) == Some("stats") {
        let s = coord.stats();
        return Ok(json::to_string(&json::obj(vec![
            ("requests", json::num(s.requests as f64)),
            ("responses", json::num(s.responses as f64)),
            ("rejected", json::num(s.rejected as f64)),
            ("mean_batch", json::num(s.mean_batch_size)),
            ("latency_p50_us", json::num(s.latency_p50_us)),
            ("latency_p99_us", json::num(s.latency_p99_us)),
            ("throughput_rps", json::num(s.throughput_rps)),
        ])));
    }
    let feats = v
        .get("features")
        .and_then(Value::as_array)
        .ok_or_else(|| "missing 'features' array".to_string())?;
    let features: Vec<f32> = feats
        .iter()
        .map(|f| f.as_f64().map(|x| x as f32).ok_or_else(|| "non-numeric feature".to_string()))
        .collect::<Result<_, _>>()?;
    let resp = coord.submit_blocking(features).map_err(|e| e.to_string())?;
    Ok(json::to_string(&json::obj(vec![
        ("id", json::num(resp.id as f64)),
        ("label", json::num(resp.label as f64)),
        ("latency_us", json::num(resp.latency.as_secs_f64() * 1e6)),
    ])))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::Engine;
    use crate::tensor::Matrix;

    struct Echo;
    impl Engine for Echo {
        fn name(&self) -> String {
            "echo".into()
        }
        fn features(&self) -> usize {
            2
        }
        fn infer(&mut self, x: &Matrix) -> anyhow::Result<Vec<i32>> {
            Ok((0..x.rows()).map(|i| x.at(i, 0) as i32).collect())
        }
    }

    #[test]
    fn round_trip_over_tcp() {
        let coord = Arc::new(Coordinator::start(
            2,
            BatcherConfig::default(),
            Box::new(|| Ok(Box::new(Echo))),
        ));
        let mut server = Server::start("127.0.0.1:0", Arc::clone(&coord)).unwrap();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        stream.write_all(b"{\"features\": [7, 0]}\n{\"cmd\": \"stats\"}\n").unwrap();
        stream.shutdown(std::net::Shutdown::Write).unwrap();
        let reader = BufReader::new(stream);
        let lines: Vec<String> = reader.lines().map(|l| l.unwrap()).collect();
        assert_eq!(lines.len(), 2);
        let first = json::parse(&lines[0]).unwrap();
        assert_eq!(first.get("label").and_then(Value::as_f64), Some(7.0));
        let stats = json::parse(&lines[1]).unwrap();
        assert_eq!(stats.get("responses").and_then(Value::as_f64), Some(1.0));
        server.shutdown();
    }

    #[test]
    fn bad_requests_get_errors() {
        let coord = Arc::new(Coordinator::start(
            2,
            BatcherConfig::default(),
            Box::new(|| Ok(Box::new(Echo))),
        ));
        let mut server = Server::start("127.0.0.1:0", Arc::clone(&coord)).unwrap();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        stream
            .write_all(b"not json\n{\"features\": [1]}\n{\"nope\": 1}\n")
            .unwrap();
        stream.shutdown(std::net::Shutdown::Write).unwrap();
        let reader = BufReader::new(stream);
        let lines: Vec<String> = reader.lines().map(|l| l.unwrap()).collect();
        assert_eq!(lines.len(), 3);
        for line in lines {
            assert!(json::parse(&line).unwrap().get("error").is_some(), "{line}");
        }
        server.shutdown();
    }
}

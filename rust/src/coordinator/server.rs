//! TCP JSON-lines front-end over the model registry.
//!
//! One JSON document per line; the full protocol (schemas, admin verbs,
//! error codes, backpressure semantics) is specified in
//! `docs/PROTOCOL.md` at the repo root — that file is the source of
//! truth for client authors. In short:
//!
//!   -> {"features": [f, ...], "model": "name"?}
//!   <- {"id": N, "model": "name", "label": L, "latency_us": T}
//!   -> {"cmd": "stats", "model": "name"?}     per-tenant metrics snapshot
//!   -> {"cmd": "models"}                      tenant list + per-model stats
//!   -> {"cmd": "reload", "model"?, "path"?, "bits"?}   hot-swap a tenant
//!   <- {"error": "...", "code": "..."}        bad request / routing /
//!                                             per-tenant backpressure
//!
//! Every error is a *reply*, not a disconnect: the connection survives
//! malformed lines, unknown tenants, width mismatches, and queue-full
//! rejections. Connections are handled on per-client threads; each
//! tenant's coordinator serializes work through its own dynamic batcher.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::util::json::{self, Value};

use super::registry::{ModelRegistry, TenantInfo};
use super::stats::StatsSnapshot;

/// A running TCP server.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve `registry`.
    pub fn start(addr: &str, registry: Arc<ModelRegistry>) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept_thread = std::thread::Builder::new()
            .name("loghd-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Acquire) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let reg = Arc::clone(&registry);
                            std::thread::spawn(move || {
                                let _ = handle_client(stream, reg);
                            });
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(10));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        crate::log_info!("serving on {local}");
        Ok(Self { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn error_line(msg: &str, code: &str) -> String {
    json::to_string(&json::obj(vec![("error", json::s(msg)), ("code", json::s(code))]))
}

fn handle_client(stream: TcpStream, registry: Arc<ModelRegistry>) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match handle_line(&line, &registry) {
            Ok(v) => v,
            Err((msg, code)) => error_line(&msg, code),
        };
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
    }
    crate::log_debug!("client {peer:?} disconnected");
    Ok(())
}

fn stats_fields(s: &StatsSnapshot) -> Vec<(&'static str, Value)> {
    vec![
        ("requests", json::num(s.requests as f64)),
        ("responses", json::num(s.responses as f64)),
        ("rejected", json::num(s.rejected as f64)),
        ("failures", json::num(s.failures as f64)),
        ("reloads", json::num(s.reloads as f64)),
        ("mean_batch", json::num(s.mean_batch_size)),
        ("latency_p50_us", json::num(s.latency_p50_us)),
        ("latency_p99_us", json::num(s.latency_p99_us)),
        ("throughput_rps", json::num(s.throughput_rps)),
    ]
}

fn tenant_json(info: &TenantInfo) -> Value {
    let mut fields = vec![
        ("model", json::s(info.name.clone())),
        ("kind", json::s(info.kind.clone())),
        ("precision", json::s(info.precision)),
        ("replicas", json::num(info.replicas as f64)),
        ("live_replicas", json::num(info.live_replicas as f64)),
        ("features", json::num(info.features as f64)),
        ("default", Value::Bool(info.is_default)),
    ];
    if let Some(path) = &info.path {
        fields.push(("path", json::s(path.display().to_string())));
    }
    fields.extend(stats_fields(&info.stats));
    json::obj(fields)
}

type WireError = (String, &'static str);

/// A field that must be a string when present — a non-string value is a
/// protocol error, never silently treated as absent (a numeric "model"
/// must not route to the default tenant).
fn optional_str<'a>(v: &'a Value, key: &str) -> Result<Option<&'a str>, WireError> {
    match v.get(key) {
        None => Ok(None),
        Some(Value::String(s)) => Ok(Some(s.as_str())),
        Some(_) => Err((format!("'{key}' must be a string"), "bad_request")),
    }
}

fn handle_line(line: &str, registry: &ModelRegistry) -> Result<String, WireError> {
    let v = json::parse(line).map_err(|e| (format!("bad json: {e}"), "bad_request"))?;
    let model = optional_str(&v, "model")?;
    match optional_str(&v, "cmd")? {
        Some("stats") => {
            let (name, s) =
                registry.stats(model).map_err(|e| (e.to_string(), e.code()))?;
            let mut fields = vec![("model", json::s(name))];
            fields.extend(stats_fields(&s));
            Ok(json::to_string(&json::obj(fields)))
        }
        Some("models") => {
            let models: Vec<Value> =
                registry.describe().iter().map(tenant_json).collect();
            Ok(json::to_string(&json::obj(vec![
                ("default", json::s(registry.default_model())),
                ("models", json::arr(models)),
            ])))
        }
        Some("reload") => {
            let path = optional_str(&v, "path")?.map(std::path::Path::new);
            let bits = match v.get("bits") {
                None => None,
                Some(b) => match b.as_f64() {
                    Some(x) if x.fract() == 0.0 && x >= 0.0 => Some(x as u32),
                    _ => {
                        return Err(("'bits' must be a non-negative integer".into(), "bad_request"))
                    }
                },
            };
            let info = registry
                .reload(model, path, bits)
                .map_err(|e| (e.to_string(), e.code()))?;
            Ok(json::to_string(&json::obj(vec![
                ("reloaded", json::s(info.name)),
                ("kind", json::s(info.kind)),
                ("precision", json::s(info.precision)),
                ("replicas", json::num(info.replicas as f64)),
            ])))
        }
        Some(other) => Err((format!("unknown cmd '{other}'"), "bad_request")),
        None => {
            let feats = v
                .get("features")
                .and_then(Value::as_array)
                .ok_or_else(|| ("missing 'features' array".to_string(), "bad_request"))?;
            let features: Vec<f32> = feats
                .iter()
                .map(|f| {
                    f.as_f64()
                        .map(|x| x as f32)
                        .ok_or_else(|| ("non-numeric feature".to_string(), "bad_request"))
                })
                .collect::<Result<_, _>>()?;
            let (name, resp) = registry
                .submit_blocking(model, features)
                .map_err(|e| (e.to_string(), e.code()))?;
            Ok(json::to_string(&json::obj(vec![
                ("id", json::num(resp.id as f64)),
                ("model", json::s(name)),
                ("label", json::num(resp.label as f64)),
                ("latency_us", json::num(resp.latency.as_secs_f64() * 1e6)),
            ])))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::Engine;
    use crate::tensor::Matrix;

    struct Echo;
    impl Engine for Echo {
        fn name(&self) -> String {
            "echo".into()
        }
        fn features(&self) -> usize {
            2
        }
        fn infer(&mut self, x: &Matrix) -> anyhow::Result<Vec<i32>> {
            Ok((0..x.rows()).map(|i| x.at(i, 0) as i32).collect())
        }
    }

    fn echo_registry() -> Arc<ModelRegistry> {
        Arc::new(ModelRegistry::single(
            "echo",
            "demo",
            2,
            &BatcherConfig::default(),
            vec![Box::new(|| Ok(Box::new(Echo) as Box<dyn Engine>))],
        ))
    }

    #[test]
    fn round_trip_over_tcp() {
        let registry = echo_registry();
        let mut server = Server::start("127.0.0.1:0", Arc::clone(&registry)).unwrap();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        stream
            .write_all(
                b"{\"features\": [7, 0]}\n{\"model\": \"echo\", \"features\": [3, 0]}\n{\"cmd\": \"stats\"}\n{\"cmd\": \"models\"}\n",
            )
            .unwrap();
        stream.shutdown(std::net::Shutdown::Write).unwrap();
        let reader = BufReader::new(stream);
        let lines: Vec<String> = reader.lines().map(|l| l.unwrap()).collect();
        assert_eq!(lines.len(), 4);
        let first = json::parse(&lines[0]).unwrap();
        assert_eq!(first.get("label").and_then(Value::as_f64), Some(7.0));
        assert_eq!(first.get("model").and_then(Value::as_str), Some("echo"));
        let second = json::parse(&lines[1]).unwrap();
        assert_eq!(second.get("label").and_then(Value::as_f64), Some(3.0));
        let stats = json::parse(&lines[2]).unwrap();
        assert_eq!(stats.get("responses").and_then(Value::as_f64), Some(2.0));
        assert_eq!(stats.get("model").and_then(Value::as_str), Some("echo"));
        let models = json::parse(&lines[3]).unwrap();
        assert_eq!(models.get("default").and_then(Value::as_str), Some("echo"));
        let list = models.get("models").and_then(Value::as_array).unwrap();
        assert_eq!(list.len(), 1);
        assert_eq!(list[0].get("replicas").and_then(Value::as_f64), Some(1.0));
        server.shutdown();
    }

    #[test]
    fn bad_requests_get_coded_errors() {
        let registry = echo_registry();
        let mut server = Server::start("127.0.0.1:0", Arc::clone(&registry)).unwrap();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        stream
            .write_all(
                b"not json\n{\"features\": [1]}\n{\"nope\": 1}\n{\"model\": \"ghost\", \"features\": [1, 2]}\n{\"cmd\": \"frobnicate\"}\n{\"model\": 5, \"features\": [1, 2]}\n{\"cmd\": 7, \"features\": [1, 2]}\n{\"cmd\": \"reload\", \"bits\": \"8\"}\n{\"features\": [4, 0]}\n",
            )
            .unwrap();
        stream.shutdown(std::net::Shutdown::Write).unwrap();
        let reader = BufReader::new(stream);
        let lines: Vec<String> = reader.lines().map(|l| l.unwrap()).collect();
        assert_eq!(lines.len(), 9);
        let code = |i: usize| {
            json::parse(&lines[i])
                .unwrap()
                .get("code")
                .and_then(Value::as_str)
                .map(String::from)
        };
        assert_eq!(code(0).as_deref(), Some("bad_request"));
        assert_eq!(code(1).as_deref(), Some("bad_width"));
        assert_eq!(code(2).as_deref(), Some("bad_request"));
        assert_eq!(code(3).as_deref(), Some("unknown_model"));
        assert_eq!(code(4).as_deref(), Some("bad_request"));
        // Type-strict fields: a numeric "model" or "cmd" must NOT silently
        // route to the default tenant, and string "bits" must not silently
        // reload at the old precision.
        assert_eq!(code(5).as_deref(), Some("bad_request"));
        assert_eq!(code(6).as_deref(), Some("bad_request"));
        assert_eq!(code(7).as_deref(), Some("bad_request"));
        // The connection survived all eight errors: the final good request
        // is answered normally.
        let last = json::parse(&lines[8]).unwrap();
        assert!(last.get("error").is_none(), "{}", lines[8]);
        assert_eq!(last.get("label").and_then(Value::as_f64), Some(4.0));
        server.shutdown();
    }
}

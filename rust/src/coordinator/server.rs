//! TCP front door over the model registry.
//!
//! Two wire protocols share one listener, distinguished by the first
//! byte a client sends (`0xB7` opens the length-prefixed binary
//! protocol; anything else is JSON-lines). The full specification —
//! schemas, admin verbs, error codes, framing, backpressure semantics —
//! lives in `docs/PROTOCOL.md` at the repo root; that file is the
//! source of truth for client authors. In short (JSON-lines form):
//!
//!   -> {"features": [f, ...], "model": "name"?}
//!   <- {"id": N, "model": "name", "label": L, "latency_us": T}
//!   -> {"cmd": "stats", "model": "name"?}     per-tenant metrics snapshot
//!   -> {"cmd": "models"}                      tenant list + per-model stats
//!   -> {"cmd": "reload", "model"?, "path"?, "bits"?}   hot-swap a tenant
//!   <- {"error": "...", "code": "..."}        bad request / routing /
//!                                             per-tenant backpressure
//!
//! Every recoverable error is a *reply*, not a disconnect: the
//! connection survives malformed lines, unknown tenants, width
//! mismatches, queue-full rejections, and oversized frames.
//!
//! [`Server`] is a thin facade. On unix it runs the nonblocking
//! event-loop reactor ([`super::eventloop`]): a small fixed thread pool,
//! zero wakeups while idle, bounded write buffering, and a graceful
//! drain on shutdown that answers every admitted request before the
//! last thread is joined. On other targets a blocking
//! thread-per-connection fallback drives the same
//! [`super::conn::Conn`] protocol state machine, so wire behaviour is
//! identical everywhere.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use super::frame;
use super::registry::ModelRegistry;

/// Tunables for the front door. `Default` is right for production use;
/// tests shrink the limits to force edge cases (tiny `write_hwm` for
/// backpressure, tiny `max_frame` for oversize rejection).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Reactor threads multiplexing connections (unix only; min 1).
    pub reactors: usize,
    /// Hard cap on one frame's payload / one JSON line, in bytes.
    pub max_frame: usize,
    /// Per-connection write high-water mark: past this many buffered
    /// reply bytes the connection stops being read until the peer
    /// drains (write-interest-driven backpressure).
    pub write_hwm: usize,
    /// Upper bound on the shutdown drain: connections still owing
    /// replies after this long are closed anyway.
    pub drain_deadline: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            reactors: 2,
            max_frame: frame::DEFAULT_MAX_FRAME,
            write_hwm: 256 * 1024,
            drain_deadline: Duration::from_secs(5),
        }
    }
}

/// Counters exposed by the running server, for tests and monitoring.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServerStats {
    /// Times a reactor woke from its poll sleep. An idle server with no
    /// clients holds at zero — the regression guard against busy-wait
    /// accept loops.
    pub wakeups: u64,
    /// Connections accepted over the server's lifetime.
    pub accepted: u64,
    /// Connections currently open.
    pub open: u64,
}

enum Imp {
    #[cfg(unix)]
    Reactor(super::eventloop::EventLoop),
    #[cfg(not(unix))]
    Threaded(threaded::ThreadedServer),
}

/// A running TCP server (see module docs for the two backends).
pub struct Server {
    pub addr: std::net::SocketAddr,
    imp: Imp,
}

impl Server {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve `registry` with
    /// default tunables.
    pub fn start(addr: &str, registry: Arc<ModelRegistry>) -> Result<Self> {
        Self::start_with(addr, registry, ServerConfig::default())
    }

    /// Bind `addr` and serve `registry` with explicit tunables.
    pub fn start_with(addr: &str, registry: Arc<ModelRegistry>, cfg: ServerConfig) -> Result<Self> {
        #[cfg(unix)]
        {
            let ev = super::eventloop::EventLoop::start(addr, registry, cfg)?;
            let local = ev.addr;
            crate::log_info!("serving on {local}");
            Ok(Self { addr: local, imp: Imp::Reactor(ev) })
        }
        #[cfg(not(unix))]
        {
            let srv = threaded::ThreadedServer::start(addr, registry, cfg)?;
            let local = srv.addr;
            crate::log_info!("serving on {local}");
            Ok(Self { addr: local, imp: Imp::Threaded(srv) })
        }
    }

    /// Stop accepting, drain owed replies (bounded by
    /// [`ServerConfig::drain_deadline`]), and join every server thread.
    /// Idempotent.
    pub fn shutdown(&mut self) {
        match &mut self.imp {
            #[cfg(unix)]
            Imp::Reactor(ev) => ev.shutdown(),
            #[cfg(not(unix))]
            Imp::Threaded(srv) => srv.shutdown(),
        }
    }

    pub fn stats(&self) -> ServerStats {
        match &self.imp {
            #[cfg(unix)]
            Imp::Reactor(ev) => ev.stats(),
            #[cfg(not(unix))]
            Imp::Threaded(srv) => srv.stats(),
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

mod threaded {
    //! Blocking thread-per-connection fallback for targets without a
    //! poller backend. Drives the same [`Conn`] state machine as the
    //! reactor, so the wire protocol (both framings, reply ordering,
    //! error survival) is byte-identical; only the concurrency model
    //! differs. Client threads are tracked, reaped as they finish, and
    //! joined on shutdown.
    //!
    //! Compiled on every target (only [`super::Imp`] selects a backend)
    //! so the unix test suite can regression-test it directly.
    #![cfg_attr(unix, allow(dead_code))]

    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Arc, Mutex};
    use std::thread::JoinHandle;
    use std::time::Duration;

    use anyhow::{Context, Result};

    use super::super::conn::{self, Conn};
    use super::super::registry::ModelRegistry;
    use super::{ServerConfig, ServerStats};

    pub struct ThreadedServer {
        pub addr: std::net::SocketAddr,
        stop: Arc<AtomicBool>,
        accept_thread: Option<JoinHandle<()>>,
        clients: Arc<Mutex<Vec<JoinHandle<()>>>>,
    }

    impl ThreadedServer {
        pub fn start(addr: &str, registry: Arc<ModelRegistry>, cfg: ServerConfig) -> Result<Self> {
            let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
            let local = listener.local_addr()?;
            listener.set_nonblocking(true)?;
            let stop = Arc::new(AtomicBool::new(false));
            let clients: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
            let stop2 = Arc::clone(&stop);
            let clients2 = Arc::clone(&clients);
            let accept_thread = std::thread::Builder::new()
                .name("loghd-accept".into())
                .spawn(move || {
                    while !stop2.load(Ordering::Acquire) {
                        match listener.accept() {
                            Ok((stream, _)) => {
                                let reg = Arc::clone(&registry);
                                let stop3 = Arc::clone(&stop2);
                                let cfg = cfg.clone();
                                let h = std::thread::spawn(move || {
                                    let _ = serve_client(stream, reg, cfg, stop3);
                                });
                                let mut clients = clients2.lock().unwrap();
                                clients.push(h);
                                // Reap finished client threads on every
                                // accept: the old grow-forever Vec leaked
                                // one JoinHandle per connection for the
                                // process lifetime under churn.
                                let mut i = 0;
                                while i < clients.len() {
                                    if clients[i].is_finished() {
                                        let done = clients.swap_remove(i);
                                        let _ = done.join();
                                    } else {
                                        i += 1;
                                    }
                                }
                            }
                            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                std::thread::sleep(Duration::from_millis(10));
                            }
                            Err(_) => break,
                        }
                    }
                })?;
            Ok(Self { addr: local, stop, accept_thread: Some(accept_thread), clients })
        }

        pub fn shutdown(&mut self) {
            self.stop.store(true, Ordering::Release);
            if let Some(h) = self.accept_thread.take() {
                let _ = h.join();
            }
            let drained: Vec<JoinHandle<()>> =
                std::mem::take(&mut *self.clients.lock().unwrap());
            for h in drained {
                let _ = h.join();
            }
        }

        pub fn stats(&self) -> ServerStats {
            ServerStats::default()
        }

        /// Client `JoinHandle`s currently tracked (live + not yet
        /// reaped) — observability hook for the churn regression test.
        pub fn tracked_clients(&self) -> usize {
            self.clients.lock().unwrap().len()
        }
    }

    impl Drop for ThreadedServer {
        fn drop(&mut self) {
            self.shutdown();
        }
    }

    fn serve_client(
        mut stream: TcpStream,
        registry: Arc<ModelRegistry>,
        cfg: ServerConfig,
        stop: Arc<AtomicBool>,
    ) -> std::io::Result<()> {
        // A finite read timeout lets the thread notice shutdown.
        stream.set_read_timeout(Some(Duration::from_millis(50)))?;
        let mut conn = Conn::new(cfg.max_frame);
        let mut chunk = [0u8; 16 * 1024];
        loop {
            let mut submits = Vec::new();
            match stream.read(&mut chunk) {
                Ok(0) => conn.on_eof(&registry, &mut submits),
                Ok(n) => {
                    conn.ingest(&chunk[..n]);
                    conn.process(&registry, usize::MAX, &mut submits);
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut
                        || e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
            for s in submits {
                let bytes = match registry.submit_blocking(s.model.as_deref(), s.features) {
                    Ok((name, resp)) => {
                        conn::encode_infer_reply_bytes(conn.protocol(), &name, &resp)
                    }
                    Err(e) => conn::encode_error_bytes(conn.protocol(), &e.to_string(), e.code()),
                };
                conn.complete(&registry, s.seq, bytes);
            }
            while conn.wants_write() {
                let n = stream.write(conn.writable())?;
                if n == 0 {
                    return Ok(());
                }
                conn.advance_write(n);
            }
            if conn.done() || (conn.at_eof() && conn.quiesced()) {
                return Ok(());
            }
            if stop.load(Ordering::Acquire) {
                return Ok(());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::Engine;
    use crate::tensor::Matrix;
    use crate::util::json::{self, Value};
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    struct Echo;
    impl Engine for Echo {
        fn name(&self) -> String {
            "echo".into()
        }
        fn features(&self) -> usize {
            2
        }
        fn infer(&mut self, x: &Matrix) -> anyhow::Result<Vec<i32>> {
            Ok((0..x.rows()).map(|i| x.at(i, 0) as i32).collect())
        }
    }

    fn echo_registry() -> Arc<ModelRegistry> {
        Arc::new(ModelRegistry::single(
            "echo",
            "demo",
            2,
            &BatcherConfig::default(),
            vec![Box::new(|| Ok(Box::new(Echo) as Box<dyn Engine>))],
        ))
    }

    #[test]
    fn round_trip_over_tcp() {
        let registry = echo_registry();
        let mut server = Server::start("127.0.0.1:0", Arc::clone(&registry)).unwrap();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        stream
            .write_all(
                b"{\"features\": [7, 0]}\n{\"model\": \"echo\", \"features\": [3, 0]}\n{\"cmd\": \"stats\"}\n{\"cmd\": \"models\"}\n",
            )
            .unwrap();
        stream.shutdown(std::net::Shutdown::Write).unwrap();
        let reader = BufReader::new(stream);
        let lines: Vec<String> = reader.lines().map(|l| l.unwrap()).collect();
        assert_eq!(lines.len(), 4);
        let first = json::parse(&lines[0]).unwrap();
        assert_eq!(first.get("label").and_then(Value::as_f64), Some(7.0));
        assert_eq!(first.get("model").and_then(Value::as_str), Some("echo"));
        let second = json::parse(&lines[1]).unwrap();
        assert_eq!(second.get("label").and_then(Value::as_f64), Some(3.0));
        let stats = json::parse(&lines[2]).unwrap();
        assert_eq!(stats.get("responses").and_then(Value::as_f64), Some(2.0));
        assert_eq!(stats.get("model").and_then(Value::as_str), Some("echo"));
        let models = json::parse(&lines[3]).unwrap();
        assert_eq!(models.get("default").and_then(Value::as_str), Some("echo"));
        let list = models.get("models").and_then(Value::as_array).unwrap();
        assert_eq!(list.len(), 1);
        assert_eq!(list[0].get("replicas").and_then(Value::as_f64), Some(1.0));
        server.shutdown();
    }

    #[test]
    fn bad_requests_get_coded_errors() {
        let registry = echo_registry();
        let mut server = Server::start("127.0.0.1:0", Arc::clone(&registry)).unwrap();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        stream
            .write_all(
                b"not json\n{\"features\": [1]}\n{\"nope\": 1}\n{\"model\": \"ghost\", \"features\": [1, 2]}\n{\"cmd\": \"frobnicate\"}\n{\"model\": 5, \"features\": [1, 2]}\n{\"cmd\": 7, \"features\": [1, 2]}\n{\"cmd\": \"reload\", \"bits\": \"8\"}\n{\"features\": [4, 0]}\n",
            )
            .unwrap();
        stream.shutdown(std::net::Shutdown::Write).unwrap();
        let reader = BufReader::new(stream);
        let lines: Vec<String> = reader.lines().map(|l| l.unwrap()).collect();
        assert_eq!(lines.len(), 9);
        let code = |i: usize| {
            json::parse(&lines[i])
                .unwrap()
                .get("code")
                .and_then(Value::as_str)
                .map(String::from)
        };
        assert_eq!(code(0).as_deref(), Some("bad_request"));
        assert_eq!(code(1).as_deref(), Some("bad_width"));
        assert_eq!(code(2).as_deref(), Some("bad_request"));
        assert_eq!(code(3).as_deref(), Some("unknown_model"));
        assert_eq!(code(4).as_deref(), Some("bad_request"));
        // Type-strict fields: a numeric "model" or "cmd" must NOT silently
        // route to the default tenant, and string "bits" must not silently
        // reload at the old precision.
        assert_eq!(code(5).as_deref(), Some("bad_request"));
        assert_eq!(code(6).as_deref(), Some("bad_request"));
        assert_eq!(code(7).as_deref(), Some("bad_request"));
        // The connection survived all eight errors: the final good request
        // is answered normally.
        let last = json::parse(&lines[8]).unwrap();
        assert!(last.get("error").is_none(), "{}", lines[8]);
        assert_eq!(last.get("label").and_then(Value::as_f64), Some(4.0));
        server.shutdown();
    }

    /// Regression for the fallback server's handle leak: pre-fix, every
    /// client connection pushed a `JoinHandle` into a Vec that was only
    /// reaped at shutdown, so connection churn grew it forever. The
    /// accept loop now sweeps finished handles on each iteration; after
    /// a burst of short-lived connections the tracked count must be a
    /// small residue, not one handle per connection. Drives
    /// `ThreadedServer` directly (on unix the `Server` facade runs the
    /// event loop instead).
    #[test]
    fn threaded_fallback_reaps_finished_clients_under_churn() {
        let registry = echo_registry();
        let mut server =
            threaded::ThreadedServer::start("127.0.0.1:0", registry, ServerConfig::default())
                .unwrap();
        const CHURN: usize = 24;
        for _ in 0..CHURN {
            let mut stream = TcpStream::connect(server.addr).unwrap();
            stream.write_all(b"{\"features\": [5, 0]}\n").unwrap();
            let mut reader = BufReader::new(&stream);
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let doc = json::parse(&line).unwrap();
            assert_eq!(doc.get("label").and_then(Value::as_f64), Some(5.0));
            drop(reader);
            drop(stream);
            // Give the client thread its EOF turn (50ms read timeout
            // granularity) so later accept sweeps can observe it done.
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        // One more accepted connection triggers a final sweep pass.
        let mut last = TcpStream::connect(server.addr).unwrap();
        last.write_all(b"{\"features\": [1, 0]}\n").unwrap();
        let mut reader = BufReader::new(&last);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let tracked = server.tracked_clients();
        assert!(
            tracked < CHURN / 2,
            "finished client handles not reaped: {tracked} tracked after {CHURN} churned \
             connections"
        );
        drop(reader);
        drop(last);
        server.shutdown();
    }
}

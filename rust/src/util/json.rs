//! Minimal JSON parser/serializer.
//!
//! The offline environment vendors only the `xla` crate closure (no serde),
//! so manifests, configs, and metrics use this hand-rolled implementation.
//! It supports the full JSON grammar we emit and consume: objects, arrays,
//! strings (with escapes incl. `\uXXXX` BMP), numbers, booleans, null.
//! Key order is preserved on round-trip (objects are association lists).

use std::collections::BTreeMap;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(Vec<(String, Value)>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Nested lookup: `get_path(&["config", "D"])`.
    pub fn get_path(&self, path: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for key in path {
            cur = cur.get(key)?;
        }
        Some(cur)
    }

    /// Object fields as a map (for iteration in sorted order).
    pub fn as_map(&self) -> Option<BTreeMap<&str, &Value>> {
        match self {
            Value::Object(fields) => {
                Some(fields.iter().map(|(k, v)| (k.as_str(), v)).collect())
            }
            _ => None,
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, message: impl Into<String>) -> Result<T, ParseError> {
        Err(ParseError { offset: self.pos, message: message.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            self.err(format!("expected '{}'", b as char))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(format!("expected '{word}'"))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected a value"),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(fields)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let h = self.bump().ok_or(ParseError {
                                offset: self.pos,
                                message: "truncated \\u escape".into(),
                            })?;
                            code = code * 16
                                + (h as char).to_digit(16).ok_or(ParseError {
                                    offset: self.pos,
                                    message: "bad hex digit".into(),
                                })?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) if c < 0x20 => return self.err("control char in string"),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return self.err("truncated utf-8");
                    }
                    match std::str::from_utf8(&self.bytes[start..end]) {
                        Ok(s) => {
                            out.push_str(s);
                            self.pos = end;
                        }
                        Err(_) => return self.err("invalid utf-8"),
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match text.parse::<f64>() {
            Ok(n) => Ok(Value::Number(n)),
            Err(_) => self.err(format!("bad number '{text}'")),
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing characters");
    }
    Ok(v)
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_value(v: &Value, out: &mut String, indent: usize, pretty: bool) {
    let pad = |out: &mut String, n: usize| {
        if pretty {
            out.push('\n');
            for _ in 0..n {
                out.push(' ');
            }
        }
    };
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Number(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::String(s) => escape_into(s, out),
        Value::Array(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                pad(out, indent + 1);
                write_value(item, out, indent + 1, pretty);
            }
            if !items.is_empty() {
                pad(out, indent);
            }
            out.push(']');
        }
        Value::Object(fields) => {
            out.push('{');
            for (i, (k, val)) in fields.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                pad(out, indent + 1);
                escape_into(k, out);
                out.push(':');
                if pretty {
                    out.push(' ');
                }
                write_value(val, out, indent + 1, pretty);
            }
            if !fields.is_empty() {
                pad(out, indent);
            }
            out.push('}');
        }
    }
}

/// Serialize compactly.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, 0, false);
    out
}

/// Serialize with 1-space indentation (matches Python's `indent=1`).
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, 0, true);
    out
}

/// Convenience builders.
pub fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::Object(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Number(n)
}

pub fn s(text: impl Into<String>) -> Value {
    Value::String(text.into())
}

pub fn arr(items: Vec<Value>) -> Value {
    Value::Array(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse(" false ").unwrap(), Value::Bool(false));
        assert_eq!(parse("3.5").unwrap(), Value::Number(3.5));
        assert_eq!(parse("-2e3").unwrap(), Value::Number(-2000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::String("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(v.get_path(&["d"]), Some(&Value::Null));
        assert_eq!(
            v.get("a").unwrap().as_array().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — ok"));
    }

    #[test]
    fn errors_have_offsets() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"config":{"D":10000,"k":2},"entries":[{"name":"x","shapes":[[64,617]]}],"acc":0.9321,"flag":true,"none":null}"#;
        let v = parse(text).unwrap();
        let back = parse(&to_string(&v)).unwrap();
        assert_eq!(v, back);
        let back2 = parse(&to_string_pretty(&v)).unwrap();
        assert_eq!(v, back2);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(to_string(&Value::Number(10000.0)), "10000");
        assert_eq!(to_string(&Value::Number(0.5)), "0.5");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Array(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Object(vec![]));
        assert_eq!(to_string(&parse("[]").unwrap()), "[]");
    }
}

//! Persistent data-parallel worker pool (no rayon offline).
//!
//! The figure harnesses and the native inference hot path split batches
//! of queries into contiguous chunks and fan them out over worker
//! threads. Historically this spawned fresh OS threads per kernel call
//! via `std::thread::scope`; a serving batch paid that spawn latency
//! several times per request (encode, activations, decode). The pool is
//! now **persistent**: [`available_threads`]` − 1` workers are spawned
//! lazily on first use and then park on a condvar, and each
//! [`parallel_rows`]/[`parallel_ranges`] call publishes one chunk-claiming
//! job, participates in it from the calling thread, and blocks until the
//! last chunk completes — the same borrowed-state fork-join shape, minus
//! the spawns.
//!
//! Properties the call sites rely on:
//!
//! - The caller returns only after every chunk has run, so closures may
//!   borrow stack state (the lifetime erasure below is sound for exactly
//!   this reason).
//! - Multiple jobs may be in flight concurrently (multi-tenant engines
//!   share the one process-wide pool); workers drain whatever job has
//!   unclaimed chunks.
//! - Nested calls are safe: the inner caller claims its own chunks, so
//!   progress never depends on a parked worker.
//! - A panic inside a chunk is caught on the worker and re-raised on the
//!   calling thread after the job drains (`std::thread::scope` parity).
//! - `LOGHD_THREADS=N` pins the worker count (reproducible benching);
//!   otherwise `available_parallelism` decides, cached once per process.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

type PanicPayload = Box<dyn std::any::Any + Send + 'static>;

/// Number of worker threads to use (>= 1). Honors `LOGHD_THREADS=N`;
/// cached in a `OnceLock` after the first call (it used to be a fresh
/// `available_parallelism` syscall per kernel invocation).
pub fn available_threads() -> usize {
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED.get_or_init(|| {
        if let Ok(v) = std::env::var("LOGHD_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n >= 1 {
                    return n;
                }
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// One published fork-join job: a lifetime-erased chunk runner plus the
/// claim/completion counters. Workers claim chunk indices with a
/// fetch-add race; the publishing caller participates too and then waits
/// on `finished`.
struct Job {
    /// Erased `&F` where `F: Fn(usize) + Sync`, valid until `done`
    /// reaches `n_chunks` (the publisher blocks until then).
    ctx: *const (),
    /// Monomorphized trampoline that reconstitutes `ctx` and runs one
    /// chunk index.
    call: unsafe fn(*const (), usize),
    n_chunks: usize,
    next: AtomicUsize,
    done: AtomicUsize,
    panic_payload: Mutex<Option<PanicPayload>>,
    finished: Mutex<bool>,
    finished_cv: Condvar,
}

// SAFETY: `ctx` points at an `F: Fn(usize) + Sync` owned by the
// publishing call frame, which outlives every dereference (the publisher
// blocks until `done == n_chunks`, and exhausted jobs are never called
// again). Shared invocation is fine because `F: Sync`.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

impl Job {
    /// Claim and run chunks until none remain.
    fn run_chunks(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n_chunks {
                return;
            }
            let result = catch_unwind(AssertUnwindSafe(|| unsafe { (self.call)(self.ctx, i) }));
            if let Err(payload) = result {
                *self.panic_payload.lock().unwrap() = Some(payload);
            }
            // AcqRel: the finishing increment acquires every prior
            // chunk's release so the waiter observes all chunk writes.
            if self.done.fetch_add(1, Ordering::AcqRel) + 1 == self.n_chunks {
                let mut fin = self.finished.lock().unwrap();
                *fin = true;
                self.finished_cv.notify_all();
            }
        }
    }

    fn exhausted(&self) -> bool {
        self.next.load(Ordering::Relaxed) >= self.n_chunks
    }

    fn wait(&self) {
        let mut fin = self.finished.lock().unwrap();
        while !*fin {
            fin = self.finished_cv.wait(fin).unwrap();
        }
    }
}

unsafe fn trampoline<F: Fn(usize) + Sync>(ctx: *const (), i: usize) {
    (*(ctx as *const F))(i)
}

struct Shared {
    queue: Mutex<VecDeque<Arc<Job>>>,
    cv: Condvar,
}

fn worker_loop(shared: &'static Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                q.retain(|j| !j.exhausted());
                if let Some(j) = q.front() {
                    break j.clone();
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        job.run_chunks();
    }
}

/// The process-wide pool: `available_threads() - 1` parked workers,
/// spawned on first use (the calling thread is the Nth participant).
fn pool() -> &'static Shared {
    static POOL: OnceLock<&'static Shared> = OnceLock::new();
    POOL.get_or_init(|| {
        let shared: &'static Shared =
            Box::leak(Box::new(Shared { queue: Mutex::new(VecDeque::new()), cv: Condvar::new() }));
        for i in 0..available_threads().saturating_sub(1) {
            std::thread::Builder::new()
                .name(format!("loghd-worker-{i}"))
                .spawn(move || worker_loop(shared))
                .expect("spawn loghd worker");
        }
        shared
    })
}

/// Publish `n_chunks` invocations of `f` to the pool, participate from
/// this thread, and return once all have run (re-raising any panic).
fn run_parallel<F: Fn(usize) + Sync>(n_chunks: usize, f: F) {
    debug_assert!(n_chunks >= 2, "single-chunk jobs run inline at the call site");
    if available_threads() <= 1 {
        // Zero-worker pool: publishing would only queue garbage — run
        // the chunks inline on the caller.
        for i in 0..n_chunks {
            f(i);
        }
        return;
    }
    let job = Arc::new(Job {
        ctx: &f as *const F as *const (),
        call: trampoline::<F>,
        n_chunks,
        next: AtomicUsize::new(0),
        done: AtomicUsize::new(0),
        panic_payload: Mutex::new(None),
        finished: Mutex::new(false),
        finished_cv: Condvar::new(),
    });
    let shared = pool();
    {
        let mut q = shared.queue.lock().unwrap();
        q.push_back(job.clone());
    }
    shared.cv.notify_all();
    job.run_chunks();
    job.wait();
    // Publisher-side cleanup: workers also drop exhausted jobs, but only
    // when one next wakes — removing our own entry keeps the queue from
    // retaining finished jobs (and their dangling ctx) between calls.
    {
        let mut q = shared.queue.lock().unwrap();
        q.retain(|j| !Arc::ptr_eq(j, &job));
    }
    if let Some(payload) = job.panic_payload.lock().unwrap().take() {
        std::panic::resume_unwind(payload);
    }
}

/// Run `f(chunk_start, chunk_end)` over `[0, len)` split into roughly equal
/// contiguous chunks, at most one per participating thread. `f` runs on
/// borrowed state — the classic fork-join shape, now on parked workers.
pub fn parallel_ranges<F>(len: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = threads.clamp(1, len.max(1));
    if threads <= 1 || len == 0 {
        f(0, len);
        return;
    }
    let chunk = len.div_ceil(threads);
    let n_chunks = len.div_ceil(chunk);
    if n_chunks <= 1 {
        f(0, len);
        return;
    }
    run_parallel(n_chunks, |c| {
        let lo = c * chunk;
        let hi = ((c + 1) * chunk).min(len);
        f(lo, hi);
    });
}

/// Parallel map over disjoint mutable row chunks of `out` (each of width
/// `row_width`), where `f(row_index, row_slice)` fills one row.
pub fn parallel_rows<F>(out: &mut [f32], row_width: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert!(row_width > 0, "row_width must be positive");
    assert_eq!(out.len() % row_width, 0, "out not a whole number of rows");
    let rows = out.len() / row_width;
    let threads = threads.clamp(1, rows.max(1));
    if threads <= 1 {
        for (i, row) in out.chunks_mut(row_width).enumerate() {
            f(i, row);
        }
        return;
    }
    let chunk_rows = rows.div_ceil(threads);
    let n_chunks = rows.div_ceil(chunk_rows);
    if n_chunks <= 1 {
        for (i, row) in out.chunks_mut(row_width).enumerate() {
            f(i, row);
        }
        return;
    }
    // Chunks are disjoint row ranges of `out`; each is re-sliced from the
    // base pointer inside its own claim, so no two chunks alias.
    let base = out.as_mut_ptr() as usize;
    run_parallel(n_chunks, |c| {
        let lo_row = c * chunk_rows;
        let hi_row = ((c + 1) * chunk_rows).min(rows);
        let ptr = (base as *mut f32).wrapping_add(lo_row * row_width);
        let slab = unsafe { std::slice::from_raw_parts_mut(ptr, (hi_row - lo_row) * row_width) };
        for (i, row) in slab.chunks_mut(row_width).enumerate() {
            f(lo_row + i, row);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn ranges_cover_everything_once() {
        let hits = (0..100).map(|_| AtomicUsize::new(0)).collect::<Vec<_>>();
        parallel_ranges(100, 4, |lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn ranges_zero_len() {
        parallel_ranges(0, 4, |lo, hi| assert_eq!(lo, hi));
    }

    #[test]
    fn rows_fill_each_row() {
        let mut out = vec![0.0f32; 12];
        parallel_rows(&mut out, 3, 4, |i, row| {
            for v in row.iter_mut() {
                *v = i as f32;
            }
        });
        assert_eq!(out, vec![0., 0., 0., 1., 1., 1., 2., 2., 2., 3., 3., 3.]);
    }

    #[test]
    fn rows_single_thread_path() {
        let mut out = vec![0.0f32; 6];
        parallel_rows(&mut out, 2, 1, |i, row| row.fill(i as f32));
        assert_eq!(out, vec![0., 0., 1., 1., 2., 2.]);
    }

    #[test]
    fn available_threads_is_cached_and_positive() {
        let a = available_threads();
        assert!(a >= 1);
        assert_eq!(a, available_threads());
    }

    #[test]
    fn pool_survives_many_sequential_jobs() {
        // Spawn-per-call would make this test expensive; on the parked
        // pool it is one spawn set total. Also doubles as a correctness
        // soak under claim races.
        for round in 0..200usize {
            let mut out = vec![0.0f32; 64];
            parallel_rows(&mut out, 4, 4, |i, row| row.fill((i * (round + 1)) as f32));
            for (i, chunk) in out.chunks(4).enumerate() {
                assert!(chunk.iter().all(|v| *v == (i * (round + 1)) as f32));
            }
        }
    }

    #[test]
    fn nested_parallel_calls_complete() {
        let mut out = vec![0.0f32; 32];
        parallel_rows(&mut out, 8, 4, |i, row| {
            let counter = AtomicUsize::new(0);
            parallel_ranges(16, 2, |lo, hi| {
                counter.fetch_add(hi - lo, Ordering::Relaxed);
            });
            assert_eq!(counter.load(Ordering::Relaxed), 16);
            row.fill(i as f32);
        });
        for (i, chunk) in out.chunks(8).enumerate() {
            assert!(chunk.iter().all(|v| *v == i as f32));
        }
    }

    #[test]
    fn concurrent_jobs_from_many_threads() {
        std::thread::scope(|s| {
            for t in 0..4usize {
                s.spawn(move || {
                    let mut out = vec![0.0f32; 40];
                    parallel_rows(&mut out, 5, 4, |i, row| row.fill((t * 100 + i) as f32));
                    for (i, chunk) in out.chunks(5).enumerate() {
                        assert!(chunk.iter().all(|v| *v == (t * 100 + i) as f32));
                    }
                });
            }
        });
    }

    #[test]
    fn worker_panic_propagates_to_caller() {
        let result = std::panic::catch_unwind(|| {
            parallel_ranges(8, 4, |lo, _hi| {
                if lo == 0 {
                    panic!("chunk failure");
                }
            });
        });
        assert!(result.is_err(), "panic inside a chunk must reach the caller");
    }
}

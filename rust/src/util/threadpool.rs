//! Scoped data-parallel helpers over `std::thread` (no rayon offline).
//!
//! The figure harnesses and the native inference hot path split batches of
//! queries into contiguous chunks and process them on `available_threads()`
//! OS threads via `std::thread::scope`. On this CI box that is 1 core (the
//! pool degrades to an in-place loop); on a real machine it scales.

/// Number of worker threads to use (>= 1).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(chunk_start, chunk_end)` over `[0, len)` split into roughly equal
/// contiguous chunks, one per thread. `f` runs on borrowed state — the
/// classic fork-join shape.
pub fn parallel_ranges<F>(len: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = threads.clamp(1, len.max(1));
    if threads <= 1 || len == 0 {
        f(0, len);
        return;
    }
    let chunk = len.div_ceil(threads);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(len);
            if lo >= hi {
                break;
            }
            let f = &f;
            scope.spawn(move || f(lo, hi));
        }
    });
}

/// Parallel map over disjoint mutable row chunks of `out` (each of width
/// `row_width`), where `f(row_index, row_slice)` fills one row.
pub fn parallel_rows<F>(out: &mut [f32], row_width: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert!(row_width > 0, "row_width must be positive");
    assert_eq!(out.len() % row_width, 0, "out not a whole number of rows");
    let rows = out.len() / row_width;
    let threads = threads.clamp(1, rows.max(1));
    if threads <= 1 {
        for (i, row) in out.chunks_mut(row_width).enumerate() {
            f(i, row);
        }
        return;
    }
    let chunk_rows = rows.div_ceil(threads);
    std::thread::scope(|scope| {
        for (t, slab) in out.chunks_mut(chunk_rows * row_width).enumerate() {
            let f = &f;
            scope.spawn(move || {
                for (i, row) in slab.chunks_mut(row_width).enumerate() {
                    f(t * chunk_rows + i, row);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn ranges_cover_everything_once() {
        let hits = (0..100).map(|_| AtomicUsize::new(0)).collect::<Vec<_>>();
        parallel_ranges(100, 4, |lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn ranges_zero_len() {
        parallel_ranges(0, 4, |lo, hi| assert_eq!(lo, hi));
    }

    #[test]
    fn rows_fill_each_row() {
        let mut out = vec![0.0f32; 12];
        parallel_rows(&mut out, 3, 4, |i, row| {
            for v in row.iter_mut() {
                *v = i as f32;
            }
        });
        assert_eq!(out, vec![0., 0., 0., 1., 1., 1., 2., 2., 2., 3., 3., 3.]);
    }

    #[test]
    fn rows_single_thread_path() {
        let mut out = vec![0.0f32; 6];
        parallel_rows(&mut out, 2, 1, |i, row| row.fill(i as f32));
        assert_eq!(out, vec![0., 0., 1., 1., 2., 2.]);
    }
}

//! SplitMix64-based deterministic PRNG — the bit-exact twin of
//! `python/compile/prng.py`.
//!
//! Both worlds must draw *identical* streams so that the synthetic datasets
//! and codebooks built at artifact time (Python) match the ones the figure
//! harnesses and property tests build natively (Rust). The contract:
//!
//! - SplitMix64 for raw `u64`s,
//! - uniform `f64` in `[0,1)` as `(z >> 11) * 2^-53`,
//! - standard normals via Box–Muller, each consuming exactly TWO uniforms
//!   (the sine twin is discarded so stream position is batching-independent),
//! - Fisher–Yates shuffles indexed with `next_u64() % (i+1)`.
//!
//! Canonical vectors live in the tests below and in
//! `python/tests/test_prng.py`; change one and you must change both.

const GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;
const M1: u64 = 0xBF58_476D_1CE4_E5B9;
const M2: u64 = 0x94D0_49BB_1331_11EB;
const TWO53_INV: f64 = 1.0 / 9007199254740992.0; // 2^-53

/// Deterministic SplitMix64 stream.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GAMMA);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(M1);
        z = (z ^ (z >> 27)).wrapping_mul(M2);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * TWO53_INV
    }

    /// Standard normal (Box–Muller, cosine branch; consumes 2 uniforms).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(TWO53_INV);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// `count` uniforms.
    pub fn uniforms(&mut self, count: usize) -> Vec<f64> {
        (0..count).map(|_| self.uniform()).collect()
    }

    /// `count` normals.
    pub fn normals(&mut self, count: usize) -> Vec<f64> {
        (0..count).map(|_| self.normal()).collect()
    }

    /// `count` normals directly as f32 (the common tensor case).
    pub fn normals_f32(&mut self, count: usize) -> Vec<f32> {
        (0..count).map(|_| self.normal() as f32).collect()
    }

    /// In-place Fisher–Yates, high-to-low, `next_u64 % (i+1)` indices —
    /// identical to the Python twin (modulo bias and all).
    pub fn shuffle<T>(&mut self, arr: &mut [T]) {
        for i in (1..arr.len()).rev() {
            let j = (self.next_u64() % (i as u64 + 1)) as usize;
            arr.swap(i, j);
        }
    }

    /// Uniform integer in [0, bound) via modulo (parity over perfection).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        self.next_u64() % bound
    }

    /// Derive an independent stream for a labelled sub-task. Mixing the
    /// label through one SplitMix64 step keeps derivation deterministic.
    pub fn fork(&mut self, label: u64) -> SplitMix64 {
        SplitMix64::new(self.next_u64() ^ label.wrapping_mul(GAMMA))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Canonical vectors, identical to python/tests/test_prng.py.
    #[test]
    fn u64_vectors_seed42() {
        let mut r = SplitMix64::new(42);
        let got: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        assert_eq!(
            got,
            vec![
                0xBDD7_3226_2FEB_6E95,
                0x28EF_E333_B266_F103,
                0x4752_6757_130F_9F52,
                0x581C_E1FF_0E4A_E394
            ]
        );
    }

    #[test]
    fn uniform_vectors_seed42() {
        let mut r = SplitMix64::new(42);
        let want = [0.74156488, 0.15991039, 0.27860113, 0.34419072];
        for w in want {
            assert!((r.uniform() - w).abs() < 1e-8);
        }
    }

    #[test]
    fn normal_vectors_seed42() {
        let mut r = SplitMix64::new(42);
        let want = [0.41471975, -0.89188621, 1.72959309, 0.54562044];
        for w in want {
            assert!((r.normal() - w).abs() < 1e-8);
        }
    }

    #[test]
    fn shuffle_vector_seed123() {
        let mut r = SplitMix64::new(123);
        let mut a: Vec<i64> = (0..10).collect();
        r.shuffle(&mut a);
        assert_eq!(a, vec![7, 3, 4, 9, 8, 2, 1, 0, 6, 5]);
    }

    #[test]
    fn normal_consumes_two_uniforms() {
        let mut r1 = SplitMix64::new(9);
        for _ in 0..3 {
            r1.normal();
        }
        let mut r2 = SplitMix64::new(9);
        for _ in 0..6 {
            r2.uniform();
        }
        assert_eq!(r1.next_u64(), r2.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(1234);
        let n = 200_000;
        let zs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = zs.iter().sum::<f64>() / n as f64;
        let var = zs.iter().map(|z| (z - mean) * (z - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var.sqrt() - 1.0).abs() < 0.01, "std {}", var.sqrt());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(5);
        let mut a: Vec<u32> = (0..1000).collect();
        r.shuffle(&mut a);
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_diverge() {
        let mut r = SplitMix64::new(1);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}

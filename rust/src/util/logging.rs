//! Tiny leveled logger (no external crates in this environment).
//!
//! Level is chosen by `LOGHD_LOG` (error|warn|info|debug|trace), default
//! `info`. Output goes to stderr with a monotonic-ish wall timestamp.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized

fn init_from_env() -> u8 {
    let lvl = match std::env::var("LOGHD_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    MAX_LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// True when messages at `level` should be emitted.
pub fn enabled(level: Level) -> bool {
    let mut max = MAX_LEVEL.load(Ordering::Relaxed);
    if max == u8::MAX {
        max = init_from_env();
    }
    (level as u8) <= max
}

/// Override the level programmatically (tests, CLI flags).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

#[doc(hidden)]
pub fn emit(level: Level, module: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let now = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    eprintln!(
        "[{:>10}.{:03} {:5} {}] {}",
        now.as_secs(),
        now.subsec_millis(),
        level.as_str(),
        module,
        args
    );
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}

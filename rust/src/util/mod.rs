//! Substrate utilities built from scratch for the offline environment:
//! deterministic PRNG (Python-parity), minimal JSON, leveled logging, and
//! scoped thread-pool helpers.

pub mod json;
pub mod logging;
pub mod rng;
pub mod threadpool;

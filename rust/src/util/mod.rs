//! Substrate utilities built from scratch for the offline environment:
//! deterministic PRNG (Python-parity), minimal JSON, leveled logging, and
//! a persistent parked-worker thread pool.

pub mod json;
pub mod logging;
pub mod rng;
pub mod threadpool;

//! Bundle construction (paper Eq. 4): M_j = sum_c g(B_cj) H_c, normalized.

use crate::loghd::codebook::{g, Codebook};
use crate::tensor::{self, Matrix};

/// Weighted superposition of class prototypes into n bundles, f64
/// accumulation, unit-row output (zero guard as in the Python twin).
pub fn build_bundles(h: &Matrix, book: &Codebook) -> Matrix {
    let c = book.classes();
    let n = book.n();
    assert_eq!(h.rows(), c, "prototype count != codebook classes");
    let d = h.cols();
    let mut acc = vec![0.0f64; n * d];
    for (cls, code) in book.rows.iter().enumerate() {
        let hrow = h.row(cls);
        for (j, &s) in code.iter().enumerate() {
            let w = g(s, book.k);
            if w == 0.0 {
                continue;
            }
            let dst = &mut acc[j * d..(j + 1) * d];
            for (a, v) in dst.iter_mut().zip(hrow) {
                *a += w * *v as f64;
            }
        }
    }
    let mut m = Matrix::from_vec(n, d, acc.into_iter().map(|v| v as f32).collect());
    tensor::normalize_rows(&mut m);
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loghd::codebook::Codebook;

    #[test]
    fn weights_follow_symbols() {
        // Two orthogonal prototypes, codebook k=2:
        // class0 -> (1,0), class1 -> (1,1).
        let h = Matrix::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let book = Codebook { k: 2, rows: vec![vec![1, 0], vec![1, 1]] };
        let m = build_bundles(&h, &book);
        // bundle0 = normalize(H0 + H1) = (1,1)/sqrt(2)
        assert!((m.at(0, 0) - std::f32::consts::FRAC_1_SQRT_2).abs() < 1e-6);
        assert!((m.at(0, 1) - std::f32::consts::FRAC_1_SQRT_2).abs() < 1e-6);
        // bundle1 = normalize(H1) = (0,1)
        assert!(m.at(1, 0).abs() < 1e-6);
        assert!((m.at(1, 1) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn ternary_weights() {
        let h = Matrix::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let book = Codebook { k: 3, rows: vec![vec![1], vec![2]] };
        let m = build_bundles(&h, &book);
        // bundle0 = normalize(0.5*H0 + 1.0*H1): direction (0.5, 1)/|..|
        let norm = (0.25f32 + 1.0).sqrt();
        assert!((m.at(0, 0) - 0.5 / norm).abs() < 1e-6);
        assert!((m.at(0, 1) - 1.0 / norm).abs() < 1e-6);
    }

    #[test]
    fn all_zero_column_stays_finite() {
        let h = Matrix::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let book = Codebook { k: 2, rows: vec![vec![0, 1]] };
        let m = build_bundles(&h, &book);
        assert!(m.row(0).iter().all(|v| v.is_finite()));
        assert!(tensor::norm(m.row(0)) < 1e-6); // empty bundle ~ zero
    }
}

//! Iterative bundle refinement (paper §III-F, Eq. 8/9) — batched minibatch
//! variant, mirroring `python/compile/trainer.py::refine_bundles` and the
//! L2 `refine_step` graph: per minibatch, A = activations(enc_b, M),
//! coef = eta (tau - A), M <- normalize(M + coefᵀ·enc_b).
//!
//! Two entry points: [`refine_step`] (allocating, the reference) and
//! [`refine_step_into`] (in-place over caller [`RefineScratch`] — the
//! steady-state form the online trainer loops on, no per-minibatch clone
//! of the bundle matrix). [`refine_bundles`] validates its inputs and
//! returns `Result` because labels may arrive from an untrusted feedback
//! stream (see `coordinator::conn`'s `feedback` verb).

use anyhow::{bail, ensure, Result};

use crate::hd::prototype::gather_rows;
use crate::hd::similarity::{activations, activations_into};
use crate::loghd::codebook::Codebook;
use crate::tensor::{self, Matrix};
use crate::util::rng::SplitMix64;

/// Reused intermediates for [`refine_step_into`]: the (B, n) activations,
/// the (n, B) update coefficients, and the (n, D) delta. All settle at
/// their high-water shapes after the first minibatch.
#[derive(Debug, Clone, Default)]
pub struct RefineScratch {
    acts: Matrix,
    coef: Matrix,
    delta: Matrix,
}

/// One batched refinement step; returns re-normalized bundles.
pub fn refine_step(m: &Matrix, enc_b: &Matrix, tau: &Matrix, eta: f32) -> Matrix {
    let mut out = m.clone();
    refine_step_into(&mut out, enc_b, tau, eta, &mut RefineScratch::default());
    out
}

/// [`refine_step`] updating `m` in place through caller-owned scratch —
/// the minibatch loop stops cloning the bundle matrix twice per step
/// (the `m.clone()` plus the returned matrix). Identical math and float
/// behavior to [`refine_step`], which now delegates here.
pub fn refine_step_into(
    m: &mut Matrix,
    enc_b: &Matrix,
    tau: &Matrix,
    eta: f32,
    scratch: &mut RefineScratch,
) {
    let n = m.rows();
    let bsz = enc_b.rows();
    assert_eq!(tau.rows(), bsz);
    assert_eq!(tau.cols(), n);
    activations_into(enc_b, m, &mut scratch.acts); // (B, n)
    // coef (n, B) = eta * (tau - A)^T; delta = coef @ enc_b  (n, D)
    scratch.coef.resize(n, bsz);
    for i in 0..bsz {
        for j in 0..n {
            scratch.coef.set(j, i, eta * (tau.at(i, j) - scratch.acts.at(i, j)));
        }
    }
    tensor::matmul_into(&scratch.coef, enc_b, &mut scratch.delta);
    for j in 0..n {
        tensor::axpy(1.0, scratch.delta.row(j), m.row_mut(j));
    }
    tensor::normalize_rows(m);
}

/// Full refinement: `epochs` shuffled passes of minibatch steps.
///
/// Errors (rather than panicking) on `batch == 0` and on any label
/// outside `0..book.classes()` — both reachable from wire-fed feedback.
#[allow(clippy::too_many_arguments)]
pub fn refine_bundles(
    m: &Matrix,
    enc: &Matrix,
    y: &[i32],
    book: &Codebook,
    epochs: usize,
    eta: f32,
    seed: u64,
    batch: usize,
) -> Result<Matrix> {
    ensure!(batch > 0, "refinement batch size must be > 0");
    let classes = book.classes();
    if let Some(&bad) = y.iter().find(|&&l| l < 0 || l as usize >= classes) {
        bail!("label {bad} outside codebook class range 0..{classes}");
    }
    let targets = book.targets(); // (C, n)
    let n = book.n();
    let mut rng = SplitMix64::new(seed);
    let mut idx: Vec<usize> = (0..y.len()).collect();
    let mut mwork = m.clone();
    let mut scratch = RefineScratch::default();
    let mut tau = Matrix::zeros(0, 0);
    for _ in 0..epochs {
        rng.shuffle(&mut idx);
        for chunk in idx.chunks(batch) {
            let enc_b = gather_rows(enc, chunk);
            tau.resize(chunk.len(), n);
            for (bi, &si) in chunk.iter().enumerate() {
                tau.row_mut(bi).copy_from_slice(&targets[y[si] as usize]);
            }
            refine_step_into(&mut mwork, &enc_b, &tau, eta, &mut scratch);
        }
    }
    Ok(mwork)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::normalize_rows;
    use crate::util::rng::SplitMix64;

    #[test]
    fn step_moves_toward_targets() {
        let mut rng = SplitMix64::new(3);
        let enc = Matrix::from_vec(8, 32, rng.normals_f32(256));
        let mut m = Matrix::from_vec(2, 32, rng.normals_f32(64));
        normalize_rows(&mut m);
        let a0 = activations(&enc, &m);
        let tau = Matrix::from_vec(8, 2, vec![1.0; 16]); // push everything up
        let m1 = refine_step(&m, &enc, &tau, 0.05);
        let a1 = activations(&enc, &m1);
        let mean0: f32 = a0.data().iter().sum::<f32>() / 16.0;
        let mean1: f32 = a1.data().iter().sum::<f32>() / 16.0;
        assert!(mean1 > mean0, "{mean1} <= {mean0}");
        for j in 0..2 {
            assert!((tensor::norm(m1.row(j)) - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn zero_eta_is_identity_after_norm() {
        let mut rng = SplitMix64::new(4);
        let enc = Matrix::from_vec(4, 16, rng.normals_f32(64));
        let mut m = Matrix::from_vec(3, 16, rng.normals_f32(48));
        normalize_rows(&mut m);
        let tau = Matrix::zeros(4, 3);
        let m1 = refine_step(&m, &enc, &tau, 0.0);
        for (a, b) in m.data().iter().zip(m1.data()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn step_into_matches_step_with_reused_scratch() {
        let mut rng = SplitMix64::new(7);
        let enc = Matrix::from_vec(6, 24, rng.normals_f32(144));
        let mut m = Matrix::from_vec(3, 24, rng.normals_f32(72));
        normalize_rows(&mut m);
        let tau = Matrix::from_vec(6, 3, rng.normals_f32(18));
        let mut scratch = RefineScratch::default();
        // run twice through the same scratch: reuse must not change math
        for _ in 0..2 {
            let want = refine_step(&m, &enc, &tau, 0.02);
            let mut got = m.clone();
            refine_step_into(&mut got, &enc, &tau, 0.02, &mut scratch);
            assert_eq!(got.data(), want.data());
            m = want;
        }
    }

    #[test]
    fn refinement_deterministic_in_seed() {
        let mut rng = SplitMix64::new(5);
        let enc = Matrix::from_vec(20, 16, rng.normals_f32(320));
        let y: Vec<i32> = (0..20).map(|i| i % 4).collect();
        let book = crate::loghd::codebook::build(4, 2, 3, 1.0, 1).unwrap();
        let mut m = Matrix::from_vec(3, 16, rng.normals_f32(48));
        normalize_rows(&mut m);
        let a = refine_bundles(&m, &enc, &y, &book, 3, 0.01, 42, 8).unwrap();
        let b = refine_bundles(&m, &enc, &y, &book, 3, 0.01, 42, 8).unwrap();
        assert_eq!(a.data(), b.data());
    }

    /// Regression (pre-fix code panicked): `batch == 0` is an error, not
    /// a `chunks(0)` panic.
    #[test]
    fn zero_batch_is_an_error_not_a_panic() {
        let mut rng = SplitMix64::new(6);
        let enc = Matrix::from_vec(8, 16, rng.normals_f32(128));
        let y: Vec<i32> = (0..8).map(|i| i % 4).collect();
        let book = crate::loghd::codebook::build(4, 2, 3, 1.0, 1).unwrap();
        let m = Matrix::from_vec(3, 16, rng.normals_f32(48));
        let err = refine_bundles(&m, &enc, &y, &book, 1, 0.01, 42, 0).unwrap_err();
        assert!(err.to_string().contains("batch size"), "{err}");
    }

    /// Regression (pre-fix code index-panicked on `targets[y[si]]`):
    /// labels outside the codebook class range are an error.
    #[test]
    fn out_of_range_label_is_an_error_not_a_panic() {
        let mut rng = SplitMix64::new(8);
        let enc = Matrix::from_vec(8, 16, rng.normals_f32(128));
        let book = crate::loghd::codebook::build(4, 2, 3, 1.0, 1).unwrap();
        let m = Matrix::from_vec(3, 16, rng.normals_f32(48));
        for bad in [4i32, 99, -1] {
            let mut y: Vec<i32> = (0..8).map(|i| i % 4).collect();
            y[5] = bad;
            let err = refine_bundles(&m, &enc, &y, &book, 1, 0.01, 42, 8).unwrap_err();
            assert!(err.to_string().contains("class range"), "{bad}: {err}");
        }
    }
}

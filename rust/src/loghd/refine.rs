//! Iterative bundle refinement (paper §III-F, Eq. 8/9) — batched minibatch
//! variant, mirroring `python/compile/trainer.py::refine_bundles` and the
//! L2 `refine_step` graph: per minibatch, A = activations(enc_b, M),
//! coef = eta (tau - A), M <- normalize(M + coefᵀ·enc_b).

use crate::hd::prototype::gather_rows;
use crate::hd::similarity::activations;
use crate::loghd::codebook::Codebook;
use crate::tensor::{self, Matrix};
use crate::util::rng::SplitMix64;

/// One batched refinement step; returns re-normalized bundles.
pub fn refine_step(m: &Matrix, enc_b: &Matrix, tau: &Matrix, eta: f32) -> Matrix {
    let n = m.rows();
    let d = m.cols();
    let bsz = enc_b.rows();
    assert_eq!(tau.rows(), bsz);
    assert_eq!(tau.cols(), n);
    let a = activations(enc_b, m); // (B, n)
    // coef (n, B) = eta * (tau - A)^T; delta = coef @ enc_b  (n, D)
    let mut coef = Matrix::zeros(n, bsz);
    for i in 0..bsz {
        for j in 0..n {
            coef.set(j, i, eta * (tau.at(i, j) - a.at(i, j)));
        }
    }
    let delta = tensor::matmul(&coef, enc_b);
    let mut out = m.clone();
    for j in 0..n {
        tensor::axpy(1.0, delta.row(j), out.row_mut(j));
    }
    let _ = d;
    tensor::normalize_rows(&mut out);
    out
}

/// Full refinement: `epochs` shuffled passes of minibatch steps.
pub fn refine_bundles(
    m: &Matrix,
    enc: &Matrix,
    y: &[i32],
    book: &Codebook,
    epochs: usize,
    eta: f32,
    seed: u64,
    batch: usize,
) -> Matrix {
    let targets = book.targets(); // (C, n)
    let n = book.n();
    let mut rng = SplitMix64::new(seed);
    let mut idx: Vec<usize> = (0..y.len()).collect();
    let mut mwork = m.clone();
    for _ in 0..epochs {
        rng.shuffle(&mut idx);
        for chunk in idx.chunks(batch) {
            let enc_b = gather_rows(enc, chunk);
            let mut tau = Matrix::zeros(chunk.len(), n);
            for (bi, &si) in chunk.iter().enumerate() {
                tau.row_mut(bi).copy_from_slice(&targets[y[si] as usize]);
            }
            mwork = refine_step(&mwork, &enc_b, &tau, eta);
        }
    }
    mwork
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::normalize_rows;
    use crate::util::rng::SplitMix64;

    #[test]
    fn step_moves_toward_targets() {
        let mut rng = SplitMix64::new(3);
        let enc = Matrix::from_vec(8, 32, rng.normals_f32(256));
        let mut m = Matrix::from_vec(2, 32, rng.normals_f32(64));
        normalize_rows(&mut m);
        let a0 = activations(&enc, &m);
        let tau = Matrix::from_vec(8, 2, vec![1.0; 16]); // push everything up
        let m1 = refine_step(&m, &enc, &tau, 0.05);
        let a1 = activations(&enc, &m1);
        let mean0: f32 = a0.data().iter().sum::<f32>() / 16.0;
        let mean1: f32 = a1.data().iter().sum::<f32>() / 16.0;
        assert!(mean1 > mean0, "{mean1} <= {mean0}");
        for j in 0..2 {
            assert!((tensor::norm(m1.row(j)) - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn zero_eta_is_identity_after_norm() {
        let mut rng = SplitMix64::new(4);
        let enc = Matrix::from_vec(4, 16, rng.normals_f32(64));
        let mut m = Matrix::from_vec(3, 16, rng.normals_f32(48));
        normalize_rows(&mut m);
        let tau = Matrix::zeros(4, 3);
        let m1 = refine_step(&m, &enc, &tau, 0.0);
        for (a, b) in m.data().iter().zip(m1.data()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn refinement_deterministic_in_seed() {
        let mut rng = SplitMix64::new(5);
        let enc = Matrix::from_vec(20, 16, rng.normals_f32(320));
        let y: Vec<i32> = (0..20).map(|i| i % 4).collect();
        let book = crate::loghd::codebook::build(4, 2, 3, 1.0, 1).unwrap();
        let mut m = Matrix::from_vec(3, 16, rng.normals_f32(48));
        normalize_rows(&mut m);
        let a = refine_bundles(&m, &enc, &y, &book, 3, 0.01, 42, 8);
        let b = refine_bundles(&m, &enc, &y, &book, 3, 0.01, 42, 8);
        assert_eq!(a.data(), b.data());
    }
}

//! Activation profiles (paper Eq. 5/6): per-class mean activation vectors.

use crate::hd::similarity::activations;
use crate::tensor::Matrix;

/// P_c = mean over class-c samples of A(x); (C, n), f64 accumulation.
pub fn compute_profiles(enc: &Matrix, y: &[i32], m: &Matrix, classes: usize) -> Matrix {
    assert_eq!(enc.rows(), y.len());
    let n = m.rows();
    let a = activations(enc, m);
    let mut acc = vec![0.0f64; classes * n];
    let mut counts = vec![0usize; classes];
    for (i, &cls) in y.iter().enumerate() {
        counts[cls as usize] += 1;
        let dst = &mut acc[cls as usize * n..(cls as usize + 1) * n];
        for (av, v) in dst.iter_mut().zip(a.row(i)) {
            *av += *v as f64;
        }
    }
    let mut out = Matrix::zeros(classes, n);
    for cls in 0..classes {
        let cnt = counts[cls].max(1) as f64;
        for (o, v) in out.row_mut(cls).iter_mut().zip(&acc[cls * n..(cls + 1) * n]) {
            *o = (*v / cnt) as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::normalize_rows;
    use crate::util::rng::SplitMix64;

    #[test]
    fn profiles_are_class_means() {
        let mut rng = SplitMix64::new(2);
        let enc = Matrix::from_vec(6, 8, rng.normals_f32(48));
        let y = vec![0, 1, 0, 1, 0, 1];
        let mut m = Matrix::from_vec(3, 8, rng.normals_f32(24));
        normalize_rows(&mut m);
        let p = compute_profiles(&enc, &y, &m, 2);
        let a = activations(&enc, &m);
        for j in 0..3 {
            let want0 = (a.at(0, j) + a.at(2, j) + a.at(4, j)) / 3.0;
            assert!((p.at(0, j) - want0).abs() < 1e-5);
            let want1 = (a.at(1, j) + a.at(3, j) + a.at(5, j)) / 3.0;
            assert!((p.at(1, j) - want1).abs() < 1e-5);
        }
    }

    #[test]
    fn empty_class_is_zero() {
        let enc = Matrix::from_vec(2, 4, vec![1.0; 8]);
        let y = vec![0, 0];
        let mut m = Matrix::from_vec(2, 4, SplitMix64::new(1).normals_f32(8));
        normalize_rows(&mut m);
        let p = compute_profiles(&enc, &y, &m, 3);
        assert!(p.row(2).iter().all(|v| *v == 0.0));
    }

    #[test]
    fn profile_values_bounded() {
        let mut rng = SplitMix64::new(5);
        let enc = Matrix::from_vec(20, 16, rng.normals_f32(320));
        let y: Vec<i32> = (0..20).map(|i| i % 4).collect();
        let mut m = Matrix::from_vec(5, 16, rng.normals_f32(80));
        normalize_rows(&mut m);
        let p = compute_profiles(&enc, &y, &m, 4);
        assert!(p.data().iter().all(|v| v.abs() <= 1.0 + 1e-5));
    }
}

//! Quantized LogHD inference: similarity computed directly on the packed
//! bit-planes, never materializing f32 bundle tensors.
//!
//! [`QuantizedLogHdModel`] is the precision-tagged serving twin of
//! [`LogHdModel`]: the stored state is the bit-packed quantizer output
//! ([`Quantized`] bundles + centered per-column profiles, exactly the
//! representation the fault injector flips bits in), and inference runs
//! on derived kernel views:
//!
//! - **1-bit**: queries are sign-binarized and bundle activations come
//!   from XNOR/popcount over u64 words (`tensor::xnor_popcount_nt`). The
//!   raw ±1 agreement rate is mapped back to cosine scale through the
//!   small-angle linearization of the arcsine law, `ρ ≈ (π/2)·s`, so the
//!   activations land where the f32-trained profiles expect them. The
//!   calibration is one positive per-model scalar: per-query activation
//!   *rankings* are bit-exact with the sign-dequantized f32 path (the
//!   properties test pins this).
//! - **8-bit**: queries are symmetrically quantized per batch and the
//!   activation GEMM runs in i32 over i16 containers
//!   (`tensor::i16_matmul_nt`), per-tensor scales folded once.
//!
//! Decoding is the fused form `|A|² − 2·A·Pᵀ + |P|²`
//! (`tensor::pairwise_sqdists_pre`) with the profile norms precomputed at
//! build; after fault injection [`refresh`](QuantizedLogHdModel::refresh)
//! re-derives the kernel views from the (possibly corrupted) packed
//! words — flip → infer, with no dequantize round-trip of the bundles.

use crate::loghd::model::LogHdModel;
use crate::model::{FaultPlane, FaultSurface, HdClassifier};
use crate::quant::{self, Precision, Quantized};
use crate::tensor::{self, BitMatrix, I16Matrix, Matrix, NtPrepared};
use crate::util::rng::SplitMix64;

/// First-order arcsine-law calibration from sign-agreement scale to
/// cosine scale: `ρ ≈ sin(π·s/2) ≈ (π/2)·s` for the small activations
/// HDC similarity produces.
const SIGN_COS_CALIBRATION: f32 = std::f32::consts::FRAC_PI_2;

/// The derived, row-aligned view the similarity kernel consumes.
enum BundleKernel {
    Bits(BitMatrix),
    I16(I16Matrix),
}

/// Stored activation profiles in the robust representation the sweep
/// engine corrupts (`eval::sweep::corrupt_profiles`): per-bundle-column
/// deviations from the cross-class mean, plus that mean — every part
/// quantized and packed, every part a fault target.
struct StoredProfiles {
    classes: usize,
    n: usize,
    mean: Quantized,      // (1, n)
    cols: Vec<Quantized>, // n columns of shape (C, 1)
}

impl StoredProfiles {
    fn from_matrix(p: &Matrix, precision: Precision) -> Self {
        let (classes, n) = (p.rows(), p.cols());
        let mean = tensor::col_means(p);
        let mut dev = p.clone();
        tensor::sub_row_inplace(&mut dev, &mean);
        let cols = (0..n)
            .map(|j| {
                let col: Vec<f32> = (0..classes).map(|r| dev.at(r, j)).collect();
                quant::quantize(&Matrix::from_vec(classes, 1, col), precision)
            })
            .collect();
        let mean_q = quant::quantize(&Matrix::from_vec(1, n, mean), precision);
        Self { classes, n, mean: mean_q, cols }
    }

    /// Reassemble the (C, n) profile matrix from the packed state.
    fn dequantize(&self) -> Matrix {
        let mean = quant::dequantize(&self.mean);
        let mut out = Matrix::zeros(self.classes, self.n);
        for (j, col_q) in self.cols.iter().enumerate() {
            let col = quant::dequantize(col_q);
            for r in 0..self.classes {
                out.set(r, j, col.at(r, 0) + mean.at(0, j));
            }
        }
        out
    }

    fn total_bits(&self) -> usize {
        self.mean.packed.total_bits()
            + self.cols.iter().map(|c| c.packed.total_bits()).sum::<usize>()
    }
}

/// A LogHD classifier whose stored state is bit-packed and whose hot path
/// runs in the packed domain (see module docs).
pub struct QuantizedLogHdModel {
    pub precision: Precision,
    pub classes: usize,
    pub d: usize,
    /// Packed bundle storage — the (n·D·bits)-bit fault surface.
    pub bundles: Quantized,
    profiles: StoredProfiles,
    kernel: BundleKernel,
    profiles_f32: Matrix,
    profiles_prep: NtPrepared,
    profile_sqnorms: Vec<f32>,
    activation_gain: f32,
}

/// Reusable query-side buffers for the packed hot paths. The B8 engine
/// re-quantizes every incoming batch; routing that through one of these
/// (held in engine state) makes the steady-state quantize allocation-free
/// (`I16Matrix::quantize_into`).
#[derive(Debug)]
pub struct QueryScratch {
    q8: I16Matrix,
    qbits: BitMatrix,
    qnorms: Vec<f32>,
}

impl QueryScratch {
    pub fn new() -> Self {
        Self { q8: I16Matrix::empty(), qbits: BitMatrix::zeros(0, 0), qnorms: Vec::new() }
    }
}

impl Default for QueryScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl QuantizedLogHdModel {
    /// Post-training quantization of a trained model. Only the widths
    /// with packed kernels are accepted (1 and 8 bits); 2/4-bit models
    /// keep the dequantize-and-score path in `eval::sweep`.
    pub fn from_model(model: &LogHdModel, precision: Precision) -> Self {
        assert!(
            matches!(precision, Precision::B1 | Precision::B8),
            "packed inference supports B1/B8, got {precision:?}"
        );
        let bundles = quant::quantize(&model.bundles, precision);
        let profiles = StoredProfiles::from_matrix(&model.profiles, precision);
        let kernel = Self::kernel_view(&bundles);
        let profiles_f32 = profiles.dequantize();
        let profiles_prep = NtPrepared::for_operand(&profiles_f32);
        let profile_sqnorms = tensor::row_sqnorms(&profiles_f32);
        Self {
            precision,
            classes: model.classes,
            d: model.d,
            bundles,
            profiles,
            kernel,
            profiles_f32,
            profiles_prep,
            profile_sqnorms,
            activation_gain: 1.0,
        }
    }

    /// Constant multiplier applied to activations before decoding.
    ///
    /// Needed when the model was column-compacted from a wider space
    /// (hybrid masking): the kernels normalize by the *kept*-dimension
    /// query norm, while the stored profiles were trained against
    /// full-width normalization — a systematic ratio of
    /// `≈ sqrt(D_kept / D_full)` that this gain restores.
    pub fn set_activation_gain(&mut self, gain: f32) {
        assert!(gain > 0.0 && gain.is_finite(), "activation gain must be positive");
        self.activation_gain = gain;
    }

    fn kernel_view(bundles: &Quantized) -> BundleKernel {
        match bundles.precision {
            Precision::B1 => BundleKernel::Bits(bundles.to_bit_matrix()),
            Precision::B8 => BundleKernel::I16(bundles.to_i16_matrix()),
            other => unreachable!("no packed kernel for {other:?}"),
        }
    }

    /// Re-derive the kernel views from the packed words. Call after any
    /// direct mutation of the packed state (fault injection).
    pub fn refresh(&mut self) {
        self.kernel = Self::kernel_view(&self.bundles);
        self.profiles_f32 = self.profiles.dequantize();
        self.profiles_prep = NtPrepared::for_operand(&self.profiles_f32);
        self.profile_sqnorms = tensor::row_sqnorms(&self.profiles_f32);
    }

    /// Per-value single-random-bit upsets with probability `p` over the
    /// whole stored state (bundles, then profile columns, then the
    /// profile mean — the order [`HdClassifier::fault_surface`]
    /// enumerates, which is also the order the pre-trait f32 sweep path
    /// drew in), followed by a view refresh. Returns flips.
    ///
    /// Thin wrapper over the shared [`crate::model::inject_value_faults`]
    /// driver, so the packed model and every other family consume one
    /// fault-stream discipline.
    pub fn inject_value_faults(&mut self, p: f64, rng: &mut SplitMix64) -> usize {
        crate::model::inject_value_faults(self, p, rng)
    }

    /// Bundle activations (B, n) in cosine scale, computed in the packed
    /// domain (see module docs for the per-precision semantics).
    pub fn activations(&self, enc: &Matrix) -> Matrix {
        self.activations_scratch(enc, &mut QueryScratch::new())
    }

    /// [`Self::activations`] through a caller-held [`QueryScratch`]: the
    /// B8 query batch is quantized into the reused buffer instead of a
    /// fresh allocation (serving engines keep one scratch per replica).
    pub fn activations_scratch(&self, enc: &Matrix, scratch: &mut QueryScratch) -> Matrix {
        let mut out = Matrix::zeros(0, 0);
        self.activations_into(enc, scratch, &mut out);
        out
    }

    /// [`Self::activations_scratch`] into a reused output matrix — the
    /// zero-allocation serving form: query-side packing lands in
    /// `scratch`, the activation matrix in `out`, and at steady state
    /// (stable batch shape) nothing allocates.
    pub fn activations_into(&self, enc: &Matrix, scratch: &mut QueryScratch, out: &mut Matrix) {
        assert_eq!(enc.cols(), self.d, "encoded width mismatch");
        match &self.kernel {
            BundleKernel::Bits(bundles) => {
                BitMatrix::from_signs_into(enc, &mut scratch.qbits);
                tensor::xnor_popcount_nt_into(&scratch.qbits, bundles, out);
                let scale = self.activation_gain * SIGN_COS_CALIBRATION / self.d.max(1) as f32;
                for v in out.data_mut() {
                    *v *= scale;
                }
            }
            BundleKernel::I16(bundles) => {
                I16Matrix::quantize_into(enc, &mut scratch.q8);
                tensor::i16_matmul_nt_into(&scratch.q8, bundles, out);
                scratch.q8.row_norms_into(&mut scratch.qnorms);
                for (i, qn) in scratch.qnorms.iter().enumerate() {
                    let scale = self.activation_gain / qn.max(1e-12);
                    for v in out.row_mut(i) {
                        *v *= scale;
                    }
                }
            }
        }
    }

    /// [`Self::predict_scratch`] writing every intermediate into
    /// caller-owned scratch (`acts`: the (B, n) activations, `dists`: the
    /// (B, C) distances, `asq`: the per-query `|A|²` terms, `labels`: the
    /// output) — the packed twin of
    /// [`LogHdModel::predict_prepared_into`]. Identical math to the
    /// allocating path; parity is pinned by the engine tests.
    pub fn predict_into(
        &self,
        enc: &Matrix,
        scratch: &mut QueryScratch,
        acts: &mut Matrix,
        dists: &mut Matrix,
        asq: &mut Vec<f32>,
        labels: &mut Vec<i32>,
    ) {
        self.activations_into(enc, scratch, acts);
        tensor::pairwise_sqdists_prepared_into(
            acts,
            &self.profiles_f32,
            &self.profile_sqnorms,
            &self.profiles_prep,
            asq,
            dists,
        );
        labels.clear();
        labels.extend((0..dists.rows()).map(|i| tensor::argmin(dists.row(i)) as i32));
    }

    /// Per-model normalizer for decode margins: the mean stored-profile
    /// squared norm, floored away from zero. Dividing the raw
    /// `runner-up − best` squared-distance gap by this constant puts
    /// margins from differently-scaled models (and the same model at
    /// different widths) on a comparable footing, so one calibrated
    /// threshold survives quantization-induced scale shifts.
    pub fn margin_scale(&self) -> f32 {
        let n = self.profile_sqnorms.len().max(1) as f32;
        (self.profile_sqnorms.iter().sum::<f32>() / n).max(1e-12)
    }

    /// [`Self::predict_into`] that additionally reports each row's
    /// normalized decode margin (runner-up minus best squared distance,
    /// divided by [`Self::margin_scale`]; lowest-index-wins tie
    /// discipline, so tied rows report margin 0). This is the cascade
    /// tier-1 primitive: the margin costs O(C) on top of the decode the
    /// call already did, and everything lands in caller-owned buffers.
    #[allow(clippy::too_many_arguments)]
    pub fn predict_margins_into(
        &self,
        enc: &Matrix,
        scratch: &mut QueryScratch,
        acts: &mut Matrix,
        dists: &mut Matrix,
        asq: &mut Vec<f32>,
        labels: &mut Vec<i32>,
        margins: &mut Vec<f32>,
    ) {
        self.predict_into(enc, scratch, acts, dists, asq, labels);
        crate::model::instances::distance_margins_into(dists, margins);
        let inv = 1.0 / self.margin_scale();
        for m in margins.iter_mut() {
            *m *= inv;
        }
    }

    /// Fused activation-space decode: (B, C) squared distances to the
    /// stored profiles, `|A|² − 2·A·Pᵀ + |P|²` with precomputed `|P|²`
    /// and the profile operand's GEMM form prepared at build.
    pub fn decode_dists(&self, enc: &Matrix) -> Matrix {
        self.decode_dists_scratch(enc, &mut QueryScratch::new())
    }

    /// [`Self::decode_dists`] through a caller-held [`QueryScratch`].
    pub fn decode_dists_scratch(&self, enc: &Matrix, scratch: &mut QueryScratch) -> Matrix {
        let a = self.activations_scratch(enc, scratch);
        tensor::pairwise_sqdists_prepared(
            &a,
            &self.profiles_f32,
            &self.profile_sqnorms,
            &self.profiles_prep,
        )
    }

    /// Predicted labels for encoded queries.
    pub fn predict(&self, enc: &Matrix) -> Vec<i32> {
        self.predict_scratch(enc, &mut QueryScratch::new())
    }

    /// [`Self::predict`] through a caller-held [`QueryScratch`].
    pub fn predict_scratch(&self, enc: &Matrix, scratch: &mut QueryScratch) -> Vec<i32> {
        let d = self.decode_dists_scratch(enc, scratch);
        (0..d.rows()).map(|i| tensor::argmin(d.row(i)) as i32).collect()
    }

    pub fn n_bundles(&self) -> usize {
        self.bundles.rows
    }

    /// Total stored payload bits (the fault-injection surface).
    pub fn memory_bits(&self) -> usize {
        self.bundles.packed.total_bits() + self.profiles.total_bits()
    }

    /// Dequantize the *current* packed state (bundles, profiles) into
    /// dense f32 matrices — the dense twin of whatever the stored words
    /// hold right now, faults included. Differential tests score this
    /// twin through the f32 pipeline and compare predictions against the
    /// packed kernels running on the very same corrupted words.
    pub fn dequantized_state(&self) -> (Matrix, Matrix) {
        (quant::dequantize(&self.bundles), self.profiles.dequantize())
    }
}

/// The packed model IS its own [`HdClassifier`] instance: the stored
/// bit-planes the trait enumerates are the very words inference runs on.
/// Plane order (bundles, profile columns 0..n-1, profile mean) is
/// contractual — see `crate::model` docs.
impl HdClassifier for QuantizedLogHdModel {
    fn kind(&self) -> &'static str {
        "loghd"
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn d(&self) -> usize {
        self.d
    }

    fn decode_activations(&self, enc: &Matrix) -> Matrix {
        let mut dists = self.decode_dists(enc);
        for v in dists.data_mut() {
            *v = -*v;
        }
        dists
    }

    fn predict(&self, enc: &Matrix) -> Vec<i32> {
        QuantizedLogHdModel::predict(self, enc)
    }

    fn fault_surface(&self) -> FaultSurface {
        let mut planes = vec![FaultPlane::with_shape(
            "bundles",
            self.bundles.rows,
            self.bundles.cols,
            self.bundles.packed.bits(),
        )];
        for (j, col) in self.profiles.cols.iter().enumerate() {
            planes.push(FaultPlane::with_shape(
                format!("profiles[{j}]"),
                col.rows,
                col.cols,
                col.packed.bits(),
            ));
        }
        planes.push(FaultPlane::with_shape(
            "profile_mean",
            self.profiles.mean.rows,
            self.profiles.mean.cols,
            self.profiles.mean.packed.bits(),
        ));
        FaultSurface::new(planes)
    }

    fn apply_flips(&mut self, plane: usize, mask: &[(usize, u32)]) {
        let n = self.profiles.cols.len();
        let target = if plane == 0 {
            &mut self.bundles.packed
        } else if plane <= n {
            &mut self.profiles.cols[plane - 1].packed
        } else {
            &mut self.profiles.mean.packed
        };
        crate::faults::apply_value_mask_packed(target, mask);
    }

    fn apply_fault(&mut self, plane: usize, fault: &crate::faults::PlaneFault) {
        let n = self.profiles.cols.len();
        let (target, cols) = if plane == 0 {
            (&mut self.bundles.packed, self.bundles.cols)
        } else if plane <= n {
            let col = &mut self.profiles.cols[plane - 1];
            (&mut col.packed, col.cols)
        } else {
            (&mut self.profiles.mean.packed, self.profiles.mean.cols)
        };
        quant::apply_analog_packed(target, cols, fault);
    }

    fn refresh(&mut self) {
        QuantizedLogHdModel::refresh(self);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::loghd::model::{TrainOptions, TrainedStack};

    fn small_stack() -> (data::Dataset, TrainedStack) {
        let ds = data::generate_scaled(data::spec("page").unwrap(), 500, 200);
        let opts = TrainOptions { epochs: 3, conv_epochs: 1, extra_bundles: 2, ..Default::default() };
        let stack = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 512, 0xE5C0DE, &opts).unwrap();
        (ds, stack)
    }

    #[test]
    fn packed_models_predict_reasonably() {
        let (ds, stack) = small_stack();
        let enc = stack.encoder.encode(&ds.x_test);
        let f32_acc = {
            let pred = stack.loghd.predict(&enc);
            crate::eval::accuracy(&pred, &ds.y_test)
        };
        for precision in [Precision::B8, Precision::B1] {
            let qm = QuantizedLogHdModel::from_model(&stack.loghd, precision);
            let acc = crate::eval::accuracy(&qm.predict(&enc), &ds.y_test);
            let floor = if precision == Precision::B8 { f32_acc - 0.08 } else { 0.3 };
            assert!(acc > floor, "{precision:?}: packed acc {acc} (f32 {f32_acc})");
        }
    }

    #[test]
    fn b8_activations_close_to_f32_of_quantized_operands() {
        let (ds, stack) = small_stack();
        let enc = stack.encoder.encode(&ds.x_test.rows_slice(0, 12));
        let qm = QuantizedLogHdModel::from_model(&stack.loghd, Precision::B8);
        let got = qm.activations(&enc);
        let enc_q = quant::quantize_roundtrip(&enc, Precision::B8);
        let bundles_q = quant::dequantize(&qm.bundles);
        let want = crate::hd::similarity::activations(&enc_q, &bundles_q);
        for i in 0..got.rows() {
            for j in 0..got.cols() {
                assert!(
                    (got.at(i, j) - want.at(i, j)).abs() < 1e-3,
                    "({i},{j}): {} vs {}",
                    got.at(i, j),
                    want.at(i, j)
                );
            }
        }
    }

    #[test]
    fn scratch_paths_match_plain_and_survive_reuse() {
        let (ds, stack) = small_stack();
        let enc = stack.encoder.encode(&ds.x_test.rows_slice(0, 16));
        for precision in [Precision::B8, Precision::B1] {
            let qm = QuantizedLogHdModel::from_model(&stack.loghd, precision);
            let mut scratch = QueryScratch::new();
            let plain = qm.predict(&enc);
            assert_eq!(plain, qm.predict_scratch(&enc, &mut scratch), "{precision:?}");
            // reuse across batches of different sizes
            let small = stack.encoder.encode(&ds.x_test.rows_slice(16, 21));
            assert_eq!(qm.predict(&small), qm.predict_scratch(&small, &mut scratch));
            assert_eq!(plain, qm.predict_scratch(&enc, &mut scratch), "{precision:?} reuse");
        }
    }

    #[test]
    fn margin_variant_matches_predict_and_reports_normalized_gaps() {
        let (ds, stack) = small_stack();
        let enc = stack.encoder.encode(&ds.x_test.rows_slice(0, 32));
        for precision in [Precision::B8, Precision::B1] {
            let qm = QuantizedLogHdModel::from_model(&stack.loghd, precision);
            let mut scratch = QueryScratch::new();
            let (mut acts, mut dists) = (Matrix::zeros(0, 0), Matrix::zeros(0, 0));
            let (mut asq, mut labels, mut margins) = (Vec::new(), Vec::new(), Vec::new());
            qm.predict_margins_into(
                &enc,
                &mut scratch,
                &mut acts,
                &mut dists,
                &mut asq,
                &mut labels,
                &mut margins,
            );
            assert_eq!(labels, qm.predict(&enc), "{precision:?}: labels diverge");
            assert_eq!(margins.len(), enc.rows());
            assert!(margins.iter().all(|m| *m >= 0.0), "{precision:?}: negative margin");
            assert!(qm.margin_scale() > 0.0);
            // Hand-check one row against the normalized runner-up gap.
            let row = dists.row(0);
            let best = tensor::argmin(row);
            let runner = row
                .iter()
                .enumerate()
                .filter(|(j, _)| *j != best)
                .map(|(_, v)| *v)
                .fold(f32::INFINITY, f32::min);
            let want = (runner - row[best]) / qm.margin_scale();
            assert!((margins[0] - want).abs() <= 1e-6 * want.abs().max(1.0));
        }
    }

    #[test]
    fn fault_injection_flips_packed_state_and_refreshes_views() {
        let (_, stack) = small_stack();
        let mut qm = QuantizedLogHdModel::from_model(&stack.loghd, Precision::B1);
        let before = qm.bundles.packed.clone();
        let mut rng = SplitMix64::new(5);
        let flips = qm.inject_value_faults(0.5, &mut rng);
        assert!(flips > 0);
        assert_ne!(qm.bundles.packed, before, "bundle words unchanged");
        // the kernel view must reflect the corrupted words, not the clean model
        let fresh_view = qm.bundles.to_bit_matrix();
        match &qm.kernel {
            BundleKernel::Bits(view) => assert_eq!(view, &fresh_view),
            BundleKernel::I16(_) => unreachable!(),
        }
    }

    #[test]
    fn zero_flip_probability_is_identity() {
        let (ds, stack) = small_stack();
        let enc = stack.encoder.encode(&ds.x_test.rows_slice(0, 32));
        let mut qm = QuantizedLogHdModel::from_model(&stack.loghd, Precision::B8);
        let clean = qm.predict(&enc);
        let mut rng = SplitMix64::new(9);
        assert_eq!(qm.inject_value_faults(0.0, &mut rng), 0);
        assert_eq!(qm.predict(&enc), clean);
    }

    #[test]
    fn memory_accounting_counts_every_stored_bit() {
        let (_, stack) = small_stack();
        let qm = QuantizedLogHdModel::from_model(&stack.loghd, Precision::B8);
        let n = stack.loghd.n_bundles();
        let (c, d) = (stack.loghd.classes, stack.loghd.d);
        assert_eq!(qm.memory_bits(), 8 * (n * d + c * n + n));
        assert_eq!(qm.memory_bits(), 8 * crate::model::loghd_stored_values(n, d, c));
        assert_eq!(qm.n_bundles(), n);
    }

    #[test]
    fn trait_surface_matches_packed_accounting_and_order() {
        let (_, stack) = small_stack();
        for precision in [Precision::B8, Precision::B1] {
            let qm = QuantizedLogHdModel::from_model(&stack.loghd, precision);
            let surface = qm.fault_surface();
            // bundles, n profile columns, mean — in that order
            let n = qm.n_bundles();
            assert_eq!(surface.planes.len(), n + 2);
            assert_eq!(surface.planes[0].label, "bundles");
            assert_eq!(surface.planes[0].values(), n * qm.d);
            assert_eq!((surface.planes[0].rows, surface.planes[0].cols), (n, qm.d));
            assert_eq!(surface.planes[n + 1].label, "profile_mean");
            assert_eq!(surface.planes[n + 1].values(), n);
            assert_eq!(surface.total_bits(), qm.memory_bits());
            assert_eq!(HdClassifier::stored_bits(&qm), qm.memory_bits());
        }
    }
}

//! Native model persistence: a directory of LHT tensors + a JSON manifest,
//! the same on-disk shapes the Python AOT path emits, so a Rust-trained
//! stack and a Python-trained bundle are interchangeable for the native
//! engine.
//!
//! Three native kinds share the layout (`model.json` `kind` field):
//! `native-loghd` (bundles + profiles + codebook), `native-conventional`
//! (the O(C·D) prototype baseline), and `native-decohd` (the decomposed
//! basis + coefficients classifier). [`load_any`] dispatches on the kind
//! through the [`crate::model::zoo`] registry — and falls back to the
//! Python AOT `manifest.json` layout — which is what lets the serving
//! registry host a mixed fleet of artifacts behind one wire protocol
//! and lets a new family register its loader in exactly one place.

use std::path::Path;

use anyhow::{Context, Result};

use crate::baselines::conventional::ConventionalModel;
use crate::baselines::decohd::DecoHdModel;
use crate::encoder::Encoder;
use crate::loghd::codebook::Codebook;
use crate::loghd::model::LogHdModel;
use crate::runtime::artifact::{read_lht, write_lht_f32};
use crate::tensor::Matrix;
use crate::util::json::{self, Value};

/// Write the shared encoder tensors (projection, bias, centering mean).
fn save_encoder(dir: &Path, encoder: &Encoder) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let w = encoder.w();
    write_lht_f32(&dir.join("w.lht"), &[w.rows(), w.cols()], w.data())?;
    write_lht_f32(&dir.join("b.lht"), &[encoder.b.len()], &encoder.b)?;
    write_lht_f32(&dir.join("mu.lht"), &[encoder.mu.len()], &encoder.mu)?;
    Ok(())
}

/// Read the shared encoder tensors written by [`save_encoder`].
fn load_encoder(dir: &Path) -> Result<Encoder> {
    let w = read_lht(&dir.join("w.lht"))?.to_matrix()?;
    let b = read_lht(&dir.join("b.lht"))?.as_f32()?.to_vec();
    let mu = read_lht(&dir.join("mu.lht"))?.as_f32()?.to_vec();
    Ok(Encoder::from_parts(w, b, mu))
}

/// Save encoder + LogHD model into `dir`.
pub fn save(dir: &Path, encoder: &Encoder, model: &LogHdModel) -> Result<()> {
    save_encoder(dir, encoder)?;
    write_lht_f32(
        &dir.join("bundles.lht"),
        &[model.bundles.rows(), model.bundles.cols()],
        model.bundles.data(),
    )?;
    write_lht_f32(
        &dir.join("profiles.lht"),
        &[model.profiles.rows(), model.profiles.cols()],
        model.profiles.data(),
    )?;
    let book_f32: Vec<f32> = model.book.to_i32().iter().map(|v| *v as f32).collect();
    write_lht_f32(&dir.join("codebook.lht"), &[model.classes, model.book.n()], &book_f32)?;
    let manifest = json::obj(vec![
        ("format", json::num(1.0)),
        ("kind", json::s("native-loghd")),
        ("classes", json::num(model.classes as f64)),
        ("d", json::num(model.d as f64)),
        ("k", json::num(model.book.k as f64)),
        ("n", json::num(model.n_bundles() as f64)),
        ("features", json::num(encoder.features() as f64)),
    ]);
    std::fs::write(dir.join("model.json"), json::to_string_pretty(&manifest))?;
    Ok(())
}

/// Load a model saved by [`save`].
pub fn load(dir: &Path) -> Result<(Encoder, LogHdModel)> {
    let text = std::fs::read_to_string(dir.join("model.json"))
        .with_context(|| format!("reading {}/model.json", dir.display()))?;
    let v = json::parse(&text).map_err(|e| anyhow::anyhow!("model.json: {e}"))?;
    let get = |k: &str| -> Result<usize> {
        v.get(k).and_then(Value::as_usize).with_context(|| format!("model.json missing {k}"))
    };
    let classes = get("classes")?;
    let d = get("d")?;
    let k = get("k")? as u32;
    let n = get("n")?;

    let encoder = load_encoder(dir)?;
    let bundles = read_lht(&dir.join("bundles.lht"))?.to_matrix()?;
    let profiles = read_lht(&dir.join("profiles.lht"))?.to_matrix()?;
    let book_vals: Vec<i32> =
        read_lht(&dir.join("codebook.lht"))?.as_f32()?.iter().map(|v| *v as i32).collect();
    let book = Codebook::from_i32(k, n, &book_vals)?;
    anyhow::ensure!(bundles.rows() == n, "bundle count mismatch");
    anyhow::ensure!(profiles.rows() == classes, "profile count mismatch");
    anyhow::ensure!(bundles.cols() == d, "bundle width mismatch");
    let model = LogHdModel { classes, d, book, bundles, profiles };
    Ok((encoder, model))
}

/// Save encoder + conventional baseline (prototype matrix) into `dir`.
pub fn save_conventional(dir: &Path, encoder: &Encoder, model: &ConventionalModel) -> Result<()> {
    save_encoder(dir, encoder)?;
    let h = &model.prototypes;
    write_lht_f32(&dir.join("prototypes.lht"), &[h.rows(), h.cols()], h.data())?;
    let manifest = json::obj(vec![
        ("format", json::num(1.0)),
        ("kind", json::s("native-conventional")),
        ("classes", json::num(model.classes() as f64)),
        ("d", json::num(model.d() as f64)),
        ("features", json::num(encoder.features() as f64)),
    ]);
    std::fs::write(dir.join("model.json"), json::to_string_pretty(&manifest))?;
    Ok(())
}

/// Load a baseline saved by [`save_conventional`].
pub fn load_conventional(dir: &Path) -> Result<(Encoder, ConventionalModel)> {
    let encoder = load_encoder(dir)?;
    let prototypes = read_lht(&dir.join("prototypes.lht"))?.to_matrix()?;
    Ok((encoder, ConventionalModel::new(prototypes)))
}

/// Save encoder + DecoHD model (basis + coefficients) into `dir`.
pub fn save_decohd(dir: &Path, encoder: &Encoder, model: &DecoHdModel) -> Result<()> {
    save_encoder(dir, encoder)?;
    let basis = &model.basis;
    write_lht_f32(&dir.join("basis.lht"), &[basis.rows(), basis.cols()], basis.data())?;
    let coeffs = &model.coeffs;
    write_lht_f32(&dir.join("coeffs.lht"), &[coeffs.rows(), coeffs.cols()], coeffs.data())?;
    let manifest = json::obj(vec![
        ("format", json::num(1.0)),
        ("kind", json::s("native-decohd")),
        ("classes", json::num(model.classes() as f64)),
        ("d", json::num(model.d() as f64)),
        ("rank", json::num(model.rank() as f64)),
        ("features", json::num(encoder.features() as f64)),
    ]);
    std::fs::write(dir.join("model.json"), json::to_string_pretty(&manifest))?;
    Ok(())
}

/// Load a model saved by [`save_decohd`].
pub fn load_decohd(dir: &Path) -> Result<(Encoder, DecoHdModel)> {
    let encoder = load_encoder(dir)?;
    let basis = read_lht(&dir.join("basis.lht"))?.to_matrix()?;
    let coeffs = read_lht(&dir.join("coeffs.lht"))?.to_matrix()?;
    anyhow::ensure!(
        basis.rows() == coeffs.cols(),
        "decohd rank mismatch: basis has {} rows, coeffs {} cols",
        basis.rows(),
        coeffs.cols()
    );
    Ok((encoder, DecoHdModel { basis, coeffs }))
}

/// A native artifact of any supported kind, as loaded by [`load_any`].
pub enum LoadedModel {
    LogHd(Encoder, LogHdModel),
    Conventional(Encoder, ConventionalModel),
    DecoHd(Encoder, DecoHdModel),
}

impl LoadedModel {
    /// Short family tag for logs and the `models` admin verb — matches
    /// the zoo registry's family keys and [`HdClassifier::kind`].
    ///
    /// [`HdClassifier::kind`]: crate::model::HdClassifier::kind
    pub fn kind(&self) -> &'static str {
        match self {
            LoadedModel::LogHd(..) => "loghd",
            LoadedModel::Conventional(..) => "conventional",
            LoadedModel::DecoHd(..) => "decohd",
        }
    }

    /// Feature width the artifact's encoder admits.
    pub fn features(&self) -> usize {
        self.encoder().features()
    }

    /// The artifact's encoder.
    pub fn encoder(&self) -> &Encoder {
        match self {
            LoadedModel::LogHd(e, _)
            | LoadedModel::Conventional(e, _)
            | LoadedModel::DecoHd(e, _) => e,
        }
    }

    /// Build the loaded classifier's [`HdClassifier`] instance at a
    /// serving precision — the same instance layer the sweep engine
    /// evaluates (see `model::instances`), so `loghd inspect`, fault
    /// tooling, and serving all report one accounting.
    ///
    /// [`HdClassifier`]: crate::model::HdClassifier
    pub fn instance(
        &self,
        precision: crate::quant::Precision,
    ) -> Box<dyn crate::model::HdClassifier> {
        use crate::model::instances;
        match self {
            LoadedModel::LogHd(_, m) => instances::loghd(m, precision),
            LoadedModel::Conventional(_, m) => instances::conventional(&m.prototypes, precision),
            LoadedModel::DecoHd(_, m) => instances::decohd(m, precision),
        }
    }
}

/// Load any artifact directory the registry can serve: a native model
/// or a Python AOT bundle (served through the native engine). The kind
/// probe is [`crate::runtime::artifact::ModelCard::load`] and the
/// per-kind loader table is [`crate::model::zoo`] — one registry entry
/// per family — so the serving admission check, this loader, and
/// `loghd inspect` can never disagree about what an artifact is.
pub fn load_any(dir: &Path) -> Result<LoadedModel> {
    crate::model::zoo::load(dir)
}

/// Load a *Python-trained* artifact bundle (aot.py manifest layout) into a
/// native engine pair — proves the two worlds interoperate.
pub fn load_from_aot_bundle(dir: &Path) -> Result<(Encoder, LogHdModel)> {
    let manifest = crate::runtime::artifact::Manifest::load(dir)?;
    let w = manifest.tensor("w")?.to_matrix()?;
    let b = manifest.tensor("b")?.as_f32()?.to_vec();
    let mu = manifest.tensor("mu")?.as_f32()?.to_vec();
    let encoder = Encoder::from_parts(w, b, mu);
    let bundles = manifest.tensor("bundles")?.to_matrix()?;
    let profiles = manifest.tensor("profiles")?.to_matrix()?;
    let book_vals = manifest.tensor("codebook")?.as_i32()?.to_vec();
    let book = Codebook::from_i32(manifest.k, manifest.n, &book_vals)?;
    let model = LogHdModel {
        classes: manifest.classes,
        d: manifest.d,
        book,
        bundles,
        profiles,
    };
    Ok((encoder, model))
}

/// Load (matrix-shaped) test data from an aot bundle.
pub fn load_test_data(dir: &Path) -> Result<(Matrix, Vec<i32>)> {
    let manifest = crate::runtime::artifact::Manifest::load(dir)?;
    let x = manifest.tensor("x_test")?.to_matrix()?;
    let y = manifest.tensor("y_test")?.as_i32()?.to_vec();
    Ok((x, y))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::loghd::model::{TrainOptions, TrainedStack};

    #[test]
    fn save_load_roundtrip() {
        let ds = data::generate_scaled(data::spec("page").unwrap(), 300, 60);
        let opts = TrainOptions { epochs: 1, conv_epochs: 0, extra_bundles: 1, ..Default::default() };
        let st = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 128, 3, &opts).unwrap();
        let dir = std::env::temp_dir().join("loghd_persist_test");
        let _ = std::fs::remove_dir_all(&dir);
        save(&dir, &st.encoder, &st.loghd).unwrap();
        let (enc2, model2) = load(&dir).unwrap();
        assert_eq!(enc2.w().data(), st.encoder.w().data());
        assert_eq!(enc2.mu, st.encoder.mu);
        assert_eq!(model2.bundles.data(), st.loghd.bundles.data());
        assert_eq!(model2.book, st.loghd.book);
        // predictions identical
        let e = st.encoder.encode(&ds.x_test);
        assert_eq!(st.loghd.predict(&e), model2.predict(&enc2.encode(&ds.x_test)));
        // load_any dispatches to the same model
        match load_any(&dir).unwrap() {
            LoadedModel::LogHd(_, m) => assert_eq!(m.bundles.data(), st.loghd.bundles.data()),
            _ => panic!("wrong kind"),
        }
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn conventional_roundtrip_and_kind_dispatch() {
        let ds = data::generate_scaled(data::spec("page").unwrap(), 300, 60);
        let opts = TrainOptions { epochs: 1, conv_epochs: 1, ..Default::default() };
        let st = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 128, 3, &opts).unwrap();
        let conv = ConventionalModel::new(st.prototypes.clone());
        let dir = std::env::temp_dir().join("loghd_persist_conv_test");
        let _ = std::fs::remove_dir_all(&dir);
        save_conventional(&dir, &st.encoder, &conv).unwrap();
        let loaded = load_any(&dir).unwrap();
        assert_eq!(loaded.kind(), "conventional");
        assert_eq!(loaded.features(), 10);
        match loaded {
            LoadedModel::Conventional(enc2, conv2) => {
                let e = st.encoder.encode(&ds.x_test);
                assert_eq!(conv.predict(&e), conv2.predict(&enc2.encode(&ds.x_test)));
            }
            _ => panic!("wrong kind"),
        }
        let _ = std::fs::remove_dir_all(&dir);
        assert!(load_any(&dir).is_err(), "missing dir must error");
    }

    #[test]
    fn decohd_roundtrip_and_kind_dispatch() {
        let ds = data::generate_scaled(data::spec("page").unwrap(), 300, 60);
        let opts = TrainOptions { epochs: 1, conv_epochs: 1, ..Default::default() };
        let st = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 128, 3, &opts).unwrap();
        let deco =
            crate::baselines::DecoHdModel::from_prototypes(&st.prototypes, 3).unwrap();
        let dir = std::env::temp_dir().join("loghd_persist_decohd_test");
        let _ = std::fs::remove_dir_all(&dir);
        save_decohd(&dir, &st.encoder, &deco).unwrap();
        let loaded = load_any(&dir).unwrap();
        assert_eq!(loaded.kind(), "decohd");
        assert_eq!(loaded.features(), 10);
        match loaded {
            LoadedModel::DecoHd(enc2, deco2) => {
                assert_eq!(deco2.rank(), 3);
                let e = st.encoder.encode(&ds.x_test);
                assert_eq!(deco.predict(&e), deco2.predict(&enc2.encode(&ds.x_test)));
            }
            _ => panic!("wrong kind"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

//! Capacity-aware k-ary codebook (paper §III-C, Eq. 2/3) — the exact twin
//! of `python/compile/codebook.py` (same SplitMix64 stream discipline: one
//! tie-break xi per candidate per round, candidates in lexicographic
//! order; sampled pool beyond `MAX_ENUM`).

use anyhow::{bail, Result};

use crate::util::rng::SplitMix64;

pub const EPS_TIEBREAK: f64 = 1e-6;
pub const MAX_ENUM: u64 = 8192;
pub const POOL_SIZE: usize = 4096;

/// A codebook: C unique length-n k-ary codes.
#[derive(Debug, Clone, PartialEq)]
pub struct Codebook {
    pub k: u32,
    pub rows: Vec<Vec<u8>>, // (C, n)
}

/// Feasibility limit n >= ceil(log_k C).
pub fn min_bundles(classes: usize, k: u32) -> usize {
    let mut n = 1usize;
    let mut cap = k as u128;
    while cap < classes as u128 {
        n += 1;
        cap *= k as u128;
    }
    n
}

/// Symbol weight g(s) = s/(k-1).
#[inline]
pub fn g(s: u8, k: u32) -> f64 {
    s as f64 / (k - 1) as f64
}

/// Capacity surrogate U(w) = w^alpha.
#[inline]
pub fn capacity(w: f64, alpha: f64) -> f64 {
    w.powf(alpha)
}

/// Refinement target t(s) = 2 s/(k-1) - 1 (paper Eq. 8).
#[inline]
pub fn target(s: u8, k: u32) -> f32 {
    (2.0 * g(s, k) - 1.0) as f32
}

/// All k^n codes in lexicographic order.
fn enumerate_codes(k: u32, n: usize) -> Vec<Vec<u8>> {
    let total = (k as u64).pow(n as u32) as usize;
    let mut out = Vec::with_capacity(total);
    for idx in 0..total {
        let mut code = vec![0u8; n];
        let mut rem = idx as u64;
        for j in (0..n).rev() {
            code[j] = (rem % k as u64) as u8;
            rem /= k as u64;
        }
        out.push(code);
    }
    out
}

/// Greedy minimax-load codebook, deterministic in `seed`.
pub fn build(classes: usize, k: u32, n: usize, alpha: f64, seed: u64) -> Result<Codebook> {
    if k < 2 {
        bail!("alphabet size k must be >= 2, got {k}");
    }
    let kn = (k as u128).checked_pow(n as u32).unwrap_or(u128::MAX);
    if kn < classes as u128 {
        bail!("k^n = {k}^{n} < C = {classes}: infeasible codebook");
    }
    let mut rng = SplitMix64::new(seed);
    let full = kn <= MAX_ENUM as u128;
    let candidates: Vec<Vec<u8>> = if full {
        enumerate_codes(k, n)
    } else {
        // Sampled pool: POOL_SIZE codes, n symbols each, u64 % k row-major
        // (duplicates possible; uniqueness enforced by the `used` sweep).
        (0..POOL_SIZE)
            .map(|_| (0..n).map(|_| (rng.next_u64() % k as u64) as u8).collect())
            .collect()
    };
    let cand_cap: Vec<Vec<f64>> = candidates
        .iter()
        .map(|code| code.iter().map(|&s| capacity(g(s, k), alpha)).collect())
        .collect();

    let mut used = vec![false; candidates.len()];
    let mut loads = vec![0.0f64; n];
    let mut rows = Vec::with_capacity(classes);
    for _ in 0..classes {
        let mut best: Option<(f64, usize)> = None;
        for (q, cap) in cand_cap.iter().enumerate() {
            let xi = rng.uniform();
            if used[q] {
                continue;
            }
            let mut worst = f64::NEG_INFINITY;
            for (j, c) in cap.iter().enumerate() {
                let v = loads[j] + c;
                if v > worst {
                    worst = v;
                }
            }
            let score = worst + EPS_TIEBREAK * xi;
            if best.map_or(true, |(bs, _)| score < bs) {
                best = Some((score, q));
            }
        }
        let (_, q) = best.expect("candidate pool exhausted");
        for (l, c) in loads.iter_mut().zip(&cand_cap[q]) {
            *l += c;
        }
        let chosen = candidates[q].clone();
        used[q] = true;
        if !full {
            for (u, cand) in used.iter_mut().zip(&candidates) {
                if cand == &chosen {
                    *u = true;
                }
            }
        }
        rows.push(chosen);
    }
    Ok(Codebook { k, rows })
}

impl Codebook {
    pub fn classes(&self) -> usize {
        self.rows.len()
    }

    pub fn n(&self) -> usize {
        self.rows.first().map_or(0, Vec::len)
    }

    /// Per-bundle cumulative load L_j = sum_c U(g(B_cj)).
    pub fn bundle_loads(&self, alpha: f64) -> Vec<f64> {
        let n = self.n();
        let mut loads = vec![0.0f64; n];
        for row in &self.rows {
            for (l, &s) in loads.iter_mut().zip(row) {
                *l += capacity(g(s, self.k), alpha);
            }
        }
        loads
    }

    /// Target activation matrix (C, n): tau_{c,j} = t(B_{c,j}).
    pub fn targets(&self) -> Vec<Vec<f32>> {
        self.rows.iter().map(|row| row.iter().map(|&s| target(s, self.k)).collect()).collect()
    }

    /// Append one codeword for a newly observed class — the class-axis
    /// payoff of the paper's design: a new class costs one length-n
    /// code (plus one profile row), not a D-wide prototype. Continues
    /// the greedy minimax-load criterion of [`build`] from the current
    /// cumulative [`Self::bundle_loads`], with the same stream
    /// discipline (one tie-break xi per candidate, drawn before the
    /// used-skip), so the choice is deterministic in `seed`. Errors
    /// when the k^n code space (or the sampled pool) is exhausted.
    pub fn extend_one(&mut self, alpha: f64, seed: u64) -> Result<()> {
        let n = self.n();
        if n == 0 {
            bail!("cannot extend an empty codebook");
        }
        let k = self.k;
        let kn = (k as u128).checked_pow(n as u32).unwrap_or(u128::MAX);
        if kn <= self.rows.len() as u128 {
            bail!("k^n = {k}^{n} code space exhausted at {} classes", self.rows.len());
        }
        let mut rng = SplitMix64::new(seed);
        let candidates: Vec<Vec<u8>> = if kn <= MAX_ENUM as u128 {
            enumerate_codes(k, n)
        } else {
            (0..POOL_SIZE)
                .map(|_| (0..n).map(|_| (rng.next_u64() % k as u64) as u8).collect())
                .collect()
        };
        let existing: std::collections::HashSet<&Vec<u8>> = self.rows.iter().collect();
        let loads = self.bundle_loads(alpha);
        let mut best: Option<(f64, usize)> = None;
        for (q, code) in candidates.iter().enumerate() {
            let xi = rng.uniform();
            if existing.contains(code) {
                continue;
            }
            let mut worst = f64::NEG_INFINITY;
            for (j, &s) in code.iter().enumerate() {
                let v = loads[j] + capacity(g(s, k), alpha);
                if v > worst {
                    worst = v;
                }
            }
            let score = worst + EPS_TIEBREAK * xi;
            if best.map_or(true, |(bs, _)| score < bs) {
                best = Some((score, q));
            }
        }
        let Some((_, q)) = best else {
            bail!("candidate pool exhausted: no unused code among {} samples", candidates.len());
        };
        self.rows.push(candidates[q].clone());
        Ok(())
    }

    /// Flatten to i32 row-major (artifact interchange form).
    pub fn to_i32(&self) -> Vec<i32> {
        self.rows.iter().flatten().map(|&s| s as i32).collect()
    }

    /// Rebuild from i32 row-major.
    pub fn from_i32(k: u32, n: usize, data: &[i32]) -> Result<Self> {
        if n == 0 || data.len() % n != 0 {
            bail!("codebook data length {} not divisible by n={n}", data.len());
        }
        let rows = data
            .chunks(n)
            .map(|chunk| {
                chunk
                    .iter()
                    .map(|&v| {
                        if v < 0 || v as u32 >= k {
                            bail!("symbol {v} out of range for k={k}");
                        }
                        Ok(v as u8)
                    })
                    .collect::<Result<Vec<u8>>>()
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Codebook { k, rows })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn min_bundles_matches_paper() {
        assert_eq!(min_bundles(26, 2), 5); // ceil(log2 26)
        assert_eq!(min_bundles(26, 3), 3); // paper: k=3, C=26 -> 3
        assert_eq!(min_bundles(5, 2), 3);
        assert_eq!(min_bundles(2, 2), 1);
        assert_eq!(min_bundles(1, 2), 1);
    }

    #[test]
    fn g_and_targets() {
        assert_eq!(g(0, 3), 0.0);
        assert_eq!(g(1, 3), 0.5);
        assert_eq!(g(2, 3), 1.0);
        assert_eq!(target(0, 3), -1.0);
        assert_eq!(target(1, 3), 0.0);
        assert_eq!(target(2, 3), 1.0);
    }

    #[test]
    fn infeasible_errors() {
        assert!(build(10, 2, 3, 1.0, 0).is_err());
        assert!(build(4, 1, 4, 1.0, 0).is_err());
    }

    #[test]
    fn rows_unique_and_in_range() {
        for (c, k, n, seed) in [(26, 2, 5, 0xC0DE), (26, 3, 4, 7), (40, 4, 4, 9), (5, 2, 4, 3)] {
            let cb = build(c, k, n, 1.0, seed).unwrap();
            assert_eq!(cb.classes(), c);
            assert_eq!(cb.n(), n);
            let set: HashSet<&Vec<u8>> = cb.rows.iter().collect();
            assert_eq!(set.len(), c, "duplicate codes for C={c} k={k}");
            assert!(cb.rows.iter().flatten().all(|&s| (s as u32) < k));
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let a = build(26, 2, 5, 1.0, 99).unwrap();
        let b = build(26, 2, 5, 1.0, 99).unwrap();
        assert_eq!(a, b);
        let c = build(26, 2, 5, 1.0, 100).unwrap();
        assert_ne!(a, c);
    }

    #[test]
    fn greedy_flattens_loads_vs_lexicographic() {
        let cb = build(20, 3, 5, 1.0, 1).unwrap();
        let lex: Vec<Vec<u8>> = enumerate_codes(3, 5).into_iter().take(20).collect();
        let lex_cb = Codebook { k: 3, rows: lex };
        let worst_greedy =
            cb.bundle_loads(1.0).into_iter().fold(f64::NEG_INFINITY, f64::max);
        let worst_lex =
            lex_cb.bundle_loads(1.0).into_iter().fold(f64::NEG_INFINITY, f64::max);
        assert!(worst_greedy <= worst_lex + 1e-9);
    }

    #[test]
    fn sampled_pool_path() {
        // 4^8 = 65536 > MAX_ENUM
        let cb = build(50, 4, 8, 1.0, 3).unwrap();
        assert_eq!(cb.classes(), 50);
        let set: HashSet<&Vec<u8>> = cb.rows.iter().collect();
        assert_eq!(set.len(), 50);
    }

    #[test]
    fn extend_one_adds_a_fresh_code_deterministically() {
        let base = build(5, 2, 4, 1.0, 3).unwrap();
        let mut a = base.clone();
        let mut b = base.clone();
        a.extend_one(1.0, 11).unwrap();
        b.extend_one(1.0, 11).unwrap();
        assert_eq!(a, b, "extension must be deterministic in seed");
        assert_eq!(a.classes(), 6);
        assert_eq!(a.n(), 4);
        let set: HashSet<&Vec<u8>> = a.rows.iter().collect();
        assert_eq!(set.len(), 6, "extended code must be unused");
        // Exhaustion is an error, not a panic: k=2, n=1 holds 2 codes.
        let mut tiny = build(2, 2, 1, 1.0, 0).unwrap();
        assert!(tiny.extend_one(1.0, 0).is_err());
    }

    #[test]
    fn i32_roundtrip() {
        let cb = build(8, 3, 3, 1.0, 5).unwrap();
        let flat = cb.to_i32();
        let back = Codebook::from_i32(3, 3, &flat).unwrap();
        assert_eq!(cb, back);
        assert!(Codebook::from_i32(2, 3, &[0, 1, 2]).is_err()); // symbol 2 with k=2
    }
}

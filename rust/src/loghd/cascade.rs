//! Offline calibration for the precision-cascade serving tier
//! (`coordinator::worker::CascadeEngine`).
//!
//! The cascade answers a row from the packed b1 prefilter whenever its
//! normalized decode margin clears a threshold, and escalates the rest
//! to the exact tier. Because escalated rows are answered by the exact
//! path, cascade-vs-exact disagreement can only come from *answered*
//! (tier-1) rows — so for a labeled-free calibration set the agreement
//! at threshold `t` is
//!
//! ```text
//! agreement(t) = 1 − |{i : margin_i ≥ t  ∧  b1_i ≠ exact_i}| / N
//! ```
//!
//! which is monotone non-decreasing in `t`. [`calibrate`] fits the
//! smallest threshold whose *bootstrap lower confidence bound* on
//! agreement meets the target fidelity (point estimates alone overfit
//! the calibration split; the CI guard is what makes the bound carry to
//! held-out traffic), reports the escalation rate that buys, and
//! [`write_threshold`] persists the result into the artifact's
//! `model.json` — where `runtime::artifact::ModelCard` reads it and the
//! serving registry enforces its presence at `--cascade` admission.
//!
//! The exact reference here is the dense f32 decode — the strictest
//! tier the cascade can escalate to; a b8 exact tier only tightens the
//! gap. Re-training an artifact rewrites `model.json` without the
//! `cascade_*` fields, which is intentional: a new model invalidates
//! the old calibration and must be re-calibrated before cascade serving.

use std::path::Path;

use anyhow::{Context, Result};

use crate::encoder::Encoder;
use crate::eval::percentile;
use crate::loghd::model::{DecodePrep, LogHdModel};
use crate::loghd::qmodel::{QuantizedLogHdModel, QueryScratch};
use crate::quant::Precision;
use crate::tensor::Matrix;
use crate::util::json::{self, Value};
use crate::util::rng::SplitMix64;

/// Default fidelity target: the cascade must agree with the exact path
/// on at least this fraction of traffic (ISSUE/EXPERIMENTS acceptance).
pub const DEFAULT_TARGET: f64 = 0.995;

/// Bootstrap resamples behind the confidence interval.
const BOOTSTRAP_RESAMPLES: usize = 200;

/// A fitted cascade operating point plus its calibration evidence.
#[derive(Debug, Clone)]
pub struct Calibration {
    /// Normalized-margin threshold the gate compares against.
    pub threshold: f32,
    /// Point-estimate agreement with the exact path on the calibration set.
    pub agreement: f64,
    /// Bootstrap 95% CI on the agreement (2.5th / 97.5th percentiles).
    pub agreement_ci: (f64, f64),
    /// Fraction of calibration rows the threshold escalates.
    pub escalation_rate: f64,
    /// Calibration rows.
    pub rows: usize,
    /// The fidelity target the fit was run against.
    pub target: f64,
}

/// Per-row calibration evidence: normalized b1 margin + whether the b1
/// label matched the exact (dense f32) label.
fn margin_table(encoder: &Encoder, model: &LogHdModel, x: &Matrix) -> Vec<(f32, bool)> {
    let enc = encoder.encode(x);
    let prep = DecodePrep::new(model);
    let exact = model.predict_prepared(&enc, &prep);

    let b1 = QuantizedLogHdModel::from_model(model, Precision::B1);
    let mut scratch = QueryScratch::new();
    let (mut acts, mut dists) = (Matrix::zeros(0, 0), Matrix::zeros(0, 0));
    let (mut asq, mut labels, mut margins) = (Vec::new(), Vec::new(), Vec::new());
    b1.predict_margins_into(
        &enc,
        &mut scratch,
        &mut acts,
        &mut dists,
        &mut asq,
        &mut labels,
        &mut margins,
    );
    margins.iter().zip(labels.iter().zip(&exact)).map(|(&m, (b, e))| (m, b == e)).collect()
}

/// Smallest representable float strictly above a non-negative finite
/// margin — the step that turns "escalate rows with margin ≤ m" into a
/// `margin < t` gate threshold.
fn next_up(m: f32) -> f32 {
    debug_assert!(m >= 0.0 && m.is_finite());
    f32::from_bits(m.to_bits() + 1)
}

/// Agreement / escalation statistics of `rows` under threshold `t`.
fn stats_at(rows: &[(f32, bool)], t: f32) -> (f64, f64) {
    let n = rows.len() as f64;
    let answered_wrong = rows.iter().filter(|(m, agree)| *m >= t && !agree).count() as f64;
    let escalated = rows.iter().filter(|(m, _)| *m < t).count() as f64;
    (1.0 - answered_wrong / n, escalated / n)
}

/// Bootstrap 95% CI on agreement at threshold `t` (deterministic for a
/// given `rng` stream).
fn bootstrap_ci(rows: &[(f32, bool)], t: f32, rng: &mut SplitMix64) -> (f64, f64) {
    let n = rows.len();
    let bad: Vec<bool> = rows.iter().map(|(m, agree)| *m >= t && !agree).collect();
    let mut samples = Vec::with_capacity(BOOTSTRAP_RESAMPLES);
    for _ in 0..BOOTSTRAP_RESAMPLES {
        let mut wrong = 0usize;
        for _ in 0..n {
            if bad[rng.below(n as u64) as usize] {
                wrong += 1;
            }
        }
        samples.push(1.0 - wrong as f64 / n as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (percentile(&samples, 0.025), percentile(&samples, 0.975))
}

/// Fit the smallest threshold whose bootstrap lower confidence bound on
/// exact-path agreement meets `target`, on the given calibration set.
///
/// Escalation is bought disagreement-first: candidate thresholds step
/// through the sorted margins of the rows where b1 and the exact path
/// disagree (escalating a *agreeing* low-margin row costs compute but
/// never buys agreement). If even full escalation of every disagreeing
/// row's margin neighborhood cannot clear the CI guard, the fit lands
/// on a threshold just above the largest disagreeing margin — agreement
/// 1.0 on the calibration set by construction.
pub fn calibrate(
    encoder: &Encoder,
    model: &LogHdModel,
    x: &Matrix,
    target: f64,
    seed: u64,
) -> Result<Calibration> {
    anyhow::ensure!(x.rows() > 0, "calibration set is empty");
    anyhow::ensure!(
        (0.0..=1.0).contains(&target) && target > 0.0,
        "fidelity target must be in (0, 1], got {target}"
    );
    let rows = margin_table(encoder, model, x);
    let n = rows.len();

    // Candidate thresholds: 0 (never escalate), then one step above each
    // disagreeing row's margin, in ascending margin order.
    let mut disagree: Vec<f32> =
        rows.iter().filter(|(_, agree)| !agree).map(|(m, _)| *m).collect();
    disagree.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut candidates = vec![0.0f32];
    candidates.extend(disagree.iter().filter(|m| m.is_finite()).map(|&m| next_up(m)));
    candidates.dedup();

    let mut rng = SplitMix64::new(seed);
    let mut chosen = None;
    for &t in &candidates {
        let (agreement, _) = stats_at(&rows, t);
        if agreement < target {
            continue; // monotone, but cheap to just skip
        }
        let ci = bootstrap_ci(&rows, t, &mut rng);
        if ci.0 >= target {
            chosen = Some((t, ci));
            break;
        }
    }
    // Fall back to the largest candidate: every disagreeing row
    // escalates, agreement is exactly 1.0 on this set.
    let (threshold, agreement_ci) = match chosen {
        Some(c) => c,
        None => {
            let t = *candidates.last().expect("candidates always holds 0.0");
            (t, bootstrap_ci(&rows, t, &mut rng))
        }
    };
    let (agreement, escalation_rate) = stats_at(&rows, threshold);
    Ok(Calibration { threshold, agreement, agreement_ci, escalation_rate, rows: n, target })
}

/// Held-out evaluation of an already-fitted threshold: (agreement with
/// the exact path, escalation rate) of the cascade's *output* on `x` —
/// the quantity the integration suite asserts against the target.
pub fn evaluate(encoder: &Encoder, model: &LogHdModel, x: &Matrix, threshold: f32) -> (f64, f64) {
    let rows = margin_table(encoder, model, x);
    stats_at(&rows, threshold)
}

/// Persist a fitted calibration into `dir`'s `model.json` (native LogHD
/// artifacts only — AOT bundles have no `model.json` and are rejected
/// upstream). Any previous `cascade_*` fields are replaced; every other
/// manifest field is preserved byte-for-byte in order. The
/// `cascade_threshold` field is what `ModelCard::load` reads and
/// registry admission enforces.
pub fn write_threshold(dir: &Path, cal: &Calibration) -> Result<()> {
    let path = dir.join("model.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {} (native artifact required)", path.display()))?;
    let v = json::parse(&text).map_err(|e| anyhow::anyhow!("model.json: {e}"))?;
    let Value::Object(fields) = v else {
        anyhow::bail!("model.json must hold a JSON object");
    };
    let mut out: Vec<(String, Value)> =
        fields.into_iter().filter(|(k, _)| !k.starts_with("cascade_")).collect();
    out.push(("cascade_threshold".into(), json::num(cal.threshold as f64)));
    out.push(("cascade_target".into(), json::num(cal.target)));
    out.push(("cascade_agreement".into(), json::num(cal.agreement)));
    out.push(("cascade_agreement_ci_lower".into(), json::num(cal.agreement_ci.0)));
    out.push(("cascade_agreement_ci_upper".into(), json::num(cal.agreement_ci.1)));
    out.push(("cascade_escalation_rate".into(), json::num(cal.escalation_rate)));
    out.push(("cascade_calibration_rows".into(), json::num(cal.rows as f64)));
    std::fs::write(&path, json::to_string_pretty(&Value::Object(out)))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::loghd::model::{TrainOptions, TrainedStack};

    fn stack() -> (data::Dataset, TrainedStack) {
        let ds = data::generate_scaled(data::spec("page").unwrap(), 600, 300);
        let opts =
            TrainOptions { epochs: 3, conv_epochs: 1, extra_bundles: 2, ..Default::default() };
        let st = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 512, 0xE5C0DE, &opts).unwrap();
        (ds, st)
    }

    #[test]
    fn calibration_meets_target_on_its_own_set() {
        let (ds, st) = stack();
        let cal = calibrate(&st.encoder, &st.loghd, &ds.x_train, 0.99, 7).unwrap();
        assert!(cal.threshold >= 0.0);
        assert!(cal.agreement >= 0.99, "point agreement {} below target", cal.agreement);
        assert!(cal.agreement_ci.0 <= cal.agreement && cal.agreement <= cal.agreement_ci.1 + 1e-12);
        assert!((0.0..=1.0).contains(&cal.escalation_rate));
        assert_eq!(cal.rows, ds.x_train.rows());
        // Evaluating the fitted threshold on the same set reproduces the
        // reported point estimates exactly.
        let (agreement, esc) = evaluate(&st.encoder, &st.loghd, &ds.x_train, cal.threshold);
        assert_eq!(agreement, cal.agreement);
        assert_eq!(esc, cal.escalation_rate);
    }

    #[test]
    fn stricter_targets_never_lower_the_threshold() {
        let (ds, st) = stack();
        let loose = calibrate(&st.encoder, &st.loghd, &ds.x_train, 0.90, 7).unwrap();
        let strict = calibrate(&st.encoder, &st.loghd, &ds.x_train, 0.999, 7).unwrap();
        assert!(strict.threshold >= loose.threshold);
        assert!(strict.escalation_rate >= loose.escalation_rate);
    }

    #[test]
    fn threshold_persists_into_model_json_and_survives_recalibration() {
        let (ds, st) = stack();
        let dir = std::env::temp_dir().join("loghd_cascade_persist_test");
        let _ = std::fs::remove_dir_all(&dir);
        crate::loghd::persist::save(&dir, &st.encoder, &st.loghd).unwrap();
        let cal = calibrate(&st.encoder, &st.loghd, &ds.x_train, 0.99, 7).unwrap();
        write_threshold(&dir, &cal).unwrap();
        let card = crate::runtime::artifact::ModelCard::load(&dir).unwrap();
        assert_eq!(card.cascade_threshold, Some(cal.threshold as f64));
        // The artifact still loads, and a second write replaces (not
        // duplicates) the cascade fields.
        let (_, model2) = crate::loghd::persist::load(&dir).unwrap();
        assert_eq!(model2.bundles.data(), st.loghd.bundles.data());
        write_threshold(&dir, &cal).unwrap();
        let text = std::fs::read_to_string(dir.join("model.json")).unwrap();
        assert_eq!(text.matches("cascade_threshold").count(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn next_up_is_strictly_above() {
        for m in [0.0f32, 1e-30, 0.5, 3.25] {
            assert!(next_up(m) > m);
        }
    }
}

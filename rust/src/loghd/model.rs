//! The LogHD classifier: train / predict / save / load.
//!
//! This is the paper's primary contribution assembled end-to-end
//! (Algorithm 1): codebook -> bundles -> profiles -> (refinement) ->
//! nearest-profile decoding in activation space.

use anyhow::Result;

use crate::encoder::Encoder;
use crate::hd::prototype::{refine_conventional, train_prototypes};
use crate::hd::similarity::{activations, activations_with};
use crate::loghd::bundling::build_bundles;
use crate::loghd::codebook::{self, Codebook};
use crate::loghd::profiles::compute_profiles;
use crate::loghd::refine::refine_bundles;
use crate::tensor::{self, Matrix};

/// Training hyper-parameters (defaults follow the paper §IV-A, with the
/// epoch count reduced as documented in DESIGN.md).
#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub k: u32,
    pub extra_bundles: usize, // epsilon redundancy
    pub alpha: f64,
    pub eta: f32,
    pub epochs: usize,
    pub conv_epochs: usize, // OnlineHD passes on prototypes pre-bundling
    pub batch: usize,
    pub codebook_seed: u64,
    pub shuffle_seed: u64,
}

impl Default for TrainOptions {
    fn default() -> Self {
        Self {
            k: 2,
            extra_bundles: 2,
            alpha: 1.0,
            eta: 3e-4,
            epochs: 20,
            conv_epochs: 3,
            batch: 64,
            codebook_seed: 0xC0DE,
            shuffle_seed: 0x5EED,
        }
    }
}

/// A trained LogHD model (plus the prototypes it was distilled from, kept
/// for baselines/hybrid composition; they are NOT needed at inference).
#[derive(Debug, Clone)]
pub struct LogHdModel {
    pub classes: usize,
    pub d: usize,
    pub book: Codebook,
    pub bundles: Matrix,  // (n, D) unit rows
    pub profiles: Matrix, // (C, n)
}

impl LogHdModel {
    /// Algorithm 1 steps 2–5 from pre-trained prototypes.
    pub fn from_prototypes(
        h: &Matrix,
        enc_train: &Matrix,
        y_train: &[i32],
        opts: &TrainOptions,
    ) -> Result<Self> {
        let classes = h.rows();
        let n = codebook::min_bundles(classes, opts.k) + opts.extra_bundles;
        Self::from_prototypes_with_n(h, enc_train, y_train, n, opts)
    }

    /// Same, with an explicit bundle count (figure sweeps vary n directly).
    pub fn from_prototypes_with_n(
        h: &Matrix,
        enc_train: &Matrix,
        y_train: &[i32],
        n: usize,
        opts: &TrainOptions,
    ) -> Result<Self> {
        let classes = h.rows();
        let book = codebook::build(classes, opts.k, n, opts.alpha, opts.codebook_seed)?;
        let mut bundles = build_bundles(h, &book);
        if opts.epochs > 0 {
            bundles = refine_bundles(
                &bundles,
                enc_train,
                y_train,
                &book,
                opts.epochs,
                opts.eta,
                opts.shuffle_seed,
                opts.batch,
            )?;
        }
        let profiles = compute_profiles(enc_train, y_train, &bundles, classes);
        Ok(Self { classes, d: h.cols(), book, bundles, profiles })
    }

    /// Activation-space distances (B, C): ||A(x) - P_c||^2 (paper Eq. 7).
    ///
    /// Fused form: `|A|² − 2·A·Pᵀ + |P|²` turns the old O(B·C·n) scalar
    /// `sqdist` loop into one small GEMM over the profile matrix (with
    /// tiny negative expansion residues clamped to zero) — see
    /// EXPERIMENTS.md §Perf. The packed twin (`qmodel`) shares the same
    /// primitive with `|P|²` precomputed at build.
    pub fn decode_dists(&self, enc: &Matrix) -> Matrix {
        let a = activations(enc, &self.bundles); // (B, n)
        tensor::pairwise_sqdists(&a, &self.profiles)
    }

    /// Predicted labels for encoded queries.
    pub fn predict(&self, enc: &Matrix) -> Vec<i32> {
        let d = self.decode_dists(enc);
        (0..d.rows()).map(|i| tensor::argmin(d.row(i)) as i32).collect()
    }

    /// [`Self::decode_dists`] over request-invariant prepared state (see
    /// [`DecodePrep`]) — the serving-engine form, identical math with
    /// the per-batch operand preparation hoisted out.
    pub fn decode_dists_prepared(&self, enc: &Matrix, prep: &DecodePrep) -> Matrix {
        let a = activations_with(enc, &self.bundles, &prep.bundles_nt);
        tensor::pairwise_sqdists_prepared(
            &a,
            &self.profiles,
            &prep.profile_sqnorms,
            &prep.profiles_nt,
        )
    }

    /// [`Self::predict`] over prepared state.
    pub fn predict_prepared(&self, enc: &Matrix, prep: &DecodePrep) -> Vec<i32> {
        let d = self.decode_dists_prepared(enc, prep);
        (0..d.rows()).map(|i| tensor::argmin(d.row(i)) as i32).collect()
    }

    /// [`Self::predict_prepared`] writing every intermediate into
    /// caller-owned scratch (`acts`: the (B, n) activations, `dists`: the
    /// (B, C) distances, `asq`: the per-query `|A|²` terms, `labels`: the
    /// output) — the zero-allocation serving form. Identical math to the
    /// allocating path; parity is pinned by the engine tests.
    pub fn predict_prepared_into(
        &self,
        enc: &Matrix,
        prep: &DecodePrep,
        acts: &mut Matrix,
        dists: &mut Matrix,
        asq: &mut Vec<f32>,
        labels: &mut Vec<i32>,
    ) {
        crate::hd::similarity::activations_with_into(enc, &self.bundles, &prep.bundles_nt, acts);
        tensor::pairwise_sqdists_prepared_into(
            acts,
            &self.profiles,
            &prep.profile_sqnorms,
            &prep.profiles_nt,
            asq,
            dists,
        );
        labels.clear();
        labels.extend((0..dists.rows()).map(|i| tensor::argmin(dists.row(i)) as i32));
    }

    /// [`Self::predict_prepared_into`] that additionally reports each
    /// row's normalized decode margin (runner-up minus best squared
    /// distance, divided by [`DecodePrep::margin_scale`];
    /// lowest-index-wins tie discipline, ties report 0) — the dense twin
    /// of `QuantizedLogHdModel::predict_margins_into`, used by the
    /// cascade calibrator to reason about the exact path's own
    /// confidence structure.
    #[allow(clippy::too_many_arguments)]
    pub fn predict_prepared_margins_into(
        &self,
        enc: &Matrix,
        prep: &DecodePrep,
        acts: &mut Matrix,
        dists: &mut Matrix,
        asq: &mut Vec<f32>,
        labels: &mut Vec<i32>,
        margins: &mut Vec<f32>,
    ) {
        self.predict_prepared_into(enc, prep, acts, dists, asq, labels);
        crate::model::instances::distance_margins_into(dists, margins);
        let inv = 1.0 / prep.margin_scale();
        for m in margins.iter_mut() {
            *m *= inv;
        }
    }

    /// Stored model values: n·D bundles + the (C, n) profiles in their
    /// robust stored form (per-column deviations **plus** the n-vector
    /// cross-class mean — paper §III-G plus the centering the fault
    /// protocol stores). Shares [`crate::model::loghd_stored_values`]
    /// with the equal-memory campaign solver and the packed twin's
    /// `memory_bits`, so the model's own accounting and the budget
    /// accounting cannot drift (they historically disagreed by the
    /// `+ n` mean term).
    pub fn memory_floats(&self) -> usize {
        crate::model::loghd_stored_values(self.n_bundles(), self.d, self.classes)
    }

    /// Memory budget as a fraction of the conventional C*D footprint.
    pub fn budget_fraction(&self) -> f64 {
        self.memory_floats() as f64 / (self.classes * self.d) as f64
    }

    pub fn n_bundles(&self) -> usize {
        self.bundles.rows()
    }
}

/// Request-invariant decode state for a fixed [`LogHdModel`]: the
/// prepared GEMM forms of bundles and profiles ([`tensor::NtPrepared`],
/// hoisting the mid-width transposed copy out of the per-batch path) and
/// the precomputed `|P|²` terms of the fused squared-distance decode.
/// Serving engines build one per replica at model load
/// (`coordinator::worker`); the model's own `predict` recomputes these
/// per call and stays the reference.
#[derive(Debug, Clone)]
pub struct DecodePrep {
    bundles_nt: tensor::NtPrepared,
    profiles_nt: tensor::NtPrepared,
    profile_sqnorms: Vec<f32>,
}

impl DecodePrep {
    pub fn new(model: &LogHdModel) -> Self {
        Self {
            bundles_nt: tensor::NtPrepared::for_operand(&model.bundles),
            profiles_nt: tensor::NtPrepared::for_operand(&model.profiles),
            profile_sqnorms: tensor::row_sqnorms(&model.profiles),
        }
    }

    /// Per-model margin normalizer: mean profile squared norm, floored
    /// away from zero (the dense twin of
    /// `QuantizedLogHdModel::margin_scale`).
    pub fn margin_scale(&self) -> f32 {
        let n = self.profile_sqnorms.len().max(1) as f32;
        (self.profile_sqnorms.iter().sum::<f32>() / n).max(1e-12)
    }
}

/// Everything trained in one go (shared encoder + conventional + LogHD) —
/// the native twin of `python/compile/trainer.py::train_all`.
#[derive(Debug, Clone)]
pub struct TrainedStack {
    pub encoder: Encoder,
    pub prototypes: Matrix, // refined conventional model (C, D)
    pub loghd: LogHdModel,
}

impl TrainedStack {
    pub fn train(
        x_train: &Matrix,
        y_train: &[i32],
        classes: usize,
        d: usize,
        encoder_seed: u64,
        opts: &TrainOptions,
    ) -> Result<Self> {
        let mut encoder = Encoder::new(x_train.cols(), d, encoder_seed);
        let mut enc_train = encoder.encode(x_train);
        // Centering (DESIGN.md §Centering): mu on the raw encodings, then
        // re-center the already-encoded matrix in place.
        let mu = tensor::col_means(&enc_train);
        tensor::sub_row_inplace(&mut enc_train, &mu);
        encoder.set_mu(mu);

        let h0 = train_prototypes(&enc_train, y_train, classes);
        let prototypes = if opts.conv_epochs > 0 {
            refine_conventional(
                &h0,
                &enc_train,
                y_train,
                opts.conv_epochs,
                0.05,
                opts.shuffle_seed ^ 0xA5A5,
                opts.batch,
            )
        } else {
            h0
        };
        let loghd = LogHdModel::from_prototypes(&prototypes, &enc_train, y_train, opts)?;
        Ok(Self { encoder, prototypes, loghd })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;

    fn small_stack() -> (data::Dataset, TrainedStack) {
        let ds = data::generate_scaled(data::spec("page").unwrap(), 600, 200);
        let opts = TrainOptions { epochs: 5, conv_epochs: 1, extra_bundles: 1, ..Default::default() };
        let stack = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 256, 0xE5C0DE, &opts).unwrap();
        (ds, stack)
    }

    #[test]
    fn trained_model_shapes() {
        let (_, stack) = small_stack();
        assert_eq!(stack.loghd.n_bundles(), codebook::min_bundles(5, 2) + 1);
        assert_eq!(stack.loghd.bundles.cols(), 256);
        assert_eq!(stack.loghd.profiles.rows(), 5);
        assert!(stack.loghd.budget_fraction() < 1.0);
    }

    #[test]
    fn accuracy_beats_chance_comfortably() {
        let (ds, stack) = small_stack();
        let enc_test = stack.encoder.encode(&ds.x_test);
        let preds = stack.loghd.predict(&enc_test);
        let hits = preds.iter().zip(&ds.y_test).filter(|(p, y)| p == y).count();
        let acc = hits as f64 / ds.y_test.len() as f64;
        assert!(acc > 0.55, "LogHD acc {acc} too low");

        let scores = activations(&enc_test, &stack.prototypes);
        let chits = (0..enc_test.rows())
            .filter(|&i| tensor::argmax(scores.row(i)) == ds.y_test[i] as usize)
            .count();
        let cacc = chits as f64 / ds.y_test.len() as f64;
        assert!(cacc > 0.6, "conventional acc {cacc} too low");
    }

    #[test]
    fn memory_reduction_holds() {
        let (_, stack) = small_stack();
        let conv = 5 * 256;
        assert!(stack.loghd.memory_floats() < conv);
    }

    #[test]
    fn prepared_margin_variant_matches_prepared_labels() {
        let (ds, stack) = small_stack();
        let enc = stack.encoder.encode(&ds.x_test.rows_slice(0, 24));
        let prep = DecodePrep::new(&stack.loghd);
        let (mut acts, mut dists) = (Matrix::zeros(0, 0), Matrix::zeros(0, 0));
        let (mut asq, mut labels, mut margins) = (Vec::new(), Vec::new(), Vec::new());
        stack.loghd.predict_prepared_margins_into(
            &enc,
            &prep,
            &mut acts,
            &mut dists,
            &mut asq,
            &mut labels,
            &mut margins,
        );
        assert_eq!(labels, stack.loghd.predict(&enc));
        assert_eq!(margins.len(), enc.rows());
        assert!(margins.iter().all(|m| *m >= 0.0));
        assert!(prep.margin_scale() > 0.0);
    }

    #[test]
    fn decode_dists_are_nonnegative() {
        let (ds, stack) = small_stack();
        let enc = stack.encoder.encode(&ds.x_test.rows_slice(0, 16));
        let d = stack.loghd.decode_dists(&enc);
        assert!(d.data().iter().all(|v| *v >= 0.0));
    }
}

//! LogHD — logarithmic class-axis compression (the paper's contribution).
//!
//! - [`codebook`]: capacity-aware k-ary code assignment (Eq. 2/3)
//! - [`bundling`]: weighted prototype superposition (Eq. 4)
//! - [`profiles`]: per-class expected activation profiles (Eq. 5/6)
//! - [`refine`]: perceptron-style bundle refinement (Eq. 8/9)
//! - [`online`]: streaming continual learning (reservoir + live refits)
//! - [`model`]: the assembled classifier (train / predict / memory math)
//! - [`qmodel`]: the bit-packed serving twin (XNOR/popcount + int8 path)
//! - [`cascade`]: offline threshold calibration for the b1-prefilter
//!   serving cascade (fit / evaluate / persist)
//! - [`persist`]: artifact save/load (the format the serving registry hosts)
//!
//! # Example
//!
//! Train a stack on a synthetic Table-I dataset and classify with the
//! compressed model — `n ≈ log_k C` bundles instead of `C` prototypes:
//!
//! ```
//! use loghd::data;
//! use loghd::loghd::model::{TrainOptions, TrainedStack};
//!
//! let ds = data::generate_scaled(data::spec("page").unwrap(), 200, 40);
//! let opts = TrainOptions { epochs: 1, conv_epochs: 0, extra_bundles: 0, ..Default::default() };
//! let stack = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 256, 1, &opts).unwrap();
//! let labels = stack.loghd.predict(&stack.encoder.encode(&ds.x_test));
//! assert_eq!(labels.len(), 40);
//! // Stored floats: n·D bundles + C·n profiles, below the C·D baseline.
//! assert!(stack.loghd.budget_fraction() < 1.0);
//! ```

pub mod bundling;
pub mod cascade;
pub mod codebook;
pub mod model;
pub mod online;
pub mod profiles;
pub mod qmodel;
pub mod refine;

pub mod persist;

pub use cascade::Calibration;
pub use codebook::{min_bundles, Codebook};
pub use model::{LogHdModel, TrainOptions, TrainedStack};
pub use online::{FeedbackError, OnlineConfig, OnlineTrainer, Reservoir, TrainerStats};
pub use qmodel::QuantizedLogHdModel;

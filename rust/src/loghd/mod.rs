//! LogHD — logarithmic class-axis compression (the paper's contribution).
//!
//! - [`codebook`]: capacity-aware k-ary code assignment (Eq. 2/3)
//! - [`bundling`]: weighted prototype superposition (Eq. 4)
//! - [`profiles`]: per-class expected activation profiles (Eq. 5/6)
//! - [`refine`]: perceptron-style bundle refinement (Eq. 8/9)
//! - [`model`]: the assembled classifier (train / predict / memory math)
//! - [`qmodel`]: the bit-packed serving twin (XNOR/popcount + int8 path)

pub mod bundling;
pub mod codebook;
pub mod model;
pub mod profiles;
pub mod qmodel;
pub mod refine;

pub mod persist;

pub use codebook::{min_bundles, Codebook};
pub use model::{LogHdModel, TrainOptions, TrainedStack};
pub use qmodel::QuantizedLogHdModel;

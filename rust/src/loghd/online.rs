//! Streaming continual learning: a per-tenant online trainer.
//!
//! The serving front door ([`crate::coordinator`]) accepts labeled
//! feedback (`feedback` wire verb); this module is what that feedback
//! feeds. An [`OnlineTrainer`] buffers `(features, label)` pairs in a
//! seeded reservoir ([`Reservoir`], Algorithm R — a uniform sample of
//! the stream so old regimes decay instead of dominating), runs
//! incremental minibatch [`refine_step_into`] passes against the *live*
//! bundle matrix on a publish cadence, recomputes the activation
//! profiles, and hands refreshed engine state back to the registry.
//! Re-quantization happens at publish: the registry rebuilds
//! [`crate::coordinator::worker::NativeEngine`] factories at the
//! tenant's serving precision, so B1/B8 tenants repack their stored
//! state from the refreshed f32 tensors on every publish.
//!
//! Class addition is the paper's selling point exercised live: a label
//! equal to the current class count (with
//! [`OnlineConfig::allow_new_classes`]) extends the codebook by ONE
//! codeword ([`crate::loghd::codebook::Codebook::extend_one`]) and one
//! profile row — O(n) new state, not a new O(D) prototype.
//!
//! Everything is deterministic in the config seed plus the ingest
//! sequence: the reservoir RNG, the refit shuffles, and the codeword
//! draws are all forked SplitMix64 streams, so two trainers fed the
//! same stream produce bit-identical models (pinned by tests here and
//! the drift campaign golden).

use crate::encoder::Encoder;
use crate::hd::prototype::gather_rows;
use crate::loghd::model::LogHdModel;
use crate::loghd::profiles::compute_profiles;
use crate::loghd::refine::{refine_step_into, RefineScratch};
use crate::tensor::Matrix;
use crate::util::rng::SplitMix64;

/// Online-training hyper-parameters.
#[derive(Debug, Clone)]
pub struct OnlineConfig {
    /// Reservoir capacity (samples kept for refits).
    pub capacity: usize,
    /// Refits are skipped until the reservoir holds this many samples.
    pub min_samples: usize,
    /// Shuffled passes over the reservoir per refit.
    pub refine_epochs: usize,
    /// Refinement learning rate. Larger than the offline default
    /// (`TrainOptions::eta`): an online refit gets one or two passes per
    /// publish, not twenty epochs, and must track a moving distribution.
    pub eta: f32,
    /// Minibatch size for refit passes.
    pub batch: usize,
    /// Accepted ingests between publishes (the cadence).
    pub publish_every: usize,
    /// Root seed for the reservoir / shuffle / codeword streams.
    pub seed: u64,
    /// Accept `label == classes` by growing the codebook one codeword.
    pub allow_new_classes: bool,
    /// Capacity exponent for new-codeword selection (paper Eq. 2/3).
    pub alpha: f64,
}

impl Default for OnlineConfig {
    fn default() -> Self {
        Self {
            capacity: 512,
            min_samples: 32,
            refine_epochs: 1,
            eta: 0.02,
            batch: 64,
            publish_every: 64,
            seed: 0x0F_EEDBAC,
            allow_new_classes: true,
            alpha: 1.0,
        }
    }
}

/// Why a feedback sample was rejected (maps onto the wire protocol's
/// coded errors — see `RouteError::code` in `coordinator::registry`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FeedbackError {
    /// Label outside `0..classes` (or `0..=classes` when new classes are
    /// allowed), or the codebook's code space is exhausted.
    BadLabel { label: i32, classes: usize },
    /// Feature vector width does not match the tenant's encoder.
    BadWidth { got: usize, want: usize },
}

impl std::fmt::Display for FeedbackError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FeedbackError::BadLabel { label, classes } => {
                write!(f, "label {label} outside class range 0..{classes}")
            }
            FeedbackError::BadWidth { got, want } => {
                write!(f, "feature width {got} != expected {want}")
            }
        }
    }
}

impl std::error::Error for FeedbackError {}

/// Seeded Algorithm-R reservoir over `(features, label)` pairs: after
/// `seen` pushes every sample survived with probability
/// `capacity / seen`. Deterministic in `(seed, push sequence)`.
#[derive(Debug, Clone)]
pub struct Reservoir {
    capacity: usize,
    rng: SplitMix64,
    seen: u64,
    rows: Vec<Vec<f32>>,
    labels: Vec<i32>,
}

impl Reservoir {
    pub fn new(capacity: usize, seed: u64) -> Self {
        assert!(capacity > 0, "reservoir capacity must be > 0");
        Self {
            capacity,
            rng: SplitMix64::new(seed),
            seen: 0,
            rows: Vec::new(),
            labels: Vec::new(),
        }
    }

    /// Offer one sample. While under capacity it is always kept; past
    /// capacity it replaces a uniformly random slot with probability
    /// `capacity / seen` (classic Algorithm R).
    pub fn push(&mut self, x: Vec<f32>, y: i32) {
        self.seen += 1;
        if self.rows.len() < self.capacity {
            self.rows.push(x);
            self.labels.push(y);
            return;
        }
        let j = self.rng.below(self.seen);
        if (j as usize) < self.capacity {
            self.rows[j as usize] = x;
            self.labels[j as usize] = y;
        }
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Total samples ever offered (≥ [`Self::len`]).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    pub fn labels(&self) -> &[i32] {
        &self.labels
    }

    /// The buffered feature rows as a `(len, features)` matrix.
    pub fn to_matrix(&self, features: usize) -> Matrix {
        let mut m = Matrix::zeros(self.rows.len(), features);
        for (i, row) in self.rows.iter().enumerate() {
            m.row_mut(i).copy_from_slice(row);
        }
        m
    }
}

/// Counters for the `stats` admin verb (trainer-attached tenants only).
#[derive(Debug, Clone, Copy)]
pub struct TrainerStats {
    /// Accepted feedback samples.
    pub ingested: u64,
    /// Rejected feedback samples (bad label / bad width).
    pub rejected: u64,
    /// Samples currently buffered in the reservoir.
    pub buffered: usize,
    /// Monotone publish generation (0 until the first publish).
    pub generation: u64,
    /// Classes the live model currently decodes.
    pub classes: usize,
}

/// Per-tenant streaming trainer. The registry owns one behind the
/// tenant's trainer mutex; the `feedback` verb drives [`Self::ingest`]
/// and, when [`Self::publish_due`] fires, [`Self::refit`] +
/// engine-factory rebuild + `Coordinator::reload` +
/// [`Self::mark_published`]. Refits mutate the live `model` in place
/// (the whole point of [`refine_step_into`]); serving replicas only see
/// a *published* snapshot, so mid-refit state never leaks onto the wire.
#[derive(Debug, Clone)]
pub struct OnlineTrainer {
    cfg: OnlineConfig,
    encoder: Encoder,
    model: LogHdModel,
    reservoir: Reservoir,
    shuffle_rng: SplitMix64,
    scratch: RefineScratch,
    enc_scratch: Matrix,
    tau: Matrix,
    ingested: u64,
    rejected: u64,
    since_publish: usize,
    generation: u64,
}

impl OnlineTrainer {
    /// Wrap a trained `(encoder, model)` pair — typically the tenant's
    /// just-loaded artifact, so the first refit starts from the served
    /// weights rather than from scratch.
    pub fn new(encoder: Encoder, model: LogHdModel, cfg: OnlineConfig) -> Self {
        let mut root = SplitMix64::new(cfg.seed);
        let reservoir = Reservoir::new(cfg.capacity, root.fork(1).next_u64());
        let shuffle_rng = root.fork(2);
        Self {
            cfg,
            encoder,
            model,
            reservoir,
            shuffle_rng,
            scratch: RefineScratch::default(),
            enc_scratch: Matrix::zeros(0, 0),
            tau: Matrix::zeros(0, 0),
            ingested: 0,
            rejected: 0,
            since_publish: 0,
            generation: 0,
        }
    }

    /// Validate and buffer one feedback sample. `label == classes` with
    /// [`OnlineConfig::allow_new_classes`] grows the model by one
    /// codeword and one (zero) profile row before buffering; the new
    /// class becomes decodable after its first refit.
    pub fn ingest(&mut self, features: &[f32], label: i32) -> Result<(), FeedbackError> {
        let want = self.encoder.features();
        if features.len() != want {
            self.rejected += 1;
            return Err(FeedbackError::BadWidth { got: features.len(), want });
        }
        let classes = self.model.classes;
        let in_range = label >= 0 && (label as usize) < classes;
        let is_new = self.cfg.allow_new_classes && label >= 0 && label as usize == classes;
        if !in_range && !is_new {
            self.rejected += 1;
            return Err(FeedbackError::BadLabel { label, classes });
        }
        if is_new && self.add_class().is_err() {
            // Code space exhausted: the label stays unservable, so it is
            // rejected with the same code as any other out-of-range label.
            self.rejected += 1;
            return Err(FeedbackError::BadLabel { label, classes });
        }
        self.reservoir.push(features.to_vec(), label);
        self.ingested += 1;
        self.since_publish += 1;
        Ok(())
    }

    /// Grow the codebook by one codeword (deterministic in the config
    /// seed and the class count) and append a zero profile row.
    fn add_class(&mut self) -> anyhow::Result<()> {
        let classes = self.model.classes;
        self.model.book.extend_one(self.cfg.alpha, self.cfg.seed.wrapping_add(classes as u64))?;
        let n = self.model.book.n();
        let mut profiles = Matrix::zeros(classes + 1, n);
        for c in 0..classes {
            profiles.row_mut(c).copy_from_slice(self.model.profiles.row(c));
        }
        self.model.profiles = profiles;
        self.model.classes = classes + 1;
        Ok(())
    }

    /// Whether the cadence says it is time to refit + publish.
    pub fn publish_due(&self) -> bool {
        self.since_publish >= self.cfg.publish_every.max(1)
            && self.reservoir.len() >= self.cfg.min_samples
    }

    /// One incremental refit over the reservoir: encode the buffered
    /// rows, run `refine_epochs` shuffled minibatch passes of
    /// [`refine_step_into`] directly on the live bundle matrix (no
    /// clones — the scratch and tau buffers persist across refits), then
    /// recompute the per-class activation profiles. No-op on an empty
    /// reservoir.
    pub fn refit(&mut self) {
        let count = self.reservoir.len();
        if count == 0 {
            return;
        }
        let x = self.reservoir.to_matrix(self.encoder.features());
        self.encoder.encode_into(&x, &mut self.enc_scratch);
        let targets = self.model.book.targets();
        let n = self.model.book.n();
        let mut idx: Vec<usize> = (0..count).collect();
        for _ in 0..self.cfg.refine_epochs.max(1) {
            self.shuffle_rng.shuffle(&mut idx);
            for chunk in idx.chunks(self.cfg.batch.max(1)) {
                let enc_b = gather_rows(&self.enc_scratch, chunk);
                self.tau.resize(chunk.len(), n);
                for (bi, &si) in chunk.iter().enumerate() {
                    let y = self.reservoir.labels[si] as usize;
                    self.tau.row_mut(bi).copy_from_slice(&targets[y]);
                }
                refine_step_into(
                    &mut self.model.bundles,
                    &enc_b,
                    &self.tau,
                    self.cfg.eta,
                    &mut self.scratch,
                );
            }
        }
        self.model.profiles = compute_profiles(
            &self.enc_scratch,
            &self.reservoir.labels,
            &self.model.bundles,
            self.model.classes,
        );
    }

    /// Record a successful publish: bump the monotone generation and
    /// restart the cadence counter. Called by the registry only after
    /// the coordinator adopted the new engines.
    pub fn mark_published(&mut self) {
        self.generation += 1;
        self.since_publish = 0;
    }

    /// Snapshot of the live `(encoder, model)` pair for engine-factory
    /// construction (one clone per replica happens at the factory layer).
    pub fn snapshot(&self) -> (Encoder, LogHdModel) {
        (self.encoder.clone(), self.model.clone())
    }

    pub fn stats(&self) -> TrainerStats {
        TrainerStats {
            ingested: self.ingested,
            rejected: self.rejected,
            buffered: self.reservoir.len(),
            generation: self.generation,
            classes: self.model.classes,
        }
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn classes(&self) -> usize {
        self.model.classes
    }

    pub fn model(&self) -> &LogHdModel {
        &self.model
    }

    pub fn encoder(&self) -> &Encoder {
        &self.encoder
    }

    pub fn config(&self) -> &OnlineConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::loghd::model::{TrainOptions, TrainedStack};

    fn small_trainer(cfg: OnlineConfig) -> (data::Dataset, OnlineTrainer) {
        let ds = data::generate_scaled(data::spec("page").unwrap(), 400, 100);
        let opts =
            TrainOptions { epochs: 2, conv_epochs: 0, extra_bundles: 1, ..Default::default() };
        let st = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 128, 0xE5C0DE, &opts).unwrap();
        let trainer = OnlineTrainer::new(st.encoder, st.loghd, cfg);
        (ds, trainer)
    }

    #[test]
    fn reservoir_is_deterministic_in_seed() {
        let mut a = Reservoir::new(16, 7);
        let mut b = Reservoir::new(16, 7);
        let mut c = Reservoir::new(16, 8);
        let mut rng = SplitMix64::new(1);
        for i in 0..500 {
            let x = vec![rng.uniform() as f32, i as f32];
            a.push(x.clone(), i % 3);
            b.push(x.clone(), i % 3);
            c.push(x, i % 3);
        }
        assert_eq!(a.len(), 16);
        assert_eq!(a.seen(), 500);
        assert_eq!(a.rows, b.rows);
        assert_eq!(a.labels, b.labels);
        // A different seed keeps a different subset (overwhelmingly).
        assert_ne!(a.rows, c.rows);
    }

    #[test]
    fn reservoir_keeps_everything_under_capacity() {
        let mut r = Reservoir::new(8, 1);
        for i in 0..5 {
            r.push(vec![i as f32], i);
        }
        assert_eq!(r.len(), 5);
        assert_eq!(r.labels(), &[0, 1, 2, 3, 4]);
        assert_eq!(r.to_matrix(1).at(3, 0), 3.0);
    }

    #[test]
    fn reservoir_sampling_is_roughly_uniform() {
        // Each of 1000 offers should land with p = 50/1000; check the
        // retained index mean sits near the stream midpoint.
        let mut r = Reservoir::new(50, 42);
        for i in 0..1000 {
            r.push(vec![i as f32], 0);
        }
        let mean: f64 =
            (0..50).map(|i| r.to_matrix(1).at(i, 0) as f64).sum::<f64>() / 50.0;
        assert!((300.0..700.0).contains(&mean), "retained-index mean {mean}");
    }

    #[test]
    fn ingest_validates_width_and_label() {
        let (_, mut tr) =
            small_trainer(OnlineConfig { allow_new_classes: false, ..Default::default() });
        let err = tr.ingest(&[0.0; 3], 0).unwrap_err();
        assert_eq!(err, FeedbackError::BadWidth { got: 3, want: 10 });
        let err = tr.ingest(&[0.0; 10], -1).unwrap_err();
        assert_eq!(err, FeedbackError::BadLabel { label: -1, classes: 5 });
        let err = tr.ingest(&[0.0; 10], 5).unwrap_err();
        assert_eq!(err, FeedbackError::BadLabel { label: 5, classes: 5 });
        tr.ingest(&[0.0; 10], 4).unwrap();
        let s = tr.stats();
        assert_eq!((s.ingested, s.rejected, s.buffered), (1, 3, 1));
    }

    #[test]
    fn new_class_costs_one_codeword_and_one_profile_row() {
        let (_, mut tr) = small_trainer(OnlineConfig::default());
        let n_before = tr.model().bundles.rows();
        let codes_before = tr.model().book.classes();
        tr.ingest(&[0.5; 10], 5).unwrap();
        assert_eq!(tr.classes(), 6);
        assert_eq!(tr.model().book.classes(), codes_before + 1);
        assert_eq!(tr.model().bundles.rows(), n_before, "no new bundles");
        assert_eq!(tr.model().profiles.rows(), 6);
        assert!(tr.model().profiles.row(5).iter().all(|v| *v == 0.0));
        // A gap is still rejected: label 99 is not "the next class".
        let err = tr.ingest(&[0.5; 10], 99).unwrap_err();
        assert_eq!(err, FeedbackError::BadLabel { label: 99, classes: 6 });
    }

    #[test]
    fn refit_is_deterministic_in_seed_and_stream() {
        let cfg = OnlineConfig { publish_every: 32, min_samples: 16, ..Default::default() };
        let (ds, mut a) = small_trainer(cfg.clone());
        let (_, mut b) = small_trainer(cfg);
        for i in 0..40 {
            let row = ds.x_train.row(i).to_vec();
            a.ingest(&row, ds.y_train[i]).unwrap();
            b.ingest(&row, ds.y_train[i]).unwrap();
        }
        assert!(a.publish_due());
        a.refit();
        b.refit();
        assert_eq!(a.model().bundles.data(), b.model().bundles.data());
        assert_eq!(a.model().profiles.data(), b.model().profiles.data());
        a.mark_published();
        assert_eq!(a.generation(), 1);
        assert!(!a.publish_due(), "cadence counter must reset");
    }

    #[test]
    fn refit_keeps_model_predictive() {
        let cfg = OnlineConfig { publish_every: 64, min_samples: 32, ..Default::default() };
        let (ds, mut tr) = small_trainer(cfg);
        let enc_test = tr.encoder().encode(&ds.x_test);
        let acc = |m: &LogHdModel| {
            let preds = m.predict(&enc_test);
            preds.iter().zip(&ds.y_test).filter(|(p, y)| p == y).count() as f64
                / ds.y_test.len() as f64
        };
        let before = acc(tr.model());
        for i in 0..200 {
            tr.ingest(&ds.x_train.row(i).to_vec(), ds.y_train[i]).unwrap();
        }
        tr.refit();
        let after = acc(tr.model());
        // In-distribution feedback must not wreck the model.
        assert!(after > before - 0.10, "refit degraded accuracy {before} -> {after}");
        for j in 0..tr.model().bundles.rows() {
            let norm = crate::tensor::norm(tr.model().bundles.row(j));
            assert!((norm - 1.0).abs() < 1e-4, "bundle {j} not unit: {norm}");
        }
    }

    #[test]
    fn refit_on_empty_reservoir_is_a_noop() {
        let (_, mut tr) = small_trainer(OnlineConfig::default());
        let before = tr.model().bundles.data().to_vec();
        tr.refit();
        assert_eq!(tr.model().bundles.data(), before.as_slice());
        assert!(!tr.publish_due());
    }
}

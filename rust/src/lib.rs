//! # LogHD — logarithmic class-axis compression of HDC classifiers
//!
//! Production-shaped reproduction of *"LogHD: Robust Compression of
//! Hyperdimensional Classifiers via Logarithmic Class-Axis Reduction"*
//! (Yun et al., 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L1/L2 (build-time Python)**: Pallas kernels + JAX graphs, AOT-lowered
//!   to HLO text artifacts (`python/compile/`, `make artifacts`).
//! - **L3 (this crate)**: the serving coordinator (router → dynamic batcher
//!   → PJRT workers), a complete native implementation of LogHD and every
//!   baseline, the fault-injection engine, and the figure/table harnesses.
//!
//! Python never runs on the request path: [`runtime`] loads the HLO
//! artifacts via the PJRT C API (`xla` crate) and [`coordinator`] serves
//! batched requests against them.
//!
//! Module map (see DESIGN.md for the paper-to-module index):
//!
//! | module | paper artifact |
//! |---|---|
//! | [`encoder`] | φ(x) = cos(xW+b) random-projection encoder |
//! | [`hd`] | prototypes + cosine similarity (§III-A) |
//! | [`loghd`] | codebook/bundles/profiles/refinement (§III-C..F) |
//! | [`baselines`] | conventional, SparseHD, hybrid (§II-B, §IV-D), DecoHD (follow-up work) |
//! | [`model`] | the unified classifier core: the [`model::HdClassifier`] trait, the [`model::FaultSurface`] bit-plane contract, per-precision instances, and the string-keyed [`model::zoo`] registry behind eval, faults, persistence, and serving |
//! | [`quant`], [`faults`] | PTQ + stored-state bit flips (§IV-A) |
//! | [`eval`] | the (method × precision × p) sweep engine (Figs. 3–6) and the equal-memory robustness campaign (`eval::campaign`) |
//! | [`hwmodel`] | Table II analytical ASIC/CPU/GPU model |
//! | [`runtime`], [`coordinator`] | the serving system |
//! | [`testkit`] | deterministic miniature datasets + golden-artifact conformance |
//!
//! `docs/ARCHITECTURE.md` maps the layering end-to-end, including the
//! checklist for adding a new classifier family to the zoo.

pub mod baselines;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod encoder;
pub mod eval;
pub mod faults;
pub mod hd;
pub mod hwmodel;
pub mod loghd;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod testkit;
pub mod util;

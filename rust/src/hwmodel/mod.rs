//! Analytical hardware model behind Table II.
//!
//! The paper reports an ASIC instantiation against a Ryzen 9 9950X, an
//! RTX 4090, and a SparseHD ASIC baseline. None of that silicon is in this
//! environment, so Table II is regenerated from *measured op counts* of
//! our implementations plus per-platform energy/throughput constants
//! calibrated to the paper's absolute operating points (documented in
//! EXPERIMENTS.md §TableII; the *ratios* are what the table claims, and
//! they are driven by the O(CD) vs O(nD) asymmetry we measure directly).
//!
//! Modeled pipeline per query (batch-amortized):
//!   encode -> class-memory similarity stage -> decode
//! CPU/GPU run the f32 random-projection encoder (as our code does);
//! the ASICs use the standard HDC binary ID-level encoder (bit-serial ops
//! at ~1/64 MAC-equivalent cost). SparseHD's ASIC pays irregular-access
//! penalties (index storage + gather datapath + lower lane utilization),
//! which is exactly why the paper's dense class-axis reduction wins at
//! matched memory.

use crate::faults::FaultModelKind;

/// Per-query operation counts for one model configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpCounts {
    /// MAC-equivalents in the encoder stage.
    pub encode_macs: f64,
    /// MAC-equivalents in the similarity/decode stages (dense).
    pub sim_macs: f64,
    /// Stored-model bytes touched per query.
    pub model_bytes: f64,
    /// Extra index/metadata bytes (sparse formats).
    pub index_bytes: f64,
    /// True when the similarity stage is irregular (gather) access.
    pub sparse_access: bool,
}

/// Model-side op counting. `bits` is the stored precision.
pub mod ops {
    use super::OpCounts;

    /// Conventional HDC: C·D similarity MACs, C·D stored values.
    pub fn conventional(f: usize, d: usize, c: usize, bits: u32) -> OpCounts {
        OpCounts {
            encode_macs: (f * d) as f64,
            sim_macs: (c * d) as f64 + c as f64,
            model_bytes: (c * d) as f64 * bits as f64 / 8.0,
            index_bytes: 0.0,
            sparse_access: false,
        }
    }

    /// SparseHD at sparsity S: C·(1−S)·D MACs on gathered values, plus
    /// per-value index metadata (log2 D bits each).
    pub fn sparsehd(f: usize, d: usize, c: usize, sparsity: f64, bits: u32) -> OpCounts {
        let kept = ((1.0 - sparsity) * d as f64).max(1.0);
        let values = c as f64 * kept;
        let index_bits = (d as f64).log2().ceil();
        OpCounts {
            encode_macs: (f * d) as f64,
            sim_macs: values + c as f64,
            model_bytes: values * bits as f64 / 8.0,
            index_bytes: values * index_bits / 8.0,
            sparse_access: true,
        }
    }

    /// LogHD: n·D bundle MACs + C·n profile-decode MACs, all dense.
    pub fn loghd(f: usize, d: usize, c: usize, n: usize, bits: u32) -> OpCounts {
        OpCounts {
            encode_macs: (f * d) as f64,
            sim_macs: (n * d) as f64 + 2.0 * (c * n) as f64,
            model_bytes: ((n * d) + (c * n)) as f64 * bits as f64 / 8.0,
            index_bytes: 0.0,
            sparse_access: false,
        }
    }
}

/// A modeled execution platform.
#[derive(Debug, Clone, Copy)]
pub struct Platform {
    pub name: &'static str,
    /// Dynamic energy per dense MAC-equivalent (pJ), system-amortized.
    pub pj_per_mac: f64,
    /// Effective dense throughput (GMAC/s) at this workload's shape.
    pub gmacs: f64,
    /// Energy per stored-model byte touched (pJ).
    pub pj_per_byte: f64,
    /// Encoder cost multiplier (1.0 = full MAC cost; ASICs use the
    /// bit-serial binary ID encoder at ~1/64 of a MAC per op).
    pub encode_cost_factor: f64,
    /// Sparse-access penalties (apply when `OpCounts.sparse_access`):
    /// energy multiplier on sim MACs, byte-energy multiplier on gathered
    /// model/index traffic, and lane-utilization divisor.
    pub sparse_energy_mult: f64,
    pub sparse_byte_mult: f64,
    pub sparse_util: f64,
}

/// Calibrated platform table (see module docs; EXPERIMENTS.md §TableII).
pub const ASIC: Platform = Platform {
    name: "LogHD ASIC (8-bit, edge-class)",
    pj_per_mac: 0.5,
    gmacs: 160.0,
    pj_per_byte: 2.5,
    encode_cost_factor: 1.0 / 64.0,
    sparse_energy_mult: 2.5,
    sparse_byte_mult: 1.8,
    sparse_util: 0.26,
};

pub const CPU: Platform = Platform {
    name: "AMD Ryzen 9 9950X (f32 AVX)",
    pj_per_mac: 20.0,
    gmacs: 100.0,
    pj_per_byte: 4.0,
    encode_cost_factor: 1.0,
    sparse_energy_mult: 1.6,
    sparse_byte_mult: 1.2,
    sparse_util: 0.7,
};

pub const GPU: Platform = Platform {
    name: "NVIDIA RTX 4090 (f32)",
    pj_per_mac: 1.0,
    gmacs: 950.0,
    pj_per_byte: 0.35,
    encode_cost_factor: 1.0,
    sparse_energy_mult: 1.8,
    sparse_byte_mult: 1.3,
    sparse_util: 0.6,
};

/// Analog in-memory compute (AIMC) platforms for the analog fault
/// campaign. The similarity stage runs *inside* the crossbar (Ohm's-law
/// MACs, ~0.03–0.05 pJ each, system-amortized per Karunaratne et al.
/// class-vector AIMC and ISAAC-class ReRAM numbers); the trade is
/// exactly the fault surface `faults::FaultModel` injects — drifting,
/// stuck, and line-correlated conductances. Sparse formats pay dearly
/// here: a crossbar computes dense rows whether or not values are
/// pruned, so gather-style access forfeits most of the array.
pub const PCM_AIMC: Platform = Platform {
    name: "PCM analog in-memory crossbar",
    pj_per_mac: 0.03,
    gmacs: 1200.0,
    pj_per_byte: 0.1,
    encode_cost_factor: 1.0 / 64.0,
    sparse_energy_mult: 3.0,
    sparse_byte_mult: 2.0,
    sparse_util: 0.2,
};

pub const RERAM_AIMC: Platform = Platform {
    name: "ReRAM analog in-memory crossbar",
    pj_per_mac: 0.05,
    gmacs: 900.0,
    pj_per_byte: 0.12,
    encode_cost_factor: 1.0 / 64.0,
    sparse_energy_mult: 3.0,
    sparse_byte_mult: 2.0,
    sparse_util: 0.2,
};

/// The memory technology a fault-model family is characteristic of —
/// the annotation that lets `results/BENCH_analog.json` index the
/// resilience table and the energy table over one scenario grid.
#[derive(Debug, Clone, Copy)]
pub struct MemoryTechnology {
    pub name: &'static str,
    /// Storage cell the model's faults physically live in.
    pub cell: &'static str,
    /// Dominant physical failure mechanism the model abstracts.
    pub fault_mode: &'static str,
    /// Platform whose energy/latency constants price this technology.
    pub platform: Platform,
}

/// Map each fault-model family to its characteristic memory technology.
/// Bit flips are the digital (SRAM) reference; the three analog models
/// are priced on the AIMC platforms whose physics they abstract.
pub fn technology(kind: FaultModelKind) -> MemoryTechnology {
    match kind {
        FaultModelKind::BitFlip => MemoryTechnology {
            name: "digital SRAM edge ASIC",
            cell: "6T SRAM bit cell",
            fault_mode: "particle-strike bit upsets",
            platform: ASIC,
        },
        FaultModelKind::GaussianDrift => MemoryTechnology {
            name: "PCM crossbar",
            cell: "phase-change (GST) conductance",
            fault_mode: "resistance drift over time/temperature",
            platform: PCM_AIMC,
        },
        FaultModelKind::StuckAt => MemoryTechnology {
            name: "ReRAM crossbar",
            cell: "HfOx filamentary ReRAM",
            fault_mode: "stuck-at forming/endurance defects",
            platform: RERAM_AIMC,
        },
        FaultModelKind::LineFailure => MemoryTechnology {
            name: "ReRAM crossbar periphery",
            cell: "shared word-line driver",
            fault_mode: "correlated word-line failures",
            platform: RERAM_AIMC,
        },
    }
}

/// Modeled energy (µJ) and latency (µs) of one query.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Estimate {
    pub energy_uj: f64,
    pub latency_us: f64,
}

/// Evaluate the model.
pub fn estimate(ops: &OpCounts, p: &Platform) -> Estimate {
    let encode_equiv = ops.encode_macs * p.encode_cost_factor;
    let sim_energy_mult = if ops.sparse_access { p.sparse_energy_mult } else { 1.0 };
    let byte_mult = if ops.sparse_access { p.sparse_byte_mult } else { 1.0 };
    let sim_util = if ops.sparse_access { p.sparse_util } else { 1.0 };

    let energy_pj = encode_equiv * p.pj_per_mac
        + ops.sim_macs * p.pj_per_mac * sim_energy_mult
        + (ops.model_bytes + ops.index_bytes) * p.pj_per_byte * byte_mult
        + ops.index_bytes * p.pj_per_mac; // index decode work
    let mac_seconds = (encode_equiv + ops.sim_macs / sim_util) / (p.gmacs * 1e9);
    Estimate { energy_uj: energy_pj / 1e6, latency_us: mac_seconds * 1e6 }
}

/// Energy-efficiency and speedup of `a` relative to `b` (ratios > 1 mean
/// `a` wins) — the quantities Table II reports.
pub fn ratios(a: &Estimate, b: &Estimate) -> (f64, f64) {
    (b.energy_uj / a.energy_uj, b.latency_us / a.latency_us)
}

/// The full Table II for a dataset configuration: LogHD-ASIC vs
/// {SparseHD-ASIC (matched memory), conventional CPU, conventional GPU}.
pub fn table2(f: usize, d: usize, c: usize, n: usize) -> Vec<(String, f64, f64)> {
    let loghd_asic = estimate(&ops::loghd(f, d, c, n, 8), &ASIC);
    // matched memory: (1-S)·D per class == n·D/C
    let matched_s = 1.0 - n as f64 / c as f64;
    let sparse_asic = estimate(&ops::sparsehd(f, d, c, matched_s, 8), &ASIC);
    let conv_cpu = estimate(&ops::conventional(f, d, c, 32), &CPU);
    let conv_gpu = estimate(&ops::conventional(f, d, c, 32), &GPU);

    let mut rows = Vec::new();
    let (e, s) = ratios(&loghd_asic, &sparse_asic);
    rows.push(("SparseHD / ASIC".to_string(), e, s));
    let (e, s) = ratios(&loghd_asic, &conv_cpu);
    rows.push(("Conventional HDC / CPU (Ryzen 9 9950X)".to_string(), e, s));
    let (e, s) = ratios(&loghd_asic, &conv_gpu);
    rows.push(("Conventional HDC / GPU (RTX 4090)".to_string(), e, s));
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    // Paper Table II targets (ISOLET, C=26, k=2): acceptance is the
    // DESIGN.md band — ordering preserved, magnitudes within ~2x.
    const PAPER: [(f64, f64); 3] = [(4.06, 2.19), (498.1, 62.6), (24.3, 6.58)];

    #[test]
    fn table2_ratios_in_band() {
        let rows = table2(617, 10_000, 26, 7);
        for ((_, e, s), (pe, ps)) in rows.iter().zip(PAPER) {
            assert!(*e > 1.0 && *s > 1.0, "LogHD ASIC must win: {e} {s}");
            assert!(
                *e >= pe / 2.0 && *e <= pe * 2.0,
                "energy ratio {e} outside 2x band of paper {pe}"
            );
            assert!(
                *s >= ps / 2.0 && *s <= ps * 2.0,
                "speedup {s} outside 2x band of paper {ps}"
            );
        }
    }

    #[test]
    fn ordering_matches_paper() {
        let rows = table2(617, 10_000, 26, 7);
        // CPU ratio >> GPU ratio >> SparseHD ratio, in both metrics.
        assert!(rows[1].1 > rows[2].1 && rows[2].1 > rows[0].1);
        assert!(rows[1].2 > rows[2].2 && rows[2].2 > rows[0].2);
    }

    #[test]
    fn loghd_cheaper_than_conventional_on_same_asic() {
        let conv = estimate(&ops::conventional(617, 10_000, 26, 8), &ASIC);
        let log = estimate(&ops::loghd(617, 10_000, 26, 7, 8), &ASIC);
        assert!(log.energy_uj < conv.energy_uj);
        assert!(log.latency_us < conv.latency_us);
    }

    #[test]
    fn every_fault_kind_maps_to_a_technology() {
        // One scenario grid: each fault family prices on some platform,
        // and the digital reference is the only SRAM entry.
        for kind in FaultModelKind::ALL {
            let tech = technology(kind);
            assert!(!tech.name.is_empty() && !tech.fault_mode.is_empty());
            assert!(tech.platform.pj_per_mac > 0.0 && tech.platform.gmacs > 0.0);
            let is_digital = kind == FaultModelKind::BitFlip;
            assert_eq!(tech.name.contains("SRAM"), is_digital, "{}", tech.name);
        }
    }

    #[test]
    fn aimc_similarity_stage_undercuts_the_digital_asic() {
        // In-crossbar MACs are the whole point of tolerating analog
        // faults: the same LogHD workload must be cheaper per query on
        // PCM/ReRAM than on the digital edge ASIC.
        let ops = ops::loghd(617, 10_000, 26, 7, 8);
        let digital = estimate(&ops, &ASIC);
        for p in [PCM_AIMC, RERAM_AIMC] {
            let analog = estimate(&ops, &p);
            assert!(analog.energy_uj < digital.energy_uj, "{}", p.name);
        }
    }

    #[test]
    fn op_counts_scale_as_claimed() {
        // memory O(CD) vs O(nD): ratio ~ C/n for the class-memory stage
        let conv = ops::conventional(617, 10_000, 26, 8);
        let log = ops::loghd(617, 10_000, 26, 7, 8);
        let mem_ratio = conv.model_bytes / log.model_bytes;
        assert!((mem_ratio - 26.0 / 7.0).abs() / (26.0 / 7.0) < 0.05, "{mem_ratio}");
    }
}

//! Continual-learning drift campaign (`loghd drift`): frozen vs online
//! serving under a non-stationary stream, through the real serving
//! stack.
//!
//! The campaign pretrains one LogHD stack on the stationary window-0
//! distribution, then hosts it twice in a [`ModelRegistry`] — a
//! `frozen` tenant that never learns, and an `online` tenant with an
//! [`OnlineTrainer`] attached. A [`DriftStream`] (rotating class
//! means, covariate shift, a mid-stream class addition) is replayed
//! window by window, prequentially: every window is first scored
//! through `submit_blocking` on BOTH tenants, and only then fed to the
//! online tenant as labeled `feedback`, which refits + hot-publishes
//! on its cadence. The artifact records accuracy-over-time for both
//! tenants, the publish/generation history, and the zero-drop counters
//! (every inference across every live publish must answer).
//!
//! Everything outside `meta` is deterministic for a fixed config at
//! any `LOGHD_THREADS` (serial submission ⇒ batch-of-1 inference;
//! kernels are bit-identical at any pool width), which the golden
//! conformance suite pins.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::{BatcherConfig, EngineFactory, ModelRegistry, NativeEngine};
use crate::data::{self, DriftSpec, DriftStream};
use crate::loghd::model::{TrainOptions, TrainedStack};
use crate::loghd::online::{OnlineConfig, OnlineTrainer};
use crate::util::json::{self, Value};
use crate::util::threadpool;

/// Campaign shape: pretraining, stream drift, and online cadence.
#[derive(Debug, Clone)]
pub struct DriftConfig {
    pub profile: String,
    pub dataset: String,
    pub d: usize,
    /// Stationary samples used to train the initial (frozen) stack.
    pub pretrain: usize,
    pub epochs: usize,
    pub conv_epochs: usize,
    pub windows: usize,
    pub samples_per_window: usize,
    pub rotate_frac: f64,
    pub shift_scale: f64,
    pub add_class_at: Option<usize>,
    pub replicas: usize,
    /// Online cadence: refit + hot-publish every this many accepted
    /// feedback samples.
    pub publish_every: usize,
    pub capacity: usize,
    pub min_samples: usize,
    pub refine_epochs: usize,
    pub eta: f32,
    pub seed: u64,
}

impl DriftConfig {
    /// CI-sized: page shapes, two drift mechanisms plus a class
    /// addition, 18 live publishes.
    pub fn smoke() -> Self {
        Self {
            profile: "smoke".into(),
            dataset: "page".into(),
            d: 256,
            pretrain: 400,
            epochs: 3,
            conv_epochs: 1,
            windows: 8,
            samples_per_window: 150,
            rotate_frac: 0.2,
            shift_scale: 0.75,
            add_class_at: Some(4),
            replicas: 2,
            publish_every: 64,
            capacity: 512,
            min_samples: 32,
            refine_epochs: 2,
            eta: 0.05,
            seed: 1,
        }
    }

    /// Paper-scale: ISOLET shapes, longer stream, slower rotation.
    pub fn full() -> Self {
        Self {
            profile: "full".into(),
            dataset: "isolet".into(),
            d: 2000,
            pretrain: 2000,
            epochs: 5,
            conv_epochs: 2,
            windows: 12,
            samples_per_window: 400,
            rotate_frac: 0.12,
            shift_scale: 1.0,
            add_class_at: Some(6),
            replicas: 2,
            publish_every: 128,
            capacity: 1024,
            min_samples: 64,
            refine_epochs: 2,
            eta: 0.03,
            seed: 1,
        }
    }

    pub fn by_name(profile: &str) -> Option<Self> {
        match profile {
            "smoke" => Some(Self::smoke()),
            "full" => Some(Self::full()),
            _ => None,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.windows < 2 {
            bail!("drift campaign needs >= 2 windows, got {}", self.windows);
        }
        if self.samples_per_window == 0 {
            bail!("samples_per_window must be > 0");
        }
        if self.publish_every == 0 || self.min_samples == 0 {
            bail!("publish_every and min_samples must be > 0");
        }
        if self.capacity < self.min_samples {
            bail!(
                "reservoir capacity {} below min_samples {}",
                self.capacity,
                self.min_samples
            );
        }
        let total = self.windows * self.samples_per_window;
        if total < 2 * self.publish_every {
            bail!(
                "stream of {total} samples cannot cross two publish cadences of {}",
                self.publish_every
            );
        }
        if let Some(at) = self.add_class_at {
            if at >= self.windows {
                bail!("add_class_at {at} outside the {}-window stream", self.windows);
            }
        }
        Ok(())
    }
}

/// One stream window's scorecard.
#[derive(Debug, Clone, Copy)]
pub struct WindowReport {
    pub index: usize,
    /// Classes live in the stream this window.
    pub classes: usize,
    /// Mean-rotation progress in [0, 1].
    pub progress: f64,
    pub frozen_acc: f64,
    pub online_acc: f64,
    /// Live publishes triggered by this window's feedback.
    pub publishes: u64,
    /// Trainer generation after this window.
    pub generation: u64,
}

/// The whole campaign: per-window curves plus zero-drop accounting.
#[derive(Debug, Clone)]
pub struct DriftResult {
    pub config: DriftConfig,
    /// Classes in the pretraining distribution.
    pub classes: usize,
    pub windows: Vec<WindowReport>,
    /// Inference submissions (both tenants, all windows).
    pub requests: u64,
    /// Inference submissions that errored or were refused — the
    /// zero-drop guarantee says this stays 0 across every publish.
    pub dropped: u64,
    pub feedback_accepted: u64,
    pub feedback_rejected: u64,
    /// Total live publishes (refit + engine hot-swap) over the stream.
    pub publishes: u64,
    /// Trainer class count at end of stream.
    pub final_classes: usize,
    /// Mean accuracy over the last two windows, per tenant.
    pub frozen_last2: f64,
    pub online_last2: f64,
    pub threads: usize,
    pub elapsed_s: f64,
}

/// Run the frozen-vs-online drift campaign.
pub fn run(cfg: &DriftConfig) -> Result<DriftResult> {
    cfg.validate()?;
    let t0 = Instant::now();
    let spec = data::spec(&cfg.dataset)
        .with_context(|| format!("unknown dataset '{}'", cfg.dataset))?;

    // Pretrain on the stationary window-0 distribution.
    let ds = data::generate_scaled(spec, cfg.pretrain, 1);
    let opts = TrainOptions {
        epochs: cfg.epochs,
        conv_epochs: cfg.conv_epochs,
        ..Default::default()
    };
    let st = TrainedStack::train(&ds.x_train, &ds.y_train, spec.classes, cfg.d, 1, &opts)?;

    // Two tenants off the same artifact: one frozen, one learning.
    let replicas = cfg.replicas.max(1);
    let factories = |label: &str| -> Vec<EngineFactory> {
        (0..replicas)
            .map(|_| NativeEngine::factory(st.encoder.clone(), st.loghd.clone(), label.to_string()))
            .collect()
    };
    let registry = ModelRegistry::with_tenants(
        vec![
            ("frozen", "loghd", spec.features, factories("frozen")),
            ("online", "loghd", spec.features, factories("online")),
        ],
        "online",
        &BatcherConfig::default(),
    );
    let online_cfg = OnlineConfig {
        capacity: cfg.capacity,
        min_samples: cfg.min_samples,
        refine_epochs: cfg.refine_epochs,
        eta: cfg.eta,
        publish_every: cfg.publish_every,
        seed: cfg.seed,
        allow_new_classes: true,
        ..OnlineConfig::default()
    };
    let trainer = OnlineTrainer::new(st.encoder.clone(), st.loghd.clone(), online_cfg);
    registry
        .attach_trainer(Some("online"), trainer)
        .map_err(|e| anyhow::anyhow!("attaching trainer: {e}"))?;

    let stream = DriftStream::new(DriftSpec {
        base: *spec,
        windows: cfg.windows,
        samples_per_window: cfg.samples_per_window,
        rotate_frac: cfg.rotate_frac,
        shift_scale: cfg.shift_scale,
        add_class_at: cfg.add_class_at,
    });

    let mut windows = Vec::with_capacity(cfg.windows);
    let (mut requests, mut dropped) = (0u64, 0u64);
    let (mut feedback_accepted, mut feedback_rejected) = (0u64, 0u64);
    let mut publishes = 0u64;
    let mut final_classes = spec.classes;
    for w in 0..cfg.windows {
        let win = stream.window(w);
        // Prequential split: score the window on both tenants BEFORE
        // its labels reach the trainer — the online curve only ever
        // reflects generations published from earlier windows.
        let mut hits = [0usize; 2];
        for i in 0..win.x.rows() {
            for (t, name) in ["frozen", "online"].into_iter().enumerate() {
                requests += 1;
                match registry.submit_blocking(Some(name), win.x.row(i).to_vec()) {
                    Ok((_, resp)) if resp.label == win.y[i] => hits[t] += 1,
                    Ok(_) => {}
                    Err(_) => dropped += 1,
                }
            }
        }
        let mut window_publishes = 0u64;
        let mut generation = 0u64;
        for i in 0..win.x.rows() {
            match registry.feedback(Some("online"), win.x.row(i), win.y[i]) {
                Ok((_, ack)) => {
                    feedback_accepted += 1;
                    generation = ack.generation;
                    final_classes = ack.classes;
                    if ack.published {
                        window_publishes += 1;
                    }
                }
                Err(_) => feedback_rejected += 1,
            }
        }
        publishes += window_publishes;
        let n = win.x.rows() as f64;
        windows.push(WindowReport {
            index: w,
            classes: win.classes,
            progress: win.progress,
            frozen_acc: hits[0] as f64 / n,
            online_acc: hits[1] as f64 / n,
            publishes: window_publishes,
            generation,
        });
    }

    let last2 = |pick: fn(&WindowReport) -> f64| -> f64 {
        let tail = &windows[windows.len().saturating_sub(2)..];
        tail.iter().map(pick).sum::<f64>() / tail.len() as f64
    };
    Ok(DriftResult {
        config: cfg.clone(),
        classes: spec.classes,
        frozen_last2: last2(|w| w.frozen_acc),
        online_last2: last2(|w| w.online_acc),
        windows,
        requests,
        dropped,
        feedback_accepted,
        feedback_rejected,
        publishes,
        final_classes,
        threads: threadpool::available_threads(),
        elapsed_s: t0.elapsed().as_secs_f64(),
    })
}

impl DriftResult {
    /// Serialize to the `loghd-drift/v1` schema (the shape
    /// `results/BENCH_drift.json` and the golden conformance suite
    /// consume). Everything outside `meta` is deterministic for a
    /// fixed config, at any thread count.
    pub fn to_json(&self) -> Value {
        let cfg = &self.config;
        let curve: Vec<Value> = self
            .windows
            .iter()
            .map(|w| {
                json::obj(vec![
                    ("w", json::num(w.index as f64)),
                    ("classes", json::num(w.classes as f64)),
                    ("progress", json::num(w.progress)),
                    ("frozen_acc", json::num(w.frozen_acc)),
                    ("online_acc", json::num(w.online_acc)),
                    ("publishes", json::num(w.publishes as f64)),
                    ("generation", json::num(w.generation as f64)),
                ])
            })
            .collect();
        json::obj(vec![
            ("schema", json::s("loghd-drift/v1")),
            ("profile", json::s(cfg.profile.as_str())),
            ("dataset", json::s(cfg.dataset.as_str())),
            ("d", json::num(cfg.d as f64)),
            ("classes", json::num(self.classes as f64)),
            ("pretrain", json::num(cfg.pretrain as f64)),
            ("windows", json::num(cfg.windows as f64)),
            ("samples_per_window", json::num(cfg.samples_per_window as f64)),
            ("rotate_frac", json::num(cfg.rotate_frac)),
            ("shift_scale", json::num(cfg.shift_scale)),
            (
                "add_class_at",
                match cfg.add_class_at {
                    Some(at) => json::num(at as f64),
                    None => Value::Null,
                },
            ),
            ("replicas", json::num(cfg.replicas as f64)),
            ("publish_every", json::num(cfg.publish_every as f64)),
            ("capacity", json::num(cfg.capacity as f64)),
            ("min_samples", json::num(cfg.min_samples as f64)),
            ("refine_epochs", json::num(cfg.refine_epochs as f64)),
            ("eta", json::num(cfg.eta as f64)),
            ("seed", json::num(cfg.seed as f64)),
            ("curve", json::arr(curve)),
            (
                "totals",
                json::obj(vec![
                    ("requests", json::num(self.requests as f64)),
                    ("dropped", json::num(self.dropped as f64)),
                    ("feedback_accepted", json::num(self.feedback_accepted as f64)),
                    ("feedback_rejected", json::num(self.feedback_rejected as f64)),
                    ("publishes", json::num(self.publishes as f64)),
                    ("final_classes", json::num(self.final_classes as f64)),
                ]),
            ),
            (
                "verdict",
                json::obj(vec![
                    ("frozen_last2", json::num(self.frozen_last2)),
                    ("online_last2", json::num(self.online_last2)),
                    (
                        "online_minus_frozen",
                        json::num(self.online_last2 - self.frozen_last2),
                    ),
                ]),
            ),
            (
                "meta",
                json::obj(vec![
                    ("threads", json::num(self.threads as f64)),
                    ("elapsed_s", json::num(self.elapsed_s)),
                ]),
            ),
        ])
    }

    /// Write the default artifact pair — `results/BENCH_drift.json`
    /// plus the repo-root snapshot (same convention as the robustness
    /// campaign).
    pub fn write_default_artifacts(&self) -> std::io::Result<()> {
        let text = json::to_string_pretty(&self.to_json());
        std::fs::create_dir_all("results")?;
        std::fs::write("results/BENCH_drift.json", &text)?;
        std::fs::write("BENCH_drift.json", &text)
    }

    /// Human summary for the CLI / bench stdout.
    pub fn summary(&self) -> String {
        let cfg = &self.config;
        let mut out = format!(
            "continual-learning drift campaign [{}]: {} D={} C={} — {} windows x {} samples, \
             rotate {:.2}/win, shift {:.2}, class add at {:?}\n",
            cfg.profile,
            cfg.dataset,
            cfg.d,
            self.classes,
            cfg.windows,
            cfg.samples_per_window,
            cfg.rotate_frac,
            cfg.shift_scale,
            cfg.add_class_at,
        );
        out.push_str(&format!(
            "{:<4} {:>8} {:>9} {:>11} {:>11} {:>10} {:>11}\n",
            "win", "classes", "progress", "frozen_acc", "online_acc", "publishes", "generation"
        ));
        for w in &self.windows {
            out.push_str(&format!(
                "{:<4} {:>8} {:>9.2} {:>11.4} {:>11.4} {:>10} {:>11}\n",
                w.index, w.classes, w.progress, w.frozen_acc, w.online_acc, w.publishes,
                w.generation
            ));
        }
        out.push_str(&format!(
            "last-2-window accuracy: frozen {:.4} vs online {:.4} (delta {:+.4}); \
             {} publishes, {}/{} inferences dropped\n",
            self.frozen_last2,
            self.online_last2,
            self.online_last2 - self.frozen_last2,
            self.publishes,
            self.dropped,
            self.requests,
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::golden;

    /// Unit-test sized: one replica, 8 publishes, a class add at the
    /// midpoint.
    fn micro() -> DriftConfig {
        DriftConfig {
            profile: "micro".into(),
            dataset: "page".into(),
            d: 64,
            pretrain: 150,
            epochs: 1,
            conv_epochs: 0,
            windows: 4,
            samples_per_window: 48,
            rotate_frac: 0.4,
            shift_scale: 0.5,
            add_class_at: Some(2),
            replicas: 1,
            publish_every: 24,
            capacity: 256,
            min_samples: 16,
            refine_epochs: 1,
            eta: 0.05,
            seed: 7,
        }
    }

    #[test]
    fn micro_campaign_counts_publishes_and_drops_nothing() {
        let res = run(&micro()).unwrap();
        assert_eq!(res.windows.len(), 4);
        assert_eq!(res.requests, 4 * 48 * 2);
        assert_eq!(res.dropped, 0, "inference dropped across live publishes");
        assert_eq!(res.feedback_rejected, 0);
        assert_eq!(res.feedback_accepted, 4 * 48);
        // Cadence of 24 over 192 accepted samples: exactly 8 publishes.
        assert_eq!(res.publishes, 8);
        assert!(res.windows.last().unwrap().generation >= 2, "crossed two publish cycles");
        // One codeword bought one new class mid-stream.
        assert_eq!(res.final_classes, 6);
        assert_eq!(res.windows[1].classes, 5);
        assert_eq!(res.windows[2].classes, 6);
        let mut last_gen = 0;
        for w in &res.windows {
            assert!((0.0..=1.0).contains(&w.frozen_acc), "window {}", w.index);
            assert!((0.0..=1.0).contains(&w.online_acc), "window {}", w.index);
            assert!(w.generation >= last_gen, "generations must be monotone");
            last_gen = w.generation;
        }
    }

    #[test]
    fn micro_campaign_is_deterministic() {
        let a = golden::without_keys(run(&micro()).unwrap().to_json(), &["meta"]);
        let b = golden::without_keys(run(&micro()).unwrap().to_json(), &["meta"]);
        assert_eq!(json::to_string(&a), json::to_string(&b));
    }

    #[test]
    fn profiles_and_validation() {
        assert_eq!(DriftConfig::by_name("smoke").unwrap().profile, "smoke");
        assert_eq!(DriftConfig::by_name("full").unwrap().profile, "full");
        assert!(DriftConfig::by_name("warp").is_none());
        DriftConfig::smoke().validate().unwrap();
        DriftConfig::full().validate().unwrap();
        let mut c = micro();
        c.windows = 1;
        assert!(c.validate().is_err());
        let mut c = micro();
        c.publish_every = 10_000;
        assert!(c.validate().is_err(), "stream must cross two cadences");
        let mut c = micro();
        c.add_class_at = Some(99);
        assert!(c.validate().is_err());
        let mut c = micro();
        c.capacity = 4;
        assert!(c.validate().is_err());
    }
}

//! Classification metrics.

/// Fraction of correct predictions.
pub fn accuracy(pred: &[i32], truth: &[i32]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hits = pred.iter().zip(truth).filter(|(p, y)| p == y).count();
    hits as f64 / pred.len() as f64
}

/// Confusion matrix (truth-major, classes x classes).
pub fn confusion(pred: &[i32], truth: &[i32], classes: usize) -> Vec<Vec<usize>> {
    let mut m = vec![vec![0usize; classes]; classes];
    for (p, y) in pred.iter().zip(truth) {
        m[*y as usize][*p as usize] += 1;
    }
    m
}

/// Mean and (population) standard deviation.
pub fn mean_std(values: &[f64]) -> (f64, f64) {
    if values.is_empty() {
        return (0.0, 0.0);
    }
    let mean = values.iter().sum::<f64>() / values.len() as f64;
    let var = values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / values.len() as f64;
    (mean, var.sqrt())
}

/// Linear-interpolated percentile of an ascending-sorted slice
/// (`q` in [0, 1]; the numpy `linear` convention). Used by the
/// campaign engine's bootstrap confidence intervals.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty slice");
    assert!((0.0..=1.0).contains(&q), "percentile q {q} out of [0,1]");
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Largest x in `xs` (assumed ascending) whose paired accuracy stays at or
/// above `floor`; linear-interpolated crossing point when it drops.
/// This is the "sustains target accuracy up to p" statistic the paper's
/// robustness claims are phrased in (e.g. "2.5–3.0x higher bit-flip rates").
pub fn sustained_until(xs: &[f64], accs: &[f64], floor: f64) -> f64 {
    assert_eq!(xs.len(), accs.len());
    let mut last_ok: Option<usize> = None;
    for (i, a) in accs.iter().enumerate() {
        if *a >= floor {
            last_ok = Some(i);
        } else {
            break;
        }
    }
    match last_ok {
        None => 0.0,
        Some(i) if i + 1 >= xs.len() => xs[i],
        Some(i) => {
            // interpolate between the last passing and first failing point
            let (x0, x1) = (xs[i], xs[i + 1]);
            let (a0, a1) = (accs[i], accs[i + 1]);
            if (a0 - a1).abs() < 1e-12 {
                x0
            } else {
                x0 + (x1 - x0) * (a0 - floor) / (a0 - a1)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[1, 2, 3], &[1, 2, 0]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn confusion_counts() {
        let m = confusion(&[0, 1, 1], &[0, 0, 1], 2);
        assert_eq!(m[0][0], 1);
        assert_eq!(m[0][1], 1);
        assert_eq!(m[1][1], 1);
        assert_eq!(m[1][0], 0);
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[1.0, 3.0]);
        assert_eq!(m, 2.0);
        assert_eq!(s, 1.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
        assert_eq!(percentile(&v, 0.5), 2.5);
        assert!((percentile(&v, 0.25) - 1.75).abs() < 1e-12);
        assert_eq!(percentile(&[7.0], 0.3), 7.0);
    }

    #[test]
    fn sustained_until_interpolates() {
        let xs = [0.0, 0.2, 0.4, 0.6];
        let accs = [0.9, 0.9, 0.5, 0.2];
        // floor 0.7 crossed between 0.2 and 0.4: 0.2 + 0.2*(0.9-0.7)/(0.9-0.5)
        let p = sustained_until(&xs, &accs, 0.7);
        assert!((p - 0.3).abs() < 1e-9);
        assert_eq!(sustained_until(&xs, &accs, 0.95), 0.0);
        assert_eq!(sustained_until(&xs, &[0.9; 4], 0.5), 0.6);
    }
}

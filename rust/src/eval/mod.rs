//! Experiment engine: metrics + the (method × precision × fault-rate)
//! sweep machinery that regenerates the paper's figures.

pub mod figures;
pub mod metrics;
pub mod sweep;

pub use metrics::{accuracy, confusion, mean_std, sustained_until};
pub use sweep::{corrupt, corrupt_masked, Method, Workbench};

//! Experiment engine: metrics, the (method × precision × fault-rate)
//! sweep machinery that regenerates the paper's figures, the
//! equal-memory robustness campaign engine behind `loghd robustness`,
//! and the continual-learning drift campaign behind `loghd drift`.

pub mod campaign;
pub mod drift;
pub mod figures;
pub mod metrics;
pub mod sweep;

pub use campaign::{
    run_analog, solve_equal_memory, stored_bits, AnalogConfig, AnalogResult, CampaignConfig,
    CampaignResult,
};
pub use drift::{DriftConfig, DriftResult};
pub use metrics::{accuracy, confusion, mean_std, percentile, sustained_until};
pub use sweep::{cell_stream, corrupt, corrupt_masked, fault_cell_stream, Method, Workbench};

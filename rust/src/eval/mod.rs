//! Experiment engine: metrics, the (method × precision × fault-rate)
//! sweep machinery that regenerates the paper's figures, and the
//! equal-memory robustness campaign engine behind `loghd robustness`.

pub mod campaign;
pub mod figures;
pub mod metrics;
pub mod sweep;

pub use campaign::{
    run_analog, solve_equal_memory, stored_bits, AnalogConfig, AnalogResult, CampaignConfig,
    CampaignResult,
};
pub use metrics::{accuracy, confusion, mean_std, percentile, sustained_until};
pub use sweep::{cell_stream, corrupt, corrupt_masked, fault_cell_stream, Method, Workbench};

//! Equal-memory robustness campaign engine (the paper's headline claim,
//! made regression-testable).
//!
//! The paper's core comparison is *matched-budget*: at the same stored
//! model size, LogHD's class-axis reduction sustains target accuracy at
//! ~2.5–3.0× higher bit-flip rates than feature-axis compression. This
//! module turns that sentence into a pipeline:
//!
//! 1. **Solve** — [`solve_equal_memory`] enumerates (method, precision,
//!    n / sparsity) tuples whose *stored* model size (in bits, counted
//!    exactly over the representation the fault injector corrupts —
//!    [`stored_bits`]) lands within a tolerance of one memory budget.
//!    Lower precision buys redundancy: at the same bits a 1-bit LogHD
//!    cell affords many more bundles than an 8-bit one — which is
//!    exactly the robustness trade the paper studies.
//! 2. **Run** — Monte-Carlo bit-flip campaigns over the solved cells on
//!    the persistent worker pool. Every (cell, flip rate, trial) job
//!    derives its own [`SplitMix64`] stream via
//!    [`sweep::cell_stream`], and every tensor kernel parallelizes over
//!    whole output rows, so campaign output is **bit-identical for any
//!    `LOGHD_THREADS`** (pinned by `rust/tests/integration_robustness.rs`).
//! 3. **Score** — accuracy-vs-flip-rate curves, the interpolated
//!    "max flip rate sustaining target accuracy" resilience metric
//!    ([`sustained_until`]), bootstrap 95% CIs, and the class-axis vs
//!    feature-axis resilience ratio.
//!
//! `loghd robustness` (CLI) and `benches/robustness.rs` drive it and
//! emit `results/BENCH_robustness.json`; `testkit::golden` pins the
//! solver table + schema as a conformance suite.
//!
//! The **analog axis** ([`run_analog`]) reruns the same solved grid
//! under each [`FaultModelKind`] — digital bit flips, Gaussian
//! conductance drift, stuck-at cells, correlated word-line failures —
//! on a shared normalized severity grid (`cfg.ps` reinterpreted per
//! model by [`FaultModelKind::at_severity`]). Each model is annotated
//! with its memory technology ([`crate::hwmodel::technology`]) so the
//! emitted `results/BENCH_analog.json` indexes resilience and modeled
//! energy over one scenario grid. The bit-flip leg draws the *same*
//! streams as the digital campaign (its stream salt is zero), so the
//! committed digital golden stays byte-identical.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::eval::metrics::{mean_std, percentile, sustained_until};
use crate::eval::sweep::{self, Method, Workbench};
use crate::faults::{FaultModel, FaultModelKind, StuckPolarity};
use crate::hwmodel;
use crate::loghd::codebook::min_bundles;
use crate::loghd::model::TrainOptions;
use crate::model::HdClassifier;
use crate::quant::Precision;
use crate::testkit;
use crate::util::json::{self, Value};
use crate::util::rng::SplitMix64;
use crate::util::threadpool;

/// Campaign scope: dataset, memory budget, fault grid, statistics.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    pub profile: String,
    pub dataset: String,
    pub d: usize,
    pub train_cap: usize,
    pub test_cap: usize,
    /// Budget as a fraction of the conventional f32 footprint:
    /// `budget_bits = round(frac · C · D · 32)`.
    pub budget_frac_f32: f64,
    /// Max relative |stored − budget| / budget for a cell to qualify.
    pub tolerance: f64,
    /// Target accuracy as a fraction of the clean conventional accuracy.
    pub target_frac: f64,
    /// Ascending flip-rate grid; must start at 0.0 (the clean point).
    pub ps: Vec<f64>,
    pub trials: usize,
    pub seed: u64,
    pub epochs: usize,
    pub conv_epochs: usize,
    /// Hybrid cells run at n = min_bundles(C, k) + hybrid_extra.
    pub hybrid_extra: usize,
    pub k: u32,
    /// Bootstrap resamples for the resilience CI.
    pub bootstrap: usize,
    /// Also solve DecoHD (decomposed class-weight) cells. Off in the
    /// stock profiles so committed golden artifacts are unchanged;
    /// `loghd robustness --decohd true` turns it on.
    pub decohd: bool,
}

impl CampaignConfig {
    /// CI-sized profile: miniature page workload, minutes of CPU.
    pub fn smoke() -> Self {
        Self {
            profile: "smoke".into(),
            dataset: "page".into(),
            d: 256,
            train_cap: 400,
            test_cap: 150,
            budget_frac_f32: 0.15,
            tolerance: 0.05,
            target_frac: 0.8,
            ps: vec![0.0, 0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.65, 0.8],
            trials: 3,
            seed: 1,
            epochs: 3,
            conv_epochs: 1,
            hybrid_extra: 2,
            k: 2,
            bootstrap: 200,
            decohd: false,
        }
    }

    /// Paper-scale profile (ISOLET, D=2000).
    pub fn full() -> Self {
        Self {
            profile: "full".into(),
            dataset: "isolet".into(),
            d: 2000,
            train_cap: 3000,
            test_cap: 800,
            budget_frac_f32: 0.15,
            tolerance: 0.05,
            target_frac: 0.8,
            ps: vec![0.0, 0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9],
            trials: 5,
            seed: 1,
            epochs: 5,
            conv_epochs: 2,
            hybrid_extra: 2,
            k: 2,
            bootstrap: 500,
            decohd: false,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "smoke" => Some(Self::smoke()),
            "full" => Some(Self::full()),
            _ => None,
        }
    }

    /// The solved budget in bits for a (classes, d) workload.
    pub fn budget_bits(&self, classes: usize, d: usize) -> usize {
        (self.budget_frac_f32 * (classes * d * 32) as f64).round() as usize
    }

    fn validate(&self) -> Result<()> {
        if self.ps.is_empty() || self.ps[0] != 0.0 {
            bail!("flip-rate grid must start at 0.0 (the clean reference point)");
        }
        if !self.ps.windows(2).all(|w| w[0] < w[1]) {
            bail!("flip-rate grid must be strictly ascending");
        }
        if self.trials == 0 {
            bail!("trials must be >= 1");
        }
        if !self.budget_frac_f32.is_finite() || self.budget_frac_f32 <= 0.0 {
            bail!("budget fraction must be a positive number, got {}", self.budget_frac_f32);
        }
        if !self.target_frac.is_finite() || self.target_frac <= 0.0 || self.target_frac > 1.0 {
            bail!("target fraction must be in (0, 1], got {}", self.target_frac);
        }
        if !self.tolerance.is_finite() || self.tolerance < 0.0 || self.tolerance >= 1.0 {
            bail!("budget tolerance must be in [0, 1), got {}", self.tolerance);
        }
        Ok(())
    }
}

pub use crate::baselines::sparsehd::retained_dims;

/// Stored model size in bits for one (method, precision) cell — counted
/// over exactly the representation the trait layer's
/// [`FaultSurface`](crate::model::FaultSurface) exposes to the injector
/// (LogHD/Hybrid store bundles + per-column profile deviations + the
/// n-vector profile mean, via the shared
/// [`model::loghd_stored_values`](crate::model::loghd_stored_values)
/// rule; SparseHD stores only retained coordinates; DecoHD stores basis
/// + coefficients; the index bitmap is excluded, as in the paper).
///
/// This closed form exists so the solver can enumerate cells *before*
/// training anything; [`run`] re-verifies every solved cell against the
/// trait-reported `stored_bits()` of its built instance, so the formula
/// and the actual fault surface cannot silently diverge.
pub fn stored_bits(method: &Method, precision: Precision, classes: usize, d: usize) -> usize {
    let b = precision.bits() as usize;
    match *method {
        Method::Conventional => classes * d * b,
        Method::SparseHd { sparsity } => retained_dims(d, sparsity) * classes * b,
        Method::LogHd { n, .. } => crate::model::loghd_stored_values(n, d, classes) * b,
        Method::Hybrid { n, sparsity, .. } => {
            crate::model::loghd_stored_values(n, retained_dims(d, sparsity), classes) * b
        }
        Method::DecoHd { rank } => (rank * d + classes * rank) * b,
    }
}

/// One solved equal-memory grid cell.
#[derive(Debug, Clone, PartialEq)]
pub struct CampaignCell {
    pub method: Method,
    pub precision: Precision,
    pub stored_bits: usize,
    /// Relative deviation (stored − budget) / budget.
    pub budget_dev: f64,
}

impl CampaignCell {
    pub fn label(&self) -> String {
        format!("{}@{}", self.method.label(), self.precision.label())
    }

    /// Which side of the paper's comparison the cell sits on.
    pub fn family(&self) -> &'static str {
        match self.method {
            Method::Conventional => "reference",
            Method::SparseHd { .. } => "feature-axis",
            Method::LogHd { .. } | Method::Hybrid { .. } | Method::DecoHd { .. } => "class-axis",
        }
    }
}

/// Solve the equal-memory grid: for each method family × precision,
/// pick the free parameter (bundle count n, sparsity S, or rank r) that
/// lands the stored size nearest `budget_bits`, and keep the cell if it
/// is feasible and within `tolerance`. Enumeration order is fixed
/// (conventional, LogHD, SparseHD, hybrid × f32, b8, b1 — then DecoHD
/// when `decohd` is set, appended last so stock campaign artifacts are
/// byte-identical with the flag off).
pub fn solve_equal_memory(
    budget_bits: usize,
    classes: usize,
    d: usize,
    k: u32,
    hybrid_n: usize,
    tolerance: f64,
    decohd: bool,
) -> Vec<CampaignCell> {
    let precisions = [Precision::F32, Precision::B8, Precision::B1];
    let budget = budget_bits as f64;
    let mut out = Vec::new();
    let mut push = |method: Method, precision: Precision| {
        let stored = stored_bits(&method, precision, classes, d);
        let dev = (stored as f64 - budget) / budget;
        if dev.abs() <= tolerance {
            out.push(CampaignCell { method, precision, stored_bits: stored, budget_dev: dev });
        }
    };
    for precision in precisions {
        push(Method::Conventional, precision);
    }
    for precision in precisions {
        let b = precision.bits() as usize;
        let per_n = (b * (d + classes + 1)) as f64;
        let n = (budget / per_n).round() as usize;
        if n >= min_bundles(classes, k) {
            push(Method::LogHd { k, n }, precision);
        }
    }
    for precision in precisions {
        let b = precision.bits() as usize;
        let r = (budget / (b * classes) as f64).round() as usize;
        if (1..=d).contains(&r) {
            push(Method::SparseHd { sparsity: 1.0 - r as f64 / d as f64 }, precision);
        }
    }
    for precision in precisions {
        let b = precision.bits() as usize;
        let values = budget / b as f64;
        let fixed = (classes * hybrid_n + hybrid_n) as f64; // profiles + mean
        let r = ((values - fixed) / hybrid_n as f64).round() as usize;
        if (1..=d).contains(&r) {
            push(
                Method::Hybrid { k, n: hybrid_n, sparsity: 1.0 - r as f64 / d as f64 },
                precision,
            );
        }
    }
    if decohd {
        for precision in precisions {
            let b = precision.bits() as usize;
            // stored = r·(D + C)·b; the nearest feasible rank is the
            // rounded budget ratio clamped into 1..=C (a budget above
            // the full-rank size still offers rank C — the tolerance
            // gate in `push` decides whether the cell qualifies).
            let r = (budget / (b * (d + classes)) as f64).round() as usize;
            push(Method::DecoHd { rank: r.clamp(1, classes) }, precision);
        }
    }
    out
}

/// Per-cell campaign outcome.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub cell: CampaignCell,
    /// Per-p per-trial accuracies, `acc_trials[p_index][trial]`.
    pub acc_trials: Vec<Vec<f64>>,
    pub acc_mean: Vec<f64>,
    pub acc_std: Vec<f64>,
    /// Clean (p = 0) mean accuracy.
    pub clean: f64,
    /// Max flip rate sustaining the target accuracy (interpolated).
    pub resilience: f64,
    /// Bootstrap 95% CI on the resilience.
    pub resilience_ci95: (f64, f64),
}

/// Whole-campaign outcome (serialize with [`CampaignResult::to_json`]).
#[derive(Debug, Clone)]
pub struct CampaignResult {
    pub config: CampaignConfig,
    pub classes: usize,
    pub budget_bits: usize,
    pub clean_conventional: f64,
    pub target_accuracy: f64,
    pub cells: Vec<CellResult>,
    pub class_axis_best: (String, f64),
    pub feature_axis_best: (String, f64),
    /// class-axis best / feature-axis best; `None` when the feature-axis
    /// side never reaches the target even clean.
    pub resilience_ratio: Option<f64>,
    pub threads: usize,
    pub elapsed_s: f64,
}

/// Default correlated-line span (rows taken down per failure event).
pub const DEFAULT_LINE_SPAN: usize = 2;
/// Default drift σ at severity 1.0, in plane-amplitude units.
pub const DEFAULT_DRIFT_SIGMA_MAX: f64 = 2.0;

/// Everything the digital and analog campaigns share before a single
/// fault is drawn: the solved grid, the trained workbench, and the
/// clean reference points. Built once, swept under any number of fault
/// models.
struct Prepared {
    classes: usize,
    features: usize,
    budget_bits: usize,
    cells: Vec<CampaignCell>,
    wb: Workbench,
    clean_conventional: f64,
    target_accuracy: f64,
}

/// Solve the equal-memory grid, train + warm the workbench, and verify
/// every solved cell against the trait-reported fault-surface size.
fn prepare(cfg: &CampaignConfig) -> Result<Prepared> {
    cfg.validate()?;
    let ds = testkit::scaled_dataset(&cfg.dataset, cfg.train_cap, cfg.test_cap)?;
    let classes = ds.spec.classes;
    let features = ds.spec.features;
    let budget_bits = cfg.budget_bits(classes, cfg.d);
    let hybrid_n = min_bundles(classes, cfg.k) + cfg.hybrid_extra;
    let cells = solve_equal_memory(
        budget_bits,
        classes,
        cfg.d,
        cfg.k,
        hybrid_n,
        cfg.tolerance,
        cfg.decohd,
    );
    if !cells.iter().any(|c| c.family() == "class-axis") {
        bail!("no class-axis cell fits budget {budget_bits} bits (tolerance {})", cfg.tolerance);
    }
    if !cells.iter().any(|c| c.family() == "feature-axis") {
        bail!("no feature-axis cell fits budget {budget_bits} bits (tolerance {})", cfg.tolerance);
    }
    crate::log_info!(
        "campaign[{}]: {} at D={}, budget {} bits, {} equal-memory cells",
        cfg.profile,
        cfg.dataset,
        cfg.d,
        budget_bits,
        cells.len()
    );

    let opts = TrainOptions {
        epochs: cfg.epochs,
        conv_epochs: cfg.conv_epochs,
        ..Default::default()
    };
    let mut wb = Workbench::new(&ds, cfg.d, 0xE5C0DE, opts);
    for cell in &cells {
        wb.warm(cell.method)?;
        // Equal-memory means equal *fault-surface* memory: the solver's
        // closed-form bit count must equal what the built instance (the
        // representation the injector actually flips) reports through
        // the trait. A mismatch is a solver/model drift bug, not a
        // recoverable condition.
        let surface_bits = wb.instance(cell.method, cell.precision)?.stored_bits();
        if surface_bits != cell.stored_bits {
            bail!(
                "stored-bits drift for {}: solver counted {} bits, fault surface holds {}",
                cell.label(),
                cell.stored_bits,
                surface_bits
            );
        }
    }
    let clean_conventional = wb.conventional_clean();
    let target_accuracy = cfg.target_frac * clean_conventional;
    Ok(Prepared {
        classes,
        features,
        budget_bits,
        cells,
        wb,
        clean_conventional,
        target_accuracy,
    })
}

/// Run the campaign: solve cells, warm the model caches, fan the
/// (cell × flip rate × trial) grid out over the worker pool, score.
pub fn run(cfg: &CampaignConfig) -> Result<CampaignResult> {
    let t0 = Instant::now();
    let prep = prepare(cfg)?;
    Ok(run_axis(
        cfg,
        &prep,
        FaultModelKind::BitFlip,
        DEFAULT_LINE_SPAN,
        DEFAULT_DRIFT_SIGMA_MAX,
        t0,
    ))
}

/// One fault-model leg over a prepared grid: fan the (cell × severity ×
/// trial) Monte-Carlo out over the worker pool and score it. The
/// bit-flip kind has stream salt 0 and severity = flip rate, so this is
/// *exactly* the historical digital campaign for
/// `FaultModelKind::BitFlip` — byte-identical artifacts outside `meta`.
fn run_axis(
    cfg: &CampaignConfig,
    prep: &Prepared,
    kind: FaultModelKind,
    span: usize,
    drift_sigma_max: f64,
    t0: Instant,
) -> CampaignResult {
    // Monte-Carlo grid on the persistent pool. Each job owns its slot
    // and derives its own stream, so scheduling cannot shift a single
    // draw — output is bit-identical at any LOGHD_THREADS.
    let n_ps = cfg.ps.len();
    let n_jobs = prep.cells.len() * n_ps * cfg.trials;
    let slots: Vec<AtomicU64> = (0..n_jobs).map(|_| AtomicU64::new(0)).collect();
    let wb_ref = &prep.wb;
    let cells_ref = &prep.cells;
    let target_accuracy = prep.target_accuracy;
    threadpool::parallel_ranges(n_jobs, threadpool::available_threads(), |lo, hi| {
        for j in lo..hi {
            let ci = j / (n_ps * cfg.trials);
            let rem = j % (n_ps * cfg.trials);
            let (pi, trial) = (rem / cfg.trials, rem % cfg.trials);
            let cell = &cells_ref[ci];
            let t = cfg.ps[pi];
            let fault = kind.at_severity(t, span, drift_sigma_max);
            let mut rng = sweep::fault_cell_stream(
                cfg.seed,
                kind,
                &cell.method,
                cell.precision,
                t,
                trial as u64,
            );
            let acc = wb_ref
                .evaluate_cell_fault(cell.method, cell.precision, &fault, &mut rng)
                .expect("campaign cell evaluation");
            slots[j].store(acc.to_bits(), Ordering::Relaxed);
        }
    });
    let accs: Vec<f64> = slots.iter().map(|s| f64::from_bits(s.load(Ordering::Relaxed))).collect();

    let mut results = Vec::with_capacity(prep.cells.len());
    for (ci, cell) in prep.cells.iter().enumerate() {
        let acc_trials: Vec<Vec<f64>> = (0..n_ps)
            .map(|pi| {
                (0..cfg.trials)
                    .map(|t| accs[ci * n_ps * cfg.trials + pi * cfg.trials + t])
                    .collect()
            })
            .collect();
        let (acc_mean, acc_std): (Vec<f64>, Vec<f64>) =
            acc_trials.iter().map(|tr| mean_std(tr)).unzip();
        let resilience = sustained_until(&cfg.ps, &acc_mean, target_accuracy);
        let resilience_ci95 = bootstrap_resilience_ci(
            &acc_trials,
            &cfg.ps,
            target_accuracy,
            cfg.bootstrap,
            &mut sweep::fault_cell_stream(
                cfg.seed ^ 0xB007,
                kind,
                &cell.method,
                cell.precision,
                0.0,
                0,
            ),
        );
        results.push(CellResult {
            cell: cell.clone(),
            clean: acc_mean[0],
            acc_trials,
            acc_mean,
            acc_std,
            resilience,
            resilience_ci95,
        });
    }

    let best_of = |family: &str| -> (String, f64) {
        results
            .iter()
            .filter(|r| r.cell.family() == family)
            .map(|r| (r.cell.label(), r.resilience))
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap_or_else(|| ("none".into(), 0.0))
    };
    let class_axis_best = best_of("class-axis");
    let feature_axis_best = best_of("feature-axis");
    let resilience_ratio = if feature_axis_best.1 > 0.0 {
        Some(class_axis_best.1 / feature_axis_best.1)
    } else {
        None
    };
    crate::log_info!(
        "campaign[{}/{}]: class-axis best {} p<={:.3}, feature-axis best {} p<={:.3}, ratio {:?}",
        cfg.profile,
        kind.label(),
        class_axis_best.0,
        class_axis_best.1,
        feature_axis_best.0,
        feature_axis_best.1,
        resilience_ratio
    );

    CampaignResult {
        config: cfg.clone(),
        classes: prep.classes,
        budget_bits: prep.budget_bits,
        clean_conventional: prep.clean_conventional,
        target_accuracy,
        cells: results,
        class_axis_best,
        feature_axis_best,
        resilience_ratio,
        threads: threadpool::available_threads(),
        elapsed_s: t0.elapsed().as_secs_f64(),
    }
}

/// Percentile-bootstrap 95% CI on the resilience metric: resample the
/// trials at each flip rate with replacement, recompute the mean curve
/// and its sustained flip rate, take the [2.5%, 97.5%] band.
fn bootstrap_resilience_ci(
    acc_trials: &[Vec<f64>],
    ps: &[f64],
    target: f64,
    reps: usize,
    rng: &mut SplitMix64,
) -> (f64, f64) {
    if reps == 0 {
        let means: Vec<f64> = acc_trials.iter().map(|t| mean_std(t).0).collect();
        let r = sustained_until(ps, &means, target);
        return (r, r);
    }
    let trials = acc_trials[0].len() as u64;
    let mut stats = Vec::with_capacity(reps);
    for _ in 0..reps {
        let means: Vec<f64> = acc_trials
            .iter()
            .map(|tr| {
                let sum: f64 = (0..trials).map(|_| tr[rng.below(trials) as usize]).sum();
                sum / trials as f64
            })
            .collect();
        stats.push(sustained_until(ps, &means, target));
    }
    stats.sort_by(|a, b| a.total_cmp(b));
    (percentile(&stats, 0.025), percentile(&stats, 0.975))
}

impl CampaignResult {
    /// Serialize to the `loghd-robustness/v1` schema (the shape
    /// `results/BENCH_robustness.json` and the golden conformance suite
    /// consume). Everything outside `meta` is deterministic for a fixed
    /// config, at any thread count.
    pub fn to_json(&self) -> Value {
        let cfg = &self.config;
        let cells: Vec<Value> = self
            .cells
            .iter()
            .map(|r| {
                json::obj(vec![
                    ("label", json::s(r.cell.label())),
                    ("family", json::s(r.cell.family())),
                    ("method", json::s(r.cell.method.label())),
                    ("precision", json::s(r.cell.precision.label())),
                    ("stored_bits", json::num(r.cell.stored_bits as f64)),
                    ("budget_dev", json::num(r.cell.budget_dev)),
                    ("clean_accuracy", json::num(r.clean)),
                    ("acc_mean", json::arr(r.acc_mean.iter().map(|v| json::num(*v)).collect())),
                    ("acc_std", json::arr(r.acc_std.iter().map(|v| json::num(*v)).collect())),
                    ("resilience", json::num(r.resilience)),
                    (
                        "resilience_ci95",
                        json::arr(vec![
                            json::num(r.resilience_ci95.0),
                            json::num(r.resilience_ci95.1),
                        ]),
                    ),
                ])
            })
            .collect();
        let best = |label: &str, value: f64| {
            json::obj(vec![("label", json::s(label)), ("value", json::num(value))])
        };
        json::obj(vec![
            ("schema", json::s("loghd-robustness/v1")),
            ("profile", json::s(cfg.profile.as_str())),
            ("dataset", json::s(cfg.dataset.as_str())),
            ("d", json::num(cfg.d as f64)),
            ("classes", json::num(self.classes as f64)),
            ("budget_bits", json::num(self.budget_bits as f64)),
            ("budget_frac_f32", json::num(cfg.budget_frac_f32)),
            ("tolerance", json::num(cfg.tolerance)),
            ("target_frac", json::num(cfg.target_frac)),
            ("target_accuracy", json::num(self.target_accuracy)),
            ("clean_conventional_f32", json::num(self.clean_conventional)),
            ("seed", json::num(cfg.seed as f64)),
            ("trials", json::num(cfg.trials as f64)),
            ("ps", json::arr(cfg.ps.iter().map(|p| json::num(*p)).collect())),
            ("cells", json::arr(cells)),
            (
                "resilience",
                json::obj(vec![
                    ("class_axis_best", best(&self.class_axis_best.0, self.class_axis_best.1)),
                    (
                        "feature_axis_best",
                        best(&self.feature_axis_best.0, self.feature_axis_best.1),
                    ),
                    (
                        "ratio",
                        match self.resilience_ratio {
                            Some(r) => json::num(r),
                            None => Value::Null,
                        },
                    ),
                ]),
            ),
            (
                "meta",
                json::obj(vec![
                    ("threads", json::num(self.threads as f64)),
                    ("elapsed_s", json::num(self.elapsed_s)),
                ]),
            ),
        ])
    }

    /// Write the default artifact pair — `results/BENCH_robustness.json`
    /// plus the repo-root snapshot — the one protocol the CLI, the bench
    /// target, and the CI artifact upload all share.
    pub fn write_default_artifacts(&self) -> std::io::Result<()> {
        let text = json::to_string_pretty(&self.to_json());
        std::fs::create_dir_all("results")?;
        std::fs::write("results/BENCH_robustness.json", &text)?;
        std::fs::write("BENCH_robustness.json", &text)
    }

    /// Human summary for the CLI / bench stdout.
    pub fn summary(&self) -> String {
        let mut out = format!(
            "equal-memory robustness campaign [{}]: {} D={} C={} budget={} bits, target acc {:.4} ({}% of clean conventional {:.4})\n",
            self.config.profile,
            self.config.dataset,
            self.config.d,
            self.classes,
            self.budget_bits,
            self.target_accuracy,
            (self.config.target_frac * 100.0).round(),
            self.clean_conventional,
        );
        out.push_str(&format!(
            "{:<28} {:>10} {:>7} {:>7} {:>11} {:>17}\n",
            "cell", "bits", "dev%", "clean", "resilience", "ci95"
        ));
        for r in &self.cells {
            out.push_str(&format!(
                "{:<28} {:>10} {:>6.1}% {:>7.4} {:>11.3} [{:.3}, {:.3}]\n",
                r.cell.label(),
                r.cell.stored_bits,
                100.0 * r.cell.budget_dev,
                r.clean,
                r.resilience,
                r.resilience_ci95.0,
                r.resilience_ci95.1,
            ));
        }
        match self.resilience_ratio {
            Some(ratio) => out.push_str(&format!(
                "resilience ratio (class-axis {} / feature-axis {}): {ratio:.2}x (paper claims 2.5-3.0x at matched memory)\n",
                self.class_axis_best.0, self.feature_axis_best.0
            )),
            None => out.push_str(
                "resilience ratio: undefined (feature-axis never reaches the target accuracy)\n",
            ),
        }
        out
    }
}

/// Analog campaign scope: one digital base config swept under several
/// fault-model families on their normalized severity grids.
#[derive(Debug, Clone)]
pub struct AnalogConfig {
    pub base: CampaignConfig,
    /// Fault-model families to sweep; artifact order follows this list.
    pub kinds: Vec<FaultModelKind>,
    /// Correlated-line failure span (rows taken down per event).
    pub span: usize,
    /// Drift σ at severity 1.0, in plane-amplitude units.
    pub drift_sigma_max: f64,
}

impl AnalogConfig {
    /// CI-sized profile: the digital smoke grid under all four models.
    pub fn smoke() -> Self {
        Self {
            base: CampaignConfig::smoke(),
            kinds: FaultModelKind::ALL.to_vec(),
            span: DEFAULT_LINE_SPAN,
            drift_sigma_max: DEFAULT_DRIFT_SIGMA_MAX,
        }
    }

    /// Paper-scale profile (ISOLET, D=2000) under all four models.
    pub fn full() -> Self {
        Self { base: CampaignConfig::full(), ..Self::smoke() }
    }

    fn validate(&self) -> Result<()> {
        self.base.validate()?;
        if self.kinds.is_empty() {
            bail!("analog campaign needs at least one fault model");
        }
        for (i, k) in self.kinds.iter().enumerate() {
            if self.kinds[..i].contains(k) {
                bail!("duplicate fault model '{}' in the sweep list", k.label());
            }
        }
        if self.span == 0 {
            bail!("line-failure span must be >= 1");
        }
        if !self.drift_sigma_max.is_finite() || self.drift_sigma_max <= 0.0 {
            bail!("drift sigma max must be positive, got {}", self.drift_sigma_max);
        }
        Ok(())
    }
}

/// One fault-model leg of an analog campaign.
#[derive(Debug, Clone)]
pub struct AnalogRun {
    pub kind: FaultModelKind,
    pub campaign: CampaignResult,
}

/// Whole analog-campaign outcome (serialize with
/// [`AnalogResult::to_json`]).
#[derive(Debug, Clone)]
pub struct AnalogResult {
    pub config: AnalogConfig,
    pub classes: usize,
    pub features: usize,
    pub budget_bits: usize,
    pub runs: Vec<AnalogRun>,
    pub threads: usize,
    pub elapsed_s: f64,
}

/// Run the equal-memory campaign under every configured fault model.
/// The grid is solved and the workbench trained **once**; each model
/// then sweeps the same cells with its own salted fault streams, so
/// per-model results are independent and the bit-flip leg reproduces
/// the digital campaign exactly.
pub fn run_analog(cfg: &AnalogConfig) -> Result<AnalogResult> {
    cfg.validate()?;
    let t0 = Instant::now();
    let prep = prepare(&cfg.base)?;
    let mut runs = Vec::with_capacity(cfg.kinds.len());
    for &kind in &cfg.kinds {
        crate::log_info!(
            "analog[{}]: sweeping {} ({})",
            cfg.base.profile,
            kind.label(),
            hwmodel::technology(kind).name
        );
        let campaign =
            run_axis(&cfg.base, &prep, kind, cfg.span, cfg.drift_sigma_max, Instant::now());
        runs.push(AnalogRun { kind, campaign });
    }
    Ok(AnalogResult {
        config: cfg.clone(),
        classes: prep.classes,
        features: prep.features,
        budget_bits: prep.budget_bits,
        runs,
        threads: threadpool::available_threads(),
        elapsed_s: t0.elapsed().as_secs_f64(),
    })
}

/// Per-query op counts of one solved cell, for the technology-side
/// energy/latency annotation of the analog artifact (Hybrid counts its
/// retained dimensions; DecoHD's rank plays the bundle role).
fn cell_ops(cell: &CampaignCell, features: usize, d: usize, classes: usize) -> hwmodel::OpCounts {
    let bits = cell.precision.bits();
    match cell.method {
        Method::Conventional => hwmodel::ops::conventional(features, d, classes, bits),
        Method::SparseHd { sparsity } => {
            hwmodel::ops::sparsehd(features, d, classes, sparsity, bits)
        }
        Method::LogHd { n, .. } => hwmodel::ops::loghd(features, d, classes, n, bits),
        Method::Hybrid { n, sparsity, .. } => {
            hwmodel::ops::loghd(features, retained_dims(d, sparsity), classes, n, bits)
        }
        Method::DecoHd { rank } => hwmodel::ops::loghd(features, d, classes, rank, bits),
    }
}

/// The per-model severity grid in physical parameter units — what the
/// normalized `severities` axis means for each fault family. Derived
/// from [`FaultModelKind::at_severity`] so artifact and engine cannot
/// disagree.
fn severity_params(kind: FaultModelKind, ps: &[f64], span: usize, drift_sigma_max: f64) -> Value {
    let grid: Vec<Value> = ps
        .iter()
        .map(|&t| {
            let v = match kind.at_severity(t, span, drift_sigma_max) {
                FaultModel::BitFlip { p } => p,
                FaultModel::GaussianDrift { sigma } => sigma,
                FaultModel::StuckAt { frac, .. } => frac,
                FaultModel::LineFailure { rate, .. } => rate,
            };
            json::num(v)
        })
        .collect();
    match kind {
        FaultModelKind::BitFlip => json::obj(vec![("p", json::arr(grid))]),
        FaultModelKind::GaussianDrift => json::obj(vec![("sigma", json::arr(grid))]),
        FaultModelKind::StuckAt => json::obj(vec![
            ("frac", json::arr(grid)),
            ("polarity", json::s(StuckPolarity::Mixed.label())),
        ]),
        FaultModelKind::LineFailure => json::obj(vec![
            ("rate", json::arr(grid)),
            ("span", json::num(span.max(1) as f64)),
        ]),
    }
}

impl AnalogResult {
    /// Serialize to the `loghd-analog/v1` schema (the shape
    /// `results/BENCH_analog.json` and the analog golden consume). Each
    /// model leg embeds its full `loghd-robustness/v1` campaign doc
    /// (nested `meta` stripped), so everything outside the top-level
    /// `meta` is deterministic for a fixed config, at any thread count.
    pub fn to_json(&self) -> Value {
        let cfg = &self.config;
        let base = &cfg.base;
        let models: Vec<Value> = self
            .runs
            .iter()
            .map(|run| {
                let tech = hwmodel::technology(run.kind);
                let eff = |label: &str| -> Value {
                    match run.campaign.cells.iter().find(|r| r.cell.label() == label) {
                        Some(r) => {
                            let ops = cell_ops(&r.cell, self.features, base.d, self.classes);
                            let est = hwmodel::estimate(&ops, &tech.platform);
                            json::obj(vec![
                                ("label", json::s(label)),
                                ("energy_uj", json::num(est.energy_uj)),
                                ("latency_us", json::num(est.latency_us)),
                            ])
                        }
                        None => Value::Null,
                    }
                };
                json::obj(vec![
                    ("fault_model", json::s(run.kind.label())),
                    (
                        "params",
                        severity_params(run.kind, &base.ps, cfg.span, cfg.drift_sigma_max),
                    ),
                    (
                        "technology",
                        json::obj(vec![
                            ("name", json::s(tech.name)),
                            ("cell", json::s(tech.cell)),
                            ("fault_mode", json::s(tech.fault_mode)),
                            ("platform", json::s(tech.platform.name)),
                        ]),
                    ),
                    (
                        "efficiency",
                        json::obj(vec![
                            ("class_axis_best", eff(&run.campaign.class_axis_best.0)),
                            ("feature_axis_best", eff(&run.campaign.feature_axis_best.0)),
                        ]),
                    ),
                    (
                        "campaign",
                        crate::testkit::golden::without_keys(run.campaign.to_json(), &["meta"]),
                    ),
                ])
            })
            .collect();
        let ratios = json::obj(
            self.runs
                .iter()
                .map(|run| {
                    let v = match run.campaign.resilience_ratio {
                        Some(r) => json::num(r),
                        None => Value::Null,
                    };
                    (run.kind.label(), v)
                })
                .collect(),
        );
        json::obj(vec![
            ("schema", json::s("loghd-analog/v1")),
            ("profile", json::s(base.profile.as_str())),
            ("dataset", json::s(base.dataset.as_str())),
            ("d", json::num(base.d as f64)),
            ("classes", json::num(self.classes as f64)),
            ("features", json::num(self.features as f64)),
            ("budget_bits", json::num(self.budget_bits as f64)),
            ("seed", json::num(base.seed as f64)),
            ("trials", json::num(base.trials as f64)),
            ("severities", json::arr(base.ps.iter().map(|p| json::num(*p)).collect())),
            ("span", json::num(cfg.span as f64)),
            ("drift_sigma_max", json::num(cfg.drift_sigma_max)),
            ("models", json::arr(models)),
            ("resilience_ratios", ratios),
            (
                "meta",
                json::obj(vec![
                    ("threads", json::num(self.threads as f64)),
                    ("elapsed_s", json::num(self.elapsed_s)),
                ]),
            ),
        ])
    }

    /// Write the default artifact pair — `results/BENCH_analog.json`
    /// plus the repo-root snapshot (the robustness-campaign convention).
    pub fn write_default_artifacts(&self) -> std::io::Result<()> {
        let text = json::to_string_pretty(&self.to_json());
        std::fs::create_dir_all("results")?;
        std::fs::write("results/BENCH_analog.json", &text)?;
        std::fs::write("BENCH_analog.json", &text)
    }

    /// Human summary for the CLI / bench stdout.
    pub fn summary(&self) -> String {
        let base = &self.config.base;
        let mut out = format!(
            "analog fault-surface campaign [{}]: {} D={} C={} budget={} bits, {} fault models\n",
            base.profile,
            base.dataset,
            base.d,
            self.classes,
            self.budget_bits,
            self.runs.len(),
        );
        out.push_str(&format!(
            "{:<8} {:<34} {:<34} {:<34} {:>7}\n",
            "model", "technology", "class-axis best", "feature-axis best", "ratio"
        ));
        for run in &self.runs {
            let c = &run.campaign;
            let ratio = match c.resilience_ratio {
                Some(r) => format!("{r:.2}x"),
                None => "n/a".into(),
            };
            let class_best = format!("{} t<={:.3}", c.class_axis_best.0, c.class_axis_best.1);
            let feature_best =
                format!("{} t<={:.3}", c.feature_axis_best.0, c.feature_axis_best.1);
            out.push_str(&format!(
                "{:<8} {:<34} {:<34} {:<34} {:>7}\n",
                run.kind.label(),
                hwmodel::technology(run.kind).name,
                class_best,
                feature_best,
                ratio,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loghd::model::TrainedStack;
    use crate::loghd::qmodel::QuantizedLogHdModel;
    use crate::testkit::golden;

    /// Micro-profile for unit tests: same machinery, seconds of CPU.
    fn micro() -> CampaignConfig {
        CampaignConfig {
            profile: "micro".into(),
            d: 128,
            train_cap: 250,
            test_cap: 80,
            ps: vec![0.0, 0.6],
            trials: 2,
            epochs: 1,
            conv_epochs: 0,
            bootstrap: 50,
            ..CampaignConfig::smoke()
        }
    }

    #[test]
    fn smoke_solver_table_is_the_committed_golden() {
        // The exact table rust/tests/golden/robustness_smoke.json pins:
        // page C=5 D=256, budget 0.15·C·D·32 = 6144 bits, tolerance 5%.
        let cells = solve_equal_memory(6144, 5, 256, 2, 5, 0.05, false);
        let want: Vec<(&str, usize)> = vec![
            ("loghd(k=2,n=3)@b8", 6288),
            ("loghd(k=2,n=23)@b1", 6026),
            ("sparsehd(S=0.85)@f32", 6080),
            ("sparsehd(S=0.40)@b8", 6160),
            ("hybrid(k=2,n=5,S=0.88)@f32", 6080),
            ("hybrid(k=2,n=5,S=0.42)@b8", 6160),
        ];
        let got: Vec<(String, usize)> =
            cells.iter().map(|c| (c.label(), c.stored_bits)).collect();
        assert_eq!(
            got,
            want.iter().map(|(l, b)| (l.to_string(), *b)).collect::<Vec<_>>()
        );
        // class-axis redundancy trade: the 1-bit LogHD cell buys many
        // more bundles than the 8-bit one at the same memory
        assert!(matches!(cells[1].method, Method::LogHd { n: 23, .. }));
        assert!(cells.iter().all(|c| c.budget_dev.abs() <= 0.05));
    }

    #[test]
    fn stored_bits_matches_qmodel_accounting() {
        // The solver's LogHD accounting must equal what the packed model
        // actually stores (and the fault injector actually flips).
        let ds = crate::data::generate_scaled(crate::data::spec("page").unwrap(), 300, 100);
        let opts =
            TrainOptions { epochs: 1, conv_epochs: 0, extra_bundles: 1, ..Default::default() };
        let stack = TrainedStack::train(&ds.x_train, &ds.y_train, 5, 128, 0xE5C0DE, &opts).unwrap();
        let n = stack.loghd.n_bundles();
        for precision in [Precision::B8, Precision::B1] {
            let qm = QuantizedLogHdModel::from_model(&stack.loghd, precision);
            assert_eq!(
                qm.memory_bits(),
                stored_bits(&Method::LogHd { k: 2, n }, precision, 5, 128)
            );
        }
    }

    #[test]
    fn sparse_accounting_matches_build_mask_rounding() {
        use crate::baselines::SparseHdModel;
        use crate::tensor::Matrix;
        let mut rng = SplitMix64::new(5);
        let h = Matrix::from_vec(5, 200, rng.normals_f32(1000));
        for r in [1usize, 77, 129, 200] {
            let sparsity = 1.0 - r as f64 / 200.0;
            assert_eq!(retained_dims(200, sparsity), r);
            let model = SparseHdModel::from_prototypes(&h, sparsity.min(1.0 - 1e-9));
            if sparsity < 1.0 {
                assert_eq!(model.retained(), r, "r={r}");
            }
        }
    }

    #[test]
    fn validate_rejects_degenerate_configs() {
        let bad = |f: fn(&mut CampaignConfig)| {
            let mut cfg = micro();
            f(&mut cfg);
            run(&cfg).unwrap_err()
        };
        assert!(bad(|c| c.target_frac = 0.0).to_string().contains("target"));
        assert!(bad(|c| c.target_frac = 1.5).to_string().contains("target"));
        assert!(bad(|c| c.budget_frac_f32 = -0.1).to_string().contains("budget"));
        assert!(bad(|c| c.tolerance = 1.0).to_string().contains("tolerance"));
        assert!(bad(|c| c.ps = vec![0.1, 0.2]).to_string().contains("clean reference"));
        assert!(bad(|c| c.ps = vec![0.0, 0.4, 0.3]).to_string().contains("ascending"));
        assert!(bad(|c| c.trials = 0).to_string().contains("trials"));
    }

    #[test]
    fn infeasible_budgets_yield_no_cells() {
        // A budget below every representable cell produces an empty grid
        // (and run() would bail with a config error).
        let cells = solve_equal_memory(10, 5, 256, 2, 5, 0.05, true);
        assert!(cells.is_empty());
    }

    #[test]
    fn decohd_solves_into_the_smoke_grid_only_when_asked() {
        // Flag off: the exact committed-golden table (no DecoHD rows).
        let stock = solve_equal_memory(6144, 5, 256, 2, 5, 0.05, false);
        assert!(stock.iter().all(|c| !matches!(c.method, Method::DecoHd { .. })));
        // Flag on: same leading table, DecoHD appended. At 6144 bits /
        // b8, rank 3 stores 3·(256+5)·8 = 6264 bits (within 5%); f32
        // rounds to rank 1 (8352 bits, 36% over budget) and b1 clamps
        // to the full rank C=5 (1305 bits, 79% under) — both outside
        // the 5% tolerance.
        let with = solve_equal_memory(6144, 5, 256, 2, 5, 0.05, true);
        assert_eq!(&with[..stock.len()], &stock[..]);
        let extra: Vec<&CampaignCell> = with[stock.len()..].iter().collect();
        assert_eq!(extra.len(), 1, "{:?}", with.iter().map(|c| c.label()).collect::<Vec<_>>());
        assert_eq!(extra[0].label(), "decohd(r=3)@b8");
        assert_eq!(extra[0].stored_bits, 6264);
        assert_eq!(extra[0].family(), "class-axis");
    }

    #[test]
    fn micro_campaign_evaluates_a_decohd_cell() {
        // The acceptance demo: a DecoHD cell registered through the
        // model zoo is solvable, warmable, and Monte-Carlo-evaluable in
        // a campaign with zero campaign-engine changes.
        let mut cfg = micro();
        cfg.decohd = true;
        let res = run(&cfg).unwrap();
        let deco: Vec<_> = res
            .cells
            .iter()
            .filter(|r| matches!(r.cell.method, Method::DecoHd { .. }))
            .collect();
        assert_eq!(deco.len(), 1, "expected one decohd cell at the micro budget");
        assert_eq!(deco[0].cell.family(), "class-axis");
        assert!(deco[0].clean > 0.3, "decohd clean {}", deco[0].clean);
        assert!(deco[0].acc_mean.iter().all(|a| (0.0..=1.0).contains(a)));
    }

    #[test]
    fn decohd_flag_leaves_stock_campaign_artifacts_untouched() {
        // Same config modulo the flag: the stock cells' numbers must be
        // byte-identical (DecoHD rows append; nothing reorders, and the
        // per-cell fault streams are cell-local).
        let a = run(&micro()).unwrap();
        let mut cfg = micro();
        cfg.decohd = true;
        let b = run(&cfg).unwrap();
        assert_eq!(a.cells.len() + 1, b.cells.len());
        for (ra, rb) in a.cells.iter().zip(&b.cells) {
            assert_eq!(ra.cell.label(), rb.cell.label());
            assert_eq!(ra.acc_trials, rb.acc_trials, "{}", ra.cell.label());
            assert_eq!(ra.resilience, rb.resilience, "{}", ra.cell.label());
        }
    }

    #[test]
    fn micro_campaign_runs_and_scores() {
        let res = run(&micro()).unwrap();
        assert!(res.cells.len() >= 4, "only {} cells", res.cells.len());
        assert!(res.cells.iter().any(|r| r.cell.family() == "class-axis"));
        assert!(res.cells.iter().any(|r| r.cell.family() == "feature-axis"));
        for r in &res.cells {
            assert_eq!(r.acc_mean.len(), 2);
            assert!(r.acc_mean.iter().all(|a| (0.0..=1.0).contains(a)));
            assert!((0.0..=0.6).contains(&r.resilience));
            assert!(r.resilience_ci95.0 <= r.resilience_ci95.1 + 1e-12);
        }
        let v = res.to_json();
        assert_eq!(v.get("schema").unwrap().as_str(), Some("loghd-robustness/v1"));
        assert_eq!(v.get("cells").unwrap().as_array().unwrap().len(), res.cells.len());
        assert!(res.summary().contains("equal-memory"));
    }

    #[test]
    fn severity_params_report_physical_grids() {
        let ps = [0.0, 0.5, 1.0];
        let nums = |v: &Value, key: &str| -> Vec<f64> {
            v.get(key)
                .unwrap()
                .as_array()
                .unwrap()
                .iter()
                .map(|x| x.as_f64().unwrap())
                .collect()
        };
        let v = severity_params(FaultModelKind::BitFlip, &ps, 2, 2.0);
        assert_eq!(nums(&v, "p"), ps.to_vec());
        let v = severity_params(FaultModelKind::GaussianDrift, &ps, 2, 2.0);
        assert_eq!(nums(&v, "sigma"), vec![0.0, 1.0, 2.0]);
        let v = severity_params(FaultModelKind::StuckAt, &ps, 2, 2.0);
        assert_eq!(nums(&v, "frac"), ps.to_vec());
        assert_eq!(v.get("polarity").unwrap().as_str(), Some("mixed"));
        // Line rates are chosen so span-expanded row coverage ~= t.
        let v = severity_params(FaultModelKind::LineFailure, &ps, 2, 2.0);
        let rates = nums(&v, "rate");
        assert_eq!(v.get("span").unwrap().as_f64(), Some(2.0));
        assert_eq!(rates[0], 0.0);
        assert_eq!(rates[2], 1.0);
        let coverage = 1.0 - (1.0 - rates[1]) * (1.0 - rates[1]);
        assert!((coverage - 0.5).abs() < 1e-12, "coverage {coverage}");
    }

    #[test]
    fn analog_validate_rejects_degenerate_configs() {
        let bad = |f: fn(&mut AnalogConfig)| {
            let mut cfg = AnalogConfig { base: micro(), ..AnalogConfig::smoke() };
            f(&mut cfg);
            run_analog(&cfg).unwrap_err()
        };
        assert!(bad(|c| c.kinds.clear()).to_string().contains("fault model"));
        assert!(bad(|c| c.kinds = vec![FaultModelKind::StuckAt; 2])
            .to_string()
            .contains("duplicate"));
        assert!(bad(|c| c.span = 0).to_string().contains("span"));
        assert!(bad(|c| c.drift_sigma_max = f64::NAN).to_string().contains("sigma"));
        assert!(bad(|c| c.base.trials = 0).to_string().contains("trials"));
    }

    #[test]
    fn analog_micro_campaign_sweeps_all_kinds() {
        let digital = run(&micro()).unwrap();
        let cfg = AnalogConfig { base: micro(), ..AnalogConfig::smoke() };
        let res = run_analog(&cfg).unwrap();
        assert_eq!(res.runs.len(), 4);
        let strip = |v: Value| golden::without_keys(v, &["meta"]);
        // The bit-flip leg IS the digital campaign: stream salt 0,
        // severity = flip rate, same draw-per-plane discipline.
        assert_eq!(
            json::to_string(&strip(res.runs[0].campaign.to_json())),
            json::to_string(&strip(digital.to_json()))
        );
        for leg in &res.runs {
            assert_eq!(leg.campaign.cells.len(), digital.cells.len());
            for (ra, rd) in leg.campaign.cells.iter().zip(&digital.cells) {
                assert_eq!(ra.cell.label(), rd.cell.label());
                // Severity 0 is a no-op under every model, so the clean
                // row of the grid is bit-identical across fault models.
                assert_eq!(ra.acc_trials[0], rd.acc_trials[0], "{}", ra.cell.label());
            }
        }
        let v = res.to_json();
        assert_eq!(v.get("schema").unwrap().as_str(), Some("loghd-analog/v1"));
        let models = v.get("models").unwrap().as_array().unwrap();
        assert_eq!(models.len(), 4);
        assert_eq!(models[0].get("fault_model").unwrap().as_str(), Some("bitflip"));
        let energy = models[0]
            .get_path(&["efficiency", "class_axis_best", "energy_uj"])
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(energy > 0.0, "energy {energy}");
        assert!(res.summary().contains("analog fault-surface"));
    }

    #[test]
    fn campaign_is_deterministic_for_fixed_config() {
        // Bit-identical artifacts (outside meta) across repeated runs in
        // one process — the in-process half of the reproducibility
        // contract (the cross-LOGHD_THREADS half lives in
        // rust/tests/integration_robustness.rs).
        let a = run(&micro()).unwrap();
        let b = run(&micro()).unwrap();
        let strip = |v: Value| golden::without_keys(v, &["meta"]);
        assert_eq!(
            json::to_string(&strip(a.to_json())),
            json::to_string(&strip(b.to_json()))
        );
    }
}

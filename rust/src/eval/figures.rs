//! Figure regeneration logic (paper Figs. 3–6).
//!
//! Each `figN` function sweeps the same grid the paper plots and returns
//! flat rows; the `benches/figN_*.rs` targets write them to
//! `results/figN.csv` and print a quick-look ASCII chart. Scope defaults
//! are sized for this 1-core CI box (D=2000, capped train sets, 2 seeds);
//! set `LOGHD_FULL=1` for the paper-scale grid (D=10,000, full Table I
//! sample counts) — same code path, more points. EXPERIMENTS.md records
//! which scale produced the committed numbers.

use anyhow::Result;

use crate::data;
use crate::eval::sweep::{Method, Workbench};
use crate::loghd::codebook::min_bundles;
use crate::loghd::model::TrainOptions;
use crate::quant::Precision;

/// A single measured grid cell.
#[derive(Debug, Clone)]
pub struct Row {
    pub dataset: String,
    pub method: String,
    pub budget: f64,
    pub d: usize,
    pub bits: u32,
    pub p: f64,
    pub seed: u64,
    pub accuracy: f64,
}

impl Row {
    pub fn csv_header() -> &'static str {
        "dataset,method,budget,d,bits,p,seed,accuracy"
    }

    pub fn csv(&self) -> Vec<String> {
        vec![
            self.dataset.clone(),
            // method labels contain commas (e.g. "loghd(k=2,n=5)"):
            // keep the CSV single-delimiter by mapping to ';'
            self.method.replace(',', ";"),
            format!("{:.3}", self.budget),
            self.d.to_string(),
            self.bits.to_string(),
            format!("{:.3}", self.p),
            self.seed.to_string(),
            format!("{:.4}", self.accuracy),
        ]
    }
}

/// Sweep scope (CI-sized by default; env `LOGHD_FULL=1` for paper scale).
#[derive(Debug, Clone)]
pub struct Scope {
    pub d: usize,
    pub train_cap: usize,
    pub test_cap: usize,
    pub seeds: Vec<u64>,
    pub ps: Vec<f64>,
    pub epochs: usize,
}

impl Scope {
    pub fn from_env() -> Self {
        if std::env::var("LOGHD_FULL").as_deref() == Ok("1") {
            Self {
                d: 10_000,
                train_cap: usize::MAX,
                test_cap: usize::MAX,
                seeds: vec![1, 2, 3],
                ps: vec![0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9],
                epochs: 20,
            }
        } else {
            Self {
                d: 2000,
                train_cap: 3000,
                test_cap: 800,
                seeds: vec![1, 2],
                ps: vec![0.0, 0.2, 0.4, 0.6, 0.8],
                epochs: 5,
            }
        }
    }
}

fn workbench(name: &str, d: usize, scope: &Scope) -> Workbench {
    let spec = data::spec(name).expect("dataset");
    let ds = data::generate_scaled(
        spec,
        spec.n_train.min(scope.train_cap),
        spec.n_test.min(scope.test_cap),
    );
    let opts = TrainOptions { epochs: scope.epochs, conv_epochs: 2, ..Default::default() };
    Workbench::new(&ds, d, 0xE5C0DE, opts)
}

/// Methods evaluated at one memory budget x (fraction of C·D), matching
/// the paper's matched-budget protocol. Infeasible combinations (budget
/// below ceil(log_k C)/C) are skipped, exactly as the paper's missing
/// points (§IV-B).
pub fn methods_at_budget(classes: usize, budget: f64) -> Vec<Method> {
    let mut out = vec![Method::SparseHd { sparsity: (1.0 - budget).clamp(0.0, 0.95) }];
    for k in [2u32, 3] {
        let n = ((budget * classes as f64).floor() as usize).max(1);
        if n >= min_bundles(classes, k) && n <= classes {
            out.push(Method::LogHd { k, n });
        }
    }
    // Hybrid: fixed n (min+2 for k=2), sparsity chosen to hit the budget.
    let nh = min_bundles(classes, 2) + 2;
    let needed = budget * classes as f64 / nh as f64;
    if needed < 1.0 {
        out.push(Method::Hybrid { k: 2, n: nh, sparsity: (1.0 - needed).clamp(0.0, 0.95) });
    }
    out
}

/// Fig. 3: accuracy vs bit-flip p at matched budgets, all datasets.
pub fn fig3(scope: &Scope, bits: u32) -> Result<Vec<Row>> {
    let precision = Precision::from_bits(bits).unwrap();
    let budgets = [0.2, 0.4, 0.6];
    let mut rows = Vec::new();
    for name in ["isolet", "ucihar", "pamap2", "page"] {
        let mut wb = workbench(name, scope.d, scope);
        for &budget in &budgets {
            for method in methods_at_budget(wb.classes, budget) {
                for &p in &scope.ps {
                    for &seed in &scope.seeds {
                        let acc = wb.evaluate(method, precision, p, seed)?;
                        rows.push(Row {
                            dataset: name.into(),
                            method: method.label(),
                            budget,
                            d: scope.d,
                            bits,
                            p,
                            seed,
                            accuracy: acc,
                        });
                    }
                }
            }
        }
        crate::log_info!("fig3: {name} done ({} rows so far)", rows.len());
    }
    Ok(rows)
}

/// Fig. 4: sensitivity to D and precision on UCIHAR at a fixed budget.
pub fn fig4(scope: &Scope) -> Result<Vec<Row>> {
    let dims: Vec<usize> = if scope.d >= 10_000 {
        vec![1000, 2000, 4000, 10_000]
    } else {
        vec![500, 1000, 2000]
    };
    let budget = 0.4;
    let mut rows = Vec::new();
    for d in dims {
        let mut wb = workbench("ucihar", d, scope);
        for bits in [1u32, 2, 4, 8] {
            let precision = Precision::from_bits(bits).unwrap();
            for method in methods_at_budget(wb.classes, budget) {
                for &p in &scope.ps {
                    for &seed in &scope.seeds {
                        let acc = wb.evaluate(method, precision, p, seed)?;
                        rows.push(Row {
                            dataset: "ucihar".into(),
                            method: method.label(),
                            budget,
                            d,
                            bits,
                            p,
                            seed,
                            accuracy: acc,
                        });
                    }
                }
            }
        }
        crate::log_info!("fig4: D={d} done");
    }
    Ok(rows)
}

/// Fig. 5: effect of alphabet size k — accuracy vs n/C for p in {0, 0.8}.
pub fn fig5(scope: &Scope, bits: u32) -> Result<Vec<Row>> {
    let precision = Precision::from_bits(bits).unwrap();
    let mut rows = Vec::new();
    for name in ["page", "ucihar"] {
        let mut wb = workbench(name, scope.d, scope);
        let c = wb.classes;
        for k in [2u32, 3, 4, 8] {
            let nmin = min_bundles(c, k);
            let nmax = ((0.9 * c as f64) as usize).max(nmin + 1);
            let mut n = nmin;
            while n <= nmax {
                for &p in &[0.0, 0.8] {
                    for &seed in &scope.seeds {
                        let acc = wb.evaluate(Method::LogHd { k, n }, precision, p, seed)?;
                        rows.push(Row {
                            dataset: name.into(),
                            method: format!("k={k}"),
                            budget: n as f64 / c as f64,
                            d: scope.d,
                            bits,
                            p,
                            seed,
                            accuracy: acc,
                        });
                    }
                }
                n += (c / 6).max(1);
            }
        }
        crate::log_info!("fig5: {name} done");
    }
    Ok(rows)
}

/// Fig. 6: hybrid heatmap on ISOLET — accuracy over n x retained (1−S).
pub fn fig6(scope: &Scope) -> Result<Vec<Row>> {
    let mut wb = workbench("isolet", scope.d, scope);
    let c = wb.classes;
    let ns: Vec<usize> = vec![min_bundles(c, 2), min_bundles(c, 2) + 2, min_bundles(c, 2) + 5, 13];
    let retained = [0.1, 0.25, 0.4, 0.55, 0.7, 0.85, 1.0];
    let bits_list: Vec<u32> =
        if scope.d >= 10_000 { vec![1, 2, 4, 8] } else { vec![1, 8] };
    let ps = [0.0, 0.2, 0.4, 0.8];
    let mut rows = Vec::new();
    for &bits in &bits_list {
        let precision = Precision::from_bits(bits).unwrap();
        for &n in &ns {
            for &r in &retained {
                let method = if r >= 1.0 {
                    Method::LogHd { k: 2, n }
                } else {
                    Method::Hybrid { k: 2, n, sparsity: 1.0 - r }
                };
                for &p in &ps {
                    for &seed in &scope.seeds {
                        let acc = wb.evaluate(method, precision, p, seed)?;
                        rows.push(Row {
                            dataset: "isolet".into(),
                            method: format!("n={n},r={r:.2}"),
                            budget: n as f64 * r / c as f64,
                            d: scope.d,
                            bits,
                            p,
                            seed,
                            accuracy: acc,
                        });
                    }
                }
            }
        }
        crate::log_info!("fig6: bits={bits} done");
    }
    Ok(rows)
}

/// Aggregate rows into (x, mean-accuracy) series keyed by `key_fn`,
/// sorted by x — the shape the ASCII charts want.
pub fn series_by<F>(rows: &[Row], key_fn: F) -> Vec<(String, Vec<(f64, f64)>)>
where
    F: Fn(&Row) -> Option<(String, f64)>,
{
    use std::collections::BTreeMap;
    let mut acc: BTreeMap<String, BTreeMap<i64, (f64, usize)>> = BTreeMap::new();
    for row in rows {
        if let Some((key, x)) = key_fn(row) {
            let bucket = acc.entry(key).or_default().entry((x * 1e6) as i64).or_insert((0.0, 0));
            bucket.0 += row.accuracy;
            bucket.1 += 1;
        }
    }
    acc.into_iter()
        .map(|(k, points)| {
            (
                k,
                points
                    .into_iter()
                    .map(|(x, (sum, cnt))| (x as f64 / 1e6, sum / cnt as f64))
                    .collect(),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn methods_at_budget_respects_feasibility() {
        // C=5, k=2: min bundles 3 -> budget 0.2 gives n=1 < 3: no loghd k=2
        let m = methods_at_budget(5, 0.2);
        assert!(m.iter().all(|m| !matches!(m, Method::LogHd { k: 2, .. })));
        // budget 0.8 -> n=4 >= 3: loghd k=2 present (paper Fig 3 analysis)
        let m = methods_at_budget(5, 0.8);
        assert!(m.iter().any(|m| matches!(m, Method::LogHd { k: 2, n: 4 })));
        // sparsehd always present
        assert!(m.iter().any(|m| matches!(m, Method::SparseHd { .. })));
    }

    #[test]
    fn tiny_fig3_slice_runs() {
        let scope = Scope {
            d: 128,
            train_cap: 300,
            test_cap: 100,
            seeds: vec![1],
            ps: vec![0.0, 0.8],
            epochs: 1,
        };
        // restrict to one dataset by running methods_at_budget directly
        let spec = data::spec("page").unwrap();
        let ds = data::generate_scaled(spec, 300, 100);
        let opts = TrainOptions { epochs: 1, conv_epochs: 0, ..Default::default() };
        let mut wb = Workbench::new(&ds, scope.d, 1, opts);
        for method in methods_at_budget(wb.classes, 0.8) {
            for &p in &scope.ps {
                let acc = wb.evaluate(method, Precision::B8, p, 1).unwrap();
                assert!((0.0..=1.0).contains(&acc));
            }
        }
    }

    #[test]
    fn series_aggregation_means() {
        let rows = vec![
            Row { dataset: "d".into(), method: "m".into(), budget: 0.4, d: 10, bits: 8, p: 0.0, seed: 1, accuracy: 0.8 },
            Row { dataset: "d".into(), method: "m".into(), budget: 0.4, d: 10, bits: 8, p: 0.0, seed: 2, accuracy: 0.6 },
        ];
        let s = series_by(&rows, |r| Some((r.method.clone(), r.p)));
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].1.len(), 1);
        let (x, y) = s[0].1[0];
        assert_eq!(x, 0.0);
        assert!((y - 0.7).abs() < 1e-12);
    }
}

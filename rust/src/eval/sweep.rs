//! The experiment engine behind every figure harness.
//!
//! A [`Workbench`] holds one dataset encoded at one dimensionality D
//! (the expensive part, done once), trains the shared prototype model,
//! and evaluates any (method, precision, bit-flip p, seed) cell of the
//! paper's grids by corrupting a *copy* of the stored model state —
//! exactly the protocol of §IV-A (test inputs never corrupted; SparseHD
//! flips hit only non-pruned coordinates; LogHD flips hit bundles AND
//! stored profiles).
//!
//! **Dispatch is the model core**: [`Workbench::instance`] materializes
//! the cell's family at its precision as a
//! [`crate::model::HdClassifier`] trait object (`model::instances`),
//! faults go through the shared [`crate::model::inject_value_faults`]
//! bit-plane driver, and scoring is the trait's `predict` — one code
//! path for every family, including ones registered after this engine
//! was written (the DecoHD baseline arrived exactly that way). The
//! pre-trait corruption helpers ([`corrupt`], [`corrupt_profiles`],
//! [`corrupt_masked`]) remain below as the *scalar reference
//! implementations*: `rust/tests/trait_parity.rs` pins the trait path
//! bit-identical to them, stream and all.
//!
//! At 1 and 8 bits the LogHD/Hybrid cells run **flip → infer entirely in
//! the packed domain**: the model is quantized once into a
//! [`QuantizedLogHdModel`](crate::loghd::qmodel::QuantizedLogHdModel),
//! faults flip its packed words, and scoring
//! runs on the corrupted bit-planes (XNOR/popcount resp. i32 int8
//! kernels) with no dequantize round-trip — the stored-state fault model
//! the paper describes, and several times faster per cell. The other
//! widths (2/4-bit, and f32 word upsets) keep the
//! quantize → flip → dequantize → score path.
//!
//! **Measurement-semantics note:** queries are still never *corrupted*,
//! but the packed datapath quantizes them at inference time (1-bit
//! sign-binarizes, 8-bit rounds to int8) — that is what a binary/int8
//! HDC accelerator does, and it is a change from the pre-packed
//! protocol, which scored dequantized models against f32 queries. The
//! 1-bit accuracy series therefore carry a query-binarization component
//! on top of storage effects and are not directly comparable to runs
//! produced before this engine existed (EXPERIMENTS.md §Fig3/§Fig4).
//!
//! **Fault-stream discipline:** every grid cell draws its faults from its
//! own [`SplitMix64`] stream, derived by [`cell_stream`] from
//! (campaign seed, method, precision, flip rate, trial). Cells never
//! share a sequential stream, so a sweep's numbers do not depend on cell
//! visit order or on how many `LOGHD_THREADS` workers evaluate them —
//! the campaign engine (`eval::campaign`) fans cells out over the
//! persistent pool and stays bit-identical at any thread count.
//! (Historically every cell re-seeded from `seed ^ 0xFA17` alone, which
//! made different cells at the same seed draw *identical* fault
//! streams — correlated corruption across methods.)

use std::collections::HashMap;

use anyhow::Result;

use crate::baselines::{DecoHdModel, HybridModel, SparseHdModel};
use crate::data::Dataset;
use crate::encoder::Encoder;
use crate::eval::metrics::accuracy;
use crate::faults::{self, FaultModel, FaultModelKind};
use crate::hd::prototype::{refine_conventional, train_prototypes};
use crate::hd::similarity::activations;
use crate::loghd::model::{LogHdModel, TrainOptions};
use crate::model::{self, instances, HdClassifier};
use crate::quant::{self, Precision};
use crate::tensor::{self, Matrix};
use crate::util::rng::SplitMix64;

pub use crate::model::instances::gather_cols;

/// Which classifier variant a grid cell evaluates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    Conventional,
    /// SparseHD at sparsity S (budget 1-S).
    SparseHd { sparsity: f64 },
    /// LogHD with alphabet k and n bundles.
    LogHd { k: u32, n: usize },
    /// LogHD(k, n) + dimension mask at sparsity S.
    Hybrid { k: u32, n: usize, sparsity: f64 },
    /// DecoHD-style decomposition: shared rank-r basis + coefficients.
    DecoHd { rank: usize },
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::Conventional => "conventional".into(),
            Method::SparseHd { sparsity } => format!("sparsehd(S={sparsity:.2})"),
            Method::LogHd { k, n } => format!("loghd(k={k},n={n})"),
            Method::Hybrid { k, n, sparsity } => {
                format!("hybrid(k={k},n={n},S={sparsity:.2})")
            }
            Method::DecoHd { rank } => format!("decohd(r={rank})"),
        }
    }
}

/// One dataset, encoded once at dimension D, with the shared prototype
/// model trained; LogHD/Hybrid variants are trained lazily and cached.
pub struct Workbench {
    pub name: String,
    pub classes: usize,
    pub d: usize,
    pub encoder: Encoder,
    pub enc_train: Matrix,
    pub y_train: Vec<i32>,
    pub enc_test: Matrix,
    pub y_test: Vec<i32>,
    pub prototypes: Matrix,
    pub opts: TrainOptions,
    loghd_cache: HashMap<(u32, usize), LogHdModel>,
    /// Hybrid variants keyed by (k, n, sparsity bits) — the masked
    /// re-profile (a GEMM over the training set) is deterministic in the
    /// key, so campaigns build it once in [`Self::warm`] instead of once
    /// per Monte-Carlo job.
    hybrid_cache: HashMap<(u32, usize, u64), HybridModel>,
    /// SparseHD variants keyed by sparsity bits (same rationale: the
    /// saliency sort over C·D prototype magnitudes is deterministic).
    sparse_cache: HashMap<u64, SparseHdModel>,
    /// DecoHD variants keyed by rank (deterministic Gram-matrix
    /// eigendecomposition of the shared prototypes).
    decohd_cache: HashMap<usize, DecoHdModel>,
}

impl Workbench {
    /// Encode + train the shared stack. `opts.k/extra_bundles` are unused
    /// here (each LogHD variant passes its own (k, n)).
    pub fn new(ds: &Dataset, d: usize, encoder_seed: u64, opts: TrainOptions) -> Self {
        let classes = ds.spec.classes;
        let mut encoder = Encoder::new(ds.spec.features, d, encoder_seed);
        let mut enc_train = encoder.encode(&ds.x_train);
        let mu = tensor::col_means(&enc_train);
        tensor::sub_row_inplace(&mut enc_train, &mu);
        encoder.set_mu(mu);
        let enc_test = encoder.encode(&ds.x_test);

        let h0 = train_prototypes(&enc_train, &ds.y_train, classes);
        let prototypes = if opts.conv_epochs > 0 {
            refine_conventional(
                &h0,
                &enc_train,
                &ds.y_train,
                opts.conv_epochs,
                0.05,
                opts.shuffle_seed ^ 0xA5A5,
                opts.batch,
            )
        } else {
            h0
        };
        Self {
            name: ds.spec.name.to_string(),
            classes,
            d,
            encoder,
            enc_train,
            y_train: ds.y_train.clone(),
            enc_test,
            y_test: ds.y_test.clone(),
            prototypes,
            opts,
            loghd_cache: HashMap::new(),
            hybrid_cache: HashMap::new(),
            sparse_cache: HashMap::new(),
            decohd_cache: HashMap::new(),
        }
    }

    /// Train (or fetch) the LogHD variant for (k, n).
    pub fn loghd(&mut self, k: u32, n: usize) -> Result<&LogHdModel> {
        if !self.loghd_cache.contains_key(&(k, n)) {
            let mut opts = self.opts.clone();
            opts.k = k;
            let model = LogHdModel::from_prototypes_with_n(
                &self.prototypes,
                &self.enc_train,
                &self.y_train,
                n,
                &opts,
            )?;
            self.loghd_cache.insert((k, n), model);
        }
        Ok(&self.loghd_cache[&(k, n)])
    }

    /// Pre-train everything `method` needs so that [`evaluate_cell`]
    /// (the shared-`&self` form campaigns run concurrently) can serve it
    /// from the cache.
    ///
    /// [`evaluate_cell`]: Self::evaluate_cell
    pub fn warm(&mut self, method: Method) -> Result<()> {
        match method {
            Method::LogHd { k, n } => {
                self.loghd(k, n)?;
            }
            Method::Hybrid { k, n, sparsity } => {
                self.loghd(k, n)?;
                let key = (k, n, sparsity.to_bits());
                if !self.hybrid_cache.contains_key(&key) {
                    let hybrid = HybridModel::from_loghd(
                        &self.loghd_cache[&(k, n)],
                        &self.enc_train,
                        &self.y_train,
                        sparsity,
                    )?;
                    self.hybrid_cache.insert(key, hybrid);
                }
            }
            Method::SparseHd { sparsity } => {
                self.sparse_cache
                    .entry(sparsity.to_bits())
                    .or_insert_with(|| SparseHdModel::from_prototypes(&self.prototypes, sparsity));
            }
            Method::DecoHd { rank } => {
                if !self.decohd_cache.contains_key(&rank) {
                    let model = DecoHdModel::from_prototypes(&self.prototypes, rank)?;
                    self.decohd_cache.insert(rank, model);
                }
            }
            Method::Conventional => {}
        }
        Ok(())
    }

    /// Cache-only LogHD lookup for the `&self` evaluation path.
    fn loghd_cached(&self, k: u32, n: usize) -> Result<&LogHdModel> {
        self.loghd_cache.get(&(k, n)).ok_or_else(|| {
            anyhow::anyhow!("LogHD(k={k}, n={n}) not trained — call Workbench::warm first")
        })
    }

    /// Cache-only hybrid lookup for the `&self` evaluation path.
    fn hybrid_cached(&self, k: u32, n: usize, sparsity: f64) -> Result<&HybridModel> {
        self.hybrid_cache.get(&(k, n, sparsity.to_bits())).ok_or_else(|| {
            anyhow::anyhow!(
                "Hybrid(k={k}, n={n}, S={sparsity}) not trained — call Workbench::warm first"
            )
        })
    }

    /// Cache-only SparseHD lookup for the `&self` evaluation path.
    fn sparse_cached(&self, sparsity: f64) -> Result<&SparseHdModel> {
        self.sparse_cache.get(&sparsity.to_bits()).ok_or_else(|| {
            anyhow::anyhow!("SparseHD(S={sparsity}) not built — call Workbench::warm first")
        })
    }

    /// Cache-only DecoHD lookup for the `&self` evaluation path.
    fn decohd_cached(&self, rank: usize) -> Result<&DecoHdModel> {
        self.decohd_cache.get(&rank).ok_or_else(|| {
            anyhow::anyhow!("DecoHD(r={rank}) not built — call Workbench::warm first")
        })
    }

    /// Evaluate one grid cell; returns test accuracy.
    ///
    /// Convenience wrapper: warms the model cache, derives the cell's
    /// private fault stream via [`cell_stream`] (trial 0 — fold extra
    /// trials into `seed`, or use [`Self::evaluate_cell`] directly), and
    /// evaluates.
    pub fn evaluate(
        &mut self,
        method: Method,
        precision: Precision,
        flip_p: f64,
        seed: u64,
    ) -> Result<f64> {
        self.warm(method)?;
        let mut rng = cell_stream(seed, &method, precision, flip_p, 0);
        self.evaluate_cell(method, precision, flip_p, &mut rng)
    }

    /// Materialize the cell's classifier as a [`HdClassifier`] trait
    /// object: the family model from the warm cache, snapshotted at
    /// `precision` with its stored state in exactly the bit-plane form
    /// the fault injector corrupts (packed-domain inference at the 1/8
    /// bit LogHD widths — see `model::instances`). This is the one
    /// dispatch point of the sweep engine; everything downstream is
    /// trait calls.
    pub fn instance(
        &self,
        method: Method,
        precision: Precision,
    ) -> Result<Box<dyn HdClassifier>> {
        Ok(match method {
            Method::Conventional => instances::conventional(&self.prototypes, precision),
            Method::SparseHd { sparsity } => {
                instances::sparsehd(self.sparse_cached(sparsity)?, precision)
            }
            Method::LogHd { k, n } => instances::loghd(self.loghd_cached(k, n)?, precision),
            Method::Hybrid { k, n, sparsity } => {
                instances::hybrid(self.hybrid_cached(k, n, sparsity)?, precision)
            }
            Method::DecoHd { rank } => instances::decohd(self.decohd_cached(rank)?, precision),
        })
    }

    /// Evaluate one grid cell against a caller-provided fault stream,
    /// without touching the model cache (shared-`&self`, so campaigns
    /// may fan cells out across the worker pool). Every model the cell
    /// needs must have been trained via [`Self::warm`] first.
    ///
    /// Uniform across families: build the cell [`instance`], drive its
    /// stored bit-planes through [`model::inject_value_faults`] (one
    /// flip-mask draw per plane, in surface order — byte-identical to
    /// the pre-trait dispatch), score with the trait's `predict`.
    ///
    /// [`instance`]: Self::instance
    pub fn evaluate_cell(
        &self,
        method: Method,
        precision: Precision,
        flip_p: f64,
        rng: &mut SplitMix64,
    ) -> Result<f64> {
        self.evaluate_cell_fault(method, precision, &FaultModel::BitFlip { p: flip_p }, rng)
    }

    /// [`Self::evaluate_cell`] generalized over the analog fault models:
    /// build the cell [`instance`], drive its stored planes through
    /// [`model::inject_faults`] (one sampled realization per plane, in
    /// surface order), score with the trait's `predict`. At
    /// [`FaultModel::BitFlip`] this IS `evaluate_cell` — same stream,
    /// same flips, same accuracy.
    ///
    /// [`instance`]: Self::instance
    pub fn evaluate_cell_fault(
        &self,
        method: Method,
        precision: Precision,
        fault: &FaultModel,
        rng: &mut SplitMix64,
    ) -> Result<f64> {
        let mut inst = self.instance(method, precision)?;
        model::inject_faults(inst.as_mut(), fault, rng);
        let pred = inst.predict(&self.enc_test);
        Ok(accuracy(&pred, &self.y_test))
    }

    /// Clean accuracy of the conventional model (reference line).
    pub fn conventional_clean(&self) -> f64 {
        let s = activations(&self.enc_test, &self.prototypes);
        let pred: Vec<i32> =
            (0..s.rows()).map(|i| tensor::argmax(s.row(i)) as i32).collect();
        accuracy(&pred, &self.y_test)
    }
}

/// Derive the private fault stream of one (method, precision, flip rate,
/// trial) grid cell from the campaign seed.
///
/// The method's *raw* fields (variant tag, k, n, full sparsity bits —
/// not the display label, whose `{:.2}` sparsity rounding would
/// collide), precision width, flip rate bits, and trial index are
/// folded in through successive [`SplitMix64::fork`] steps, so two
/// cells share a stream only if they are the *same* cell — evaluation
/// order and `LOGHD_THREADS` cannot change any cell's draws.
pub fn cell_stream(
    seed: u64,
    method: &Method,
    precision: Precision,
    flip_p: f64,
    trial: u64,
) -> SplitMix64 {
    let (tag, m1, m2, m3) = match *method {
        Method::Conventional => (0u64, 0, 0, 0),
        Method::SparseHd { sparsity } => (1, sparsity.to_bits(), 0, 0),
        Method::LogHd { k, n } => (2, k as u64, n as u64, 0),
        Method::Hybrid { k, n, sparsity } => (3, k as u64, n as u64, sparsity.to_bits()),
        Method::DecoHd { rank } => (4, rank as u64, 0, 0),
    };
    let mut s = SplitMix64::new(seed ^ 0xFA17);
    let mut s = s.fork(tag);
    let mut s = s.fork(m1);
    let mut s = s.fork(m2);
    let mut s = s.fork(m3);
    let mut s = s.fork(precision.bits() as u64);
    let mut s = s.fork(flip_p.to_bits());
    s.fork(trial)
}

/// [`cell_stream`] extended with the fault-model axis: the kind's salt
/// is folded into the campaign seed, so each fault model sweeps its own
/// independent Monte-Carlo streams. [`FaultModelKind::BitFlip`] salts
/// with 0 — its streams (and therefore the whole digital campaign) are
/// byte-identical to [`cell_stream`]'s.
pub fn fault_cell_stream(
    seed: u64,
    kind: FaultModelKind,
    method: &Method,
    precision: Precision,
    severity: f64,
    trial: u64,
) -> SplitMix64 {
    cell_stream(seed ^ kind.stream_salt(), method, precision, severity, trial)
}

/// Quantize to `precision`, inject faults (per-value single-random-bit
/// upsets with probability `flip_p` — see `faults` module docs for why
/// this is the paper's protocol), dequantize. F32 upsets the raw
/// IEEE-754 words instead.
///
/// **Reference path.** The sweep engine itself now corrupts through the
/// trait layer's bit-plane driver (`model::inject_value_faults`), which
/// consumes the identical fault stream; this helper (and its two
/// variants below) is retained as the direct scalar reference that
/// `rust/tests/trait_parity.rs` pins the trait dispatch against, and
/// for ad-hoc single-tensor ablations.
pub fn corrupt(m: &Matrix, precision: Precision, flip_p: f64, rng: &mut SplitMix64) -> Matrix {
    match precision {
        Precision::F32 => {
            let mut out = m.clone();
            if flip_p > 0.0 {
                faults::flip_values_f32(out.data_mut(), flip_p, rng);
            }
            out
        }
        p => {
            let mut q = quant::quantize(m, p);
            if flip_p > 0.0 {
                faults::flip_values_packed(&mut q.packed, flip_p, rng);
            }
            quant::dequantize(&q)
        }
    }
}

/// Profile corruption in the *stored representation*: LogHD stores the
/// (C, n) activation profiles as deviations from the cross-class mean
/// activation vector plus that n-vector mean (both quantized, both fault
/// targets). Centering matches the quantizer scale to the profiles'
/// informative spread instead of their absolute magnitude, so a worst-case
/// single-bit upset displaces a class profile by O(profile spread) rather
/// than O(profile magnitude) — the representation an implementation that
/// cares about robustness would store, and the LogHD analogue of the unit
/// row-norm storage the prototype/bundle tensors already enjoy.
pub fn corrupt_profiles(
    p_mat: &Matrix,
    precision: Precision,
    flip_p: f64,
    rng: &mut SplitMix64,
) -> Matrix {
    let (c, n) = (p_mat.rows(), p_mat.cols());
    let mean = tensor::col_means(p_mat); // (n,)
    let mut dev = p_mat.clone();
    tensor::sub_row_inplace(&mut dev, &mean);
    // per-coordinate (per-bundle) quantization: bundle loads differ, so
    // deviation scales differ per column; sharing one scale would let the
    // widest column dictate everyone's upset magnitude.
    let mut out = Matrix::zeros(c, n);
    for j in 0..n {
        let col: Vec<f32> = (0..c).map(|r| dev.at(r, j)).collect();
        let col_m = Matrix::from_vec(c, 1, col);
        let col_c = corrupt(&col_m, precision, flip_p, rng);
        for r in 0..c {
            out.set(r, j, col_c.at(r, 0));
        }
    }
    let mean_mat = Matrix::from_vec(1, n, mean);
    let mean_c = corrupt(&mean_mat, precision, flip_p, rng);
    for r in 0..c {
        for j in 0..n {
            let v = out.at(r, j) + mean_c.at(0, j);
            out.set(r, j, v);
        }
    }
    out
}

/// SparseHD-style corruption: only the retained (stored) coordinates are
/// quantized and exposed to flips; pruned coordinates stay exactly zero.
pub fn corrupt_masked(
    m: &Matrix,
    mask: &[bool],
    precision: Precision,
    flip_p: f64,
    rng: &mut SplitMix64,
) -> Matrix {
    assert_eq!(m.cols(), mask.len());
    let kept: Vec<usize> =
        mask.iter().enumerate().filter(|(_, k)| **k).map(|(i, _)| i).collect();
    let mut compact = Matrix::zeros(m.rows(), kept.len());
    for r in 0..m.rows() {
        let src = m.row(r);
        for (cj, &j) in kept.iter().enumerate() {
            compact.set(r, cj, src[j]);
        }
    }
    let corrupted = corrupt(&compact, precision, flip_p, rng);
    let mut out = Matrix::zeros(m.rows(), m.cols());
    for r in 0..m.rows() {
        let dst = out.row_mut(r);
        for (cj, &j) in kept.iter().enumerate() {
            dst[j] = corrupted.at(r, cj);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;

    fn bench_small() -> Workbench {
        let ds = data::generate_scaled(data::spec("page").unwrap(), 600, 200);
        let opts = TrainOptions { epochs: 3, conv_epochs: 1, ..Default::default() };
        Workbench::new(&ds, 256, 0xE5C0DE, opts)
    }

    #[test]
    fn clean_cells_match_direct_models() {
        let mut wb = bench_small();
        let conv = wb.evaluate(Method::Conventional, Precision::F32, 0.0, 1).unwrap();
        assert!((conv - wb.conventional_clean()).abs() < 1e-12);
        assert!(conv > 0.6);
        let log = wb
            .evaluate(Method::LogHd { k: 2, n: 4 }, Precision::F32, 0.0, 1)
            .unwrap();
        assert!(log > 0.55, "loghd clean {log}");
    }

    #[test]
    fn quantization_8bit_close_to_f32() {
        let mut wb = bench_small();
        let f32acc = wb.evaluate(Method::Conventional, Precision::F32, 0.0, 1).unwrap();
        let q8 = wb.evaluate(Method::Conventional, Precision::B8, 0.0, 1).unwrap();
        assert!((f32acc - q8).abs() < 0.05, "{f32acc} vs {q8}");
    }

    #[test]
    fn heavy_flips_destroy_accuracy() {
        let mut wb = bench_small();
        let clean = wb.evaluate(Method::Conventional, Precision::B8, 0.0, 1).unwrap();
        let wrecked = wb.evaluate(Method::Conventional, Precision::B8, 0.5, 1).unwrap();
        assert!(wrecked < clean, "flips should hurt: {wrecked} vs {clean}");
    }

    #[test]
    fn sparsehd_flips_do_not_touch_pruned_dims() {
        let wb = bench_small();
        let model = SparseHdModel::from_prototypes(&wb.prototypes, 0.6);
        let mut rng = SplitMix64::new(3);
        let h = corrupt_masked(&model.prototypes, &model.mask, Precision::B8, 0.4, &mut rng);
        for r in 0..h.rows() {
            for (v, keep) in h.row(r).iter().zip(&model.mask) {
                if !keep {
                    assert_eq!(*v, 0.0);
                }
            }
        }
    }

    #[test]
    fn packed_cells_track_dequantized_cells_when_clean() {
        // The packed-domain 8-bit path must land near the old
        // dequantize-and-score protocol at p = 0 (same quantizer levels,
        // different kernels); 1-bit additionally binarizes queries, so it
        // only gets a loose floor.
        let mut wb = bench_small();
        let f32acc = wb
            .evaluate(Method::LogHd { k: 2, n: 4 }, Precision::F32, 0.0, 1)
            .unwrap();
        let q8 = wb.evaluate(Method::LogHd { k: 2, n: 4 }, Precision::B8, 0.0, 1).unwrap();
        assert!((f32acc - q8).abs() < 0.08, "packed b8 {q8} vs f32 {f32acc}");
        let q1 = wb.evaluate(Method::LogHd { k: 2, n: 4 }, Precision::B1, 0.0, 1).unwrap();
        assert!(q1 > 0.3, "packed b1 collapsed: {q1}");
    }

    #[test]
    fn packed_hybrid_cell_runs_and_degrades() {
        let mut wb = bench_small();
        let method = Method::Hybrid { k: 2, n: 4, sparsity: 0.5 };
        let clean = wb.evaluate(method, Precision::B8, 0.0, 1).unwrap();
        let wrecked = wb.evaluate(method, Precision::B8, 0.6, 1).unwrap();
        assert!((0.0..=1.0).contains(&clean) && clean > 0.4, "hybrid clean {clean}");
        assert!(wrecked <= clean + 0.05, "flips should not help: {wrecked} vs {clean}");
    }

    #[test]
    fn decohd_cells_run_clean_and_degrade() {
        let mut wb = bench_small();
        let method = Method::DecoHd { rank: 3 };
        let clean = wb.evaluate(method, Precision::F32, 0.0, 1).unwrap();
        assert!(clean > 0.5, "decohd clean {clean}");
        // clean trait cell == the direct model on the same prototypes
        let direct = {
            let m = crate::baselines::DecoHdModel::from_prototypes(&wb.prototypes, 3).unwrap();
            let pred = m.predict(&wb.enc_test);
            accuracy(&pred, &wb.y_test)
        };
        assert_eq!(clean, direct);
        let wrecked = wb.evaluate(method, Precision::B8, 0.6, 1).unwrap();
        assert!(wrecked <= clean + 0.05, "flips should not help: {wrecked} vs {clean}");
    }

    #[test]
    fn gather_cols_selects_in_order() {
        let m = Matrix::from_vec(2, 4, vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let g = gather_cols(&m, &[0, 2, 3]);
        assert_eq!(g.row(0), &[1., 3., 4.]);
        assert_eq!(g.row(1), &[5., 7., 8.]);
    }

    #[test]
    fn loghd_cache_reuses_models() {
        let mut wb = bench_small();
        let a = wb.loghd(2, 4).unwrap().bundles.clone();
        let b = wb.loghd(2, 4).unwrap().bundles.clone();
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn method_labels() {
        assert_eq!(Method::Conventional.label(), "conventional");
        assert!(Method::SparseHd { sparsity: 0.5 }.label().contains("0.50"));
        assert!(Method::LogHd { k: 3, n: 4 }.label().contains("k=3"));
    }

    #[test]
    fn cell_streams_are_cell_local() {
        // identical cell -> identical stream
        let draw = |m: &Method, pr, p, t| cell_stream(7, m, pr, p, t).next_u64();
        let a = Method::LogHd { k: 2, n: 4 };
        assert_eq!(
            draw(&a, Precision::B8, 0.3, 1),
            draw(&a, Precision::B8, 0.3, 1)
        );
        // any coordinate change -> a different stream
        let base = draw(&a, Precision::B8, 0.3, 1);
        assert_ne!(base, draw(&Method::Conventional, Precision::B8, 0.3, 1));
        assert_ne!(base, draw(&Method::DecoHd { rank: 4 }, Precision::B8, 0.3, 1));
        assert_ne!(base, draw(&a, Precision::B1, 0.3, 1));
        assert_ne!(base, draw(&a, Precision::B8, 0.4, 1));
        assert_ne!(base, draw(&a, Precision::B8, 0.3, 2));
        assert_ne!(base, cell_stream(8, &a, Precision::B8, 0.3, 1).next_u64());
        // sparsities colliding under the label's {:.2} rounding must
        // still get distinct streams (raw bits are folded, not labels)
        let s1 = Method::SparseHd { sparsity: 0.851 };
        let s2 = Method::SparseHd { sparsity: 0.854 };
        assert_eq!(s1.label(), s2.label());
        assert_ne!(
            draw(&s1, Precision::B8, 0.3, 1),
            draw(&s2, Precision::B8, 0.3, 1)
        );
    }

    #[test]
    fn evaluate_cell_requires_warm() {
        let wb = bench_small();
        let mut rng = SplitMix64::new(1);
        let err = wb
            .evaluate_cell(Method::LogHd { k: 2, n: 4 }, Precision::B8, 0.0, &mut rng)
            .unwrap_err();
        assert!(err.to_string().contains("warm"), "{err}");
    }

    #[test]
    fn evaluate_cell_matches_evaluate_after_warm() {
        let mut wb = bench_small();
        let method = Method::LogHd { k: 2, n: 4 };
        let via_mut = wb.evaluate(method, Precision::B8, 0.4, 3).unwrap();
        let mut rng = cell_stream(3, &method, Precision::B8, 0.4, 0);
        let via_cell = wb.evaluate_cell(method, Precision::B8, 0.4, &mut rng).unwrap();
        assert_eq!(via_mut, via_cell);
    }
}

//! Typed run configuration: JSON config files + `--key value` CLI
//! overrides (no serde/clap offline; see `util::json` and `cli`).
//!
//! A config file looks like:
//! ```json
//! {"dataset": "isolet", "d": 10000, "k": 2, "extra_bundles": 5,
//!  "epochs": 30, "conv_epochs": 3, "eta": 0.0003, "batch": 64}
//! ```
//! Every field is optional; defaults follow the paper's §IV-A setup.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::loghd::model::TrainOptions;
use crate::util::json::{self, Value};

/// Full run configuration for train/eval/serve commands.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub dataset: String,
    pub d: usize,
    pub train: TrainOptions,
    pub encoder_seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            dataset: "page".into(),
            d: 2000,
            train: TrainOptions::default(),
            encoder_seed: 0xE5C0DE,
        }
    }
}

impl RunConfig {
    /// Load from a JSON file.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let mut cfg = Self::default();
        cfg.apply_json(&v)?;
        Ok(cfg)
    }

    fn apply_json(&mut self, v: &Value) -> Result<()> {
        let fields = match v {
            Value::Object(fields) => fields,
            _ => bail!("config root must be an object"),
        };
        for (key, val) in fields {
            self.apply_one(key, val)?;
        }
        Ok(())
    }

    fn apply_one(&mut self, key: &str, val: &Value) -> Result<()> {
        let as_usize =
            || val.as_usize().with_context(|| format!("'{key}' must be a number"));
        let as_f64 = || val.as_f64().with_context(|| format!("'{key}' must be a number"));
        match key {
            "dataset" => {
                self.dataset = val.as_str().context("'dataset' must be a string")?.into()
            }
            "d" | "D" => self.d = as_usize()?,
            "k" => self.train.k = as_usize()? as u32,
            "extra_bundles" | "eps" => self.train.extra_bundles = as_usize()?,
            "alpha" => self.train.alpha = as_f64()?,
            "eta" => self.train.eta = as_f64()? as f32,
            "epochs" => self.train.epochs = as_usize()?,
            "conv_epochs" => self.train.conv_epochs = as_usize()?,
            "batch" => self.train.batch = as_usize()?,
            "encoder_seed" => self.encoder_seed = as_f64()? as u64,
            "codebook_seed" => self.train.codebook_seed = as_f64()? as u64,
            "shuffle_seed" => self.train.shuffle_seed = as_f64()? as u64,
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Apply `--key value` overrides (numbers parsed as needed).
    pub fn apply_overrides(&mut self, flags: &HashMap<String, String>) -> Result<()> {
        for (key, raw) in flags {
            let val = match raw.parse::<f64>() {
                Ok(n) => Value::Number(n),
                Err(_) => Value::String(raw.clone()),
            };
            // ignore keys that are not config fields — callers own those
            if matches!(
                key.as_str(),
                "dataset" | "d" | "D" | "k" | "extra_bundles" | "eps" | "alpha" | "eta"
                    | "epochs" | "conv_epochs" | "batch" | "encoder_seed"
                    | "codebook_seed" | "shuffle_seed"
            ) {
                self.apply_one(key, &val)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_follow_paper() {
        let c = RunConfig::default();
        assert_eq!(c.train.k, 2);
        assert!((c.train.eta - 3e-4).abs() < 1e-9);
        assert_eq!(c.train.alpha, 1.0);
    }

    #[test]
    fn parses_json_and_overrides() {
        let mut c = RunConfig::default();
        c.apply_json(&json::parse(r#"{"dataset":"isolet","d":500,"k":3}"#).unwrap()).unwrap();
        assert_eq!(c.dataset, "isolet");
        assert_eq!(c.d, 500);
        assert_eq!(c.train.k, 3);
        let mut flags = HashMap::new();
        flags.insert("epochs".to_string(), "7".to_string());
        flags.insert("addr".to_string(), "127.0.0.1:1".to_string()); // non-config: ignored
        c.apply_overrides(&flags).unwrap();
        assert_eq!(c.train.epochs, 7);
    }

    #[test]
    fn rejects_unknown_json_key() {
        let mut c = RunConfig::default();
        assert!(c.apply_json(&json::parse(r#"{"nope": 1}"#).unwrap()).is_err());
    }
}

//! Row-major f32 matrix substrate.
//!
//! Everything the native (non-XLA) path computes — encoding, similarities,
//! bundling, refinement — runs on this small tensor layer. It is written
//! for clarity first and then hand-optimized where the profile said it
//! matters (see `matmul.rs` and EXPERIMENTS.md §Perf). The inner loops of
//! every kernel dispatch once per process into explicit AVX2/NEON or
//! scalar code — see [`simd`] for the dispatch contract and the
//! `LOGHD_FORCE_SCALAR` escape hatch.
//!
//! # Example
//!
//! The serving hot path is `matmul_nt` — rows of `a` dotted with rows of
//! `b` (i.e. `a · bᵀ`, the activation shape):
//!
//! ```
//! use loghd::tensor::{matmul_nt, Matrix};
//!
//! let a = Matrix::from_vec(2, 3, vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0]);
//! let b = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
//! let c = matmul_nt(&a, &b);
//! assert_eq!((c.rows(), c.cols()), (2, 2));
//! assert_eq!(c.data(), &[1.0, 4.0, 2.0, 5.0]);
//! ```

mod bitops;
mod matmul;
mod ops;
pub mod simd;

pub use bitops::{
    hamming_words, i16_matmul_nt, i16_matmul_nt_into, xnor_popcount_nt, xnor_popcount_nt_into,
    BitMatrix, I16Matrix,
};
pub use matmul::{
    dot_unrolled, matmul, matmul_into, matmul_nt, matmul_nt_into, matmul_nt_with,
    matmul_nt_with_into, matmul_tn, NtPrepared,
};
pub use ops::*;

/// Dense row-major f32 matrix.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch: {rows}x{cols} vs {}", data.len());
        Self { rows, cols, data }
    }

    /// Build from a row-major iterator of rows.
    pub fn from_rows(rows_iter: &[Vec<f32>]) -> Self {
        let rows = rows_iter.len();
        let cols = rows_iter.first().map_or(0, Vec::len);
        let mut data = Vec::with_capacity(rows * cols);
        for r in rows_iter {
            assert_eq!(r.len(), cols, "ragged rows");
            data.extend_from_slice(r);
        }
        Self { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert!(r < self.rows);
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        debug_assert!(r < self.rows);
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// Reshape in place to `rows × cols`, reusing the backing allocation.
    /// Existing contents are NOT preserved meaningfully (rows shift with
    /// the new width); newly exposed elements are zero. Shrinking never
    /// releases capacity, so a scratch matrix resized per batch settles
    /// at the high-water size and stops allocating — the serving hot
    /// path's reuse primitive.
    pub fn resize(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    /// Copy a contiguous block of rows.
    pub fn rows_slice(&self, lo: usize, hi: usize) -> Matrix {
        assert!(lo <= hi && hi <= self.rows);
        Matrix::from_vec(hi - lo, self.cols, self.data[lo * self.cols..hi * self.cols].to_vec())
    }

    /// Transposed copy.
    pub fn transposed(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_accessors() {
        let mut m = Matrix::zeros(2, 3);
        m.set(1, 2, 5.0);
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0]);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
    }

    #[test]
    fn from_rows_and_transpose() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        let t = m.transposed();
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.row(0), &[1.0, 3.0, 5.0]);
        assert_eq!(t.row(1), &[2.0, 4.0, 6.0]);
    }

    #[test]
    fn rows_slice() {
        let m = Matrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let s = m.rows_slice(1, 3);
        assert_eq!(s.rows(), 2);
        assert_eq!(s.row(0), &[3., 4.]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Matrix::from_vec(2, 2, vec![1.0; 3]);
    }
}
